//! Lockstep shared-base comparators: how vLLM (inference) and mLoRA
//! (fine-tuning) batch multiple adapters against one base model.
//!
//! Both share the base instance (so their *memory* story matches
//! Symbiosis's sharing) but execute all co-batched requests **in
//! lockstep**: every layer waits for every client, so small requests
//! inherit the iteration time of the largest co-batched one (paper
//! Table 4) and clients cannot progress at independent rates.
//!
//! Functionally this is `BatchPolicy::Lockstep` on the real executor;
//! this module adds the analytic models the paper-scale figures need.

use crate::config::ModelConfig;

/// vLLM-style lockstep prefill: co-batched requests all take the time of
/// the longest request (padding to max sequence length).
/// Returns per-request latency estimates for a batch of sequence
/// lengths. `per_token_secs` is the calibrated prefill cost per token.
pub fn vllm_lockstep_latency(seq_lens: &[usize], per_token_secs: f64)
                             -> Vec<f64> {
    let max = seq_lens.iter().copied().max().unwrap_or(0);
    // every request pays the max-length execution (plus its own tiny
    // share of batching overhead)
    seq_lens.iter().map(|_| max as f64 * per_token_secs).collect()
}

/// Independent (no-batching) prefill latency for the same requests.
pub fn independent_latency(seq_lens: &[usize], per_token_secs: f64)
                           -> Vec<f64> {
    seq_lens.iter().map(|&s| s as f64 * per_token_secs).collect()
}

/// mLoRA's memory/performance trade-off (paper section 4.2.2):
/// `recompute = true` drops stored activations and recomputes them in
/// backward (slower, less memory); `recompute = false` stores them
/// (faster, more memory, fewer adapters fit).
#[derive(Debug, Clone, Copy)]
pub struct MloraMode {
    pub recompute: bool,
}

impl MloraMode {
    /// Per-GPU memory for `n` co-trained adapters on a shared base.
    pub fn memory_bytes(&self, cfg: &ModelConfig, n: usize, batch: usize,
                        seq: usize, rank: usize, n_targets: usize) -> u64 {
        let acts = if self.recompute {
            // only per-layer boundary activations retained
            (batch * seq) as u64
                * cfg.d_model as u64
                * cfg.n_layers as u64
                * cfg.precision.bytes() as u64
        } else {
            super::dedicated::activation_bytes(cfg, batch, seq)
        };
        cfg.param_bytes()
            + n as u64
                * (acts
                    + cfg.kv_cache_bytes(batch, seq)
                    + cfg.lora_params(rank, n_targets) * 4
                    + cfg.optimizer_bytes(rank, n_targets))
    }

    /// Iteration-time multiplier vs the stored-activation path:
    /// recompute re-runs the forward during backward (~1.33x of fwd+bwd).
    pub fn time_multiplier(&self) -> f64 {
        if self.recompute {
            4.0 / 3.0
        } else {
            1.0
        }
    }

    /// Adapters that fit one GPU.
    pub fn max_adapters(&self, cfg: &ModelConfig, capacity: u64,
                        batch: usize, seq: usize, rank: usize,
                        n_targets: usize) -> usize {
        let mut n = 0;
        while self.memory_bytes(cfg, n + 1, batch, seq, rank, n_targets)
            <= capacity
        {
            n += 1;
        }
        n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LLAMA2_13B;
    use crate::device::GIB;

    #[test]
    fn lockstep_penalizes_small_requests() {
        // paper Table 4: small+large batched -> small pays large's time
        let lat = vllm_lockstep_latency(&[1, 512], 0.007);
        assert!((lat[0] - lat[1]).abs() < 1e-9);
        let ind = independent_latency(&[1, 512], 0.007);
        assert!(ind[0] < lat[0] / 100.0);
    }

    #[test]
    fn recompute_saves_memory_but_costs_time() {
        let fast = MloraMode { recompute: false };
        let lean = MloraMode { recompute: true };
        let mf = fast.memory_bytes(&LLAMA2_13B, 4, 2, 512, 8, 4);
        let ml = lean.memory_bytes(&LLAMA2_13B, 4, 2, 512, 8, 4);
        assert!(ml < mf);
        assert!(lean.time_multiplier() > fast.time_multiplier());
    }

    #[test]
    fn recompute_fits_more_adapters() {
        let fast = MloraMode { recompute: false };
        let lean = MloraMode { recompute: true };
        let nf = fast.max_adapters(&LLAMA2_13B, 80 * GIB, 2, 512, 8, 4);
        let nl = lean.max_adapters(&LLAMA2_13B, 80 * GIB, 2, 512, 8, 4);
        assert!(nl >= nf);
    }
}
