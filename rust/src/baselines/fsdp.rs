//! FSDP data-parallel fine-tuning baseline (paper sections 4.2.2-4.2.3).
//!
//! The comparison point for the sharded Symbiosis configurations: FSDP
//! shards the model over N GPUs and trains **one common adapter** with
//! data parallelism — so it must (a) all-gather parameters per layer like
//! sharded Symbiosis, and (b) additionally all-reduce adapter gradients
//! every iteration, and (c) dedicate all N GPUs to a single adapter.
//! Symbiosis instead serves N *different* adapters from the same shards.

use crate::config::ModelConfig;
use crate::coordinator::sharding::ShardPlan;
use crate::device::{Device, DeviceKind};
use crate::transport::LinkKind;

/// Analytic FSDP iteration for one adapter over `shards` GPUs.
#[derive(Debug, Clone)]
pub struct FsdpTrainer {
    pub cfg: ModelConfig,
    pub shards: usize,
    pub batch: usize,
    pub seq: usize,
}

impl FsdpTrainer {
    /// Per-GPU memory: parameter shard + gathered block + local runtime
    /// state (matches the paper's measured ~17GB/GPU for Llama2-13B over
    /// 2 GPUs).
    pub fn memory_per_gpu(&self, rank: usize, n_targets: usize) -> u64 {
        let plan = ShardPlan::new(self.cfg.clone(), self.shards);
        plan.resident_bytes_per_gpu()
            + plan.block_working_set()
            + self.cfg.kv_cache_bytes(self.batch, self.seq) / self.shards as u64
            + self.cfg.lora_params(rank, n_targets) * 4
            + self.cfg.optimizer_bytes(rank, n_targets)
    }

    /// Simulated seconds per iteration (fwd+bwd+step) on A100-80s.
    pub fn iteration_secs(&self, rank: usize, n_targets: usize) -> f64 {
        let dev = Device::new("fsdp", DeviceKind::GpuA100_80);
        let t = (self.batch * self.seq) as u64;
        // per-GPU compute: 1/shards of the batch, fwd + 2x bwd
        let flops = 3 * self.cfg.forward_flops(t, self.seq as u64)
            / self.shards as u64;
        let compute = dev.op_time(flops, self.cfg.param_bytes()
                                  / self.shards as u64,
                                  self.cfg.precision);
        // parameter all-gather per layer, both passes
        let plan = ShardPlan::new(self.cfg.clone(), self.shards);
        let fetch = 2.0 * plan.fetch_secs_per_pass(0.5);
        // adapter gradient all-reduce (2x adapter bytes ring cost)
        let grad_bytes = self.cfg.lora_params(rank, n_targets) * 4;
        let allreduce = if self.shards > 1 {
            LinkKind::NvLink.transfer_time(2 * grad_bytes)
        } else {
            0.0
        };
        compute + fetch + allreduce
    }

    /// Tokens/s for `n_replicas` independent FSDP processes (each over
    /// `shards` GPUs) — how the paper runs "4 FSDP processes in parallel
    /// on 2 GPUs".
    pub fn throughput(&self, n_replicas: usize, rank: usize,
                      n_targets: usize) -> f64 {
        let iter = self.iteration_secs(rank, n_targets);
        // replicas contend for the same GPUs: time dilates linearly
        let effective = iter * n_replicas as f64;
        (self.batch * self.seq * n_replicas) as f64 / effective
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LLAMA2_13B;
    use crate::device::GIB;

    #[test]
    fn fsdp_13b_2gpu_memory_matches_paper() {
        // paper: "FSDP occupies 17GB of memory on each of the two GPUs"
        let t = FsdpTrainer { cfg: LLAMA2_13B, shards: 2, batch: 2,
                              seq: 512 };
        let gb = t.memory_per_gpu(8, 4) as f64 / GIB as f64;
        assert!((gb - 17.0).abs() < 4.0, "got {gb} GB");
    }

    #[test]
    fn gradient_sync_makes_fsdp_slower_than_frozen_sharding() {
        let t = FsdpTrainer { cfg: LLAMA2_13B, shards: 2, batch: 2,
                              seq: 512 };
        let one = t.iteration_secs(8, 4);
        assert!(one > 0.0);
        // more replicas on same GPUs do not increase total throughput
        let tp1 = t.throughput(1, 8, 4);
        let tp4 = t.throughput(4, 8, 4);
        assert!((tp1 - tp4).abs() / tp1 < 1e-6);
    }
}
