//! Comparator systems for the paper's evaluation:
//! * [`dedicated`] — per-job model instance (HF-Transformers baseline).
//! * [`lockstep`] — shared base, lockstep batching (vLLM / mLoRA).
//! * [`fsdp`] — FSDP data-parallel single-adapter trainer.
//!
//! The policies are reimplemented on the same substrate as Symbiosis so
//! the benches compare batching/placement policy, not implementation
//! accidents.

pub mod dedicated;
pub mod fsdp;
pub mod lockstep;
