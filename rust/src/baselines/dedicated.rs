//! The "Baseline" of the paper's evaluation: HF-Transformers-style
//! fine-tuning/inference where **every job deploys its own base-model
//! instance** — no sharing, no cross-job batching.
//!
//! Functionally we reuse the same composition machinery by giving each
//! job a *private* executor (batch size is then always 1 and the base
//! weights are replicated per job); the memory model below charges a full
//! model instance per job, which is exactly what Figs. 9-12 compare
//! against.

use std::path::Path;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::coordinator::{Adapter, BatchPolicy, Deployment, Placement};

/// One dedicated job: private executor + single client.
pub struct DedicatedJob {
    pub deployment: Deployment,
}

impl DedicatedJob {
    /// Spin up a private base-model instance for one job.
    pub fn start(cfg: &ModelConfig, artifact_dir: &Path)
                 -> Result<DedicatedJob> {
        let deployment = Deployment::start(cfg, artifact_dir,
                                           BatchPolicy::NoLockstep,
                                           Placement::Local)?;
        Ok(DedicatedJob { deployment })
    }

    pub fn client_core(&self, adapter: Option<Adapter>)
                       -> crate::coordinator::ClientCore {
        self.deployment.client_core(adapter)
    }

    /// Session builder against this job's private executor.
    pub fn session(&self) -> crate::coordinator::SessionBuilder<'_> {
        self.deployment.session()
    }

    /// Trainer builder against this job's private executor.
    pub fn trainer(&self) -> crate::coordinator::TrainerBuilder<'_> {
        self.deployment.trainer()
    }
}

/// Allocator overhead on measured GPU memory: the PyTorch caching
/// allocator + transient workspaces roughly double the live runtime
/// state (calibrated so Fig 10 reproduces the paper's measured
/// 5-clients-fit on 80GB; parameters are not affected).
pub const ALLOC_OVERHEAD: f64 = 2.0;

/// Runtime state of one fine-tuning job (KV/activations/optimizer/
/// adapter), including allocator overhead — the per-client memory the
/// paper's Figs 1/9/10 plot.
pub fn client_state_bytes(cfg: &ModelConfig, batch: usize, seq: usize,
                          rank: usize, n_targets: usize) -> u64 {
    let live = cfg.kv_cache_bytes(batch, seq)
        + cfg.lora_params(rank, n_targets) * 4
        + cfg.optimizer_bytes(rank, n_targets)
        + activation_bytes(cfg, batch, seq);
    (live as f64 * ALLOC_OVERHEAD) as u64
}

/// Analytic GPU memory for `n_jobs` dedicated fine-tuning jobs
/// (paper Fig. 10 "baseline"): each job holds a full model instance plus
/// its runtime state.
pub fn memory_bytes(cfg: &ModelConfig, n_jobs: usize, batch: usize,
                    seq: usize, rank: usize, n_targets: usize) -> u64 {
    let per_job = cfg.param_bytes()
        + client_state_bytes(cfg, batch, seq, rank, n_targets);
    n_jobs as u64 * per_job
}

/// Stored-activation bytes of a full autograd training pass (what the
/// baseline's computation graph retains; Symbiosis-MO avoids this on the
/// executor side).
pub fn activation_bytes(cfg: &ModelConfig, batch: usize, seq: usize)
                        -> u64 {
    let t = (batch * seq) as u64;
    // per block: qkv out (3d) + attn (d) + 2 norms (2d) + mlp (d_ff + d)
    let linear = t
        * (7 * cfg.d_model as u64 + cfg.d_ff as u64)
        * cfg.precision.bytes() as u64;
    // eager-attention models (GPT2, GPTBigCode) also retain the
    // (B, H, S, S) score/prob matrices for backward — the dominant term
    // at longer sequences; SDPA/flash models (Llama, Gemma) do not.
    let heads = if cfg.eager_attn {
        if cfg.kv_heads == 1 { 1 } else { cfg.n_heads as u64 }
    } else {
        0
    };
    let scores = 2
        * batch as u64
        * heads
        * (seq as u64).pow(2)
        * cfg.precision.bytes() as u64;
    cfg.n_layers as u64 * (linear + scores)
}

/// Max dedicated jobs that fit one GPU (the paper: "the baseline can
/// only accommodate 2 independent fine-tuning jobs" on 80GB for
/// Llama2-13B).
pub fn max_jobs(cfg: &ModelConfig, gpu_capacity: u64, batch: usize,
                seq: usize, rank: usize, n_targets: usize) -> usize {
    let mut n = 0;
    while memory_bytes(cfg, n + 1, batch, seq, rank, n_targets)
        <= gpu_capacity
    {
        n += 1;
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LLAMA2_13B;
    use crate::device::GIB;

    #[test]
    fn baseline_fits_two_13b_jobs_on_80gb() {
        // paper section 4.1.2: baseline fits only 2 jobs on 80GB
        let n = max_jobs(&LLAMA2_13B, 80 * GIB, 2, 512, 8, 4);
        assert_eq!(n, 2, "got {n}");
    }

    #[test]
    fn memory_scales_linearly_with_jobs() {
        let one = memory_bytes(&LLAMA2_13B, 1, 2, 512, 8, 4);
        let three = memory_bytes(&LLAMA2_13B, 3, 2, 512, 8, 4);
        assert_eq!(three, 3 * one);
    }
}
