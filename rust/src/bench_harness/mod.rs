//! Minimal criterion-like measurement harness (criterion is not in the
//! vendored registry — DESIGN.md section 8).
//!
//! Usage from a `harness = false` bench binary:
//! ```ignore
//! let mut b = Bench::new("fig11_single_gpu");
//! b.row("clients=2", || iteration());
//! b.report();
//! ```
//!
//! Sections that feed CI artifacts (e.g. `BENCH_pipeline.json`)
//! serialize through the hand-rolled [`JsonValue`] builder — the
//! vendored registry carries no serde, and the emitted documents are
//! small, flat tables.

use std::time::Instant;

/// One measured row of a bench table.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub iters: u32,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

/// A named bench that measures closures and prints a fixed-width table.
pub struct Bench {
    pub name: String,
    pub rows: Vec<Row>,
    warmup: u32,
    iters: u32,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench { name: name.to_string(), rows: Vec::new(), warmup: 1, iters: 5 }
    }

    pub fn with_iters(mut self, warmup: u32, iters: u32) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Measure `f` (called `iters` times after warmup) under `label`.
    pub fn row<F: FnMut()>(&mut self, label: &str, mut f: F) -> &Row {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().copied().fold(f64::INFINITY, f64::min);
        let max = times.iter().copied().fold(0.0, f64::max);
        self.rows.push(Row {
            label: label.to_string(),
            iters: self.iters,
            mean_secs: mean,
            min_secs: min,
            max_secs: max,
        });
        self.rows.last().unwrap()
    }

    /// Record an externally measured value (e.g. simulated seconds).
    pub fn record(&mut self, label: &str, secs: f64) {
        self.rows.push(Row {
            label: label.to_string(),
            iters: 1,
            mean_secs: secs,
            min_secs: secs,
            max_secs: secs,
        });
    }

    pub fn report(&self) {
        println!("\n== bench: {} ==", self.name);
        println!("{:<44} {:>12} {:>12} {:>12}", "case", "mean", "min", "max");
        for r in &self.rows {
            println!("{:<44} {:>12} {:>12} {:>12}",
                     r.label, fmt_secs(r.mean_secs), fmt_secs(r.min_secs),
                     fmt_secs(r.max_secs));
        }
    }
}

// ---------------------------------------------------------------------------
// Dependency-free JSON emission (bench artifacts for CI)
// ---------------------------------------------------------------------------

/// A minimal JSON document builder: enough for the flat tables the
/// bench sections emit as CI artifacts — no serde in the vendored
/// registry, and nothing here needs parsing back.
#[derive(Debug, Clone)]
pub enum JsonValue {
    Bool(bool),
    Int(i64),
    /// Serialized with enough precision to round-trip an f64; NaN and
    /// infinities become `null` (JSON has no spelling for them).
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    /// Insertion-ordered object.
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Convenience: build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, JsonValue)>) -> JsonValue {
        JsonValue::Obj(
            pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn render(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            JsonValue::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            JsonValue::Int(i) => out.push_str(&i.to_string()),
            JsonValue::Num(n) => {
                if n.is_finite() {
                    out.push_str(&format!("{n}"));
                } else {
                    out.push_str("null");
                }
            }
            JsonValue::Str(s) => {
                out.push('"');
                out.push_str(&json_escape(s));
                out.push('"');
            }
            JsonValue::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            JsonValue::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    out.push_str(&json_escape(k));
                    out.push_str("\":");
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Escape a string for a JSON literal.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// The shared bench-record schema (symbiosis-bench-v1)
// ---------------------------------------------------------------------------

/// Schema tag stamped into every CI bench artifact so downstream
/// tooling can diff `BENCH_*.json` files across PRs without guessing
/// at their shape.
pub const BENCH_SCHEMA: &str = "symbiosis-bench-v1";

/// Build one standardized bench record.  Every CI artifact
/// (`BENCH_pipeline.json`, `BENCH_chaos.json`, `BENCH_overload.json`,
/// `BENCH_serving.json`) is an array of these:
///
/// ```json
/// { "schema": "symbiosis-bench-v1", "name": "...", "quick": true,
///   "config": {...}, "percentiles": {...}, "counters": {...},
///   "detail": {...} }
/// ```
///
/// * `config` — the knobs that shaped the run (shards, sessions, seed);
/// * `percentiles` — latency distributions, milliseconds, named
///   `<metric>_p<q>_ms`;
/// * `counters` — monotone totals (requests, sheds, retries);
/// * `detail` — anything section-specific that fits neither bucket.
///
/// Keys inside each sub-object are section-defined; the four top-level
/// buckets are the stable contract.
pub fn bench_record(name: &str, quick: bool,
                    config: Vec<(&str, JsonValue)>,
                    percentiles: Vec<(&str, JsonValue)>,
                    counters: Vec<(&str, JsonValue)>,
                    detail: Vec<(&str, JsonValue)>) -> JsonValue {
    JsonValue::obj(vec![
        ("schema", JsonValue::Str(BENCH_SCHEMA.into())),
        ("name", JsonValue::Str(name.into())),
        ("quick", JsonValue::Bool(quick)),
        ("config", JsonValue::obj(config)),
        ("percentiles", JsonValue::obj(percentiles)),
        ("counters", JsonValue::obj(counters)),
        ("detail", JsonValue::obj(detail)),
    ])
}

/// Nearest-rank percentile over raw samples (`q` in 0..=100).  Returns
/// 0.0 on an empty slice — bench tables render that as "no samples"
/// rather than poisoning the JSON with null.
pub fn percentile_of(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(|a, b| {
        a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal)
    });
    let rank = ((q / 100.0) * (sorted.len() as f64 - 1.0)).round();
    let idx = (rank as usize).min(sorted.len() - 1);
    sorted[idx]
}

/// Human duration formatting: ns/us/ms/s.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_rows() {
        let mut b = Bench::new("t").with_iters(0, 3);
        b.row("noop", || {});
        assert_eq!(b.rows.len(), 1);
        assert!(b.rows[0].mean_secs < 0.01);
    }

    #[test]
    fn formats_durations() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-5).ends_with("us"));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }

    #[test]
    fn json_renders_flat_tables() {
        let doc = JsonValue::obj(vec![
            ("name", JsonValue::Str("pipeline".into())),
            ("ok", JsonValue::Bool(true)),
            ("shards", JsonValue::Int(2)),
            ("mean_ms", JsonValue::Num(1.5)),
            ("rows", JsonValue::Arr(vec![JsonValue::Int(1),
                                         JsonValue::Int(2)])),
        ]);
        assert_eq!(doc.render(),
                   r#"{"name":"pipeline","ok":true,"shards":2,"mean_ms":1.5,"rows":[1,2]}"#);
    }

    #[test]
    fn json_escapes_and_non_finite() {
        assert_eq!(json_escape("a\"b\\c\nd"), "a\\\"b\\\\c\\nd");
        assert_eq!(JsonValue::Num(f64::NAN).render(), "null");
        assert_eq!(JsonValue::Str("x\t".into()).render(), "\"x\\t\"");
    }

    #[test]
    fn bench_record_has_stable_top_level_shape() {
        let rec = bench_record(
            "serving_load_gen", true,
            vec![("sessions", JsonValue::Int(96))],
            vec![("ttft_p50_ms", JsonValue::Num(1.25))],
            vec![("completed", JsonValue::Int(96))],
            vec![]);
        let s = rec.render();
        assert!(s.starts_with(
            r#"{"schema":"symbiosis-bench-v1","name":"serving_load_gen","quick":true"#));
        for key in ["\"config\":", "\"percentiles\":", "\"counters\":",
                    "\"detail\":"] {
            assert!(s.contains(key), "missing bucket {key} in {s}");
        }
    }

    #[test]
    fn percentile_nearest_rank() {
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_of(&xs, 50.0), 51.0);
        assert_eq!(percentile_of(&xs, 0.0), 1.0);
        assert_eq!(percentile_of(&xs, 100.0), 100.0);
        assert_eq!(percentile_of(&[], 99.0), 0.0);
        assert_eq!(percentile_of(&[7.5], 99.0), 7.5);
    }
}
