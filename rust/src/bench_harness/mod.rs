//! Minimal criterion-like measurement harness (criterion is not in the
//! vendored registry — DESIGN.md section 8).
//!
//! Usage from a `harness = false` bench binary:
//! ```ignore
//! let mut b = Bench::new("fig11_single_gpu");
//! b.row("clients=2", || iteration());
//! b.report();
//! ```

use std::time::Instant;

/// One measured row of a bench table.
#[derive(Debug, Clone)]
pub struct Row {
    pub label: String,
    pub iters: u32,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

/// A named bench that measures closures and prints a fixed-width table.
pub struct Bench {
    pub name: String,
    pub rows: Vec<Row>,
    warmup: u32,
    iters: u32,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        Bench { name: name.to_string(), rows: Vec::new(), warmup: 1, iters: 5 }
    }

    pub fn with_iters(mut self, warmup: u32, iters: u32) -> Self {
        self.warmup = warmup;
        self.iters = iters;
        self
    }

    /// Measure `f` (called `iters` times after warmup) under `label`.
    pub fn row<F: FnMut()>(&mut self, label: &str, mut f: F) -> &Row {
        for _ in 0..self.warmup {
            f();
        }
        let mut times = Vec::with_capacity(self.iters as usize);
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            times.push(t0.elapsed().as_secs_f64());
        }
        let mean = times.iter().sum::<f64>() / times.len() as f64;
        let min = times.iter().copied().fold(f64::INFINITY, f64::min);
        let max = times.iter().copied().fold(0.0, f64::max);
        self.rows.push(Row {
            label: label.to_string(),
            iters: self.iters,
            mean_secs: mean,
            min_secs: min,
            max_secs: max,
        });
        self.rows.last().unwrap()
    }

    /// Record an externally measured value (e.g. simulated seconds).
    pub fn record(&mut self, label: &str, secs: f64) {
        self.rows.push(Row {
            label: label.to_string(),
            iters: 1,
            mean_secs: secs,
            min_secs: secs,
            max_secs: secs,
        });
    }

    pub fn report(&self) {
        println!("\n== bench: {} ==", self.name);
        println!("{:<44} {:>12} {:>12} {:>12}", "case", "mean", "min", "max");
        for r in &self.rows {
            println!("{:<44} {:>12} {:>12} {:>12}",
                     r.label, fmt_secs(r.mean_secs), fmt_secs(r.min_secs),
                     fmt_secs(r.max_secs));
        }
    }
}

/// Human duration formatting: ns/us/ms/s.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{s:.3}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_rows() {
        let mut b = Bench::new("t").with_iters(0, 3);
        b.row("noop", || {});
        assert_eq!(b.rows.len(), 1);
        assert!(b.rows[0].mean_secs < 0.01);
    }

    #[test]
    fn formats_durations() {
        assert!(fmt_secs(2.5e-9).ends_with("ns"));
        assert!(fmt_secs(2.5e-5).ends_with("us"));
        assert!(fmt_secs(2.5e-3).ends_with("ms"));
        assert!(fmt_secs(2.5).ends_with('s'));
    }
}
