//! Symbiosis CLI — the launcher.
//!
//! Subcommands (hand-rolled parsing; clap is not in the vendored
//! registry):
//!   serve     — start a base executor + N inference clients
//!   finetune  — co-train N adapters against the shared base
//!   models    — print the model registry (executable + analytic)
//!   artifacts — inspect the AOT manifest
//!
//! Examples live in `examples/`; paper-figure reproductions in
//! `rust/benches/paper_benches.rs` (run: `cargo bench`).

use std::path::PathBuf;

use anyhow::{bail, Result};

use symbiosis::config::{self, SYM_TINY};
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             InferenceSession, KvPlacement, Placement};
use symbiosis::metrics::{gib, LatencyStats, Throughput};
use symbiosis::runtime::Manifest;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "serve" => serve(&args),
        "finetune" => finetune(&args),
        "models" => models(),
        "artifacts" => artifacts(&args),
        _ => {
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "symbiosis — multi-adapter inference and fine-tuning\n\n\
         USAGE: symbiosis <COMMAND> [OPTIONS]\n\n\
         COMMANDS:\n\
           serve      --clients N --requests R --gen-len G [--policy \
         no-lockstep|lockstep|opportunistic]\n\
           finetune   --clients N --steps S --seq L\n\
           models     print the model registry\n\
           artifacts  [--dir PATH] inspect the AOT manifest\n"
    );
}

fn opt<T: std::str::FromStr>(args: &[String], name: &str, default: T)
                             -> T {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

fn opt_str(args: &[String], name: &str, default: &str) -> String {
    args.iter()
        .position(|a| a == name)
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| default.to_string())
}

fn artifact_dir(args: &[String]) -> PathBuf {
    PathBuf::from(opt_str(args, "--dir",
                          concat!(env!("CARGO_MANIFEST_DIR"),
                                  "/artifacts")))
}

fn policy(args: &[String]) -> Result<BatchPolicy> {
    Ok(match opt_str(args, "--policy", "opportunistic").as_str() {
        "no-lockstep" => BatchPolicy::NoLockstep,
        "lockstep" => BatchPolicy::Lockstep,
        "opportunistic" => BatchPolicy::opportunistic_default(),
        "continuous" => BatchPolicy::Continuous,
        other => bail!("unknown policy {other}"),
    })
}

fn serve(args: &[String]) -> Result<()> {
    let n_clients: usize = opt(args, "--clients", 4);
    let n_requests: usize = opt(args, "--requests", 4);
    let gen_len: usize = opt(args, "--gen-len", 16);
    let dir = artifact_dir(args);
    let dep = Deployment::start(&SYM_TINY, &dir, policy(args)?,
                                Placement::Local)?;
    println!("serving {} to {n_clients} clients...", SYM_TINY.name);
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let adapter = if c % 2 == 1 {
            Some(Adapter::lora_from_artifacts(&SYM_TINY, &dir, 8,
                                              LoraTargets::QKVO, 2.0)?)
        } else {
            None
        };
        let core = dep.client_core(adapter);
        handles.push(std::thread::spawn(move || -> Result<_> {
            let mut lat = LatencyStats::new();
            let mut tput = Throughput::start();
            for r in 0..n_requests {
                // fresh session per request; the core (and its executor
                // registration) is shared across them
                let mut sess = InferenceSession::new(
                    core.clone(), 1, KvPlacement::Device)?;
                let prompt: Vec<i32> = (0..16)
                    .map(|k| ((c * 71 + r * 13 + k) % 256) as i32)
                    .collect();
                sess.prefill(&prompt)?;
                for _ in 1..gen_len {
                    let t = std::time::Instant::now();
                    sess.decode_step()?;
                    lat.record(t.elapsed());
                }
                tput.add(gen_len as u64);
            }
            Ok((c, lat, tput.tokens_per_sec()))
        }));
    }
    for h in handles {
        let (c, lat, tps) = h.join().unwrap()?;
        println!("client {c}: p50 {:.2}ms p99 {:.2}ms  {tps:.1} tok/s",
                 lat.p50() * 1e3, lat.p99() * 1e3);
    }
    let stats = dep.shutdown();
    println!("executor: {} flushes, avg batch {:.2}, wait {:.2}ms",
             stats.n_flushes, stats.mean_batch_clients(),
             stats.mean_wait_secs() * 1e3);
    Ok(())
}

fn finetune(args: &[String]) -> Result<()> {
    let n_clients: usize = opt(args, "--clients", 2);
    let steps: usize = opt(args, "--steps", 20);
    let seq: usize = opt(args, "--seq", 32);
    let dir = artifact_dir(args);
    let dep = Deployment::start(&SYM_TINY, &dir, policy(args)?,
                                Placement::Local)?;
    println!("fine-tuning {n_clients} adapters x {steps} steps...");
    let mut handles = Vec::new();
    for c in 0..n_clients {
        let adapter = Adapter::lora_from_artifacts(
            &SYM_TINY, &dir, if c % 2 == 0 { 8 } else { 64 },
            LoraTargets::QKVO, 2.0)?;
        let tr = dep.trainer().adapter(adapter).build()?;
        handles.push(std::thread::spawn(move || -> Result<_> {
            let mut tr = tr;
            let mut first = 0.0;
            let mut last = 0.0;
            for s in 0..steps {
                let tokens: Vec<i32> = (0..seq)
                    .map(|k| ((c * 31 + s + k * 7) % 256) as i32)
                    .collect();
                let labels: Vec<i32> = tokens
                    .iter()
                    .map(|t| (t * 3 + 1) % 256)
                    .collect();
                let out = tr.train_step(&tokens, &labels)?;
                if s == 0 {
                    first = out.loss;
                }
                last = out.loss;
            }
            Ok((c, first, last))
        }));
    }
    for h in handles {
        let (c, first, last) = h.join().unwrap()?;
        println!("client {c}: loss {first:.4} -> {last:.4}");
    }
    dep.shutdown();
    Ok(())
}

fn models() -> Result<()> {
    println!("{:<16} {:>8} {:>8} {:>8} {:>8} {:>10} {:>6}", "name",
             "layers", "d_model", "heads", "d_ff", "params", "exec");
    for name in ["sym-tiny", "sym-small", "gpt2-xl", "llama3-1b",
                 "llama2-7b", "llama2-13b", "granite-20b",
                 "starcoder-15b", "gemma2-27b"] {
        let m = config::model_by_name(name).unwrap();
        println!("{:<16} {:>8} {:>8} {:>8} {:>8} {:>9.1}B {:>6}",
                 m.name, m.n_layers, m.d_model, m.n_heads, m.d_ff,
                 m.n_params() as f64 / 1e9, m.executable);
    }
    println!("\nKV cache (batch 2, seq 512):");
    for name in ["llama2-7b", "llama2-13b", "granite-20b"] {
        let m = config::model_by_name(name).unwrap();
        println!("  {:<14} {:.2} GiB", m.name,
                 gib(m.kv_cache_bytes(2, 512)));
    }
    Ok(())
}

fn artifacts(args: &[String]) -> Result<()> {
    let dir = artifact_dir(args);
    let m = Manifest::load(&dir)?;
    println!("manifest at {}:", dir.display());
    for model in &m.models {
        println!("  model {} (d={}, layers={})", model.name,
                 model.d_model, model.n_layers);
    }
    let mut kinds: std::collections::BTreeMap<&str, usize> =
        Default::default();
    for name in m.artifacts.keys() {
        let kind = name.split('_').next().unwrap_or("?");
        *kinds.entry(kind).or_default() += 1;
    }
    println!("  {} artifacts:", m.artifacts.len());
    for (k, n) in kinds {
        println!("    {k:<12} {n}");
    }
    Ok(())
}
