//! SYMT named-tensor container reader/writer.
//!
//! Byte-compatible with `python/compile/container.py` (there is a
//! round-trip test on each side). Layout: `b"SYMT"`, version u32, count
//! u32, then per tensor: name (u32 len + utf-8), dtype u8, ndim u8,
//! dims u32×ndim, raw little-endian data.

use std::collections::HashMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{DType, Tensor};

const MAGIC: &[u8; 4] = b"SYMT";
const VERSION: u32 = 1;

/// Read all tensors from a SYMT file.
pub fn read_tensors(path: &Path) -> Result<HashMap<String, Tensor>> {
    let buf = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    read_tensors_bytes(&buf)
}

/// Read all tensors from SYMT bytes.
pub fn read_tensors_bytes(buf: &[u8]) -> Result<HashMap<String, Tensor>> {
    let mut r = buf;
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("bad SYMT magic {:?}", magic);
    }
    let version = read_u32(&mut r)?;
    if version != VERSION {
        bail!("unsupported SYMT version {version}");
    }
    let count = read_u32(&mut r)? as usize;
    let mut out = HashMap::with_capacity(count);
    for _ in 0..count {
        let nlen = read_u32(&mut r)? as usize;
        let mut name = vec![0u8; nlen];
        r.read_exact(&mut name)?;
        let name = String::from_utf8(name)?;
        let mut hdr = [0u8; 2];
        r.read_exact(&mut hdr)?;
        let dtype = DType::from_code(hdr[0])?;
        let ndim = hdr[1] as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        let t = match dtype {
            DType::F32 => {
                let mut v = vec![0f32; n];
                let bytes = unsafe {
                    std::slice::from_raw_parts_mut(
                        v.as_mut_ptr() as *mut u8, n * 4)
                };
                r.read_exact(bytes)?;
                Tensor::from_f32_raw(v, &shape)
            }
            DType::I32 => {
                let mut v = vec![0i32; n];
                let bytes = unsafe {
                    std::slice::from_raw_parts_mut(
                        v.as_mut_ptr() as *mut u8, n * 4)
                };
                r.read_exact(bytes)?;
                Tensor::from_i32_raw(v, &shape)
            }
        };
        out.insert(name, t);
    }
    Ok(out)
}

/// Write tensors to a SYMT file (sorted by name for determinism).
pub fn write_tensors(path: &Path, tensors: &HashMap<String, Tensor>)
                     -> Result<()> {
    let mut f = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    let mut names: Vec<&String> = tensors.keys().collect();
    names.sort();
    for name in names {
        let t = &tensors[name];
        f.write_all(&(name.len() as u32).to_le_bytes())?;
        f.write_all(name.as_bytes())?;
        f.write_all(&[t.dtype().code(), t.shape.len() as u8])?;
        for d in &t.shape {
            f.write_all(&(*d as u32).to_le_bytes())?;
        }
        match t.dtype() {
            DType::F32 => {
                let v = t.as_f32();
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        v.as_ptr() as *const u8, v.len() * 4)
                };
                f.write_all(bytes)?;
            }
            DType::I32 => {
                let v = t.as_i32();
                let bytes = unsafe {
                    std::slice::from_raw_parts(
                        v.as_ptr() as *const u8, v.len() * 4)
                };
                f.write_all(bytes)?;
            }
        }
    }
    Ok(())
}

fn read_u32(r: &mut &[u8]) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut m = HashMap::new();
        m.insert("a".to_string(),
                 Tensor::from_f32(vec![1.0, 2.5, -3.0], &[3]));
        m.insert("b".to_string(),
                 Tensor::from_i32(vec![7, -9], &[2, 1]));
        let dir = std::env::temp_dir().join("symt_test.bin");
        write_tensors(&dir, &m).unwrap();
        let back = read_tensors(&dir).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back["a"], m["a"]);
        assert_eq!(back["b"], m["b"]);
    }

    #[test]
    fn rejects_bad_magic() {
        assert!(read_tensors_bytes(b"NOPE\0\0\0\0").is_err());
    }
}
