//! Native client-side math: the cheap elementwise pieces of the model that
//! are not worth a PJRT dispatch (residuals, RMSNorm, GELU, LoRA scaling,
//! noise add/sub for the privacy protocol, argmax).
//!
//! Formulas mirror `python/compile/kernels/ref.py` exactly — the Rust
//! integration tests compare full-model outputs against jax goldens, which
//! transitively pins these implementations.

use super::Tensor;

const RMS_EPS: f32 = 1e-6;

/// Elementwise `a + b` (same shape).
pub fn add(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let v = a.as_f32().iter().zip(b.as_f32()).map(|(x, y)| x + y).collect();
    Tensor::from_f32(v, &a.shape)
}

/// In-place `a += b`.
pub fn add_assign(a: &mut Tensor, b: &Tensor) {
    assert_eq!(a.shape, b.shape);
    let bv = b.as_f32();
    for (x, y) in a.as_f32_mut().iter_mut().zip(bv) {
        *x += *y;
    }
}

/// Elementwise `a - b`.
pub fn sub(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.shape, b.shape);
    let v = a.as_f32().iter().zip(b.as_f32()).map(|(x, y)| x - y).collect();
    Tensor::from_f32(v, &a.shape)
}

/// `a * s` (scalar).
pub fn scale(a: &Tensor, s: f32) -> Tensor {
    Tensor::from_f32(a.as_f32().iter().map(|x| x * s).collect(), &a.shape)
}

/// In-place `a += b * s` — used for LoRA delta accumulation.
pub fn add_scaled(a: &mut Tensor, b: &Tensor, s: f32) {
    assert_eq!(a.shape, b.shape);
    let bv = b.as_f32();
    for (x, y) in a.as_f32_mut().iter_mut().zip(bv) {
        *x += *y * s;
    }
}

/// Copy `n` token rows between two `(BH, ·, H)` row-major f32 buffers
/// whose sequence strides differ: source rows start at token `s0` with
/// per-batch-head stride `s_tokens`, destination rows at `d0` with
/// stride `d_tokens`.  This is the single row-movement primitive of the
/// paged KV cache (block → gather buffer, append input → block), so the
/// cache's bytes-copied accounting maps 1:1 onto calls to this helper.
#[allow(clippy::too_many_arguments)] // two (buffer, stride, offset) triples
pub fn copy_seq_rows(dst: &mut [f32], d_tokens: usize, d0: usize,
                     src: &[f32], s_tokens: usize, s0: usize,
                     bh: usize, h: usize, n: usize) {
    debug_assert!(d0 + n <= d_tokens && s0 + n <= s_tokens);
    for b in 0..bh {
        let d = (b * d_tokens + d0) * h;
        let s = (b * s_tokens + s0) * h;
        dst[d..d + n * h].copy_from_slice(&src[s..s + n * h]);
    }
}

/// RMSNorm over the last axis of a (T, D) tensor with a (D,) gain.
pub fn rmsnorm(x: &Tensor, gain: &Tensor) -> Tensor {
    let (t, d) = (x.shape[0], x.shape[1]);
    let xv = x.as_f32();
    let g = gain.as_f32();
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        let row = &xv[r * d..(r + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for c in 0..d {
            out[r * d + c] = row[c] * inv * g[c];
        }
    }
    Tensor::from_f32(out, &[t, d])
}

/// dX of RMSNorm with frozen gain: for row x, y = x*g/rms,
/// dx = (dy*g)/rms - x * (x . (dy*g)) / (d * rms^3).
pub fn rmsnorm_bwd(x: &Tensor, gain: &Tensor, dy: &Tensor) -> Tensor {
    let (t, d) = (x.shape[0], x.shape[1]);
    let (xv, g, dyv) = (x.as_f32(), gain.as_f32(), dy.as_f32());
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        let row = &xv[r * d..(r + 1) * d];
        let dyr = &dyv[r * d..(r + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let rms2 = ms + RMS_EPS;
        let rms = rms2.sqrt();
        let mut dot = 0.0f32;
        for c in 0..d {
            dot += row[c] * dyr[c] * g[c];
        }
        let k = dot / (d as f32 * rms2 * rms);
        for c in 0..d {
            out[r * d + c] = dyr[c] * g[c] / rms - row[c] * k;
        }
    }
    Tensor::from_f32(out, &[t, d])
}

/// Tanh-approximate GELU, matching `jax.nn.gelu(x, approximate=True)`:
/// 0.5 x (1 + tanh(sqrt(2/pi) (x + 0.044715 x^3))).
pub fn gelu(x: &Tensor) -> Tensor {
    let v = x.as_f32().iter().map(|&x| gelu_scalar(x)).collect();
    Tensor::from_f32(v, &x.shape)
}

fn gelu_scalar(x: f32) -> f32 {
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// d/dx of the tanh-approximate GELU, evaluated at the saved input.
pub fn gelu_bwd(x: &Tensor, dy: &Tensor) -> Tensor {
    assert_eq!(x.shape, dy.shape);
    const C: f32 = 0.797_884_6;
    let v = x
        .as_f32()
        .iter()
        .zip(dy.as_f32())
        .map(|(&x, &dy)| {
            let u = C * (x + 0.044715 * x * x * x);
            let t = u.tanh();
            let du = C * (1.0 + 3.0 * 0.044715 * x * x);
            dy * (0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * du)
        })
        .collect();
    Tensor::from_f32(v, &x.shape)
}

/// NaN-safe argmax over a slice: NaNs are skipped, ties keep the first
/// occurrence (matching `jnp.argmax`), and an all-NaN row falls back to
/// `total_cmp` total-order selection instead of silently returning 0.
fn argmax_slice(row: &[f32]) -> usize {
    let mut best: Option<usize> = None;
    for (i, &x) in row.iter().enumerate() {
        if x.is_nan() {
            continue;
        }
        match best {
            Some(b) if row[b].total_cmp(&x).is_ge() => {}
            _ => best = Some(i),
        }
    }
    best.unwrap_or_else(|| {
        // All NaN: pick the total_cmp maximum (a positive-sign NaN beats
        // a negative-sign one) so degenerate logits yield a
        // deterministic, non-misleading index rather than token 0.
        row.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    })
}

/// Argmax over the last row of a (T, V) logits tensor (greedy decoding).
pub fn argmax_last_row(logits: &Tensor) -> i32 {
    let (t, v) = (logits.shape[0], logits.shape[1]);
    let row = &logits.as_f32()[(t - 1) * v..t * v];
    argmax_slice(row) as i32
}

/// Argmax of row `r` of a (T, V) logits tensor.
pub fn argmax_row(logits: &Tensor, r: usize) -> i32 {
    let v = logits.shape[1];
    let row = &logits.as_f32()[r * v..(r + 1) * v];
    argmax_slice(row) as i32
}

/// Naive matmul for tests and tiny baseline paths: (m,k) @ (k,n).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (k2, n) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2);
    let (av, bv) = (a.as_f32(), b.as_f32());
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        for p in 0..k {
            let aip = av[i * k + p];
            if aip == 0.0 {
                continue;
            }
            let brow = &bv[p * n..(p + 1) * n];
            let orow = &mut out[i * n..(i + 1) * n];
            for j in 0..n {
                orow[j] += aip * brow[j];
            }
        }
    }
    Tensor::from_f32(out, &[m, n])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn rmsnorm_unit_gain_normalizes() {
        let x = Tensor::from_f32(vec![3.0, 4.0], &[1, 2]);
        let g = Tensor::from_f32(vec![1.0, 1.0], &[2]);
        let y = rmsnorm(&x, &g);
        // rms = sqrt((9+16)/2) = sqrt(12.5)
        let rms = 12.5f32.sqrt();
        assert_close(y.as_f32(), &[3.0 / rms, 4.0 / rms], 1e-5);
    }

    #[test]
    fn rmsnorm_bwd_matches_finite_difference() {
        let x = Tensor::from_f32(vec![0.5, -1.2, 2.0, 0.1], &[1, 4]);
        let g = Tensor::from_f32(vec![1.1, 0.9, 1.3, 0.7], &[4]);
        let dy = Tensor::from_f32(vec![0.3, -0.2, 0.5, 1.0], &[1, 4]);
        let grad = rmsnorm_bwd(&x, &g, &dy);
        let eps = 1e-3f32;
        for i in 0..4 {
            let mut xp = x.clone();
            xp.as_f32_mut()[i] += eps;
            let mut xm = x.clone();
            xm.as_f32_mut()[i] -= eps;
            let yp = rmsnorm(&xp, &g);
            let ym = rmsnorm(&xm, &g);
            let fd: f32 = yp
                .as_f32()
                .iter()
                .zip(ym.as_f32())
                .zip(dy.as_f32())
                .map(|((p, m), d)| (p - m) / (2.0 * eps) * d)
                .sum();
            assert!((fd - grad.as_f32()[i]).abs() < 1e-2,
                    "fd {fd} vs analytic {}", grad.as_f32()[i]);
        }
    }

    #[test]
    fn gelu_bwd_matches_finite_difference() {
        for &x0 in &[-2.0f32, -0.5, 0.0, 0.7, 3.0] {
            let x = Tensor::from_f32(vec![x0], &[1]);
            let dy = Tensor::from_f32(vec![1.0], &[1]);
            let g = gelu_bwd(&x, &dy).as_f32()[0];
            let eps = 1e-3;
            let fd = (gelu_scalar(x0 + eps) - gelu_scalar(x0 - eps))
                / (2.0 * eps);
            assert!((g - fd).abs() < 1e-3, "x={x0}: {g} vs {fd}");
        }
    }

    #[test]
    fn matmul_small() {
        let a = Tensor::from_f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let b = Tensor::from_f32(vec![1.0, 0.0, 0.0, 1.0], &[2, 2]);
        assert_eq!(matmul(&a, &b), a);
    }

    #[test]
    fn argmax_ignores_nan_entries() {
        // A stray NaN must not mask the true maximum (the old `>` scan
        // returned index 0 whenever row[0] was NaN).
        let t = Tensor::from_f32(vec![f32::NAN, 1.0, 3.0, 2.0], &[1, 4]);
        assert_eq!(argmax_last_row(&t), 2);
        assert_eq!(argmax_row(&t, 0), 2);
        let t = Tensor::from_f32(vec![0.5, f32::NAN, -1.0], &[1, 3]);
        assert_eq!(argmax_last_row(&t), 0);
    }

    #[test]
    fn argmax_all_nan_row_is_deterministic_not_zero() {
        let t = Tensor::from_f32(vec![f32::NAN; 5], &[1, 5]);
        let a = argmax_last_row(&t);
        assert_eq!(a, argmax_last_row(&t));
        assert_ne!(a, 0, "all-NaN row silently decoded as token 0");
    }

    #[test]
    fn argmax_ties_keep_first_occurrence() {
        let t = Tensor::from_f32(vec![1.0, 7.0, 7.0, 0.0], &[1, 4]);
        assert_eq!(argmax_last_row(&t), 1);
        // multi-row selection unaffected
        let t = Tensor::from_f32(vec![9.0, 1.0, 1.0, 9.0], &[2, 2]);
        assert_eq!(argmax_row(&t, 0), 0);
        assert_eq!(argmax_row(&t, 1), 1);
    }

    #[test]
    fn noise_add_sub_is_exact_identity() {
        // the privacy protocol's arithmetic: (x + n) processed linearly,
        // then n_effect subtracted, must equal processing x directly.
        let x = Tensor::from_f32(vec![1.0, -2.0, 0.5, 3.0], &[2, 2]);
        let n = Tensor::from_f32(vec![0.1, 0.2, -0.3, 0.4], &[2, 2]);
        let w = Tensor::from_f32(vec![2.0, 1.0, -1.0, 0.5], &[2, 2]);
        let y_noisy = matmul(&add(&x, &n), &w);
        let n_eff = matmul(&n, &w);
        let y = sub(&y_noisy, &n_eff);
        let want = matmul(&x, &w);
        assert_close(y.as_f32(), want.as_f32(), 1e-5);
    }
}
