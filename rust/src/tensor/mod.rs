//! Host tensor type shared across the coordinator.
//!
//! The coordinator moves activations between clients and the base executor
//! as plain row-major host tensors; the PJRT engine converts them to/from
//! `xla::Literal` at the execute boundary.  Cheap client-side elementwise
//! math (residuals, RMSNorm, GELU, LoRA scaling) is implemented natively
//! here — the formulas are the normative reference in
//! `python/compile/kernels/ref.py` and are covered by golden tests.

pub mod container;
pub mod ops;

use anyhow::{bail, Result};

/// Element type of a [`Tensor`]. Mirrors the SYMT container codes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            _ => bail!("unknown dtype {s}"),
        })
    }
}

/// Raw storage: f32 or i32, row-major.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor: shape + row-major data.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn from_f32(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor { shape: shape.to_vec(), data: TensorData::F32(data) }
    }

    pub fn from_i32(data: Vec<i32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Tensor { shape: shape.to_vec(), data: TensorData::I32(data) }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor::from_f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::from_i32(vec![v], &[1])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::from_f32(vec![v], &[1])
    }

    pub fn dtype(&self) -> DType {
        match self.data {
            TensorData::F32(_) => DType::F32,
            TensorData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> &mut Vec<f32> {
        match &mut self.data {
            TensorData::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.data {
            TensorData::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Reshape without moving data (total element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch",
                  self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Rows `lo..hi` of a rank-2 tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2, "slice_rows needs rank 2");
        let cols = self.shape[1];
        match &self.data {
            TensorData::F32(v) => Tensor::from_f32(
                v[lo * cols..hi * cols].to_vec(), &[hi - lo, cols]),
            TensorData::I32(v) => Tensor::from_i32(
                v[lo * cols..hi * cols].to_vec(), &[hi - lo, cols]),
        }
    }

    /// Columns `lo..hi` of a rank-2 tensor (copies).
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2, "slice_cols needs rank 2");
        let (rows, cols) = (self.shape[0], self.shape[1]);
        let src = self.as_f32();
        let w = hi - lo;
        let mut out = Vec::with_capacity(rows * w);
        for r in 0..rows {
            out.extend_from_slice(&src[r * cols + lo..r * cols + hi]);
        }
        Tensor::from_f32(out, &[rows, w])
    }

    /// Stack rank-2 tensors along rows (all must share the column count).
    pub fn concat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let cols = parts[0].shape[1];
        let rows: usize = parts.iter().map(|t| t.shape[0]).sum();
        let mut out = Vec::with_capacity(rows * cols);
        for t in parts {
            assert_eq!(t.shape[1], cols, "concat_rows: column mismatch");
            out.extend_from_slice(t.as_f32());
        }
        Tensor::from_f32(out, &[rows, cols])
    }

    /// Zero-pad a rank-2 tensor's rows up to `rows` (bucket padding).
    pub fn pad_rows(&self, rows: usize) -> Tensor {
        assert!(rows >= self.shape[0]);
        let cols = self.shape[1];
        let mut v = self.as_f32().to_vec();
        v.resize(rows * cols, 0.0);
        Tensor::from_f32(v, &[rows, cols])
    }

    /// `(T, NH*H) -> (NH, T, H)` — client-side head split for attention.
    pub fn split_heads(&self, n_heads: usize) -> Tensor {
        let (t, d) = (self.shape[0], self.shape[1]);
        let h = d / n_heads;
        let src = self.as_f32();
        let mut out = vec![0.0f32; t * d];
        for ti in 0..t {
            for nh in 0..n_heads {
                let dst = (nh * t + ti) * h;
                let s = ti * d + nh * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[n_heads, t, h])
    }

    /// `(NH, T, H) -> (T, NH*H)` — inverse of [`Tensor::split_heads`].
    pub fn merge_heads(&self) -> Tensor {
        let (nh, t, h) = (self.shape[0], self.shape[1], self.shape[2]);
        let src = self.as_f32();
        let mut out = vec![0.0f32; t * nh * h];
        for ni in 0..nh {
            for ti in 0..t {
                let s = (ni * t + ti) * h;
                let dst = ti * nh * h + ni * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[t, nh * h])
    }

    /// Max |a - b| over two same-shaped f32 tensors (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.as_f32()
            .iter()
            .zip(other.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_and_concat_roundtrip() {
        let t = Tensor::from_f32((0..12).map(|x| x as f32).collect(), &[4, 3]);
        let a = t.slice_rows(0, 2);
        let b = t.slice_rows(2, 4);
        let back = Tensor::concat_rows(&[&a, &b]);
        assert_eq!(back, t);
    }

    #[test]
    fn slice_cols_picks_columns() {
        let t = Tensor::from_f32((0..12).map(|x| x as f32).collect(), &[3, 4]);
        let c = t.slice_cols(1, 3);
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.as_f32(), &[1.0, 2.0, 5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn head_split_merge_roundtrip() {
        let t = Tensor::from_f32((0..24).map(|x| x as f32).collect(), &[3, 8]);
        let split = t.split_heads(2);
        assert_eq!(split.shape, vec![2, 3, 4]);
        assert_eq!(split.merge_heads(), t);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let t = Tensor::from_f32(vec![1.0, 2.0], &[1, 2]);
        let p = t.pad_rows(3);
        assert_eq!(p.shape, vec![3, 2]);
        assert_eq!(p.as_f32(), &[1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn reshape_validates_count() {
        let t = Tensor::zeros(&[2, 3]);
        assert!(t.clone().reshape(&[3, 2]).is_ok());
        assert!(t.reshape(&[4, 2]).is_err());
    }
}
