//! Host tensor type shared across the coordinator.
//!
//! The coordinator moves activations between clients and the base executor
//! as plain row-major host tensors; the PJRT engine converts them to/from
//! `xla::Literal` at the execute boundary.  Cheap client-side elementwise
//! math (residuals, RMSNorm, GELU, LoRA scaling) is implemented natively
//! here — the formulas are the normative reference in
//! `python/compile/kernels/ref.py` and are covered by golden tests.
//!
//! # Storage model: shared buffers, views, copy-on-write
//!
//! A [`Tensor`] is a *view* `(offset, len)` into an immutable,
//! reference-counted buffer (`Arc<TensorBuf>`).  This is what makes the
//! multi-client dispatch hot path zero-copy:
//!
//! * **`clone` is a refcount bump.**  Shipping a tensor to the engine or
//!   into a [`crate::coordinator::proto::LayerRequest`] shares the buffer
//!   instead of duplicating the bytes.  In particular the frozen base
//!   weight matrices are never copied per layer call.
//! * **`slice_rows` is a zero-copy view** over the parent buffer (rank-2,
//!   row-major, so a row range is contiguous).  The executor's scatter
//!   path returns per-request outputs as views of the one batched result.
//! * **Mutation is copy-on-write.**  The mutable API (`as_f32_mut`, and
//!   through it `ops::add_assign` / `ops::add_scaled`, `Adapter::
//!   unflatten`, …) first makes the storage unique: if the buffer is
//!   shared — or pinned for the device-side literal cache, see
//!   [`Tensor::device_pin`] — exactly the viewed elements are copied into
//!   a fresh buffer.  A mutation can therefore never alias into a sibling
//!   view, which keeps the semantics bit-identical to the former
//!   deep-copy storage (pinned by `tests/property.rs`).
//! * **`device_pin` tags a buffer with a process-unique key** so the
//!   engine workers can cache the host→device literal conversion of
//!   long-lived tensors (base weights) by buffer identity.  Keys are
//!   never reused, and copy-on-write clears the tag on the copy, so a
//!   cached literal can never go stale.

pub mod container;
pub mod ops;

use std::fmt;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

/// Element type of a [`Tensor`]. Mirrors the SYMT container codes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn code(self) -> u8 {
        match self {
            DType::F32 => 0,
            DType::I32 => 1,
        }
    }

    pub fn from_code(c: u8) -> Result<Self> {
        Ok(match c {
            0 => DType::F32,
            1 => DType::I32,
            _ => bail!("unknown dtype code {c}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        4
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            _ => bail!("unknown dtype {s}"),
        })
    }
}

/// Raw storage: f32 or i32, row-major.
#[derive(Debug)]
enum BufData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A shared storage buffer.  `device_key` is 0 until the buffer is pinned
/// via [`Tensor::device_pin`]; keys come from a global counter and are
/// never reused, so they are safe cache identities (unlike pointers).
#[derive(Debug)]
pub struct TensorBuf {
    data: BufData,
    device_key: AtomicU64,
}

impl TensorBuf {
    fn new(data: BufData) -> Self {
        TensorBuf { data, device_key: AtomicU64::new(0) }
    }
}

static NEXT_DEVICE_KEY: AtomicU64 = AtomicU64::new(1);

/// A host tensor: shape + view into a shared row-major buffer.
#[derive(Clone)]
pub struct Tensor {
    pub shape: Vec<usize>,
    buf: Arc<TensorBuf>,
    /// Element offset of this view into `buf`.
    off: usize,
    /// Element count of this view.
    elems: usize,
}

impl Tensor {
    pub fn from_f32(data: Vec<f32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self::from_f32_raw(data, shape)
    }

    /// Like [`Tensor::from_f32`] but without the element-count check —
    /// only for the container reader, which preserves whatever byte
    /// stream is on disk.
    pub(crate) fn from_f32_raw(data: Vec<f32>, shape: &[usize]) -> Self {
        let elems = data.len();
        Tensor {
            shape: shape.to_vec(),
            buf: Arc::new(TensorBuf::new(BufData::F32(data))),
            off: 0,
            elems,
        }
    }

    pub fn from_i32(data: Vec<i32>, shape: &[usize]) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self::from_i32_raw(data, shape)
    }

    pub(crate) fn from_i32_raw(data: Vec<i32>, shape: &[usize]) -> Self {
        let elems = data.len();
        Tensor {
            shape: shape.to_vec(),
            buf: Arc::new(TensorBuf::new(BufData::I32(data))),
            off: 0,
            elems,
        }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor::from_f32(vec![0.0; shape.iter().product()], shape)
    }

    pub fn scalar_i32(v: i32) -> Self {
        Tensor::from_i32(vec![v], &[1])
    }

    pub fn scalar_f32(v: f32) -> Self {
        Tensor::from_f32(vec![v], &[1])
    }

    pub fn dtype(&self) -> DType {
        match self.buf.data {
            BufData::F32(_) => DType::F32,
            BufData::I32(_) => DType::I32,
        }
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn size_bytes(&self) -> usize {
        self.len() * self.dtype().size_bytes()
    }

    /// True if this view shares its buffer with at least one other
    /// tensor (test/diagnostic hook for the zero-copy invariants).
    pub fn is_shared(&self) -> bool {
        Arc::strong_count(&self.buf) > 1
    }

    pub fn as_f32(&self) -> &[f32] {
        match &self.buf.data {
            BufData::F32(v) => &v[self.off..self.off + self.elems],
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn as_i32(&self) -> &[i32] {
        match &self.buf.data {
            BufData::I32(v) => &v[self.off..self.off + self.elems],
            _ => panic!("tensor is not i32"),
        }
    }

    /// Make this view's storage unique (copy-on-write): if the buffer is
    /// shared, partially viewed, or pinned for the device literal cache,
    /// copy exactly the viewed elements into a fresh unpinned buffer.
    fn ensure_unique(&mut self) {
        if self.buf.device_key.load(Ordering::Relaxed) == 0
            && Arc::get_mut(&mut self.buf).is_some()
        {
            return;
        }
        let data = match &self.buf.data {
            BufData::F32(v) => {
                BufData::F32(v[self.off..self.off + self.elems].to_vec())
            }
            BufData::I32(v) => {
                BufData::I32(v[self.off..self.off + self.elems].to_vec())
            }
        };
        self.buf = Arc::new(TensorBuf::new(data));
        self.off = 0;
    }

    /// Mutable element access.  Copy-on-write: the storage is made
    /// unique first, so sibling views never observe the mutation.
    pub fn as_f32_mut(&mut self) -> &mut [f32] {
        self.ensure_unique();
        let (off, elems) = (self.off, self.elems);
        let buf = Arc::get_mut(&mut self.buf)
            .expect("storage unique after ensure_unique");
        match &mut buf.data {
            BufData::F32(v) => &mut v[off..off + elems],
            _ => panic!("tensor is not f32"),
        }
    }

    /// Pin this tensor's buffer for the engine's device-side literal
    /// cache and return its process-unique key.  Intended for long-lived
    /// frozen tensors (base weights): engine workers convert a pinned
    /// buffer to an `xla::Literal` once and reuse it on every execute.
    /// Pinned buffers are never mutated in place (copy-on-write always
    /// copies them), so a cached conversion cannot go stale.
    pub fn device_pin(&self) -> u64 {
        let key = self.buf.device_key.load(Ordering::Relaxed);
        if key != 0 {
            return key;
        }
        let fresh = NEXT_DEVICE_KEY.fetch_add(1, Ordering::Relaxed);
        match self.buf.device_key.compare_exchange(
            0, fresh, Ordering::Relaxed, Ordering::Relaxed) {
            Ok(_) => fresh,
            Err(existing) => existing,
        }
    }

    /// The device-cache key, if this tensor is a whole-buffer view of a
    /// pinned buffer (partial views are not cacheable identities).
    pub fn device_key(&self) -> Option<u64> {
        if self.off != 0 || !self.is_full_view() {
            return None;
        }
        match self.buf.device_key.load(Ordering::Relaxed) {
            0 => None,
            k => Some(k),
        }
    }

    fn buf_elems(&self) -> usize {
        match &self.buf.data {
            BufData::F32(v) => v.len(),
            BufData::I32(v) => v.len(),
        }
    }

    fn is_full_view(&self) -> bool {
        self.off == 0 && self.elems == self.buf_elems()
    }

    /// Reclaim the backing `Vec<f32>` if this tensor is the sole owner of
    /// a whole-buffer f32 view — the base executor uses this to recycle
    /// its batch-assembly scratch buffer across flushes.  Returns `None`
    /// (dropping the tensor) when the buffer is shared or partial.
    pub fn try_into_f32_vec(self) -> Option<Vec<f32>> {
        if !self.is_full_view() {
            return None;
        }
        match Arc::try_unwrap(self.buf) {
            Ok(TensorBuf { data: BufData::F32(v), .. }) => Some(v),
            _ => None,
        }
    }

    /// Reshape without moving data (total element count must match).
    pub fn reshape(mut self, shape: &[usize]) -> Result<Self> {
        if shape.iter().product::<usize>() != self.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch",
                  self.shape, shape);
        }
        self.shape = shape.to_vec();
        Ok(self)
    }

    /// Rows `lo..hi` of a rank-2 tensor — a zero-copy view sharing this
    /// tensor's buffer (rows are contiguous in row-major order).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2, "slice_rows needs rank 2");
        let (rows, cols) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= rows,
                "slice_rows {lo}..{hi} out of {rows} rows");
        Tensor {
            shape: vec![hi - lo, cols],
            buf: self.buf.clone(),
            off: self.off + lo * cols,
            elems: (hi - lo) * cols,
        }
    }

    /// Columns `lo..hi` of a rank-2 tensor (gathers, so it copies —
    /// columns are strided).  Works for both dtypes.
    pub fn slice_cols(&self, lo: usize, hi: usize) -> Tensor {
        assert_eq!(self.shape.len(), 2, "slice_cols needs rank 2");
        let (rows, cols) = (self.shape[0], self.shape[1]);
        assert!(lo <= hi && hi <= cols,
                "slice_cols {lo}..{hi} out of {cols} cols");
        let w = hi - lo;
        match &self.buf.data {
            BufData::F32(_) => {
                let src = self.as_f32();
                let mut out = Vec::with_capacity(rows * w);
                for r in 0..rows {
                    out.extend_from_slice(&src[r * cols + lo..r * cols + hi]);
                }
                Tensor::from_f32(out, &[rows, w])
            }
            BufData::I32(_) => {
                let src = self.as_i32();
                let mut out = Vec::with_capacity(rows * w);
                for r in 0..rows {
                    out.extend_from_slice(&src[r * cols + lo..r * cols + hi]);
                }
                Tensor::from_i32(out, &[rows, w])
            }
        }
    }

    /// Stack rank-2 tensors along rows (all must share the column count).
    pub fn concat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let rows: usize = parts.iter().map(|t| t.shape[0]).sum();
        Self::assemble_rows(Vec::new(), parts, rows)
    }

    /// Fused `concat_rows` + `pad_rows`: stack `parts` and zero-fill up
    /// to `rows` in one pass / one allocation.
    pub fn concat_rows_padded(parts: &[&Tensor], rows: usize) -> Tensor {
        Self::assemble_rows(Vec::new(), parts, rows)
    }

    /// Single-pass batch assembly into a caller-provided scratch vector:
    /// stack `parts` row-wise and zero-pad to `rows` rows.  The scratch's
    /// capacity is reused (pair with [`Tensor::try_into_f32_vec`] to
    /// recycle it after the downstream consumer is done).
    pub fn assemble_rows(mut scratch: Vec<f32>, parts: &[&Tensor],
                         rows: usize) -> Tensor {
        assert!(!parts.is_empty());
        let cols = parts[0].shape[1];
        scratch.clear();
        scratch.reserve(rows * cols);
        for t in parts {
            assert_eq!(t.shape[1], cols, "assemble_rows: column mismatch");
            scratch.extend_from_slice(t.as_f32());
        }
        assert!(scratch.len() <= rows * cols,
                "assemble_rows: {} rows exceed target {rows}",
                scratch.len() / cols.max(1));
        scratch.resize(rows * cols, 0.0);
        Tensor::from_f32(scratch, &[rows, cols])
    }

    /// Zero-pad a rank-2 tensor's rows up to `rows` (bucket padding).
    /// When no padding is needed this is a zero-copy view.
    pub fn pad_rows(&self, rows: usize) -> Tensor {
        assert!(rows >= self.shape[0]);
        if rows == self.shape[0] {
            return self.clone();
        }
        let cols = self.shape[1];
        let mut v = Vec::with_capacity(rows * cols);
        v.extend_from_slice(self.as_f32());
        v.resize(rows * cols, 0.0);
        Tensor::from_f32(v, &[rows, cols])
    }

    /// `(T, NH*H) -> (NH, T, H)` — client-side head split for attention.
    pub fn split_heads(&self, n_heads: usize) -> Tensor {
        let (t, d) = (self.shape[0], self.shape[1]);
        let h = d / n_heads;
        let src = self.as_f32();
        let mut out = vec![0.0f32; t * d];
        for ti in 0..t {
            for nh in 0..n_heads {
                let dst = (nh * t + ti) * h;
                let s = ti * d + nh * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[n_heads, t, h])
    }

    /// `(NH, T, H) -> (T, NH*H)` — inverse of [`Tensor::split_heads`].
    pub fn merge_heads(&self) -> Tensor {
        let (nh, t, h) = (self.shape[0], self.shape[1], self.shape[2]);
        let src = self.as_f32();
        let mut out = vec![0.0f32; t * nh * h];
        for ni in 0..nh {
            for ti in 0..t {
                let s = (ni * t + ti) * h;
                let dst = ti * nh * h + ni * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[t, nh * h])
    }

    /// Max |a - b| over two same-shaped f32 tensors (test helper).
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.as_f32()
            .iter()
            .zip(other.as_f32())
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

impl PartialEq for Tensor {
    /// Logical equality: same shape and same viewed elements (buffer
    /// identity and view offsets are irrelevant).
    fn eq(&self, other: &Self) -> bool {
        if self.shape != other.shape {
            return false;
        }
        match (&self.buf.data, &other.buf.data) {
            (BufData::F32(_), BufData::F32(_)) => {
                self.as_f32() == other.as_f32()
            }
            (BufData::I32(_), BufData::I32(_)) => {
                self.as_i32() == other.as_i32()
            }
            _ => false,
        }
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut d = f.debug_struct("Tensor");
        d.field("shape", &self.shape);
        match &self.buf.data {
            BufData::F32(_) => d.field("f32", &self.as_f32()),
            BufData::I32(_) => d.field("i32", &self.as_i32()),
        };
        d.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_and_concat_roundtrip() {
        let t = Tensor::from_f32((0..12).map(|x| x as f32).collect(), &[4, 3]);
        let a = t.slice_rows(0, 2);
        let b = t.slice_rows(2, 4);
        let back = Tensor::concat_rows(&[&a, &b]);
        assert_eq!(back, t);
    }

    #[test]
    fn slice_rows_is_zero_copy_view() {
        let t = Tensor::from_f32((0..12).map(|x| x as f32).collect(), &[4, 3]);
        let v = t.slice_rows(1, 3);
        assert!(v.is_shared() && t.is_shared());
        assert_eq!(v.as_f32(), &[3.0, 4.0, 5.0, 6.0, 7.0, 8.0]);
    }

    #[test]
    fn clone_is_refcount_bump_until_mutated() {
        let t = Tensor::from_f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let mut c = t.clone();
        assert!(t.is_shared());
        c.as_f32_mut()[0] = 9.0; // copy-on-write detaches c
        assert!(!t.is_shared());
        assert_eq!(t.as_f32()[0], 1.0);
        assert_eq!(c.as_f32()[0], 9.0);
    }

    #[test]
    fn cow_detaches_views_from_parent_mutation() {
        let mut t =
            Tensor::from_f32((0..12).map(|x| x as f32).collect(), &[4, 3]);
        let view = t.slice_rows(0, 2);
        let before: Vec<f32> = view.as_f32().to_vec();
        t.as_f32_mut()[0] = 100.0;
        assert_eq!(view.as_f32(), &before[..], "mutation aliased a view");
    }

    #[test]
    fn slice_cols_picks_columns() {
        let t = Tensor::from_f32((0..12).map(|x| x as f32).collect(), &[3, 4]);
        let c = t.slice_cols(1, 3);
        assert_eq!(c.shape, vec![3, 2]);
        assert_eq!(c.as_f32(), &[1.0, 2.0, 5.0, 6.0, 9.0, 10.0]);
    }

    #[test]
    fn slice_cols_works_on_i32() {
        let t = Tensor::from_i32((0..6).collect(), &[2, 3]);
        let c = t.slice_cols(1, 3);
        assert_eq!(c.dtype(), DType::I32);
        assert_eq!(c.as_i32(), &[1, 2, 4, 5]);
    }

    #[test]
    fn slice_rows_preserves_i32_dtype() {
        let t = Tensor::from_i32((0..6).collect(), &[3, 2]);
        let s = t.slice_rows(1, 3);
        assert_eq!(s.dtype(), DType::I32);
        assert_eq!(s.as_i32(), &[2, 3, 4, 5]);
    }

    #[test]
    fn head_split_merge_roundtrip() {
        let t = Tensor::from_f32((0..24).map(|x| x as f32).collect(), &[3, 8]);
        let split = t.split_heads(2);
        assert_eq!(split.shape, vec![2, 3, 4]);
        assert_eq!(split.merge_heads(), t);
    }

    #[test]
    fn pad_rows_zero_fills() {
        let t = Tensor::from_f32(vec![1.0, 2.0], &[1, 2]);
        let p = t.pad_rows(3);
        assert_eq!(p.shape, vec![3, 2]);
        assert_eq!(p.as_f32(), &[1.0, 2.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn concat_rows_padded_matches_concat_then_pad() {
        let a = Tensor::from_f32(vec![1.0, 2.0], &[1, 2]);
        let b = Tensor::from_f32(vec![3.0, 4.0, 5.0, 6.0], &[2, 2]);
        let fused = Tensor::concat_rows_padded(&[&a, &b], 5);
        let two_pass = Tensor::concat_rows(&[&a, &b]).pad_rows(5);
        assert_eq!(fused, two_pass);
    }

    #[test]
    fn scratch_recycles_through_try_into() {
        let a = Tensor::from_f32(vec![1.0, 2.0], &[1, 2]);
        let t = Tensor::assemble_rows(Vec::with_capacity(64), &[&a], 4);
        assert_eq!(t.shape, vec![4, 2]);
        let v = t.try_into_f32_vec().expect("sole owner reclaims");
        assert_eq!(v.len(), 8);
        // a shared tensor cannot be reclaimed
        let t = Tensor::zeros(&[2, 2]);
        let _keep = t.clone();
        assert!(t.try_into_f32_vec().is_none());
    }

    #[test]
    fn device_pin_is_stable_and_unique() {
        let t = Tensor::zeros(&[2, 2]);
        let k1 = t.device_pin();
        assert_eq!(t.device_pin(), k1);
        assert_eq!(t.device_key(), Some(k1));
        let u = Tensor::zeros(&[2, 2]);
        assert_ne!(u.device_pin(), k1);
        // views of a pinned buffer are not cacheable identities
        assert_eq!(t.slice_rows(0, 1).device_key(), None);
    }

    #[test]
    fn pinned_buffer_is_never_mutated_in_place() {
        let mut t = Tensor::zeros(&[2, 2]);
        let k = t.device_pin();
        t.as_f32_mut()[0] = 5.0; // must COW even though refcount is 1
        assert_eq!(t.device_key(), None, "mutation kept the pin");
        let fresh = Tensor::zeros(&[2, 2]);
        assert_ne!(fresh.device_pin(), k);
    }

    #[test]
    fn reshape_validates_count() {
        let t = Tensor::zeros(&[2, 3]);
        assert!(t.clone().reshape(&[3, 2]).is_ok());
        assert!(t.reshape(&[4, 2]).is_err());
    }
}
