//! Simulated heterogeneous device fleet.
//!
//! The paper's testbed is 8xA100-80GB plus 40GB A100s at two power caps
//! (350W "fast" / 100W "slow") and a 64-core EPYC host.  This environment
//! is CPU-only, so placement/heterogeneity experiments run against this
//! module: every device has a **memory ledger** (capacity + tagged
//! allocations, OOM on overflow) and a **compute-rate model** (effective
//! FLOP/s per precision).  Numerics still execute for real through PJRT;
//! the fleet supplies the *accounting* that the paper's figures are made
//! of (see DESIGN.md section 3).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::Precision;

pub const GIB: u64 = 1 << 30;

/// Device classes used across the paper's experiments.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    /// A100 80GB, full power — the paper's main evaluation GPU.
    GpuA100_80,
    /// A100 40GB at 350W — "fast" GPU of the heterogeneous experiment.
    GpuFast40,
    /// A100 40GB capped at 100W — "slow" GPU of the heterogeneous
    /// experiment (paper Fig. 18).
    GpuSlow40,
    /// Host CPU + DRAM (64-core EPYC, 512GB) — client placement target
    /// for long-context inference (paper Figs. 19/20).
    Cpu,
}

impl DeviceKind {
    /// Memory capacity in bytes.
    pub fn capacity(self) -> u64 {
        match self {
            DeviceKind::GpuA100_80 => 80 * GIB,
            DeviceKind::GpuFast40 | DeviceKind::GpuSlow40 => 40 * GIB,
            DeviceKind::Cpu => 512 * GIB,
        }
    }

    /// Effective dense-matmul throughput in FLOP/s for a precision.
    /// A100 peak: 312 TFLOP/s f16, 19.5 TFLOP/s f32; derated to a
    /// realistic 60% efficiency. The 100W cap derates compute ~3.5x
    /// (power-limited clocks); CPU ~1.5 TFLOP/s f32 (64 EPYC cores
    /// with AVX2 FMA).
    pub fn flops(self, p: Precision) -> f64 {
        let eff = 0.6;
        match (self, p) {
            (DeviceKind::GpuA100_80, Precision::F16 | Precision::BF16)
            | (DeviceKind::GpuFast40, Precision::F16 | Precision::BF16) => {
                312e12 * eff
            }
            (DeviceKind::GpuA100_80, Precision::F32)
            | (DeviceKind::GpuFast40, Precision::F32) => 19.5e12 * eff,
            (DeviceKind::GpuSlow40, Precision::F16 | Precision::BF16) => {
                312e12 * eff / 3.5
            }
            (DeviceKind::GpuSlow40, Precision::F32) => 19.5e12 * eff / 3.5,
            (DeviceKind::Cpu, _) => 1.5e12,
        }
    }

    /// HBM / DRAM bandwidth in bytes/s (A100: ~2 TB/s; DDR4-8ch: 200GB/s).
    pub fn mem_bw(self) -> f64 {
        match self {
            DeviceKind::GpuA100_80 => 2.0e12,
            DeviceKind::GpuFast40 | DeviceKind::GpuSlow40 => 1.5e12,
            DeviceKind::Cpu => 2.0e11,
        }
    }

    pub fn is_gpu(self) -> bool {
        !matches!(self, DeviceKind::Cpu)
    }
}

/// One tagged allocation in a ledger.
#[derive(Debug, Clone)]
struct Alloc {
    bytes: u64,
}

/// Tagged memory accounting with capacity enforcement.
///
/// Tags let the figures split consumption by component ("base-model",
/// "kv-cache:client3", "optimizer:client1", …), which is exactly how the
/// paper plots Figs. 1/9/10.
#[derive(Debug)]
pub struct MemoryLedger {
    capacity: u64,
    used: u64,
    peak: u64,
    allocs: HashMap<String, Alloc>,
}

impl MemoryLedger {
    pub fn new(capacity: u64) -> Self {
        MemoryLedger { capacity, used: 0, peak: 0, allocs: HashMap::new() }
    }

    /// Allocate (or resize) the tagged region to `bytes` total.
    /// Fails with OOM if the device capacity would be exceeded —
    /// reproducing the paper's "baseline runs out of memory at N clients"
    /// lines.
    pub fn set(&mut self, tag: &str, bytes: u64) -> Result<()> {
        let old = self.allocs.get(tag).map(|a| a.bytes).unwrap_or(0);
        let new_used = self.used - old + bytes;
        if new_used > self.capacity {
            bail!("OOM: tag {tag} wants {bytes}B, used {}B of {}B",
                  self.used - old, self.capacity);
        }
        self.used = new_used;
        self.peak = self.peak.max(self.used);
        if bytes == 0 {
            self.allocs.remove(tag);
        } else {
            self.allocs.insert(tag.to_string(), Alloc { bytes });
        }
        Ok(())
    }

    /// Grow the tagged region by `delta` bytes.
    pub fn grow(&mut self, tag: &str, delta: u64) -> Result<()> {
        let old = self.allocs.get(tag).map(|a| a.bytes).unwrap_or(0);
        self.set(tag, old + delta)
    }

    pub fn free(&mut self, tag: &str) {
        if let Some(a) = self.allocs.remove(tag) {
            self.used -= a.bytes;
        }
    }

    pub fn used(&self) -> u64 {
        self.used
    }

    pub fn peak(&self) -> u64 {
        self.peak
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn tag_bytes(&self, tag: &str) -> u64 {
        self.allocs.get(tag).map(|a| a.bytes).unwrap_or(0)
    }

    /// Sum over tags with a given prefix (e.g. all "kv-cache:" regions).
    pub fn prefix_bytes(&self, prefix: &str) -> u64 {
        self.allocs
            .iter()
            .filter(|(k, _)| k.starts_with(prefix))
            .map(|(_, a)| a.bytes)
            .sum()
    }

    /// Invariant check: used == sum of allocations (property tests).
    pub fn check_balanced(&self) -> bool {
        self.used == self.allocs.values().map(|a| a.bytes).sum::<u64>()
    }
}

/// A simulated device: kind + ledger + a monotonically advancing virtual
/// clock (seconds of simulated busy time).
#[derive(Debug)]
pub struct Device {
    pub name: String,
    pub kind: DeviceKind,
    pub ledger: MemoryLedger,
    busy_until: f64,
}

impl Device {
    pub fn new(name: &str, kind: DeviceKind) -> Self {
        Device {
            name: name.to_string(),
            kind,
            ledger: MemoryLedger::new(kind.capacity()),
            busy_until: 0.0,
        }
    }

    /// Time to run `flops` of dense math touching `bytes` of memory:
    /// roofline max of compute and bandwidth terms, plus a fixed kernel
    /// launch overhead.
    pub fn op_time(&self, flops: u64, bytes: u64, p: Precision) -> f64 {
        const LAUNCH: f64 = 5e-6;
        let compute = flops as f64 / self.kind.flops(p);
        let mem = bytes as f64 / self.kind.mem_bw();
        LAUNCH + compute.max(mem)
    }

    /// Occupy the device from `start` for `dur` simulated seconds;
    /// returns the completion time (work is serialized per device).
    pub fn run(&mut self, start: f64, dur: f64) -> f64 {
        let begin = start.max(self.busy_until);
        self.busy_until = begin + dur;
        self.busy_until
    }

    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }

    pub fn reset_clock(&mut self) {
        self.busy_until = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ledger_enforces_capacity() {
        let mut l = MemoryLedger::new(100);
        l.set("a", 60).unwrap();
        assert!(l.set("b", 50).is_err());
        l.set("b", 40).unwrap();
        assert_eq!(l.used(), 100);
        l.free("a");
        assert_eq!(l.used(), 40);
        assert!(l.check_balanced());
    }

    #[test]
    fn ledger_grow_and_resize() {
        let mut l = MemoryLedger::new(100);
        l.set("kv", 10).unwrap();
        l.grow("kv", 15).unwrap();
        assert_eq!(l.tag_bytes("kv"), 25);
        l.set("kv", 5).unwrap(); // shrink
        assert_eq!(l.used(), 5);
        assert_eq!(l.peak(), 25);
    }

    #[test]
    fn oom_leaves_ledger_unchanged() {
        let mut l = MemoryLedger::new(100);
        l.set("a", 60).unwrap();
        let before = l.used();
        assert!(l.grow("a", 50).is_err());
        assert_eq!(l.used(), before);
        assert!(l.check_balanced());
    }

    #[test]
    fn prefix_sums() {
        let mut l = MemoryLedger::new(1000);
        l.set("kv:c1", 10).unwrap();
        l.set("kv:c2", 20).unwrap();
        l.set("opt:c1", 5).unwrap();
        assert_eq!(l.prefix_bytes("kv:"), 30);
    }

    #[test]
    fn device_serializes_work() {
        let mut d = Device::new("g0", DeviceKind::GpuA100_80);
        let t1 = d.run(0.0, 1.0);
        let t2 = d.run(0.5, 1.0); // arrives while busy
        assert_eq!(t1, 1.0);
        assert_eq!(t2, 2.0);
    }

    #[test]
    fn slow_gpu_is_slower() {
        let fast = Device::new("f", DeviceKind::GpuFast40);
        let slow = Device::new("s", DeviceKind::GpuSlow40);
        let f = fast.op_time(1 << 40, 1 << 20, Precision::F16);
        let s = slow.op_time(1 << 40, 1 << 20, Precision::F16);
        assert!(s > 3.0 * f);
    }

    #[test]
    fn cpu_has_more_memory_than_gpu() {
        assert!(DeviceKind::Cpu.capacity()
                > DeviceKind::GpuA100_80.capacity());
    }
}
