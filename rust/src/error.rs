//! Typed errors of the public client API.
//!
//! Everything a caller of [`crate::coordinator::Deployment`] builders,
//! sessions, or trainers can hit is a [`SymbiosisError`] variant —
//! misuse (wrong batch, decode before prefill, prefix-seeded batch
//! prefill) is distinguishable from capacity limits (bucket overflow)
//! and from runtime faults bubbling up from the engine/executor, so
//! serving layers can map each class to a different response (reject vs
//! retry vs 500).  Internal layers keep `anyhow`; the `From` impl wraps
//! whatever crosses the public boundary.
//!
//! # Error taxonomy
//!
//! The triage a serving layer should apply per variant.  *Reject* means
//! the request is malformed or misused and re-sending it verbatim will
//! fail again (HTTP 4xx); *retry* means the fault is transient — the
//! same request may succeed against a re-spawned shard or after
//! backoff (HTTP 503 + Retry-After); *500* means an operator-level
//! fault (capacity misplanning, engine/artifact corruption) that no
//! client action fixes.
//!
//! | Variant | Triage | Why |
//! |---|---|---|
//! | `UnsupportedBatch` | reject | no compiled artifact for this batch |
//! | `ContextExceeded` | reject | prompt longer than the largest bucket |
//! | `PrefilledCacheNeedsIncremental` | reject | API misuse on a seeded cache |
//! | `DecodeBeforePrefill` | reject | API misuse |
//! | `PrefixBatchMismatch` | reject | adapter built for another batch |
//! | `NotTrainable` | reject | adapter has no trainable layout |
//! | `InvalidMicroBatch` | reject | micro-batch count incompatible with the training batch |
//! | `InvalidGenerationConfig` | reject | malformed request |
//! | `MalformedRoutingTable` | reject | assignment/route count mismatch |
//! | `DeadlineExceeded` | retry | shard hung or overloaded; frozen-base ops are pure, safe to re-send |
//! | `ExecutorFailed` | retry | per-request shard fault; a respawned shard may serve it |
//! | `ShardUnavailable` | retry (after respawn) | bounded-retry budget exhausted, or the shard's circuit breaker is open; escalate if it persists |
//! | `ShardSaturated` | retry (after backoff) | ingress queue at its high-water mark — backpressure, not a fault; drains as the shard catches up |
//! | `AdmissionDenied` | reject (until a session exits) | tenant at its concurrent-session quota; admitting more would not fit |
//! | `QuotaExceeded` | reject (until the tenant frees) | per-tenant in-flight/KV budget exhausted by the tenant's *own* usage |
//! | `WorkShed` | defer (re-submit later) | background work shed during a brown-out; interactive traffic still proceeds |
//! | `KvCacheOom` | retry (after eviction) | co-tenant pressure; frees up when a tenant leaves |
//! | `KvSwapOom` | retry (after host frees) | host ledger full — oversubscription exhausted both memory tiers |
//! | `KvFaultInOom` | retry (after device frees) | swapped blocks cannot return to the device; a co-tenant must finish or evict first |
//! | `TrainerOom` | retry (after a trainer exits) | optimizer/activation state does not fit the client device alongside co-tenant state |
//! | `ShardOom` | 500 | fleet cannot hold the model; operator must re-plan |
//! | `Runtime` | 500 | engine/artifact/channel fault below the API |
//!
//! The overload variants differ in *who* must act: `ShardSaturated`
//! is fleet-wide pressure (any client backing off helps),
//! `AdmissionDenied`/`QuotaExceeded` name one tenant whose own usage
//! is the cause (only that tenant releasing resources helps), and
//! `WorkShed` is the executor choosing the victim (background work)
//! so interactive tenants never see the brown-out.  None of the four
//! are retried by the client's [`crate::coordinator::RetryPolicy`]
//! ladder — retrying into a saturated queue is exactly the dogpile
//! the breaker and shedder exist to prevent.

use std::fmt;

/// Public-surface result alias.
pub type SymResult<T> = std::result::Result<T, SymbiosisError>;

/// Every error the session/trainer API surfaces.
#[derive(Debug)]
pub enum SymbiosisError {
    /// Request batch size has no compiled attention artifact.
    UnsupportedBatch { batch: usize, supported: &'static [usize] },
    /// Sequence/context length exceeds the largest compiled bucket.
    ContextExceeded { len: usize, limit: usize },
    /// Batch prefill was called on a session whose KV cache already
    /// holds rows (e.g. a learned prefix).  The bucketed prefill
    /// artifact ignores pre-existing cache rows and would silently
    /// compute wrong attention — use incremental prefill (the
    /// [`crate::coordinator::SessionBuilder`] path routes automatically).
    PrefilledCacheNeedsIncremental { cached_rows: usize },
    /// `decode_step` before any prefill.
    DecodeBeforePrefill,
    /// The adapter's learned KV prefix was built for a different batch
    /// size than the session's (prefix tensors are `(batch*heads, P, H)`).
    PrefixBatchMismatch { prefix_bh: usize, cache_bh: usize },
    /// The trainer was given an adapter whose gradients are not wired
    /// into the flattened optimizer layout (IA3/Prefix), or none at all.
    NotTrainable { adapter: &'static str },
    /// The requested micro-batch count cannot tile the training batch:
    /// either it does not divide the batch evenly, or the per-micro-batch
    /// size has no compiled attention artifact.
    InvalidMicroBatch {
        batch: usize,
        micro_batches: usize,
        supported: &'static [usize],
    },
    /// A malformed generation request (e.g. `max_tokens == 0`).
    InvalidGenerationConfig(String),
    /// A shard executor failed while serving a layer batch (engine /
    /// artifact fault).  Reported over the wire per request — clients
    /// see the executor's actual error instead of a dropped channel.
    ExecutorFailed { layer: String, message: String },
    /// A layer request did not come back within the configured
    /// `request_timeout`: the shard is hung, crashed mid-flush, or
    /// overloaded.  Frozen-base ops are pure, so the request is safe
    /// to re-send (the client walker does this automatically under a
    /// [`crate::coordinator::RetryPolicy`]).
    DeadlineExceeded {
        layer: String,
        shard: usize,
        waited: std::time::Duration,
    },
    /// The bounded-retry budget against one shard is exhausted: every
    /// attempt (including any against a re-spawned executor) failed or
    /// timed out.  The source chain carries the last underlying fault.
    /// Also surfaced with `retries: 0` when the shard's circuit
    /// breaker is open — a fast-fail that spends no retry sleeps.
    ShardUnavailable { shard: usize, retries: u32 },
    /// A dispatch would push the shard's ingress queue past its
    /// configured high-water mark.  This is backpressure, not a fault:
    /// the shard is healthy but behind, and the bounded queue refuses
    /// new work instead of growing without limit.  Back off and
    /// re-send; the queue drains as the shard catches up.
    ShardSaturated { shard: usize, depth: usize, limit: usize },
    /// The admission controller refused a new session/trainer: the
    /// tenant is at its concurrent-session quota.  Re-sending fails
    /// until one of the tenant's existing sessions exits.
    AdmissionDenied {
        tenant: String,
        resource: &'static str,
        current: usize,
        limit: usize,
    },
    /// A per-tenant runtime quota (in-flight layer requests, KV-cache
    /// bytes) is exhausted by the tenant's own usage.  Unlike
    /// [`SymbiosisError::ShardSaturated`] this names the tenant whose
    /// budget ran out — only that tenant completing or releasing work
    /// clears it.
    QuotaExceeded {
        tenant: String,
        resource: &'static str,
        used: u64,
        requested: u64,
        limit: u64,
    },
    /// The executor shed this request during a saturation brown-out:
    /// the work was [`crate::coordinator::proto::Urgency::Background`]
    /// and the shard's ingress queue was at its high-water mark, so
    /// the batch was answered with this error instead of occupying the
    /// device ahead of interactive decode.  Deferred, not failed —
    /// re-submit when load drops (the client retry ladder deliberately
    /// does *not* re-send it into the same saturated queue).
    WorkShed { layer: String, shard: usize },
    /// A routing table was built with a route count that does not match
    /// its layer assignment's shard count — a malformed deployment, not
    /// a runtime fault.
    MalformedRoutingTable { shards: usize, routes: usize },
    /// A shard's resident slice of the base weights does not fit its
    /// device ledger: the `ShardPlan` cannot be deployed on this fleet
    /// (paper Fig. 17's "model too large for N GPUs" lines).
    ShardOom { shard: usize, need_bytes: u64, capacity_bytes: u64 },
    /// A session's KV cache growth does not fit the client device's
    /// memory ledger — the executable form of the paper's mixed-tenant
    /// OOM lines (Figs 9/10): the request fails with this instead of an
    /// analytic estimate predicting it would.  `need_bytes` is this
    /// cache's requested total; `used_bytes` what the device already
    /// holds for *other* allocations (co-tenant caches included) — in
    /// the multi-tenant case `need_bytes` alone is typically well below
    /// `capacity_bytes`.
    KvCacheOom { need_bytes: u64, used_bytes: u64, capacity_bytes: u64 },
    /// Swapping a cold KV block to the host device failed: the host
    /// ledger is itself full.  Oversubscription has exhausted both
    /// memory tiers — only a session finishing (on either tier) frees
    /// room.  `used_bytes`/`capacity_bytes` describe the *host* ledger.
    KvSwapOom { need_bytes: u64, used_bytes: u64, capacity_bytes: u64 },
    /// A swapped-out KV block could not be faulted back onto the client
    /// device: the device is full and no further background blocks are
    /// eligible to swap out.  The session's data is intact on the host;
    /// the touch that triggered the fault-in is safe to retry once a
    /// co-tenant frees device memory.  `used_bytes`/`capacity_bytes`
    /// describe the *device* ledger.
    KvFaultInOom { need_bytes: u64, used_bytes: u64, capacity_bytes: u64 },
    /// A trainer's client-side state (Adam optimizer moments under the
    /// `opt:` tag, or a saved-activation stash under `act:`) does not
    /// fit the client device's memory ledger — the executable form of
    /// the paper's Fig 9 capacity edge: admitting one more simultaneous
    /// fine-tune fails with this instead of an analytic estimate
    /// predicting it would.  `what` names the charge that failed;
    /// `used_bytes` is what the device already holds for *other*
    /// allocations (co-tenant trainers and KV caches included).
    TrainerOom {
        what: &'static str,
        need_bytes: u64,
        used_bytes: u64,
        capacity_bytes: u64,
    },
    /// Anything below the API surface: engine execution, executor
    /// channel loss, artifact I/O.
    Runtime(anyhow::Error),
}

impl fmt::Display for SymbiosisError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SymbiosisError::UnsupportedBatch { batch, supported } => {
                write!(f, "batch {batch} has no attention artifact \
                           (exported: {supported:?})")
            }
            SymbiosisError::ContextExceeded { len, limit } => {
                write!(f, "sequence/context length {len} exceeds the \
                           largest compiled bucket ({limit})")
            }
            SymbiosisError::PrefilledCacheNeedsIncremental {
                cached_rows,
            } => {
                write!(f, "batch prefill on a KV cache holding \
                           {cached_rows} pre-existing rows would compute \
                           wrong attention (the bucketed prefill \
                           artifact ignores cache contents); use \
                           prefill_incremental / the SessionBuilder \
                           auto-routing path")
            }
            SymbiosisError::DecodeBeforePrefill => {
                write!(f, "decode before prefill")
            }
            SymbiosisError::PrefixBatchMismatch {
                prefix_bh,
                cache_bh,
            } => {
                write!(f, "the adapter's KV prefix holds {prefix_bh} \
                           batch-head rows but the session's cache \
                           expects {cache_bh} — the prefix was built \
                           for a different batch size")
            }
            SymbiosisError::NotTrainable { adapter } => {
                write!(f, "trainer requires a trainable adapter \
                           (got {adapter}; LoRA gradients are the only \
                           ones wired into the flat optimizer layout)")
            }
            SymbiosisError::InvalidMicroBatch {
                batch,
                micro_batches,
                supported,
            } => {
                write!(f, "cannot split a batch of {batch} into \
                           {micro_batches} micro-batches: the count must \
                           divide the batch and the per-micro-batch size \
                           must have a compiled attention artifact \
                           (exported: {supported:?})")
            }
            SymbiosisError::InvalidGenerationConfig(msg) => {
                write!(f, "invalid generation config: {msg}")
            }
            SymbiosisError::ExecutorFailed { layer, message } => {
                write!(f, "shard executor failed serving layer {layer}: \
                           {message}")
            }
            SymbiosisError::DeadlineExceeded { layer, shard, waited } => {
                write!(f, "layer {layer} on shard {shard} missed its \
                           deadline after {:.1} ms — the shard is hung \
                           or overloaded; the request is pure and safe \
                           to retry", waited.as_secs_f64() * 1e3)
            }
            SymbiosisError::ShardUnavailable { shard, retries } => {
                write!(f, "shard {shard} unavailable after {retries} \
                           retr{} — respawn the shard or escalate",
                       if *retries == 1 { "y" } else { "ies" })
            }
            SymbiosisError::ShardSaturated { shard, depth, limit } => {
                write!(f, "shard {shard} ingress queue is saturated \
                           ({depth} queued, high-water {limit}) — \
                           backpressure, not a fault; back off and \
                           re-send")
            }
            SymbiosisError::AdmissionDenied {
                tenant,
                resource,
                current,
                limit,
            } => {
                write!(f, "admission denied for tenant '{tenant}': \
                           {resource} quota reached ({current} of \
                           {limit}) — an existing session must exit \
                           first")
            }
            SymbiosisError::QuotaExceeded {
                tenant,
                resource,
                used,
                requested,
                limit,
            } => {
                write!(f, "tenant '{tenant}' exceeded its {resource} \
                           quota: {used} used + {requested} requested \
                           vs limit {limit} — the tenant must complete \
                           or release work")
            }
            SymbiosisError::WorkShed { layer, shard } => {
                write!(f, "background work on layer {layer} was shed \
                           by shard {shard} during a saturation \
                           brown-out — deferred, re-submit when load \
                           drops")
            }
            SymbiosisError::MalformedRoutingTable { shards, routes } => {
                write!(f, "routing table is malformed: the layer \
                           assignment spans {shards} shards but \
                           {routes} routes were supplied")
            }
            SymbiosisError::ShardOom {
                shard,
                need_bytes,
                capacity_bytes,
            } => {
                write!(f, "shard {shard} cannot hold its base slice: \
                           {need_bytes} B resident vs {capacity_bytes} B \
                           device capacity — use more shards or a larger \
                           device")
            }
            SymbiosisError::KvCacheOom {
                need_bytes,
                used_bytes,
                capacity_bytes,
            } => {
                write!(f, "KV cache growth to {need_bytes} B does not \
                           fit the client device: co-tenants already \
                           hold {used_bytes} B of {capacity_bytes} B — \
                           offload the cache to the host, shorten the \
                           context, or evict a tenant")
            }
            SymbiosisError::KvSwapOom {
                need_bytes,
                used_bytes,
                capacity_bytes,
            } => {
                write!(f, "cannot swap a {need_bytes} B KV block to the \
                           host: the host ledger already holds \
                           {used_bytes} B of {capacity_bytes} B — both \
                           memory tiers are full; a session must finish \
                           before more KV can be oversubscribed")
            }
            SymbiosisError::KvFaultInOom {
                need_bytes,
                used_bytes,
                capacity_bytes,
            } => {
                write!(f, "cannot fault a swapped {need_bytes} B KV \
                           block back in: the device holds {used_bytes} \
                           B of {capacity_bytes} B and no background \
                           blocks are left to swap out — retry after a \
                           co-tenant frees device memory")
            }
            SymbiosisError::TrainerOom {
                what,
                need_bytes,
                used_bytes,
                capacity_bytes,
            } => {
                write!(f, "trainer {what} of {need_bytes} B does not fit \
                           the client device: co-tenants already hold \
                           {used_bytes} B of {capacity_bytes} B — lower \
                           the micro-batch count, shrink the adapter, or \
                           wait for a trainer to exit")
            }
            SymbiosisError::Runtime(e) => write!(f, "{e:#}"),
        }
    }
}

impl std::error::Error for SymbiosisError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SymbiosisError::Runtime(e) => Some(e.as_ref()),
            _ => None,
        }
    }
}

impl From<anyhow::Error> for SymbiosisError {
    fn from(e: anyhow::Error) -> Self {
        // Preserve typed errors that crossed an anyhow boundary inside
        // the coordinator instead of double-wrapping them.
        match e.downcast::<SymbiosisError>() {
            Ok(typed) => typed,
            Err(e) => SymbiosisError::Runtime(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_names_the_misuse() {
        let e = SymbiosisError::PrefilledCacheNeedsIncremental {
            cached_rows: 4,
        };
        let msg = format!("{e}");
        assert!(msg.contains("4 pre-existing rows"));
        assert!(msg.contains("prefill_incremental"));
    }

    #[test]
    fn anyhow_roundtrip_preserves_type() {
        let typed: anyhow::Error =
            SymbiosisError::DecodeBeforePrefill.into();
        let back: SymbiosisError = typed.into();
        assert!(matches!(back, SymbiosisError::DecodeBeforePrefill));
    }

    #[test]
    fn executor_and_oom_errors_name_the_fault() {
        let e = SymbiosisError::ExecutorFailed {
            layer: "l2.qkv".into(),
            message: "artifact missing".into(),
        };
        let msg = format!("{e}");
        assert!(msg.contains("l2.qkv"));
        assert!(msg.contains("artifact missing"));
        let e = SymbiosisError::ShardOom {
            shard: 3,
            need_bytes: 1 << 30,
            capacity_bytes: 1 << 20,
        };
        assert!(format!("{e}").contains("shard 3"));
        let e = SymbiosisError::KvCacheOom {
            need_bytes: 512,
            used_bytes: 768,
            capacity_bytes: 1024,
        };
        let msg = format!("{e}");
        assert!(msg.contains("512"));
        assert!(msg.contains("768"));
        assert!(msg.contains("1024"));
    }

    #[test]
    fn swap_errors_name_the_full_tier() {
        let e = SymbiosisError::KvSwapOom {
            need_bytes: 4096,
            used_bytes: 900,
            capacity_bytes: 1024,
        };
        let msg = format!("{e}");
        assert!(msg.contains("4096"));
        assert!(msg.contains("host ledger"));
        let e = SymbiosisError::KvFaultInOom {
            need_bytes: 4096,
            used_bytes: 900,
            capacity_bytes: 1024,
        };
        let msg = format!("{e}");
        assert!(msg.contains("fault"));
        assert!(msg.contains("retry"));
    }

    #[test]
    fn fault_domain_errors_name_shard_and_budget() {
        let e = SymbiosisError::DeadlineExceeded {
            layer: "l1.mlp_up".into(),
            shard: 2,
            waited: std::time::Duration::from_millis(250),
        };
        let msg = format!("{e}");
        assert!(msg.contains("l1.mlp_up"));
        assert!(msg.contains("shard 2"));
        assert!(msg.contains("250.0 ms"));
        let e = SymbiosisError::ShardUnavailable { shard: 1, retries: 3 };
        assert!(format!("{e}").contains("shard 1 unavailable after \
                                         3 retries"));
        let e = SymbiosisError::ShardUnavailable { shard: 0, retries: 1 };
        assert!(format!("{e}").contains("1 retry"));
        let e = SymbiosisError::MalformedRoutingTable {
            shards: 4,
            routes: 2,
        };
        let msg = format!("{e}");
        assert!(msg.contains('4'));
        assert!(msg.contains('2'));
    }

    #[test]
    fn shard_unavailable_context_downcasts_to_outermost() {
        // The retry loop wraps the last underlying fault in
        // `ShardUnavailable` via anyhow context; the public boundary
        // must surface the outermost (triage-relevant) variant.
        let inner: anyhow::Error = SymbiosisError::ExecutorFailed {
            layer: "l0.qkv".into(),
            message: "flush rejected".into(),
        }
        .into();
        let wrapped = inner
            .context(SymbiosisError::ShardUnavailable { shard: 0,
                                                        retries: 2 });
        let back: SymbiosisError = wrapped.into();
        assert!(matches!(back,
                         SymbiosisError::ShardUnavailable { shard: 0,
                                                            retries: 2 }));
    }

    #[test]
    fn overload_errors_name_tenant_and_resource() {
        let e = SymbiosisError::ShardSaturated {
            shard: 2,
            depth: 65,
            limit: 64,
        };
        let msg = format!("{e}");
        assert!(msg.contains("shard 2"));
        assert!(msg.contains("65 queued"));
        assert!(msg.contains("high-water 64"));
        let e = SymbiosisError::AdmissionDenied {
            tenant: "acme".into(),
            resource: "concurrent sessions",
            current: 3,
            limit: 3,
        };
        let msg = format!("{e}");
        assert!(msg.contains("'acme'"));
        assert!(msg.contains("concurrent sessions"));
        assert!(msg.contains("3 of 3"));
        let e = SymbiosisError::QuotaExceeded {
            tenant: "acme".into(),
            resource: "KV-cache bytes",
            used: 900,
            requested: 200,
            limit: 1024,
        };
        let msg = format!("{e}");
        assert!(msg.contains("'acme'"));
        assert!(msg.contains("900 used"));
        assert!(msg.contains("200 requested"));
        assert!(msg.contains("limit 1024"));
        let e = SymbiosisError::WorkShed {
            layer: "l3.mlp_up".into(),
            shard: 1,
        };
        let msg = format!("{e}");
        assert!(msg.contains("l3.mlp_up"));
        assert!(msg.contains("shard 1"));
        assert!(msg.contains("re-submit"));
    }

    #[test]
    fn overload_errors_roundtrip_through_anyhow() {
        let typed: anyhow::Error = SymbiosisError::ShardSaturated {
            shard: 0,
            depth: 9,
            limit: 8,
        }
        .into();
        let back: SymbiosisError = typed.into();
        assert!(matches!(back,
                         SymbiosisError::ShardSaturated { shard: 0,
                                                          depth: 9,
                                                          limit: 8 }));
        let typed: anyhow::Error = SymbiosisError::WorkShed {
            layer: "l0.qkv".into(),
            shard: 0,
        }
        .into();
        let back: SymbiosisError = typed.into();
        assert!(matches!(back, SymbiosisError::WorkShed { .. }));
    }

    #[test]
    fn training_errors_name_charge_and_tiling() {
        let e = SymbiosisError::TrainerOom {
            what: "optimizer state",
            need_bytes: 8192,
            used_bytes: 900,
            capacity_bytes: 1024,
        };
        let msg = format!("{e}");
        assert!(msg.contains("optimizer state"));
        assert!(msg.contains("8192"));
        assert!(msg.contains("900"));
        assert!(msg.contains("1024"));
        let e = SymbiosisError::InvalidMicroBatch {
            batch: 4,
            micro_batches: 3,
            supported: &[1, 2, 4],
        };
        let msg = format!("{e}");
        assert!(msg.contains("batch of 4"));
        assert!(msg.contains("3 micro-batches"));
        let typed: anyhow::Error = SymbiosisError::TrainerOom {
            what: "saved activations",
            need_bytes: 1,
            used_bytes: 2,
            capacity_bytes: 3,
        }
        .into();
        let back: SymbiosisError = typed.into();
        assert!(matches!(back,
                         SymbiosisError::TrainerOom {
                             what: "saved activations",
                             ..
                         }));
    }

    #[test]
    fn runtime_wraps_foreign_errors() {
        let e: SymbiosisError = anyhow::anyhow!("engine died").into();
        assert!(matches!(e, SymbiosisError::Runtime(_)));
        assert!(format!("{e}").contains("engine died"));
    }
}
