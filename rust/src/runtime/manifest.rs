//! Parser for `artifacts/manifest.txt` emitted by `python/compile/aot.py`.
//!
//! Line-oriented format (no serde in the vendored registry):
//! ```text
//! symbiosis-manifest v1
//! model name=sym-tiny d_model=64 ...
//! buckets tokens=8,16,... seq=... batches=... ranks=...
//! artifact <name> <file> in=x:f32:8x64;w:f32:64x192 out=y:f32:8x192
//! ```

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::tensor::DType;

/// One named input/output slot of an artifact.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    fn parse(s: &str) -> Result<Self> {
        let mut it = s.split(':');
        let name = it.next().context("spec name")?.to_string();
        let dtype = DType::parse(it.next().context("spec dtype")?)?;
        let dims = it.next().context("spec dims")?;
        let shape = dims
            .split('x')
            .map(|d| d.parse::<usize>().context("dim"))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { name, dtype, shape })
    }
}

/// One AOT-compiled HLO module.  `name` is the manifest's interned copy
/// (`Arc<str>`): the engine threads it through `ExecuteReq` and its
/// compile-cache keys by refcount bump, so the per-call dispatch path
/// never re-allocates the artifact name.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: Arc<str>,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Executable model dims as recorded by the AOT step (drift check against
/// `config::ModelConfig`).
#[derive(Debug, Clone, Default)]
pub struct ManifestModel {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub max_seq: usize,
}

/// Parsed manifest: models + artifact table.  Artifact names are
/// interned once at parse time; `Arc<str>` keys let both lookups (via
/// `Borrow<str>`) and handle-outs (via clone = refcount bump) avoid
/// allocation.
#[derive(Debug, Default)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ManifestModel>,
    pub artifacts: HashMap<Arc<str>, ArtifactSpec>,
}

impl Manifest {
    /// Load `<dir>/manifest.txt`.
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: &Path) -> Result<Manifest> {
        let mut lines = text.lines();
        match lines.next() {
            Some(h) if h.starts_with("symbiosis-manifest") => {}
            other => bail!("bad manifest header: {other:?}"),
        }
        let mut m = Manifest { dir: dir.to_path_buf(), ..Default::default() };
        for line in lines {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            match parts.next() {
                Some("model") => {
                    let kv: HashMap<&str, &str> = parts
                        .filter_map(|p| p.split_once('='))
                        .collect();
                    let get = |k: &str| -> Result<usize> {
                        kv.get(k)
                            .with_context(|| format!("model missing {k}"))?
                            .parse()
                            .context("model dim")
                    };
                    m.models.push(ManifestModel {
                        name: kv.get("name").context("model name")?
                            .to_string(),
                        d_model: get("d_model")?,
                        n_heads: get("n_heads")?,
                        n_layers: get("n_layers")?,
                        d_ff: get("d_ff")?,
                        vocab: get("vocab")?,
                        max_seq: get("max_seq")?,
                    });
                }
                Some("buckets") => {} // informational; mirrored in config/
                Some("artifact") => {
                    let name = parts.next().context("artifact name")?;
                    let file = parts.next().context("artifact file")?;
                    let mut inputs = Vec::new();
                    let mut outputs = Vec::new();
                    for p in parts {
                        if let Some(rest) = p.strip_prefix("in=") {
                            for s in rest.split(';') {
                                inputs.push(TensorSpec::parse(s)?);
                            }
                        } else if let Some(rest) = p.strip_prefix("out=") {
                            for s in rest.split(';') {
                                outputs.push(TensorSpec::parse(s)?);
                            }
                        }
                    }
                    let name: Arc<str> = Arc::from(name);
                    m.artifacts.insert(
                        name.clone(),
                        ArtifactSpec {
                            name,
                            file: dir.join(file),
                            inputs,
                            outputs,
                        },
                    );
                }
                Some(other) => bail!("unknown manifest record {other:?}"),
                None => {}
            }
        }
        Ok(m)
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name} not in manifest"))
    }

    pub fn model(&self, name: &str) -> Result<&ManifestModel> {
        self.models
            .iter()
            .find(|m| m.name == name)
            .with_context(|| format!("model {name} not in manifest"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
symbiosis-manifest v1
model name=sym-tiny d_model=64 n_heads=4 n_layers=4 d_ff=256 vocab=256 max_seq=512
buckets tokens=8,16 seq=16 batches=1 ranks=8
artifact linear_fwd_t8_64x192 linear_fwd_t8_64x192.hlo.txt in=x:f32:8x64;w:f32:64x192;b:f32:192 out=y:f32:8x192
artifact adam_n1024 adam_n1024.hlo.txt in=p:f32:1024;g:f32:1024;m:f32:1024;v:f32:1024;t:f32:1 out=p2:f32:1024;m2:f32:1024;v2:f32:1024
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert_eq!(m.models.len(), 1);
        assert_eq!(m.models[0].d_model, 64);
        let a = m.artifact("linear_fwd_t8_64x192").unwrap();
        assert_eq!(a.inputs.len(), 3);
        assert_eq!(a.inputs[1].shape, vec![64, 192]);
        assert_eq!(a.outputs[0].name, "y");
        let adam = m.artifact("adam_n1024").unwrap();
        assert_eq!(adam.outputs.len(), 3);
        assert_eq!(adam.inputs[4].shape, vec![1]);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("nonsense", Path::new("/tmp")).is_err());
        assert!(Manifest::parse("symbiosis-manifest v1\nwat x",
                                Path::new("/tmp")).is_err());
    }

    #[test]
    fn missing_artifact_errors() {
        let m = Manifest::parse(SAMPLE, Path::new("/tmp/a")).unwrap();
        assert!(m.artifact("nope").is_err());
    }
}
