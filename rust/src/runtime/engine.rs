//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them on
//! the CPU PJRT client (once, cached), and executes them with host
//! tensors.
//!
//! The `xla` crate's client/executable types are not `Send`/`Sync`
//! (internal `Rc` + raw pointers), so all PJRT objects live on **engine
//! service threads** (a small worker pool, each with its own client and
//! compile cache); [`Engine`] is a cheap, cloneable, thread-safe handle
//! that hands execute requests to the pool.  One worker mirrors a single
//! device stream; the pool mirrors multiple streams and is what lets
//! independent clients' attention overlap with executor flushes (see
//! EXPERIMENTS.md §Perf).
//!
//! Dispatch is zero-copy and wake-on-work:
//! * Inputs ride into [`ExecuteReq`] as `Arc`-backed tensor views —
//!   submitting a request bumps refcounts instead of duplicating the
//!   activation (or worse, the frozen weight) bytes.
//! * The two priority lanes are `VecDeque`s behind one mutex with a
//!   `Condvar`: idle workers park and are woken by `submit`, so there is
//!   no timed sleep anywhere on the request path (the old design polled
//!   both lanes every 50µs).
//! * Each worker keeps a device-resident literal cache for tensors
//!   pinned via [`Tensor::device_pin`] (the base weights): the host →
//!   `xla::Literal` conversion of a weight matrix happens once per
//!   worker, not once per layer call.
//!
//! This is the only place Python-produced bits are touched at run time —
//! and only as static `.hlo.txt` files.  Pattern adapted from
//! `/opt/xla-example/load_hlo/`: HLO *text* interchange, `return_tuple`
//! outputs unwrapped via `to_tuple`.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use crate::tensor::{DType, Tensor};

/// Cumulative execution statistics (for the perf pass / EXPERIMENTS.md).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: u64,
    pub executes: u64,
    pub compile_secs: f64,
    pub execute_secs: f64,
    /// Host bytes converted to device literals (excludes cache hits).
    pub literal_bytes: u64,
    /// Pinned-weight literal conversions served from the worker cache.
    pub weight_cache_hits: u64,
    /// Pinned-weight literal conversions that had to run.
    pub weight_cache_misses: u64,
}

struct ExecuteReq {
    /// The manifest's interned artifact name — threading it through the
    /// request is a refcount bump, not a per-call allocation.
    name: Arc<str>,
    /// Arc-backed views — cloning into the request is a refcount bump.
    inputs: Vec<Tensor>,
    resp: Sender<Result<Vec<Tensor>>>,
}

/// Two-lane work queue: interactive (decode) work jumps ahead of queued
/// bulk/training work — this is how "Symbiosis prioritizes the inference
/// requests" (paper section 4.4) reaches the device queue.  Workers park
/// on the condvar when both lanes are empty and are woken by `submit`.
struct LaneState {
    hi: VecDeque<ExecuteReq>,
    lo: VecDeque<ExecuteReq>,
    /// Set when every [`Engine`] handle is gone; workers drain and exit.
    closed: bool,
}

struct WorkQueues {
    state: Mutex<LaneState>,
    cv: Condvar,
}

impl WorkQueues {
    fn new() -> Self {
        WorkQueues {
            state: Mutex::new(LaneState {
                hi: VecDeque::new(),
                lo: VecDeque::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn submit(&self, req: ExecuteReq, high: bool) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        if st.closed {
            bail!("engine service threads are gone");
        }
        if high {
            st.hi.push_back(req);
        } else {
            st.lo.push_back(req);
        }
        drop(st);
        self.cv.notify_one();
        Ok(())
    }

    /// Block until a request is available (high lane first) or the
    /// queues are closed *and* drained.
    fn next(&self) -> Option<ExecuteReq> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(r) = st.hi.pop_front() {
                return Some(r);
            }
            if let Some(r) = st.lo.pop_front() {
                return Some(r);
            }
            if st.closed {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Close the queues: no further submits are accepted and queued
    /// requests are dropped (their response senders with them, so blocked
    /// callers observe a disconnect instead of hanging).  Called when the
    /// last [`Engine`] handle goes away — at which point no caller can be
    /// blocked, since `execute_prio` borrows the engine — or when the
    /// last worker dies, where dropping the queued requests is exactly
    /// what unblocks the waiting callers.  Panic-proof (runs in `Drop`
    /// during unwinds): a poisoned lock is taken anyway.
    fn close(&self) {
        let mut st = self
            .state
            .lock()
            .unwrap_or_else(|poisoned| poisoned.into_inner());
        st.closed = true;
        st.hi.clear();
        st.lo.clear();
        drop(st);
        self.cv.notify_all();
    }
}

/// Closes the work queues when dropped.  Two instances exist: one shared
/// by all [`Engine`] handles (so parked workers wake up and exit instead
/// of leaking when the engine goes away) and one shared by all workers
/// (so callers get a disconnect error instead of parking forever if the
/// whole pool dies — including by panic, since locals drop on unwind).
struct QueueCloser(Arc<WorkQueues>);

impl Drop for QueueCloser {
    fn drop(&mut self) {
        self.0.close();
    }
}

/// Thread-safe handle to the engine worker pool.
#[derive(Clone)]
pub struct Engine {
    queues: Arc<WorkQueues>,
    manifest: Arc<Manifest>,
    stats: Arc<Mutex<EngineStats>>,
    _closer: Arc<QueueCloser>,
}

/// Default worker count: one per available core, capped at 4
/// (overridable via SYMBIOSIS_ENGINE_THREADS).  On a single-core host
/// extra workers only multiply compile caches — measured in
/// EXPERIMENTS.md §Perf.
fn default_workers() -> usize {
    std::env::var("SYMBIOSIS_ENGINE_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get().min(4))
                .unwrap_or(1)
        })
}

impl Engine {
    /// Build an engine over `artifacts/` with the default worker pool.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        Self::with_workers(artifact_dir, default_workers())
    }

    /// Build an engine with an explicit worker-pool size (each worker
    /// owns a PJRT client + compile cache; 1 = a single device stream).
    pub fn with_workers(artifact_dir: &Path, workers: usize)
                        -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let queues = Arc::new(WorkQueues::new());
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        // Shared by the workers only: when the last worker exits (or
        // panics), its drop closes the queues so blocked and future
        // callers error out instead of waiting forever.
        let worker_closer = Arc::new(QueueCloser(queues.clone()));
        for w in 0..workers.max(1) {
            let manifest = manifest.clone();
            let stats = stats.clone();
            let queues = queues.clone();
            let ready_tx = ready_tx.clone();
            let alive = worker_closer.clone();
            std::thread::Builder::new()
                .name(format!("pjrt-engine-{w}"))
                .spawn(move || {
                    let _alive = alive;
                    service_loop(manifest, stats, queues, ready_tx);
                })
                .expect("spawn engine thread");
        }
        drop(worker_closer);
        // Created before the ready-wait: if any worker fails to init and
        // we bail with `?`, dropping the closer closes the queues so the
        // surviving workers wake and exit instead of parking forever.
        let closer = Arc::new(QueueCloser(queues.clone()));
        for _ in 0..workers.max(1) {
            ready_rx
                .recv()
                .context("engine worker died during init")??;
        }
        Ok(Engine { queues, manifest, stats, _closer: closer })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }

    /// True if the manifest has an artifact with this name.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Pre-compile a set of artifacts (warm-up before serving) by
    /// executing them once with zero inputs.
    pub fn warm_up<'a, I: IntoIterator<Item = &'a str>>(&self, names: I)
                                                        -> Result<()> {
        for n in names {
            let spec = self.manifest.artifact(n)?;
            let zeros: Vec<Tensor> = spec
                .inputs
                .iter()
                .map(|s| zeros_for_spec(s.dtype, &s.shape))
                .collect();
            let refs: Vec<&Tensor> = zeros.iter().collect();
            self.execute(n, &refs)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with `inputs` on the normal lane.
    pub fn execute(&self, name: &str, inputs: &[&Tensor])
                   -> Result<Vec<Tensor>> {
        self.execute_prio(name, inputs, false)
    }

    /// Execute with an explicit priority: `high` jumps the device queue
    /// ahead of any queued bulk/training work.  Inputs are shared with
    /// the worker (refcount bump), never deep-copied.
    pub fn execute_prio(&self, name: &str, inputs: &[&Tensor],
                        high: bool) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?;
        validate_inputs(spec, inputs)?;
        let (tx, rx) = channel();
        self.queues
            .submit(
                ExecuteReq {
                    name: spec.name.clone(),
                    inputs: inputs.iter().map(|t| (*t).clone()).collect(),
                    resp: tx,
                },
                high,
            )
            .context("engine service thread is gone")?;
        rx.recv().context("engine dropped the request")?
    }
}

fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!("{}: expected {} inputs, got {}", spec.name,
              spec.inputs.len(), inputs.len());
    }
    for (t, s) in inputs.iter().zip(&spec.inputs) {
        if t.shape != s.shape {
            bail!("{}: input {} shape {:?} != manifest {:?}", spec.name,
                  s.name, t.shape, s.shape);
        }
        if t.dtype() != s.dtype {
            bail!("{}: input {} dtype mismatch", spec.name, s.name);
        }
    }
    Ok(())
}

/// Per-worker cache of device literals for pinned (weight) buffers,
/// keyed by the buffer's process-unique pin key.  The shape is kept to
/// guard against a pinned buffer being viewed under a different shape.
/// Entries live for the worker's lifetime — keys are never reused, so an
/// entry whose weights were dropped is only a memory cost (bounded by
/// the number of model loads per process), never a stale answer.
struct WeightLiteralCache {
    map: HashMap<u64, (Vec<usize>, xla::Literal)>,
}

impl WeightLiteralCache {
    fn new() -> Self {
        WeightLiteralCache { map: HashMap::new() }
    }

    /// Make sure the pinned tensor's literal is resident (converting on
    /// a miss), updating hit/miss statistics.
    fn ensure(&mut self, t: &Tensor, stats: &Arc<Mutex<EngineStats>>)
              -> Result<()> {
        let key = t.device_key().expect("cache requires a pinned tensor");
        if let Some((shape, _)) = self.map.get(&key) {
            if *shape == t.shape {
                stats.lock().unwrap().weight_cache_hits += 1;
                return Ok(());
            }
            self.map.remove(&key);
        }
        let lit = tensor_to_literal(t)?;
        {
            let mut s = stats.lock().unwrap();
            s.weight_cache_misses += 1;
            s.literal_bytes += t.size_bytes() as u64;
        }
        self.map.insert(key, (t.shape.clone(), lit));
        Ok(())
    }

    /// Borrow the resident literal for a pinned tensor (after `ensure`).
    fn get(&self, t: &Tensor) -> Result<&xla::Literal> {
        let key = t.device_key().expect("cache requires a pinned tensor");
        match self.map.get(&key) {
            Some((shape, lit)) if *shape == t.shape => Ok(lit),
            _ => bail!("pinned literal not resident (shape drift?)"),
        }
    }
}

/// One worker: owns a PJRT client, a compiled-executable cache, and a
/// pinned-weight literal cache; launches are serialized per worker,
/// parallel across workers.  The high-priority lane is always drained
/// before the low one; with nothing queued the worker parks on the
/// condvar (no sleep polling).
fn service_loop(manifest: Arc<Manifest>, stats: Arc<Mutex<EngineStats>>,
                queues: Arc<WorkQueues>, ready: Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready
                .send(Err(anyhow::anyhow!("PJRT cpu client: {e:?}")));
            return;
        }
    };
    let mut cache: HashMap<Arc<str>, xla::PjRtLoadedExecutable> =
        HashMap::new();
    let mut weights = WeightLiteralCache::new();
    while let Some(req) = queues.next() {
        let ExecuteReq { name, inputs, resp } = req;
        let result = serve_one(&client, &manifest, &mut cache,
                               &mut weights, &stats, &name, &inputs);
        // Release our share of the input buffers before answering, so a
        // caller that wants to reclaim its scratch buffer (see
        // `Tensor::try_into_f32_vec`) observes a unique Arc.
        drop(inputs);
        let _ = resp.send(result);
    }
}

fn serve_one(client: &xla::PjRtClient, manifest: &Manifest,
             cache: &mut HashMap<Arc<str>, xla::PjRtLoadedExecutable>,
             weights: &mut WeightLiteralCache,
             stats: &Arc<Mutex<EngineStats>>, name: &Arc<str>,
             inputs: &[Tensor]) -> Result<Vec<Tensor>> {
    let spec = manifest.artifact(name)?;
    if !cache.contains_key(name) {
        let t0 = Instant::now();
        let exe = compile(client, &spec.file, name)?;
        let mut s = stats.lock().unwrap();
        s.compiles += 1;
        s.compile_secs += t0.elapsed().as_secs_f64();
        drop(s);
        cache.insert(name.clone(), exe);
    }
    let exe = cache.get(name).unwrap();
    // Convert inputs: pinned weights come from (or enter) the worker's
    // device-resident cache; activations are converted fresh.  Owned
    // literals are kept alive in `fresh` while `literals` borrows.
    let mut fresh: Vec<xla::Literal> = Vec::new();
    let mut slots: Vec<Option<usize>> = Vec::with_capacity(inputs.len());
    let mut fresh_bytes = 0u64;
    for t in inputs {
        if t.device_key().is_some() {
            slots.push(None); // resolved via the cache below
        } else {
            fresh_bytes += t.size_bytes() as u64;
            fresh.push(tensor_to_literal(t)?);
            slots.push(Some(fresh.len() - 1));
        }
    }
    if fresh_bytes > 0 {
        stats.lock().unwrap().literal_bytes += fresh_bytes;
    }
    // Two passes because the cache hands out borrows: first ensure every
    // pinned input is resident (mutable), then assemble the borrow list
    // (immutable).
    for t in inputs {
        if t.device_key().is_some() {
            weights.ensure(t, stats)?;
        }
    }
    let literals: Vec<&xla::Literal> = inputs
        .iter()
        .zip(&slots)
        .map(|(t, slot)| match slot {
            Some(i) => Ok(&fresh[*i]),
            None => weights.get(t),
        })
        .collect::<Result<Vec<_>>>()?;
    let t0 = Instant::now();
    let result = exe
        .execute::<&xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("executing {name}: {e:?}"))?;
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch {name}: {e:?}"))?;
    // aot.py lowers with return_tuple=True: always a tuple literal.
    let parts = tuple
        .to_tuple()
        .map_err(|e| anyhow::anyhow!("untuple {name}: {e:?}"))?;
    if parts.len() != spec.outputs.len() {
        bail!("{name}: expected {} outputs, got {}", spec.outputs.len(),
              parts.len());
    }
    let outs = parts
        .into_iter()
        .zip(&spec.outputs)
        .map(|(l, os)| literal_to_tensor(&l, &os.shape))
        .collect::<Result<Vec<_>>>()?;
    let mut s = stats.lock().unwrap();
    s.executes += 1;
    s.execute_secs += t0.elapsed().as_secs_f64();
    Ok(outs)
}

fn compile(client: &xla::PjRtClient, file: &PathBuf, name: &str)
           -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        file.to_str().context("artifact path utf-8")?)
        .map_err(|e| anyhow::anyhow!("loading HLO {}: {e:?}",
                                     file.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))
}

/// Host tensor -> xla Literal (row-major bytes of the view).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, &[u8]) = match t.dtype() {
        DType::F32 => {
            let v = t.as_f32();
            (xla::ElementType::F32, unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8,
                                           v.len() * 4)
            })
        }
        DType::I32 => {
            let v = t.as_i32();
            (xla::ElementType::S32, unsafe {
                std::slice::from_raw_parts(v.as_ptr() as *const u8,
                                           v.len() * 4)
            })
        }
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, bytes)
        .map_err(|e| anyhow::anyhow!("literal create: {e:?}"))
}

/// xla Literal -> host tensor, shaped per the manifest spec.
pub fn literal_to_tensor(l: &xla::Literal, shape: &[usize])
                         -> Result<Tensor> {
    let ty = l.ty().map_err(|e| anyhow::anyhow!("literal ty: {e:?}"))?;
    let t = match ty {
        xla::ElementType::F32 => Tensor::from_f32_raw(
            l.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("literal f32: {e:?}"))?,
            shape),
        xla::ElementType::S32 => Tensor::from_i32_raw(
            l.to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("literal i32: {e:?}"))?,
            shape),
        other => bail!("unsupported literal type {other:?}"),
    };
    if t.len() != l.element_count() {
        bail!("literal element count {} != spec shape {:?}",
              l.element_count(), shape);
    }
    Ok(t)
}

/// Zero tensor matching a manifest spec — test/warm-up helper.
pub fn zeros_for_spec(dtype: DType, shape: &[usize]) -> Tensor {
    match dtype {
        DType::F32 => Tensor::zeros(shape),
        DType::I32 => {
            Tensor::from_i32(vec![0; shape.iter().product()], shape)
        }
    }
}
