//! PJRT execution engine: loads AOT HLO-text artifacts, compiles them on
//! the CPU PJRT client (once, cached), and executes them with host
//! tensors.
//!
//! The `xla` crate's client/executable types are not `Send`/`Sync`
//! (internal `Rc` + raw pointers), so all PJRT objects live on **engine
//! service threads** (a small worker pool, each with its own client and
//! compile cache); [`Engine`] is a cheap, cloneable, thread-safe handle
//! that round-trips execute requests over a channel.  One worker mirrors
//! a single device stream; the pool mirrors multiple streams and is what
//! lets independent clients' attention overlap with executor flushes
//! (see EXPERIMENTS.md §Perf).
//!
//! This is the only place Python-produced bits are touched at run time —
//! and only as static `.hlo.txt` files.  Pattern adapted from
//! `/opt/xla-example/load_hlo/`: HLO *text* interchange, `return_tuple`
//! outputs unwrapped via `to_tuple`.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, Manifest};
use crate::tensor::{DType, Tensor, TensorData};

/// Cumulative execution statistics (for the perf pass / EXPERIMENTS.md).
#[derive(Debug, Default, Clone)]
pub struct EngineStats {
    pub compiles: u64,
    pub executes: u64,
    pub compile_secs: f64,
    pub execute_secs: f64,
}

struct ExecuteReq {
    name: String,
    inputs: Vec<Tensor>,
    resp: Sender<Result<Vec<Tensor>>>,
}

/// Thread-safe handle to the engine worker pool.  Two priority lanes:
/// interactive (decode) work jumps ahead of queued bulk/training work —
/// this is how "Symbiosis prioritizes the inference requests" (paper
/// section 4.4) reaches the device queue.
#[derive(Clone)]
pub struct Engine {
    tx_hi: Sender<ExecuteReq>,
    tx_lo: Sender<ExecuteReq>,
    manifest: Arc<Manifest>,
    stats: Arc<Mutex<EngineStats>>,
}

/// Default worker count: one per available core, capped at 4
/// (overridable via SYMBIOSIS_ENGINE_THREADS).  On a single-core host
/// extra workers only multiply compile caches — measured in
/// EXPERIMENTS.md §Perf.
fn default_workers() -> usize {
    std::env::var("SYMBIOSIS_ENGINE_THREADS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| {
            std::thread::available_parallelism()
                .map(|n| n.get().min(4))
                .unwrap_or(1)
        })
}

impl Engine {
    /// Build an engine over `artifacts/` with the default worker pool.
    pub fn new(artifact_dir: &Path) -> Result<Engine> {
        Self::with_workers(artifact_dir, default_workers())
    }

    /// Build an engine with an explicit worker-pool size (each worker
    /// owns a PJRT client + compile cache; 1 = a single device stream).
    pub fn with_workers(artifact_dir: &Path, workers: usize)
                        -> Result<Engine> {
        let manifest = Arc::new(Manifest::load(artifact_dir)?);
        let stats = Arc::new(Mutex::new(EngineStats::default()));
        let (tx_hi, rx_hi) = channel::<ExecuteReq>();
        let (tx_lo, rx_lo) = channel::<ExecuteReq>();
        let rx = Arc::new(Mutex::new((rx_hi, rx_lo)));
        let (ready_tx, ready_rx) = channel::<Result<()>>();
        for w in 0..workers.max(1) {
            let manifest = manifest.clone();
            let stats = stats.clone();
            let rx = rx.clone();
            let ready_tx = ready_tx.clone();
            std::thread::Builder::new()
                .name(format!("pjrt-engine-{w}"))
                .spawn(move || {
                    service_loop(manifest, stats, rx, ready_tx);
                })
                .expect("spawn engine thread");
        }
        for _ in 0..workers.max(1) {
            ready_rx
                .recv()
                .context("engine worker died during init")??;
        }
        Ok(Engine { tx_hi, tx_lo, manifest, stats })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn stats(&self) -> EngineStats {
        self.stats.lock().unwrap().clone()
    }

    /// True if the manifest has an artifact with this name.
    pub fn has_artifact(&self, name: &str) -> bool {
        self.manifest.artifacts.contains_key(name)
    }

    /// Pre-compile a set of artifacts (warm-up before serving) by
    /// executing them once with zero inputs.
    pub fn warm_up<'a, I: IntoIterator<Item = &'a str>>(&self, names: I)
                                                        -> Result<()> {
        for n in names {
            let spec = self.manifest.artifact(n)?;
            let zeros: Vec<Tensor> = spec
                .inputs
                .iter()
                .map(|s| zeros_for_spec(s.dtype, &s.shape))
                .collect();
            let refs: Vec<&Tensor> = zeros.iter().collect();
            self.execute(n, &refs)?;
        }
        Ok(())
    }

    /// Execute artifact `name` with `inputs` on the normal lane.
    pub fn execute(&self, name: &str, inputs: &[&Tensor])
                   -> Result<Vec<Tensor>> {
        self.execute_prio(name, inputs, false)
    }

    /// Execute with an explicit priority: `high` jumps the device queue
    /// ahead of any queued bulk/training work.
    pub fn execute_prio(&self, name: &str, inputs: &[&Tensor],
                        high: bool) -> Result<Vec<Tensor>> {
        let spec = self.manifest.artifact(name)?;
        validate_inputs(spec, inputs)?;
        let (tx, rx) = channel();
        let lane = if high { &self.tx_hi } else { &self.tx_lo };
        lane.send(ExecuteReq {
            name: name.to_string(),
            inputs: inputs.iter().map(|t| (*t).clone()).collect(),
            resp: tx,
        })
        .ok()
        .context("engine service thread is gone")?;
        rx.recv().context("engine dropped the request")?
    }
}

fn validate_inputs(spec: &ArtifactSpec, inputs: &[&Tensor]) -> Result<()> {
    if inputs.len() != spec.inputs.len() {
        bail!("{}: expected {} inputs, got {}", spec.name,
              spec.inputs.len(), inputs.len());
    }
    for (t, s) in inputs.iter().zip(&spec.inputs) {
        if t.shape != s.shape {
            bail!("{}: input {} shape {:?} != manifest {:?}", spec.name,
                  s.name, t.shape, s.shape);
        }
        if t.dtype() != s.dtype {
            bail!("{}: input {} dtype mismatch", spec.name, s.name);
        }
    }
    Ok(())
}

/// One worker: owns a PJRT client and a compiled-executable cache;
/// launches are serialized per worker, parallel across workers.  The
/// high-priority lane is always drained before the low one.
fn service_loop(manifest: Arc<Manifest>, stats: Arc<Mutex<EngineStats>>,
                rx: Arc<Mutex<(Receiver<ExecuteReq>,
                               Receiver<ExecuteReq>)>>,
                ready: Sender<Result<()>>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => {
            let _ = ready.send(Ok(()));
            c
        }
        Err(e) => {
            let _ = ready
                .send(Err(anyhow::anyhow!("PJRT cpu client: {e:?}")));
            return;
        }
    };
    let mut cache: HashMap<String, xla::PjRtLoadedExecutable> =
        HashMap::new();
    loop {
        // hold the receiver lock only while picking up the next request;
        // prefer the high-priority lane, then poll both.
        let req = {
            let guard = rx.lock().unwrap();
            let (hi, lo) = &*guard;
            match hi.try_recv() {
                Ok(r) => Some(r),
                Err(std::sync::mpsc::TryRecvError::Empty) => {
                    match lo.try_recv() {
                        Ok(r) => Some(r),
                        Err(std::sync::mpsc::TryRecvError::Empty) => None,
                        Err(std::sync::mpsc::TryRecvError::Disconnected)
                            => return,
                    }
                }
                Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                    return
                }
            }
        };
        let req = match req {
            Some(r) => r,
            None => {
                // nothing queued: park briefly without holding the lock
                std::thread::sleep(Duration::from_micros(50));
                continue;
            }
        };
        let result = serve_one(&client, &manifest, &mut cache, &stats,
                               &req);
        let _ = req.resp.send(result);
    }
}

fn serve_one(client: &xla::PjRtClient, manifest: &Manifest,
             cache: &mut HashMap<String, xla::PjRtLoadedExecutable>,
             stats: &Arc<Mutex<EngineStats>>, req: &ExecuteReq)
             -> Result<Vec<Tensor>> {
    let spec = manifest.artifact(&req.name)?;
    if !cache.contains_key(&req.name) {
        let t0 = Instant::now();
        let exe = compile(client, &spec.file, &req.name)?;
        let mut s = stats.lock().unwrap();
        s.compiles += 1;
        s.compile_secs += t0.elapsed().as_secs_f64();
        drop(s);
        cache.insert(req.name.clone(), exe);
    }
    let exe = cache.get(&req.name).unwrap();
    let literals = req
        .inputs
        .iter()
        .map(tensor_to_literal)
        .collect::<Result<Vec<_>>>()?;
    let t0 = Instant::now();
    let result = exe
        .execute::<xla::Literal>(&literals)
        .map_err(|e| anyhow::anyhow!("executing {}: {e:?}", req.name))?;
    let tuple = result[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow::anyhow!("fetch {}: {e:?}", req.name))?;
    // aot.py lowers with return_tuple=True: always a tuple literal.
    let parts = tuple
        .to_tuple()
        .map_err(|e| anyhow::anyhow!("untuple {}: {e:?}", req.name))?;
    if parts.len() != spec.outputs.len() {
        bail!("{}: expected {} outputs, got {}", req.name,
              spec.outputs.len(), parts.len());
    }
    let outs = parts
        .into_iter()
        .zip(&spec.outputs)
        .map(|(l, os)| literal_to_tensor(&l, &os.shape))
        .collect::<Result<Vec<_>>>()?;
    let mut s = stats.lock().unwrap();
    s.executes += 1;
    s.execute_secs += t0.elapsed().as_secs_f64();
    Ok(outs)
}

fn compile(client: &xla::PjRtClient, file: &PathBuf, name: &str)
           -> Result<xla::PjRtLoadedExecutable> {
    let proto = xla::HloModuleProto::from_text_file(
        file.to_str().context("artifact path utf-8")?)
        .map_err(|e| anyhow::anyhow!("loading HLO {}: {e:?}",
                                     file.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow::anyhow!("compiling {name}: {e:?}"))
}

/// Host tensor -> xla Literal (row-major bytes).
pub fn tensor_to_literal(t: &Tensor) -> Result<xla::Literal> {
    let (ty, bytes): (xla::ElementType, &[u8]) = match &t.data {
        TensorData::F32(v) => (xla::ElementType::F32, unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8,
                                       v.len() * 4)
        }),
        TensorData::I32(v) => (xla::ElementType::S32, unsafe {
            std::slice::from_raw_parts(v.as_ptr() as *const u8,
                                       v.len() * 4)
        }),
    };
    xla::Literal::create_from_shape_and_untyped_data(ty, &t.shape, bytes)
        .map_err(|e| anyhow::anyhow!("literal create: {e:?}"))
}

/// xla Literal -> host tensor, shaped per the manifest spec.
pub fn literal_to_tensor(l: &xla::Literal, shape: &[usize])
                         -> Result<Tensor> {
    let ty = l.ty().map_err(|e| anyhow::anyhow!("literal ty: {e:?}"))?;
    let data = match ty {
        xla::ElementType::F32 => TensorData::F32(
            l.to_vec::<f32>()
                .map_err(|e| anyhow::anyhow!("literal f32: {e:?}"))?),
        xla::ElementType::S32 => TensorData::I32(
            l.to_vec::<i32>()
                .map_err(|e| anyhow::anyhow!("literal i32: {e:?}"))?),
        other => bail!("unsupported literal type {other:?}"),
    };
    let t = Tensor { shape: shape.to_vec(), data };
    if t.len() != l.element_count() {
        bail!("literal element count {} != spec shape {:?}",
              l.element_count(), shape);
    }
    Ok(t)
}

/// Zero tensor matching a manifest spec — test/warm-up helper.
pub fn zeros_for_spec(dtype: DType, shape: &[usize]) -> Tensor {
    match dtype {
        DType::F32 => Tensor::zeros(shape),
        DType::I32 => {
            Tensor::from_i32(vec![0; shape.iter().product()], shape)
        }
    }
}
