//! PJRT runtime: manifest parsing + artifact compilation/execution.
//!
//! The Rust request path calls [`engine::Engine::execute`] with named
//! artifacts; Python is never involved at run time.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineStats};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};
