//! Latency/throughput/memory recorders used by the benches and examples.

use std::time::{Duration, Instant};

/// Streaming summary of a series of duration samples.
#[derive(Debug, Default, Clone)]
pub struct LatencyStats {
    samples: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&mut self, d: Duration) {
        self.samples.push(d.as_secs_f64());
    }

    pub fn record_secs(&mut self, s: f64) {
        self.samples.push(s);
    }

    pub fn count(&self) -> usize {
        self.samples.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(0.0, f64::max)
    }

    /// Percentile over recorded samples (q in [0, 100]).
    pub fn percentile(&self, q: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let idx = ((q / 100.0) * (s.len() - 1) as f64).floor() as usize;
        s[idx.min(s.len() - 1)]
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    pub fn sum(&self) -> f64 {
        self.samples.iter().sum()
    }
}

/// Tokens-per-second counter over a wall-clock window.
#[derive(Debug)]
pub struct Throughput {
    start: Instant,
    pub tokens: u64,
}

impl Throughput {
    pub fn start() -> Self {
        Throughput { start: Instant::now(), tokens: 0 }
    }

    pub fn add(&mut self, tokens: u64) {
        self.tokens += tokens;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt == 0.0 {
            0.0
        } else {
            self.tokens as f64 / dt
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Pretty-print bytes as GiB with 2 decimals (figure output helper).
pub fn gib(bytes: u64) -> f64 {
    bytes as f64 / (1u64 << 30) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn latency_percentiles() {
        let mut l = LatencyStats::new();
        for i in 1..=100 {
            l.record_secs(i as f64);
        }
        assert_eq!(l.p50(), 50.0);
        assert_eq!(l.p99(), 99.0);
        assert_eq!(l.min(), 1.0);
        assert_eq!(l.max(), 100.0);
        assert!((l.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn empty_stats_are_zero() {
        let l = LatencyStats::new();
        assert_eq!(l.mean(), 0.0);
        assert_eq!(l.p99(), 0.0);
    }

    #[test]
    fn gib_conversion() {
        assert!((gib(1 << 30) - 1.0).abs() < 1e-9);
    }
}
