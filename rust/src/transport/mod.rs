//! Client <-> base-executor communication links.
//!
//! The paper uses three mechanisms (section 3.5): a pre-allocated shared
//! CUDA tensor + ZeroMQ control channel when co-located, NCCL over NVLink
//! across GPUs, and TCP across nodes.  Here each mechanism is a
//! [`LinkKind`] with a latency + bandwidth model; tensors move for real
//! (in-process) and the link charges simulated transfer time, which the
//! placement experiments consume.

use crate::tensor::Tensor;

/// Physical link classes between a client and the base executor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LinkKind {
    /// Same device: pre-allocated shared tensor, ZeroMQ metadata only.
    SharedLocal,
    /// GPU<->GPU over NVLink (NCCL). ~300 GB/s effective, ~10us setup.
    NvLink,
    /// GPU<->CPU over PCIe gen4 x16. ~25 GB/s effective, ~15us setup.
    Pcie,
    /// Cross-node TCP (the privacy deployment). ~10 Gb/s, ~100us RTT.
    Tcp,
}

impl LinkKind {
    /// One-way latency floor in seconds (control message / kernel setup).
    pub fn latency(self) -> f64 {
        match self {
            LinkKind::SharedLocal => 2e-6, // ZeroMQ metadata ping
            LinkKind::NvLink => 1e-5,
            LinkKind::Pcie => 1.5e-5,
            LinkKind::Tcp => 1e-4,
        }
    }

    /// Effective bandwidth in bytes/s. `SharedLocal` moves no data — the
    /// tensor is shared, only metadata crosses (paper: "sharing obviates
    /// the need to transfer or copy the data").
    pub fn bandwidth(self) -> f64 {
        match self {
            LinkKind::SharedLocal => f64::INFINITY,
            LinkKind::NvLink => 3.0e11,
            LinkKind::Pcie => 2.5e10,
            LinkKind::Tcp => 1.25e9,
        }
    }

    /// Simulated time to move `bytes` across this link.
    pub fn transfer_time(self, bytes: u64) -> f64 {
        self.latency() + bytes as f64 / self.bandwidth()
    }
}

/// A link instance with accumulated traffic statistics.
#[derive(Debug)]
pub struct Link {
    pub kind: LinkKind,
    pub bytes_moved: u64,
    pub messages: u64,
    pub sim_time: f64,
}

impl Link {
    pub fn new(kind: LinkKind) -> Self {
        Link { kind, bytes_moved: 0, messages: 0, sim_time: 0.0 }
    }

    /// Account a tensor crossing the link; returns the simulated transfer
    /// time for this message.
    pub fn send(&mut self, t: &Tensor) -> f64 {
        self.send_bytes(t.size_bytes() as u64)
    }

    pub fn send_bytes(&mut self, bytes: u64) -> f64 {
        let dt = self.kind.transfer_time(bytes);
        // SharedLocal counts messages, not payload bytes.
        if self.kind != LinkKind::SharedLocal {
            self.bytes_moved += bytes;
        }
        self.messages += 1;
        self.sim_time += dt;
        dt
    }
}

/// Shared pre-allocated exchange buffer, mirroring the paper's
/// `share_memory_()` / `rebuild_cuda_tensor()` optimization: allocated
/// once at `batch x seq x max(din, dout)` and resized only when a request
/// exceeds it (section 3.5).
#[derive(Debug)]
pub struct SharedBuffer {
    capacity_elems: usize,
    pub resizes: u64,
}

impl SharedBuffer {
    pub fn new(batch: usize, seq: usize, max_dim: usize) -> Self {
        SharedBuffer { capacity_elems: batch * seq * max_dim, resizes: 0 }
    }

    /// Ensure the buffer can hold a tensor; grows (and counts a resize —
    /// the expensive CUDA-call path in the paper) when too small.
    pub fn ensure(&mut self, t: &Tensor) {
        if t.len() > self.capacity_elems {
            self.capacity_elems = t.len();
            self.resizes += 1;
        }
    }

    pub fn capacity(&self) -> usize {
        self.capacity_elems
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shared_local_is_fastest() {
        let b = 1 << 20; // 1 MiB
        let t_local = LinkKind::SharedLocal.transfer_time(b);
        let t_nv = LinkKind::NvLink.transfer_time(b);
        let t_pcie = LinkKind::Pcie.transfer_time(b);
        let t_tcp = LinkKind::Tcp.transfer_time(b);
        assert!(t_local < t_nv && t_nv < t_pcie && t_pcie < t_tcp);
    }

    #[test]
    fn link_accumulates_stats() {
        let mut l = Link::new(LinkKind::NvLink);
        let t = Tensor::zeros(&[16, 64]);
        l.send(&t);
        l.send(&t);
        assert_eq!(l.messages, 2);
        assert_eq!(l.bytes_moved, 2 * 16 * 64 * 4);
        assert!(l.sim_time > 0.0);
    }

    #[test]
    fn shared_local_moves_no_bytes() {
        let mut l = Link::new(LinkKind::SharedLocal);
        l.send(&Tensor::zeros(&[1024]));
        assert_eq!(l.bytes_moved, 0);
        assert_eq!(l.messages, 1);
    }

    #[test]
    fn shared_buffer_grows_once() {
        let mut b = SharedBuffer::new(2, 128, 256);
        b.ensure(&Tensor::zeros(&[2 * 128, 256]));
        assert_eq!(b.resizes, 0);
        b.ensure(&Tensor::zeros(&[2 * 512, 256]));
        assert_eq!(b.resizes, 1);
        b.ensure(&Tensor::zeros(&[2 * 256, 256]));
        assert_eq!(b.resizes, 1);
    }
}
