//! Model configuration registry — the Rust mirror of
//! `python/compile/configs.py`.
//!
//! Two families:
//! * **Executable** (`sym-tiny`, `sym-small`): actually run end-to-end
//!   through PJRT; dims are re-checked against the AOT manifest at load.
//! * **Paper models** (Llama2-7B/13B, GPT2-XL, …): analytic configs with
//!   published dims, used by the device simulator to reproduce the paper's
//!   memory/placement figures.

/// Parameter/activation precision on the (simulated) paper testbed.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    F16,
    BF16,
    F32,
}

impl Precision {
    pub fn bytes(self) -> usize {
        match self {
            Precision::F16 | Precision::BF16 => 2,
            Precision::F32 => 4,
        }
    }
}

/// Dimensions of a decoder-only transformer.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: &'static str,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_layers: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub precision: Precision,
    /// Whether HLO artifacts exist for this config.
    pub executable: bool,
    /// KV heads (< n_heads for MQA/GQA models: Starcoder, Granite,
    /// Llama3).  Affects qkv parameter count and KV-cache size.
    pub kv_heads: usize,
    /// MLP matrices per block: 2 (GPT GELU) or 3 (Llama/Gemma SwiGLU).
    pub mlp_mats: usize,
    /// Whether the HF implementation materializes (B, H, S, S) attention
    /// scores for backward (eager attention: GPT2, GPTBigCode) or uses
    /// SDPA/flash (Llama, Gemma).  Drives the activation-memory model.
    pub eager_attn: bool,
}

impl ModelConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// Total base-model parameter count (embed + pos + blocks + head).
    /// Accounts for MQA/GQA (`kv_heads`) and gated MLPs (`mlp_mats`).
    pub fn n_params(&self) -> u64 {
        let (d, f, v) = (self.d_model as u64, self.d_ff as u64,
                         self.vocab as u64);
        let kv_dim = (self.kv_heads * self.d_head()) as u64;
        let per_layer = d * d + 2 * d * kv_dim + 3 * d // q + kv proj
            + d * d + d                                 // attn out
            + self.mlp_mats as u64 * d * f + f + d      // mlp
            + 2 * d;                                    // norms
        v * d + self.max_seq as u64 * d + self.n_layers as u64 * per_layer
            + d + d * v + v
    }

    /// Base-model weight bytes at this config's precision.
    pub fn param_bytes(&self) -> u64 {
        self.n_params() * self.precision.bytes() as u64
    }

    /// KV-cache bytes for one request:
    /// 2 (K and V) * layers * seq * kv_heads * d_head.
    pub fn kv_cache_bytes(&self, batch: usize, seq_len: usize) -> u64 {
        2 * self.n_layers as u64
            * batch as u64
            * seq_len as u64
            * (self.kv_heads * self.d_head()) as u64
            * self.precision.bytes() as u64
    }

    /// Approximate FLOPs of one forward pass over `t` tokens
    /// (2*params*tokens for the linears + attention quadratic term).
    pub fn forward_flops(&self, t: u64, kv_len: u64) -> u64 {
        let d = self.d_model as u64;
        let kv_dim = (self.kv_heads * self.d_head()) as u64;
        let linear = 2 * t
            * (self.n_layers as u64
                * (d * d + 2 * d * kv_dim + d * d
                    + self.mlp_mats as u64 * d * self.d_ff as u64)
                + d * self.vocab as u64);
        let attn = 4 * self.n_layers as u64 * t * kv_len * d;
        linear + attn
    }

    /// Backward is ~2x forward for the linears (dX and the adapter path).
    pub fn backward_flops(&self, t: u64, kv_len: u64) -> u64 {
        2 * self.forward_flops(t, kv_len)
    }

    /// LoRA adapter parameter count for rank `r` over `n_targets`
    /// projection matrices per layer.
    pub fn lora_params(&self, rank: usize, n_targets: usize) -> u64 {
        (self.n_layers * n_targets * 2 * self.d_model * rank) as u64
    }

    /// Adam optimizer state bytes for an adapter (2 moments, f32).
    pub fn optimizer_bytes(&self, rank: usize, n_targets: usize) -> u64 {
        self.lora_params(rank, n_targets) * 2 * 4
    }

    /// Activation bytes crossing the client->executor boundary per layer
    /// invocation (one (T, d_model) f-precision tensor).
    pub fn activation_bytes(&self, t: u64) -> u64 {
        t * self.d_model as u64 * self.precision.bytes() as u64
    }
}

/// Executable family — must match `python/compile/configs.py`.
pub const SYM_TINY: ModelConfig = ModelConfig {
    name: "sym-tiny",
    vocab: 256,
    d_model: 64,
    n_heads: 4,
    n_layers: 4,
    d_ff: 256,
    max_seq: 512,
    precision: Precision::F32,
    executable: true,
    kv_heads: 4, mlp_mats: 2,
 eager_attn: false,
};

pub const SYM_SMALL: ModelConfig = ModelConfig {
    name: "sym-small",
    vocab: 512,
    d_model: 128,
    n_heads: 8,
    n_layers: 8,
    d_ff: 512,
    max_seq: 512,
    precision: Precision::F32,
    executable: true,
    kv_heads: 8, mlp_mats: 2,
 eager_attn: false,
};

/// Paper evaluation models (analytic only).
pub const GPT2_XL: ModelConfig = ModelConfig {
    name: "gpt2-xl", vocab: 50257, d_model: 1600, n_heads: 25, n_layers: 48,
    d_ff: 6400, max_seq: 1024, precision: Precision::F16, executable: false,
    kv_heads: 25, mlp_mats: 2,
 eager_attn: true,
};
pub const LLAMA3_1B: ModelConfig = ModelConfig {
    name: "llama3-1b", vocab: 128256, d_model: 2048, n_heads: 32,
    n_layers: 16, d_ff: 8192, max_seq: 8192, precision: Precision::BF16,
    executable: false,
    kv_heads: 8, mlp_mats: 3,
 eager_attn: false,
};
pub const LLAMA2_7B: ModelConfig = ModelConfig {
    name: "llama2-7b", vocab: 32000, d_model: 4096, n_heads: 32,
    n_layers: 32, d_ff: 11008, max_seq: 4096, precision: Precision::F16,
    executable: false,
    kv_heads: 32, mlp_mats: 3,
 eager_attn: false,
};
pub const LLAMA2_13B: ModelConfig = ModelConfig {
    name: "llama2-13b", vocab: 32000, d_model: 5120, n_heads: 40,
    n_layers: 40, d_ff: 13824, max_seq: 4096, precision: Precision::F16,
    executable: false,
    kv_heads: 40, mlp_mats: 3,
 eager_attn: false,
};
pub const GRANITE_20B: ModelConfig = ModelConfig {
    name: "granite-20b", vocab: 49152, d_model: 6144, n_heads: 48,
    n_layers: 52, d_ff: 24576, max_seq: 8192, precision: Precision::F16,
    executable: false,
    kv_heads: 1, mlp_mats: 2,
 eager_attn: true,
};
pub const STARCODER_15B: ModelConfig = ModelConfig {
    name: "starcoder-15b", vocab: 49152, d_model: 6144, n_heads: 48,
    n_layers: 40, d_ff: 24576, max_seq: 8192, precision: Precision::F32,
    executable: false,
    kv_heads: 1, mlp_mats: 2,
 eager_attn: true,
};
pub const GEMMA2_27B: ModelConfig = ModelConfig {
    name: "gemma2-27b", vocab: 256128, d_model: 4608, n_heads: 32,
    n_layers: 46, d_ff: 36864, max_seq: 8192, precision: Precision::BF16,
    executable: false,
    kv_heads: 16, mlp_mats: 3,
 eager_attn: false,
};

/// Look up any model (executable or analytic) by name.
pub fn model_by_name(name: &str) -> Option<ModelConfig> {
    Some(match name {
        "sym-tiny" => SYM_TINY,
        "sym-small" => SYM_SMALL,
        "gpt2-xl" => GPT2_XL,
        "llama3-1b" => LLAMA3_1B,
        "llama2-7b" => LLAMA2_7B,
        "llama2-13b" => LLAMA2_13B,
        "granite-20b" => GRANITE_20B,
        "starcoder-15b" => STARCODER_15B,
        "gemma2-27b" => GEMMA2_27B,
        _ => return None,
    })
}

/// Token-count buckets for the flattened-linear executor artifacts
/// (mirrors `configs.TOKEN_BUCKETS`).
pub const TOKEN_BUCKETS: &[usize] = &[8, 16, 32, 64, 128, 256, 512, 1024,
                                      2048];
/// Sequence buckets for attention artifacts.
pub const SEQ_BUCKETS: &[usize] = &[16, 32, 64, 128, 256, 512];
/// Request batch sizes with attention artifacts.
pub const ATTN_BATCHES: &[usize] = &[1, 2, 4];
/// Exported LoRA ranks.
pub const LORA_RANKS: &[usize] = &[8, 64];
/// Adam artifact parameter-count buckets.
pub const ADAM_BUCKETS: &[usize] = &[1024, 2048, 4096, 8192, 16384, 32768,
                                     65536, 131072, 262144, 524288];

/// Smallest bucket >= n.
pub fn bucket_for(n: usize, buckets: &[usize]) -> Option<usize> {
    buckets.iter().copied().find(|&b| b >= n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_selection() {
        assert_eq!(bucket_for(1, TOKEN_BUCKETS), Some(8));
        assert_eq!(bucket_for(8, TOKEN_BUCKETS), Some(8));
        assert_eq!(bucket_for(9, TOKEN_BUCKETS), Some(16));
        assert_eq!(bucket_for(2048, TOKEN_BUCKETS), Some(2048));
        assert_eq!(bucket_for(2049, TOKEN_BUCKETS), None);
    }

    #[test]
    fn paper_model_sizes_are_plausible() {
        // Published sizes: 7B ~= 13GB f16, 13B ~= 26GB f16 (paper Table 3).
        let gb = |b: u64| b as f64 / (1 << 30) as f64;
        assert!((gb(LLAMA2_7B.param_bytes()) - 13.0).abs() < 2.0);
        assert!((gb(LLAMA2_13B.param_bytes()) - 26.0).abs() < 3.0);
        assert!((gb(GPT2_XL.param_bytes()) - 3.2).abs() < 1.5);
    }

    #[test]
    fn kv_cache_matches_paper_examples() {
        // Paper section 3.4: Llama2-7B, 16K tokens, batch 1 => ~8 GB.
        let bytes = LLAMA2_7B.kv_cache_bytes(1, 16 * 1024);
        let gb = bytes as f64 / (1 << 30) as f64;
        assert!((gb - 8.0).abs() < 0.5, "got {gb} GB");
        // Fig 19: 128K context = 64GB KV cache.
        let gb128 = LLAMA2_7B.kv_cache_bytes(1, 128 * 1024) as f64
            / (1 << 30) as f64;
        assert!((gb128 - 64.0).abs() < 2.0, "got {gb128} GB");
    }

    #[test]
    fn tiny_config_matches_python() {
        assert_eq!(SYM_TINY.d_head(), 16);
        assert_eq!(SYM_TINY.n_layers, 4);
        assert_eq!(SYM_TINY.lora_params(8, 4), 4 * 4 * 2 * 64 * 8);
    }
}
