//! Per-layer batching policies at the base executor.
//!
//! The paper compares three (Table 5):
//! * **NoLockstep** — every request executes immediately, batch of 1.
//!   Maximal independence, minimal batching efficiency.
//! * **Lockstep** — the executor waits for *all* registered clients at
//!   every layer (how vLLM/mLoRA-style shared-base systems behave). Small
//!   requests inherit the latency of the slowest client (Table 4).
//! * **Opportunistic** — wait a bounded, urgency-scaled time to
//!   accumulate a batch; requests batched at layer *i* are NOT required
//!   to batch again at layer *i+1* (section 3.7).
//!
//! A fourth, [`BatchPolicy::Continuous`], serves the iteration-level
//! scheduler ([`crate::coordinator::scheduler`]): the executor never
//! waits on a registration cohort — each flush takes whatever the
//! scheduler's current wavefront dispatched.

#![deny(clippy::unwrap_used)]

use std::time::Duration;

use crate::coordinator::proto::Urgency;

/// Executor batching policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum BatchPolicy {
    NoLockstep,
    /// Per-shard lockstep: each shard's barrier counts the
    /// registrations *it* received.  Registration and deregistration
    /// messages reach shards independently, so the counts can drift
    /// apart transiently under client churn.
    Lockstep,
    /// Fleet-wide lockstep: every shard's barrier counts against one
    /// shared fleet-global client count (an `Arc`'d registration
    /// counter the *clients* update synchronously — see
    /// [`crate::coordinator::fleet::FleetBarrier`]).  This reproduces
    /// mLoRA's *global* lockstep at shards > 1 for the Table 4/5
    /// comparisons: a layer flushes only when every client of the
    /// deployment has arrived, not every client the local shard happens
    /// to have counted.
    LockstepFleet,
    /// `base_wait` is the budget for `Urgency::Training`; other classes
    /// scale down from it.
    Opportunistic { base_wait: Duration },
    /// Iteration-driven continuous batching: the scheduler — not a
    /// registration cohort — decides who participates in each token
    /// iteration, so the executor flushes per iteration: requests
    /// accumulate only while the ingress channel drains (one wavefront's
    /// dispatches arrive back-to-back), then the idle flush sends the
    /// whole batch.  A small deadline bounds the wait so a straggling
    /// iteration cannot park the device.
    Continuous,
}

impl BatchPolicy {
    /// Default opportunistic policy: 50 ms worst-case wait for training /
    /// big-batch requests — the paper's "256-batch waits at most 50ms".
    pub fn opportunistic_default() -> Self {
        BatchPolicy::Opportunistic { base_wait: Duration::from_millis(50) }
    }

    /// Wait budget for a request of a given urgency: interactive decode
    /// requests wait a small fraction of the training budget, bulk
    /// requests half of it (the wait is "a smaller fraction of their
    /// naturally longer iteration latency").
    pub fn wait_budget(&self, urgency: Urgency) -> Duration {
        match self {
            BatchPolicy::NoLockstep => Duration::ZERO,
            // lockstep has no deadline: it waits for the client barrier;
            // the cap bounds the damage when a client leaves mid-layer.
            BatchPolicy::Lockstep | BatchPolicy::LockstepFleet => {
                Duration::from_millis(50)
            }
            BatchPolicy::Opportunistic { base_wait } => match urgency {
                Urgency::Interactive => *base_wait / 50,
                Urgency::Bulk => *base_wait / 4,
                // Background is sheddable, not slower: it gets the full
                // training budget when admitted at all.
                Urgency::Training | Urgency::Background => *base_wait,
            },
            // Long enough to catch a wavefront's stragglers arriving
            // back-to-back, short enough to never stall an iteration.
            BatchPolicy::Continuous => Duration::from_millis(2),
        }
    }

    /// Whether a pending batch should flush given the number of distinct
    /// clients queued and the number registered.  For `LockstepFleet`
    /// the executor passes the fleet-global registration count as
    /// `registered`; for `Lockstep` the shard-local one.
    pub fn ready(&self, queued_clients: usize, registered: usize) -> bool {
        match self {
            BatchPolicy::NoLockstep => true,
            BatchPolicy::Lockstep | BatchPolicy::LockstepFleet => {
                registered > 0 && queued_clients >= registered
            }
            // Opportunistic flushes on deadline (handled by the executor
            // loop), or early when everyone is already here.
            BatchPolicy::Opportunistic { .. } => {
                registered > 0 && queued_clients >= registered
            }
            // Never cohort-flush: the drain-idle flush (plus the small
            // deadline) delivers exactly the current iteration's batch.
            BatchPolicy::Continuous => false,
        }
    }

    /// Whether this policy holds a barrier (no flush-on-idle).
    pub fn is_lockstep(&self) -> bool {
        matches!(self,
                 BatchPolicy::Lockstep | BatchPolicy::LockstepFleet)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn nolockstep_always_ready_with_zero_wait() {
        let p = BatchPolicy::NoLockstep;
        assert!(p.ready(1, 8));
        assert_eq!(p.wait_budget(Urgency::Training), Duration::ZERO);
    }

    #[test]
    fn lockstep_waits_for_everyone() {
        for p in [BatchPolicy::Lockstep, BatchPolicy::LockstepFleet] {
            assert!(!p.ready(3, 4));
            assert!(p.ready(4, 4));
            assert!(p.is_lockstep());
            assert_eq!(p.wait_budget(Urgency::Interactive),
                       Duration::from_millis(50));
        }
        assert!(!BatchPolicy::NoLockstep.is_lockstep());
        assert!(!BatchPolicy::opportunistic_default().is_lockstep());
    }

    #[test]
    fn opportunistic_scales_wait_with_urgency() {
        let p = BatchPolicy::opportunistic_default();
        let t = p.wait_budget(Urgency::Training);
        let b = p.wait_budget(Urgency::Bulk);
        let i = p.wait_budget(Urgency::Interactive);
        assert!(i < b && b < t);
        assert_eq!(t, Duration::from_millis(50));
        assert_eq!(p.wait_budget(Urgency::Background), t,
                   "background waits like training; shedding — not a \
                    shorter budget — is its degraded mode");
    }

    #[test]
    fn continuous_never_cohort_flushes_and_holds_no_barrier() {
        let p = BatchPolicy::Continuous;
        assert!(!p.ready(8, 8),
                "continuous ignores the registration cohort entirely");
        assert!(!p.ready(1, 0));
        assert!(!p.is_lockstep(),
                "must flush on idle drain, or iterations would deadlock");
        for u in [Urgency::Interactive, Urgency::Bulk, Urgency::Training,
                  Urgency::Background] {
            let w = p.wait_budget(u);
            assert!(w > Duration::ZERO && w <= Duration::from_millis(5),
                    "small uniform deadline, urgency-independent: the \
                     scheduler already ordered the iteration");
        }
    }
}
