//! Clients: the drivers of inference and fine-tuning.
//!
//! Each client owns everything request-specific — adapter parameters,
//! attention + KV cache, optimizer state, saved activations for its own
//! backward — and invokes the shared base executor layer-by-layer through
//! its [`VirtLayerCtx`].  Clients never synchronize with each other; they
//! only opportunistically share executor batches (paper section 3.2,
//! design goal 5).
//!
//! The transformer block is implemented **once**, in [`LayerWalker`]:
//! batch prefill, incremental prefill, token decode, and the training
//! forward are all the same walk, parameterized by how attention reads
//! K/V ([`AttnPath`]) and whether activations are saved for backward.
//! Adapter math enters only through the [`AdapterHooks`] interception
//! points — the walker never inspects the adapter kind.
//!
//! # Pipelined prefill
//!
//! A sequential walk visits the fleet's shards strictly in order: shard
//! s+1 idles while shard s computes.  With
//! [`SessionBuilder::prefill_chunk`] (or
//! [`GenerationConfig::with_prefill_chunk`]) the prompt is split into
//! micro-batches along the token axis and driven as a wavefront via the
//! split-phase [`VirtLayerCtx::dispatch`] API: micro-batch k runs on
//! shard s+1 while micro-batch k+1 occupies shard s, each micro-batch
//! keeping one request in flight.  Causality is the only cross-chunk
//! dependency — micro-batch k's attention reads the K/V of micro-batches
//! 0..k — so a reorder gate makes K/V enter the session cache in token
//! order, and a reorder buffer recombines per-chunk logits into the
//! sequential token-major layout.  Every client-side op is row-wise and
//! attention is causal, so the pipelined walk is output-identical to the
//! sequential one (asserted by `tests/pipeline_equivalence.rs` and the
//! `pipeline` bench section); unlike batch prefill it also accepts a
//! prefix-seeded cache, because each chunk attends over the real cache
//! prefix.  What is charged where follows the split-phase contract: the
//! request link at dispatch, the response link + executor queue-wait at
//! collect.
//!
//! * [`InferenceSession`] — prefill + decode against a bucketed KV cache
//!   (optionally host-offloaded), built via
//!   [`SessionBuilder`](crate::coordinator::SessionBuilder), driven
//!   either by [`InferenceSession::generate`] or by the low-level
//!   `prefill`/`decode_step` calls.
//! * [`Trainer`] — full forward/backward/Adam iteration.  The backward
//!   composes the executor's memory-optimized `dX = dY . W^T` with
//!   client-side attention/adapter/norm gradients, reproducing jax
//!   autodiff (pinned by the golden integration tests).
//!
//! # Pipelined training
//!
//! With [`TrainerBuilder::micro_batches`] the training batch is split
//! along the *batch* axis into M micro-batches driven through the fleet
//! as a GPipe-style wavefront ([`TrainDriver`]): the forward fills the
//! pipeline, the backward drains it, and each micro-batch keeps its own
//! activation stash.  Per-micro-batch work is row-wise (every
//! client-side op, the executor's linears, and per-(b,h) attention), so
//! each micro-batch's activations and dX chain are bit-identical to the
//! corresponding rows of the full-batch walk.  The two reductions that
//! are *not* row-wise are run once at full shape behind barriers: the
//! loss (per-chunk logits reassembled, the same `xent` artifact call)
//! and the adapter-gradient accumulations (per layer, once every
//! micro-batch has passed it in backward, over the reassembled
//! full-batch tensors).  The final Adam step is therefore bit-identical
//! to the sequential walk — pinned by `tests/training_pipeline.rs`.
//!
//! Training memory is a first-class ledger citizen like KV: Adam state
//! is charged under `opt:client{id}` at build, saved activations under
//! `act:client{id}` as micro-batches stash them (released as backward
//! consumes the stash), with typed [`SymbiosisError::TrainerOom`] /
//! `QuotaExceeded` at the capacity edge.

#![deny(clippy::unwrap_used)]

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::{bucket_for, ModelConfig, ATTN_BATCHES, SEQ_BUCKETS,
                    TOKEN_BUCKETS};
use crate::coordinator::adapter::{Adapter, AdapterGrads, AdapterHooks,
                                  HookCtx, NO_ADAPTER};
use crate::coordinator::admission::{SessionTicket, TenantState};
use crate::coordinator::fleet::TrainingStats;
use crate::coordinator::kv_cache::{BlockPool, KvCache, KvPlacement,
                                   PrefixMeta};
use crate::coordinator::model_state::ClientWeights;
use crate::coordinator::optimizer::Adam;
use crate::coordinator::privacy::PrivacyCtx;
use crate::coordinator::proto::{LayerId, Urgency};
use crate::coordinator::virt_layer::{PendingLayer, RetryPolicy,
                                     VirtLayerCtx};
use crate::coordinator::Deployment;
use crate::device::Device;
use crate::error::{SymResult, SymbiosisError};
use crate::runtime::Engine;
use crate::tensor::{ops, Tensor};
use crate::transport::LinkKind;

/// Shared per-client context: model dims, client-side weights, executor
/// proxy, and the engine used for client-side artifacts (attention,
/// adapters, loss) — in a local placement this is the same engine as the
/// executor's.
#[derive(Clone)]
pub struct ClientCore {
    pub cfg: ModelConfig,
    pub engine: Arc<Engine>,
    pub virt: Arc<VirtLayerCtx>,
    pub weights: ClientWeights,
    pub adapter: Option<Adapter>,
}

/// Per-layer activations saved *by the client* for its backward pass.
/// The executor saves nothing (paper section 3.6).
struct SavedLayer {
    h_in: Tensor,        // (T, D) input to the block
    a_in: Tensor,        // (T, D) rmsnorm1 output (adapter bwd input)
    qh: Tensor,          // (BH, S, H)
    kh: Tensor,
    vh: Tensor,
    attn_merged: Tensor, // (T, D)
    h_mid: Tensor,       // (T, D) after attention residual
    u_pre: Tensor,       // (T, F) gelu input
}

struct SavedActs {
    layers: Vec<SavedLayer>,
    h_last: Tensor,
}

impl ClientCore {
    fn check_batch(&self, batch: usize) -> Result<(), SymbiosisError> {
        if !ATTN_BATCHES.contains(&batch) {
            return Err(SymbiosisError::UnsupportedBatch {
                batch,
                supported: ATTN_BATCHES,
            });
        }
        Ok(())
    }

    /// The adapter's hook set, or the identity hooks for a bare client.
    fn hooks(&self) -> &dyn AdapterHooks {
        self.adapter
            .as_ref()
            .map(|a| a.hooks())
            .unwrap_or(&NO_ADAPTER)
    }

    // The four block transitions below are the single source of the
    // transformer-block math between base-layer hops.  Both walks — the
    // sequential [`LayerWalker::walk`] and the split-phase
    // [`PipelineDriver::advance`] — call these, so the math cannot
    // drift between them; only the dispatch/collect sequencing differs.

    /// Split the fused-QKV projection into `(q, k, v)` and run the
    /// adapter's projection-side hooks (`qkv_delta`, then `kv_scale`).
    fn qkv_split_adjust(&self, cx: &HookCtx, l: usize, a_in: &Tensor,
                        qkv: &Tensor)
                        -> Result<(Tensor, Tensor, Tensor)> {
        let d = self.cfg.d_model;
        let mut q = qkv.slice_cols(0, d);
        let mut k = qkv.slice_cols(d, 2 * d);
        let mut v = qkv.slice_cols(2 * d, 3 * d);
        let hooks = self.hooks();
        hooks.qkv_delta(cx, l, a_in, &mut q, &mut k, &mut v)?;
        hooks.kv_scale(l, &mut k, &mut v);
        Ok((q, k, v))
    }

    /// Attention-output transition: adapter `attn_out_delta` on `o`,
    /// residual add onto `h`, rmsnorm-2.  Returns `(h_mid, m_in)` —
    /// the residual carried forward and the MlpUp input.
    fn attn_out_transition(&self, cx: &HookCtx, l: usize, h: &Tensor,
                           attn_merged: &Tensor, o: &mut Tensor)
                           -> Result<(Tensor, Tensor)> {
        self.hooks().attn_out_delta(cx, l, attn_merged, o)?;
        let h_mid = ops::add(h, o);
        let m_in = ops::rmsnorm(&h_mid, &self.weights.norm2[l]);
        Ok((h_mid, m_in))
    }

    /// FFN activation: adapter `ffn_scale` then gelu.  Scales `u_pre`
    /// in place — the training forward saves the *scaled*
    /// pre-activation for its backward.
    fn ffn_activate(&self, l: usize, u_pre: &mut Tensor) -> Tensor {
        self.hooks().ffn_scale(l, u_pre);
        ops::gelu(u_pre)
    }

    /// Final rmsnorm before the LM head.
    fn final_norm(&self, h: &Tensor) -> Tensor {
        ops::rmsnorm(h, &self.weights.norm_f)
    }

    /// Place a `(BH, T, H)` chunk at sequence offset `start` of a
    /// zeroed `(BH, bucket, H)` tensor.  Pipelined prefill uses this to
    /// put a micro-batch's queries at their *absolute* causal rows so
    /// the prefill attention artifact's mask attends exactly the cache
    /// prefix each query may see.
    fn place_seq(x: &Tensor, start: usize, bucket: usize) -> Tensor {
        let (bh, t, h) = (x.shape[0], x.shape[1], x.shape[2]);
        debug_assert!(start + t <= bucket,
                      "window {start}+{t} exceeds bucket {bucket}");
        let src = x.as_f32();
        let mut out = vec![0.0f32; bh * bucket * h];
        for b in 0..bh {
            let srow = b * t * h;
            let drow = (b * bucket + start) * h;
            out[drow..drow + t * h]
                .copy_from_slice(&src[srow..srow + t * h]);
        }
        Tensor::from_f32(out, &[bh, bucket, h])
    }

    /// Cut the `[start, start + len)` sequence window out of a
    /// `(BH, Sb, H)` tensor (the rows outside a micro-batch's window are
    /// discarded — causal masking makes them garbage-in/garbage-out for
    /// zero-placed queries).
    fn slice_seq(x: &Tensor, start: usize, len: usize) -> Tensor {
        let (bh, sb, h) = (x.shape[0], x.shape[1], x.shape[2]);
        debug_assert!(start + len <= sb,
                      "window {start}+{len} exceeds seq {sb}");
        let src = x.as_f32();
        let mut out = vec![0.0f32; bh * len * h];
        for b in 0..bh {
            let srow = (b * sb + start) * h;
            let drow = b * len * h;
            out[drow..drow + len * h]
                .copy_from_slice(&src[srow..srow + len * h]);
        }
        Tensor::from_f32(out, &[bh, len, h])
    }

    /// Zero-pad `(BH, S, H)` to `(BH, Sb, H)` along the sequence axis.
    fn pad_seq(x: &Tensor, sb: usize) -> Tensor {
        if x.shape[1] == sb {
            return x.clone(); // refcount bump, not a copy
        }
        Self::place_seq(x, 0, sb)
    }

    /// Drop sequence padding: `(BH, Sb, H) -> (BH, S, H)`.
    fn unpad_seq(x: &Tensor, s: usize) -> Tensor {
        if x.shape[1] == s {
            return x.clone();
        }
        Self::slice_seq(x, 0, s)
    }

    /// `(T, D) x3 -> (T, 3D)` — reassemble the fused-QKV gradient.
    fn concat_cols3(a: &Tensor, b: &Tensor, c: &Tensor) -> Tensor {
        let (t, d) = (a.shape[0], a.shape[1]);
        let mut out = vec![0.0f32; t * 3 * d];
        for r in 0..t {
            out[r * 3 * d..r * 3 * d + d]
                .copy_from_slice(&a.as_f32()[r * d..(r + 1) * d]);
            out[r * 3 * d + d..r * 3 * d + 2 * d]
                .copy_from_slice(&b.as_f32()[r * d..(r + 1) * d]);
            out[r * 3 * d + 2 * d..r * 3 * d + 3 * d]
                .copy_from_slice(&c.as_f32()[r * d..(r + 1) * d]);
        }
        Tensor::from_f32(out, &[t, 3 * d])
    }

    /// Full forward over `batch` sequences of length `s` (token-major
    /// concat) through the shared layer walker.  Saves activations when
    /// `save` is set (training) and appends K/V when `kv` is set
    /// (inference prefill).
    fn forward_full(&self, tokens: &[i32], batch: usize, urgency: Urgency,
                    save: Option<&mut SavedActs>,
                    kv: Option<&mut KvCache>) -> Result<Tensor> {
        self.check_batch(batch)?;
        let t = tokens.len();
        let s = t / batch;
        let sb = bucket_for(s, SEQ_BUCKETS)
            .ok_or(SymbiosisError::ContextExceeded {
                len: s,
                limit: *SEQ_BUCKETS.last()
                    .expect("SEQ_BUCKETS is a non-empty static"),
            })?;

        // positions restart per sequence
        let positions: Vec<i32> =
            (0..t).map(|i| (i % s) as i32).collect();
        let h = self.virt.embed(
            Tensor::from_i32(tokens.to_vec(), &[t]),
            Tensor::from_i32(positions, &[t]),
            urgency,
        )?;
        LayerWalker::full(self, batch, s, sb, urgency, save, kv).walk(h)
    }
}

// ---------------------------------------------------------------------------
// The layer walker — the one transformer-block implementation
// ---------------------------------------------------------------------------

/// How the walk computes attention.
enum AttnPath<'a> {
    /// Full-sequence causal attention over freshly-projected K/V
    /// (batch prefill and the training forward).  Optionally appends
    /// each layer's K/V to the session cache.
    Full {
        batch: usize,
        seq: usize,
        seq_bucket: usize,
        kv: Option<&'a mut KvCache>,
    },
    /// One token column attended against the session's KV cache
    /// (decode and incremental prefill); `len` is the per-layer cache
    /// length *after* this step's append, `seq_bucket` its bucket.
    Cached {
        batch: usize,
        kv: &'a mut KvCache,
        len: usize,
        seq_bucket: usize,
    },
}

/// One pass over all transformer blocks.  Every *blocking* execution
/// mode of the system — training forward, batch prefill, incremental
/// prefill, token decode — is this walk; they differ only in the
/// [`AttnPath`] and in whether activations are retained.
///
/// KEEP IN SYNC: the pipelined prefill driver ([`PipelineDriver`])
/// encodes the same walk as a split-phase state machine (one `Stage`
/// per base-layer hop).  The block *math* is shared — both walks go
/// through the `ClientCore` transition helpers (`qkv_split_adjust`,
/// `attn_out_transition`, `ffn_activate`, `final_norm`) — so what can
/// still drift is the dispatch/collect sequencing: any change to the
/// hop order here must be mirrored there.  The equivalence tests
/// (`tests/pipeline_equivalence.rs`) and the `pipeline` bench assert
/// the two walks stay output-identical, but only on hosts with AOT
/// artifacts.
struct LayerWalker<'a> {
    core: &'a ClientCore,
    urgency: Urgency,
    path: AttnPath<'a>,
    save: Option<&'a mut SavedActs>,
    /// Attention artifact name — constant across layers, formatted once
    /// per walk (not twice per layer per token).
    attn_artifact: String,
}

impl<'a> LayerWalker<'a> {
    fn full(core: &'a ClientCore, batch: usize, seq: usize,
            seq_bucket: usize, urgency: Urgency,
            save: Option<&'a mut SavedActs>, kv: Option<&'a mut KvCache>)
            -> Self {
        let attn_artifact = format!("attn_prefill_bh{}_s{seq_bucket}_h{}",
                                    batch * core.cfg.n_heads,
                                    core.cfg.d_head());
        LayerWalker {
            core,
            urgency,
            path: AttnPath::Full { batch, seq, seq_bucket, kv },
            save,
            attn_artifact,
        }
    }

    fn cached(core: &'a ClientCore, batch: usize, kv: &'a mut KvCache,
              len: usize, seq_bucket: usize, urgency: Urgency) -> Self {
        let attn_artifact = format!("attn_decode_bh{}_s{seq_bucket}_h{}",
                                    batch * core.cfg.n_heads,
                                    core.cfg.d_head());
        LayerWalker {
            core,
            urgency,
            path: AttnPath::Cached { batch, kv, len, seq_bucket },
            save: None,
            attn_artifact,
        }
    }

    /// Attention for layer `l` over the adapter-adjusted projections.
    /// Returns `(attn_merged, qh, kh, vh)` — the head tensors are
    /// retained for the training backward.
    fn attention(&mut self, l: usize, q: &Tensor, k: &Tensor, v: &Tensor)
                 -> Result<(Tensor, Tensor, Tensor, Tensor)> {
        let core = self.core;
        let nh = core.cfg.n_heads;
        match &mut self.path {
            AttnPath::Full { batch, seq, seq_bucket, kv } => {
                let qh = to_heads_batched(q, *batch, nh);
                let kh = to_heads_batched(k, *batch, nh);
                let vh = to_heads_batched(v, *batch, nh);
                if let Some(cache) = kv.as_deref_mut() {
                    cache.append(l, &kh, &vh)?;
                }
                let qp = ClientCore::pad_seq(&qh, *seq_bucket);
                let kp = ClientCore::pad_seq(&kh, *seq_bucket);
                let vp = ClientCore::pad_seq(&vh, *seq_bucket);
                let attn_p = core.engine
                    .execute(&self.attn_artifact, &[&qp, &kp, &vp])?;
                let attn = ClientCore::unpad_seq(&attn_p[0], *seq);
                let merged = from_heads_batched(&attn, *batch);
                Ok((merged, qh, kh, vh))
            }
            AttnPath::Cached { batch, kv, len, seq_bucket } => {
                // single-token head split: (B, D) -> (B*NH, 1, H)
                let qh = q.split_heads_rows(*batch, nh);
                let kh = k.split_heads_rows(*batch, nh);
                let vh = v.split_heads_rows(*batch, nh);
                let layer_len = kv.append(l, &kh, &vh)?;
                debug_assert_eq!(layer_len, *len);
                let (kc, vc) = kv.padded_view(l, *seq_bucket)?;
                let kv_len = Tensor::scalar_i32(*len as i32);
                // interactive decode rides the high-priority device lane
                let prio = self.urgency == Urgency::Interactive;
                let out = core.engine.execute_prio(
                    &self.attn_artifact, &[&qh, &kc, &vc, &kv_len],
                    prio)?;
                let merged = out[0].merge_heads_rows(*batch);
                Ok((merged, qh, kh, vh))
            }
        }
    }

    /// Run every block, final norm, and the LM head; returns logits.
    fn walk(mut self, mut h: Tensor) -> Result<Tensor> {
        let core = self.core;
        let cx = HookCtx { engine: core.engine.as_ref(), cfg: &core.cfg };
        for l in 0..core.cfg.n_layers {
            let h_in = h.clone();
            let a_in = ops::rmsnorm(&h, &core.weights.norm1[l]);
            let qkv = core.virt.forward(LayerId::Qkv(l), a_in.clone(),
                                        self.urgency)?;
            let (q, k, v) = core.qkv_split_adjust(&cx, l, &a_in, &qkv)?;
            let (attn_merged, qh, kh, vh) = self.attention(l, &q, &k, &v)?;
            let mut o = core.virt.forward(LayerId::AttnOut(l),
                                          attn_merged.clone(),
                                          self.urgency)?;
            let (h_mid, m_in) = core.attn_out_transition(
                &cx, l, &h, &attn_merged, &mut o)?;
            let mut u_pre = core.virt.forward(LayerId::MlpUp(l), m_in,
                                              self.urgency)?;
            let u = core.ffn_activate(l, &mut u_pre);
            let down = core.virt.forward(LayerId::MlpDown(l), u,
                                         self.urgency)?;
            let h_out = ops::add(&h_mid, &down);
            if let Some(sv) = self.save.as_deref_mut() {
                sv.layers.push(SavedLayer {
                    h_in,
                    a_in,
                    qh,
                    kh,
                    vh,
                    attn_merged,
                    h_mid,
                    u_pre,
                });
            }
            h = h_out;
        }
        if let Some(sv) = self.save.as_deref_mut() {
            sv.h_last = h.clone();
        }
        let hf = core.final_norm(&h);
        core.virt.forward(LayerId::LmHead, hf, self.urgency)
    }
}

// ---------------------------------------------------------------------------
// Pipelined prefill — micro-batched wavefront over the shard fleet
// ---------------------------------------------------------------------------

/// One micro-batch's position in the walk: either an in-flight
/// base-layer request (split-phase dispatch, one per micro-batch) or
/// client-side tensors waiting for the next dispatch.
enum Stage<'a> {
    /// Not yet embedded.
    Start,
    PendEmbed(PendingLayer<'a>),
    PendQkv { h: Tensor, a_in: Tensor, pend: PendingLayer<'a> },
    /// Adapter-adjusted projections, gated on the predecessor
    /// micro-batch having appended its K/V at this layer (the reorder
    /// gate: cache rows must enter in token order).
    HaveQkv { h: Tensor, q: Tensor, k: Tensor, v: Tensor },
    PendAttnOut { h: Tensor, attn_merged: Tensor, pend: PendingLayer<'a> },
    PendMlpUp { h_mid: Tensor, pend: PendingLayer<'a> },
    PendMlpDown { h_mid: Tensor, pend: PendingLayer<'a> },
    PendHead(PendingLayer<'a>),
    Done(Tensor),
    /// Transient placeholder while a transition executes.
    Taken,
}

/// One micro-batch: the column window `[c0, c1)` of every sequence,
/// the block it is currently in, and its stage.
struct ChunkState<'a> {
    c0: usize,
    c1: usize,
    layer: usize,
    stage: Stage<'a>,
}

/// Drives all micro-batches round-robin, one stage per turn: while one
/// chunk blocks collecting its response, every other chunk's request is
/// already queued at some shard — micro-batch k occupies shard s+1
/// while micro-batch k+1 occupies shard s.
///
/// KEEP IN SYNC: the stage transitions in [`Self::advance`] are the
/// split-phase form of [`LayerWalker::walk`].  The block math itself
/// is shared (both call the `ClientCore` transition helpers), so only
/// the dispatch/collect *order* lives twice; change both together or
/// the equivalence tests diverge.
struct PipelineDriver<'a> {
    core: &'a ClientCore,
    virt: &'a VirtLayerCtx,
    batch: usize,
    seq: usize,
    /// Token position of column 0 (non-zero on continued sessions).
    pos0: usize,
    urgency: Urgency,
    tokens: &'a [i32],
    /// Reorder-gate cursor per layer: how many micro-batches have
    /// appended their K/V.  Chunk k may append at layer l only when
    /// `appended[l] == k`.
    appended: Vec<usize>,
}

impl<'a> PipelineDriver<'a> {
    /// This micro-batch's token ids and positions, token-major within
    /// the chunk (row `b*tc + i` is column `c0 + i` of sequence `b`).
    fn chunk_tokens(&self, c0: usize, c1: usize) -> (Tensor, Tensor) {
        let tc = c1 - c0;
        let mut toks = Vec::with_capacity(self.batch * tc);
        let mut poss = Vec::with_capacity(self.batch * tc);
        for b in 0..self.batch {
            for col in c0..c1 {
                toks.push(self.tokens[b * self.seq + col]);
                poss.push((self.pos0 + col) as i32);
            }
        }
        (
            Tensor::from_i32(toks, &[self.batch * tc]),
            Tensor::from_i32(poss, &[self.batch * tc]),
        )
    }

    /// rmsnorm-1 + QKV dispatch for block `l` over hidden `h`.
    fn begin_block(&self, h: Tensor, l: usize) -> Result<Stage<'a>> {
        let virt = self.virt;
        let a_in = ops::rmsnorm(&h, &self.core.weights.norm1[l]);
        let pend = virt.dispatch_forward(LayerId::Qkv(l), a_in.clone(),
                                         self.urgency)?;
        Ok(Stage::PendQkv { h, a_in, pend })
    }

    /// Chunk attention at block `l`: append this micro-batch's K/V to
    /// the session cache (token order guaranteed by the reorder gate),
    /// then run the *prefill* attention artifact over the whole cache
    /// prefix with the chunk's queries placed at their absolute rows.
    /// The causal mask gives each query exactly the keys `[0, row]` —
    /// prefix-adapter rows included — so the windowed output rows equal
    /// the sequential walk's.
    #[allow(clippy::too_many_arguments)]
    fn attention(&self, kv: &mut KvCache, c0: usize, c1: usize, l: usize,
                 q: &Tensor, k: &Tensor, v: &Tensor) -> Result<Tensor> {
        let core = self.core;
        let nh = core.cfg.n_heads;
        let tc = c1 - c0;
        let qh = to_heads_batched(q, self.batch, nh);
        let kh = to_heads_batched(k, self.batch, nh);
        let vh = to_heads_batched(v, self.batch, nh);
        let ctx_len = kv.append(l, &kh, &vh)?;
        let bucket = bucket_for(ctx_len, SEQ_BUCKETS)
            .ok_or(SymbiosisError::ContextExceeded {
                len: ctx_len,
                limit: *SEQ_BUCKETS.last()
                    .expect("SEQ_BUCKETS is a non-empty static"),
            })?;
        let (kc, vc) = kv.padded_view(l, bucket)?;
        let qp = ClientCore::place_seq(&qh, ctx_len - tc, bucket);
        let name = format!("attn_prefill_bh{}_s{bucket}_h{}",
                           self.batch * nh, core.cfg.d_head());
        let out = core.engine.execute(&name, &[&qp, &kc, &vc])?;
        let attn = ClientCore::slice_seq(&out[0], ctx_len - tc, tc);
        Ok(from_heads_batched(&attn, self.batch))
    }

    /// Attention + AttnOut dispatch once the reorder gate opens;
    /// returns the `HaveQkv` stage unchanged (no progress) while the
    /// predecessor micro-batch has not appended at this layer yet.
    #[allow(clippy::too_many_arguments)]
    fn attend_or_wait(&mut self, kv: &mut KvCache, k_idx: usize,
                      c0: usize, c1: usize, l: usize, h: Tensor,
                      q: Tensor, k: Tensor, v: Tensor)
                      -> Result<(Stage<'a>, bool)> {
        if self.appended[l] != k_idx {
            return Ok((Stage::HaveQkv { h, q, k, v }, false));
        }
        let merged = self.attention(kv, c0, c1, l, &q, &k, &v)?;
        self.appended[l] = k_idx + 1;
        let virt = self.virt;
        let pend = virt.dispatch_forward(LayerId::AttnOut(l),
                                         merged.clone(), self.urgency)?;
        Ok((Stage::PendAttnOut { h, attn_merged: merged, pend }, true))
    }

    /// Advance micro-batch `k_idx` by one stage.  Returns whether it
    /// made progress (`false`: done, or parked at the reorder gate).
    fn advance(&mut self, kv: &mut KvCache, k_idx: usize,
               ch: &mut ChunkState<'a>) -> Result<bool> {
        let core = self.core;
        let virt = self.virt;
        let cx = HookCtx { engine: core.engine.as_ref(), cfg: &core.cfg };
        let stage = std::mem::replace(&mut ch.stage, Stage::Taken);
        let (next, progressed) = match stage {
            Stage::Start => {
                let (toks, poss) = self.chunk_tokens(ch.c0, ch.c1);
                let pend =
                    virt.dispatch_embed(toks, poss, self.urgency)?;
                (Stage::PendEmbed(pend), true)
            }
            Stage::PendEmbed(pend) => {
                let h = pend.collect()?;
                (self.begin_block(h, ch.layer)?, true)
            }
            Stage::PendQkv { h, a_in, pend } => {
                let l = ch.layer;
                let qkv = pend.collect()?;
                let (q, k, v) =
                    core.qkv_split_adjust(&cx, l, &a_in, &qkv)?;
                // collecting the projection is progress even if the
                // reorder gate then parks the chunk
                let (st, _) = self.attend_or_wait(kv, k_idx, ch.c0,
                                                  ch.c1, l, h, q, k, v)?;
                (st, true)
            }
            Stage::HaveQkv { h, q, k, v } => {
                self.attend_or_wait(kv, k_idx, ch.c0, ch.c1, ch.layer,
                                    h, q, k, v)?
            }
            Stage::PendAttnOut { h, attn_merged, pend } => {
                let l = ch.layer;
                let mut o = pend.collect()?;
                let (h_mid, m_in) = core.attn_out_transition(
                    &cx, l, &h, &attn_merged, &mut o)?;
                let pend = virt.dispatch_forward(LayerId::MlpUp(l), m_in,
                                                 self.urgency)?;
                (Stage::PendMlpUp { h_mid, pend }, true)
            }
            Stage::PendMlpUp { h_mid, pend } => {
                let l = ch.layer;
                let mut u_pre = pend.collect()?;
                let u = core.ffn_activate(l, &mut u_pre);
                let pend = virt.dispatch_forward(LayerId::MlpDown(l), u,
                                                 self.urgency)?;
                (Stage::PendMlpDown { h_mid, pend }, true)
            }
            Stage::PendMlpDown { h_mid, pend } => {
                let down = pend.collect()?;
                let h = ops::add(&h_mid, &down);
                ch.layer += 1;
                if ch.layer < core.cfg.n_layers {
                    (self.begin_block(h, ch.layer)?, true)
                } else {
                    let hf = core.final_norm(&h);
                    let pend = virt.dispatch_forward(LayerId::LmHead, hf,
                                                     self.urgency)?;
                    (Stage::PendHead(pend), true)
                }
            }
            Stage::PendHead(pend) => (Stage::Done(pend.collect()?), true),
            done @ Stage::Done(_) => (done, false),
            Stage::Taken => unreachable!("stage advanced re-entrantly"),
        };
        ch.stage = next;
        Ok(progressed)
    }
}

impl ClientCore {
    /// Pipelined prefill over `batch` sequences of `seq` columns:
    /// micro-batches of `chunk` columns walk the layers as a wavefront,
    /// overlapping shard compute across chunks.  Appends K/V to `kv` in
    /// token order and returns the full-prompt logits `(batch*seq,
    /// vocab)` in the sequential token-major layout — output-identical
    /// to [`Self::forward_full`] on an empty cache, and to the
    /// incremental walk on a prefix-seeded one.
    fn forward_pipelined(&self, tokens: &[i32], batch: usize,
                         chunk: usize, pos0: usize, urgency: Urgency,
                         kv: &mut KvCache) -> Result<Tensor> {
        self.check_batch(batch)?;
        let s = tokens.len() / batch;
        let chunk = chunk.clamp(1, s);
        let n_chunks = (s + chunk - 1) / chunk;
        // The final per-layer context must fit an attention bucket.
        let final_len = kv.len() + s;
        bucket_for(final_len, SEQ_BUCKETS)
            .ok_or(SymbiosisError::ContextExceeded {
                len: final_len,
                limit: *SEQ_BUCKETS.last()
                    .expect("SEQ_BUCKETS is a non-empty static"),
            })?;
        let virt: &VirtLayerCtx = self.virt.as_ref();
        let mut driver = PipelineDriver {
            core: self,
            virt,
            batch,
            seq: s,
            pos0,
            urgency,
            tokens,
            appended: vec![0; self.cfg.n_layers],
        };
        let mut chunks: Vec<ChunkState> = (0..n_chunks)
            .map(|k| ChunkState {
                c0: k * chunk,
                c1: ((k + 1) * chunk).min(s),
                layer: 0,
                stage: Stage::Start,
            })
            .collect();
        loop {
            let mut any_progress = false;
            let mut all_done = true;
            for (k_idx, ch) in chunks.iter_mut().enumerate() {
                if !matches!(ch.stage, Stage::Done(_)) {
                    all_done = false;
                    any_progress |= driver.advance(kv, k_idx, ch)?;
                }
            }
            if all_done {
                break;
            }
            // The least-index unfinished chunk is never gated, so a
            // full round without progress means a logic error — fail
            // loudly rather than spin (and an executor failure above
            // already unwound every in-flight receiver).
            anyhow::ensure!(any_progress, "pipelined prefill stalled");
        }
        // Reorder-buffer tail: recombine per-chunk logits into the
        // sequential token-major (batch*seq, vocab) layout.
        let vocab = self.cfg.vocab;
        let mut flat = vec![0.0f32; batch * s * vocab];
        for ch in &chunks {
            let Stage::Done(logits) = &ch.stage else {
                unreachable!("all chunks done")
            };
            let src = logits.as_f32();
            let tc = ch.c1 - ch.c0;
            for b in 0..batch {
                let drow = (b * s + ch.c0) * vocab;
                let srow = b * tc * vocab;
                flat[drow..drow + tc * vocab]
                    .copy_from_slice(&src[srow..srow + tc * vocab]);
            }
        }
        Ok(Tensor::from_f32(flat, &[batch * s, vocab]))
    }
}

// ---------------------------------------------------------------------------
// Generation configuration
// ---------------------------------------------------------------------------

/// When layer invocations are scheduled relative to other clients'
/// (paper section 3.7: the wait budget is based on request size).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct UrgencyPolicy {
    /// Batch prefill invocations.
    pub prefill: Urgency,
    /// Decode / incremental-prefill invocations.  `Interactive` also
    /// routes client-side decode attention onto the engine's
    /// high-priority lane.
    pub decode: Urgency,
}

impl Default for UrgencyPolicy {
    fn default() -> Self {
        UrgencyPolicy {
            prefill: Urgency::Bulk,
            decode: Urgency::Interactive,
        }
    }
}

/// Token selection strategy for [`InferenceSession::generate`].
#[derive(Debug, Clone)]
pub enum Sampling {
    /// Deterministic argmax (byte-identical to the low-level
    /// `prefill` + `decode_step` loop).
    Greedy,
    /// Softmax over the top-k logits at the given temperature, driven
    /// by a deterministic xorshift stream seeded with `seed`.
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenerationConfig {
    /// Tokens to emit per sequence, *including* the one produced by
    /// prefill.
    pub max_tokens: usize,
    /// A sequence stops (individually) right after emitting any of
    /// these.
    pub stop_tokens: Vec<i32>,
    pub sampling: Sampling,
    /// Pipelined-prefill micro-batch size in token columns for this
    /// request; `None` falls back to the session's
    /// [`SessionBuilder::prefill_chunk`] default (itself off unless
    /// configured).
    pub prefill_chunk: Option<usize>,
}

impl GenerationConfig {
    /// Greedy decoding, no stop tokens.
    pub fn greedy(max_tokens: usize) -> Self {
        GenerationConfig {
            max_tokens,
            stop_tokens: Vec::new(),
            sampling: Sampling::Greedy,
            prefill_chunk: None,
        }
    }

    /// Temperature + top-k sampling with a deterministic seed.
    pub fn sampled(max_tokens: usize, temperature: f32, top_k: usize,
                   seed: u64) -> Self {
        GenerationConfig {
            max_tokens,
            stop_tokens: Vec::new(),
            sampling: Sampling::TopK { k: top_k, temperature, seed },
            prefill_chunk: None,
        }
    }

    pub fn with_stop(mut self, token: i32) -> Self {
        self.stop_tokens.push(token);
        self
    }

    /// Pipeline the prefill in micro-batches of `tokens` columns.
    pub fn with_prefill_chunk(mut self, tokens: usize) -> Self {
        self.prefill_chunk = Some(tokens);
        self
    }
}

/// Stateful token selector (sampling carries an RNG stream).
enum Sampler {
    Greedy,
    TopK { k: usize, temperature: f32, state: u64 },
}

impl Sampler {
    fn new(s: &Sampling) -> Self {
        match s {
            Sampling::Greedy => Sampler::Greedy,
            Sampling::TopK { k, temperature, seed } => Sampler::TopK {
                k: (*k).max(1),
                temperature: *temperature,
                // xorshift must not start at 0; every other seed keeps
                // its own distinct stream
                state: if *seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { *seed },
            },
        }
    }

    fn pick(&mut self, logits: &Tensor, row: usize) -> i32 {
        match self {
            Sampler::Greedy => ops::argmax_row(logits, row),
            Sampler::TopK { k, temperature, state } => {
                let v = logits.shape[logits.shape.len() - 1];
                let r = &logits.as_f32()[row * v..(row + 1) * v];
                let take = (*k).min(v);
                // partition the top-k first — O(V + k log k), not a
                // full O(V log V) vocab sort per token
                let mut idx: Vec<usize> = (0..v).collect();
                if take < v {
                    idx.select_nth_unstable_by(
                        take - 1, |&a, &b| r[b].total_cmp(&r[a]));
                    idx.truncate(take);
                }
                idx.sort_unstable_by(|&a, &b| r[b].total_cmp(&r[a]));
                let t = temperature.max(1e-6);
                let m = r[idx[0]];
                let probs: Vec<f32> =
                    idx.iter().map(|&i| ((r[i] - m) / t).exp()).collect();
                let sum: f32 = probs.iter().sum();
                // xorshift64* uniform in [0, 1)
                *state ^= *state << 13;
                *state ^= *state >> 7;
                *state ^= *state << 17;
                let u = (*state >> 11) as f32 / (1u64 << 53) as f32;
                let target = u * sum;
                let mut acc = 0.0f32;
                for (j, p) in probs.iter().enumerate() {
                    acc += p;
                    if acc >= target {
                        return idx[j] as i32;
                    }
                }
                *idx.last().expect("top-k keeps >= 1 candidate") as i32
            }
        }
    }
}

/// Resumable generation bookkeeping: the sampler stream, per-sequence
/// done mask, and emission budget that [`InferenceSession::generate`]
/// used to keep on its stack.  Factored into a value so an external
/// driver — the continuous-batching scheduler
/// ([`crate::coordinator::scheduler`]) — can advance a request one
/// token step at a time across many sessions, while `generate` itself
/// stays a thin loop over the same methods.  Because both paths run
/// the *same* selection code, their token streams are bit-identical
/// (pinned by `tests/serving.rs`).
pub(crate) struct GenState {
    sampler: Sampler,
    /// Per-sequence stop mask; empty until the first (prefill) token.
    done: Vec<bool>,
    /// Tokens emitted so far, the prefill token included.
    emitted: usize,
    max_tokens: usize,
    stop_tokens: Vec<i32>,
    /// Per-sequence `generated` lengths when the request began, so the
    /// request's own output can be sliced off a continued session.
    already: Vec<usize>,
}

impl GenState {
    /// Whether the request still wants decode steps.  False before the
    /// first token (the prefill phase is tracked by the caller) and
    /// after every sequence stopped or the budget is spent.
    pub(crate) fn running(&self) -> bool {
        self.emitted > 0
            && self.emitted < self.max_tokens
            && !self.done.iter().all(|&d| d)
    }

    pub(crate) fn emitted(&self) -> usize {
        self.emitted
    }

    /// Absorb the prefill token per sequence: initialize the stop mask
    /// and count the first emission — the done-mask line of the
    /// sequential `generate`.
    fn absorb_first(&mut self, first: &[i32]) {
        self.done = first
            .iter()
            .map(|t| self.stop_tokens.contains(t))
            .collect();
        self.emitted = 1;
    }
}

// ---------------------------------------------------------------------------
// Inference
// ---------------------------------------------------------------------------

/// An inference job: prefill once, then decode token-by-token against the
/// client-owned KV cache.  Build one with
/// [`Deployment::session`](crate::coordinator::Deployment::session) and
/// drive it with [`Self::generate`]; the low-level
/// `prefill`/`decode_step` calls remain for step-at-a-time control.
pub struct InferenceSession {
    pub core: ClientCore,
    pub batch: usize,
    kv: KvCache,
    /// Last emitted token per sequence.
    last: Vec<i32>,
    /// Tokens generated so far (per sequence, column-major appended).
    pub generated: Vec<Vec<i32>>,
    pos: usize,
    prefix_seeded: bool,
    urgency: UrgencyPolicy,
    /// Session-default pipelined-prefill micro-batch size (columns);
    /// `None` = sequential prefill.
    prefill_chunk: Option<usize>,
    /// Slot in the tenant's concurrent-session quota (RAII: dropping
    /// the session frees it).  `None` for untenanted sessions.
    _tenant_ticket: Option<SessionTicket>,
}

impl InferenceSession {
    pub fn new(core: ClientCore, batch: usize,
               kv_placement: KvPlacement) -> SymResult<Self> {
        core.check_batch(batch)?;
        let kv = KvCache::new(core.cfg.n_layers, batch * core.cfg.n_heads,
                              core.cfg.d_head(), kv_placement);
        Ok(InferenceSession {
            core,
            batch,
            kv,
            last: Vec::new(),
            generated: vec![Vec::new(); batch],
            pos: 0,
            prefix_seeded: false,
            urgency: UrgencyPolicy::default(),
            prefill_chunk: None,
            _tenant_ticket: None,
        })
    }

    pub(crate) fn set_urgency(&mut self, u: UrgencyPolicy) {
        self.urgency = u;
    }

    pub(crate) fn set_prefill_chunk(&mut self, chunk: Option<usize>) {
        self.prefill_chunk = chunk;
    }

    /// Session-default pipelined-prefill micro-batch size, if any (the
    /// scheduler resolves request > session > engine defaults).
    pub(crate) fn session_prefill_chunk(&self) -> Option<usize> {
        self.prefill_chunk
    }

    /// Charge this session's KV cache to a simulated device ledger
    /// (done by [`SessionBuilder::build`]: `KvPlacement::Device` caches
    /// charge the deployment's shared client device, `Host` ones the
    /// host DRAM device) — cache growth beyond the device's capacity
    /// then fails with a typed [`SymbiosisError::KvCacheOom`].
    pub fn attach_kv_ledger(&mut self, device: Arc<Mutex<Device>>,
                            tag: String) -> SymResult<()> {
        self.kv
            .attach_ledger(device, tag)
            .map_err(SymbiosisError::from)
    }

    /// Reset per-request state (KV cache, emitted tokens, positions) so
    /// the session can serve a new independent request without
    /// re-wiring — the client stays registered with the executor, which
    /// keeps the batching policies' client accounting accurate, and the
    /// cache keeps its grown buffers.  Re-seeds the adapter's KV prefix
    /// if it has one.
    pub fn reset(&mut self) -> SymResult<()> {
        self.kv.clear();
        self.last.clear();
        self.generated = vec![Vec::new(); self.batch];
        self.pos = 0;
        self.prefix_seeded = false;
        self.seed_prefix()
    }

    /// Seed the cache with the adapter's learned KV prefix, if it has
    /// one ([`AdapterHooks::seed_kv`]).  Idempotent; called
    /// automatically by [`SessionBuilder::build`](
    /// crate::coordinator::SessionBuilder::build), [`Self::generate`],
    /// and [`Self::prefill_auto`].  Errors if the prefix was built for
    /// a different batch size than this session's.
    ///
    /// Co-tenant sessions of the *same* prefix adapter share seed
    /// blocks: the first session publishes its seeded rows into the
    /// block pool's prefix registry (keyed by the seed tensor's shared
    /// buffer, so clones of one adapter hit the same key and distinct
    /// adapters cannot collide), and later sessions adopt those blocks
    /// copy-on-write instead of re-materializing the seed.
    pub fn seed_prefix(&mut self) -> SymResult<()> {
        if self.prefix_seeded {
            return Ok(());
        }
        let bh = self.batch * self.core.cfg.n_heads;
        let seed_key = self
            .core
            .hooks()
            .seed_kv(0)
            .map(|(k0, _)| {
                format!("seed:{:p}:bh{bh}", k0.as_f32().as_ptr())
            });
        // a brand-new cache (no blocks yet — a cleared cache keeps its
        // grown tables and takes the append path below) adopts the
        // published seed blocks when a sibling session already paid
        if self.kv.capacity() == 0 {
            if let Some(key) = &seed_key {
                if let Some(meta) = self.kv.adopt_prefix(key)? {
                    debug_assert!(meta.seeded);
                    self.prefix_seeded = true;
                    return Ok(());
                }
            }
        }
        let hooks = self.core.hooks();
        let mut seeded = false;
        for l in 0..self.core.cfg.n_layers {
            if let Some((k, v)) = hooks.seed_kv(l) {
                if k.shape[0] != bh {
                    return Err(SymbiosisError::PrefixBatchMismatch {
                        prefix_bh: k.shape[0],
                        cache_bh: bh,
                    });
                }
                debug_assert_eq!(v.shape[0], bh);
                // prefix occupies cache rows but not token positions
                self.kv
                    .append(l, k, v)
                    .map_err(SymbiosisError::from)?;
                seeded = true;
            }
        }
        self.prefix_seeded = seeded;
        if seeded {
            // publish for the next session of this adapter — only a
            // uniformly seeded cache is a shareable prefix (a hook
            // seeding a subset of layers is legal but private)
            let uniform = (0..self.core.cfg.n_layers)
                .all(|l| self.kv.layer_len(l) == self.kv.layer_len(0));
            if uniform {
                if let Some(key) = &seed_key {
                    self.kv.publish_prefix(key, PrefixMeta {
                        cols: 0,
                        tokens: Vec::new(),
                        pos: 0,
                        seeded: true,
                    })?;
                }
            }
        }
        Ok(())
    }

    /// Publish this session's current KV prefix (typically a just
    /// prefilled system prompt) into the deployment's block pool under
    /// `key`, so sibling sessions built with
    /// [`SessionBuilder::adopt_kv_prefix`](
    /// crate::coordinator::SessionBuilder::adopt_kv_prefix) map the
    /// same refcounted blocks instead of re-prefilling and re-charging
    /// the device.  `prompt` is the prompt this cache holds (validated
    /// at adoption).  Returns `false` when the key is already taken.
    pub fn publish_kv_prefix(&mut self, key: &str, prompt: &[i32])
                             -> SymResult<bool> {
        self.check_prompt(prompt)?;
        let s = prompt.len() / self.batch;
        let tokens: Vec<Vec<i32>> = (0..self.batch)
            .map(|b| prompt[b * s..(b + 1) * s].to_vec())
            .collect();
        self.kv.publish_prefix(key, PrefixMeta {
            cols: s,
            tokens,
            pos: self.pos,
            seeded: self.prefix_seeded,
        })
    }

    /// Adopt a prefix published by [`Self::publish_kv_prefix`]: the
    /// shared blocks become this session's cache prefix (copy-on-write)
    /// and the position counter resumes after the shared prompt, so the
    /// next [`Self::generate`] call only pays for the *suffix* of its
    /// prompt.  Requires a fresh session; returns the shared prompt
    /// columns per sequence (`None`: no such key, the session is
    /// unchanged).
    pub fn adopt_kv_prefix(&mut self, key: &str)
                           -> SymResult<Option<Vec<Vec<i32>>>> {
        if self.pos != 0 || !self.last.is_empty() {
            return Err(SymbiosisError::Runtime(anyhow::anyhow!(
                "adopt_kv_prefix on a session that already processed \
                 tokens"
            )));
        }
        match self.kv.adopt_prefix(key)? {
            Some(meta) => {
                self.pos = meta.pos;
                self.prefix_seeded = meta.seeded;
                Ok(Some(meta.tokens))
            }
            None => Ok(None),
        }
    }

    /// Demote this session's KV cache: swap every exclusive block to
    /// the host device (the scheduler's yield path calls this so a
    /// preempted background session parks its KV off-device instead of
    /// being evicted and losing its work).  Returns blocks moved.
    pub fn demote_kv(&mut self) -> SymResult<usize> {
        self.kv.swap_out_all()
    }

    fn record(&mut self, next: &[i32]) {
        self.last = next.to_vec();
        for (b, t) in next.iter().enumerate() {
            self.generated[b].push(*t);
        }
    }

    /// Process the prompt (`batch` sequences x `s` tokens, token-major)
    /// through the bucketed prefill artifact.  Returns the first
    /// generated token per sequence.
    ///
    /// Hard error when the KV cache already holds rows (e.g. a prefix
    /// adapter seeded it): the prefill artifact has no notion of
    /// pre-existing cache contents and would silently attend over the
    /// wrong keys.  [`Self::generate`] and [`Self::prefill_auto`] route
    /// such sessions to [`Self::prefill_incremental`] automatically.
    pub fn prefill(&mut self, tokens: &[i32]) -> SymResult<Vec<i32>> {
        self.prefill_with(tokens, &mut Sampler::Greedy)
    }

    pub(crate) fn check_prompt(&self, tokens: &[i32]) -> SymResult<()> {
        if tokens.len() < self.batch || tokens.len() % self.batch != 0 {
            return Err(SymbiosisError::InvalidGenerationConfig(format!(
                "prompt length {} is not a positive multiple of batch {}",
                tokens.len(), self.batch)));
        }
        Ok(())
    }

    fn prefill_with(&mut self, tokens: &[i32], sampler: &mut Sampler)
                    -> SymResult<Vec<i32>> {
        self.check_prompt(tokens)?;
        if !self.kv.is_empty() {
            return Err(SymbiosisError::PrefilledCacheNeedsIncremental {
                cached_rows: self.kv.len(),
            });
        }
        let s = tokens.len() / self.batch;
        let logits = self.core
            .forward_full(tokens, self.batch, self.urgency.prefill, None,
                          Some(&mut self.kv))
            .map_err(SymbiosisError::from)?;
        self.pos = s;
        let mut first = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let row = (b + 1) * s - 1; // last token of sequence b
            first.push(sampler.pick(&logits, row));
        }
        self.record(&first);
        Ok(first)
    }

    /// Incremental prefill: push the prompt through the *decode* path
    /// one token column at a time.  Slower than [`Self::prefill`] but
    /// required when the KV cache holds a learned prefix — and
    /// numerically identical to batch prefill otherwise (covered by the
    /// golden equivalence tests).  Returns the first generated token per
    /// sequence.
    pub fn prefill_incremental(&mut self, tokens: &[i32])
                               -> SymResult<Vec<i32>> {
        self.prefill_incremental_with(tokens, &mut Sampler::Greedy)
    }

    fn prefill_incremental_with(&mut self, tokens: &[i32],
                                sampler: &mut Sampler)
                                -> SymResult<Vec<i32>> {
        self.check_prompt(tokens)?;
        let s = tokens.len() / self.batch;
        let mut logits = None;
        for col in 0..s {
            let column: Vec<i32> = (0..self.batch)
                .map(|b| tokens[b * s + col])
                .collect();
            logits = Some(self.step_logits(&column)
                .map_err(SymbiosisError::from)?);
        }
        let logits = logits.expect("check_prompt guarantees s >= 1");
        let mut next = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            next.push(sampler.pick(&logits, b));
        }
        self.record(&next);
        Ok(next)
    }

    /// Pipelined prefill: process the prompt in micro-batches of
    /// `chunk` token columns driven as a wavefront over the shard fleet
    /// (micro-batch k on shard s+1 while micro-batch k+1 occupies shard
    /// s).  Output-identical to [`Self::prefill`] on an empty cache and
    /// to [`Self::prefill_incremental`] on a prefix-seeded one — unlike
    /// batch prefill it accepts pre-existing cache rows, since every
    /// chunk attends over the real cache prefix.  Returns the first
    /// generated token per sequence.
    pub fn prefill_pipelined(&mut self, tokens: &[i32], chunk: usize)
                             -> SymResult<Vec<i32>> {
        self.prefill_pipelined_with(tokens, chunk, &mut Sampler::Greedy)
    }

    fn prefill_pipelined_with(&mut self, tokens: &[i32], chunk: usize,
                              sampler: &mut Sampler)
                              -> SymResult<Vec<i32>> {
        self.check_prompt(tokens)?;
        let s = tokens.len() / self.batch;
        if chunk == 0 || chunk >= s {
            // one micro-batch degenerates to the unpipelined routing
            return if self.kv.is_empty() {
                self.prefill_with(tokens, sampler)
            } else {
                self.prefill_incremental_with(tokens, sampler)
            };
        }
        let logits = self
            .core
            .forward_pipelined(tokens, self.batch, chunk, self.pos,
                               self.urgency.prefill, &mut self.kv)
            .map_err(SymbiosisError::from)?;
        self.pos += s;
        let mut first = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            first.push(sampler.pick(&logits, (b + 1) * s - 1));
        }
        self.record(&first);
        Ok(first)
    }

    /// Prefill, routed: a configured `prefill_chunk` takes the
    /// pipelined path (which handles seeded caches), a seeded cache
    /// (prefix adapter) the incremental path, everything else the fast
    /// batch path.  Seeds the adapter's KV prefix first if that has not
    /// happened yet.
    pub fn prefill_auto(&mut self, tokens: &[i32]) -> SymResult<Vec<i32>> {
        self.seed_prefix()?;
        if let Some(chunk) = self.prefill_chunk {
            return self.prefill_pipelined(tokens, chunk);
        }
        if self.kv.is_empty() {
            self.prefill(tokens)
        } else {
            self.prefill_incremental(tokens)
        }
    }

    /// One greedy decode step: feed the last tokens, emit the next per
    /// sequence.
    pub fn decode_step(&mut self) -> SymResult<Vec<i32>> {
        if self.last.is_empty() {
            return Err(SymbiosisError::DecodeBeforePrefill);
        }
        let last = self.last.clone();
        let logits =
            self.step_logits(&last).map_err(SymbiosisError::from)?;
        let mut next = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            next.push(ops::argmax_row(&logits, b));
        }
        self.record(&next);
        Ok(next)
    }

    /// Run a whole request: prefill (auto-routed), then decode until
    /// every sequence hit a stop token or `max_tokens` were emitted.
    /// Returns the tokens emitted *by this call* per sequence (on a
    /// continued session, `self.generated` additionally retains earlier
    /// requests' tokens).
    pub fn generate(&mut self, prompt: &[i32], cfg: &GenerationConfig)
                    -> SymResult<Vec<Vec<i32>>> {
        let mut st = self.begin_generate(cfg)?;
        // per-request chunk overrides the session default
        let chunk = cfg.prefill_chunk.or(self.prefill_chunk);
        let first = if let Some(c) = chunk {
            self.prefill_pipelined_with(prompt, c, &mut st.sampler)?
        } else if self.kv.is_empty() {
            self.prefill_with(prompt, &mut st.sampler)?
        } else {
            self.prefill_incremental_with(prompt, &mut st.sampler)?
        };
        st.absorb_first(&first);
        while st.running() {
            let last = self.last.clone();
            let logits =
                self.step_logits(&last).map_err(SymbiosisError::from)?;
            self.apply_decode_logits(&mut st, &logits);
        }
        Ok(self.take_generated(&st))
    }

    /// Validate the request and open its resumable [`GenState`] —
    /// sampler stream, stop set, and the per-sequence `generated`
    /// snapshot used to slice this request's output off a continued
    /// session.  Also seeds the adapter's KV prefix (a prefix adapter
    /// on a hand-constructed session may not have seeded yet, and
    /// prefill routing depends on it).
    pub(crate) fn begin_generate(&mut self, cfg: &GenerationConfig)
                                 -> SymResult<GenState> {
        if cfg.max_tokens == 0 {
            return Err(SymbiosisError::InvalidGenerationConfig(
                "max_tokens must be >= 1".to_string()));
        }
        let already: Vec<usize> =
            self.generated.iter().map(|g| g.len()).collect();
        self.seed_prefix()?;
        Ok(GenState {
            sampler: Sampler::new(&cfg.sampling),
            done: Vec::new(),
            emitted: 0,
            max_tokens: cfg.max_tokens,
            stop_tokens: cfg.stop_tokens.clone(),
            already,
        })
    }

    /// Sample the first token per sequence from final-chunk prefill
    /// logits (`batch * tc` token-major rows: row `(b + 1) * tc - 1` is
    /// the last prompt column of sequence `b`), record it, and open the
    /// stop mask — the external-driver form of the `prefill_*_with`
    /// tails.  Consumes exactly one sampler pick per sequence, in
    /// sequence order, just like every sequential prefill route.
    pub(crate) fn pick_prefill(&mut self, st: &mut GenState,
                               logits: &Tensor, tc: usize) -> Vec<i32> {
        let mut first = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            first.push(st.sampler.pick(logits, (b + 1) * tc - 1));
        }
        self.record(&first);
        st.absorb_first(&first);
        first
    }

    /// Apply one decode step's logits: exactly the selection body of
    /// the sequential `generate` loop — finished sequences keep feeding
    /// their last token (cache stays aligned) but record nothing — so
    /// external drivers stay bit-identical with it.
    pub(crate) fn apply_decode_logits(&mut self, st: &mut GenState,
                                      logits: &Tensor) {
        let last = self.last.clone();
        let mut next = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            if st.done[b] {
                next.push(last[b]);
            } else {
                next.push(st.sampler.pick(logits, b));
            }
        }
        for (b, t) in next.iter().enumerate() {
            if !st.done[b] {
                self.generated[b].push(*t);
                if st.stop_tokens.contains(t) {
                    st.done[b] = true;
                }
            }
        }
        self.last = next;
        st.emitted += 1;
    }

    /// This request's emitted tokens per sequence (everything past the
    /// `generated` snapshot taken by [`Self::begin_generate`]).
    pub(crate) fn take_generated(&self, st: &GenState) -> Vec<Vec<i32>> {
        self.generated
            .iter()
            .zip(&st.already)
            .map(|(g, &from)| g[from..].to_vec())
            .collect()
    }

    /// Core single-column step: embed `tokens` at the current position,
    /// walk all layers against the cache, return the logits row per
    /// sequence.
    fn step_logits(&mut self, step_tokens: &[i32]) -> Result<Tensor> {
        let b = self.batch;
        let urgency = self.urgency.decode;
        let tokens = Tensor::from_i32(step_tokens.to_vec(), &[b]);
        let positions =
            Tensor::from_i32(vec![self.pos as i32; b], &[b]);
        let h = self.core.virt.embed(tokens, positions, urgency)?;
        // Per-layer cache length after this step's append: layers fill
        // front-to-back within a step, all reaching `len`.
        let len = self.kv.len() + 1;
        let sb = bucket_for(len, SEQ_BUCKETS)
            .ok_or(SymbiosisError::ContextExceeded {
                len,
                limit: *SEQ_BUCKETS.last()
                    .expect("SEQ_BUCKETS is a non-empty static"),
            })?;
        let logits =
            LayerWalker::cached(&self.core, b, &mut self.kv, len, sb,
                                urgency)
                .walk(h)?;
        self.pos += 1;
        Ok(logits)
    }

    pub fn kv_bytes(&self) -> u64 {
        self.kv.bytes()
    }

    pub fn kv_len(&self) -> usize {
        self.kv.len()
    }

    pub fn kv_transfer_bytes_per_step(&self) -> u64 {
        self.kv.transfer_bytes_per_step()
    }
}

// ---------------------------------------------------------------------------
// Externally driven steps — the continuous-batching scheduler's walk
// ---------------------------------------------------------------------------

/// What one externally driven micro-step does to a session.
#[derive(Clone, Copy)]
enum WalkKind {
    /// One decode token column against the session cache — the
    /// split-phase form of [`InferenceSession::step_logits`].
    Decode,
    /// One prefill micro-batch: prompt columns `[c0, c1)` through the
    /// *prefill* attention artifact over the real cache prefix — one
    /// [`PipelineDriver`] chunk driven stand-alone.  The scheduler runs
    /// a session's chunks strictly in token order (one per scheduler
    /// step), so no reorder gate is needed; cross-session overlap comes
    /// from the wavefront instead.
    Chunk { c0: usize, c1: usize },
}

/// A suspended single-step layer walk: the split-phase state of one
/// decode column (or one prefill micro-batch) that an external driver —
/// the continuous-batching scheduler
/// ([`crate::coordinator::scheduler`]) — advances one dispatch/collect
/// stage at a time via [`InferenceSession::advance_walk`].  While one
/// session's walk blocks collecting a shard response, every other
/// session in the wavefront already has its request queued at some
/// shard.
///
/// A walk that returns an error is poisoned (its stage is consumed);
/// the driver must retire the session, not re-advance the walk.
pub(crate) struct StepWalk<'v> {
    kind: WalkKind,
    layer: usize,
    stage: Stage<'v>,
    /// Decode-mode cache geometry, fixed at walk start — exactly as
    /// [`InferenceSession::step_logits`] computes it once per column.
    dec_len: usize,
    dec_bucket: usize,
    dec_artifact: String,
}

impl<'v> StepWalk<'v> {
    /// A one-token decode step over the session's last-emitted tokens.
    pub(crate) fn decode() -> Self {
        StepWalk {
            kind: WalkKind::Decode,
            layer: 0,
            stage: Stage::Start,
            dec_len: 0,
            dec_bucket: 0,
            dec_artifact: String::new(),
        }
    }

    /// A prefill micro-batch over prompt columns `[c0, c1)`.
    pub(crate) fn chunk(c0: usize, c1: usize) -> Self {
        StepWalk {
            kind: WalkKind::Chunk { c0, c1 },
            layer: 0,
            stage: Stage::Start,
            dec_len: 0,
            dec_bucket: 0,
            dec_artifact: String::new(),
        }
    }

    pub(crate) fn is_done(&self) -> bool {
        matches!(self.stage, Stage::Done(_))
    }

    /// The walk's final logits; errors unless [`Self::is_done`].
    pub(crate) fn take_logits(self) -> Result<Tensor> {
        match self.stage {
            Stage::Done(t) => Ok(t),
            _ => Err(anyhow::anyhow!(
                "walk logits taken before completion")),
        }
    }
}

impl InferenceSession {
    /// Advance an externally driven walk by one split-phase stage;
    /// returns whether the walk is still in flight (`false` once
    /// [`StepWalk::is_done`]).
    ///
    /// KEEP IN SYNC: decode mode is the split-phase form of
    /// [`Self::step_logits`] + [`LayerWalker::walk`]; chunk mode is one
    /// [`PipelineDriver`] chunk driven strictly in token order.  The
    /// block math itself is shared (all three call the `ClientCore`
    /// transition helpers), so only the dispatch/collect order lives
    /// here as a third copy — `tests/serving.rs` pins the equivalence
    /// against sequential `generate`.
    ///
    /// `virt` must be this session's own `core.virt`; the scheduler
    /// passes an `Arc` clone held outside its `&mut` borrow of the
    /// session so the pending request may outlive that borrow.
    pub(crate) fn advance_walk<'v>(&mut self, w: &mut StepWalk<'v>,
                                   virt: &'v VirtLayerCtx,
                                   prompt: &[i32]) -> Result<bool> {
        let core = &self.core;
        let cx = HookCtx { engine: core.engine.as_ref(), cfg: &core.cfg };
        let batch = self.batch;
        let urgency = match w.kind {
            WalkKind::Decode => self.urgency.decode,
            WalkKind::Chunk { .. } => self.urgency.prefill,
        };
        let stage = std::mem::replace(&mut w.stage, Stage::Taken);
        let next = match stage {
            Stage::Start => match w.kind {
                WalkKind::Decode => {
                    if self.last.is_empty() {
                        return Err(
                            SymbiosisError::DecodeBeforePrefill.into());
                    }
                    // Cache geometry once per column, as step_logits
                    // does: per-layer length after this step's append.
                    let len = self.kv.len() + 1;
                    let sb = bucket_for(len, SEQ_BUCKETS)
                        .ok_or(SymbiosisError::ContextExceeded {
                            len,
                            limit: *SEQ_BUCKETS.last()
                                .expect("SEQ_BUCKETS is a non-empty static"),
                        })?;
                    w.dec_len = len;
                    w.dec_bucket = sb;
                    w.dec_artifact =
                        format!("attn_decode_bh{}_s{sb}_h{}",
                                batch * core.cfg.n_heads,
                                core.cfg.d_head());
                    let tokens =
                        Tensor::from_i32(self.last.clone(), &[batch]);
                    let positions = Tensor::from_i32(
                        vec![self.pos as i32; batch], &[batch]);
                    let pend =
                        virt.dispatch_embed(tokens, positions, urgency)?;
                    Stage::PendEmbed(pend)
                }
                WalkKind::Chunk { c0, c1 } => {
                    let s = prompt.len() / batch;
                    let tc = c1 - c0;
                    let mut toks = Vec::with_capacity(batch * tc);
                    let mut poss = Vec::with_capacity(batch * tc);
                    // Token-major within the chunk; column `col` sits
                    // at position `pos + (col - c0)` because earlier
                    // chunks already advanced `pos` past their columns.
                    for b in 0..batch {
                        for col in c0..c1 {
                            toks.push(prompt[b * s + col]);
                            poss.push((self.pos + (col - c0)) as i32);
                        }
                    }
                    let pend = virt.dispatch_embed(
                        Tensor::from_i32(toks, &[batch * tc]),
                        Tensor::from_i32(poss, &[batch * tc]),
                        urgency)?;
                    Stage::PendEmbed(pend)
                }
            },
            Stage::PendEmbed(pend) => {
                let h = pend.collect()?;
                let a_in = ops::rmsnorm(&h, &core.weights.norm1[w.layer]);
                let pend = virt.dispatch_forward(
                    LayerId::Qkv(w.layer), a_in.clone(), urgency)?;
                Stage::PendQkv { h, a_in, pend }
            }
            Stage::PendQkv { h, a_in, pend } => {
                let l = w.layer;
                let qkv = pend.collect()?;
                let (q, k, v) =
                    core.qkv_split_adjust(&cx, l, &a_in, &qkv)?;
                let nh = core.cfg.n_heads;
                let merged = match w.kind {
                    WalkKind::Decode => {
                        let qh = q.split_heads_rows(batch, nh);
                        let kh = k.split_heads_rows(batch, nh);
                        let vh = v.split_heads_rows(batch, nh);
                        let layer_len = self.kv.append(l, &kh, &vh)?;
                        debug_assert_eq!(layer_len, w.dec_len);
                        let (kc, vc) =
                            self.kv.padded_view(l, w.dec_bucket)?;
                        let kv_len = Tensor::scalar_i32(w.dec_len as i32);
                        // interactive decode rides the high-priority
                        // device lane (as LayerWalker::attention does)
                        let prio = urgency == Urgency::Interactive;
                        let out = core.engine.execute_prio(
                            &w.dec_artifact, &[&qh, &kc, &vc, &kv_len],
                            prio)?;
                        out[0].merge_heads_rows(batch)
                    }
                    WalkKind::Chunk { c0, c1 } => {
                        let tc = c1 - c0;
                        let qh = to_heads_batched(&q, batch, nh);
                        let kh = to_heads_batched(&k, batch, nh);
                        let vh = to_heads_batched(&v, batch, nh);
                        let ctx_len = self.kv.append(l, &kh, &vh)?;
                        let bucket = bucket_for(ctx_len, SEQ_BUCKETS)
                            .ok_or(SymbiosisError::ContextExceeded {
                                len: ctx_len,
                                limit: *SEQ_BUCKETS.last()
                                    .expect(
                                        "SEQ_BUCKETS is a non-empty static"),
                            })?;
                        let (kc, vc) = self.kv.padded_view(l, bucket)?;
                        let qp = ClientCore::place_seq(
                            &qh, ctx_len - tc, bucket);
                        let name =
                            format!("attn_prefill_bh{}_s{bucket}_h{}",
                                    batch * nh, core.cfg.d_head());
                        let out = core.engine
                            .execute(&name, &[&qp, &kc, &vc])?;
                        let attn = ClientCore::slice_seq(
                            &out[0], ctx_len - tc, tc);
                        from_heads_batched(&attn, batch)
                    }
                };
                let pend = virt.dispatch_forward(
                    LayerId::AttnOut(l), merged.clone(), urgency)?;
                Stage::PendAttnOut { h, attn_merged: merged, pend }
            }
            Stage::HaveQkv { .. } => unreachable!(
                "reorder gate is pipeline-only; scheduler chunks run \
                 strictly in token order"),
            Stage::PendAttnOut { h, attn_merged, pend } => {
                let l = w.layer;
                let mut o = pend.collect()?;
                let (h_mid, m_in) = core.attn_out_transition(
                    &cx, l, &h, &attn_merged, &mut o)?;
                let pend = virt.dispatch_forward(LayerId::MlpUp(l), m_in,
                                                 urgency)?;
                Stage::PendMlpUp { h_mid, pend }
            }
            Stage::PendMlpUp { h_mid, pend } => {
                let l = w.layer;
                let mut u_pre = pend.collect()?;
                let u = core.ffn_activate(l, &mut u_pre);
                let pend = virt.dispatch_forward(LayerId::MlpDown(l), u,
                                                 urgency)?;
                Stage::PendMlpDown { h_mid, pend }
            }
            Stage::PendMlpDown { h_mid, pend } => {
                let down = pend.collect()?;
                let h = ops::add(&h_mid, &down);
                w.layer += 1;
                if w.layer < core.cfg.n_layers {
                    let a_in =
                        ops::rmsnorm(&h, &core.weights.norm1[w.layer]);
                    let pend = virt.dispatch_forward(
                        LayerId::Qkv(w.layer), a_in.clone(), urgency)?;
                    Stage::PendQkv { h, a_in, pend }
                } else {
                    let hf = core.final_norm(&h);
                    let pend = virt.dispatch_forward(LayerId::LmHead, hf,
                                                     urgency)?;
                    Stage::PendHead(pend)
                }
            }
            Stage::PendHead(pend) => {
                let logits = pend.collect()?;
                // The walk owns position advancement, at the exact spot
                // the sequential paths do it (end of step_logits; end
                // of the chunk's columns in forward_pipelined).
                match w.kind {
                    WalkKind::Decode => self.pos += 1,
                    WalkKind::Chunk { c0, c1 } => self.pos += c1 - c0,
                }
                Stage::Done(logits)
            }
            done @ Stage::Done(_) => done,
            Stage::Taken => unreachable!("stage advanced re-entrantly"),
        };
        w.stage = next;
        Ok(!matches!(w.stage, Stage::Done(_)))
    }
}

// ---------------------------------------------------------------------------
// Fine-tuning
// ---------------------------------------------------------------------------

/// Result of one training iteration.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub loss: f32,
    pub grad_norm: f32,
    pub tokens: usize,
}

/// Ledger-side identity of a trainer: the device its Adam state and
/// activation stash are charged to (under `opt:client{id}` /
/// `act:client{id}` tags), the tenant whose training-bytes budget those
/// charges draw from, and the fleet's shared [`TrainingStats`].  All
/// charging goes through here so the two books (tenant, device) move
/// together: the tenant budget is adjusted *first* — one tenant
/// exhausts its own quota with `QuotaExceeded` before it can push a
/// co-tenant into [`SymbiosisError::TrainerOom`] — and rolled back when
/// the device ledger refuses, mirroring the KV cache's charge order.
struct TrainCharge {
    device: Option<Arc<Mutex<Device>>>,
    tenant: Option<Arc<TenantState>>,
    stats: Option<Arc<TrainingStats>>,
    opt_tag: String,
    act_tag: String,
    /// Bytes currently charged under `opt_tag` / `act_tag`.
    opt_bytes: u64,
    act_bytes: u64,
    /// This trainer's balance on the tenant's training-bytes book.
    tenant_charged: u64,
}

impl TrainCharge {
    fn detached() -> Self {
        TrainCharge {
            device: None,
            tenant: None,
            stats: None,
            opt_tag: String::new(),
            act_tag: String::new(),
            opt_bytes: 0,
            act_bytes: 0,
            tenant_charged: 0,
        }
    }

    /// Resize one tag to `bytes` with tenant-first ordering and typed
    /// OOM naming what did not fit.
    fn set_tag(&mut self, what: &'static str, act: bool, bytes: u64)
               -> SymResult<()> {
        let (other, tag) = if act {
            (self.opt_bytes, self.act_tag.clone())
        } else {
            (self.act_bytes, self.opt_tag.clone())
        };
        let next_total = other + bytes;
        if let Some(t) = &self.tenant {
            t.adjust_train(self.tenant_charged, next_total)?;
        }
        if let Some(dev) = &self.device {
            let mut d = dev.lock().unwrap_or_else(|p| p.into_inner());
            let capacity = d.ledger.capacity();
            let others = d.ledger.used() - d.ledger.tag_bytes(&tag);
            if d.ledger.set(&tag, bytes).is_err() {
                // Device refused: roll the tenant book back before
                // surfacing, so both books stay consistent.
                if let Some(t) = &self.tenant {
                    let _ = t.adjust_train(next_total,
                                           self.tenant_charged);
                }
                return Err(SymbiosisError::TrainerOom {
                    what,
                    need_bytes: bytes,
                    used_bytes: others,
                    capacity_bytes: capacity,
                });
            }
        }
        if act {
            if let Some(st) = &self.stats {
                if bytes > self.act_bytes {
                    st.stash_grew(bytes - self.act_bytes);
                } else {
                    st.stash_shrunk(self.act_bytes - bytes);
                }
            }
            self.act_bytes = bytes;
        } else {
            self.opt_bytes = bytes;
        }
        self.tenant_charged = next_total;
        Ok(())
    }

    fn charge_opt(&mut self, bytes: u64) -> SymResult<()> {
        self.set_tag("optimizer state", false, bytes)
    }

    fn grow_act(&mut self, delta: u64) -> SymResult<()> {
        self.set_tag("activation stash", true, self.act_bytes + delta)
    }

    /// Shrinks never fail: the tenant book shrinks freely and a ledger
    /// resize downward always fits.
    fn shrink_act(&mut self, delta: u64) {
        let next = self.act_bytes.saturating_sub(delta);
        let _ = self.set_tag("activation stash", true, next);
    }

    /// Free both tags and the tenant balance (trainer exit).
    fn release_all(&mut self) {
        if let Some(st) = &self.stats {
            st.stash_shrunk(self.act_bytes);
        }
        if let Some(dev) = &self.device {
            let mut d = dev.lock().unwrap_or_else(|p| p.into_inner());
            d.ledger.free(&self.opt_tag);
            d.ledger.free(&self.act_tag);
        }
        if let Some(t) = &self.tenant {
            t.release_train(self.tenant_charged);
        }
        self.opt_bytes = 0;
        self.act_bytes = 0;
        self.tenant_charged = 0;
        self.device = None;
        self.tenant = None;
    }
}

/// A fine-tuning job: forward, hand-rolled backward, Adam on the
/// adapter.  Build one with
/// [`Deployment::trainer`](crate::coordinator::Deployment::trainer).
pub struct Trainer {
    pub core: ClientCore,
    pub batch: usize,
    pub optimizer: Adam,
    /// Scheduling class of every layer invocation this job issues
    /// (default [`Urgency::Training`]).  [`Urgency::Background`] makes
    /// the job sheddable when its shard's ingress queue saturates.
    pub urgency: Urgency,
    /// Micro-batches per step (GPipe wavefront when > 1; see
    /// [`TrainerBuilder::micro_batches`]).
    micro_batches: usize,
    /// Ledger identity — `opt:`/`act:` tags on the client device plus
    /// the tenant training-bytes book (no-op until
    /// [`Trainer::attach_train_ledger`]).
    charge: TrainCharge,
    /// Slot in the tenant's concurrent-session quota (RAII).
    _tenant_ticket: Option<SessionTicket>,
}

impl Trainer {
    pub fn new(core: ClientCore, batch: usize) -> SymResult<Self> {
        Self::with_micro_batches(core, batch, 1)
    }

    /// Like [`Trainer::new`], splitting each step's batch into
    /// `micro_batches` pipelined micro-batches.  The per-micro-batch
    /// size `batch / micro_batches` must be an attention batch size —
    /// which also means the *total* batch may exceed the largest
    /// attention artifact (e.g. batch 8 as 8×1): micro-batching is how
    /// large batches become runnable at all, not just faster.
    pub fn with_micro_batches(core: ClientCore, batch: usize,
                              micro_batches: usize) -> SymResult<Self> {
        let m = micro_batches.max(1);
        if m == 1 {
            core.check_batch(batch)?;
        } else if batch % m != 0
            || !ATTN_BATCHES.contains(&(batch / m))
        {
            return Err(SymbiosisError::InvalidMicroBatch {
                batch,
                micro_batches: m,
                supported: ATTN_BATCHES,
            });
        }
        // Only adapters whose gradients are wired into the flattened
        // optimizer layout can be fine-tuned (currently LoRA; IA3 and
        // Prefix are inference-only — see `AdapterHooks::trainable`).
        let n = match core.adapter.as_ref() {
            Some(a) if a.hooks().trainable() => a.n_params(),
            Some(_) => {
                return Err(SymbiosisError::NotTrainable {
                    adapter: "an inference-only adapter (IA3/Prefix)",
                })
            }
            None => {
                return Err(SymbiosisError::NotTrainable {
                    adapter: "no adapter",
                })
            }
        };
        Ok(Trainer {
            core,
            batch,
            optimizer: Adam::new(n),
            urgency: Urgency::Training,
            micro_batches: m,
            charge: TrainCharge::detached(),
            _tenant_ticket: None,
        })
    }

    /// Micro-batches per training step (1 = sequential walk).
    pub fn micro_batches(&self) -> usize {
        self.micro_batches
    }

    /// Charge this trainer's Adam state to `device` under
    /// `opt:client{id}` and arm `act:client{id}` for per-micro-batch
    /// activation charges — making training memory ledger-visible the
    /// way KV already is.  Fails with a typed
    /// [`SymbiosisError::TrainerOom`] (or `QuotaExceeded` when `tenant`
    /// is at its training-bytes budget) if the optimizer state does not
    /// fit; [`Deployment::trainer`] wires this automatically.
    ///
    /// [`Deployment::trainer`]: crate::coordinator::Deployment::trainer
    pub fn attach_train_ledger(&mut self, device: Arc<Mutex<Device>>,
                               tenant: Option<Arc<TenantState>>,
                               stats: Option<Arc<TrainingStats>>)
                               -> SymResult<()> {
        let id = self.core.virt.client_id;
        self.charge.opt_tag = format!("opt:client{id}");
        self.charge.act_tag = format!("act:client{id}");
        self.charge.device = Some(device);
        self.charge.tenant = tenant;
        self.charge.stats = stats;
        self.charge.charge_opt(self.optimizer.state_bytes())
    }

    /// One full iteration: forward, loss, backward, optimizer step.
    /// With `micro_batches > 1` the forward+backward run as a GPipe
    /// wavefront; the resulting step is bit-identical to the sequential
    /// walk (see the module docs).
    pub fn train_step(&mut self, tokens: &[i32], labels: &[i32])
                      -> SymResult<TrainOutcome> {
        let (loss, grads) = self.loss_and_grads(tokens, labels)?;
        let grad_norm = grads.l2_norm();
        let adapter = self.core.adapter.as_mut()
            .expect("Trainer::new verified a trainable adapter");
        let mut flat = adapter.flatten();
        self.optimizer
            .step_artifact(&self.core.engine, &mut flat, &grads.flat)
            .map_err(SymbiosisError::from)?;
        adapter.unflatten(&flat).map_err(SymbiosisError::from)?;
        Ok(TrainOutcome { loss, grad_norm, tokens: tokens.len() })
    }

    /// Forward + backward only (used by the golden gradient tests).
    pub fn loss_and_grads(&mut self, tokens: &[i32], labels: &[i32])
                          -> SymResult<(f32, AdapterGrads)> {
        let r = if self.micro_batches > 1 {
            self.loss_and_grads_pipelined(tokens, labels)
        } else {
            self.loss_and_grads_inner(tokens, labels)
        };
        if r.is_err() {
            // A failed step must not leak stash charges: zero the act
            // book (both ledgers) so co-tenant trainers see a clean
            // rollback.
            self.charge.shrink_act(u64::MAX);
        }
        r.map_err(SymbiosisError::from)
    }

    /// Bytes of one [`SavedLayer`] over `t` tokens: four `(T, D)`
    /// residual-path tensors + three `(T, D)` head tensors + the
    /// `(T, F)` pre-activation.
    fn saved_layer_bytes(&self, t: usize) -> u64 {
        let d = self.core.cfg.d_model as u64;
        let f = self.core.cfg.d_ff as u64;
        t as u64 * (7 * d + f) * 4
    }

    fn h_last_bytes(&self, t: usize) -> u64 {
        (t * self.core.cfg.d_model * 4) as u64
    }

    fn loss_and_grads_inner(&mut self, tokens: &[i32], labels: &[i32])
                            -> Result<(f32, AdapterGrads)> {
        let t = tokens.len();
        let urgency = self.urgency;
        // The sequential walk stashes every layer at once: one
        // full-batch charge up front, released when backward finishes.
        let full_act = self.core.cfg.n_layers as u64
            * self.saved_layer_bytes(t)
            + self.h_last_bytes(t);
        self.charge.grow_act(full_act)?;
        let mut saved = SavedActs {
            layers: Vec::with_capacity(self.core.cfg.n_layers),
            h_last: Tensor::zeros(&[1]),
        };
        let logits = self.core.forward_full(tokens, self.batch, urgency,
                                            Some(&mut saved), None)?;
        // loss + dlogits through the bucketed xent artifact
        let v = self.core.cfg.vocab;
        let tb = bucket_for(t, TOKEN_BUCKETS)
            .ok_or(SymbiosisError::ContextExceeded {
                len: t,
                limit: *TOKEN_BUCKETS.last()
                    .expect("TOKEN_BUCKETS is a non-empty static"),
            })?;
        let mut lab = labels.to_vec();
        lab.resize(tb, 0);
        let mut w = vec![1.0f32; t];
        w.resize(tb, 0.0);
        let name = format!("xent_t{tb}_v{v}");
        let lp = logits.pad_rows(tb);
        let out = self.core.engine.execute(&name, &[
            &lp,
            &Tensor::from_i32(lab, &[tb]),
            &Tensor::from_f32(w, &[tb]),
        ])?;
        let loss = out[0].as_f32()[0];
        let dlogits = out[1].slice_rows(0, t);

        let hooks = self.core.hooks();
        let cx = HookCtx {
            engine: self.core.engine.as_ref(),
            cfg: &self.core.cfg,
        };
        let mut grads = AdapterGrads::zeros_like(
            self.core.adapter.as_ref()
                .expect("Trainer::new verified a trainable adapter"));

        // ---- backward ----
        let dhf = self.core.virt.backward(LayerId::LmHead, dlogits,
                                          urgency)?;
        let mut dh = ops::rmsnorm_bwd(&saved.h_last,
                                      &self.core.weights.norm_f, &dhf);
        let s = t / self.batch;
        let sb = bucket_for(s, SEQ_BUCKETS)
            .expect("forward_full already bucketed this seq length");
        let nh = self.core.cfg.n_heads;
        let attn_bwd = format!("attn_bwd_bh{}_s{sb}_h{}",
                               self.batch * nh, self.core.cfg.d_head());
        for l in (0..self.core.cfg.n_layers).rev() {
            let sv = &saved.layers[l];
            // MLP path
            let dd = self.core.virt.backward(LayerId::MlpDown(l),
                                             dh.clone(), urgency)?;
            let dg = hooks.ffn_scale_bwd(l, &sv.u_pre, &dd);
            let dgelu = ops::gelu_bwd(&sv.u_pre, &dg);
            let dm = self.core.virt.backward(LayerId::MlpUp(l), dgelu,
                                             urgency)?;
            let dnorm2 = ops::rmsnorm_bwd(&sv.h_mid,
                                          &self.core.weights.norm2[l],
                                          &dm);
            let dh_mid = ops::add(&dh, &dnorm2);

            // attention output path
            let do_ = dh_mid.clone();
            let mut dattn = self.core.virt.backward(LayerId::AttnOut(l),
                                                    do_.clone(),
                                                    urgency)?;
            if let Some(dx) = hooks.attn_out_delta_bwd(
                &cx, l, &sv.attn_merged, &do_, &mut grads)?
            {
                ops::add_assign(&mut dattn, &dx);
            }
            // attention backward (client-side artifact)
            let dattn_h = to_heads_batched(&dattn, self.batch, nh);
            let qp = ClientCore::pad_seq(&sv.qh, sb);
            let kp = ClientCore::pad_seq(&sv.kh, sb);
            let vp = ClientCore::pad_seq(&sv.vh, sb);
            let dop = ClientCore::pad_seq(&dattn_h, sb);
            let out = self.core.engine.execute(
                &attn_bwd, &[&qp, &kp, &vp, &dop])?;
            let dq = from_heads_batched(
                &ClientCore::unpad_seq(&out[0], s), self.batch);
            let dk = from_heads_batched(
                &ClientCore::unpad_seq(&out[1], s), self.batch);
            let dv = from_heads_batched(
                &ClientCore::unpad_seq(&out[2], s), self.batch);
            // back through the adapter's k/v rescale to the projection
            // outputs …
            let (dk, dv) = hooks.kv_scale_bwd(l, &dk, &dv);

            // … then adapter deltas on q/k/v + the fused-QKV gradient
            let dqkv = ClientCore::concat_cols3(&dq, &dk, &dv);
            let mut da_in = self.core.virt.backward(LayerId::Qkv(l), dqkv,
                                                    urgency)?;
            if let Some(extra) = hooks.qkv_delta_bwd(
                &cx, l, &sv.a_in, &dq, &dk, &dv, &mut grads)?
            {
                ops::add_assign(&mut da_in, &extra);
            }
            let dnorm1 = ops::rmsnorm_bwd(&sv.h_in,
                                          &self.core.weights.norm1[l],
                                          &da_in);
            dh = ops::add(&dh_mid, &dnorm1);
        }
        // Backward consumed every saved layer: release the stash.
        self.charge.shrink_act(u64::MAX);
        Ok((loss, grads))
    }

    /// Client-side memory (adapter + optimizer + saved activations) for
    /// the memory figures.  Once the trainer is ledger-attached this
    /// reads the live `opt:`/`act:` tag balances — the report *is* the
    /// ledger (pinned by `tests/training_pipeline.rs`); detached
    /// trainers fall back to the analytic estimate over `seq_len`.
    pub fn client_state_bytes(&self, seq_len: usize) -> u64 {
        let adapter = self
            .core
            .adapter
            .as_ref()
            .map(|a| (a.n_params() * 4) as u64)
            .unwrap_or(0);
        if let Some(dev) = &self.charge.device {
            let d = dev.lock().unwrap_or_else(|p| p.into_inner());
            return adapter
                + d.ledger.tag_bytes(&self.charge.opt_tag)
                + d.ledger.tag_bytes(&self.charge.act_tag);
        }
        let opt = self.optimizer.state_bytes();
        let t = (self.batch * seq_len) as u64;
        let d = self.core.cfg.d_model as u64;
        let f = self.core.cfg.d_ff as u64;
        // per layer saved: 5 (T,D) + qkv heads (3 T D) + (T,F)
        let saved =
            self.core.cfg.n_layers as u64 * t * (8 * d + f) * 4;
        adapter + opt + saved
    }
}

impl Drop for Trainer {
    fn drop(&mut self) {
        // Trainer exit returns its opt/act bytes to the device ledger
        // and its balance to the tenant's training-bytes book.
        self.charge.release_all();
    }
}

// ---------------------------------------------------------------------------
// Pipelined training — micro-batched GPipe wavefront over the shard fleet
// ---------------------------------------------------------------------------

/// One training micro-batch's position in the forward *or* backward
/// walk: an in-flight base-layer request or client-side tensors waiting
/// for the next dispatch.  Unlike pipelined prefill there is no reorder
/// gate — training micro-batches split the *batch* axis, so they are
/// fully independent in forward (no KV cache) and in the dX chain.
enum TrainStage<'a> {
    FwdStart,
    FwdPendEmbed(PendingLayer<'a>),
    FwdPendQkv { h_in: Tensor, a_in: Tensor, pend: PendingLayer<'a> },
    FwdPendAttnOut {
        h_in: Tensor,
        a_in: Tensor,
        qh: Tensor,
        kh: Tensor,
        vh: Tensor,
        attn_merged: Tensor,
        pend: PendingLayer<'a>,
    },
    FwdPendMlpUp {
        h_in: Tensor,
        a_in: Tensor,
        qh: Tensor,
        kh: Tensor,
        vh: Tensor,
        attn_merged: Tensor,
        h_mid: Tensor,
        pend: PendingLayer<'a>,
    },
    FwdPendMlpDown { saved: SavedLayer, pend: PendingLayer<'a> },
    FwdPendHead(PendingLayer<'a>),
    /// Forward finished: this micro-batch's logits, held for the loss
    /// barrier.
    FwdDone(Tensor),
    /// Re-seeded after the loss barrier with this micro-batch's dlogits
    /// rows.
    BwdStart(Tensor),
    BwdPendHead(PendingLayer<'a>),
    BwdPendMlpDown { dh: Tensor, pend: PendingLayer<'a> },
    BwdPendMlpUp { dh: Tensor, pend: PendingLayer<'a> },
    BwdPendAttnOut { dh_mid: Tensor, pend: PendingLayer<'a> },
    BwdPendQkv {
        dh_mid: Tensor,
        dq: Tensor,
        dk: Tensor,
        dv: Tensor,
        pend: PendingLayer<'a>,
    },
    BwdDone,
    /// Transient placeholder while a transition executes.
    Taken,
}

/// One training micro-batch: sequences `[b0, b0 + mb)` of the step's
/// batch, its per-layer activation stash, and its stage.  `layer`
/// counts up in forward and down in backward.
struct TrainChunk<'a> {
    idx: usize,
    b0: usize,
    layer: usize,
    saved: Vec<Option<SavedLayer>>,
    h_last: Option<Tensor>,
    stage: TrainStage<'a>,
}

/// Per-micro-batch tensors retained after a layer's backward for the
/// deferred full-shape adapter-gradient pass (see [`BwdShared`]).
struct DeferredStash {
    a_in: Tensor,
    attn_merged: Tensor,
    dq: Tensor,
    dk: Tensor,
    dv: Tensor,
    do_: Tensor,
}

/// Backward state shared across micro-batches.  The adapter-gradient
/// accumulations (`attn_out_delta_bwd` / `qkv_delta_bwd` into `grads`)
/// are the one non-row-wise reduction in the backward, so per-micro
/// hook calls go into a throwaway `scratch` (only their dX side-outputs
/// are used — those *are* row-wise) and the real accumulation runs once
/// per layer at full batch shape, over tensors reassembled from
/// `stash`, as soon as every micro-batch has passed that layer
/// (`done[l] == m`).  Because chunk k reaches layer l only after layer
/// l+1, the deferred passes fire in descending layer order — and the
/// flat-gradient offsets are disjoint per (layer, target) regardless —
/// so the result is bit-identical to the sequential accumulation.
struct BwdShared {
    grads: AdapterGrads,
    scratch: AdapterGrads,
    /// `stash[layer][chunk]`, filled as chunks pass the layer.
    stash: Vec<Vec<Option<DeferredStash>>>,
    /// Micro-batches that have completed each layer's backward.
    done: Vec<usize>,
    m: usize,
}

/// Drives all training micro-batches round-robin, one stage per turn:
/// while one chunk blocks collecting its response, every other chunk's
/// request is already queued at some shard.  Forward fills the
/// pipeline, backward drains it.
///
/// KEEP IN SYNC: the forward transitions in [`Self::advance_fwd`] are
/// the split-phase form of [`LayerWalker::walk`] and the backward
/// transitions in [`Self::advance_bwd`] the split-phase form of
/// `Trainer::loss_and_grads_inner`'s loop.  The block math is shared
/// (the `ClientCore` transition helpers and the same hook/op calls);
/// only the dispatch/collect sequencing lives twice — change both
/// together or `tests/training_pipeline.rs` diverges.
struct TrainDriver<'a> {
    core: &'a ClientCore,
    virt: &'a VirtLayerCtx,
    urgency: Urgency,
    /// Sequences per micro-batch (`batch / m`).
    mb: usize,
    /// Columns per sequence and their bucket.
    s: usize,
    sb: usize,
    tokens: &'a [i32],
    attn_fwd: String,
    attn_bwd: String,
}

impl<'a> TrainDriver<'a> {
    /// Token ids and positions of sequences `[b0, b0 + mb)` — a
    /// contiguous row block of the token-major full batch, so chunk
    /// logits reassemble by plain concatenation.
    fn chunk_tokens(&self, b0: usize) -> (Tensor, Tensor) {
        let t = self.mb * self.s;
        let toks = self.tokens[b0 * self.s..b0 * self.s + t].to_vec();
        let poss: Vec<i32> =
            (0..t).map(|i| (i % self.s) as i32).collect();
        (Tensor::from_i32(toks, &[t]), Tensor::from_i32(poss, &[t]))
    }

    /// Bytes of one micro-batch's [`SavedLayer`].
    fn layer_act_bytes(&self) -> u64 {
        let d = self.core.cfg.d_model as u64;
        let f = self.core.cfg.d_ff as u64;
        (self.mb * self.s) as u64 * (7 * d + f) * 4
    }

    /// Bytes of one micro-batch's per-layer [`DeferredStash`].
    fn stash_bytes(&self) -> u64 {
        (self.mb * self.s * 6 * self.core.cfg.d_model * 4) as u64
    }

    fn h_last_bytes(&self) -> u64 {
        (self.mb * self.s * self.core.cfg.d_model * 4) as u64
    }

    /// rmsnorm-1 + QKV dispatch for block `l` over hidden `h`.
    fn begin_block(&self, h: Tensor, l: usize) -> Result<TrainStage<'a>> {
        let a_in = ops::rmsnorm(&h, &self.core.weights.norm1[l]);
        let pend = self.virt.dispatch_forward(LayerId::Qkv(l),
                                              a_in.clone(),
                                              self.urgency)?;
        Ok(TrainStage::FwdPendQkv { h_in: h, a_in, pend })
    }

    /// Advance micro-batch `ch` by one forward stage; returns whether
    /// it made progress (`false` once its logits are ready).
    fn advance_fwd(&self, charge: &mut TrainCharge,
                   ch: &mut TrainChunk<'a>) -> Result<bool> {
        let core = self.core;
        let cx = HookCtx { engine: core.engine.as_ref(), cfg: &core.cfg };
        let nh = core.cfg.n_heads;
        let stage = std::mem::replace(&mut ch.stage, TrainStage::Taken);
        let (next, progressed) = match stage {
            TrainStage::FwdStart => {
                if let Some(st) = &charge.stats {
                    st.microbatch_started();
                }
                let (toks, poss) = self.chunk_tokens(ch.b0);
                let pend =
                    self.virt.dispatch_embed(toks, poss, self.urgency)?;
                (TrainStage::FwdPendEmbed(pend), true)
            }
            TrainStage::FwdPendEmbed(pend) => {
                let h = pend.collect()?;
                (self.begin_block(h, ch.layer)?, true)
            }
            TrainStage::FwdPendQkv { h_in, a_in, pend } => {
                let l = ch.layer;
                let qkv = pend.collect()?;
                let (q, k, v) =
                    core.qkv_split_adjust(&cx, l, &a_in, &qkv)?;
                let qh = to_heads_batched(&q, self.mb, nh);
                let kh = to_heads_batched(&k, self.mb, nh);
                let vh = to_heads_batched(&v, self.mb, nh);
                let qp = ClientCore::pad_seq(&qh, self.sb);
                let kp = ClientCore::pad_seq(&kh, self.sb);
                let vp = ClientCore::pad_seq(&vh, self.sb);
                let out = core.engine
                    .execute(&self.attn_fwd, &[&qp, &kp, &vp])?;
                let attn = ClientCore::unpad_seq(&out[0], self.s);
                let merged = from_heads_batched(&attn, self.mb);
                let pend = self.virt.dispatch_forward(
                    LayerId::AttnOut(l), merged.clone(), self.urgency)?;
                (TrainStage::FwdPendAttnOut {
                    h_in, a_in, qh, kh, vh, attn_merged: merged, pend,
                }, true)
            }
            TrainStage::FwdPendAttnOut {
                h_in, a_in, qh, kh, vh, attn_merged, pend,
            } => {
                let l = ch.layer;
                let mut o = pend.collect()?;
                let (h_mid, m_in) = core.attn_out_transition(
                    &cx, l, &h_in, &attn_merged, &mut o)?;
                let pend = self.virt.dispatch_forward(
                    LayerId::MlpUp(l), m_in, self.urgency)?;
                (TrainStage::FwdPendMlpUp {
                    h_in, a_in, qh, kh, vh, attn_merged, h_mid, pend,
                }, true)
            }
            TrainStage::FwdPendMlpUp {
                h_in, a_in, qh, kh, vh, attn_merged, h_mid, pend,
            } => {
                let l = ch.layer;
                let mut u_pre = pend.collect()?;
                let u = core.ffn_activate(l, &mut u_pre);
                let pend = self.virt.dispatch_forward(
                    LayerId::MlpDown(l), u, self.urgency)?;
                let saved = SavedLayer {
                    h_in, a_in, qh, kh, vh, attn_merged, h_mid, u_pre,
                };
                (TrainStage::FwdPendMlpDown { saved, pend }, true)
            }
            TrainStage::FwdPendMlpDown { saved, pend } => {
                let down = pend.collect()?;
                let h = ops::add(&saved.h_mid, &down);
                charge.grow_act(self.layer_act_bytes())?;
                ch.saved[ch.layer] = Some(saved);
                ch.layer += 1;
                if ch.layer < core.cfg.n_layers {
                    (self.begin_block(h, ch.layer)?, true)
                } else {
                    charge.grow_act(self.h_last_bytes())?;
                    ch.h_last = Some(h.clone());
                    let hf = core.final_norm(&h);
                    let pend = self.virt.dispatch_forward(
                        LayerId::LmHead, hf, self.urgency)?;
                    (TrainStage::FwdPendHead(pend), true)
                }
            }
            TrainStage::FwdPendHead(pend) => {
                (TrainStage::FwdDone(pend.collect()?), true)
            }
            done @ TrainStage::FwdDone(_) => (done, false),
            TrainStage::Taken => {
                unreachable!("stage advanced re-entrantly")
            }
            _ => unreachable!("backward stage in forward wavefront"),
        };
        ch.stage = next;
        Ok(progressed)
    }

    /// Advance micro-batch `ch` by one backward stage.
    fn advance_bwd(&self, charge: &mut TrainCharge,
                   shared: &mut BwdShared, ch: &mut TrainChunk<'a>)
                   -> Result<bool> {
        let core = self.core;
        let cx = HookCtx { engine: core.engine.as_ref(), cfg: &core.cfg };
        let hooks = core.hooks();
        let stage = std::mem::replace(&mut ch.stage, TrainStage::Taken);
        let (next, progressed) = match stage {
            TrainStage::BwdStart(dlogits) => {
                let pend = self.virt.dispatch_backward(
                    LayerId::LmHead, dlogits, self.urgency)?;
                (TrainStage::BwdPendHead(pend), true)
            }
            TrainStage::BwdPendHead(pend) => {
                let dhf = pend.collect()?;
                let h_last = ch.h_last.take()
                    .expect("forward saved h_last");
                let dh = ops::rmsnorm_bwd(&h_last,
                                          &core.weights.norm_f, &dhf);
                charge.shrink_act(self.h_last_bytes());
                ch.layer = core.cfg.n_layers - 1;
                let pend = self.virt.dispatch_backward(
                    LayerId::MlpDown(ch.layer), dh.clone(),
                    self.urgency)?;
                (TrainStage::BwdPendMlpDown { dh, pend }, true)
            }
            TrainStage::BwdPendMlpDown { dh, pend } => {
                let l = ch.layer;
                let sv = ch.saved[l].as_ref()
                    .expect("forward saved this layer");
                let dd = pend.collect()?;
                let dg = hooks.ffn_scale_bwd(l, &sv.u_pre, &dd);
                let dgelu = ops::gelu_bwd(&sv.u_pre, &dg);
                let pend = self.virt.dispatch_backward(
                    LayerId::MlpUp(l), dgelu, self.urgency)?;
                (TrainStage::BwdPendMlpUp { dh, pend }, true)
            }
            TrainStage::BwdPendMlpUp { dh, pend } => {
                let l = ch.layer;
                let sv = ch.saved[l].as_ref()
                    .expect("forward saved this layer");
                let dm = pend.collect()?;
                let dnorm2 = ops::rmsnorm_bwd(&sv.h_mid,
                                              &core.weights.norm2[l],
                                              &dm);
                let dh_mid = ops::add(&dh, &dnorm2);
                let pend = self.virt.dispatch_backward(
                    LayerId::AttnOut(l), dh_mid.clone(), self.urgency)?;
                (TrainStage::BwdPendAttnOut { dh_mid, pend }, true)
            }
            TrainStage::BwdPendAttnOut { dh_mid, pend } => {
                let l = ch.layer;
                let sv = ch.saved[l].as_ref()
                    .expect("forward saved this layer");
                let mut dattn = pend.collect()?;
                // Per-micro hook call: only the row-wise dX output is
                // used; the parameter-gradient side goes to `scratch`
                // (the real accumulation runs deferred at full shape).
                if let Some(dx) = hooks.attn_out_delta_bwd(
                    &cx, l, &sv.attn_merged, &dh_mid,
                    &mut shared.scratch)?
                {
                    ops::add_assign(&mut dattn, &dx);
                }
                let dattn_h = to_heads_batched(&dattn, self.mb,
                                               core.cfg.n_heads);
                let qp = ClientCore::pad_seq(&sv.qh, self.sb);
                let kp = ClientCore::pad_seq(&sv.kh, self.sb);
                let vp = ClientCore::pad_seq(&sv.vh, self.sb);
                let dop = ClientCore::pad_seq(&dattn_h, self.sb);
                let out = core.engine.execute(
                    &self.attn_bwd, &[&qp, &kp, &vp, &dop])?;
                let dq = from_heads_batched(
                    &ClientCore::unpad_seq(&out[0], self.s), self.mb);
                let dk = from_heads_batched(
                    &ClientCore::unpad_seq(&out[1], self.s), self.mb);
                let dv = from_heads_batched(
                    &ClientCore::unpad_seq(&out[2], self.s), self.mb);
                let (dk, dv) = hooks.kv_scale_bwd(l, &dk, &dv);
                let dqkv = ClientCore::concat_cols3(&dq, &dk, &dv);
                let pend = self.virt.dispatch_backward(
                    LayerId::Qkv(l), dqkv, self.urgency)?;
                (TrainStage::BwdPendQkv { dh_mid, dq, dk, dv, pend },
                 true)
            }
            TrainStage::BwdPendQkv { dh_mid, dq, dk, dv, pend } => {
                let l = ch.layer;
                let mut da_in = pend.collect()?;
                let sv = ch.saved[l].take()
                    .expect("forward saved this layer");
                if let Some(extra) = hooks.qkv_delta_bwd(
                    &cx, l, &sv.a_in, &dq, &dk, &dv,
                    &mut shared.scratch)?
                {
                    ops::add_assign(&mut da_in, &extra);
                }
                let dnorm1 = ops::rmsnorm_bwd(&sv.h_in,
                                              &core.weights.norm1[l],
                                              &da_in);
                let dh = ops::add(&dh_mid, &dnorm1);
                // Swap the consumed SavedLayer charge for the smaller
                // deferred stash (released when the layer's full-shape
                // adapter pass runs).
                charge.shrink_act(self.layer_act_bytes());
                charge.grow_act(self.stash_bytes())?;
                shared.stash[l][ch.idx] = Some(DeferredStash {
                    a_in: sv.a_in,
                    attn_merged: sv.attn_merged,
                    dq,
                    dk,
                    dv,
                    do_: dh_mid,
                });
                shared.done[l] += 1;
                if shared.done[l] == shared.m {
                    self.deferred_adapter_pass(&cx, l, shared)?;
                    charge.shrink_act(
                        self.stash_bytes() * shared.m as u64);
                }
                if l > 0 {
                    ch.layer = l - 1;
                    let pend = self.virt.dispatch_backward(
                        LayerId::MlpDown(l - 1), dh.clone(),
                        self.urgency)?;
                    (TrainStage::BwdPendMlpDown { dh, pend }, true)
                } else {
                    if let Some(st) = &charge.stats {
                        st.grad_accum_step();
                        st.microbatch_finished();
                    }
                    (TrainStage::BwdDone, true)
                }
            }
            done @ TrainStage::BwdDone => (done, false),
            TrainStage::Taken => {
                unreachable!("stage advanced re-entrantly")
            }
            _ => unreachable!("forward stage in backward drain"),
        };
        ch.stage = next;
        Ok(progressed)
    }

    /// The deferred full-shape adapter-gradient pass for layer `l`:
    /// reassemble the full batch by row-concatenating every
    /// micro-batch's stash (chunks are contiguous sequence blocks, so
    /// index-order concat *is* the full-batch layout) and run the two
    /// accumulation hooks once into the real `grads`.  Their dX returns
    /// are discarded — those were applied per-micro already.
    fn deferred_adapter_pass(&self, cx: &HookCtx, l: usize,
                             shared: &mut BwdShared) -> Result<()> {
        let entries: Vec<DeferredStash> =
            std::mem::take(&mut shared.stash[l])
                .into_iter()
                .map(|e| e.expect("done[l] == m implies a full stash"))
                .collect();
        let hooks = self.core.hooks();
        let cat = |field: fn(&DeferredStash) -> &Tensor| {
            concat_rows(&entries.iter().map(field).collect::<Vec<_>>())
        };
        let a_in = cat(|e| &e.a_in);
        let attn_merged = cat(|e| &e.attn_merged);
        let dq = cat(|e| &e.dq);
        let dk = cat(|e| &e.dk);
        let dv = cat(|e| &e.dv);
        let do_ = cat(|e| &e.do_);
        let _ = hooks.attn_out_delta_bwd(cx, l, &attn_merged, &do_,
                                         &mut shared.grads)?;
        let _ = hooks.qkv_delta_bwd(cx, l, &a_in, &dq, &dk, &dv,
                                    &mut shared.grads)?;
        Ok(())
    }
}

/// `(T_i, D) xN -> (sum T_i, D)` — row-concatenate micro-batch tensors
/// back into the full-batch layout.
fn concat_rows(parts: &[&Tensor]) -> Tensor {
    let d = parts[0].shape[1];
    let total: usize = parts.iter().map(|p| p.shape[0]).sum();
    let mut out = Vec::with_capacity(total * d);
    for p in parts {
        out.extend_from_slice(p.as_f32());
    }
    Tensor::from_f32(out, &[total, d])
}

impl Trainer {
    /// Forward + backward as a GPipe wavefront over `micro_batches`
    /// chunks of the batch axis.  Bit-identical to
    /// [`Self::loss_and_grads_inner`] — see the module docs for why —
    /// but with micro-batch k on shard s+1 while k+1 occupies shard s,
    /// and activation-stash ledger charges that track the wavefront
    /// instead of peaking at the full batch.
    fn loss_and_grads_pipelined(&mut self, tokens: &[i32],
                                labels: &[i32])
                                -> Result<(f32, AdapterGrads)> {
        let m = self.micro_batches;
        let mb = self.batch / m;
        let t = tokens.len();
        let s = t / self.batch;
        let sb = bucket_for(s, SEQ_BUCKETS)
            .ok_or(SymbiosisError::ContextExceeded {
                len: s,
                limit: *SEQ_BUCKETS.last()
                    .expect("SEQ_BUCKETS is a non-empty static"),
            })?;
        let grads = AdapterGrads::zeros_like(
            self.core.adapter.as_ref()
                .expect("Trainer::new verified a trainable adapter"));
        let scratch = AdapterGrads::zeros_like(
            self.core.adapter.as_ref()
                .expect("Trainer::new verified a trainable adapter"));
        let n_layers = self.core.cfg.n_layers;
        // Disjoint field borrows: the driver reads `core` (and holds
        // `PendingLayer`s borrowing its `virt`) while ledger charges
        // mutate `charge`.
        let core = &self.core;
        let charge = &mut self.charge;
        let virt: &VirtLayerCtx = core.virt.as_ref();
        let nh = core.cfg.n_heads;
        let hd = core.cfg.d_head();
        let driver = TrainDriver {
            core,
            virt,
            urgency: self.urgency,
            mb,
            s,
            sb,
            tokens,
            attn_fwd: format!("attn_prefill_bh{}_s{sb}_h{hd}",
                              mb * nh),
            attn_bwd: format!("attn_bwd_bh{}_s{sb}_h{hd}", mb * nh),
        };
        let mut chunks: Vec<TrainChunk> = (0..m)
            .map(|k| TrainChunk {
                idx: k,
                b0: k * mb,
                layer: 0,
                saved: (0..n_layers).map(|_| None).collect(),
                h_last: None,
                stage: TrainStage::FwdStart,
            })
            .collect();

        // ---- forward: fill the pipeline ----
        loop {
            let mut any_progress = false;
            let mut all_done = true;
            for ch in chunks.iter_mut() {
                if !matches!(ch.stage, TrainStage::FwdDone(_)) {
                    all_done = false;
                    any_progress |= driver.advance_fwd(charge, ch)?;
                }
            }
            if all_done {
                break;
            }
            anyhow::ensure!(any_progress,
                            "pipelined training forward stalled");
        }

        // ---- loss barrier: the xent reduction is not row-wise, so it
        // runs once at full shape over the reassembled logits — the
        // very call the sequential walk makes. ----
        let v = core.cfg.vocab;
        let tb = bucket_for(t, TOKEN_BUCKETS)
            .ok_or(SymbiosisError::ContextExceeded {
                len: t,
                limit: *TOKEN_BUCKETS.last()
                    .expect("TOKEN_BUCKETS is a non-empty static"),
            })?;
        let mut parts = Vec::with_capacity(m);
        for ch in chunks.iter_mut() {
            let TrainStage::FwdDone(logits) =
                std::mem::replace(&mut ch.stage, TrainStage::Taken)
            else {
                unreachable!("forward loop left a chunk unfinished")
            };
            parts.push(logits);
        }
        let logits =
            concat_rows(&parts.iter().collect::<Vec<_>>());
        let mut lab = labels.to_vec();
        lab.resize(tb, 0);
        let mut w = vec![1.0f32; t];
        w.resize(tb, 0.0);
        let name = format!("xent_t{tb}_v{v}");
        let lp = logits.pad_rows(tb);
        let out = core.engine.execute(&name, &[
            &lp,
            &Tensor::from_i32(lab, &[tb]),
            &Tensor::from_f32(w, &[tb]),
        ])?;
        let loss = out[0].as_f32()[0];
        let dlogits = out[1].slice_rows(0, t);

        // ---- backward: drain the pipeline ----
        for ch in chunks.iter_mut() {
            let rows0 = ch.b0 * s;
            ch.stage = TrainStage::BwdStart(
                dlogits.slice_rows(rows0, rows0 + mb * s));
        }
        let mut shared = BwdShared {
            grads,
            scratch,
            stash: (0..n_layers)
                .map(|_| (0..m).map(|_| None).collect())
                .collect(),
            done: vec![0; n_layers],
            m,
        };
        loop {
            let mut any_progress = false;
            let mut all_done = true;
            for ch in chunks.iter_mut() {
                if !matches!(ch.stage, TrainStage::BwdDone) {
                    all_done = false;
                    any_progress |=
                        driver.advance_bwd(charge, &mut shared, ch)?;
                }
            }
            if all_done {
                break;
            }
            anyhow::ensure!(any_progress,
                            "pipelined training backward stalled");
        }
        Ok((loss, shared.grads))
    }
}

// ---------------------------------------------------------------------------
// Builders — the session-first public surface
// ---------------------------------------------------------------------------

/// Builder for an [`InferenceSession`], obtained from
/// [`Deployment::session`].  Owns every per-tenant choice: adapter,
/// request batch, KV placement, link kind, urgency policy, privacy.
/// `build()` wires the client to the executor, seeds the adapter's KV
/// prefix if it has one, and the resulting session auto-routes prefill
/// accordingly.
pub struct SessionBuilder<'d> {
    dep: &'d Deployment,
    adapter: Option<Adapter>,
    batch: usize,
    kv_placement: KvPlacement,
    link: Option<LinkKind>,
    realize_delays: bool,
    urgency: UrgencyPolicy,
    privacy: Option<PrivacyCtx>,
    prefill_chunk: Option<usize>,
    request_timeout: Option<std::time::Duration>,
    retry: Option<RetryPolicy>,
    tenant: Option<String>,
    adopt_prefix: Option<String>,
}

impl<'d> SessionBuilder<'d> {
    pub(crate) fn new(dep: &'d Deployment) -> Self {
        SessionBuilder {
            dep,
            adapter: None,
            batch: 1,
            kv_placement: KvPlacement::Device,
            link: None,
            realize_delays: false,
            urgency: UrgencyPolicy::default(),
            privacy: None,
            prefill_chunk: None,
            request_timeout: None,
            retry: None,
            tenant: None,
            adopt_prefix: None,
        }
    }

    /// Deadline on every layer collect (default: wait forever).  A
    /// shard that does not answer within the window fails the call with
    /// a typed [`SymbiosisError::DeadlineExceeded`] naming the layer
    /// and shard — frozen-base ops are pure, so the request is safe to
    /// retry (see [`SessionBuilder::retry`]).
    ///
    /// [`SymbiosisError::DeadlineExceeded`]:
    /// crate::error::SymbiosisError::DeadlineExceeded
    pub fn request_timeout(mut self, timeout: std::time::Duration)
                           -> Self {
        self.request_timeout = Some(timeout);
        self
    }

    /// Bounded retry of failed/timed-out layer calls (default: none).
    /// Each attempt re-dispatches the retained request against the
    /// shard's *current* endpoint — so a respawned shard serves the
    /// retry — under linear backoff; exhaustion surfaces as a typed
    /// [`SymbiosisError::ShardUnavailable`].
    ///
    /// [`SymbiosisError::ShardUnavailable`]:
    /// crate::error::SymbiosisError::ShardUnavailable
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    /// This tenant's PEFT adapter (default: bare base model).
    pub fn adapter(mut self, a: Adapter) -> Self {
        self.adapter = Some(a);
        self
    }

    /// Sequences per request (default 1; must have an attention
    /// artifact — checked at `build`).
    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    /// Where the KV cache lives (default: client device).
    pub fn kv(mut self, placement: KvPlacement) -> Self {
        self.kv_placement = placement;
        self
    }

    /// Client↔executor link kind, applied to every shard hop
    /// (default: the placement's per-shard kinds — co-located shard
    /// `SharedLocal`, cross-shard `NvLink`).
    pub fn link(mut self, link: LinkKind) -> Self {
        self.link = Some(link);
        self
    }

    /// Realize simulated link delays as actual sleeps (placement
    /// benches).
    pub fn realize_delays(mut self, yes: bool) -> Self {
        self.realize_delays = yes;
        self
    }

    /// Scheduling class of this session's layer invocations.
    pub fn urgency(mut self, policy: UrgencyPolicy) -> Self {
        self.urgency = policy;
        self
    }

    /// Attach a pre-registered activation-privacy context (paper
    /// section 3.8); the executor then only ever sees noised
    /// activations from this client.
    pub fn privacy(mut self, privacy: PrivacyCtx) -> Self {
        self.privacy = Some(privacy);
        self
    }

    /// Name the tenant this session belongs to for admission control
    /// (default: untenanted — admission is bypassed entirely).  Quotas
    /// are configured on the fleet's
    /// [`AdmissionController`](crate::coordinator::AdmissionController)
    /// via `Deployment::admission().set_quota(..)`; `build` then fails
    /// fast with a typed [`SymbiosisError::AdmissionDenied`] when the
    /// tenant is at its concurrent-session limit, and the session's
    /// dispatches / KV growth charge the tenant's in-flight and
    /// KV-byte budgets.
    ///
    /// [`SymbiosisError::AdmissionDenied`]:
    /// crate::error::SymbiosisError::AdmissionDenied
    pub fn tenant(mut self, name: &str) -> Self {
        self.tenant = Some(name.to_string());
        self
    }

    /// Pipeline prefill in micro-batches of `tokens` columns (default
    /// off = sequential prefill): prompts split into
    /// `ceil(seq/tokens)` micro-batches driven as a wavefront across
    /// the shard fleet, so shard s+1 works on micro-batch k while
    /// shard s runs micro-batch k+1.  Outputs are identical to the
    /// sequential walk; per-request
    /// [`GenerationConfig::with_prefill_chunk`] overrides this default.
    pub fn prefill_chunk(mut self, tokens: usize) -> Self {
        self.prefill_chunk = Some(tokens);
        self
    }

    /// Start from a KV prefix a sibling session published under `key`
    /// ([`InferenceSession::publish_kv_prefix`]): the new session maps
    /// the publisher's refcounted blocks copy-on-write — charging the
    /// device for none of them — and its position counter resumes
    /// after the shared prompt.  Unknown keys are ignored (the session
    /// just prefills normally), so racing publishers/adopters need no
    /// coordination.
    pub fn adopt_kv_prefix(mut self, key: &str) -> Self {
        self.adopt_prefix = Some(key.to_string());
        self
    }

    pub fn build(self) -> SymResult<InferenceSession> {
        // Admission first: a denied tenant fails fast, before any
        // executor registration or device charge happens.
        let (tenant, ticket) = admit(self.dep, self.tenant.as_deref())?;
        let core = self.dep.build_core(self.adapter, self.link,
                                       self.realize_delays, self.privacy,
                                       self.request_timeout, self.retry,
                                       tenant.clone());
        let mut sess =
            InferenceSession::new(core, self.batch, self.kv_placement)?;
        sess._tenant_ticket = ticket;
        sess.set_urgency(self.urgency);
        sess.set_prefill_chunk(self.prefill_chunk);
        // Every session of a deployment draws blocks from the shared
        // pool — prefix sharing and swap victim selection are
        // fleet-wide decisions, not per-cache ones.
        sess.kv.set_pool(self.dep.kv_pool.clone())?;
        // Charge the session's KV cache to the hosting device's shared
        // ledger: growth past the device capacity fails with a typed
        // KvCacheOom (the executable form of Figs 9/10).
        let device = match self.kv_placement {
            KvPlacement::Device => self.dep.client_device.clone(),
            KvPlacement::Host => self.dep.host_device.clone(),
        };
        let tag = format!("kv:client{}", sess.core.virt.client_id);
        sess.attach_kv_ledger(device, tag)?;
        // Device-resident background sessions may have cold blocks
        // swapped to host DRAM when a foreground append would
        // otherwise fire KvCacheOom (host-placed caches are already
        // there — nowhere colder to go).
        if self.kv_placement == KvPlacement::Device {
            sess.kv.attach_swap(self.dep.host_device.clone());
            sess.kv.set_background(
                self.urgency.decode == Urgency::Background);
        }
        // The tenant's KV budget is checked *before* the device ledger
        // on every growth, so one tenant exhausts its own quota with
        // QuotaExceeded before it can push a co-tenant into KvCacheOom.
        if let Some(t) = tenant {
            sess.kv.set_tenant(t)?;
        }
        // A requested shared prompt prefix maps the publisher's blocks
        // before any seeding decision: the published prefix includes
        // the publisher's seed rows, so a hit also satisfies
        // seed_prefix below.
        if let Some(key) = &self.adopt_prefix {
            sess.adopt_kv_prefix(key)?;
        }
        // Prefix adapters seed the cache here, which flips the session
        // into incremental-prefill routing (`generate`/`prefill_auto`).
        sess.seed_prefix()?;
        Ok(sess)
    }
}

/// Resolve a builder's tenant name against the fleet's admission
/// controller: returns the shared tenant state (wired into the client's
/// dispatch path and KV ledger) plus the session ticket holding the
/// concurrent-session slot.  Untenanted builds get `(None, None)` and
/// bypass admission entirely.
fn admit(dep: &Deployment, tenant: Option<&str>)
         -> SymResult<(Option<Arc<TenantState>>, Option<SessionTicket>)> {
    match tenant {
        Some(name) => {
            let t = dep.executor.admission().tenant(name);
            let ticket = t.admit_session()?;
            Ok((Some(t), Some(ticket)))
        }
        None => Ok((None, None)),
    }
}

/// Builder for a [`Trainer`], obtained from [`Deployment::trainer`].
pub struct TrainerBuilder<'d> {
    dep: &'d Deployment,
    adapter: Option<Adapter>,
    batch: usize,
    link: Option<LinkKind>,
    realize_delays: bool,
    lr: Option<f32>,
    micro_batches: usize,
    request_timeout: Option<std::time::Duration>,
    retry: Option<RetryPolicy>,
    tenant: Option<String>,
    urgency: Option<Urgency>,
}

impl<'d> TrainerBuilder<'d> {
    pub(crate) fn new(dep: &'d Deployment) -> Self {
        TrainerBuilder {
            dep,
            adapter: None,
            batch: 1,
            link: None,
            realize_delays: false,
            lr: None,
            micro_batches: 1,
            request_timeout: None,
            retry: None,
            tenant: None,
            urgency: None,
        }
    }

    /// Deadline on every layer collect — forward *and* backward halves
    /// of a training step (see [`SessionBuilder::request_timeout`]).
    pub fn request_timeout(mut self, timeout: std::time::Duration)
                           -> Self {
        self.request_timeout = Some(timeout);
        self
    }

    /// Bounded retry of failed/timed-out layer calls (see
    /// [`SessionBuilder::retry`]); safe because the frozen-base ops a
    /// trainer offloads (including `dX = dY·Wᵀ`) are pure.
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    /// The adapter to fine-tune (required; must be trainable).
    pub fn adapter(mut self, a: Adapter) -> Self {
        self.adapter = Some(a);
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn link(mut self, link: LinkKind) -> Self {
        self.link = Some(link);
        self
    }

    pub fn realize_delays(mut self, yes: bool) -> Self {
        self.realize_delays = yes;
        self
    }

    /// Adam learning rate (default: the optimizer's).
    pub fn lr(mut self, lr: f32) -> Self {
        self.lr = Some(lr);
        self
    }

    /// Split each training step into `m` pipelined micro-batches along
    /// the batch axis (default 1 = sequential walk).  `batch / m` must
    /// be an attention batch size; the step stays bit-identical to the
    /// sequential walk (see the module docs) while micro-batch k runs
    /// on shard s+1 as k+1 occupies shard s — and batches larger than
    /// the biggest attention artifact become runnable at all (e.g.
    /// batch 8 as 8×1).
    pub fn micro_batches(mut self, m: usize) -> Self {
        self.micro_batches = m;
        self
    }

    /// Name the tenant this job belongs to for admission control (see
    /// [`SessionBuilder::tenant`] — trainers count against the same
    /// concurrent-session and in-flight quotas).
    pub fn tenant(mut self, name: &str) -> Self {
        self.tenant = Some(name.to_string());
        self
    }

    /// Scheduling class of the job's layer invocations (default
    /// [`Urgency::Training`]).  [`Urgency::Background`] keeps the full
    /// batching wait budget but marks the work sheddable: when the
    /// shard's ingress queue saturates, its flushes answer a typed
    /// [`SymbiosisError::WorkShed`] instead of occupying the device.
    ///
    /// [`SymbiosisError::WorkShed`]:
    /// crate::error::SymbiosisError::WorkShed
    pub fn urgency(mut self, urgency: Urgency) -> Self {
        self.urgency = Some(urgency);
        self
    }

    pub fn build(self) -> SymResult<Trainer> {
        let (tenant, ticket) = admit(self.dep, self.tenant.as_deref())?;
        let core =
            self.dep.build_core(self.adapter, self.link,
                                self.realize_delays, None,
                                self.request_timeout, self.retry,
                                tenant.clone());
        let mut trainer = Trainer::with_micro_batches(
            core, self.batch, self.micro_batches)?;
        trainer._tenant_ticket = ticket;
        if let Some(lr) = self.lr {
            trainer.optimizer.lr = lr;
        }
        if let Some(u) = self.urgency {
            trainer.urgency = u;
        }
        // Training memory becomes ledger-visible here: Adam state is
        // charged up front (typed TrainerOom / QuotaExceeded if the
        // trainer does not fit), activation stash charges follow each
        // step's wavefront.
        trainer.attach_train_ledger(self.dep.client_device.clone(),
                                    tenant,
                                    Some(self.dep.train_stats.clone()))?;
        Ok(trainer)
    }
}

/// `(T = B*S, D) -> (B*NH, S, H)` head split (free function so it is
/// unit-testable without a deployment).
fn to_heads_batched(x: &Tensor, batch: usize, nh: usize) -> Tensor {
    let (t, d) = (x.shape[0], x.shape[1]);
    let s = t / batch;
    let h = d / nh;
    let src = x.as_f32();
    let mut out = vec![0.0f32; t * d];
    for b in 0..batch {
        for n in 0..nh {
            for ti in 0..s {
                let dst = ((b * nh + n) * s + ti) * h;
                let sidx = (b * s + ti) * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[sidx..sidx + h]);
            }
        }
    }
    Tensor::from_f32(out, &[batch * nh, s, h])
}

/// Inverse of [`to_heads_batched`].
fn from_heads_batched(x: &Tensor, batch: usize) -> Tensor {
    let (bh, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
    let nh = bh / batch;
    let d = nh * h;
    let src = x.as_f32();
    let mut out = vec![0.0f32; batch * s * d];
    for b in 0..batch {
        for n in 0..nh {
            for ti in 0..s {
                let sidx = ((b * nh + n) * s + ti) * h;
                let dst = (b * s + ti) * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[sidx..sidx + h]);
            }
        }
    }
    Tensor::from_f32(out, &[batch * s, d])
}

// small helpers on Tensor used only here
trait DecodeReshape {
    fn split_heads_rows(&self, b: usize, nh: usize) -> Tensor;
    fn merge_heads_rows(&self, b: usize) -> Tensor;
}

impl DecodeReshape for Tensor {
    /// `(B, D) -> (B*NH, 1, H)` for single-token decode.
    fn split_heads_rows(&self, b: usize, nh: usize) -> Tensor {
        let d = self.shape[1];
        let h = d / nh;
        let src = self.as_f32();
        let mut out = vec![0.0f32; b * d];
        for bi in 0..b {
            for n in 0..nh {
                let dst = (bi * nh + n) * h;
                let s = bi * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[b * nh, 1, h])
    }

    /// `(B*NH, 1, H) -> (B, D)`.
    fn merge_heads_rows(&self, b: usize) -> Tensor {
        let (bh, _, h) = (self.shape[0], self.shape[1], self.shape[2]);
        let nh = bh / b;
        let d = nh * h;
        let src = self.as_f32();
        let mut out = vec![0.0f32; b * d];
        for bi in 0..b {
            for n in 0..nh {
                let s = (bi * nh + n) * h;
                let dst = bi * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[b, d])
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn heads_roundtrip_batched() {
        let (b, s, nh, h) = (2usize, 3usize, 4usize, 16usize);
        let d = nh * h;
        let x = Tensor::from_f32(
            (0..b * s * d).map(|i| i as f32).collect(), &[b * s, d]);
        let heads = to_heads_batched(&x, b, nh);
        assert_eq!(heads.shape, vec![b * nh, s, h]);
        assert_eq!(from_heads_batched(&heads, b), x);
    }

    #[test]
    fn decode_reshape_roundtrip() {
        let (b, nh, h) = (2usize, 4usize, 8usize);
        let x = Tensor::from_f32(
            (0..b * nh * h).map(|i| i as f32).collect(), &[b, nh * h]);
        let split = x.split_heads_rows(b, nh);
        assert_eq!(split.shape, vec![b * nh, 1, h]);
        assert_eq!(split.merge_heads_rows(b), x);
    }

    #[test]
    fn place_and_slice_seq_window() {
        let x = Tensor::from_f32(
            (0..2 * 3 * 2).map(|i| 1.0 + i as f32).collect(), &[2, 3, 2]);
        let placed = ClientCore::place_seq(&x, 4, 8);
        assert_eq!(placed.shape, vec![2, 8, 2]);
        // window rows carry the chunk at its absolute offset …
        assert_eq!(placed.as_f32()[(4) * 2], 1.0);
        assert_eq!(placed.as_f32()[(8 + 6) * 2 + 1], 12.0);
        // … and everything outside the window is zero
        assert_eq!(placed.as_f32()[0], 0.0);
        assert_eq!(placed.as_f32()[7 * 2], 0.0);
        // slicing the window back recovers the chunk exactly
        assert_eq!(ClientCore::slice_seq(&placed, 4, 3), x);
    }

    #[test]
    fn pad_unpad_seq_roundtrip() {
        let x = Tensor::from_f32(
            (0..4 * 3 * 2).map(|i| i as f32).collect(), &[4, 3, 2]);
        let p = ClientCore::pad_seq(&x, 8);
        assert_eq!(p.shape, vec![4, 8, 2]);
        assert_eq!(ClientCore::unpad_seq(&p, 3), x);
        // padding region is zero
        assert_eq!(p.as_f32()[3 * 2], 0.0);
    }

    #[test]
    fn concat_cols3_interleaves_rows() {
        let a = Tensor::from_f32(vec![1.0, 2.0], &[1, 2]);
        let b = Tensor::from_f32(vec![3.0, 4.0], &[1, 2]);
        let c = Tensor::from_f32(vec![5.0, 6.0], &[1, 2]);
        let out = ClientCore::concat_cols3(&a, &b, &c);
        assert_eq!(out.shape, vec![1, 6]);
        assert_eq!(out.as_f32(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }

    #[test]
    fn greedy_sampler_is_argmax() {
        let logits = Tensor::from_f32(
            vec![0.1, 0.9, 0.0, 2.0, -1.0, 0.5], &[2, 3]);
        let mut s = Sampler::Greedy;
        assert_eq!(s.pick(&logits, 0), 1);
        assert_eq!(s.pick(&logits, 1), 0);
    }

    #[test]
    fn topk_sampler_stays_in_top_k_and_is_deterministic() {
        let logits = Tensor::from_f32(
            vec![5.0, 4.0, -100.0, -100.0, -100.0, -100.0], &[1, 6]);
        let cfg = Sampling::TopK { k: 2, temperature: 1.0, seed: 42 };
        let mut a = Sampler::new(&cfg);
        let mut b = Sampler::new(&cfg);
        for _ in 0..32 {
            let ta = a.pick(&logits, 0);
            assert!(ta == 0 || ta == 1, "sampled outside top-k: {ta}");
            assert_eq!(ta, b.pick(&logits, 0), "same seed, same stream");
        }
    }

    #[test]
    fn topk_low_temperature_approaches_greedy() {
        let logits = Tensor::from_f32(vec![1.0, 10.0, 0.0], &[1, 3]);
        let mut s = Sampler::new(&Sampling::TopK {
            k: 3,
            temperature: 1e-4,
            seed: 7,
        });
        for _ in 0..16 {
            assert_eq!(s.pick(&logits, 0), 1);
        }
    }

    #[test]
    fn generation_config_builders() {
        let g = GenerationConfig::greedy(8).with_stop(0);
        assert_eq!(g.max_tokens, 8);
        assert_eq!(g.stop_tokens, vec![0]);
        assert!(matches!(g.sampling, Sampling::Greedy));
        let s = GenerationConfig::sampled(4, 0.8, 50, 1);
        assert!(matches!(s.sampling,
                         Sampling::TopK { k: 50, seed: 1, .. }));
        assert_eq!(s.prefill_chunk, None, "pipelining defaults off");
        let p = GenerationConfig::greedy(4).with_prefill_chunk(32);
        assert_eq!(p.prefill_chunk, Some(32));
    }
}
