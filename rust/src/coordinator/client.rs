//! Clients: the drivers of inference and fine-tuning.
//!
//! Each client owns everything request-specific — adapter parameters,
//! attention + KV cache, optimizer state, saved activations for its own
//! backward — and invokes the shared base executor layer-by-layer through
//! its [`VirtLayerCtx`].  Clients never synchronize with each other; they
//! only opportunistically share executor batches (paper section 3.2,
//! design goal 5).
//!
//! * [`InferenceSession`] — prefill + token-by-token decode with a
//!   bucketed KV cache (optionally host-offloaded).
//! * [`Trainer`] — full forward/backward/Adam iteration.  The backward
//!   composes the executor's memory-optimized `dX = dY . W^T` with
//!   client-side attention/LoRA/norm gradients, reproducing jax autodiff
//!   (pinned by the golden integration tests).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{bucket_for, ModelConfig, ATTN_BATCHES, SEQ_BUCKETS,
                    TOKEN_BUCKETS};
use crate::coordinator::adapter::{Adapter, AdapterGrads};
use crate::coordinator::kv_cache::{KvCache, KvPlacement};
use crate::coordinator::model_state::ClientWeights;
use crate::coordinator::optimizer::Adam;
use crate::coordinator::proto::{LayerId, Urgency};
use crate::coordinator::virt_layer::VirtLayerCtx;
use crate::runtime::Engine;
use crate::tensor::{ops, Tensor};

/// Shared per-client context: model dims, client-side weights, executor
/// proxy, and the engine used for client-side artifacts (attention, LoRA,
/// loss) — in a local placement this is the same engine as the
/// executor's.
pub struct ClientCore {
    pub cfg: ModelConfig,
    pub engine: Arc<Engine>,
    pub virt: Arc<VirtLayerCtx>,
    pub weights: ClientWeights,
    pub adapter: Option<Adapter>,
    /// LoRA alpha/rank scale (ignored for other adapters).
    pub lora_scale: f32,
}

/// Per-layer activations saved *by the client* for its backward pass.
/// The executor saves nothing (paper section 3.6).
struct SavedLayer {
    h_in: Tensor,        // (T, D) input to the block
    a_in: Tensor,        // (T, D) rmsnorm1 output (LoRA bwd input)
    qh: Tensor,          // (BH, S, H)
    kh: Tensor,
    vh: Tensor,
    attn_merged: Tensor, // (T, D)
    h_mid: Tensor,       // (T, D) after attention residual
    u_pre: Tensor,       // (T, F) gelu input
}

struct SavedActs {
    layers: Vec<SavedLayer>,
    h_last: Tensor,
}

impl ClientCore {
    fn check_batch(&self, batch: usize) -> Result<()> {
        if !ATTN_BATCHES.contains(&batch) {
            bail!("batch {batch} has no attention artifact \
                   (exported: {ATTN_BATCHES:?})");
        }
        Ok(())
    }

    /// `(T = B*S, D) -> (B*NH, S, H)`: per-sequence head split for the
    /// attention artifacts (sequences are concatenated token-major).
    fn to_heads(&self, x: &Tensor, batch: usize) -> Tensor {
        to_heads_batched(x, batch, self.cfg.n_heads)
    }

    /// Inverse of [`Self::to_heads`].
    fn from_heads(&self, x: &Tensor, batch: usize) -> Tensor {
        from_heads_batched(x, batch)
    }

    /// Zero-pad `(BH, S, H)` to `(BH, Sb, H)` along the sequence axis.
    fn pad_seq(x: &Tensor, sb: usize) -> Tensor {
        let (bh, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
        if s == sb {
            return x.clone(); // refcount bump, not a copy
        }
        let src = x.as_f32();
        let mut out = vec![0.0f32; bh * sb * h];
        for b in 0..bh {
            let srow = b * s * h;
            let drow = b * sb * h;
            out[drow..drow + s * h]
                .copy_from_slice(&src[srow..srow + s * h]);
        }
        Tensor::from_f32(out, &[bh, sb, h])
    }

    /// Drop sequence padding: `(BH, Sb, H) -> (BH, S, H)`.
    fn unpad_seq(x: &Tensor, s: usize) -> Tensor {
        let (bh, sb, h) = (x.shape[0], x.shape[1], x.shape[2]);
        if sb == s {
            return x.clone();
        }
        let src = x.as_f32();
        let mut out = vec![0.0f32; bh * s * h];
        for b in 0..bh {
            out[b * s * h..(b + 1) * s * h]
                .copy_from_slice(&src[b * sb * h..b * sb * h + s * h]);
        }
        Tensor::from_f32(out, &[bh, s, h])
    }

    /// `(T, D) x3 -> (T, 3D)` — reassemble the fused-QKV gradient.
    fn concat_cols3(a: &Tensor, b: &Tensor, c: &Tensor) -> Tensor {
        let (t, d) = (a.shape[0], a.shape[1]);
        let mut out = vec![0.0f32; t * 3 * d];
        for r in 0..t {
            out[r * 3 * d..r * 3 * d + d]
                .copy_from_slice(&a.as_f32()[r * d..(r + 1) * d]);
            out[r * 3 * d + d..r * 3 * d + 2 * d]
                .copy_from_slice(&b.as_f32()[r * d..(r + 1) * d]);
            out[r * 3 * d + 2 * d..r * 3 * d + 3 * d]
                .copy_from_slice(&c.as_f32()[r * d..(r + 1) * d]);
        }
        Tensor::from_f32(out, &[t, 3 * d])
    }

    /// LoRA delta via the fused Pallas artifact (bucketed tokens), with a
    /// native fallback when no bucket fits.
    fn lora_delta(&self, x: &Tensor, layer: usize, target: &str)
                  -> Result<Option<Tensor>> {
        let Some(Adapter::Lora { rank, targets, scale, pairs }) =
            self.adapter.as_ref()
        else {
            return Ok(None);
        };
        let on = match target {
            "q" => targets.q,
            "k" => targets.k,
            "v" => targets.v,
            "o" => targets.o,
            _ => false,
        };
        if !on {
            return Ok(None);
        }
        let pair = &pairs[layer][target];
        let t = x.shape[0];
        let d = self.cfg.d_model;
        // For tiny activations (decode steps) the PJRT dispatch costs
        // ~100x the math: run the adapter natively on the client — the
        // paper's observation that client-side compute is light enough
        // for weak devices applies to the host CPU here (perf log in
        // EXPERIMENTS.md §Perf).
        if t < 8 {
            return Ok(Some(crate::coordinator::adapter::apply_lora_native(
                x, pair, *scale)));
        }
        let name = match bucket_for(t, TOKEN_BUCKETS) {
            Some(tb) => format!("lora_fwd_t{tb}_{d}x{rank}x{d}"),
            None => {
                return Ok(Some(
                    crate::coordinator::adapter::apply_lora_native(
                        x, pair, *scale)));
            }
        };
        if !self.engine.has_artifact(&name) {
            return Ok(Some(crate::coordinator::adapter::apply_lora_native(
                x, pair, *scale)));
        }
        let tb = bucket_for(t, TOKEN_BUCKETS).unwrap();
        let xp = x.pad_rows(tb);
        let out = self.engine.execute(&name, &[&xp, &pair.a, &pair.b])?;
        Ok(Some(ops::scale(&out[0].slice_rows(0, t), *scale)))
    }

    /// LoRA backward through the fused artifact: (dA, dB, dX), all
    /// already multiplied by the adapter scale.
    fn lora_bwd(&self, x: &Tensor, dy: &Tensor, layer: usize, target: &str)
                -> Result<Option<(Tensor, Tensor, Tensor)>> {
        let Some(Adapter::Lora { rank, targets, scale, pairs }) =
            self.adapter.as_ref()
        else {
            return Ok(None);
        };
        let on = match target {
            "q" => targets.q,
            "k" => targets.k,
            "v" => targets.v,
            "o" => targets.o,
            _ => false,
        };
        if !on {
            return Ok(None);
        }
        let pair = &pairs[layer][target];
        let t = x.shape[0];
        let d = self.cfg.d_model;
        let tb = bucket_for(t, TOKEN_BUCKETS)
            .context("token count exceeds lora bwd buckets")?;
        let name = format!("lora_bwd_t{tb}_{d}x{rank}x{d}");
        let xp = x.pad_rows(tb);
        let dyp = dy.pad_rows(tb);
        let out =
            self.engine.execute(&name, &[&xp, &dyp, &pair.a, &pair.b])?;
        Ok(Some((
            ops::scale(&out[0], *scale),
            ops::scale(&out[1], *scale),
            ops::scale(&out[2].slice_rows(0, t), *scale),
        )))
    }

    /// Full forward over `batch` sequences of length `s` (token-major
    /// concat).  Saves activations when `save` is set (training) and
    /// appends K/V when `kv` is set (inference prefill).
    fn forward_full(&self, tokens: &[i32], batch: usize, urgency: Urgency,
                    mut save: Option<&mut SavedActs>,
                    mut kv: Option<&mut KvCache>) -> Result<Tensor> {
        self.check_batch(batch)?;
        let t = tokens.len();
        let s = t / batch;
        let nh = self.cfg.n_heads;
        let sb = bucket_for(s, SEQ_BUCKETS)
            .with_context(|| format!("seq len {s} exceeds buckets"))?;
        let d = self.cfg.d_model;

        // positions restart per sequence
        let positions: Vec<i32> =
            (0..t).map(|i| (i % s) as i32).collect();
        let mut h = self.virt.embed(
            Tensor::from_i32(tokens.to_vec(), &[t]),
            Tensor::from_i32(positions, &[t]),
            urgency,
        )?;

        for l in 0..self.cfg.n_layers {
            let h_in = h.clone();
            let a_in = ops::rmsnorm(&h, &self.weights.norm1[l]);
            let qkv = self.virt.forward(LayerId::Qkv(l), a_in.clone(),
                                        urgency)?;
            let mut q = qkv.slice_cols(0, d);
            let mut k = qkv.slice_cols(d, 2 * d);
            let mut v = qkv.slice_cols(2 * d, 3 * d);
            if let Some(dq) = self.lora_delta(&a_in, l, "q")? {
                ops::add_assign(&mut q, &dq);
            }
            if let Some(dk) = self.lora_delta(&a_in, l, "k")? {
                ops::add_assign(&mut k, &dk);
            }
            if let Some(dv) = self.lora_delta(&a_in, l, "v")? {
                ops::add_assign(&mut v, &dv);
            }
            if let Some(Adapter::Ia3 { k_scale, v_scale, .. }) =
                self.adapter.as_ref()
            {
                k = Adapter::ia3_apply(&k, &k_scale[l]);
                v = Adapter::ia3_apply(&v, &v_scale[l]);
            }
            let qh = self.to_heads(&q, batch);
            let kh = self.to_heads(&k, batch);
            let vh = self.to_heads(&v, batch);
            if let Some(cache) = kv.as_deref_mut() {
                cache.append(l, &kh, &vh);
            }
            // Client-side attention through the Pallas prefill artifact.
            let name = format!("attn_prefill_bh{}_s{sb}_h{}", batch * nh,
                               self.cfg.d_head());
            let qp = Self::pad_seq(&qh, sb);
            let kp = Self::pad_seq(&kh, sb);
            let vp = Self::pad_seq(&vh, sb);
            let attn_p = self.engine.execute(&name, &[&qp, &kp, &vp])?;
            let attn = Self::unpad_seq(&attn_p[0], s);
            let attn_merged = self.from_heads(&attn, batch);
            let mut o = self.virt.forward(LayerId::AttnOut(l),
                                          attn_merged.clone(), urgency)?;
            if let Some(do_) = self.lora_delta(&attn_merged, l, "o")? {
                ops::add_assign(&mut o, &do_);
            }
            let h_mid = ops::add(&h, &o);
            let m_in = ops::rmsnorm(&h_mid, &self.weights.norm2[l]);
            let mut u_pre = self.virt.forward(LayerId::MlpUp(l), m_in,
                                              urgency)?;
            if let Some(Adapter::Ia3 { ff_scale, .. }) =
                self.adapter.as_ref()
            {
                u_pre = Adapter::ia3_apply(&u_pre, &ff_scale[l]);
            }
            let u = ops::gelu(&u_pre);
            let down =
                self.virt.forward(LayerId::MlpDown(l), u, urgency)?;
            let h_out = ops::add(&h_mid, &down);
            if let Some(sv) = save.as_deref_mut() {
                sv.layers.push(SavedLayer {
                    h_in,
                    a_in,
                    qh,
                    kh,
                    vh,
                    attn_merged,
                    h_mid,
                    u_pre,
                });
            }
            h = h_out;
        }
        if let Some(sv) = save.as_deref_mut() {
            sv.h_last = h.clone();
        }
        let hf = ops::rmsnorm(&h, &self.weights.norm_f);
        self.virt.forward(LayerId::LmHead, hf, urgency)
    }
}

// ---------------------------------------------------------------------------
// Inference
// ---------------------------------------------------------------------------

/// An inference job: prefill once, then decode token-by-token against the
/// client-owned KV cache.
pub struct InferenceSession {
    pub core: ClientCore,
    pub batch: usize,
    kv: KvCache,
    /// Last emitted token per sequence.
    last: Vec<i32>,
    /// Tokens generated so far (per sequence, column-major appended).
    pub generated: Vec<Vec<i32>>,
    pos: usize,
}

impl InferenceSession {
    pub fn new(core: ClientCore, batch: usize,
               kv_placement: KvPlacement) -> Result<Self> {
        core.check_batch(batch)?;
        let kv = KvCache::new(core.cfg.n_layers, batch * core.cfg.n_heads,
                              core.cfg.d_head(), kv_placement);
        Ok(InferenceSession {
            core,
            batch,
            kv,
            last: Vec::new(),
            generated: vec![Vec::new(); batch],
            pos: 0,
        })
    }

    /// If the adapter is Prefix, seed the cache with the learned prefix.
    pub fn seed_prefix(&mut self) {
        if let Some(Adapter::Prefix { k_prefix, v_prefix, .. }) =
            self.core.adapter.clone()
        {
            for l in 0..self.core.cfg.n_layers {
                self.kv.append(l, &k_prefix[l], &v_prefix[l]);
            }
            // prefix occupies cache but not token positions
        }
    }

    /// Process the prompt (`batch` sequences x `s` tokens, token-major).
    /// Returns the first generated token per sequence.
    pub fn prefill(&mut self, tokens: &[i32]) -> Result<Vec<i32>> {
        let s = tokens.len() / self.batch;
        let logits = self.core.forward_full(tokens, self.batch,
                                            Urgency::Bulk, None,
                                            Some(&mut self.kv))?;
        self.pos = s;
        let v = self.core.cfg.vocab;
        let mut first = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            let row = (b + 1) * s - 1; // last token of sequence b
            let _ = v;
            first.push(ops::argmax_row(&logits, row));
        }
        self.last = first.clone();
        for (b, t) in first.iter().enumerate() {
            self.generated[b].push(*t);
        }
        Ok(first)
    }

    /// Incremental prefill: push the prompt through the *decode* path
    /// one token column at a time.  Slower than [`Self::prefill`] but
    /// required when the KV cache holds a learned prefix (the bucketed
    /// prefill artifact has no notion of pre-existing cache rows) — and
    /// numerically identical to batch prefill otherwise (covered by an
    /// integration test).  Returns the first generated token per
    /// sequence.
    pub fn prefill_incremental(&mut self, tokens: &[i32])
                               -> Result<Vec<i32>> {
        let s = tokens.len() / self.batch;
        let mut next = Vec::new();
        for col in 0..s {
            let column: Vec<i32> = (0..self.batch)
                .map(|b| tokens[b * s + col])
                .collect();
            next = self.step_with_tokens(&column)?;
        }
        self.last = next.clone();
        for (b, t) in next.iter().enumerate() {
            self.generated[b].push(*t);
        }
        Ok(next)
    }

    /// One decode step: feed the last tokens, emit the next per sequence.
    pub fn decode_step(&mut self) -> Result<Vec<i32>> {
        if self.last.is_empty() {
            bail!("decode before prefill");
        }
        let last = self.last.clone();
        let next = self.step_with_tokens(&last)?;
        self.last = next.clone();
        for (i, t) in next.iter().enumerate() {
            self.generated[i].push(*t);
        }
        Ok(next)
    }

    /// Core single-column step: embed `tokens` at the current position,
    /// run all layers against the cache, return per-sequence argmax.
    fn step_with_tokens(&mut self, step_tokens: &[i32])
                        -> Result<Vec<i32>> {
        let b = self.batch;
        let nh = self.core.cfg.n_heads;
        let d = self.core.cfg.d_model;
        let urgency = Urgency::Interactive;
        let tokens = Tensor::from_i32(step_tokens.to_vec(), &[b]);
        let positions =
            Tensor::from_i32(vec![self.pos as i32; b], &[b]);
        let mut h = self.core.virt.embed(tokens, positions, urgency)?;
        for l in 0..self.core.cfg.n_layers {
            let a_in = ops::rmsnorm(&h, &self.core.weights.norm1[l]);
            let qkv = self.core.virt.forward(LayerId::Qkv(l),
                                             a_in.clone(), urgency)?;
            let mut q = qkv.slice_cols(0, d);
            let mut k = qkv.slice_cols(d, 2 * d);
            let mut v = qkv.slice_cols(2 * d, 3 * d);
            if let Some(dq) = self.core.lora_delta(&a_in, l, "q")? {
                ops::add_assign(&mut q, &dq);
            }
            if let Some(dk) = self.core.lora_delta(&a_in, l, "k")? {
                ops::add_assign(&mut k, &dk);
            }
            if let Some(dv) = self.core.lora_delta(&a_in, l, "v")? {
                ops::add_assign(&mut v, &dv);
            }
            if let Some(Adapter::Ia3 { k_scale, v_scale, .. }) =
                self.core.adapter.as_ref()
            {
                k = Adapter::ia3_apply(&k, &k_scale[l]);
                v = Adapter::ia3_apply(&v, &v_scale[l]);
            }
            // single-token head split: (B, D) -> (B*NH, 1, H)
            let qh = q.split_heads_rows(b, nh);
            let kh = k.split_heads_rows(b, nh);
            let vh = v.split_heads_rows(b, nh);
            // Per-layer length: during this step, earlier layers already
            // hold the new token while later ones don't yet.
            let len = self.kv.append(l, &kh, &vh);
            let sb = bucket_for(len, SEQ_BUCKETS)
                .context("KV cache exceeds seq buckets")?;
            let (kc, vc) = self.kv.padded(l, sb);
            let name = format!("attn_decode_bh{}_s{sb}_h{}", b * nh,
                               self.core.cfg.d_head());
            let kv_len = Tensor::scalar_i32(len as i32);
            // decode attention rides the high-priority device lane
            let out = self.core.engine.execute_prio(
                &name, &[&qh, &kc, &vc, &kv_len], true)?;
            let attn = out[0].clone(); // (BH, 1, H)
            let attn_merged = attn.merge_heads_rows(b);
            let mut o = self.core.virt.forward(
                LayerId::AttnOut(l), attn_merged.clone(), urgency)?;
            if let Some(dl) = self.core.lora_delta(&attn_merged, l, "o")? {
                ops::add_assign(&mut o, &dl);
            }
            let h_mid = ops::add(&h, &o);
            let m_in = ops::rmsnorm(&h_mid, &self.core.weights.norm2[l]);
            let mut u_pre = self.core.virt.forward(
                LayerId::MlpUp(l), m_in, urgency)?;
            if let Some(Adapter::Ia3 { ff_scale, .. }) =
                self.core.adapter.as_ref()
            {
                u_pre = Adapter::ia3_apply(&u_pre, &ff_scale[l]);
            }
            let u = ops::gelu(&u_pre);
            let down = self.core.virt.forward(
                LayerId::MlpDown(l), u, urgency)?;
            h = ops::add(&h_mid, &down);
        }
        let hf = ops::rmsnorm(&h, &self.core.weights.norm_f);
        let logits =
            self.core.virt.forward(LayerId::LmHead, hf, urgency)?;
        let mut next = Vec::with_capacity(b);
        for row in 0..b {
            next.push(ops::argmax_row(&logits, row));
        }
        self.pos += 1;
        Ok(next)
    }

    pub fn kv_bytes(&self) -> u64 {
        self.kv.bytes()
    }

    pub fn kv_len(&self) -> usize {
        self.kv.len()
    }

    pub fn kv_transfer_bytes_per_step(&self) -> u64 {
        self.kv.transfer_bytes_per_step()
    }
}

// ---------------------------------------------------------------------------
// Fine-tuning
// ---------------------------------------------------------------------------

/// Result of one training iteration.
#[derive(Debug, Clone)]
pub struct TrainOutcome {
    pub loss: f32,
    pub grad_norm: f32,
    pub tokens: usize,
}

/// A fine-tuning job: forward, hand-rolled backward, Adam on the adapter.
pub struct Trainer {
    pub core: ClientCore,
    pub batch: usize,
    pub optimizer: Adam,
}

impl Trainer {
    pub fn new(core: ClientCore, batch: usize) -> Result<Self> {
        core.check_batch(batch)?;
        // The hand-rolled backward accumulates LoRA gradients; IA3 and
        // Prefix adapters are inference-only in this implementation
        // (their gradient plumbing exists in `adapter::ia3_bwd` but is
        // not wired into the flattened optimizer layout).
        let n = match core.adapter.as_ref() {
            Some(a @ Adapter::Lora { .. }) => a.n_params(),
            Some(_) => bail!(
                "trainer currently supports LoRA adapters only \
                 (IA3/Prefix are inference-only)"),
            None => bail!("trainer requires a trainable adapter"),
        };
        Ok(Trainer { core, batch, optimizer: Adam::new(n) })
    }

    /// One full iteration: forward, loss, backward, optimizer step.
    pub fn train_step(&mut self, tokens: &[i32], labels: &[i32])
                      -> Result<TrainOutcome> {
        let (loss, grads) = self.loss_and_grads(tokens, labels)?;
        let grad_norm = grads.l2_norm();
        let adapter = self.core.adapter.as_mut().unwrap();
        let mut flat = adapter.flatten();
        self.optimizer
            .step_artifact(&self.core.engine, &mut flat, &grads.flat)?;
        adapter.unflatten(&flat)?;
        Ok(TrainOutcome { loss, grad_norm, tokens: tokens.len() })
    }

    /// Forward + backward only (used by the golden gradient tests).
    pub fn loss_and_grads(&mut self, tokens: &[i32], labels: &[i32])
                          -> Result<(f32, AdapterGrads)> {
        let t = tokens.len();
        let urgency = Urgency::Training;
        let mut saved = SavedActs {
            layers: Vec::with_capacity(self.core.cfg.n_layers),
            h_last: Tensor::zeros(&[1]),
        };
        let logits = self.core.forward_full(tokens, self.batch, urgency,
                                            Some(&mut saved), None)?;
        // loss + dlogits through the bucketed xent artifact
        let v = self.core.cfg.vocab;
        let tb = bucket_for(t, TOKEN_BUCKETS).context("xent bucket")?;
        let mut lab = labels.to_vec();
        lab.resize(tb, 0);
        let mut w = vec![1.0f32; t];
        w.resize(tb, 0.0);
        let name = format!("xent_t{tb}_v{v}");
        let lp = logits.pad_rows(tb);
        let out = self.core.engine.execute(&name, &[
            &lp,
            &Tensor::from_i32(lab, &[tb]),
            &Tensor::from_f32(w, &[tb]),
        ])?;
        let loss = out[0].as_f32()[0];
        let dlogits = out[1].slice_rows(0, t);

        let adapter_ref = self.core.adapter.as_ref().unwrap().clone();
        let mut grads = AdapterGrads::zeros_like(&adapter_ref);

        // ---- backward ----
        let dhf = self.core.virt.backward(LayerId::LmHead, dlogits,
                                          urgency)?;
        let mut dh = ops::rmsnorm_bwd(&saved.h_last,
                                      &self.core.weights.norm_f, &dhf);
        let s = t / self.batch;
        let sb = bucket_for(s, SEQ_BUCKETS).unwrap();
        let nh = self.core.cfg.n_heads;
        for l in (0..self.core.cfg.n_layers).rev() {
            let sv = &saved.layers[l];
            // MLP path
            let dd = self.core.virt.backward(LayerId::MlpDown(l),
                                             dh.clone(), urgency)?;
            let mut dg = dd;
            if let Some(Adapter::Ia3 { ff_scale, .. }) =
                self.core.adapter.as_ref()
            {
                // u_pre was scaled: d(scale)/d and dx through the scale
                let (_ds, dx) =
                    Adapter::ia3_bwd(&sv.u_pre, &ff_scale[l], &dg);
                dg = dx; // IA3 grads for ff handled via dscale (omitted
                          // from flat layout for LoRA-focused trainer)
            }
            let dgelu = ops::gelu_bwd(&sv.u_pre, &dg);
            let dm = self.core.virt.backward(LayerId::MlpUp(l), dgelu,
                                             urgency)?;
            let dnorm2 = ops::rmsnorm_bwd(&sv.h_mid,
                                          &self.core.weights.norm2[l],
                                          &dm);
            let dh_mid = ops::add(&dh, &dnorm2);

            // attention output path
            let do_ = dh_mid.clone();
            let mut dattn = self.core.virt.backward(LayerId::AttnOut(l),
                                                    do_.clone(),
                                                    urgency)?;
            if let Some((da, db, dx)) =
                self.core.lora_bwd(&sv.attn_merged, &do_, l, "o")?
            {
                grads.add_lora(&adapter_ref, l, "o", &da, &db);
                ops::add_assign(&mut dattn, &dx);
            }
            // attention backward (client-side artifact)
            let dattn_h = self.core.to_heads(&dattn, self.batch);
            let name = format!("attn_bwd_bh{}_s{sb}_h{}",
                               self.batch * nh, self.core.cfg.d_head());
            let qp = ClientCore::pad_seq(&sv.qh, sb);
            let kp = ClientCore::pad_seq(&sv.kh, sb);
            let vp = ClientCore::pad_seq(&sv.vh, sb);
            let dop = ClientCore::pad_seq(&dattn_h, sb);
            let out = self.core.engine.execute(
                &name, &[&qp, &kp, &vp, &dop])?;
            let dq = self.core.from_heads(
                &ClientCore::unpad_seq(&out[0], s), self.batch);
            let dk = self.core.from_heads(
                &ClientCore::unpad_seq(&out[1], s), self.batch);
            let dv = self.core.from_heads(
                &ClientCore::unpad_seq(&out[2], s), self.batch);

            // LoRA backward on q/k/v + assemble fused-QKV gradient
            let mut da_in_extra = Tensor::zeros(&[t, self.core.cfg.d_model]);
            for (target, dt) in [("q", &dq), ("k", &dk), ("v", &dv)] {
                if let Some((da, db, dx)) =
                    self.core.lora_bwd(&sv.a_in, dt, l, target)?
                {
                    grads.add_lora(&adapter_ref, l, target, &da, &db);
                    ops::add_assign(&mut da_in_extra, &dx);
                }
            }
            let dqkv = ClientCore::concat_cols3(&dq, &dk, &dv);
            let mut da_in = self.core.virt.backward(LayerId::Qkv(l), dqkv,
                                                    urgency)?;
            ops::add_assign(&mut da_in, &da_in_extra);
            let dnorm1 = ops::rmsnorm_bwd(&sv.h_in,
                                          &self.core.weights.norm1[l],
                                          &da_in);
            dh = ops::add(&dh_mid, &dnorm1);
        }
        Ok((loss, grads))
    }

    /// Client-side memory (adapter + optimizer + saved activations
    /// estimate) for the memory figures.
    pub fn client_state_bytes(&self, seq_len: usize) -> u64 {
        let adapter = self
            .core
            .adapter
            .as_ref()
            .map(|a| (a.n_params() * 4) as u64)
            .unwrap_or(0);
        let opt = self.optimizer.state_bytes();
        let t = (self.batch * seq_len) as u64;
        let d = self.core.cfg.d_model as u64;
        let f = self.core.cfg.d_ff as u64;
        // per layer saved: 5 (T,D) + qkv heads (3 T D) + (T,F)
        let saved =
            self.core.cfg.n_layers as u64 * t * (8 * d + f) * 4;
        adapter + opt + saved
    }
}

/// `(T = B*S, D) -> (B*NH, S, H)` head split (free function so it is
/// unit-testable without a deployment).
fn to_heads_batched(x: &Tensor, batch: usize, nh: usize) -> Tensor {
    let (t, d) = (x.shape[0], x.shape[1]);
    let s = t / batch;
    let h = d / nh;
    let src = x.as_f32();
    let mut out = vec![0.0f32; t * d];
    for b in 0..batch {
        for n in 0..nh {
            for ti in 0..s {
                let dst = ((b * nh + n) * s + ti) * h;
                let sidx = (b * s + ti) * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[sidx..sidx + h]);
            }
        }
    }
    Tensor::from_f32(out, &[batch * nh, s, h])
}

/// Inverse of [`to_heads_batched`].
fn from_heads_batched(x: &Tensor, batch: usize) -> Tensor {
    let (bh, s, h) = (x.shape[0], x.shape[1], x.shape[2]);
    let nh = bh / batch;
    let d = nh * h;
    let src = x.as_f32();
    let mut out = vec![0.0f32; batch * s * d];
    for b in 0..batch {
        for n in 0..nh {
            for ti in 0..s {
                let sidx = ((b * nh + n) * s + ti) * h;
                let dst = (b * s + ti) * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[sidx..sidx + h]);
            }
        }
    }
    Tensor::from_f32(out, &[batch * s, d])
}

// small helpers on Tensor used only here
trait DecodeReshape {
    fn split_heads_rows(&self, b: usize, nh: usize) -> Tensor;
    fn merge_heads_rows(&self, b: usize) -> Tensor;
}

impl DecodeReshape for Tensor {
    /// `(B, D) -> (B*NH, 1, H)` for single-token decode.
    fn split_heads_rows(&self, b: usize, nh: usize) -> Tensor {
        let d = self.shape[1];
        let h = d / nh;
        let src = self.as_f32();
        let mut out = vec![0.0f32; b * d];
        for bi in 0..b {
            for n in 0..nh {
                let dst = (bi * nh + n) * h;
                let s = bi * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[b * nh, 1, h])
    }

    /// `(B*NH, 1, H) -> (B, D)`.
    fn merge_heads_rows(&self, b: usize) -> Tensor {
        let (bh, _, h) = (self.shape[0], self.shape[1], self.shape[2]);
        let nh = bh / b;
        let d = nh * h;
        let src = self.as_f32();
        let mut out = vec![0.0f32; b * d];
        for bi in 0..b {
            for n in 0..nh {
                let s = (bi * nh + n) * h;
                let dst = bi * d + n * h;
                out[dst..dst + h].copy_from_slice(&src[s..s + h]);
            }
        }
        Tensor::from_f32(out, &[b, d])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heads_roundtrip_batched() {
        let (b, s, nh, h) = (2usize, 3usize, 4usize, 16usize);
        let d = nh * h;
        let x = Tensor::from_f32(
            (0..b * s * d).map(|i| i as f32).collect(), &[b * s, d]);
        let heads = to_heads_batched(&x, b, nh);
        assert_eq!(heads.shape, vec![b * nh, s, h]);
        assert_eq!(from_heads_batched(&heads, b), x);
    }

    #[test]
    fn decode_reshape_roundtrip() {
        let (b, nh, h) = (2usize, 4usize, 8usize);
        let x = Tensor::from_f32(
            (0..b * nh * h).map(|i| i as f32).collect(), &[b, nh * h]);
        let split = x.split_heads_rows(b, nh);
        assert_eq!(split.shape, vec![b * nh, 1, h]);
        assert_eq!(split.merge_heads_rows(b), x);
    }

    #[test]
    fn pad_unpad_seq_roundtrip() {
        let x = Tensor::from_f32(
            (0..4 * 3 * 2).map(|i| i as f32).collect(), &[4, 3, 2]);
        let p = ClientCore::pad_seq(&x, 8);
        assert_eq!(p.shape, vec![4, 8, 2]);
        assert_eq!(ClientCore::unpad_seq(&p, 3), x);
        // padding region is zero
        assert_eq!(p.as_f32()[(0 * 8 + 3) * 2], 0.0);
    }

    #[test]
    fn concat_cols3_interleaves_rows() {
        let a = Tensor::from_f32(vec![1.0, 2.0], &[1, 2]);
        let b = Tensor::from_f32(vec![3.0, 4.0], &[1, 2]);
        let c = Tensor::from_f32(vec![5.0, 6.0], &[1, 2]);
        let out = ClientCore::concat_cols3(&a, &b, &c);
        assert_eq!(out.shape, vec![1, 6]);
        assert_eq!(out.as_f32(), &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
    }
}
