//! Deterministic fault injection for the executor fleet.
//!
//! The fleet's failure story ([deadlines + bounded retry in
//! `virt_layer`](crate::coordinator::virt_layer), [supervision +
//! respawn in `fleet`](crate::coordinator::fleet)) needs faults that
//! are *drivable*: reproducible across runs, precise about which shard
//! fails, when, and how.  A [`FaultPlan`] is that driver — a seeded,
//! declarative set of [`FaultRule`]s that wraps a shard's
//! [`ShardEndpoint`] with an interposer thread sitting between the
//! client and the executor.  The interposer can
//!
//! * **drop** a request on the floor (lost message),
//! * **stall** it indefinitely (hung shard — the client's deadline is
//!   the only way out),
//! * answer with an **error** (failed flush),
//! * **delay** the response (slow shard / congested link),
//! * **kill** the executor thread ([`ExecMsg::Crash`] — the watchdog
//!   observes the dead join handle and respawns),
//! * **flood** the shard's ingress meter with phantom queue entries
//!   (background tenants piling on — drives the overload path:
//!   saturation backpressure and urgency-based shedding).
//!
//! Interposers share the inner endpoint's [`IngressMeter`] and circuit
//! breaker, so the overload machinery observes faulted traffic exactly
//! as it would real traffic: a request the interposer swallows (drop /
//! error / kill) releases its ingress slot, a stalled one holds it
//! until the interposer exits (a hung shard backs up its queue), and
//! flood phantoms drain on exit.
//!
//! Determinism: probabilistic rules draw from a splitmix64 stream
//! seeded with `seed ^ hash(shard)` (the same no-`rand` idiom as
//! `privacy::NoiseGen`), and the interposer's step counter counts
//! *requests through this wrapped endpoint*.  Plans injected via
//! [`Deployment::inject_faults`](crate::coordinator::Deployment) wrap
//! per *client* (each session/trainer's routing table gets its own
//! interposer), so step N means "the N-th request this client sends to
//! that shard" — reproducible regardless of cross-client interleaving.
//!
//! Non-request control traffic (register/deregister, noise
//! registration, shutdown) always passes through unharmed: faults
//! target the serving path, not the bookkeeping.  When the interposer
//! exits (its sender side dropped), any stalled requests are released
//! by dropping them — blocked clients observe a disconnect, not a
//! leak.

#![deny(clippy::unwrap_used)]

use std::sync::mpsc::{channel, Sender};
use std::sync::Arc;
use std::time::Duration;

use crate::coordinator::proto::{ExecMsg, LayerRequest};
use crate::coordinator::virt_layer::{IngressMeter, ShardEndpoint};

/// What the interposer does to a matched request.
#[derive(Debug, Clone, PartialEq)]
pub enum FaultAction {
    /// Silently discard the request (lost message).  The client's
    /// response receiver stays open forever — only a deadline
    /// surfaces it.
    Drop,
    /// Hold the request without answering or forwarding: the shard
    /// appears hung.  Held requests release (as disconnects) when the
    /// interposer exits.
    Stall,
    /// Answer the request with this executor-error message without
    /// involving the shard (a failed flush).
    ErrorResponse(String),
    /// Forward the request, then delay its response by this much.
    Delay(Duration),
    /// Send [`ExecMsg::Crash`] to the underlying executor and discard
    /// the request: the shard thread dies mid-service, exactly as a
    /// panic would kill it.
    KillShard,
    /// Force-admit this many phantom entries into the shard's ingress
    /// meter (the triggering request still flows).  Each firing makes
    /// the queue look that much deeper — a brown-out in a bottle:
    /// dispatch beyond the high-water mark answers `ShardSaturated`,
    /// and background flushes shed.  Phantoms drain when the
    /// interposer exits.
    Flood(usize),
}

/// One matching rule: *which shard*, *what*, *from when*, *how often*.
#[derive(Debug, Clone)]
pub struct FaultRule {
    pub shard: usize,
    pub action: FaultAction,
    /// First request step (1-based, counted per wrapped endpoint) at
    /// which this rule can fire.
    pub from_step: u64,
    /// How many times the rule fires before retiring (`None` =
    /// unlimited — e.g. a permanent stall).
    pub count: Option<u64>,
    /// Probability of firing per candidate request (`1.0` = always).
    pub probability: f64,
}

impl FaultRule {
    /// A rule that always fires, from the first request, forever.
    pub fn on(shard: usize, action: FaultAction) -> Self {
        FaultRule {
            shard,
            action,
            from_step: 1,
            count: None,
            probability: 1.0,
        }
    }

    /// Fire no earlier than the `step`-th request (1-based).
    pub fn from_step(mut self, step: u64) -> Self {
        self.from_step = step.max(1);
        self
    }

    /// Retire after firing `n` times.
    pub fn times(mut self, n: u64) -> Self {
        self.count = Some(n);
        self
    }

    /// Fire with probability `p` per candidate request.
    pub fn with_probability(mut self, p: f64) -> Self {
        self.probability = p.clamp(0.0, 1.0);
        self
    }
}

/// A deterministic, seeded fault schedule over the fleet.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    seed: u64,
    rules: Vec<FaultRule>,
}

impl FaultPlan {
    pub fn new(seed: u64) -> Self {
        FaultPlan { seed, rules: Vec::new() }
    }

    pub fn rule(mut self, rule: FaultRule) -> Self {
        self.rules.push(rule);
        self
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn rules(&self) -> &[FaultRule] {
        &self.rules
    }

    /// Wrap a raw executor channel (single-shard tests/tools): returns
    /// a sender whose shard-`shard` rules interpose on the way to `tx`.
    pub fn wrap(&self, shard: usize, tx: Sender<ExecMsg>)
                -> Sender<ExecMsg> {
        self.wrap_endpoint(shard, Arc::new(ShardEndpoint::new(tx)))
            .sender()
    }

    /// Wrap a shard's endpoint: requests route through an interposer
    /// thread applying this plan's rules for `shard`; everything else
    /// passes through.  Returns the inner endpoint unchanged when no
    /// rule targets the shard — fault-free shards keep the direct
    /// (respawn-transparent) path with zero overhead.
    ///
    /// The interposer resolves `inner.sender()` per message, so a fleet
    /// respawn swapping the inner endpoint redirects faulted traffic
    /// too.  The *wrapped* endpoint mirrors no epoch; read recovery
    /// state from the fleet's own endpoints.  It does share the
    /// inner's ingress meter and circuit breaker — overload accounting
    /// stays fleet-global across the interposition.
    pub fn wrap_endpoint(&self, shard: usize,
                         inner: Arc<ShardEndpoint>)
                         -> Arc<ShardEndpoint> {
        let rules: Vec<RuleState> = self
            .rules
            .iter()
            .filter(|r| r.shard == shard)
            .map(|r| RuleState { rule: r.clone(), remaining: r.count })
            .collect();
        if rules.is_empty() {
            return inner;
        }
        let (tx, rx) = channel::<ExecMsg>();
        let seed = self
            .seed
            .wrapping_add((shard as u64)
                .wrapping_mul(0x9E3779B97F4A7C15));
        let wrapped = Arc::new(ShardEndpoint::with_shared(
            tx, inner.meter().clone(), inner.breaker().clone()));
        std::thread::Builder::new()
            .name(format!("fault-interposer-{shard}"))
            .spawn(move || interpose(rx, inner, rules, seed))
            .expect("spawn fault interposer");
        wrapped
    }
}

struct RuleState {
    rule: FaultRule,
    remaining: Option<u64>,
}

/// splitmix64 → U(0,1) — the same deterministic idiom as
/// `privacy::NoiseGen`.
struct FaultRng {
    state: u64,
}

impl FaultRng {
    fn next_unit(&mut self) -> f64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^= z >> 31;
        (z >> 11) as f64 / (1u64 << 53) as f64
    }
}

fn interpose(rx: std::sync::mpsc::Receiver<ExecMsg>,
             inner: Arc<ShardEndpoint>, mut rules: Vec<RuleState>,
             seed: u64) {
    let mut rng = FaultRng { state: seed };
    let mut step: u64 = 0;
    // The shard's real meter: swallowed requests release their ingress
    // slot here (the executor they never reach cannot), stalls hold
    // theirs, and flood phantoms accumulate until exit.
    let meter: Arc<IngressMeter> = inner.meter().clone();
    let mut flooded: usize = 0;
    // Held requests of `Stall` rules: dropped (→ client-side
    // disconnect) only when the interposer exits.
    let mut stalled: Vec<LayerRequest> = Vec::new();
    while let Ok(msg) = rx.recv() {
        let mut req = match msg {
            ExecMsg::Request(r) => r,
            other => {
                // Control traffic is never faulted.
                let _ = inner.sender().send(other);
                continue;
            }
        };
        step += 1;
        let action = rules.iter_mut().find_map(|rs| {
            if step < rs.rule.from_step
                || rs.remaining == Some(0)
                || (rs.rule.probability < 1.0
                    && rng.next_unit() >= rs.rule.probability)
            {
                return None;
            }
            if let Some(n) = &mut rs.remaining {
                *n -= 1;
            }
            Some(rs.rule.action.clone())
        });
        match action {
            None => {
                let _ = inner.sender().send(ExecMsg::Request(req));
            }
            Some(FaultAction::Flood(n)) => {
                for _ in 0..n {
                    meter.force_admit();
                }
                flooded += n;
                // the triggering request itself still flows
                let _ = inner.sender().send(ExecMsg::Request(req));
            }
            Some(FaultAction::Drop) => {
                meter.exit(); // a lost message occupies no queue
                drop(req);
            }
            Some(FaultAction::Stall) => stalled.push(req),
            Some(FaultAction::ErrorResponse(message)) => {
                meter.exit();
                let _ = req.resp.send(
                    crate::coordinator::proto::LayerResponse {
                        y: Err(message),
                        queue_wait_secs: 0.0,
                        batch_clients: 1,
                    },
                );
            }
            Some(FaultAction::Delay(d)) => {
                // Forward with a relay response channel; a side thread
                // sleeps before releasing the real answer.
                let (tx2, rx2) = channel();
                let client_resp =
                    std::mem::replace(&mut req.resp, tx2);
                let _ = inner.sender().send(ExecMsg::Request(req));
                std::thread::spawn(move || {
                    if let Ok(resp) = rx2.recv() {
                        std::thread::sleep(d);
                        let _ = client_resp.send(resp);
                    }
                });
            }
            Some(FaultAction::KillShard) => {
                meter.exit();
                let _ = inner.sender().send(ExecMsg::Crash);
                drop(req);
            }
        }
    }
    // Return every ingress slot this interposer still holds: stalled
    // requests' and flood phantoms'.  (The fleet's respawn path also
    // resets the meter, but a plan cleared without a crash must not
    // leave the shard looking saturated forever.)
    for _ in 0..stalled.len() + flooded {
        meter.exit();
    }
    drop(stalled);
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::coordinator::proto::{LayerId, LayerResponse, OpKind,
                                    Urgency};
    use crate::tensor::Tensor;
    use std::sync::mpsc::Receiver;

    fn request(resp: Sender<LayerResponse>) -> ExecMsg {
        ExecMsg::Request(LayerRequest {
            client_id: 0,
            layer: LayerId::Qkv(0),
            op: OpKind::Forward,
            x: Tensor::zeros(&[1, 4]),
            positions: None,
            urgency: Urgency::Bulk,
            resp,
        })
    }

    /// Echo executor: answers every request with its own input.
    fn echo_shard(rx: Receiver<ExecMsg>) -> std::thread::JoinHandle<u64> {
        std::thread::spawn(move || {
            let mut served = 0;
            while let Ok(msg) = rx.recv() {
                match msg {
                    ExecMsg::Request(req) => {
                        served += 1;
                        let _ = req.resp.send(LayerResponse {
                            y: Ok(req.x.clone()),
                            queue_wait_secs: 0.0,
                            batch_clients: 1,
                        });
                    }
                    ExecMsg::Crash => return served,
                    _ => {}
                }
            }
            served
        })
    }

    #[test]
    fn rules_fire_at_their_step_and_retire_by_count() {
        let (exec_tx, exec_rx) = std::sync::mpsc::channel();
        let shard = echo_shard(exec_rx);
        let plan = FaultPlan::new(7).rule(
            FaultRule::on(0, FaultAction::ErrorResponse("boom".into()))
                .from_step(2)
                .times(2),
        );
        let tx = plan.wrap(0, exec_tx);
        // steps 1..=5: ok, boom, boom, ok, ok
        let mut outcomes = Vec::new();
        for _ in 0..5 {
            let (rtx, rrx) = std::sync::mpsc::channel();
            tx.send(request(rtx)).unwrap();
            outcomes.push(rrx.recv().unwrap().y.is_ok());
        }
        assert_eq!(outcomes, vec![true, false, false, true, true]);
        drop(tx);
        assert_eq!(shard.join().unwrap(), 3, "faulted steps must not \
                                              reach the executor");
    }

    #[test]
    fn drop_loses_the_request_without_disconnecting() {
        let (exec_tx, exec_rx) = std::sync::mpsc::channel();
        let _shard = echo_shard(exec_rx);
        let plan = FaultPlan::new(1)
            .rule(FaultRule::on(0, FaultAction::Drop).times(1));
        let tx = plan.wrap(0, exec_tx);
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(request(rtx)).unwrap();
        // the request is gone but nothing disconnected: only a timeout
        // can see this (the client-side deadline's raison d'etre)
        assert!(rrx.recv_timeout(Duration::from_millis(20)).is_err());
        // the next request flows
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(request(rtx)).unwrap();
        assert!(rrx
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .y
            .is_ok());
    }

    #[test]
    fn kill_shard_crashes_the_inner_executor() {
        let (exec_tx, exec_rx) = std::sync::mpsc::channel();
        let shard = echo_shard(exec_rx);
        let plan = FaultPlan::new(3)
            .rule(FaultRule::on(0, FaultAction::KillShard).from_step(3));
        let tx = plan.wrap(0, exec_tx);
        for _ in 0..2 {
            let (rtx, rrx) = std::sync::mpsc::channel();
            tx.send(request(rtx)).unwrap();
            assert!(rrx.recv().unwrap().y.is_ok());
        }
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(request(rtx)).unwrap();
        // the executor saw Crash and returned after serving 2
        assert_eq!(shard.join().unwrap(), 2);
        // the killed step's request never got an answer
        assert!(rrx.recv().is_err());
    }

    #[test]
    fn stalled_requests_release_on_interposer_exit() {
        let (exec_tx, exec_rx) = std::sync::mpsc::channel();
        let _shard = echo_shard(exec_rx);
        let plan =
            FaultPlan::new(9).rule(FaultRule::on(0, FaultAction::Stall));
        let tx = plan.wrap(0, exec_tx);
        let (rtx, rrx) = std::sync::mpsc::channel();
        tx.send(request(rtx)).unwrap();
        assert!(rrx.recv_timeout(Duration::from_millis(20)).is_err(),
                "stalled request must not answer");
        drop(tx); // interposer exits, releasing the held request
        assert!(rrx.recv().is_err(), "release is a disconnect");
    }

    #[test]
    fn delay_defers_but_preserves_the_answer() {
        let (exec_tx, exec_rx) = std::sync::mpsc::channel();
        let _shard = echo_shard(exec_rx);
        let plan = FaultPlan::new(5).rule(
            FaultRule::on(0, FaultAction::Delay(
                Duration::from_millis(30),
            ))
            .times(1),
        );
        let tx = plan.wrap(0, exec_tx);
        let (rtx, rrx) = std::sync::mpsc::channel();
        let t0 = std::time::Instant::now();
        tx.send(request(rtx)).unwrap();
        let resp = rrx.recv_timeout(Duration::from_secs(5)).unwrap();
        assert!(resp.y.is_ok());
        assert!(t0.elapsed() >= Duration::from_millis(30));
    }

    #[test]
    fn probabilistic_rules_are_seed_deterministic() {
        let fire_pattern = |seed: u64| -> Vec<bool> {
            let (exec_tx, exec_rx) = std::sync::mpsc::channel();
            let _shard = echo_shard(exec_rx);
            let plan = FaultPlan::new(seed).rule(
                FaultRule::on(0, FaultAction::ErrorResponse("p".into()))
                    .with_probability(0.5),
            );
            let tx = plan.wrap(0, exec_tx);
            (0..32)
                .map(|_| {
                    let (rtx, rrx) = std::sync::mpsc::channel();
                    tx.send(request(rtx)).unwrap();
                    rrx.recv().unwrap().y.is_err()
                })
                .collect()
        };
        let a = fire_pattern(42);
        assert_eq!(a, fire_pattern(42), "same seed, same faults");
        assert_ne!(a, fire_pattern(43), "different seed, different \
                                         faults");
        let fired = a.iter().filter(|&&b| b).count();
        assert!(fired > 4 && fired < 28,
                "p=0.5 should fire sometimes, not always ({fired}/32)");
    }

    #[test]
    fn flood_saturates_the_shared_meter_and_drains_on_exit() {
        let (exec_tx, exec_rx) = std::sync::mpsc::channel();
        let _shard = echo_shard(exec_rx);
        let inner = Arc::new(ShardEndpoint::new(exec_tx));
        inner.meter().set_high_water(4);
        let plan = FaultPlan::new(11)
            .rule(FaultRule::on(0, FaultAction::Flood(8)).times(1));
        let wrapped = plan.wrap_endpoint(0, inner.clone());
        assert!(Arc::ptr_eq(wrapped.meter(), inner.meter()),
                "interposition shares the inner meter");
        let (rtx, rrx) = std::sync::mpsc::channel();
        wrapped.sender().send(request(rtx)).unwrap();
        // the triggering request still flows …
        assert!(rrx
            .recv_timeout(Duration::from_secs(5))
            .unwrap()
            .y
            .is_ok());
        // … but 8 phantoms now sit over the 4-entry mark
        assert!(inner.meter().saturated());
        assert_eq!(inner.meter().depth(), 8);
        drop(wrapped); // interposer exits, draining its phantoms
        let t0 = std::time::Instant::now();
        while inner.meter().depth() != 0
            && t0.elapsed() < Duration::from_secs(5)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(inner.meter().depth(), 0,
                   "flood phantoms drain on interposer exit");
    }

    #[test]
    fn swallowed_requests_release_their_ingress_slot() {
        let (exec_tx, exec_rx) = std::sync::mpsc::channel();
        let _shard = echo_shard(exec_rx);
        let inner = Arc::new(ShardEndpoint::new(exec_tx));
        let plan = FaultPlan::new(2).rule(
            FaultRule::on(0, FaultAction::ErrorResponse("boom".into())));
        let wrapped = plan.wrap_endpoint(0, inner.clone());
        // what dispatch() does: admit, then send
        wrapped.meter().try_admit().unwrap();
        let (rtx, rrx) = std::sync::mpsc::channel();
        wrapped.sender().send(request(rtx)).unwrap();
        assert!(rrx.recv_timeout(Duration::from_secs(5))
            .unwrap().y.is_err());
        // the executor never saw the request, so the interposer must
        // have released the admitted slot
        let t0 = std::time::Instant::now();
        while wrapped.meter().depth() != 0
            && t0.elapsed() < Duration::from_secs(5)
        {
            std::thread::sleep(Duration::from_millis(1));
        }
        assert_eq!(wrapped.meter().depth(), 0);
    }

    #[test]
    fn unmatched_shards_keep_the_direct_endpoint() {
        let (exec_tx, _exec_rx) = std::sync::mpsc::channel();
        let inner = Arc::new(ShardEndpoint::new(exec_tx));
        let plan = FaultPlan::new(1)
            .rule(FaultRule::on(3, FaultAction::Drop));
        let wrapped = plan.wrap_endpoint(0, inner.clone());
        assert!(Arc::ptr_eq(&inner, &wrapped),
                "no rule for shard 0 → no interposer");
    }
}
