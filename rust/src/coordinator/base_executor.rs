//! The shard executor: one thread serving a contiguous slice of frozen
//! base-model layers to many clients.
//!
//! Each thread owns a [`ShardWeights`] slice (a contiguous `LayerId`
//! block range plus the boundary layers), a PJRT engine handle, its own
//! [`BatchPolicy`] queues, and a simulated [`Device`] whose memory
//! ledger is charged with the shard's real resident bytes.  A fleet of
//! these (see [`crate::coordinator::fleet`]) is the executable form of
//! the paper's FSDP-style sharded base (section 3.3); the single-shard
//! fleet is exactly the old `BaseExecutor`.
//!
//! Incoming [`LayerRequest`]s are queued per (layer, direction); the
//! [`BatchPolicy`] decides how long to wait for co-batchable requests.
//! At flush time the queued activations are **token-flattened** into a
//! single `(sum T_i, Din)` batch (no per-request padding — only the tail
//! pad up to the artifact's token bucket), executed once, and scattered
//! back to the per-request response channels (paper sections 3.2, 3.7).
//! A failed flush answers every request with a typed error instead of
//! dropping the senders.
//!
//! The flush path is zero-copy end to end: batch assembly is a single
//! pass into a reusable per-`(layer, op)` scratch buffer (reclaimed
//! after every execute via `Tensor::try_into_f32_vec`), the frozen
//! weights ride to the engine as `Arc` views, and the scatter returns
//! each client a zero-copy row view of the one batched output.
//!
//! The executor is stateless across iterations: the memory-optimized
//! backward (`dX = dY . W^T`, section 3.6) means no forward activation is
//! ever stored here, which is what keeps its memory footprint flat in
//! Figs. 9/10.
//!
//! # Overload: ingress metering and urgency-based shedding
//!
//! The executor decrements the shard's shared
//! [`IngressMeter`](crate::coordinator::virt_layer::IngressMeter) for
//! every dequeued request (dispatch incremented it), which is what makes
//! the fleet's high-water mark a real queue bound.  When the meter
//! stands at its mark, a flush whose every request is
//! [`Urgency::Background`] is **shed**: each co-batched request is
//! answered with a [`SHED_MARKER`]-prefixed error (clients surface it as
//! the typed, non-retried `WorkShed`) and the device executes nothing —
//! interactive decode rides out the brown-out at full speed while
//! deferrable work yields.

// Fault-domain hot path: locks recover from poison explicitly, map
// lookups carry their invariants as expect messages.
#![deny(clippy::unwrap_used)]

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::config::{bucket_for, TOKEN_BUCKETS};
use crate::coordinator::batching::BatchPolicy;
use crate::coordinator::fleet::FleetBarrier;
use crate::coordinator::model_state::ShardWeights;
use crate::coordinator::proto::{ExecMsg, LayerId, LayerRequest,
                                LayerResponse, OpKind, Urgency,
                                SHED_MARKER};
use crate::coordinator::virt_layer::IngressMeter;
use crate::device::Device;
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// One executed flush (for Table 5 / Fig 7 reproduction).
#[derive(Debug, Clone)]
pub struct FlushRecord {
    pub layer: LayerId,
    pub op: OpKind,
    pub n_requests: usize,
    pub n_clients: usize,
    pub real_tokens: usize,
    pub bucket_tokens: usize,
    pub mean_wait_secs: f64,
}

/// How many recent [`FlushRecord`]s each shard retains.  Aggregates
/// (`mean_batch_clients`, `padding_overhead`, …) are running sums over
/// *all* flushes and stay exact; only the per-record detail is bounded,
/// so executor memory does not grow with traffic.
pub const FLUSH_RECORD_CAP: usize = 1024;

/// Accumulating statistics held by a shard thread: bounded ring of
/// recent records + exact running aggregates.
#[derive(Debug, Default)]
struct StatsInner {
    recent: VecDeque<FlushRecord>,
    n_flushes: u64,
    sum_batch_clients: f64,
    sum_wait_secs: f64,
    real_tokens: u64,
    bucket_tokens: u64,
    requests_served: u64,
    requests_shed: u64,
    noise_registrations: u64,
    busy_secs: f64,
    idle_secs: f64,
    heartbeats: u64,
}

impl StatsInner {
    fn record(&mut self, rec: FlushRecord) {
        self.n_flushes += 1;
        self.sum_batch_clients += rec.n_clients as f64;
        self.sum_wait_secs += rec.mean_wait_secs;
        self.real_tokens += rec.real_tokens as u64;
        self.bucket_tokens += rec.bucket_tokens as u64;
        if self.recent.len() == FLUSH_RECORD_CAP {
            self.recent.pop_front();
        }
        self.recent.push_back(rec);
    }

    fn snapshot(&self) -> ExecutorStats {
        ExecutorStats {
            flushes: self.recent.iter().cloned().collect(),
            n_flushes: self.n_flushes,
            sum_batch_clients: self.sum_batch_clients,
            sum_wait_secs: self.sum_wait_secs,
            real_tokens: self.real_tokens,
            bucket_tokens: self.bucket_tokens,
            requests_served: self.requests_served,
            requests_shed: self.requests_shed,
            noise_registrations: self.noise_registrations,
            busy_secs: self.busy_secs,
            idle_secs: self.idle_secs,
            heartbeats: self.heartbeats,
        }
    }
}

/// Snapshot of one shard's statistics.  `flushes` holds at most
/// [`FLUSH_RECORD_CAP`] *recent* records; the aggregate accessors are
/// exact over the shard's whole lifetime.  Fleet-level aggregation
/// lives in [`crate::coordinator::fleet::FleetStats`], which folds one
/// of these per shard via [`ExecutorStats::absorb`] — the merged view's
/// `flushes` ring stays bounded at `FLUSH_RECORD_CAP` (later shards'
/// records win; not globally time-ordered), so use
/// `FleetStats::per_shard` when ring recency matters.
#[derive(Debug, Default, Clone)]
pub struct ExecutorStats {
    /// Most recent flush records (bounded ring).
    pub flushes: Vec<FlushRecord>,
    /// Total flushes ever executed (may exceed `flushes.len()`).
    pub n_flushes: u64,
    pub sum_batch_clients: f64,
    pub sum_wait_secs: f64,
    pub real_tokens: u64,
    pub bucket_tokens: u64,
    pub requests_served: u64,
    /// Background requests answered by the load shedder instead of the
    /// device (saturation brown-outs).
    pub requests_shed: u64,
    pub noise_registrations: u64,
    /// Wall seconds this shard spent executing flushes.
    pub busy_secs: f64,
    /// Wall seconds this shard spent parked on its channel with nothing
    /// to do.  `busy / (busy + idle)` is the shard's occupancy — the
    /// pipeline bench reports it to show micro-batching keeping every
    /// stage fed.
    pub idle_secs: f64,
    /// Run-loop iterations completed — the liveness signal the fleet
    /// watchdog reads: a shard whose heartbeat stops advancing while
    /// its thread is still joined is stalled, not idle (an idle shard
    /// heartbeats every channel-timeout tick).
    pub heartbeats: u64,
}

impl ExecutorStats {
    /// Mean co-batched clients per flush (Table 5 "Average Batch Size"),
    /// exact over all flushes.
    pub fn mean_batch_clients(&self) -> f64 {
        if self.n_flushes == 0 {
            return 0.0;
        }
        self.sum_batch_clients / self.n_flushes as f64
    }

    /// Mean queue wait across flushes (Fig 7), exact over all flushes.
    pub fn mean_wait_secs(&self) -> f64 {
        if self.n_flushes == 0 {
            return 0.0;
        }
        self.sum_wait_secs / self.n_flushes as f64
    }

    /// Fraction of executed token rows that were bucket padding, exact
    /// over all flushes.
    pub fn padding_overhead(&self) -> f64 {
        if self.bucket_tokens == 0 {
            0.0
        } else {
            1.0 - self.real_tokens as f64 / self.bucket_tokens as f64
        }
    }

    /// Fraction of observed wall time this shard spent executing rather
    /// than idling on its channel (pipeline occupancy).
    pub fn occupancy(&self) -> f64 {
        let total = self.busy_secs + self.idle_secs;
        if total <= 0.0 {
            0.0
        } else {
            self.busy_secs / total
        }
    }

    /// Fold a retired executor generation's statistics into this
    /// snapshot: aggregates sum exactly; the bounded flush ring keeps
    /// the *most recent* [`FLUSH_RECORD_CAP`] records across both
    /// generations (`other` is the newer one).
    pub fn absorb(&mut self, other: &ExecutorStats) {
        self.flushes.extend(other.flushes.iter().cloned());
        if self.flushes.len() > FLUSH_RECORD_CAP {
            let drop_n = self.flushes.len() - FLUSH_RECORD_CAP;
            self.flushes.drain(..drop_n);
        }
        self.n_flushes += other.n_flushes;
        self.sum_batch_clients += other.sum_batch_clients;
        self.sum_wait_secs += other.sum_wait_secs;
        self.real_tokens += other.real_tokens;
        self.bucket_tokens += other.bucket_tokens;
        self.requests_served += other.requests_served;
        self.requests_shed += other.requests_shed;
        self.noise_registrations += other.noise_registrations;
        self.busy_secs += other.busy_secs;
        self.idle_secs += other.idle_secs;
        self.heartbeats += other.heartbeats;
    }
}

/// A pending batch for one (layer, op).  Token count and the distinct
/// client set are maintained incrementally on enqueue, so ready-checks
/// and overflow tests never re-scan `reqs`.
struct Pending {
    reqs: Vec<(LayerRequest, Instant)>,
    deadline: Instant,
    /// Whether any queued request is latency-sensitive (decode): such
    /// batches flush as soon as the executor would otherwise idle.
    has_interactive: bool,
    /// Whether *every* queued request is `Urgency::Background` — only
    /// such batches are sheddable: co-batching with even one
    /// non-background request buys the batch an execution.
    all_background: bool,
    /// Running sum of queued token rows.
    tokens: usize,
    /// Distinct client ids in arrival order (small; linear scan).
    clients: Vec<usize>,
}

impl Pending {
    fn new(deadline: Instant) -> Self {
        Pending {
            reqs: Vec::new(),
            deadline,
            has_interactive: false,
            all_background: true,
            tokens: 0,
            clients: Vec::new(),
        }
    }

    fn push(&mut self, req: LayerRequest, at: Instant) {
        self.tokens += req.x.shape[0];
        self.all_background &= req.urgency == Urgency::Background;
        if !self.clients.contains(&req.client_id) {
            self.clients.push(req.client_id);
        }
        self.reqs.push((req, at));
    }

    fn distinct_clients(&self) -> usize {
        self.clients.len()
    }

    fn total_tokens(&self) -> usize {
        self.tokens
    }
}

/// Reusable per-(layer, op) batch-assembly buffers.
type ScratchMap = HashMap<(LayerId, OpKind), Vec<f32>>;

/// Handle to one running shard-executor thread.  Owned by the
/// [`crate::coordinator::fleet::ExecutorFleet`]; a fleet of one is the
/// old single `BaseExecutor`.
pub struct ShardExecutor {
    shard: usize,
    tx: Sender<ExecMsg>,
    handle: Option<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
    /// Simulated device hosting this shard; its ledger was charged with
    /// the resident slice before spawn (see `fleet::charge_shard`) and
    /// is only read afterwards.
    device: Device,
}

impl ShardExecutor {
    /// Spawn one shard thread over its weight slice.  `device` must
    /// already carry the resident-slice charge (the fleet performs the
    /// OOM-enforced charge so planning failures surface before any
    /// thread starts).  `barrier` is the fleet-shared registration
    /// count, maintained synchronously by the *clients*
    /// (`VirtLayerCtx::register`/`deregister`);
    /// `BatchPolicy::LockstepFleet` barriers read it instead of the
    /// shard-local count.
    pub fn spawn(engine: Arc<Engine>, weights: ShardWeights,
                 policy: BatchPolicy, device: Device,
                 barrier: Arc<FleetBarrier>,
                 meter: Arc<IngressMeter>) -> ShardExecutor {
        Self::spawn_with_registered(engine, weights, policy, device,
                                    barrier, 0, meter)
    }

    /// [`Self::spawn`] with a non-zero initial shard-local registration
    /// count — the respawn path: clients registered with the *previous*
    /// executor generation never re-send `Register`, so the replacement
    /// seeds its local count from the fleet barrier instead of starting
    /// at zero (which would break per-shard `Lockstep` flushing).
    /// `meter` is the shard's *stable* ingress meter (shared with the
    /// routing endpoint): the executor decrements it per dequeued
    /// request and consults it for the shed decision.
    pub fn spawn_with_registered(engine: Arc<Engine>,
                                 weights: ShardWeights,
                                 policy: BatchPolicy, device: Device,
                                 barrier: Arc<FleetBarrier>,
                                 initial_registered: usize,
                                 meter: Arc<IngressMeter>)
                                 -> ShardExecutor {
        let shard = weights.shard;
        let (tx, rx) = channel();
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let stats2 = stats.clone();
        let handle = std::thread::Builder::new()
            .name(format!("shard-exec-{shard}"))
            .spawn(move || {
                run_loop(engine, weights, policy, rx, stats2, barrier,
                         initial_registered, meter)
            })
            .expect("spawn shard executor");
        ShardExecutor {
            shard,
            tx,
            handle: Some(handle),
            stats,
            device,
        }
    }

    pub fn shard(&self) -> usize {
        self.shard
    }

    /// Channel used by clients' routed `VirtLayer` proxies.
    pub fn sender(&self) -> Sender<ExecMsg> {
        self.tx.clone()
    }

    /// Snapshot of this shard's accumulated statistics.
    pub fn stats(&self) -> ExecutorStats {
        self.stats
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .snapshot()
    }

    /// Whether the executor thread is still running.  `false` means the
    /// thread returned — crashed (see [`ExecMsg::Crash`]), panicked, or
    /// shut down — and the shard needs a respawn to serve again.  The
    /// fleet watchdog polls this.
    pub fn is_alive(&self) -> bool {
        self.handle.as_ref().is_some_and(|h| !h.is_finished())
    }

    /// Bytes currently charged to this shard's device ledger (the
    /// resident base slice).
    pub fn resident_bytes(&self) -> u64 {
        self.device.ledger.used()
    }

    /// Capacity of the simulated device hosting this shard.
    pub fn device_capacity(&self) -> u64 {
        self.device.ledger.capacity()
    }

    /// Stop the shard and join its thread, draining pending batches.
    pub fn shutdown(mut self) -> ExecutorStats {
        let _ = self.tx.send(ExecMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        self.stats
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .snapshot()
    }
}

impl Drop for ShardExecutor {
    fn drop(&mut self) {
        let _ = self.tx.send(ExecMsg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn run_loop(engine: Arc<Engine>, base: ShardWeights, policy: BatchPolicy,
            rx: Receiver<ExecMsg>, stats: Arc<Mutex<StatsInner>>,
            barrier: Arc<FleetBarrier>, initial_registered: usize,
            meter: Arc<IngressMeter>) {
    let mut pending: HashMap<(LayerId, OpKind), Pending> = HashMap::new();
    let mut scratch: ScratchMap = HashMap::new();
    let mut registered: usize = initial_registered;
    loop {
        // Liveness heartbeat: advances every iteration, including pure
        // channel-timeout ticks — a stalled shard stops heartbeating,
        // an idle one does not.
        stats.lock().unwrap_or_else(|p| p.into_inner()).heartbeats += 1;
        // Earliest deadline among pending batches bounds the wait.
        let now = Instant::now();
        let next_deadline = pending.values().map(|p| p.deadline).min();
        let timeout = match next_deadline {
            Some(d) if d <= now => Duration::ZERO,
            Some(d) => d - now,
            None => Duration::from_millis(20),
        };
        // Channel wait is the shard's idle time (a queued message makes
        // this ~zero); flush time below is its busy time — the ratio is
        // the occupancy the pipeline bench reports.
        let wait_t0 = Instant::now();
        let recv = rx.recv_timeout(timeout);
        stats.lock().unwrap_or_else(|p| p.into_inner()).idle_secs +=
            wait_t0.elapsed().as_secs_f64();
        let first = match recv {
            Ok(m) => Some(m),
            Err(RecvTimeoutError::Timeout) => None,
            Err(RecvTimeoutError::Disconnected) => {
                for (key, p) in pending.drain() {
                    flush(&engine, &base, p, key, &stats, &mut scratch,
                          &meter);
                }
                return;
            }
        };
        // Greedy drain: while the executor was busy (or sleeping),
        // more requests may have queued — fold them all in before
        // deciding what to flush.  This is what makes batching happen
        // "naturally" under load without per-request waits.
        let mut shutdown = false;
        let mut msgs: Vec<ExecMsg> = first.into_iter().collect();
        while let Ok(m) = rx.try_recv() {
            msgs.push(m);
        }
        for msg in msgs {
            match msg {
                // The fleet-global barrier is NOT maintained here: the
                // client bumps it synchronously in
                // `VirtLayerCtx::register`/`deregister`, so no shard
                // can read a count that lags a client whose requests
                // are already queued.  Shards only maintain their
                // local count (per-shard `Lockstep`).
                ExecMsg::Register { .. } => registered += 1,
                ExecMsg::Deregister { .. } => {
                    registered = registered.saturating_sub(1);
                }
                ExecMsg::RegisterNoise { layer, noise, resp } => {
                    // Bias-free linear flow: n_eff = W . n (section 3.8).
                    let out = noise_effect(&engine, &base, layer, &noise);
                    stats
                        .lock()
                        .unwrap_or_else(|p| p.into_inner())
                        .noise_registrations += 1;
                    let _ = resp.send(LayerResponse {
                        y: out.map_err(|e| format!("{e:#}")),
                        queue_wait_secs: 0.0,
                        batch_clients: 1,
                    });
                }
                ExecMsg::Request(req) => {
                    // Dequeued: the dispatch-side ingress reservation is
                    // released here, making the high-water mark a bound
                    // on *queued* (not in-service) requests.
                    meter.exit();
                    enqueue(&engine, &base, &policy, &stats, &mut pending,
                            &mut scratch, &meter, req);
                }
                ExecMsg::Shutdown => shutdown = true,
                // Simulated hard crash: return *without* draining —
                // queued requests drop their response senders exactly
                // as a panicking thread would drop them.  The fleet
                // watchdog sees the finished join handle and respawns.
                ExecMsg::Crash => return,
            }
        }
        // Flush pass: barrier-ready or expired batches always go; once
        // the channel is drained dry the device would idle, so under
        // non-lockstep policies every pending batch goes — batching
        // happens "naturally" from requests that arrived while the
        // device was busy, never from waiting on an idle device
        // (EXPERIMENTS.md §Perf iterations 1 and 4).
        let idle = true; // channel fully drained above
        // Fleet-wide lockstep counts against the shared global
        // registration count, per-shard lockstep against the local one.
        let barrier_count = match policy {
            BatchPolicy::LockstepFleet => barrier.registered(),
            _ => registered,
        };
        let now = Instant::now();
        let due: Vec<(LayerId, OpKind)> = pending
            .iter()
            .filter(|(_, p)| {
                policy.ready(p.distinct_clients(), barrier_count)
                    || p.deadline <= now
                    || (idle && !policy.is_lockstep())
            })
            .map(|(k, _)| *k)
            .collect();
        for key in due {
            let p = pending
                .remove(&key)
                .expect("due keys were just drawn from pending");
            flush(&engine, &base, p, key, &stats, &mut scratch, &meter);
        }
        if shutdown {
            for (key, p) in pending.drain() {
                flush(&engine, &base, p, key, &stats, &mut scratch,
                      &meter);
            }
            return;
        }
    }
}

/// Queue one request, flushing early if the batch would overflow the
/// largest token bucket.
#[allow(clippy::too_many_arguments)]
fn enqueue(engine: &Engine, base: &ShardWeights, policy: &BatchPolicy,
           stats: &Arc<Mutex<StatsInner>>,
           pending: &mut HashMap<(LayerId, OpKind), Pending>,
           scratch: &mut ScratchMap, meter: &IngressMeter,
           req: LayerRequest) {
    let key = (req.layer, req.op);
    let budget = policy.wait_budget(req.urgency);
    let now = Instant::now();
    let interactive = req.urgency == Urgency::Interactive;
    let max_bucket = *TOKEN_BUCKETS
        .last()
        .expect("TOKEN_BUCKETS is a non-empty static");
    let overflows = {
        let p = pending
            .entry(key)
            .or_insert_with(|| Pending::new(now + budget));
        // A latency-sensitive request tightens the deadline of the batch
        // it joins.
        p.deadline = p.deadline.min(now + budget);
        p.has_interactive |= interactive;
        p.total_tokens() + req.x.shape[0] > max_bucket
    };
    if overflows {
        let full = pending
            .remove(&key)
            .expect("entry was just inserted above");
        flush(engine, base, full, key, stats, scratch, meter);
        let mut fresh = Pending::new(now + budget);
        fresh.has_interactive = interactive;
        fresh.push(req, now);
        pending.insert(key, fresh);
    } else {
        pending
            .get_mut(&key)
            .expect("entry was just inserted above")
            .push(req, now);
    }
}

/// Execute one batched flush and scatter the outputs — or, on failure,
/// answer every co-batched request with the typed error message so
/// clients surface `SymbiosisError::ExecutorFailed` instead of a
/// channel disconnect.
fn flush(engine: &Engine, base: &ShardWeights, p: Pending,
         key: (LayerId, OpKind), stats: &Arc<Mutex<StatsInner>>,
         scratch: &mut ScratchMap, meter: &IngressMeter) {
    if p.reqs.is_empty() {
        return;
    }
    // Urgency-based shedding: under saturation an all-background batch
    // yields the device instead of executing — each request is answered
    // with the typed shed marker (clients see `WorkShed`, deferred, not
    // retried), so interactive decode proceeds through the brown-out.
    if p.all_background && meter.saturated() {
        let n = p.reqs.len();
        for (req, _) in p.reqs {
            let _ = req.resp.send(LayerResponse {
                y: Err(format!(
                    "{SHED_MARKER}shard {} shed background work under \
                     ingress saturation",
                    base.shard
                )),
                queue_wait_secs: 0.0,
                batch_clients: n,
            });
        }
        stats
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .requests_shed += n as u64;
        return;
    }
    let flush_start = Instant::now();
    let waits: Vec<f64> = p
        .reqs
        .iter()
        .map(|(_, t)| flush_start.duration_since(*t).as_secs_f64())
        .collect();
    let n_clients = p.distinct_clients();
    let n_requests = p.reqs.len();
    let high = p.has_interactive; // decode batches jump the device queue
    let (layer, op) = key;
    match execute_batch(engine, base, layer, op, &p.reqs, high, scratch) {
        Ok((outputs, real_tokens, bucket_tokens)) => {
            let mean_wait =
                waits.iter().sum::<f64>() / waits.len() as f64;
            for (((req, _), out), wait) in
                p.reqs.into_iter().zip(outputs).zip(waits)
            {
                let _ = req.resp.send(LayerResponse {
                    y: Ok(out),
                    queue_wait_secs: wait,
                    batch_clients: n_clients,
                });
            }
            let mut s = stats.lock().unwrap_or_else(|p| p.into_inner());
            s.requests_served += n_requests as u64;
            s.busy_secs += flush_start.elapsed().as_secs_f64();
            s.record(FlushRecord {
                layer,
                op,
                n_requests,
                n_clients,
                real_tokens,
                bucket_tokens,
                mean_wait_secs: mean_wait,
            });
        }
        Err(e) => {
            let msg = format!("{e:#}");
            eprintln!("shard-executor {}: flush {layer:?}/{op:?} \
                       failed: {msg}", base.shard);
            for ((req, _), wait) in p.reqs.into_iter().zip(waits) {
                let _ = req.resp.send(LayerResponse {
                    y: Err(msg.clone()),
                    queue_wait_secs: wait,
                    batch_clients: n_clients,
                });
            }
            stats
                .lock()
                .unwrap_or_else(|p| p.into_inner())
                .busy_secs += flush_start.elapsed().as_secs_f64();
        }
    }
}

/// Token-flatten + pad in one pass, execute the right artifact, scatter
/// zero-copy views.  The assembly buffer is recycled through `scratch`.
fn execute_batch(engine: &Engine, base: &ShardWeights, layer: LayerId,
                 op: OpKind, reqs: &[(LayerRequest, Instant)], high: bool,
                 scratch: &mut ScratchMap)
                 -> Result<(Vec<Tensor>, usize, usize)> {
    let real_tokens: usize =
        reqs.iter().map(|(r, _)| r.x.shape[0]).sum();
    let bucket = bucket_for(real_tokens, TOKEN_BUCKETS)
        .ok_or_else(|| anyhow::anyhow!(
            "{real_tokens} tokens exceed the largest bucket"))?;

    let outputs = match layer {
        LayerId::Embed => {
            if op == OpKind::Backward {
                bail!("embedding has no backward (frozen, below adapters)");
            }
            let (embed, pos_tab) = base.embed_tables()?;
            // 1-D i32 concat of token ids and positions.
            let mut toks = Vec::with_capacity(bucket);
            let mut poss = Vec::with_capacity(bucket);
            for (r, _) in reqs {
                toks.extend_from_slice(r.x.as_i32());
                let pos = r
                    .positions
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("embed w/o positions"))?;
                poss.extend_from_slice(pos.as_i32());
            }
            toks.resize(bucket, 0);
            poss.resize(bucket, 0);
            let name = format!("embed_t{bucket}_v{}_d{}",
                               base.cfg.vocab, base.cfg.d_model);
            let toks = Tensor::from_i32(toks, &[bucket]);
            let poss = Tensor::from_i32(poss, &[bucket]);
            let out = engine.execute_prio(
                &name, &[&toks, &poss, embed, pos_tab], high)?;
            split_rows(&out[0], reqs)
        }
        _ => {
            let (w, b) = base.linear(layer)
                .context("shard routing mismatch")?;
            let (din, dout) = (w.shape[0], w.shape[1]);
            // Token-flattened concat — the paper's no-padding batching:
            // requests of different lengths stack directly.  Assembly +
            // bucket pad happen in one pass into the recycled scratch
            // buffer; the weights go to the engine as shared views.
            let parts: Vec<&Tensor> =
                reqs.iter().map(|(r, _)| &r.x).collect();
            let buf = scratch.remove(&(layer, op)).unwrap_or_default();
            let x = Tensor::assemble_rows(buf, &parts, bucket);
            let out = match op {
                OpKind::Forward => engine.execute_prio(
                    &format!("linear_fwd_t{bucket}_{din}x{dout}"),
                    &[&x, w, b], high),
                // dX = dY . W^T from parameters only (section 3.6).
                OpKind::Backward => engine.execute_prio(
                    &format!("linear_bwd_t{bucket}_{din}x{dout}"),
                    &[&x, w], high),
            };
            // The engine dropped its share of `x` before responding, so
            // the assembly buffer can be reclaimed for the next flush.
            if let Some(v) = x.try_into_f32_vec() {
                scratch.insert((layer, op), v);
            }
            split_rows(&out?[0], reqs)
        }
    };
    Ok((outputs, real_tokens, bucket))
}

/// Scatter the batched output back into per-request tensors — zero-copy
/// row views of the one batched buffer (the bucket padding tail is
/// simply never viewed).
fn split_rows(batched: &Tensor, reqs: &[(LayerRequest, Instant)])
              -> Vec<Tensor> {
    let mut outs = Vec::with_capacity(reqs.len());
    let mut row = 0;
    for (r, _) in reqs {
        let t = r.x.shape[0];
        outs.push(batched.slice_rows(row, row + t));
        row += t;
    }
    outs
}

/// Privacy support: `n_eff = W . n` via the bias-free execution flow.
fn noise_effect(engine: &Engine, base: &ShardWeights, layer: LayerId,
                noise: &Tensor) -> Result<Tensor> {
    if layer == LayerId::Embed {
        bail!("noise protocol applies to linear layers only");
    }
    let (w, _) = base.linear(layer)?;
    let (din, dout) = (w.shape[0], w.shape[1]);
    let t = noise.shape[0];
    let bucket = bucket_for(t, TOKEN_BUCKETS)
        .ok_or_else(|| anyhow::anyhow!("noise too large"))?;
    let x = noise.pad_rows(bucket);
    let zero_bias = Tensor::zeros(&[dout]);
    let name = format!("linear_fwd_t{bucket}_{din}x{dout}");
    let out = engine.execute(&name, &[&x, w, &zero_bias])?;
    Ok(out[0].slice_rows(0, t))
}
