//! Admission control — per-tenant quotas over the shared fleet.
//!
//! The paper's economics are dense multi-tenancy (20 adapters sharing
//! one Gemma2-27B base); the failure mode of dense multi-tenancy is one
//! tenant starving the rest.  The [`AdmissionController`] (owned by
//! `ExecutorFleet`) tracks a [`TenantState`] per named tenant and
//! enforces three quotas, each optional and unlimited by default:
//!
//! * **concurrent sessions** — checked by `SessionBuilder::build` /
//!   `TrainerBuilder::build`; a denied build fails fast with a typed
//!   [`SymbiosisError::AdmissionDenied`] naming the tenant, before any
//!   executor state is touched.
//! * **in-flight layer requests** — checked by `VirtLayerCtx::dispatch`;
//!   exceeding it is [`SymbiosisError::QuotaExceeded`].  Released when
//!   the request is collected or abandoned (RAII [`InFlightGuard`]).
//! * **KV-cache bytes** — charged by `KvCache::append` *before* the
//!   block pool touches the device ledger, so a tenant hits its own
//!   budget with `QuotaExceeded` before it can push a co-tenant into
//!   `KvCacheOom`.
//! * **training-state bytes** — charged by the trainer's `opt:`/`act:`
//!   ledger writes *before* the device ledger, the same ordering as KV:
//!   a tenant exhausts its own training budget with `QuotaExceeded`
//!   before it can push a co-tenant into `TrainerOom`.
//!
//! Sessions that never name a tenant bypass admission entirely — the
//! controller costs nothing until quotas are configured, and every
//! pre-overload caller keeps its exact behavior.
//!
//! Counters are plain atomics updated via `fetch_update` (check and
//! reserve in one step), so admission never takes a lock on the
//! dispatch hot path; the controller's tenant map is only locked on
//! session build and quota configuration.

#![deny(clippy::unwrap_used)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{SymResult, SymbiosisError};

/// Per-tenant quota configuration.  `None` = unlimited (the default):
/// an unconfigured tenant is never denied anything.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TenantQuota {
    /// Max concurrently live sessions + trainers.
    pub max_sessions: Option<usize>,
    /// Max layer requests in flight at once (dispatched, not yet
    /// collected) across all of the tenant's clients.
    pub max_in_flight: Option<usize>,
    /// Max bytes of KV cache across all of the tenant's sessions.
    pub max_kv_bytes: Option<u64>,
    /// Max bytes of training state (optimizer moments + saved
    /// activations) across all of the tenant's trainers.
    pub max_train_bytes: Option<u64>,
}

impl TenantQuota {
    /// No limits — the behavior of a tenant nobody configured.
    pub fn unlimited() -> Self {
        TenantQuota::default()
    }

    pub fn max_sessions(mut self, n: usize) -> Self {
        self.max_sessions = Some(n);
        self
    }

    pub fn max_in_flight(mut self, n: usize) -> Self {
        self.max_in_flight = Some(n);
        self
    }

    pub fn max_kv_bytes(mut self, bytes: u64) -> Self {
        self.max_kv_bytes = Some(bytes);
        self
    }

    pub fn max_train_bytes(mut self, bytes: u64) -> Self {
        self.max_train_bytes = Some(bytes);
        self
    }
}

/// Live usage + limits of one tenant.  Shared (`Arc`) between the
/// admission controller, every `VirtLayerCtx` of the tenant's clients,
/// and the tenant's KV ledgers.  Limits are stored as atomics
/// (`usize::MAX`/`u64::MAX` = unlimited) so quota changes apply to live
/// tenants without locking the dispatch path.
pub struct TenantState {
    name: String,
    max_sessions: AtomicUsize,
    max_in_flight: AtomicUsize,
    max_kv_bytes: AtomicU64,
    max_train_bytes: AtomicU64,
    sessions: AtomicUsize,
    in_flight: AtomicUsize,
    kv_bytes: AtomicU64,
    train_bytes: AtomicU64,
}

impl TenantState {
    fn new(name: &str) -> Self {
        TenantState {
            name: name.to_string(),
            max_sessions: AtomicUsize::new(usize::MAX),
            max_in_flight: AtomicUsize::new(usize::MAX),
            max_kv_bytes: AtomicU64::new(u64::MAX),
            max_train_bytes: AtomicU64::new(u64::MAX),
            sessions: AtomicUsize::new(0),
            in_flight: AtomicUsize::new(0),
            kv_bytes: AtomicU64::new(0),
            train_bytes: AtomicU64::new(0),
        }
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    fn set_quota(&self, q: TenantQuota) {
        self.max_sessions
            .store(q.max_sessions.unwrap_or(usize::MAX), Ordering::SeqCst);
        self.max_in_flight
            .store(q.max_in_flight.unwrap_or(usize::MAX),
                   Ordering::SeqCst);
        self.max_kv_bytes
            .store(q.max_kv_bytes.unwrap_or(u64::MAX), Ordering::SeqCst);
        self.max_train_bytes
            .store(q.max_train_bytes.unwrap_or(u64::MAX), Ordering::SeqCst);
    }

    /// Admit one new session/trainer, or fail with a typed
    /// [`SymbiosisError::AdmissionDenied`].  The returned ticket holds
    /// the slot; dropping it (session/trainer teardown) releases it.
    pub fn admit_session(self: &Arc<Self>) -> SymResult<SessionTicket> {
        let limit = self.max_sessions.load(Ordering::SeqCst);
        match self.sessions.fetch_update(Ordering::SeqCst,
                                         Ordering::SeqCst, |cur| {
            if cur >= limit { None } else { Some(cur + 1) }
        }) {
            Ok(_) => Ok(SessionTicket { tenant: self.clone() }),
            Err(cur) => Err(SymbiosisError::AdmissionDenied {
                tenant: self.name.clone(),
                resource: "concurrent sessions",
                current: cur,
                limit,
            }),
        }
    }

    /// Reserve one in-flight request slot, or fail with a typed
    /// [`SymbiosisError::QuotaExceeded`].  Dropping the guard (collect
    /// finished, or the pending request abandoned) releases the slot.
    pub fn begin_request(self: &Arc<Self>) -> SymResult<InFlightGuard> {
        let limit = self.max_in_flight.load(Ordering::SeqCst);
        match self.in_flight.fetch_update(Ordering::SeqCst,
                                          Ordering::SeqCst, |cur| {
            if cur >= limit { None } else { Some(cur + 1) }
        }) {
            Ok(_) => Ok(InFlightGuard { tenant: self.clone() }),
            Err(cur) => Err(SymbiosisError::QuotaExceeded {
                tenant: self.name.clone(),
                resource: "in-flight layer requests",
                used: cur as u64,
                requested: 1,
                limit: limit as u64,
            }),
        }
    }

    /// Re-charge one KV allocation from `prev` to `next` bytes against
    /// the tenant budget (the ledger charges absolute totals per tag).
    /// Shrinking always succeeds; growth past the quota fails with a
    /// typed [`SymbiosisError::QuotaExceeded`] *without* mutating the
    /// count, so the caller never needs to roll this back.
    pub fn adjust_kv(&self, prev: u64, next: u64) -> SymResult<()> {
        let limit = self.max_kv_bytes.load(Ordering::SeqCst);
        match self.kv_bytes.fetch_update(Ordering::SeqCst,
                                         Ordering::SeqCst, |cur| {
            let total = cur.saturating_sub(prev).saturating_add(next);
            if next > prev && total > limit {
                None
            } else {
                Some(total)
            }
        }) {
            Ok(_) => Ok(()),
            Err(cur) => Err(SymbiosisError::QuotaExceeded {
                tenant: self.name.clone(),
                resource: "KV-cache bytes",
                used: cur.saturating_sub(prev),
                requested: next,
                limit,
            }),
        }
    }

    /// Return `bytes` of KV budget (ledger teardown).
    pub fn release_kv(&self, bytes: u64) {
        let _ = self.kv_bytes.fetch_update(Ordering::SeqCst,
                                           Ordering::SeqCst, |cur| {
            Some(cur.saturating_sub(bytes))
        });
    }

    /// Re-charge one training-state allocation from `prev` to `next`
    /// bytes against the tenant budget (trainers charge absolute totals
    /// per `opt:`/`act:` ledger tag, like KV).  Shrinking always
    /// succeeds; growth past the quota fails with a typed
    /// [`SymbiosisError::QuotaExceeded`] *without* mutating the count,
    /// so the caller never needs to roll this back.
    pub fn adjust_train(&self, prev: u64, next: u64) -> SymResult<()> {
        let limit = self.max_train_bytes.load(Ordering::SeqCst);
        match self.train_bytes.fetch_update(Ordering::SeqCst,
                                            Ordering::SeqCst, |cur| {
            let total = cur.saturating_sub(prev).saturating_add(next);
            if next > prev && total > limit {
                None
            } else {
                Some(total)
            }
        }) {
            Ok(_) => Ok(()),
            Err(cur) => Err(SymbiosisError::QuotaExceeded {
                tenant: self.name.clone(),
                resource: "training-state bytes",
                used: cur.saturating_sub(prev),
                requested: next,
                limit,
            }),
        }
    }

    /// Return `bytes` of training budget (trainer teardown).
    pub fn release_train(&self, bytes: u64) {
        let _ = self.train_bytes.fetch_update(Ordering::SeqCst,
                                              Ordering::SeqCst, |cur| {
            Some(cur.saturating_sub(bytes))
        });
    }

    /// Live sessions held by this tenant right now.
    pub fn sessions(&self) -> usize {
        self.sessions.load(Ordering::SeqCst)
    }

    /// Layer requests in flight right now.
    pub fn in_flight(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// KV bytes charged right now.
    pub fn kv_bytes(&self) -> u64 {
        self.kv_bytes.load(Ordering::SeqCst)
    }

    /// Training-state bytes charged right now.
    pub fn train_bytes(&self) -> u64 {
        self.train_bytes.load(Ordering::SeqCst)
    }
}

impl std::fmt::Debug for TenantState {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
           -> std::fmt::Result {
        f.debug_struct("TenantState")
            .field("name", &self.name)
            .field("sessions", &self.sessions())
            .field("in_flight", &self.in_flight())
            .field("kv_bytes", &self.kv_bytes())
            .field("train_bytes", &self.train_bytes())
            .finish_non_exhaustive()
    }
}

/// RAII slot in a tenant's concurrent-session quota.
pub struct SessionTicket {
    tenant: Arc<TenantState>,
}

impl Drop for SessionTicket {
    fn drop(&mut self) {
        let _ = self.tenant.sessions.fetch_update(Ordering::SeqCst,
                                                  Ordering::SeqCst,
                                                  |cur| {
            Some(cur.saturating_sub(1))
        });
    }
}

/// RAII slot in a tenant's in-flight request quota.
pub struct InFlightGuard {
    tenant: Arc<TenantState>,
}

impl Drop for InFlightGuard {
    fn drop(&mut self) {
        let _ = self.tenant.in_flight.fetch_update(Ordering::SeqCst,
                                                   Ordering::SeqCst,
                                                   |cur| {
            Some(cur.saturating_sub(1))
        });
    }
}

/// The fleet's tenant registry.  Quotas configure lazily: naming a
/// tenant on a builder creates its (unlimited) state on first use;
/// [`AdmissionController::set_quota`] installs or updates limits, live.
#[derive(Default)]
pub struct AdmissionController {
    tenants: Mutex<HashMap<String, Arc<TenantState>>>,
}

impl AdmissionController {
    pub fn new() -> Self {
        AdmissionController::default()
    }

    /// The tenant's shared state, created unlimited on first use.
    pub fn tenant(&self, name: &str) -> Arc<TenantState> {
        self.tenants
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(TenantState::new(name)))
            .clone()
    }

    /// Install or update a tenant's quota (applies to live clients —
    /// limits are read per admission check, not captured at build).
    pub fn set_quota(&self, name: &str, quota: TenantQuota) {
        self.tenant(name).set_quota(quota);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;

    #[test]
    fn unconfigured_tenant_is_never_denied() {
        let ctl = AdmissionController::new();
        let t = ctl.tenant("free");
        let _tickets: Vec<_> =
            (0..64).map(|_| t.admit_session().unwrap()).collect();
        let _guards: Vec<_> =
            (0..64).map(|_| t.begin_request().unwrap()).collect();
        t.adjust_kv(0, u64::MAX / 2).unwrap();
    }

    #[test]
    fn session_quota_denies_then_releases() {
        let ctl = AdmissionController::new();
        ctl.set_quota("acme", TenantQuota::unlimited().max_sessions(2));
        let t = ctl.tenant("acme");
        let a = t.admit_session().unwrap();
        let _b = t.admit_session().unwrap();
        let err = t.admit_session().unwrap_err();
        match err {
            SymbiosisError::AdmissionDenied {
                tenant,
                resource,
                current,
                limit,
            } => {
                assert_eq!(tenant, "acme");
                assert_eq!(resource, "concurrent sessions");
                assert_eq!(current, 2);
                assert_eq!(limit, 2);
            }
            other => panic!("expected AdmissionDenied, got {other}"),
        }
        drop(a); // ticket drop frees the slot
        let _c = t.admit_session().unwrap();
        assert_eq!(t.sessions(), 2);
    }

    #[test]
    fn in_flight_quota_is_raii() {
        let ctl = AdmissionController::new();
        ctl.set_quota("acme", TenantQuota::unlimited().max_in_flight(1));
        let t = ctl.tenant("acme");
        let g = t.begin_request().unwrap();
        assert!(matches!(t.begin_request().unwrap_err(),
                         SymbiosisError::QuotaExceeded {
                             resource: "in-flight layer requests",
                             ..
                         }));
        drop(g);
        assert_eq!(t.in_flight(), 0);
        let _g2 = t.begin_request().unwrap();
    }

    #[test]
    fn kv_quota_charges_absolute_and_shrinks_freely() {
        let ctl = AdmissionController::new();
        ctl.set_quota("acme", TenantQuota::unlimited().max_kv_bytes(1000));
        let t = ctl.tenant("acme");
        t.adjust_kv(0, 600).unwrap();
        t.adjust_kv(0, 300).unwrap(); // a second cache
        assert_eq!(t.kv_bytes(), 900);
        // growing the first cache past the budget fails, count untouched
        let err = t.adjust_kv(600, 800).unwrap_err();
        match err {
            SymbiosisError::QuotaExceeded {
                resource,
                used,
                requested,
                limit,
                ..
            } => {
                assert_eq!(resource, "KV-cache bytes");
                assert_eq!(used, 300);
                assert_eq!(requested, 800);
                assert_eq!(limit, 1000);
            }
            other => panic!("expected QuotaExceeded, got {other}"),
        }
        assert_eq!(t.kv_bytes(), 900);
        // shrinking is always admitted, even at the limit
        t.adjust_kv(600, 100).unwrap();
        assert_eq!(t.kv_bytes(), 400);
        t.release_kv(300);
        assert_eq!(t.kv_bytes(), 100);
    }

    #[test]
    fn train_quota_mirrors_kv_semantics() {
        let ctl = AdmissionController::new();
        ctl.set_quota("acme",
                      TenantQuota::unlimited().max_train_bytes(1000));
        let t = ctl.tenant("acme");
        t.adjust_train(0, 600).unwrap(); // one trainer's opt state
        t.adjust_train(0, 300).unwrap(); // a second trainer
        assert_eq!(t.train_bytes(), 900);
        // growing the first past the budget fails, count untouched
        let err = t.adjust_train(600, 800).unwrap_err();
        match err {
            SymbiosisError::QuotaExceeded {
                resource,
                used,
                requested,
                limit,
                ..
            } => {
                assert_eq!(resource, "training-state bytes");
                assert_eq!(used, 300);
                assert_eq!(requested, 800);
                assert_eq!(limit, 1000);
            }
            other => panic!("expected QuotaExceeded, got {other}"),
        }
        assert_eq!(t.train_bytes(), 900);
        // shrinking is always admitted, even at the limit
        t.adjust_train(600, 100).unwrap();
        assert_eq!(t.train_bytes(), 400);
        t.release_train(300);
        assert_eq!(t.train_bytes(), 100);
        // KV and training budgets are independent books
        t.adjust_kv(0, 500).unwrap();
        assert_eq!(t.kv_bytes(), 500);
        assert_eq!(t.train_bytes(), 100);
    }

    #[test]
    fn quota_updates_apply_to_live_tenants() {
        let ctl = AdmissionController::new();
        let t = ctl.tenant("acme");
        let _a = t.admit_session().unwrap();
        ctl.set_quota("acme", TenantQuota::unlimited().max_sessions(1));
        assert!(t.admit_session().is_err());
        ctl.set_quota("acme", TenantQuota::unlimited());
        assert!(t.admit_session().is_ok());
    }
}
