//! Paged per-client KV cache: block tables, copy-on-write prefix
//! sharing, and ledger-backed swap to the host device.
//!
//! The client owns its KV cache (it is request runtime state — the whole
//! point of the split is that it never burdens the executor).  Storage is
//! a [`BlockPool`] of fixed-size blocks — per layer, K and V live in
//! `(BH, BLOCK_TOKENS, H)` blocks addressed through a per-layer block
//! table — instead of one contiguous `(BH, cap, H)` slab per layer:
//!
//! * **O(1) bytes per appended token.**  `append` writes only the rows it
//!   received into the tail block, and [`KvCache::padded_view`] keeps a
//!   memoized gather buffer per layer so a decode step copies exactly the
//!   newly appended rows into the attention operand — not the whole
//!   prefix, as the old contiguous `padded` re-copy did.  The contiguous
//!   behaviour survives as [`KvCache::padded`], a compat shim and the
//!   bench baseline.
//! * **Copy-on-write prefix sharing.**  A prefix (a common system
//!   prompt, or a [`crate::adapters::PrefixAdapter`]'s seed KV) can be
//!   published into the pool's registry under a key; later caches adopt
//!   it by mapping the *same refcounted blocks* into their tables, so N
//!   sessions sharing a prompt charge ~1 prefix to the device ledger.  A
//!   write into a shared block forks only that block.
//! * **Ledger-backed oversubscription.**  Every block is charged to the
//!   hosting device's [`crate::device::MemoryLedger`] under its own tag
//!   *before* it is handed out, so an over-committed session fails its
//!   `append` with a typed [`SymbiosisError::KvCacheOom`] — unless cold
//!   blocks of `Background`-class sessions can first be swapped to the
//!   host device (charge moves ledgers; typed
//!   [`SymbiosisError::KvSwapOom`] when the host is full too).  Swapped
//!   blocks fault back in on the owner's next touch (typed
//!   [`SymbiosisError::KvFaultInOom`] when the device cannot take them
//!   back), and the pool counts swap-outs/fault-ins for
//!   [`crate::coordinator::FleetStats`].
//!
//! `KvPlacement` still models the paper's OffloadedCache path (section
//! 3.4): with `Host`, the cache blocks are charged to the host ledger
//! and each decode step charges a PCIe transfer for the layer's K/V
//! working set.
//!
//! A tenanted cache additionally charges its [`TenantState`]'s KV-byte
//! quota per *referenced* block — checked *before* the device ledger, so
//! a tenant at its budget fails with a typed
//! [`SymbiosisError::QuotaExceeded`] without ever contending for the
//! shared device.  CoW forks are tenant-neutral (the fork replaces a
//! reference, it does not add one); adopting a shared prefix charges the
//! adopter's tenant for the blocks it now references even though the
//! device holds only one copy — quota is a per-tenant promise, the
//! ledger is physical truth.

#![deny(clippy::unwrap_used)]

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use anyhow::Result;

use crate::coordinator::admission::TenantState;
use crate::device::Device;
use crate::error::{SymResult, SymbiosisError};
use crate::tensor::{ops, Tensor};

/// Tokens per block.  16 is the smallest decode bucket: small enough
/// that a short session wastes at most one partial block per layer,
/// large enough that the per-block ledger tags stay countable.
pub const DEFAULT_BLOCK_TOKENS: usize = 16;

/// Where the cache bytes live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPlacement {
    /// On the client's device.
    Device,
    /// Offloaded to host DRAM (OffloadedCache).
    Host,
}

/// Swap activity counters, surfaced through
/// [`crate::coordinator::FleetStats`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct KvSwapStats {
    /// Blocks swapped device → host since the pool was created.
    pub swap_outs: u64,
    /// Blocks faulted host → device since the pool was created.
    pub fault_ins: u64,
    /// Blocks currently resident on the host (gauge).
    pub swapped_blocks: u64,
}

/// One fixed-size KV block: K and V as `(BH, BLOCK_TOKENS, H)`.
#[derive(Debug)]
struct Block {
    k: Vec<f32>,
    v: Vec<f32>,
    bytes: u64,
    refs: usize,
    /// Cache id of the allocator / last exclusive writer — meaningful
    /// while `refs == 1`, which is the only state a block can swap in.
    owner: usize,
    on_host: bool,
    /// Per-block ledger tag (`<cache tag>/b<id>`); `None` while the
    /// owning cache has no ledger attached.
    tag: Option<String>,
    /// Device whose ledger currently carries the charge.
    device: Option<Arc<Mutex<Device>>>,
}

impl Block {
    fn new(floats: usize, bytes: u64, owner: usize) -> Self {
        Block {
            k: vec![0.0; floats],
            v: vec![0.0; floats],
            bytes,
            refs: 1,
            owner,
            on_host: false,
            tag: None,
            device: None,
        }
    }
}

/// Per-cache registration: where its blocks charge, whether it may be
/// swapped out, and how recently it touched its blocks.
#[derive(Debug)]
struct CacheReg {
    device: Option<Arc<Mutex<Device>>>,
    tag: String,
    host: Option<Arc<Mutex<Device>>>,
    background: bool,
    last_touch: u64,
}

/// Session-level description of a published prefix, returned verbatim
/// to the adopter so it can restore position/seed state and validate
/// its prompt against the shared columns.
#[derive(Debug, Clone, Default)]
pub struct PrefixMeta {
    /// Prompt columns covered by the shared blocks.
    pub cols: usize,
    /// Those prompt columns per batch row, for adopt-time validation.
    pub tokens: Vec<Vec<i32>>,
    /// Session position counter after the prefix.
    pub pos: usize,
    /// Whether a learned prefix seed is included.
    pub seeded: bool,
}

#[derive(Debug)]
struct PrefixEntry {
    /// Per-layer block ids; the entry holds +1 ref on each.
    layers: Vec<Vec<usize>>,
    bh: usize,
    head_dim: usize,
    /// Tokens per layer covered by the blocks.
    len: usize,
    /// Live caches referencing this entry (publisher included); the
    /// entry and its refs are released when the last user drops, so a
    /// drained fleet leaves the ledger empty.
    users: usize,
    meta: PrefixMeta,
}

#[derive(Debug, Default)]
struct PoolInner {
    blocks: Vec<Option<Block>>,
    free: Vec<usize>,
    regs: HashMap<usize, CacheReg>,
    next_cache: usize,
    registry: HashMap<String, PrefixEntry>,
    clock: u64,
    swap_outs: u64,
    fault_ins: u64,
    swapped: u64,
}

/// Shared pool of fixed-size KV blocks.  One pool per
/// [`crate::coordinator::Deployment`] (every session cache draws from
/// it, which is what makes prefix sharing and victim selection
/// fleet-wide); a bare [`KvCache::new`] gets a private pool so the
/// low-level API keeps working standalone.
#[derive(Debug)]
pub struct BlockPool {
    block_tokens: usize,
    inner: Mutex<PoolInner>,
}

impl BlockPool {
    /// A pool with the default block size.
    pub fn new() -> Arc<Self> {
        Self::with_block_tokens(DEFAULT_BLOCK_TOKENS)
    }

    /// A pool with a custom block size (tests use tiny blocks to force
    /// many-block tables cheaply).
    pub fn with_block_tokens(block_tokens: usize) -> Arc<Self> {
        assert!(block_tokens > 0);
        Arc::new(BlockPool {
            block_tokens,
            inner: Mutex::new(PoolInner::default()),
        })
    }

    pub fn block_tokens(&self) -> usize {
        self.block_tokens
    }

    fn lock(&self) -> MutexGuard<'_, PoolInner> {
        self.inner.lock().unwrap_or_else(|p| p.into_inner())
    }

    /// Swap activity counters.
    pub fn swap_stats(&self) -> KvSwapStats {
        let i = self.lock();
        KvSwapStats {
            swap_outs: i.swap_outs,
            fault_ins: i.fault_ins,
            swapped_blocks: i.swapped,
        }
    }

    /// Live (allocated, unfreed) blocks in the pool.
    pub fn live_blocks(&self) -> usize {
        self.lock().blocks.iter().flatten().count()
    }

    /// Sum of ledger-charged block bytes, split (device, host) — the
    /// property tests compare these against the actual ledgers.
    pub fn charged_bytes(&self) -> (u64, u64) {
        let i = self.lock();
        let mut dev = 0;
        let mut host = 0;
        for b in i.blocks.iter().flatten() {
            if b.tag.is_some() {
                if b.on_host {
                    host += b.bytes;
                } else {
                    dev += b.bytes;
                }
            }
        }
        (dev, host)
    }
}

/// Charge `tag` to `bytes` on `dev`; on failure report what *other*
/// allocations hold (everything outside `own_prefix`) and the capacity.
fn try_charge(dev: &Arc<Mutex<Device>>, tag: &str, own_prefix: &str,
              bytes: u64) -> std::result::Result<(), (u64, u64)> {
    let mut d = dev.lock().unwrap_or_else(|p| p.into_inner());
    let capacity = d.ledger.capacity();
    let others = d.ledger.used() - d.ledger.prefix_bytes(own_prefix);
    match d.ledger.set(tag, bytes) {
        Ok(()) => Ok(()),
        Err(_) => Err((others, capacity)),
    }
}

fn free_charge(dev: &Arc<Mutex<Device>>, tag: &str) {
    dev.lock().unwrap_or_else(|p| p.into_inner()).ledger.free(tag);
}

impl PoolInner {
    fn register(&mut self) -> usize {
        let id = self.next_cache;
        self.next_cache += 1;
        self.clock += 1;
        self.regs.insert(id, CacheReg {
            device: None,
            tag: format!("kv:anon{id}"),
            host: None,
            background: false,
            last_touch: self.clock,
        });
        id
    }

    fn touch(&mut self, cache: usize) {
        self.clock += 1;
        if let Some(r) = self.regs.get_mut(&cache) {
            r.last_touch = self.clock;
        }
    }

    fn block(&self, id: usize) -> &Block {
        match self.blocks.get(id).and_then(|b| b.as_ref()) {
            Some(b) => b,
            None => panic!("stale KV block id {id}"),
        }
    }

    fn block_mut(&mut self, id: usize) -> &mut Block {
        match self.blocks.get_mut(id).and_then(|b| b.as_mut()) {
            Some(b) => b,
            None => panic!("stale KV block id {id}"),
        }
    }

    /// Allocate a zeroed block charged to `cache`'s device (if it has
    /// one), swapping background co-tenants out to make room.  On
    /// failure nothing is allocated or charged.
    fn alloc_block(&mut self, cache: usize, floats: usize, bytes: u64)
                   -> Result<usize> {
        let id = match self.free.pop() {
            Some(i) => {
                self.blocks[i] = Some(Block::new(floats, bytes, cache));
                i
            }
            None => {
                self.blocks.push(Some(Block::new(floats, bytes, cache)));
                self.blocks.len() - 1
            }
        };
        if let Err(e) = self.charge_block(cache, id, bytes) {
            self.blocks[id] = None;
            self.free.push(id);
            return Err(e);
        }
        Ok(id)
    }

    /// Charge one block to `cache`'s device ledger under a per-block
    /// tag.  A cache without a registered device holds its blocks
    /// uncharged (they are retro-charged by `attach_ledger`).
    fn charge_block(&mut self, cache: usize, id: usize, bytes: u64)
                    -> Result<()> {
        let (dev, tag, own_prefix) = match self.regs.get(&cache) {
            Some(r) => match &r.device {
                Some(d) => (d.clone(), format!("{}/b{id}", r.tag),
                            format!("{}/", r.tag)),
                None => return Ok(()),
            },
            None => return Ok(()),
        };
        loop {
            match try_charge(&dev, &tag, &own_prefix, bytes) {
                Ok(()) => {
                    let b = self.block_mut(id);
                    b.tag = Some(tag);
                    b.device = Some(dev);
                    return Ok(());
                }
                Err((used_bytes, capacity_bytes)) => {
                    if !self.make_room(cache, &dev) {
                        return Err(anyhow::Error::new(
                            SymbiosisError::KvCacheOom {
                                need_bytes: bytes,
                                used_bytes,
                                capacity_bytes,
                            },
                        ));
                    }
                }
            }
        }
    }

    /// Release a block's ledger charge (used to unwind a failed
    /// `attach_ledger`).
    fn uncharge_block(&mut self, id: usize) {
        let b = self.block_mut(id);
        if let (Some(tag), Some(dev)) = (b.tag.take(), b.device.take()) {
            free_charge(&dev, &tag);
        }
    }

    /// Swap the coldest eligible background cache's exclusive blocks to
    /// its host device.  Returns true when at least one block moved off
    /// `dev` (so a failed charge is worth retrying).
    fn make_room(&mut self, requester: usize, dev: &Arc<Mutex<Device>>)
                 -> bool {
        let mut victims: Vec<(u64, usize)> = self
            .regs
            .iter()
            .filter(|(cid, r)| {
                **cid != requester
                    && r.background
                    && r.host.is_some()
                    && r.device.as_ref().is_some_and(|d| Arc::ptr_eq(d, dev))
            })
            .map(|(cid, r)| (r.last_touch, *cid))
            .collect();
        victims.sort_unstable();
        for (_, vid) in victims {
            if self.swap_cache_blocks(vid, false).unwrap_or(0) > 0 {
                return true;
            }
        }
        false
    }

    /// Swap every exclusive, device-resident block of `victim` to its
    /// host device.  `strict` distinguishes the explicit demotion path
    /// (a full host is a typed [`SymbiosisError::KvSwapOom`]) from
    /// best-effort room-making (a full host just stops the sweep).
    fn swap_cache_blocks(&mut self, victim: usize, strict: bool)
                         -> Result<usize> {
        let host = match self.regs.get(&victim).and_then(|r| r.host.clone())
        {
            Some(h) => h,
            None => return Ok(0),
        };
        let ids: Vec<usize> = self
            .blocks
            .iter()
            .enumerate()
            .filter(|(_, b)| {
                b.as_ref().is_some_and(|b| {
                    b.owner == victim
                        && !b.on_host
                        && b.refs == 1
                        && b.tag.is_some()
                        && b.device
                            .as_ref()
                            .is_some_and(|d| !Arc::ptr_eq(d, &host))
                })
            })
            .map(|(i, _)| i)
            .collect();
        let mut moved = 0;
        for id in ids {
            let (tag, bytes, dev) = {
                let b = self.block(id);
                match (&b.tag, &b.device) {
                    (Some(t), Some(d)) => {
                        (t.clone(), b.bytes, d.clone())
                    }
                    _ => continue,
                }
            };
            match try_charge(&host, &tag, "", bytes) {
                Ok(()) => {}
                Err((used_bytes, capacity_bytes)) => {
                    if strict {
                        return Err(anyhow::Error::new(
                            SymbiosisError::KvSwapOom {
                                need_bytes: bytes,
                                used_bytes,
                                capacity_bytes,
                            },
                        ));
                    }
                    break;
                }
            }
            free_charge(&dev, &tag);
            let b = self.block_mut(id);
            b.device = Some(host.clone());
            b.on_host = true;
            self.swap_outs += 1;
            self.swapped += 1;
            moved += 1;
        }
        Ok(moved)
    }

    /// Fault one block back onto its owner's device (no-op when it is
    /// already resident), swapping background co-tenants out to make
    /// room.
    fn fault_in_one(&mut self, cache: usize, id: usize) -> Result<()> {
        if !self.block(id).on_host {
            return Ok(());
        }
        let (tag, bytes, host) = {
            let b = self.block(id);
            match (&b.tag, &b.device) {
                (Some(t), Some(d)) => (t.clone(), b.bytes, d.clone()),
                _ => return Ok(()),
            }
        };
        let (dev, own_prefix) = match self.regs.get(&cache) {
            Some(r) => match &r.device {
                Some(d) => (d.clone(), format!("{}/", r.tag)),
                None => return Ok(()),
            },
            None => return Ok(()),
        };
        loop {
            match try_charge(&dev, &tag, &own_prefix, bytes) {
                Ok(()) => {
                    free_charge(&host, &tag);
                    let b = self.block_mut(id);
                    b.device = Some(dev);
                    b.on_host = false;
                    self.fault_ins += 1;
                    self.swapped -= 1;
                    return Ok(());
                }
                Err((used_bytes, capacity_bytes)) => {
                    if !self.make_room(cache, &dev) {
                        return Err(anyhow::Error::new(
                            SymbiosisError::KvFaultInOom {
                                need_bytes: bytes,
                                used_bytes,
                                capacity_bytes,
                            },
                        ));
                    }
                }
            }
        }
    }

    /// Fault every listed block back in (the blocks an attention read
    /// is about to touch).
    fn fault_in(&mut self, cache: usize, ids: &[usize]) -> Result<()> {
        for &id in ids {
            self.fault_in_one(cache, id)?;
        }
        Ok(())
    }

    /// Copy-on-write fork: a private, identically-valued copy of `src`
    /// charged to `cache`; drops one reference to `src`.  On failure
    /// `src` is untouched.
    fn fork_block(&mut self, cache: usize, src: usize) -> Result<usize> {
        let (kd, vd, bytes) = {
            let b = self.block(src);
            (b.k.clone(), b.v.clone(), b.bytes)
        };
        let floats = kd.len();
        let nid = self.alloc_block(cache, floats, bytes)?;
        {
            let nb = self.block_mut(nid);
            nb.k = kd;
            nb.v = vd;
        }
        self.deref_block(src);
        Ok(nid)
    }

    fn deref_block(&mut self, id: usize) {
        let freed = {
            let b = self.block_mut(id);
            b.refs -= 1;
            b.refs == 0
        };
        if freed {
            if let Some(b) = self.blocks[id].take() {
                if let (Some(tag), Some(dev)) = (&b.tag, &b.device) {
                    free_charge(dev, tag);
                }
                if b.on_host {
                    self.swapped -= 1;
                }
            }
            self.free.push(id);
        }
    }

    /// Drop one user of a registry entry; the last user out releases
    /// the entry's block references.
    fn release_entry(&mut self, key: &str) {
        let emptied = match self.registry.get_mut(key) {
            Some(e) => {
                e.users -= 1;
                e.users == 0
            }
            None => false,
        };
        if emptied {
            if let Some(e) = self.registry.remove(key) {
                for layer in &e.layers {
                    for &id in layer {
                        self.deref_block(id);
                    }
                }
            }
        }
    }
}

#[derive(Default)]
struct Gather {
    k: Option<Tensor>,
    v: Option<Tensor>,
    bucket: usize,
    /// Rows `[0, valid)` of the gather buffers match the cache (rows
    /// below `valid` are append-only, so they never go stale).
    valid: usize,
}

/// KV cache for one client: per layer, a block table over a
/// [`BlockPool`].
pub struct KvCache {
    pub bh: usize,
    pub head_dim: usize,
    pub placement: KvPlacement,
    pool: Arc<BlockPool>,
    /// This cache's registration id in the pool.
    id: usize,
    /// Per-layer block tables (block `i` holds tokens
    /// `[i*BT, (i+1)*BT)`); tables may hold trailing spare blocks after
    /// `clear()` keeps grown capacity.
    tables: Vec<Vec<usize>>,
    /// Per-layer token lengths (layers fill front-to-back within a step,
    /// so lengths may transiently differ by one during a decode step).
    lens: Vec<usize>,
    /// Memoized per-layer gather buffers backing `padded_view`.
    gather: Vec<Gather>,
    /// Registry keys this cache references (publisher or adopter).
    entries: Vec<String>,
    /// Tenant whose KV-byte quota this cache charges (checked before
    /// the device ledger); `None` = untenanted, no quota.
    tenant: Option<Arc<TenantState>>,
    /// Bytes moved by this cache (appends, gathers, forks) — the
    /// quantity `BENCH_kv.json` plots per decode step.
    copied: AtomicU64,
}

impl KvCache {
    pub fn new(n_layers: usize, bh: usize, head_dim: usize,
               placement: KvPlacement) -> Self {
        let pool = BlockPool::new();
        let id = pool.lock().register();
        KvCache {
            bh,
            head_dim,
            placement,
            pool,
            id,
            tables: vec![Vec::new(); n_layers],
            lens: vec![0; n_layers],
            gather: (0..n_layers).map(|_| Gather::default()).collect(),
            entries: Vec::new(),
            tenant: None,
            copied: AtomicU64::new(0),
        }
    }

    /// Move this (still empty) cache onto a shared pool — done by the
    /// session builder so every session of a deployment draws from one
    /// pool (prefix sharing and swap victim selection are pool-wide).
    pub fn set_pool(&mut self, pool: Arc<BlockPool>) -> SymResult<()> {
        if self.tables.iter().any(|t| !t.is_empty())
            || !self.entries.is_empty()
        {
            return Err(SymbiosisError::Runtime(anyhow::anyhow!(
                "set_pool on a non-empty KV cache"
            )));
        }
        if Arc::ptr_eq(&self.pool, &pool) {
            return Ok(());
        }
        self.pool.lock().regs.remove(&self.id);
        self.id = pool.lock().register();
        self.pool = pool;
        Ok(())
    }

    /// The pool this cache draws from.
    pub fn pool(&self) -> Arc<BlockPool> {
        self.pool.clone()
    }

    /// Attach a device ledger: every block this cache holds (and every
    /// future block) is charged under `<tag>/b<id>`, so the device's
    /// `prefix_bytes(tag)` is this cache's resident footprint.  Already
    /// charged blocks (an adopted shared prefix) keep their publisher's
    /// charge — that is the sharing win.
    pub fn attach_ledger(&mut self, device: Arc<Mutex<Device>>,
                         tag: String) -> Result<()> {
        let pool = self.pool.clone();
        let mut inner = pool.lock();
        if let Some(r) = inner.regs.get_mut(&self.id) {
            r.device = Some(device);
            r.tag = tag;
        }
        let mut charged: Vec<usize> = Vec::new();
        let mut failed = None;
        'outer: for table in &self.tables {
            for &id in table {
                let (uncharged, bytes) = {
                    let b = inner.block(id);
                    (b.tag.is_none(), b.bytes)
                };
                if !uncharged {
                    continue;
                }
                if let Err(e) = inner.charge_block(self.id, id, bytes) {
                    failed = Some(e);
                    break 'outer;
                }
                charged.push(id);
            }
        }
        if let Some(e) = failed {
            for id in charged {
                inner.uncharge_block(id);
            }
            if let Some(r) = inner.regs.get_mut(&self.id) {
                r.device = None;
            }
            return Err(e);
        }
        Ok(())
    }

    /// Register a host device as this cache's swap target.  Only caches
    /// with a swap target (and marked background, see
    /// [`KvCache::set_background`]) are eligible victims when a
    /// co-tenant's `append` would otherwise fire
    /// [`SymbiosisError::KvCacheOom`].
    pub fn attach_swap(&mut self, host: Arc<Mutex<Device>>) {
        if let Some(r) = self.pool.lock().regs.get_mut(&self.id) {
            r.host = Some(host);
        }
    }

    /// Mark this cache as background-class: its cold blocks may be
    /// swapped to the host to make room for foreground appends.
    pub fn set_background(&mut self, background: bool) {
        if let Some(r) = self.pool.lock().regs.get_mut(&self.id) {
            r.background = background;
        }
    }

    /// Charge this cache against a tenant's KV-byte quota: the current
    /// footprint immediately, every growth thereafter — checked
    /// *before* the device ledger so the tenant hits its own budget
    /// (typed [`SymbiosisError::QuotaExceeded`]) before it can push a
    /// co-tenant into [`SymbiosisError::KvCacheOom`].  Released when
    /// the cache drops.
    pub fn set_tenant(&mut self, tenant: Arc<TenantState>)
                      -> SymResult<()> {
        tenant.adjust_kv(0, self.bytes())?;
        self.tenant = Some(tenant);
        Ok(())
    }

    /// Completed token length (the minimum across layers).
    pub fn len(&self) -> usize {
        self.lens.iter().copied().min().unwrap_or(0)
    }

    /// Token length of one layer (may lead `len()` mid-step).
    pub fn layer_len(&self, layer: usize) -> usize {
        self.lens[layer]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Token capacity of the largest per-layer block table.
    pub fn capacity(&self) -> usize {
        self.tables.iter().map(Vec::len).max().unwrap_or(0)
            * self.pool.block_tokens
    }

    /// Tokens per block of the backing pool.
    pub fn block_tokens(&self) -> usize {
        self.pool.block_tokens
    }

    /// Bytes of one block (K+V).
    pub fn block_bytes(&self) -> u64 {
        (2 * self.bh * self.pool.block_tokens * self.head_dim * 4) as u64
    }

    /// Bytes this cache references (all layers, K+V, block-granular).
    /// Shared blocks count fully for every referencing cache — this is
    /// the tenant-quota view; the device ledger holds each block once.
    pub fn bytes(&self) -> u64 {
        let blocks: usize = self.tables.iter().map(Vec::len).sum();
        blocks as u64 * self.block_bytes()
    }

    /// Bytes this cache has moved (appends, gathers, CoW forks) since
    /// creation or the last [`KvCache::reset_copied`].
    pub fn copied_bytes(&self) -> u64 {
        self.copied.load(Ordering::Relaxed)
    }

    pub fn reset_copied(&self) {
        self.copied.store(0, Ordering::Relaxed);
    }

    /// Forget all cached rows (per-layer lengths to zero) while keeping
    /// the block tables as grown capacity, so a reused session does not
    /// re-pay allocation.  Shared blocks still referenced by a registry
    /// entry are forked on the next overwrite, never scribbled on.  The
    /// ledger charge is retained with the blocks.
    pub fn clear(&mut self) {
        for l in &mut self.lens {
            *l = 0;
        }
        for g in &mut self.gather {
            g.k = None;
            g.v = None;
            g.valid = 0;
        }
    }

    /// Append `t_new` tokens of K/V for `layer` (`k`/`v` are
    /// `(BH, t_new, H)`); returns the layer's new token length.  During
    /// a decode step earlier layers lead later ones by one token — the
    /// caller must use the returned per-layer length for attention, not
    /// the global `len()`.  Writing into a shared block forks only that
    /// block (copy-on-write).  When a needed block does not fit the
    /// device, cold background blocks are swapped to the host first;
    /// only when that cannot make room does the append fail with a
    /// typed [`SymbiosisError::KvCacheOom`].
    pub fn append(&mut self, layer: usize, k: &Tensor, v: &Tensor)
                  -> Result<usize> {
        let t_new = k.shape[1];
        let h = self.head_dim;
        let bt = self.pool.block_tokens;
        let bb = self.block_bytes();
        let old = self.lens[layer];
        let new_len = old + t_new;
        let have = self.tables[layer].len();
        let need = new_len.div_ceil(bt);
        let extra = need.saturating_sub(have) as u64;
        // Tenant quota first, device ledger second, both *before*
        // writing: a rejected growth leaves cache, quota, and ledger
        // exactly as they were.
        if extra > 0 {
            if let Some(t) = &self.tenant {
                t.adjust_kv(self.bytes(), self.bytes() + extra * bb)
                    .map_err(anyhow::Error::new)?;
            }
        }
        let pool = self.pool.clone();
        let mut inner = pool.lock();
        inner.touch(self.id);
        let mut failed = None;
        for bi in old / bt..need {
            if bi < have {
                // existing block we are about to write: fault it in if
                // swapped, fork it if shared
                let id = self.tables[layer][bi];
                if let Err(e) = inner.fault_in_one(self.id, id) {
                    failed = Some(e);
                    break;
                }
                if inner.block(id).refs > 1 {
                    match inner.fork_block(self.id, id) {
                        Ok(nid) => {
                            self.tables[layer][bi] = nid;
                            self.copied.fetch_add(bb, Ordering::Relaxed);
                        }
                        Err(e) => {
                            failed = Some(e);
                            break;
                        }
                    }
                }
            } else {
                match inner.alloc_block(self.id, self.bh * bt * h, bb) {
                    Ok(nid) => self.tables[layer].push(nid),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
        }
        if let Some(e) = failed {
            while self.tables[layer].len() > have {
                if let Some(id) = self.tables[layer].pop() {
                    inner.deref_block(id);
                }
            }
            drop(inner);
            if extra > 0 {
                if let Some(t) = &self.tenant {
                    t.release_kv(extra * bb);
                }
            }
            return Err(e);
        }
        let (ks, vs) = (k.as_f32(), v.as_f32());
        let mut t = 0usize;
        while t < t_new {
            let global = old + t;
            let bi = global / bt;
            let r = global % bt;
            let n = (bt - r).min(t_new - t);
            let id = self.tables[layer][bi];
            let blk = inner.block_mut(id);
            ops::copy_seq_rows(&mut blk.k, bt, r, ks, t_new, t,
                               self.bh, h, n);
            ops::copy_seq_rows(&mut blk.v, bt, r, vs, t_new, t,
                               self.bh, h, n);
            t += n;
        }
        self.copied.fetch_add((2 * t_new * self.bh * h * 4) as u64,
                              Ordering::Relaxed);
        self.lens[layer] = new_len;
        Ok(new_len)
    }

    /// K and V for `layer`, padded to `bucket` along the sequence axis:
    /// `(BH, bucket, H)`, byte-identical to [`KvCache::padded`] — but
    /// memoized.  Rows already gathered on a previous call at the same
    /// bucket are reused, so a decode step copies only the newly
    /// appended rows: O(1) bytes per token regardless of prefix length.
    /// Faults swapped blocks back in before reading (the "next touch"
    /// of the swap contract), which is why this takes `&mut self` and
    /// can fail.
    pub fn padded_view(&mut self, layer: usize, bucket: usize)
                       -> Result<(Tensor, Tensor)> {
        let len = self.lens[layer];
        assert!(bucket >= len, "bucket {bucket} < len {len}");
        let h = self.head_dim;
        let bt = self.pool.block_tokens;
        let pool = self.pool.clone();
        let mut inner = pool.lock();
        inner.touch(self.id);
        inner.fault_in(self.id,
                       &self.tables[layer][..len.div_ceil(bt)])?;
        let g = &mut self.gather[layer];
        if g.k.is_none() || g.bucket != bucket {
            let shape = [self.bh, bucket, h];
            let floats = self.bh * bucket * h;
            g.k = Some(Tensor::from_f32(vec![0.0; floats], &shape));
            g.v = Some(Tensor::from_f32(vec![0.0; floats], &shape));
            g.bucket = bucket;
            g.valid = 0;
        }
        if g.valid < len {
            let fresh = len - g.valid;
            if let (Some(kt), Some(vt)) = (g.k.as_mut(), g.v.as_mut()) {
                let gk = kt.as_f32_mut();
                let gv = vt.as_f32_mut();
                let mut t = g.valid;
                while t < len {
                    let bi = t / bt;
                    let r = t % bt;
                    let n = (bt - r).min(len - t);
                    let b = inner.block(self.tables[layer][bi]);
                    ops::copy_seq_rows(gk, bucket, t, &b.k, bt, r,
                                       self.bh, h, n);
                    ops::copy_seq_rows(gv, bucket, t, &b.v, bt, r,
                                       self.bh, h, n);
                    t += n;
                }
            }
            g.valid = len;
            self.copied.fetch_add((2 * fresh * self.bh * h * 4) as u64,
                                  Ordering::Relaxed);
        }
        match (&g.k, &g.v) {
            (Some(kt), Some(vt)) => Ok((kt.clone(), vt.clone())),
            _ => unreachable!("gather buffers were just built"),
        }
    }

    /// Contiguous compat shim: K and V for `layer`, zero-padded to
    /// `bucket`, freshly gathered on every call — the pre-paged
    /// behaviour, kept for tests wanting a contiguous view and as the
    /// bench baseline the paged path is measured against.  Reads
    /// swapped blocks in place without fault-in accounting.
    pub fn padded(&self, layer: usize, bucket: usize) -> (Tensor, Tensor) {
        let len = self.lens[layer];
        assert!(bucket >= len, "bucket {bucket} < len {len}");
        let h = self.head_dim;
        let bt = self.pool.block_tokens;
        let mut k = vec![0.0f32; self.bh * bucket * h];
        let mut v = vec![0.0f32; self.bh * bucket * h];
        {
            let inner = self.pool.lock();
            let mut t = 0usize;
            while t < len {
                let bi = t / bt;
                let r = t % bt;
                let n = (bt - r).min(len - t);
                let b = inner.block(self.tables[layer][bi]);
                ops::copy_seq_rows(&mut k, bucket, t, &b.k, bt, r,
                                   self.bh, h, n);
                ops::copy_seq_rows(&mut v, bucket, t, &b.v, bt, r,
                                   self.bh, h, n);
                t += n;
            }
        }
        self.copied.fetch_add((2 * len * self.bh * h * 4) as u64,
                              Ordering::Relaxed);
        (
            Tensor::from_f32(k, &[self.bh, bucket, h]),
            Tensor::from_f32(v, &[self.bh, bucket, h]),
        )
    }

    /// Publish this cache's current contents (all layers at equal
    /// length) into the pool's prefix registry under `key`.  The
    /// registry takes a reference on every block, and this cache counts
    /// as a user; later caches on the same pool adopt the *same*
    /// blocks.  Returns `false` (and shares nothing) when the key is
    /// already taken — a benign race between identical publishers.
    pub fn publish_prefix(&mut self, key: &str, meta: PrefixMeta)
                          -> SymResult<bool> {
        let len = self.lens.first().copied().unwrap_or(0);
        if self.lens.iter().any(|&l| l != len) {
            return Err(SymbiosisError::Runtime(anyhow::anyhow!(
                "publish_prefix mid-step: layer lengths differ"
            )));
        }
        let bt = self.pool.block_tokens;
        let nblocks = len.div_ceil(bt);
        let pool = self.pool.clone();
        let mut inner = pool.lock();
        if inner.registry.contains_key(key) {
            return Ok(false);
        }
        let layers: Vec<Vec<usize>> = self
            .tables
            .iter()
            .map(|t| t[..nblocks].to_vec())
            .collect();
        for layer in &layers {
            for &id in layer {
                inner.block_mut(id).refs += 1;
            }
        }
        inner.registry.insert(key.to_string(), PrefixEntry {
            layers,
            bh: self.bh,
            head_dim: self.head_dim,
            len,
            users: 1,
            meta,
        });
        self.entries.push(key.to_string());
        Ok(true)
    }

    /// Adopt a published prefix into this (still empty) cache: the
    /// shared blocks are mapped into the block tables with a reference
    /// each — no device bytes are charged (the publisher's charge
    /// already covers them), only the adopter's tenant quota.  Returns
    /// the publisher's [`PrefixMeta`], or `None` when no such key is
    /// registered on this pool.
    pub fn adopt_prefix(&mut self, key: &str)
                        -> SymResult<Option<PrefixMeta>> {
        if self.tables.iter().any(|t| !t.is_empty()) {
            return Err(SymbiosisError::Runtime(anyhow::anyhow!(
                "adopt_prefix on a non-empty KV cache"
            )));
        }
        let pool = self.pool.clone();
        let mut inner = pool.lock();
        let (layers, len, meta) = match inner.registry.get(key) {
            Some(e) => {
                if e.bh != self.bh
                    || e.head_dim != self.head_dim
                    || e.layers.len() != self.tables.len()
                {
                    return Err(SymbiosisError::Runtime(anyhow::anyhow!(
                        "prefix entry '{key}' was published for a \
                         different model shape"
                    )));
                }
                (e.layers.clone(), e.len, e.meta.clone())
            }
            None => return Ok(None),
        };
        if let Some(t) = &self.tenant {
            let blocks: usize = layers.iter().map(Vec::len).sum();
            t.adjust_kv(0, blocks as u64 * self.block_bytes())?;
        }
        for layer in &layers {
            for &id in layer {
                inner.block_mut(id).refs += 1;
            }
        }
        if let Some(e) = inner.registry.get_mut(key) {
            e.users += 1;
        }
        self.tables = layers;
        self.lens = vec![len; self.tables.len()];
        self.entries.push(key.to_string());
        Ok(Some(meta))
    }

    /// Demote this cache: swap every exclusive, device-resident block
    /// to the registered host device (explicit form of the swap the
    /// allocator does under pressure — the scheduler's yield path uses
    /// it to demote background sessions instead of evicting them).
    /// Returns the number of blocks moved; typed
    /// [`SymbiosisError::KvSwapOom`] when the host ledger cannot take
    /// them.
    pub fn swap_out_all(&mut self) -> SymResult<usize> {
        let pool = self.pool.clone();
        let mut inner = pool.lock();
        inner
            .swap_cache_blocks(self.id, true)
            .map_err(SymbiosisError::from)
    }

    /// Bytes that must cross PCIe per decode step if the cache is
    /// host-offloaded but attention runs on a GPU: the full K/V of every
    /// layer (fetched "right before their execution", section 3.4).
    pub fn transfer_bytes_per_step(&self) -> u64 {
        match self.placement {
            KvPlacement::Device => 0,
            KvPlacement::Host => {
                (2 * self.tables.len() * self.bh * self.len()
                    * self.head_dim * 4) as u64
            }
        }
    }
}

impl Drop for KvCache {
    /// Release registry entries, block references (freeing whatever
    /// ledger charge each block carries — device or host), and the
    /// tenant's KV budget.
    fn drop(&mut self) {
        let pool = self.pool.clone();
        let mut inner = pool.lock();
        for key in std::mem::take(&mut self.entries) {
            inner.release_entry(&key);
        }
        for table in &self.tables {
            for &id in table {
                inner.deref_block(id);
            }
        }
        inner.regs.remove(&self.id);
        drop(inner);
        if let Some(t) = &self.tenant {
            t.release_kv(self.bytes());
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, MemoryLedger};

    fn kv(t: usize, bh: usize, h: usize, base: f32) -> Tensor {
        Tensor::from_f32(
            (0..bh * t * h).map(|i| base + i as f32).collect(),
            &[bh, t, h],
        )
    }

    fn small_device(bytes: u64) -> Arc<Mutex<Device>> {
        let mut d = Device::new("tiny", DeviceKind::GpuFast40);
        d.ledger = MemoryLedger::new(bytes);
        Arc::new(Mutex::new(d))
    }

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        for layer in 0..2 {
            c.append(layer, &kv(3, 2, 4, 100.0), &kv(3, 2, 4, 200.0))
                .unwrap();
        }
        assert_eq!(c.len(), 3);
        let (k, _v) = c.padded(0, 16);
        assert_eq!(k.shape, vec![2, 16, 4]);
        // first row of first batch-head must be the first appended row
        assert_eq!(&k.as_f32()[0..4], &[100.0, 101.0, 102.0, 103.0]);
        // padding is zero (row 3 of batch-head 0)
        assert_eq!(k.as_f32()[3 * 4], 0.0);
    }

    #[test]
    fn clear_keeps_capacity_and_resets_lengths() {
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        for layer in 0..2 {
            c.append(layer, &kv(3, 2, 4, 100.0), &kv(3, 2, 4, 200.0))
                .unwrap();
        }
        let cap = c.capacity();
        assert!(cap >= 3);
        c.clear();
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), cap);
        // refill after clear reads back fresh rows, not stale ones
        c.append(0, &kv(2, 2, 4, 500.0), &kv(2, 2, 4, 600.0)).unwrap();
        let (k, _) = c.padded(0, 16);
        assert_eq!(&k.as_f32()[0..4], &[500.0, 501.0, 502.0, 503.0]);
        // beyond the new length is zero padding, not stale pre-clear data
        assert_eq!(k.as_f32()[2 * 4], 0.0);
    }

    #[test]
    fn growth_preserves_contents() {
        let mut c = KvCache::new(1, 1, 2, KvPlacement::Device);
        for step in 0..20 {
            let t = kv(1, 1, 2, step as f32 * 10.0);
            c.append(0, &t, &t).unwrap();
        }
        assert_eq!(c.len(), 20);
        let (k, _) = c.padded(0, 32);
        assert_eq!(k.as_f32()[0], 0.0);
        assert_eq!(k.as_f32()[19 * 2], 190.0);
    }

    #[test]
    fn padded_view_matches_padded_and_copies_only_the_delta() {
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        let row_bytes = (2 * 2 * 4 * 4) as u64; // K+V, bh=2, h=4, f32
        for step in 0..40 {
            for layer in 0..2 {
                let t = kv(1, 2, 4, step as f32 + layer as f32 * 1000.0);
                c.append(layer, &t, &t).unwrap();
            }
            let bucket = (step + 1usize).next_power_of_two().max(16);
            for layer in 0..2 {
                let (ke, ve) = c.padded(layer, bucket);
                c.reset_copied();
                let (kp, vp) = c.padded_view(layer, bucket).unwrap();
                assert_eq!(ke.as_f32(), kp.as_f32(),
                           "paged K diverged at step {step}");
                assert_eq!(ve.as_f32(), vp.as_f32(),
                           "paged V diverged at step {step}");
                // steady state (no bucket change): exactly one fresh
                // row was gathered, independent of the prefix length
                if step > 0 && bucket == step.next_power_of_two().max(16)
                {
                    assert_eq!(c.copied_bytes(), row_bytes,
                               "step {step} gathered more than the \
                                appended row");
                }
            }
        }
    }

    #[test]
    fn host_offload_charges_transfers() {
        let mut dev = KvCache::new(4, 4, 16, KvPlacement::Device);
        let mut host = KvCache::new(4, 4, 16, KvPlacement::Host);
        for layer in 0..4 {
            dev.append(layer, &kv(8, 4, 16, 0.0), &kv(8, 4, 16, 0.0))
                .unwrap();
            host.append(layer, &kv(8, 4, 16, 0.0), &kv(8, 4, 16, 0.0))
                .unwrap();
        }
        assert_eq!(dev.transfer_bytes_per_step(), 0);
        assert_eq!(host.transfer_bytes_per_step(),
                   (2 * 4 * 4 * 8 * 16 * 4) as u64);
    }

    #[test]
    fn ledger_charges_growth_and_releases_on_drop() {
        let dev = Arc::new(Mutex::new(Device::new("cli",
                                                  DeviceKind::GpuFast40)));
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        c.attach_ledger(dev.clone(), "kv:test".into()).unwrap();
        assert_eq!(dev.lock().unwrap().ledger.prefix_bytes("kv:test"), 0);
        c.append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0)).unwrap();
        let charged = dev.lock().unwrap().ledger.prefix_bytes("kv:test");
        assert_eq!(charged, c.bytes());
        assert!(charged > 0);
        // clear keeps the blocks and therefore the charge
        c.clear();
        assert_eq!(dev.lock().unwrap().ledger.prefix_bytes("kv:test"),
                   charged);
        drop(c);
        assert_eq!(dev.lock().unwrap().ledger.prefix_bytes("kv:test"), 0);
        assert_eq!(dev.lock().unwrap().ledger.used(), 0);
    }

    #[test]
    fn tenant_kv_quota_denies_before_the_device_ledger() {
        use crate::coordinator::admission::{AdmissionController,
                                            TenantQuota};
        let ctl = AdmissionController::new();
        ctl.set_quota("acme", TenantQuota::unlimited().max_kv_bytes(64));
        let dev = Arc::new(Mutex::new(Device::new("cli",
                                                  DeviceKind::GpuFast40)));
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        c.attach_ledger(dev.clone(), "kv:t".into()).unwrap();
        c.set_tenant(ctl.tenant("acme")).unwrap();
        let err = c
            .append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0))
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::QuotaExceeded { tenant, resource, limit,
                                            .. } => {
                assert_eq!(tenant, "acme");
                assert_eq!(resource, "KV-cache bytes");
                assert_eq!(limit, 64);
            }
            other => panic!("expected QuotaExceeded, got {other}"),
        }
        // the denied growth left every book untouched: the tenant hit
        // its own quota before contending for the shared device
        assert_eq!(c.capacity(), 0);
        assert_eq!(dev.lock().unwrap().ledger.used(), 0);
        assert_eq!(ctl.tenant("acme").kv_bytes(), 0);
        // an in-budget tenant still reaches the device ledger
        ctl.set_quota("acme", TenantQuota::unlimited());
        c.append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0)).unwrap();
        assert_eq!(ctl.tenant("acme").kv_bytes(), c.bytes());
        assert_eq!(dev.lock().unwrap().ledger.used(), c.bytes());
        drop(c);
        assert_eq!(ctl.tenant("acme").kv_bytes(), 0,
                   "drop returns the tenant's KV budget");
    }

    #[test]
    fn over_committed_append_fails_typed_and_leaves_state_intact() {
        let dev = small_device(256); // far below one block
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        c.attach_ledger(dev.clone(), "kv:tiny".into()).unwrap();
        let err = c
            .append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0))
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::KvCacheOom { need_bytes, used_bytes,
                                         capacity_bytes } => {
                assert_eq!(capacity_bytes, 256);
                assert_eq!(used_bytes, 0, "no co-tenants in this test");
                assert!(need_bytes > capacity_bytes);
            }
            other => panic!("expected KvCacheOom, got {other}"),
        }
        // the failed growth left cache and ledger untouched
        assert_eq!(c.capacity(), 0);
        assert_eq!(c.layer_len(0), 0);
        assert_eq!(dev.lock().unwrap().ledger.used(), 0);
    }

    /// Acceptance: 8 caches sharing a 256-token prefix charge the
    /// device less than 2x one cache's prefix bytes.
    #[test]
    fn shared_prefix_charges_the_ledger_once() {
        let pool = BlockPool::new();
        let dev = Arc::new(Mutex::new(Device::new("cli",
                                                  DeviceKind::GpuFast40)));
        let (layers, bh, h) = (2usize, 2usize, 4usize);
        let mut publisher = KvCache::new(layers, bh, h,
                                         KvPlacement::Device);
        publisher.set_pool(pool.clone()).unwrap();
        publisher.attach_ledger(dev.clone(), "kv:pub".into()).unwrap();
        for l in 0..layers {
            publisher
                .append(l, &kv(256, bh, h, 1.0), &kv(256, bh, h, 2.0))
                .unwrap();
        }
        let single = dev.lock().unwrap().ledger.used();
        assert_eq!(single, publisher.bytes());
        publisher
            .publish_prefix("sys-prompt", PrefixMeta::default())
            .unwrap();
        let mut adopters = Vec::new();
        for i in 0..7 {
            let mut a = KvCache::new(layers, bh, h, KvPlacement::Device);
            a.set_pool(pool.clone()).unwrap();
            a.attach_ledger(dev.clone(), format!("kv:a{i}")).unwrap();
            let meta = a.adopt_prefix("sys-prompt").unwrap();
            assert!(meta.is_some());
            assert_eq!(a.len(), 256);
            // adopted rows read back identically to the publisher's
            let (kp, _) = publisher.padded(0, 256);
            let (ka, _) = a.padded(0, 256);
            assert_eq!(kp.as_f32(), ka.as_f32());
            // each adopter decodes a few private tokens of its own
            for l in 0..layers {
                a.append(l, &kv(1, bh, h, 900.0 + i as f32),
                         &kv(1, bh, h, 901.0)).unwrap();
            }
            adopters.push(a);
        }
        let total = dev.lock().unwrap().ledger.used();
        assert!(total < 2 * single,
                "8 sessions charged {total} B, expected < 2x one \
                 session's {single} B");
        // the whole cohort dropping returns the ledger to zero
        drop(adopters);
        drop(publisher);
        assert_eq!(dev.lock().unwrap().ledger.used(), 0);
        assert_eq!(pool.live_blocks(), 0);
    }

    /// A write into a shared partial block forks only that block: the
    /// publisher's view is untouched and the device is charged for
    /// exactly one extra block.
    #[test]
    fn cow_fork_isolates_writers() {
        let pool = BlockPool::new();
        let dev = Arc::new(Mutex::new(Device::new("cli",
                                                  DeviceKind::GpuFast40)));
        let mut p = KvCache::new(1, 1, 2, KvPlacement::Device);
        p.set_pool(pool.clone()).unwrap();
        p.attach_ledger(dev.clone(), "kv:pub".into()).unwrap();
        // 8 tokens: one partial block
        p.append(0, &kv(8, 1, 2, 10.0), &kv(8, 1, 2, 20.0)).unwrap();
        p.publish_prefix("p", PrefixMeta::default()).unwrap();
        let before = dev.lock().unwrap().ledger.used();
        let mut a = KvCache::new(1, 1, 2, KvPlacement::Device);
        a.set_pool(pool.clone()).unwrap();
        a.attach_ledger(dev.clone(), "kv:a".into()).unwrap();
        a.adopt_prefix("p").unwrap();
        assert_eq!(dev.lock().unwrap().ledger.used(), before,
                   "adoption itself charges nothing");
        // the adopter's 9th token lands in the shared partial block
        a.append(0, &kv(1, 1, 2, 99.0), &kv(1, 1, 2, 98.0)).unwrap();
        assert_eq!(dev.lock().unwrap().ledger.used(),
                   before + a.block_bytes(),
                   "the fork charges exactly one block");
        let (ka, _) = a.padded(0, 16);
        let (kp, _) = p.padded(0, 16);
        assert_eq!(ka.as_f32()[8 * 2], 99.0);
        assert_eq!(kp.as_f32()[8 * 2], 0.0,
                   "publisher still sees zero padding at row 8");
        assert_eq!(&ka.as_f32()[..8 * 2], &kp.as_f32()[..8 * 2],
                   "shared rows stayed identical");
    }

    /// Acceptance: an append that would fire `KvCacheOom` instead swaps
    /// a background cache's cold blocks to the host; the background
    /// cache faults them back in later with its data intact.
    #[test]
    fn oom_append_swaps_background_blocks_and_faults_back() {
        let pool = BlockPool::new();
        // room for exactly 3 blocks of a (L=1, bh=2, h=4) cache
        let bb = (2 * 2 * 16 * 4 * 4) as u64;
        let dev = small_device(3 * bb);
        let host = Arc::new(Mutex::new(Device::new("host",
                                                   DeviceKind::Cpu)));
        let mut bg = KvCache::new(1, 2, 4, KvPlacement::Device);
        bg.set_pool(pool.clone()).unwrap();
        bg.attach_ledger(dev.clone(), "kv:bg".into()).unwrap();
        bg.attach_swap(host.clone());
        bg.set_background(true);
        bg.append(0, &kv(32, 2, 4, 7.0), &kv(32, 2, 4, 8.0)).unwrap();
        let (bg_k, bg_v) = bg.padded(0, 32);
        let mut fg = KvCache::new(1, 2, 4, KvPlacement::Device);
        fg.set_pool(pool.clone()).unwrap();
        fg.attach_ledger(dev.clone(), "kv:fg".into()).unwrap();
        // 32 fg tokens need 2 blocks; only 1 fits next to bg's 2 —
        // without swap this is the old KvCacheOom
        fg.append(0, &kv(32, 2, 4, 50.0), &kv(32, 2, 4, 60.0)).unwrap();
        let stats = pool.swap_stats();
        assert_eq!(stats.swap_outs, 2, "bg's two blocks moved to host");
        assert_eq!(stats.swapped_blocks, 2);
        assert_eq!(host.lock().unwrap().ledger.used(), 2 * bb);
        assert_eq!(dev.lock().unwrap().ledger.used(), 2 * bb);
        // while the device is still full, bg cannot fault back in and
        // says so with a typed error (fg is not an eligible victim)
        match bg.padded_view(0, 32) {
            Err(e) => match SymbiosisError::from(e) {
                SymbiosisError::KvFaultInOom { .. } => {}
                other => panic!("expected KvFaultInOom, got {other}"),
            },
            Ok(_) => panic!("fault-in succeeded on a full device"),
        }
        // fg finishing frees the device; bg's next touch faults in
        drop(fg);
        let (k2, v2) = bg.padded_view(0, 32).unwrap();
        assert_eq!(bg_k.as_f32(), k2.as_f32(),
                   "K survived the swap round-trip");
        assert_eq!(bg_v.as_f32(), v2.as_f32(),
                   "V survived the swap round-trip");
        let stats = pool.swap_stats();
        assert_eq!(stats.fault_ins, 2);
        assert_eq!(stats.swapped_blocks, 0);
        assert_eq!(host.lock().unwrap().ledger.used(), 0);
        assert_eq!(dev.lock().unwrap().ledger.used(), 2 * bb);
    }

    /// Explicit demotion (the scheduler's yield path) moves every
    /// exclusive block to the host; a full host is a typed KvSwapOom.
    #[test]
    fn explicit_swap_out_and_full_host_error() {
        let pool = BlockPool::new();
        let bb = (2 * 2 * 16 * 4 * 4) as u64;
        let dev = small_device(4 * bb);
        let host = small_device(bb); // holds exactly one block
        let mut c = KvCache::new(1, 2, 4, KvPlacement::Device);
        c.set_pool(pool.clone()).unwrap();
        c.attach_ledger(dev.clone(), "kv:c".into()).unwrap();
        c.attach_swap(host.clone());
        c.append(0, &kv(32, 2, 4, 1.0), &kv(32, 2, 4, 2.0)).unwrap();
        match c.swap_out_all() {
            Err(SymbiosisError::KvSwapOom { capacity_bytes, .. }) => {
                assert_eq!(capacity_bytes, bb);
            }
            other => panic!("expected KvSwapOom, got {other:?}"),
        }
        // one block did move before the host filled; demoting a cache
        // with a roomy host moves the rest
        host.lock().unwrap().ledger = MemoryLedger::new(16 * bb);
        // the partial first swap left its charge on the old host ledger
        // object, which was replaced above — re-demote moves the rest
        let moved = c.swap_out_all().unwrap();
        assert!(moved >= 1);
        assert_eq!(pool.swap_stats().swapped_blocks, 2);
        // data still reads back after fault-in
        let (k, _) = c.padded_view(0, 32).unwrap();
        assert_eq!(k.as_f32()[0], 1.0);
        assert_eq!(pool.swap_stats().swapped_blocks, 0);
    }
}
