//! Per-client KV cache with host-offload accounting.
//!
//! The client owns its KV cache (it is request runtime state — the whole
//! point of the split is that it never burdens the executor).  Layout per
//! layer: K and V as `(BH, cap, H)` with `cap` grown by doubling along
//! the sequence axis.  `KvPlacement` models the paper's OffloadedCache
//! path (section 3.4): with `Host`, the cache bytes are charged to the
//! host ledger and each decode step charges a PCIe transfer for the
//! layer's K/V working set — unless the client itself runs on the CPU,
//! in which case the transfer is free (that asymmetry is Fig. 19).

use crate::tensor::Tensor;

/// Where the cache bytes live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPlacement {
    /// On the client's device.
    Device,
    /// Offloaded to host DRAM (OffloadedCache).
    Host,
}

/// KV cache for one client: per layer, K and V `(BH, cap, H)`.
#[derive(Debug)]
pub struct KvCache {
    pub bh: usize,
    pub head_dim: usize,
    pub placement: KvPlacement,
    /// Per-layer token lengths (layers fill front-to-back within a step,
    /// so lengths may transiently differ by one during a decode step).
    lens: Vec<usize>,
    cap: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl KvCache {
    pub fn new(n_layers: usize, bh: usize, head_dim: usize,
               placement: KvPlacement) -> Self {
        KvCache {
            bh,
            head_dim,
            placement,
            lens: vec![0; n_layers],
            cap: 0,
            k: vec![Vec::new(); n_layers],
            v: vec![Vec::new(); n_layers],
        }
    }

    /// Completed token length (the minimum across layers).
    pub fn len(&self) -> usize {
        self.lens.iter().copied().min().unwrap_or(0)
    }

    /// Token length of one layer (may lead `len()` mid-step).
    pub fn layer_len(&self, layer: usize) -> usize {
        self.lens[layer]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Bytes currently held (all layers, K+V).
    pub fn bytes(&self) -> u64 {
        (2 * self.k.len() * self.bh * self.cap * self.head_dim * 4) as u64
    }

    fn ensure_cap(&mut self, want: usize) {
        if want <= self.cap {
            return;
        }
        let new_cap = want.next_power_of_two().max(16);
        for layer in 0..self.k.len() {
            let mut nk = vec![0.0f32; self.bh * new_cap * self.head_dim];
            let mut nv = vec![0.0f32; self.bh * new_cap * self.head_dim];
            let h = self.head_dim;
            for b in 0..self.bh {
                for t in 0..self.lens[layer] {
                    let src = (b * self.cap + t) * h;
                    let dst = (b * new_cap + t) * h;
                    if !self.k[layer].is_empty() {
                        nk[dst..dst + h]
                            .copy_from_slice(&self.k[layer][src..src + h]);
                        nv[dst..dst + h]
                            .copy_from_slice(&self.v[layer][src..src + h]);
                    }
                }
            }
            self.k[layer] = nk;
            self.v[layer] = nv;
        }
        self.cap = new_cap;
    }

    /// Forget all cached rows (per-layer lengths to zero) while keeping
    /// the grown buffers, so a reused session does not re-pay the
    /// doubling growth.  `append`/`padded` never read past the lengths,
    /// so stale bytes in the retained capacity are unreachable.
    pub fn clear(&mut self) {
        for l in &mut self.lens {
            *l = 0;
        }
    }

    /// Append `t_new` tokens of K/V for `layer` (`k`/`v` are
    /// `(BH, t_new, H)`); returns the layer's new token length.  During a
    /// decode step earlier layers lead later ones by one token — the
    /// caller must use the returned per-layer length for attention, not
    /// the global `len()`.
    pub fn append(&mut self, layer: usize, k: &Tensor, v: &Tensor)
                  -> usize {
        let t_new = k.shape[1];
        let h = self.head_dim;
        let old = self.lens[layer];
        self.ensure_cap(old + t_new);
        let (ks, vs) = (k.as_f32(), v.as_f32());
        for b in 0..self.bh {
            for t in 0..t_new {
                let src = (b * t_new + t) * h;
                let dst = (b * self.cap + old + t) * h;
                self.k[layer][dst..dst + h]
                    .copy_from_slice(&ks[src..src + h]);
                self.v[layer][dst..dst + h]
                    .copy_from_slice(&vs[src..src + h]);
            }
        }
        self.lens[layer] = old + t_new;
        self.lens[layer]
    }

    /// K and V for `layer`, padded to `bucket` along the sequence axis:
    /// `(BH, bucket, H)` — ready for the bucketed decode artifact.
    pub fn padded(&self, layer: usize, bucket: usize) -> (Tensor, Tensor) {
        let len = self.lens[layer];
        assert!(bucket >= len, "bucket {bucket} < len {len}");
        let h = self.head_dim;
        let mut k = vec![0.0f32; self.bh * bucket * h];
        let mut v = vec![0.0f32; self.bh * bucket * h];
        for b in 0..self.bh {
            for t in 0..len {
                let src = (b * self.cap + t) * h;
                let dst = (b * bucket + t) * h;
                k[dst..dst + h].copy_from_slice(&self.k[layer][src..src + h]);
                v[dst..dst + h].copy_from_slice(&self.v[layer][src..src + h]);
            }
        }
        (
            Tensor::from_f32(k, &[self.bh, bucket, h]),
            Tensor::from_f32(v, &[self.bh, bucket, h]),
        )
    }

    /// Bytes that must cross PCIe per decode step if the cache is
    /// host-offloaded but attention runs on a GPU: the full K/V of every
    /// layer (fetched "right before their execution", section 3.4).
    pub fn transfer_bytes_per_step(&self) -> u64 {
        match self.placement {
            KvPlacement::Device => 0,
            KvPlacement::Host => {
                (2 * self.k.len() * self.bh * self.len() * self.head_dim
                    * 4) as u64
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kv(t: usize, bh: usize, h: usize, base: f32) -> Tensor {
        Tensor::from_f32(
            (0..bh * t * h).map(|i| base + i as f32).collect(),
            &[bh, t, h],
        )
    }

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        for layer in 0..2 {
            c.append(layer, &kv(3, 2, 4, 100.0), &kv(3, 2, 4, 200.0));
        }
        assert_eq!(c.len(), 3);
        let (k, _v) = c.padded(0, 16);
        assert_eq!(k.shape, vec![2, 16, 4]);
        // first row of first batch-head must be the first appended row
        assert_eq!(&k.as_f32()[0..4], &[100.0, 101.0, 102.0, 103.0]);
        // padding is zero
        assert_eq!(k.as_f32()[(0 * 16 + 3) * 4], 0.0);
    }

    #[test]
    fn clear_keeps_capacity_and_resets_lengths() {
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        for layer in 0..2 {
            c.append(layer, &kv(3, 2, 4, 100.0), &kv(3, 2, 4, 200.0));
        }
        let cap = c.capacity();
        assert!(cap >= 3);
        c.clear();
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), cap);
        // refill after clear reads back fresh rows, not stale ones
        c.append(0, &kv(2, 2, 4, 500.0), &kv(2, 2, 4, 600.0));
        let (k, _) = c.padded(0, 16);
        assert_eq!(&k.as_f32()[0..4], &[500.0, 501.0, 502.0, 503.0]);
        // beyond the new length is zero padding, not stale pre-clear data
        assert_eq!(k.as_f32()[2 * 4], 0.0);
    }

    #[test]
    fn growth_preserves_contents() {
        let mut c = KvCache::new(1, 1, 2, KvPlacement::Device);
        for step in 0..20 {
            let t = kv(1, 1, 2, step as f32 * 10.0);
            c.append(0, &t, &t);
        }
        assert_eq!(c.len(), 20);
        let (k, _) = c.padded(0, 32);
        assert_eq!(k.as_f32()[0], 0.0);
        assert_eq!(k.as_f32()[19 * 2], 190.0);
    }

    #[test]
    fn host_offload_charges_transfers() {
        let mut dev = KvCache::new(4, 4, 16, KvPlacement::Device);
        let mut host = KvCache::new(4, 4, 16, KvPlacement::Host);
        for layer in 0..4 {
            dev.append(layer, &kv(8, 4, 16, 0.0), &kv(8, 4, 16, 0.0));
            host.append(layer, &kv(8, 4, 16, 0.0), &kv(8, 4, 16, 0.0));
        }
        assert_eq!(dev.transfer_bytes_per_step(), 0);
        assert_eq!(host.transfer_bytes_per_step(),
                   (2 * 4 * 4 * 8 * 16 * 4) as u64);
    }
}
