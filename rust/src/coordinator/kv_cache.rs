//! Per-client KV cache with host-offload accounting and real ledger
//! charging.
//!
//! The client owns its KV cache (it is request runtime state — the whole
//! point of the split is that it never burdens the executor).  Layout per
//! layer: K and V as `(BH, cap, H)` with `cap` grown by doubling along
//! the sequence axis.  `KvPlacement` models the paper's OffloadedCache
//! path (section 3.4): with `Host`, the cache bytes are charged to the
//! host ledger and each decode step charges a PCIe transfer for the
//! layer's K/V working set — unless the client itself runs on the CPU,
//! in which case the transfer is free (that asymmetry is Fig. 19).
//!
//! A cache built by the session builder
//! ([`crate::coordinator::SessionBuilder`]) carries a [`KvLedger`]:
//! every capacity growth is charged to the hosting device's
//! [`crate::device::MemoryLedger`] *before* the buffers grow, so an
//! over-committed session fails its `append` with a typed
//! [`SymbiosisError::KvCacheOom`] instead of only showing up in the
//! analytic memory model — the executable form of the paper's
//! mixed-tenant OOM lines (Figs 9/10).  `clear()` keeps the grown
//! buffers and therefore keeps the charge; the charge is released when
//! the cache drops.
//!
//! A tenanted session additionally carries its [`TenantState`]: every
//! growth is charged against the tenant's KV-byte quota *before* the
//! device ledger, so a tenant at its budget fails with a typed
//! [`SymbiosisError::QuotaExceeded`] without ever contending for the
//! shared device — its co-tenants keep their headroom.

#![deny(clippy::unwrap_used)]

use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::coordinator::admission::TenantState;
use crate::device::Device;
use crate::error::{SymResult, SymbiosisError};
use crate::tensor::Tensor;

/// Where the cache bytes live.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPlacement {
    /// On the client's device.
    Device,
    /// Offloaded to host DRAM (OffloadedCache).
    Host,
}

/// A handle charging this cache's bytes to a (shared) simulated device:
/// sessions on the same device contend for the same capacity, which is
/// what makes multi-tenant OOM executable.
#[derive(Debug, Clone)]
pub struct KvLedger {
    pub device: Arc<Mutex<Device>>,
    /// Ledger tag, e.g. `kv:client3`.
    pub tag: String,
}

impl KvLedger {
    /// Charge the tag to `bytes` total; typed
    /// [`SymbiosisError::KvCacheOom`] when the device cannot hold it.
    fn charge(&self, bytes: u64) -> Result<()> {
        let mut dev =
            self.device.lock().unwrap_or_else(|p| p.into_inner());
        let capacity = dev.ledger.capacity();
        // what *other* allocations hold — the informative number in
        // the multi-tenant case, where this cache alone would fit
        let others = dev.ledger.used() - dev.ledger.tag_bytes(&self.tag);
        dev.ledger.set(&self.tag, bytes).map_err(|_| {
            anyhow::Error::new(SymbiosisError::KvCacheOom {
                need_bytes: bytes,
                used_bytes: others,
                capacity_bytes: capacity,
            })
        })
    }

    fn release(&self) {
        self.device
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .ledger
            .free(&self.tag);
    }
}

/// KV cache for one client: per layer, K and V `(BH, cap, H)`.
#[derive(Debug)]
pub struct KvCache {
    pub bh: usize,
    pub head_dim: usize,
    pub placement: KvPlacement,
    /// Per-layer token lengths (layers fill front-to-back within a step,
    /// so lengths may transiently differ by one during a decode step).
    lens: Vec<usize>,
    cap: usize,
    k: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
    ledger: Option<KvLedger>,
    /// Tenant whose KV-byte quota this cache charges (checked before
    /// the device ledger); `None` = untenanted, no quota.
    tenant: Option<Arc<TenantState>>,
}

impl KvCache {
    pub fn new(n_layers: usize, bh: usize, head_dim: usize,
               placement: KvPlacement) -> Self {
        KvCache {
            bh,
            head_dim,
            placement,
            lens: vec![0; n_layers],
            cap: 0,
            k: vec![Vec::new(); n_layers],
            v: vec![Vec::new(); n_layers],
            ledger: None,
            tenant: None,
        }
    }

    /// Attach a device ledger: from now on every capacity growth is
    /// charged (and the current footprint is charged immediately).
    /// The charge is released when the cache drops.
    pub fn attach_ledger(&mut self, device: Arc<Mutex<Device>>,
                         tag: String) -> Result<()> {
        let ledger = KvLedger { device, tag };
        ledger.charge(self.bytes())?;
        self.ledger = Some(ledger);
        Ok(())
    }

    /// Charge this cache against a tenant's KV-byte quota: the current
    /// footprint immediately, every growth thereafter — checked
    /// *before* the device ledger so the tenant hits its own budget
    /// (typed [`SymbiosisError::QuotaExceeded`]) before it can push a
    /// co-tenant into [`SymbiosisError::KvCacheOom`].  Released when
    /// the cache drops.
    pub fn set_tenant(&mut self, tenant: Arc<TenantState>)
                      -> SymResult<()> {
        tenant.adjust_kv(0, self.bytes())?;
        self.tenant = Some(tenant);
        Ok(())
    }

    /// Completed token length (the minimum across layers).
    pub fn len(&self) -> usize {
        self.lens.iter().copied().min().unwrap_or(0)
    }

    /// Token length of one layer (may lead `len()` mid-step).
    pub fn layer_len(&self, layer: usize) -> usize {
        self.lens[layer]
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Bytes currently held (all layers, K+V).
    pub fn bytes(&self) -> u64 {
        self.bytes_at_cap(self.cap)
    }

    /// Footprint at a hypothetical capacity — the single source of the
    /// layout formula, used both for the current footprint and for the
    /// ledger pre-charge in `ensure_cap`.
    fn bytes_at_cap(&self, cap: usize) -> u64 {
        (2 * self.k.len() * self.bh * cap * self.head_dim * 4) as u64
    }

    fn ensure_cap(&mut self, want: usize) -> Result<()> {
        if want <= self.cap {
            return Ok(());
        }
        let new_cap = want.next_power_of_two().max(16);
        // Tenant quota first, then device ledger, both *before*
        // growing: a rejected growth leaves cache, quota, and ledger
        // exactly as they were.
        if let Some(t) = &self.tenant {
            t.adjust_kv(self.bytes(), self.bytes_at_cap(new_cap))
                .map_err(anyhow::Error::new)?;
        }
        if let Some(ledger) = &self.ledger {
            if let Err(e) = ledger.charge(self.bytes_at_cap(new_cap)) {
                // roll the tenant charge back so both books agree
                if let Some(t) = &self.tenant {
                    let _ = t.adjust_kv(self.bytes_at_cap(new_cap),
                                        self.bytes());
                }
                return Err(e);
            }
        }
        for layer in 0..self.k.len() {
            let mut nk = vec![0.0f32; self.bh * new_cap * self.head_dim];
            let mut nv = vec![0.0f32; self.bh * new_cap * self.head_dim];
            let h = self.head_dim;
            for b in 0..self.bh {
                for t in 0..self.lens[layer] {
                    let src = (b * self.cap + t) * h;
                    let dst = (b * new_cap + t) * h;
                    if !self.k[layer].is_empty() {
                        nk[dst..dst + h]
                            .copy_from_slice(&self.k[layer][src..src + h]);
                        nv[dst..dst + h]
                            .copy_from_slice(&self.v[layer][src..src + h]);
                    }
                }
            }
            self.k[layer] = nk;
            self.v[layer] = nv;
        }
        self.cap = new_cap;
        Ok(())
    }

    /// Forget all cached rows (per-layer lengths to zero) while keeping
    /// the grown buffers, so a reused session does not re-pay the
    /// doubling growth.  `append`/`padded` never read past the lengths,
    /// so stale bytes in the retained capacity are unreachable.  The
    /// ledger charge is retained with the buffers.
    pub fn clear(&mut self) {
        for l in &mut self.lens {
            *l = 0;
        }
    }

    /// Append `t_new` tokens of K/V for `layer` (`k`/`v` are
    /// `(BH, t_new, H)`); returns the layer's new token length.  During a
    /// decode step earlier layers lead later ones by one token — the
    /// caller must use the returned per-layer length for attention, not
    /// the global `len()`.  Fails with a typed
    /// [`SymbiosisError::KvCacheOom`] when a ledger is attached and the
    /// required capacity growth does not fit the device.
    pub fn append(&mut self, layer: usize, k: &Tensor, v: &Tensor)
                  -> Result<usize> {
        let t_new = k.shape[1];
        let h = self.head_dim;
        let old = self.lens[layer];
        self.ensure_cap(old + t_new)?;
        let (ks, vs) = (k.as_f32(), v.as_f32());
        for b in 0..self.bh {
            for t in 0..t_new {
                let src = (b * t_new + t) * h;
                let dst = (b * self.cap + old + t) * h;
                self.k[layer][dst..dst + h]
                    .copy_from_slice(&ks[src..src + h]);
                self.v[layer][dst..dst + h]
                    .copy_from_slice(&vs[src..src + h]);
            }
        }
        self.lens[layer] = old + t_new;
        Ok(self.lens[layer])
    }

    /// K and V for `layer`, padded to `bucket` along the sequence axis:
    /// `(BH, bucket, H)` — ready for the bucketed decode artifact.
    pub fn padded(&self, layer: usize, bucket: usize) -> (Tensor, Tensor) {
        let len = self.lens[layer];
        assert!(bucket >= len, "bucket {bucket} < len {len}");
        let h = self.head_dim;
        let mut k = vec![0.0f32; self.bh * bucket * h];
        let mut v = vec![0.0f32; self.bh * bucket * h];
        for b in 0..self.bh {
            for t in 0..len {
                let src = (b * self.cap + t) * h;
                let dst = (b * bucket + t) * h;
                k[dst..dst + h].copy_from_slice(&self.k[layer][src..src + h]);
                v[dst..dst + h].copy_from_slice(&self.v[layer][src..src + h]);
            }
        }
        (
            Tensor::from_f32(k, &[self.bh, bucket, h]),
            Tensor::from_f32(v, &[self.bh, bucket, h]),
        )
    }

    /// Bytes that must cross PCIe per decode step if the cache is
    /// host-offloaded but attention runs on a GPU: the full K/V of every
    /// layer (fetched "right before their execution", section 3.4).
    pub fn transfer_bytes_per_step(&self) -> u64 {
        match self.placement {
            KvPlacement::Device => 0,
            KvPlacement::Host => {
                (2 * self.k.len() * self.bh * self.len() * self.head_dim
                    * 4) as u64
            }
        }
    }
}

impl Drop for KvCache {
    /// Release the device charge and the tenant's KV budget with the
    /// buffers.
    fn drop(&mut self) {
        if let Some(ledger) = &self.ledger {
            ledger.release();
        }
        if let Some(t) = &self.tenant {
            t.release_kv(self.bytes());
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::device::{DeviceKind, MemoryLedger};

    fn kv(t: usize, bh: usize, h: usize, base: f32) -> Tensor {
        Tensor::from_f32(
            (0..bh * t * h).map(|i| base + i as f32).collect(),
            &[bh, t, h],
        )
    }

    #[test]
    fn append_and_read_back() {
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        for layer in 0..2 {
            c.append(layer, &kv(3, 2, 4, 100.0), &kv(3, 2, 4, 200.0))
                .unwrap();
        }
        assert_eq!(c.len(), 3);
        let (k, _v) = c.padded(0, 16);
        assert_eq!(k.shape, vec![2, 16, 4]);
        // first row of first batch-head must be the first appended row
        assert_eq!(&k.as_f32()[0..4], &[100.0, 101.0, 102.0, 103.0]);
        // padding is zero
        assert_eq!(k.as_f32()[(0 * 16 + 3) * 4], 0.0);
    }

    #[test]
    fn clear_keeps_capacity_and_resets_lengths() {
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        for layer in 0..2 {
            c.append(layer, &kv(3, 2, 4, 100.0), &kv(3, 2, 4, 200.0))
                .unwrap();
        }
        let cap = c.capacity();
        assert!(cap >= 3);
        c.clear();
        assert_eq!(c.len(), 0);
        assert!(c.is_empty());
        assert_eq!(c.capacity(), cap);
        // refill after clear reads back fresh rows, not stale ones
        c.append(0, &kv(2, 2, 4, 500.0), &kv(2, 2, 4, 600.0)).unwrap();
        let (k, _) = c.padded(0, 16);
        assert_eq!(&k.as_f32()[0..4], &[500.0, 501.0, 502.0, 503.0]);
        // beyond the new length is zero padding, not stale pre-clear data
        assert_eq!(k.as_f32()[2 * 4], 0.0);
    }

    #[test]
    fn growth_preserves_contents() {
        let mut c = KvCache::new(1, 1, 2, KvPlacement::Device);
        for step in 0..20 {
            let t = kv(1, 1, 2, step as f32 * 10.0);
            c.append(0, &t, &t).unwrap();
        }
        assert_eq!(c.len(), 20);
        let (k, _) = c.padded(0, 32);
        assert_eq!(k.as_f32()[0], 0.0);
        assert_eq!(k.as_f32()[19 * 2], 190.0);
    }

    #[test]
    fn host_offload_charges_transfers() {
        let mut dev = KvCache::new(4, 4, 16, KvPlacement::Device);
        let mut host = KvCache::new(4, 4, 16, KvPlacement::Host);
        for layer in 0..4 {
            dev.append(layer, &kv(8, 4, 16, 0.0), &kv(8, 4, 16, 0.0))
                .unwrap();
            host.append(layer, &kv(8, 4, 16, 0.0), &kv(8, 4, 16, 0.0))
                .unwrap();
        }
        assert_eq!(dev.transfer_bytes_per_step(), 0);
        assert_eq!(host.transfer_bytes_per_step(),
                   (2 * 4 * 4 * 8 * 16 * 4) as u64);
    }

    #[test]
    fn ledger_charges_growth_and_releases_on_drop() {
        let dev = Arc::new(Mutex::new(Device::new("cli",
                                                  DeviceKind::GpuFast40)));
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        c.attach_ledger(dev.clone(), "kv:test".into()).unwrap();
        assert_eq!(dev.lock().unwrap().ledger.tag_bytes("kv:test"), 0);
        c.append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0)).unwrap();
        let charged = dev.lock().unwrap().ledger.tag_bytes("kv:test");
        assert_eq!(charged, c.bytes());
        assert!(charged > 0);
        // clear keeps the buffers and therefore the charge
        c.clear();
        assert_eq!(dev.lock().unwrap().ledger.tag_bytes("kv:test"),
                   charged);
        drop(c);
        assert_eq!(dev.lock().unwrap().ledger.tag_bytes("kv:test"), 0);
    }

    #[test]
    fn tenant_kv_quota_denies_before_the_device_ledger() {
        use crate::coordinator::admission::{AdmissionController,
                                            TenantQuota};
        let ctl = AdmissionController::new();
        ctl.set_quota("acme", TenantQuota::unlimited().max_kv_bytes(64));
        let dev = Arc::new(Mutex::new(Device::new("cli",
                                                  DeviceKind::GpuFast40)));
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        c.attach_ledger(dev.clone(), "kv:t".into()).unwrap();
        c.set_tenant(ctl.tenant("acme")).unwrap();
        let err = c
            .append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0))
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::QuotaExceeded { tenant, resource, limit,
                                            .. } => {
                assert_eq!(tenant, "acme");
                assert_eq!(resource, "KV-cache bytes");
                assert_eq!(limit, 64);
            }
            other => panic!("expected QuotaExceeded, got {other}"),
        }
        // the denied growth left every book untouched: the tenant hit
        // its own quota before contending for the shared device
        assert_eq!(c.capacity(), 0);
        assert_eq!(dev.lock().unwrap().ledger.used(), 0);
        assert_eq!(ctl.tenant("acme").kv_bytes(), 0);
        // an in-budget tenant still reaches the device ledger
        ctl.set_quota("acme", TenantQuota::unlimited());
        c.append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0)).unwrap();
        assert_eq!(ctl.tenant("acme").kv_bytes(), c.bytes());
        assert_eq!(dev.lock().unwrap().ledger.used(), c.bytes());
        drop(c);
        assert_eq!(ctl.tenant("acme").kv_bytes(), 0,
                   "drop returns the tenant's KV budget");
    }

    #[test]
    fn over_committed_append_fails_typed_and_leaves_state_intact() {
        let mut small = Device::new("tiny", DeviceKind::GpuFast40);
        small.ledger = MemoryLedger::new(256); // far below one growth
        let dev = Arc::new(Mutex::new(small));
        let mut c = KvCache::new(2, 2, 4, KvPlacement::Device);
        c.attach_ledger(dev.clone(), "kv:tiny".into()).unwrap();
        let err = c
            .append(0, &kv(3, 2, 4, 0.0), &kv(3, 2, 4, 0.0))
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::KvCacheOom { need_bytes, used_bytes,
                                         capacity_bytes } => {
                assert_eq!(capacity_bytes, 256);
                assert_eq!(used_bytes, 0, "no co-tenants in this test");
                assert!(need_bytes > capacity_bytes);
            }
            other => panic!("expected KvCacheOom, got {other}"),
        }
        // the failed growth left cache and ledger untouched
        assert_eq!(c.capacity(), 0);
        assert_eq!(c.layer_len(0), 0);
        assert_eq!(dev.lock().unwrap().ledger.used(), 0);
    }
}
