//! PEFT adapters — client-owned trainable state, exposed to the layer
//! walker through the [`AdapterHooks`] trait.
//!
//! Symbiosis supports *different* PEFT methods per client against the
//! same shared base (design goal 6).  Implemented: **LoRA** (the paper's
//! evaluation workhorse, Table 2 configs), **IA3** (elementwise
//! rescaling), and **Prefix** tuning (learned KV prefix per layer).
//! Adapter math runs client-side: LoRA through the fused Pallas artifact
//! when available, IA3/Prefix natively (they are elementwise/concat
//! work, not matmuls).
//!
//! The client's transformer walk never inspects the adapter kind: it
//! calls the hook at each interception point and each adapter object
//! ([`LoraAdapter`], [`Ia3Adapter`], [`PrefixAdapter`]) overrides the
//! hooks it needs.  Adding a new PEFT family (see LLM-Adapters, arXiv
//! 2304.01933) means implementing this trait and wrapping the new
//! object in an [`Adapter`] variant *in this file* (hooks dispatch,
//! parameter count, flatten/unflatten) — the walker, sessions, and
//! trainers in `client.rs` need no edits.

// Client-owned trainable state sits on the training hot path: every
// failure must surface as a typed error, never a panic.
#![deny(clippy::unwrap_used)]

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::config::{bucket_for, ModelConfig, TOKEN_BUCKETS};
use crate::runtime::Engine;
use crate::tensor::{container, ops, Tensor};

/// Which projections a LoRA adapter applies to (paper Table 2: LoRA1 =
/// (8,[q]) … LoRA4 = (64,[q,k,v,o])).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoraTargets {
    pub q: bool,
    pub k: bool,
    pub v: bool,
    pub o: bool,
}

impl LoraTargets {
    pub const Q_ONLY: LoraTargets =
        LoraTargets { q: true, k: false, v: false, o: false };
    pub const QKVO: LoraTargets =
        LoraTargets { q: true, k: true, v: true, o: true };

    pub fn count(&self) -> usize {
        [self.q, self.k, self.v, self.o].iter().filter(|&&b| b).count()
    }

    pub fn list(&self) -> Vec<&'static str> {
        let mut v = Vec::new();
        if self.q { v.push("q"); }
        if self.k { v.push("k"); }
        if self.v { v.push("v"); }
        if self.o { v.push("o"); }
        v
    }

    fn on(&self, target: &str) -> bool {
        match target {
            "q" => self.q,
            "k" => self.k,
            "v" => self.v,
            "o" => self.o,
            _ => false,
        }
    }
}

/// The paper's Table 2 adapter configurations.
pub fn lora_table2(which: usize) -> (usize, LoraTargets) {
    match which {
        1 => (8, LoraTargets::Q_ONLY),
        2 => (64, LoraTargets::Q_ONLY),
        3 => (8, LoraTargets::QKVO),
        4 => (64, LoraTargets::QKVO),
        _ => panic!("Table 2 defines LoRA 1..4"),
    }
}

/// One LoRA pair for one target projection of one block.
#[derive(Debug, Clone)]
pub struct LoraPair {
    pub a: Tensor, // (D, r)
    pub b: Tensor, // (r, D)
}

// ---------------------------------------------------------------------------
// The hook trait
// ---------------------------------------------------------------------------

/// Read-only client context handed to every hook: the engine (for fused
/// adapter artifacts) and the model dims.
pub struct HookCtx<'a> {
    pub engine: &'a Engine,
    pub cfg: &'a ModelConfig,
}

/// Adapter interception points of one transformer block.
///
/// The layer walker calls every hook unconditionally; the default
/// implementation of each is the identity, so an adapter only overrides
/// the points where its math lives.  Forward hooks *mutate* the
/// activation in place (the walker owns the tensors); backward hooks
/// accumulate parameter gradients into [`AdapterGrads`] and return the
/// extra input-gradient contribution, if any.
pub trait AdapterHooks: Send + Sync {
    /// Add deltas to q/k/v after the fused base QKV projection
    /// (`a_in` is the rmsnorm-1 output the projection consumed).
    fn qkv_delta(&self, _cx: &HookCtx, _layer: usize, _a_in: &Tensor,
                 _q: &mut Tensor, _k: &mut Tensor, _v: &mut Tensor)
                 -> Result<()> {
        Ok(())
    }

    /// Rescale k/v before they are split into heads / appended to the
    /// KV cache (IA3).
    fn kv_scale(&self, _layer: usize, _k: &mut Tensor, _v: &mut Tensor) {}

    /// Add a delta to the attention output projection (`attn_merged` is
    /// the head-merged attention result the projection consumed).
    fn attn_out_delta(&self, _cx: &HookCtx, _layer: usize,
                      _attn_merged: &Tensor, _o: &mut Tensor)
                      -> Result<()> {
        Ok(())
    }

    /// Rescale the MLP intermediate pre-activation (IA3 ff).
    fn ffn_scale(&self, _layer: usize, _u_pre: &mut Tensor) {}

    /// Learned KV rows to seed the cache with before any token is
    /// processed (prefix tuning).  Returns `(k, v)`, each `(BH, P, H)`.
    fn seed_kv(&self, _layer: usize) -> Option<(&Tensor, &Tensor)> {
        None
    }

    /// Backward of [`Self::qkv_delta`]: `dq`/`dk`/`dv` are gradients at
    /// the (pre-`kv_scale`) projection outputs.  Accumulates parameter
    /// gradients and returns the adapter's extra contribution to
    /// `d(a_in)`.
    #[allow(clippy::too_many_arguments)]
    fn qkv_delta_bwd(&self, _cx: &HookCtx, _layer: usize, _a_in: &Tensor,
                     _dq: &Tensor, _dk: &Tensor, _dv: &Tensor,
                     _grads: &mut AdapterGrads) -> Result<Option<Tensor>> {
        Ok(None)
    }

    /// Backward of [`Self::kv_scale`]: map gradients at the scaled k/v
    /// back to the pre-scale projection outputs.
    fn kv_scale_bwd(&self, _layer: usize, dk: &Tensor, dv: &Tensor)
                    -> (Tensor, Tensor) {
        (dk.clone(), dv.clone())
    }

    /// Backward of [`Self::attn_out_delta`]: returns the adapter's extra
    /// contribution to `d(attn_merged)`.
    fn attn_out_delta_bwd(&self, _cx: &HookCtx, _layer: usize,
                          _attn_merged: &Tensor, _do: &Tensor,
                          _grads: &mut AdapterGrads)
                          -> Result<Option<Tensor>> {
        Ok(None)
    }

    /// Backward of [`Self::ffn_scale`]: map the gradient at the scaled
    /// pre-activation back through the scale.
    fn ffn_scale_bwd(&self, _layer: usize, _u_pre: &Tensor, dy: &Tensor)
                     -> Tensor {
        dy.clone() // refcount bump, not a copy
    }

    /// Whether this adapter's parameter gradients are wired into the
    /// flattened optimizer layout (i.e. a [`crate::coordinator::Trainer`]
    /// can fine-tune it).
    fn trainable(&self) -> bool {
        false
    }
}

/// Hooks of the bare base model: every hook is the identity.
#[derive(Debug, Clone, Copy, Default)]
pub struct NoAdapter;

impl AdapterHooks for NoAdapter {}

/// The identity hook set, usable wherever a `&dyn AdapterHooks` is
/// needed and the client has no adapter.
pub static NO_ADAPTER: NoAdapter = NoAdapter;

// ---------------------------------------------------------------------------
// LoRA
// ---------------------------------------------------------------------------

/// Low-rank adaptation of the attention projections: `y += s · (x A) B`.
#[derive(Debug, Clone)]
pub struct LoraAdapter {
    pub rank: usize,
    pub targets: LoraTargets,
    /// alpha / rank.
    pub scale: f32,
    /// `pairs[layer]["q"|"k"|"v"|"o"]`.
    pub pairs: Vec<HashMap<&'static str, LoraPair>>,
}

impl LoraAdapter {
    /// Forward delta for one target via the fused Pallas artifact
    /// (bucketed tokens), with a native fallback when the activation is
    /// tiny or no bucket/artifact fits.
    pub fn delta(&self, cx: &HookCtx, layer: usize, target: &'static str,
                 x: &Tensor) -> Result<Option<Tensor>> {
        if !self.targets.on(target) {
            return Ok(None);
        }
        let pair = &self.pairs[layer][target];
        let t = x.shape[0];
        // For tiny activations (decode steps) the PJRT dispatch costs
        // ~100x the math: run the adapter natively on the client — the
        // paper's observation that client-side compute is light enough
        // for weak devices applies to the host CPU here (perf log in
        // EXPERIMENTS.md §Perf).
        if t < 8 {
            return Ok(Some(apply_lora_native(x, pair, self.scale)));
        }
        let d = cx.cfg.d_model;
        let Some(tb) = bucket_for(t, TOKEN_BUCKETS) else {
            return Ok(Some(apply_lora_native(x, pair, self.scale)));
        };
        let name = format!("lora_fwd_t{tb}_{d}x{r}x{d}", r = self.rank);
        if !cx.engine.has_artifact(&name) {
            return Ok(Some(apply_lora_native(x, pair, self.scale)));
        }
        let xp = x.pad_rows(tb);
        let out = cx.engine.execute(&name, &[&xp, &pair.a, &pair.b])?;
        Ok(Some(ops::scale(&out[0].slice_rows(0, t), self.scale)))
    }

    /// Backward for one target through the fused artifact:
    /// `(dA, dB, dX)`, all already multiplied by the adapter scale.
    pub fn delta_bwd(&self, cx: &HookCtx, layer: usize,
                     target: &'static str, x: &Tensor, dy: &Tensor)
                     -> Result<Option<(Tensor, Tensor, Tensor)>> {
        if !self.targets.on(target) {
            return Ok(None);
        }
        let pair = &self.pairs[layer][target];
        let t = x.shape[0];
        let d = cx.cfg.d_model;
        let tb = bucket_for(t, TOKEN_BUCKETS)
            .context("token count exceeds lora bwd buckets")?;
        let name = format!("lora_bwd_t{tb}_{d}x{r}x{d}", r = self.rank);
        let xp = x.pad_rows(tb);
        let dyp = dy.pad_rows(tb);
        let out =
            cx.engine.execute(&name, &[&xp, &dyp, &pair.a, &pair.b])?;
        Ok(Some((
            ops::scale(&out[0], self.scale),
            ops::scale(&out[1], self.scale),
            ops::scale(&out[2].slice_rows(0, t), self.scale),
        )))
    }

    /// Offset of `(layer, target)`'s A block in the flattened parameter
    /// layout (layer-major, target order q,k,v,o, A then B).
    fn flat_offset(&self, layer: usize, target: &str) -> Option<usize> {
        let list = self.targets.list();
        let mut off = 0;
        for (l, m) in self.pairs.iter().enumerate() {
            for t in &list {
                let p = &m[t];
                if l == layer && *t == target {
                    return Some(off);
                }
                off += p.a.len() + p.b.len();
            }
        }
        None
    }

    pub fn n_params(&self) -> usize {
        self.pairs
            .iter()
            .flat_map(|m| m.values())
            .map(|p| p.a.len() + p.b.len())
            .sum()
    }

    fn flatten_into(&self, out: &mut Vec<f32>) {
        for m in &self.pairs {
            for t in self.targets.list() {
                let p = &m[t];
                out.extend_from_slice(p.a.as_f32());
                out.extend_from_slice(p.b.as_f32());
            }
        }
    }

    fn unflatten_from(&mut self, take: &mut impl FnMut(&mut Tensor)) {
        let list = self.targets.list();
        for m in &mut self.pairs {
            for t in &list {
                let p = m.get_mut(t)
                    .expect("pairs hold every listed target by \
                             construction");
                take(&mut p.a);
                take(&mut p.b);
            }
        }
    }
}

impl AdapterHooks for LoraAdapter {
    fn qkv_delta(&self, cx: &HookCtx, layer: usize, a_in: &Tensor,
                 q: &mut Tensor, k: &mut Tensor, v: &mut Tensor)
                 -> Result<()> {
        if let Some(dq) = self.delta(cx, layer, "q", a_in)? {
            ops::add_assign(q, &dq);
        }
        if let Some(dk) = self.delta(cx, layer, "k", a_in)? {
            ops::add_assign(k, &dk);
        }
        if let Some(dv) = self.delta(cx, layer, "v", a_in)? {
            ops::add_assign(v, &dv);
        }
        Ok(())
    }

    fn attn_out_delta(&self, cx: &HookCtx, layer: usize,
                      attn_merged: &Tensor, o: &mut Tensor) -> Result<()> {
        if let Some(d) = self.delta(cx, layer, "o", attn_merged)? {
            ops::add_assign(o, &d);
        }
        Ok(())
    }

    #[allow(clippy::too_many_arguments)]
    fn qkv_delta_bwd(&self, cx: &HookCtx, layer: usize, a_in: &Tensor,
                     dq: &Tensor, dk: &Tensor, dv: &Tensor,
                     grads: &mut AdapterGrads) -> Result<Option<Tensor>> {
        let mut extra: Option<Tensor> = None;
        for (target, dt) in [("q", dq), ("k", dk), ("v", dv)] {
            if let Some((da, db, dx)) =
                self.delta_bwd(cx, layer, target, a_in, dt)?
            {
                let off = self.flat_offset(layer, target)
                    .expect("delta_bwd only fires on active targets");
                grads.accumulate(off, da.len(), &da, &db);
                match &mut extra {
                    Some(e) => ops::add_assign(e, &dx),
                    None => extra = Some(dx),
                }
            }
        }
        Ok(extra)
    }

    fn attn_out_delta_bwd(&self, cx: &HookCtx, layer: usize,
                          attn_merged: &Tensor, do_: &Tensor,
                          grads: &mut AdapterGrads)
                          -> Result<Option<Tensor>> {
        let Some((da, db, dx)) =
            self.delta_bwd(cx, layer, "o", attn_merged, do_)?
        else {
            return Ok(None);
        };
        let off = self.flat_offset(layer, "o")
            .expect("delta_bwd only fires on active targets");
        grads.accumulate(off, da.len(), &da, &db);
        Ok(Some(dx))
    }

    fn trainable(&self) -> bool {
        true
    }
}

// ---------------------------------------------------------------------------
// IA3
// ---------------------------------------------------------------------------

/// IA3: learned elementwise rescaling of k, v and the MLP intermediate.
#[derive(Debug, Clone)]
pub struct Ia3Adapter {
    /// Per layer: elementwise scales for k, v (each (D,)) and the mlp
    /// intermediate (D_ff,).
    pub k_scale: Vec<Tensor>,
    pub v_scale: Vec<Tensor>,
    pub ff_scale: Vec<Tensor>,
}

impl Ia3Adapter {
    pub fn n_params(&self) -> usize {
        self.k_scale.iter().map(|t| t.len()).sum::<usize>()
            + self.v_scale.iter().map(|t| t.len()).sum::<usize>()
            + self.ff_scale.iter().map(|t| t.len()).sum::<usize>()
    }

    fn flatten_into(&self, out: &mut Vec<f32>) {
        for t in self.k_scale.iter()
            .chain(&self.v_scale)
            .chain(&self.ff_scale)
        {
            out.extend_from_slice(t.as_f32());
        }
    }

    fn unflatten_from(&mut self, take: &mut impl FnMut(&mut Tensor)) {
        for t in self.k_scale.iter_mut()
            .chain(self.v_scale.iter_mut())
            .chain(self.ff_scale.iter_mut())
        {
            take(t);
        }
    }
}

impl AdapterHooks for Ia3Adapter {
    fn kv_scale(&self, layer: usize, k: &mut Tensor, v: &mut Tensor) {
        *k = ia3_apply(k, &self.k_scale[layer]);
        *v = ia3_apply(v, &self.v_scale[layer]);
    }

    fn kv_scale_bwd(&self, layer: usize, dk: &Tensor, dv: &Tensor)
                    -> (Tensor, Tensor) {
        // dx = dy * scale (dscale is dropped: IA3 is inference-only in
        // this implementation — its gradients are not in the flat layout)
        (
            ia3_apply(dk, &self.k_scale[layer]),
            ia3_apply(dv, &self.v_scale[layer]),
        )
    }

    fn ffn_scale(&self, layer: usize, u_pre: &mut Tensor) {
        *u_pre = ia3_apply(u_pre, &self.ff_scale[layer]);
    }

    fn ffn_scale_bwd(&self, layer: usize, u_pre: &Tensor, dy: &Tensor)
                     -> Tensor {
        let (_dscale, dx) = ia3_bwd(u_pre, &self.ff_scale[layer], dy);
        dx
    }
}

// ---------------------------------------------------------------------------
// Prefix tuning
// ---------------------------------------------------------------------------

/// Prefix tuning: a learned per-layer KV prefix occupying cache rows
/// (but not token positions) ahead of the real sequence.
#[derive(Debug, Clone)]
pub struct PrefixAdapter {
    pub prefix_len: usize,
    /// Learned per-layer KV prefix, each (BH, P, H).
    pub k_prefix: Vec<Tensor>,
    pub v_prefix: Vec<Tensor>,
}

impl PrefixAdapter {
    pub fn n_params(&self) -> usize {
        self.k_prefix.iter().map(|t| t.len()).sum::<usize>()
            + self.v_prefix.iter().map(|t| t.len()).sum::<usize>()
    }

    fn flatten_into(&self, out: &mut Vec<f32>) {
        for t in self.k_prefix.iter().chain(&self.v_prefix) {
            out.extend_from_slice(t.as_f32());
        }
    }

    fn unflatten_from(&mut self, take: &mut impl FnMut(&mut Tensor)) {
        for t in self.k_prefix.iter_mut()
            .chain(self.v_prefix.iter_mut())
        {
            take(t);
        }
    }
}

impl AdapterHooks for PrefixAdapter {
    fn seed_kv(&self, layer: usize) -> Option<(&Tensor, &Tensor)> {
        Some((&self.k_prefix[layer], &self.v_prefix[layer]))
    }
}

// ---------------------------------------------------------------------------
// The adapter sum type (storage / construction / optimizer layout)
// ---------------------------------------------------------------------------

/// A client's adapter state.  Behavior flows through
/// [`Adapter::hooks`]; this enum only owns the parameters and the
/// flattened optimizer layout.
#[derive(Debug, Clone)]
pub enum Adapter {
    Lora(LoraAdapter),
    Ia3(Ia3Adapter),
    Prefix(PrefixAdapter),
}

impl Adapter {
    /// The behavior object the layer walker calls into.
    pub fn hooks(&self) -> &dyn AdapterHooks {
        match self {
            Adapter::Lora(a) => a,
            Adapter::Ia3(a) => a,
            Adapter::Prefix(a) => a,
        }
    }

    /// Load the deterministic LoRA init exported by aot.py
    /// (`adapters_<model>.bin`, keys `r{rank}.l{l}.{t}.{a|b}`).
    pub fn lora_from_artifacts(cfg: &ModelConfig, dir: &std::path::Path,
                               rank: usize, targets: LoraTargets,
                               scale: f32) -> Result<Adapter> {
        let all = container::read_tensors(
            &dir.join(format!("adapters_{}.bin", cfg.name)))?;
        let mut pairs = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut m = HashMap::new();
            for t in targets.list() {
                let a = all
                    .get(&format!("r{rank}.l{l}.{t}.a"))
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!(
                        "adapter init missing r{rank}.l{l}.{t}.a"))?;
                let b = all
                    .get(&format!("r{rank}.l{l}.{t}.b"))
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!(
                        "adapter init missing r{rank}.l{l}.{t}.b"))?;
                m.insert(t, LoraPair { a, b });
            }
            pairs.push(m);
        }
        Ok(Adapter::Lora(LoraAdapter { rank, targets, scale, pairs }))
    }

    /// Fresh IA3 adapter (scales initialized to 1 = identity).
    pub fn ia3(cfg: &ModelConfig) -> Adapter {
        let ones = |n: usize| Tensor::from_f32(vec![1.0; n], &[n]);
        Adapter::Ia3(Ia3Adapter {
            k_scale: (0..cfg.n_layers).map(|_| ones(cfg.d_model)).collect(),
            v_scale: (0..cfg.n_layers).map(|_| ones(cfg.d_model)).collect(),
            ff_scale: (0..cfg.n_layers).map(|_| ones(cfg.d_ff)).collect(),
        })
    }

    /// Fresh prefix adapter with a small deterministic init.
    pub fn prefix(cfg: &ModelConfig, batch: usize, prefix_len: usize,
                  seed: u64) -> Adapter {
        let bh = batch * cfg.n_heads;
        let h = cfg.d_head();
        let mut gen = crate::coordinator::privacy::NoiseGen::new(seed, 0.1);
        let mk = |g: &mut crate::coordinator::privacy::NoiseGen| {
            g.tensor(&[bh, prefix_len, h])
        };
        Adapter::Prefix(PrefixAdapter {
            prefix_len,
            k_prefix: (0..cfg.n_layers).map(|_| mk(&mut gen)).collect(),
            v_prefix: (0..cfg.n_layers).map(|_| mk(&mut gen)).collect(),
        })
    }

    /// Trainable parameter count.
    pub fn n_params(&self) -> usize {
        match self {
            Adapter::Lora(a) => a.n_params(),
            Adapter::Ia3(a) => a.n_params(),
            Adapter::Prefix(a) => a.n_params(),
        }
    }

    /// Flatten all trainable parameters into one vector (optimizer order
    /// is deterministic: layer-major, target order q,k,v,o then a,b).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_params());
        match self {
            Adapter::Lora(a) => a.flatten_into(&mut out),
            Adapter::Ia3(a) => a.flatten_into(&mut out),
            Adapter::Prefix(a) => a.flatten_into(&mut out),
        }
        out
    }

    /// Inverse of [`Adapter::flatten`].
    pub fn unflatten(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.n_params() {
            bail!("unflatten: {} vs {}", flat.len(), self.n_params());
        }
        let mut off = 0;
        let mut take = |t: &mut Tensor| {
            let n = t.len();
            t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        };
        match self {
            Adapter::Lora(a) => a.unflatten_from(&mut take),
            Adapter::Ia3(a) => a.unflatten_from(&mut take),
            Adapter::Prefix(a) => a.unflatten_from(&mut take),
        }
        Ok(())
    }
}

/// IA3 application: y = x * scale (broadcast last dim).
pub fn ia3_apply(x: &Tensor, scale: &Tensor) -> Tensor {
    let (t, d) = (x.shape[0], x.shape[1]);
    assert_eq!(scale.len(), d);
    let (xs, ss) = (x.as_f32(), scale.as_f32());
    let mut out = vec![0.0f32; t * d];
    for r in 0..t {
        for c in 0..d {
            out[r * d + c] = xs[r * d + c] * ss[c];
        }
    }
    Tensor::from_f32(out, &[t, d])
}

/// IA3 gradients: (d_scale = sum_t x*dy, dx = dy*scale).
pub fn ia3_bwd(x: &Tensor, scale: &Tensor, dy: &Tensor)
               -> (Tensor, Tensor) {
    let (t, d) = (x.shape[0], x.shape[1]);
    let (xs, ss, dys) = (x.as_f32(), scale.as_f32(), dy.as_f32());
    let mut dscale = vec![0.0f32; d];
    let mut dx = vec![0.0f32; t * d];
    for r in 0..t {
        for c in 0..d {
            dscale[c] += xs[r * d + c] * dys[r * d + c];
            dx[r * d + c] = dys[r * d + c] * ss[c];
        }
    }
    (Tensor::from_f32(dscale, &[d]), Tensor::from_f32(dx, &[t, d]))
}

/// Gradient accumulator with the same flattened layout as the adapter.
#[derive(Debug, Clone)]
pub struct AdapterGrads {
    pub flat: Vec<f32>,
}

impl AdapterGrads {
    pub fn zeros_like(a: &Adapter) -> Self {
        AdapterGrads { flat: vec![0.0; a.n_params()] }
    }

    /// Accumulate an `(dA, dB)` pair at flat offset `off` (`a_len` =
    /// length of the A block, so dB lands at `off + a_len`).
    pub fn accumulate(&mut self, off: usize, a_len: usize, da: &Tensor,
                      db: &Tensor) {
        for (i, g) in da.as_f32().iter().enumerate() {
            self.flat[off + i] += g;
        }
        let boff = off + a_len;
        for (i, g) in db.as_f32().iter().enumerate() {
            self.flat[boff + i] += g;
        }
    }

    /// Accumulate a LoRA (dA, dB) pair at its flattened offset.
    pub fn add_lora(&mut self, adapter: &Adapter, layer: usize,
                    target: &str, da: &Tensor, db: &Tensor) {
        let Adapter::Lora(lora) = adapter else {
            panic!("add_lora on non-LoRA adapter");
        };
        let off = lora
            .flat_offset(layer, target)
            .unwrap_or_else(|| {
                panic!("lora target l{layer}.{target} not found")
            });
        self.accumulate(off, da.len(), da, db);
    }

    pub fn scale(&mut self, s: f32) {
        for g in &mut self.flat {
            *g *= s;
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.flat.iter().map(|g| g * g).sum::<f32>().sqrt()
    }
}

/// LoRA delta application used by the clients' forward when the fused
/// PJRT artifact is unavailable or not worth the dispatch:
/// `y = scale * (x A) B` natively.
pub fn apply_lora_native(x: &Tensor, pair: &LoraPair, scale: f32)
                         -> Tensor {
    let xa = ops::matmul(x, &pair.a);
    let xab = ops::matmul(&xa, &pair.b);
    ops::scale(&xab, scale)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::config::SYM_TINY;

    fn tiny_lora() -> Adapter {
        let d = 64;
        let r = 8;
        let mut pairs = Vec::new();
        for l in 0..4 {
            let mut m = HashMap::new();
            for t in ["q", "k", "v", "o"] {
                let a = Tensor::from_f32(
                    (0..d * r).map(|i| (i + l) as f32 * 1e-3).collect(),
                    &[d, r]);
                let b = Tensor::from_f32(
                    (0..r * d).map(|i| (i * 2 + l) as f32 * 1e-3).collect(),
                    &[r, d]);
                m.insert(t, LoraPair { a, b });
            }
            pairs.push(m);
        }
        Adapter::Lora(LoraAdapter {
            rank: r,
            targets: LoraTargets::QKVO,
            scale: 2.0,
            pairs,
        })
    }

    #[test]
    fn flatten_roundtrip() {
        let mut a = tiny_lora();
        let flat = a.flatten();
        assert_eq!(flat.len(), a.n_params());
        let mut mutated = flat.clone();
        mutated[0] += 1.0;
        mutated[flat.len() - 1] -= 2.0;
        a.unflatten(&mutated).unwrap();
        assert_eq!(a.flatten(), mutated);
    }

    #[test]
    fn param_counts_match_config_formula() {
        let a = tiny_lora();
        assert_eq!(a.n_params() as u64, SYM_TINY.lora_params(8, 4));
    }

    #[test]
    fn grads_accumulate_at_right_offset() {
        let a = tiny_lora();
        let mut g = AdapterGrads::zeros_like(&a);
        let da = Tensor::from_f32(vec![1.0; 64 * 8], &[64, 8]);
        let db = Tensor::from_f32(vec![2.0; 8 * 64], &[8, 64]);
        g.add_lora(&a, 1, "k", &da, &db);
        // layer 1, target k: offset = (4 pairs of layer0 + q of layer1)
        let pair = 64 * 8 + 8 * 64;
        let off = 4 * pair + pair;
        assert_eq!(g.flat[off - 1], 0.0);
        assert_eq!(g.flat[off], 1.0);
        assert_eq!(g.flat[off + 64 * 8], 2.0);
    }

    #[test]
    fn ia3_identity_at_ones() {
        let x = Tensor::from_f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let s = Tensor::from_f32(vec![1.0, 1.0], &[2]);
        assert_eq!(ia3_apply(&x, &s), x);
    }

    #[test]
    fn ia3_bwd_shapes_and_values() {
        let x = Tensor::from_f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let s = Tensor::from_f32(vec![0.5, 2.0], &[2]);
        let dy = Tensor::from_f32(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
        let (ds, dx) = ia3_bwd(&x, &s, &dy);
        assert_eq!(ds.as_f32(), &[4.0, 6.0]); // sum of x per column
        assert_eq!(dx.as_f32(), &[0.5, 2.0, 0.5, 2.0]);
    }

    #[test]
    fn ia3_hooks_scale_and_unscale() {
        let Adapter::Ia3(mut ia3) = Adapter::ia3(&SYM_TINY) else {
            unreachable!()
        };
        // identity scales: hooks must be exact no-ops
        let x = Tensor::from_f32(
            (0..2 * SYM_TINY.d_model).map(|i| i as f32).collect(),
            &[2, SYM_TINY.d_model]);
        let (mut k, mut v) = (x.clone(), x.clone());
        ia3.kv_scale(0, &mut k, &mut v);
        assert_eq!(k, x);
        // non-identity scale roundtrips through the backward map
        for s in ia3.ff_scale[1].as_f32_mut() {
            *s = 2.0;
        }
        let mut u = Tensor::from_f32(
            vec![1.0; SYM_TINY.d_ff], &[1, SYM_TINY.d_ff]);
        let u_pre = u.clone();
        ia3.ffn_scale(1, &mut u);
        assert_eq!(u.as_f32()[0], 2.0);
        let dy = Tensor::from_f32(
            vec![1.0; SYM_TINY.d_ff], &[1, SYM_TINY.d_ff]);
        let dx = ia3.ffn_scale_bwd(1, &u_pre, &dy);
        assert_eq!(dx.as_f32()[0], 2.0);
    }

    #[test]
    fn prefix_hook_seeds_every_layer() {
        let Adapter::Prefix(p) = Adapter::prefix(&SYM_TINY, 1, 4, 7)
        else {
            unreachable!()
        };
        for l in 0..SYM_TINY.n_layers {
            let (k, v) = p.seed_kv(l).unwrap();
            assert_eq!(k.shape, vec![SYM_TINY.n_heads, 4,
                                     SYM_TINY.d_head()]);
            assert_eq!(v.shape, k.shape);
        }
        // other hooks stay identity
        assert!(!p.trainable());
    }

    #[test]
    fn no_adapter_hooks_are_identity() {
        let x = Tensor::from_f32(vec![1.0, 2.0], &[1, 2]);
        let (mut k, mut v) = (x.clone(), x.clone());
        NO_ADAPTER.kv_scale(0, &mut k, &mut v);
        assert_eq!(k, x);
        assert_eq!(v, x);
        assert!(NO_ADAPTER.seed_kv(0).is_none());
        assert!(!NO_ADAPTER.trainable());
    }

    #[test]
    fn table2_configs() {
        assert_eq!(lora_table2(1), (8, LoraTargets::Q_ONLY));
        assert_eq!(lora_table2(4).0, 64);
        assert_eq!(lora_table2(3).1.count(), 4);
    }
}
