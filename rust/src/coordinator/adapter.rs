//! PEFT adapters — client-owned trainable state.
//!
//! Symbiosis supports *different* PEFT methods per client against the
//! same shared base (design goal 6).  Implemented: **LoRA** (the paper's
//! evaluation workhorse, Table 2 configs), **IA3** (elementwise
//! rescaling), and **Prefix** tuning (learned KV prefix per layer).
//! Adapter math runs client-side: LoRA through the fused Pallas artifact
//! when available, IA3/Prefix natively (they are elementwise/concat
//! work, not matmuls).

use std::collections::HashMap;

use anyhow::{bail, Result};

use crate::config::ModelConfig;
use crate::tensor::{container, ops, Tensor};

/// Which projections a LoRA adapter applies to (paper Table 2: LoRA1 =
/// (8,[q]) … LoRA4 = (64,[q,k,v,o])).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LoraTargets {
    pub q: bool,
    pub k: bool,
    pub v: bool,
    pub o: bool,
}

impl LoraTargets {
    pub const Q_ONLY: LoraTargets =
        LoraTargets { q: true, k: false, v: false, o: false };
    pub const QKVO: LoraTargets =
        LoraTargets { q: true, k: true, v: true, o: true };

    pub fn count(&self) -> usize {
        [self.q, self.k, self.v, self.o].iter().filter(|&&b| b).count()
    }

    pub fn list(&self) -> Vec<&'static str> {
        let mut v = Vec::new();
        if self.q { v.push("q"); }
        if self.k { v.push("k"); }
        if self.v { v.push("v"); }
        if self.o { v.push("o"); }
        v
    }
}

/// The paper's Table 2 adapter configurations.
pub fn lora_table2(which: usize) -> (usize, LoraTargets) {
    match which {
        1 => (8, LoraTargets::Q_ONLY),
        2 => (64, LoraTargets::Q_ONLY),
        3 => (8, LoraTargets::QKVO),
        4 => (64, LoraTargets::QKVO),
        _ => panic!("Table 2 defines LoRA 1..4"),
    }
}

/// One LoRA pair for one target projection of one block.
#[derive(Debug, Clone)]
pub struct LoraPair {
    pub a: Tensor, // (D, r)
    pub b: Tensor, // (r, D)
}

/// A client's adapter state.
#[derive(Debug, Clone)]
pub enum Adapter {
    Lora {
        rank: usize,
        targets: LoraTargets,
        /// alpha / rank.
        scale: f32,
        /// `pairs[layer]["q"|"k"|"v"|"o"]`.
        pairs: Vec<HashMap<&'static str, LoraPair>>,
    },
    Ia3 {
        /// Per layer: elementwise scales for k, v (each (D,)) and mlp
        /// intermediate (D_ff,).
        k_scale: Vec<Tensor>,
        v_scale: Vec<Tensor>,
        ff_scale: Vec<Tensor>,
    },
    Prefix {
        /// Learned per-layer KV prefix, each (BH, P, H).
        prefix_len: usize,
        k_prefix: Vec<Tensor>,
        v_prefix: Vec<Tensor>,
    },
}

impl Adapter {
    /// Load the deterministic LoRA init exported by aot.py
    /// (`adapters_<model>.bin`, keys `r{rank}.l{l}.{t}.{a|b}`).
    pub fn lora_from_artifacts(cfg: &ModelConfig, dir: &std::path::Path,
                               rank: usize, targets: LoraTargets,
                               scale: f32) -> Result<Adapter> {
        let all = container::read_tensors(
            &dir.join(format!("adapters_{}.bin", cfg.name)))?;
        let mut pairs = Vec::with_capacity(cfg.n_layers);
        for l in 0..cfg.n_layers {
            let mut m = HashMap::new();
            for t in targets.list() {
                let a = all
                    .get(&format!("r{rank}.l{l}.{t}.a"))
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!(
                        "adapter init missing r{rank}.l{l}.{t}.a"))?;
                let b = all
                    .get(&format!("r{rank}.l{l}.{t}.b"))
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!(
                        "adapter init missing r{rank}.l{l}.{t}.b"))?;
                m.insert(t, LoraPair { a, b });
            }
            pairs.push(m);
        }
        Ok(Adapter::Lora { rank, targets, scale, pairs })
    }

    /// Fresh IA3 adapter (scales initialized to 1 = identity).
    pub fn ia3(cfg: &ModelConfig) -> Adapter {
        let ones = |n: usize| Tensor::from_f32(vec![1.0; n], &[n]);
        Adapter::Ia3 {
            k_scale: (0..cfg.n_layers).map(|_| ones(cfg.d_model)).collect(),
            v_scale: (0..cfg.n_layers).map(|_| ones(cfg.d_model)).collect(),
            ff_scale: (0..cfg.n_layers).map(|_| ones(cfg.d_ff)).collect(),
        }
    }

    /// Fresh prefix adapter with a small deterministic init.
    pub fn prefix(cfg: &ModelConfig, batch: usize, prefix_len: usize,
                  seed: u64) -> Adapter {
        let bh = batch * cfg.n_heads;
        let h = cfg.d_head();
        let mut gen = crate::coordinator::privacy::NoiseGen::new(seed, 0.1);
        let mk = |g: &mut crate::coordinator::privacy::NoiseGen| {
            g.tensor(&[bh, prefix_len, h])
        };
        Adapter::Prefix {
            prefix_len,
            k_prefix: (0..cfg.n_layers).map(|_| mk(&mut gen)).collect(),
            v_prefix: (0..cfg.n_layers).map(|_| mk(&mut gen)).collect(),
        }
    }

    /// Trainable parameter count.
    pub fn n_params(&self) -> usize {
        match self {
            Adapter::Lora { pairs, .. } => pairs
                .iter()
                .flat_map(|m| m.values())
                .map(|p| p.a.len() + p.b.len())
                .sum(),
            Adapter::Ia3 { k_scale, v_scale, ff_scale } => {
                k_scale.iter().map(|t| t.len()).sum::<usize>()
                    + v_scale.iter().map(|t| t.len()).sum::<usize>()
                    + ff_scale.iter().map(|t| t.len()).sum::<usize>()
            }
            Adapter::Prefix { k_prefix, v_prefix, .. } => {
                k_prefix.iter().map(|t| t.len()).sum::<usize>()
                    + v_prefix.iter().map(|t| t.len()).sum::<usize>()
            }
        }
    }

    /// Flatten all trainable parameters into one vector (optimizer order
    /// is deterministic: layer-major, target order q,k,v,o then a,b).
    pub fn flatten(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_params());
        match self {
            Adapter::Lora { pairs, targets, .. } => {
                for m in pairs {
                    for t in targets.list() {
                        let p = &m[t];
                        out.extend_from_slice(p.a.as_f32());
                        out.extend_from_slice(p.b.as_f32());
                    }
                }
            }
            Adapter::Ia3 { k_scale, v_scale, ff_scale } => {
                for t in k_scale.iter().chain(v_scale).chain(ff_scale) {
                    out.extend_from_slice(t.as_f32());
                }
            }
            Adapter::Prefix { k_prefix, v_prefix, .. } => {
                for t in k_prefix.iter().chain(v_prefix) {
                    out.extend_from_slice(t.as_f32());
                }
            }
        }
        out
    }

    /// Inverse of [`Adapter::flatten`].
    pub fn unflatten(&mut self, flat: &[f32]) -> Result<()> {
        if flat.len() != self.n_params() {
            bail!("unflatten: {} vs {}", flat.len(), self.n_params());
        }
        let mut off = 0;
        let mut take = |t: &mut Tensor| {
            let n = t.len();
            t.as_f32_mut().copy_from_slice(&flat[off..off + n]);
            off += n;
        };
        match self {
            Adapter::Lora { pairs, targets, .. } => {
                let list = targets.list();
                for m in pairs {
                    for t in &list {
                        let p = m.get_mut(t).unwrap();
                        take(&mut p.a);
                        take(&mut p.b);
                    }
                }
            }
            Adapter::Ia3 { k_scale, v_scale, ff_scale } => {
                for t in k_scale.iter_mut().chain(v_scale).chain(ff_scale) {
                    take(t);
                }
            }
            Adapter::Prefix { k_prefix, v_prefix, .. } => {
                for t in k_prefix.iter_mut().chain(v_prefix) {
                    take(t);
                }
            }
        }
        Ok(())
    }

    /// IA3 application: y = x * scale (broadcast last dim).
    pub fn ia3_apply(x: &Tensor, scale: &Tensor) -> Tensor {
        let (t, d) = (x.shape[0], x.shape[1]);
        assert_eq!(scale.len(), d);
        let (xs, ss) = (x.as_f32(), scale.as_f32());
        let mut out = vec![0.0f32; t * d];
        for r in 0..t {
            for c in 0..d {
                out[r * d + c] = xs[r * d + c] * ss[c];
            }
        }
        Tensor::from_f32(out, &[t, d])
    }

    /// IA3 gradients: (d_scale = sum_t x*dy, dx = dy*scale).
    pub fn ia3_bwd(x: &Tensor, scale: &Tensor, dy: &Tensor)
                   -> (Tensor, Tensor) {
        let (t, d) = (x.shape[0], x.shape[1]);
        let (xs, ss, dys) = (x.as_f32(), scale.as_f32(), dy.as_f32());
        let mut dscale = vec![0.0f32; d];
        let mut dx = vec![0.0f32; t * d];
        for r in 0..t {
            for c in 0..d {
                dscale[c] += xs[r * d + c] * dys[r * d + c];
                dx[r * d + c] = dys[r * d + c] * ss[c];
            }
        }
        (Tensor::from_f32(dscale, &[d]), Tensor::from_f32(dx, &[t, d]))
    }
}

/// Gradient accumulator with the same flattened layout as the adapter.
#[derive(Debug, Clone)]
pub struct AdapterGrads {
    pub flat: Vec<f32>,
}

impl AdapterGrads {
    pub fn zeros_like(a: &Adapter) -> Self {
        AdapterGrads { flat: vec![0.0; a.n_params()] }
    }

    /// Accumulate a LoRA (dA, dB) pair at its flattened offset.
    pub fn add_lora(&mut self, adapter: &Adapter, layer: usize,
                    target: &str, da: &Tensor, db: &Tensor) {
        let Adapter::Lora { pairs, targets, .. } = adapter else {
            panic!("add_lora on non-LoRA adapter");
        };
        let list = targets.list();
        let mut off = 0;
        for (l, m) in pairs.iter().enumerate() {
            for t in &list {
                let p = &m[t];
                if l == layer && *t == target {
                    for (i, g) in da.as_f32().iter().enumerate() {
                        self.flat[off + i] += g;
                    }
                    let boff = off + p.a.len();
                    for (i, g) in db.as_f32().iter().enumerate() {
                        self.flat[boff + i] += g;
                    }
                    return;
                }
                off += p.a.len() + p.b.len();
            }
        }
        panic!("lora target l{layer}.{target} not found");
    }

    pub fn scale(&mut self, s: f32) {
        for g in &mut self.flat {
            *g *= s;
        }
    }

    pub fn l2_norm(&self) -> f32 {
        self.flat.iter().map(|g| g * g).sum::<f32>().sqrt()
    }
}

/// Convenience: LoRA delta application used by the clients' forward —
/// y += scale * (x A) B via the provided apply function (PJRT artifact or
/// native fallback).
pub fn apply_lora_native(x: &Tensor, pair: &LoraPair, scale: f32)
                         -> Tensor {
    let xa = ops::matmul(x, &pair.a);
    let xab = ops::matmul(&xa, &pair.b);
    ops::scale(&xab, scale)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SYM_TINY;

    fn tiny_lora() -> Adapter {
        let d = 64;
        let r = 8;
        let mut pairs = Vec::new();
        for l in 0..4 {
            let mut m = HashMap::new();
            for t in ["q", "k", "v", "o"] {
                let a = Tensor::from_f32(
                    (0..d * r).map(|i| (i + l) as f32 * 1e-3).collect(),
                    &[d, r]);
                let b = Tensor::from_f32(
                    (0..r * d).map(|i| (i * 2 + l) as f32 * 1e-3).collect(),
                    &[r, d]);
                m.insert(t, LoraPair { a, b });
            }
            pairs.push(m);
        }
        Adapter::Lora { rank: r, targets: LoraTargets::QKVO, scale: 2.0,
                        pairs }
    }

    #[test]
    fn flatten_roundtrip() {
        let mut a = tiny_lora();
        let flat = a.flatten();
        assert_eq!(flat.len(), a.n_params());
        let mut mutated = flat.clone();
        mutated[0] += 1.0;
        mutated[flat.len() - 1] -= 2.0;
        a.unflatten(&mutated).unwrap();
        assert_eq!(a.flatten(), mutated);
    }

    #[test]
    fn param_counts_match_config_formula() {
        let a = tiny_lora();
        assert_eq!(a.n_params() as u64, SYM_TINY.lora_params(8, 4));
    }

    #[test]
    fn grads_accumulate_at_right_offset() {
        let a = tiny_lora();
        let mut g = AdapterGrads::zeros_like(&a);
        let da = Tensor::from_f32(vec![1.0; 64 * 8], &[64, 8]);
        let db = Tensor::from_f32(vec![2.0; 8 * 64], &[8, 64]);
        g.add_lora(&a, 1, "k", &da, &db);
        // layer 1, target k: offset = (4 pairs of layer0 + q of layer1)
        let pair = 64 * 8 + 8 * 64;
        let off = 4 * pair + pair;
        assert_eq!(g.flat[off - 1], 0.0);
        assert_eq!(g.flat[off], 1.0);
        assert_eq!(g.flat[off + 64 * 8], 2.0);
    }

    #[test]
    fn ia3_identity_at_ones() {
        let x = Tensor::from_f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let s = Tensor::from_f32(vec![1.0, 1.0], &[2]);
        assert_eq!(Adapter::ia3_apply(&x, &s), x);
    }

    #[test]
    fn ia3_bwd_shapes_and_values() {
        let x = Tensor::from_f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        let s = Tensor::from_f32(vec![0.5, 2.0], &[2]);
        let dy = Tensor::from_f32(vec![1.0, 1.0, 1.0, 1.0], &[2, 2]);
        let (ds, dx) = Adapter::ia3_bwd(&x, &s, &dy);
        assert_eq!(ds.as_f32(), &[4.0, 6.0]); // sum of x per column
        assert_eq!(dx.as_f32(), &[0.5, 2.0, 0.5, 2.0]);
    }

    #[test]
    fn table2_configs() {
        assert_eq!(lora_table2(1), (8, LoraTargets::Q_ONLY));
        assert_eq!(lora_table2(4).0, 64);
        assert_eq!(lora_table2(3).1.count(), 4);
    }
}
