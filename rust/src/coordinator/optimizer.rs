//! Client-side optimizer over flattened adapter parameters.
//!
//! Optimizer state is *client* runtime state in Symbiosis (like the KV
//! cache) — it grows with the adapter, not the base model, and never
//! touches the executor.  The Adam step itself runs through the bucketed
//! `adam_n*` artifact (zero-padded tail: padded grads are 0, so padded
//! params never move); a native fallback exists for odd sizes and tests.

// Optimizer state sits on the training hot path: failures surface as
// typed errors, never panics.
#![deny(clippy::unwrap_used)]

use anyhow::{Context, Result};

use crate::config::{bucket_for, ADAM_BUCKETS};
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// Adam with the same hyperparameters as `kernels/ref.py::adam_step`.
#[derive(Debug)]
pub struct Adam {
    pub lr: f32,
    pub b1: f32,
    pub b2: f32,
    pub eps: f32,
    pub step: u64,
    m: Vec<f32>,
    v: Vec<f32>,
}

impl Adam {
    pub fn new(n_params: usize) -> Self {
        Adam {
            lr: 1e-3,
            b1: 0.9,
            b2: 0.999,
            eps: 1e-8,
            step: 0,
            m: vec![0.0; n_params],
            v: vec![0.0; n_params],
        }
    }

    pub fn with_lr(mut self, lr: f32) -> Self {
        self.lr = lr;
        self
    }

    /// State bytes (2 moments, f32) — the client memory the paper plots.
    pub fn state_bytes(&self) -> u64 {
        (self.m.len() * 2 * 4) as u64
    }

    /// One update through the AOT `adam_n{bucket}` artifact.
    pub fn step_artifact(&mut self, engine: &Engine, params: &mut [f32],
                         grads: &[f32]) -> Result<()> {
        assert_eq!(params.len(), grads.len());
        assert_eq!(params.len(), self.m.len());
        self.step += 1;
        let n = params.len();
        let bucket = bucket_for(n, ADAM_BUCKETS)
            .context("adapter larger than biggest adam bucket")?;
        let pad = |s: &[f32]| {
            let mut v = s.to_vec();
            v.resize(bucket, 0.0);
            Tensor::from_f32(v, &[bucket])
        };
        let (p, g, m, v) =
            (pad(params), pad(grads), pad(&self.m), pad(&self.v));
        let t = Tensor::scalar_f32(self.step as f32);
        let name = format!("adam_n{bucket}");
        let out = engine.execute(&name, &[&p, &g, &m, &v, &t])?;
        params.copy_from_slice(&out[0].as_f32()[..n]);
        self.m.copy_from_slice(&out[1].as_f32()[..n]);
        self.v.copy_from_slice(&out[2].as_f32()[..n]);
        Ok(())
    }

    /// Native update (bit-equivalent formula; used when no engine is at
    /// hand and in property tests).
    pub fn step_native(&mut self, params: &mut [f32], grads: &[f32]) {
        self.step += 1;
        let t = self.step as f32;
        let bc1 = 1.0 - self.b1.powf(t);
        let bc2 = 1.0 - self.b2.powf(t);
        for i in 0..params.len() {
            self.m[i] = self.b1 * self.m[i] + (1.0 - self.b1) * grads[i];
            self.v[i] =
                self.b2 * self.v[i] + (1.0 - self.b2) * grads[i] * grads[i];
            let mhat = self.m[i] / bc1;
            let vhat = self.v[i] / bc2;
            params[i] -= self.lr * mhat / (vhat.sqrt() + self.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_step_descends() {
        let mut adam = Adam::new(3).with_lr(0.1);
        let mut p = vec![1.0f32, -1.0, 0.0];
        let g = vec![1.0f32, -1.0, 0.0];
        adam.step_native(&mut p, &g);
        assert!(p[0] < 1.0);
        assert!(p[1] > -1.0);
        assert_eq!(p[2], 0.0);
    }

    #[test]
    fn repeated_steps_converge_on_quadratic() {
        // minimize f(p) = 0.5 * p^2 -> grad = p
        let mut adam = Adam::new(1).with_lr(0.05);
        let mut p = vec![5.0f32];
        for _ in 0..500 {
            let g = vec![p[0]];
            adam.step_native(&mut p, &g);
        }
        assert!(p[0].abs() < 0.1, "p = {}", p[0]);
    }

    #[test]
    fn state_bytes_scale_with_params() {
        let a = Adam::new(1000);
        assert_eq!(a.state_bytes(), 8000);
    }
}
