//! Split loading of model weights along the Symbiosis lines.
//!
//! Two splits happen here.  `scan` mirrors the paper's model-structure
//! scan (section 3.2): given the full weight container, it partitions
//! parameters into the **base-executor share** (the big frozen linears +
//! embeddings) and the **client share** (norm gains — the tenant loads
//! these next to its adapters).  `split_shards` then cuts the executor
//! share along a [`LayerAssignment`] (section 3.3): each shard executor
//! receives only the contiguous block range it owns — `Arc`-backed
//! tensor views, so the cut moves no bytes — and its `Device` ledger is
//! charged with exactly that resident slice.

#![deny(clippy::unwrap_used)]

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelConfig;
use crate::coordinator::proto::LayerId;
use crate::coordinator::sharding::LayerAssignment;
use crate::tensor::{container, Tensor};

/// Frozen base-model parameters held by the base executor.
#[derive(Debug)]
pub struct BaseWeights {
    pub cfg: ModelConfig,
    pub embed: Tensor,
    pub pos: Tensor,
    pub lm_head_w: Tensor,
    pub lm_head_b: Tensor,
    /// Per block: (wqkv, bqkv, wo, bo, wup, bup, wdown, bdown).
    pub blocks: Vec<BlockWeights>,
}

#[derive(Debug, Clone)]
pub struct BlockWeights {
    pub wqkv: Tensor,
    pub bqkv: Tensor,
    pub wo: Tensor,
    pub bo: Tensor,
    pub wup: Tensor,
    pub bup: Tensor,
    pub wdown: Tensor,
    pub bdown: Tensor,
}

impl BlockWeights {
    /// Parameter bytes of one block — the unit both the full-base and
    /// per-shard ledger sums are built from.
    pub fn param_bytes(&self) -> u64 {
        (self.wqkv.size_bytes() + self.bqkv.size_bytes()
            + self.wo.size_bytes() + self.bo.size_bytes()
            + self.wup.size_bytes() + self.bup.size_bytes()
            + self.wdown.size_bytes() + self.bdown.size_bytes()) as u64
    }
}

/// Client-side non-base parameters (norm gains). Adapters live in
/// `coordinator::adapter`.
#[derive(Debug, Clone)]
pub struct ClientWeights {
    pub norm1: Vec<Tensor>,
    pub norm2: Vec<Tensor>,
    pub norm_f: Tensor,
}

impl BaseWeights {
    /// Weight matrix + bias for a linear base layer.
    pub fn linear(&self, layer: LayerId) -> (&Tensor, &Tensor) {
        match layer {
            LayerId::Qkv(l) => (&self.blocks[l].wqkv, &self.blocks[l].bqkv),
            LayerId::AttnOut(l) => (&self.blocks[l].wo, &self.blocks[l].bo),
            LayerId::MlpUp(l) => (&self.blocks[l].wup, &self.blocks[l].bup),
            LayerId::MlpDown(l) => {
                (&self.blocks[l].wdown, &self.blocks[l].bdown)
            }
            LayerId::LmHead => (&self.lm_head_w, &self.lm_head_b),
            LayerId::Embed => panic!("embed is not a linear layer"),
        }
    }

    /// (Din, Dout) of a linear base layer.
    pub fn linear_dims(&self, layer: LayerId) -> (usize, usize) {
        let (w, _) = self.linear(layer);
        (w.shape[0], w.shape[1])
    }

    /// Pin every frozen tensor for the engine's device-resident literal
    /// cache (see `Tensor::device_pin`): each engine worker converts a
    /// pinned weight to an `xla::Literal` once, instead of once per
    /// layer call.  Idempotent.
    pub fn pin_for_device_cache(&self) {
        self.embed.device_pin();
        self.pos.device_pin();
        self.lm_head_w.device_pin();
        self.lm_head_b.device_pin();
        for b in &self.blocks {
            b.wqkv.device_pin();
            b.bqkv.device_pin();
            b.wo.device_pin();
            b.bo.device_pin();
            b.wup.device_pin();
            b.bup.device_pin();
            b.wdown.device_pin();
            b.bdown.device_pin();
        }
    }

    /// Total parameter bytes held by the executor (memory accounting).
    pub fn param_bytes(&self) -> u64 {
        (self.embed.size_bytes() + self.pos.size_bytes()
            + self.lm_head_w.size_bytes()
            + self.lm_head_b.size_bytes()) as u64
            + self.blocks.iter().map(|b| b.param_bytes()).sum::<u64>()
    }
}

/// One executor shard's slice of the frozen base: a contiguous block
/// range plus the boundary layers (embedding on the first shard, LM
/// head on the last).  Built by [`split_shards`]; owned by one
/// `ShardExecutor` thread.  `Clone` is a refcount bump per tensor
/// (`Arc`-backed), which is what lets the fleet retain each shard's
/// slice as a respawn seed at zero memory cost.
#[derive(Debug, Clone)]
pub struct ShardWeights {
    pub cfg: ModelConfig,
    pub shard: usize,
    /// Absolute index of `blocks[0]`.
    pub block_start: usize,
    pub blocks: Vec<BlockWeights>,
    /// `(embed, pos)` — present on the shard owning block 0 only.
    pub embed: Option<(Tensor, Tensor)>,
    /// `(w, b)` — present on the shard owning the last block only.
    pub lm_head: Option<(Tensor, Tensor)>,
}

impl ShardWeights {
    fn block(&self, l: usize) -> Result<&BlockWeights> {
        if l < self.block_start
            || l >= self.block_start + self.blocks.len()
        {
            bail!("shard {} does not own block {l} (owns {}..{})",
                  self.shard, self.block_start,
                  self.block_start + self.blocks.len());
        }
        Ok(&self.blocks[l - self.block_start])
    }

    /// Whether this shard serves `layer`.
    pub fn owns(&self, layer: LayerId) -> bool {
        match layer {
            LayerId::Embed => self.embed.is_some(),
            LayerId::LmHead => self.lm_head.is_some(),
            _ => layer
                .block()
                .map(|l| self.block(l).is_ok())
                .unwrap_or(false),
        }
    }

    /// Weight matrix + bias for a linear base layer; errors when the
    /// request was mis-routed to a shard that does not own the layer.
    pub fn linear(&self, layer: LayerId) -> Result<(&Tensor, &Tensor)> {
        match layer {
            LayerId::Qkv(l) => {
                self.block(l).map(|b| (&b.wqkv, &b.bqkv))
            }
            LayerId::AttnOut(l) => {
                self.block(l).map(|b| (&b.wo, &b.bo))
            }
            LayerId::MlpUp(l) => {
                self.block(l).map(|b| (&b.wup, &b.bup))
            }
            LayerId::MlpDown(l) => {
                self.block(l).map(|b| (&b.wdown, &b.bdown))
            }
            LayerId::LmHead => self
                .lm_head
                .as_ref()
                .map(|(w, b)| (w, b))
                .ok_or_else(|| anyhow::anyhow!(
                    "shard {} does not own the LM head", self.shard)),
            LayerId::Embed => bail!("embed is not a linear layer"),
        }
    }

    /// Embedding + position tables (first shard only).
    pub fn embed_tables(&self) -> Result<(&Tensor, &Tensor)> {
        self.embed
            .as_ref()
            .map(|(e, p)| (e, p))
            .ok_or_else(|| anyhow::anyhow!(
                "shard {} does not own the embedding", self.shard))
    }

    /// Resident parameter bytes of this slice — what the shard's device
    /// ledger is charged with (~1/shards of the base).
    pub fn param_bytes(&self) -> u64 {
        let mut total = 0u64;
        if let Some((e, p)) = &self.embed {
            total += (e.size_bytes() + p.size_bytes()) as u64;
        }
        if let Some((w, b)) = &self.lm_head {
            total += (w.size_bytes() + b.size_bytes()) as u64;
        }
        total + self.blocks.iter().map(|b| b.param_bytes()).sum::<u64>()
    }
}

/// Cut the executor share into per-shard slices along `assign`.  The
/// blocks move (each tensor keeps exactly one owner); the boundary
/// layers are refcount-bumped views into their shards.
pub fn split_shards(base: BaseWeights, assign: &LayerAssignment)
                    -> Vec<ShardWeights> {
    let BaseWeights { cfg, embed, pos, lm_head_w, lm_head_b, blocks } =
        base;
    debug_assert_eq!(blocks.len(), assign.n_layers());
    let n = assign.shards();
    let mut blocks_iter = blocks.into_iter();
    let mut out = Vec::with_capacity(n);
    for s in 0..n {
        let range = assign.block_range(s);
        let slice: Vec<BlockWeights> =
            blocks_iter.by_ref().take(range.len()).collect();
        out.push(ShardWeights {
            cfg: cfg.clone(),
            shard: s,
            block_start: range.start,
            blocks: slice,
            embed: (s == 0).then(|| (embed.clone(), pos.clone())),
            lm_head: (s == n - 1)
                .then(|| (lm_head_w.clone(), lm_head_b.clone())),
        });
    }
    out
}

/// Scan a full weight container and split it into base / client shares.
pub fn scan(cfg: &ModelConfig, weights: &HashMap<String, Tensor>)
            -> Result<(BaseWeights, ClientWeights)> {
    let get = |k: &str| -> Result<Tensor> {
        weights.get(k).cloned().with_context(|| format!("missing {k}"))
    };
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    let mut norm1 = Vec::new();
    let mut norm2 = Vec::new();
    for l in 0..cfg.n_layers {
        blocks.push(BlockWeights {
            wqkv: get(&format!("l{l}.wqkv"))?,
            bqkv: get(&format!("l{l}.bqkv"))?,
            wo: get(&format!("l{l}.wo"))?,
            bo: get(&format!("l{l}.bo"))?,
            wup: get(&format!("l{l}.wup"))?,
            bup: get(&format!("l{l}.bup"))?,
            wdown: get(&format!("l{l}.wdown"))?,
            bdown: get(&format!("l{l}.bdown"))?,
        });
        norm1.push(get(&format!("l{l}.norm1"))?);
        norm2.push(get(&format!("l{l}.norm2"))?);
    }
    let base = BaseWeights {
        cfg: cfg.clone(),
        embed: get("embed")?,
        pos: get("pos")?,
        lm_head_w: get("lm_head_w")?,
        lm_head_b: get("lm_head_b")?,
        blocks,
    };
    // Frozen for the deployment's lifetime: let engine workers keep the
    // device literals resident instead of re-converting per dispatch.
    base.pin_for_device_cache();
    Ok((base, ClientWeights { norm1, norm2, norm_f: get("norm_f")? }))
}

/// Load + split `artifacts/weights_<model>.bin`.
pub fn load_split(cfg: &ModelConfig, artifact_dir: &Path)
                  -> Result<(BaseWeights, ClientWeights)> {
    let path = artifact_dir.join(format!("weights_{}.bin", cfg.name));
    let weights = container::read_tensors(&path)?;
    scan(cfg, &weights)
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::config::SYM_TINY;

    fn fake_weights(cfg: &ModelConfig) -> HashMap<String, Tensor> {
        let mut m = HashMap::new();
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        m.insert("embed".into(), Tensor::zeros(&[v, d]));
        m.insert("pos".into(), Tensor::zeros(&[cfg.max_seq, d]));
        m.insert("norm_f".into(), Tensor::zeros(&[d]));
        m.insert("lm_head_w".into(), Tensor::zeros(&[d, v]));
        m.insert("lm_head_b".into(), Tensor::zeros(&[v]));
        for l in 0..cfg.n_layers {
            m.insert(format!("l{l}.norm1"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.norm2"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wqkv"), Tensor::zeros(&[d, 3 * d]));
            m.insert(format!("l{l}.bqkv"), Tensor::zeros(&[3 * d]));
            m.insert(format!("l{l}.wo"), Tensor::zeros(&[d, d]));
            m.insert(format!("l{l}.bo"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wup"), Tensor::zeros(&[d, f]));
            m.insert(format!("l{l}.bup"), Tensor::zeros(&[f]));
            m.insert(format!("l{l}.wdown"), Tensor::zeros(&[f, d]));
            m.insert(format!("l{l}.bdown"), Tensor::zeros(&[d]));
        }
        m
    }

    #[test]
    fn scan_splits_base_and_client() {
        let w = fake_weights(&SYM_TINY);
        let (base, client) = scan(&SYM_TINY, &w).unwrap();
        assert_eq!(base.blocks.len(), 4);
        assert_eq!(client.norm1.len(), 4);
        assert_eq!(base.linear_dims(LayerId::Qkv(0)), (64, 192));
        assert_eq!(base.linear_dims(LayerId::MlpDown(1)), (256, 64));
        assert_eq!(base.linear_dims(LayerId::LmHead), (64, 256));
    }

    #[test]
    fn scan_detects_missing_keys() {
        let mut w = fake_weights(&SYM_TINY);
        w.remove("l2.wo");
        assert!(scan(&SYM_TINY, &w).is_err());
    }

    #[test]
    fn split_shards_partitions_blocks_and_bytes() {
        let w = fake_weights(&SYM_TINY);
        let (base, _) = scan(&SYM_TINY, &w).unwrap();
        let total = base.param_bytes();
        let assign = LayerAssignment::contiguous(SYM_TINY.n_layers, 2);
        let shards = split_shards(base, &assign);
        assert_eq!(shards.len(), 2);
        // boundary layers sit on the boundary shards
        assert!(shards[0].embed.is_some());
        assert!(shards[0].lm_head.is_none());
        assert!(shards[1].lm_head.is_some());
        assert!(shards[1].embed.is_none());
        // every block is owned exactly once; bytes are conserved
        assert_eq!(shards.iter().map(|s| s.blocks.len()).sum::<usize>(),
                   SYM_TINY.n_layers);
        assert_eq!(shards.iter().map(|s| s.param_bytes()).sum::<u64>(),
                   total);
        // routing-side lookups agree with ownership
        assert!(shards[0].linear(LayerId::Qkv(0)).is_ok());
        assert!(shards[0].linear(LayerId::Qkv(3)).is_err());
        assert!(shards[1].linear(LayerId::MlpDown(3)).is_ok());
        assert!(shards[1].linear(LayerId::LmHead).is_ok());
        assert!(shards[0].embed_tables().is_ok());
        assert!(shards[1].embed_tables().is_err());
        assert!(shards[0].owns(LayerId::Embed));
        assert!(!shards[1].owns(LayerId::Embed));
    }

    #[test]
    fn base_param_bytes_counts_everything() {
        let w = fake_weights(&SYM_TINY);
        let (base, _) = scan(&SYM_TINY, &w).unwrap();
        assert!(base.param_bytes() > 0);
        // embed + pos + head dominate the tiny config
        let embed_bytes = (256 * 64 * 4) as u64;
        assert!(base.param_bytes() > embed_bytes);
    }
}
