//! Split loading of model weights along the Symbiosis line.
//!
//! `scan` mirrors the paper's model-structure scan (section 3.2): given
//! the full weight container, it partitions parameters into the
//! **base-executor share** (the big frozen linears + embeddings) and the
//! **client share** (norm gains — the tenant loads these next to its
//! adapters).  This is the Rust analogue of replacing frozen layers with
//! `VirtLayer` without touching model code.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{Context, Result};

use crate::config::ModelConfig;
use crate::coordinator::proto::LayerId;
use crate::tensor::{container, Tensor};

/// Frozen base-model parameters held by the base executor.
#[derive(Debug)]
pub struct BaseWeights {
    pub cfg: ModelConfig,
    pub embed: Tensor,
    pub pos: Tensor,
    pub lm_head_w: Tensor,
    pub lm_head_b: Tensor,
    /// Per block: (wqkv, bqkv, wo, bo, wup, bup, wdown, bdown).
    pub blocks: Vec<BlockWeights>,
}

#[derive(Debug)]
pub struct BlockWeights {
    pub wqkv: Tensor,
    pub bqkv: Tensor,
    pub wo: Tensor,
    pub bo: Tensor,
    pub wup: Tensor,
    pub bup: Tensor,
    pub wdown: Tensor,
    pub bdown: Tensor,
}

/// Client-side non-base parameters (norm gains). Adapters live in
/// `coordinator::adapter`.
#[derive(Debug, Clone)]
pub struct ClientWeights {
    pub norm1: Vec<Tensor>,
    pub norm2: Vec<Tensor>,
    pub norm_f: Tensor,
}

impl BaseWeights {
    /// Weight matrix + bias for a linear base layer.
    pub fn linear(&self, layer: LayerId) -> (&Tensor, &Tensor) {
        match layer {
            LayerId::Qkv(l) => (&self.blocks[l].wqkv, &self.blocks[l].bqkv),
            LayerId::AttnOut(l) => (&self.blocks[l].wo, &self.blocks[l].bo),
            LayerId::MlpUp(l) => (&self.blocks[l].wup, &self.blocks[l].bup),
            LayerId::MlpDown(l) => {
                (&self.blocks[l].wdown, &self.blocks[l].bdown)
            }
            LayerId::LmHead => (&self.lm_head_w, &self.lm_head_b),
            LayerId::Embed => panic!("embed is not a linear layer"),
        }
    }

    /// (Din, Dout) of a linear base layer.
    pub fn linear_dims(&self, layer: LayerId) -> (usize, usize) {
        let (w, _) = self.linear(layer);
        (w.shape[0], w.shape[1])
    }

    /// Pin every frozen tensor for the engine's device-resident literal
    /// cache (see `Tensor::device_pin`): each engine worker converts a
    /// pinned weight to an `xla::Literal` once, instead of once per
    /// layer call.  Idempotent.
    pub fn pin_for_device_cache(&self) {
        self.embed.device_pin();
        self.pos.device_pin();
        self.lm_head_w.device_pin();
        self.lm_head_b.device_pin();
        for b in &self.blocks {
            b.wqkv.device_pin();
            b.bqkv.device_pin();
            b.wo.device_pin();
            b.bo.device_pin();
            b.wup.device_pin();
            b.bup.device_pin();
            b.wdown.device_pin();
            b.bdown.device_pin();
        }
    }

    /// Total parameter bytes held by the executor (memory accounting).
    pub fn param_bytes(&self) -> u64 {
        let mut total = self.embed.size_bytes() + self.pos.size_bytes()
            + self.lm_head_w.size_bytes() + self.lm_head_b.size_bytes();
        for b in &self.blocks {
            total += b.wqkv.size_bytes() + b.bqkv.size_bytes()
                + b.wo.size_bytes() + b.bo.size_bytes()
                + b.wup.size_bytes() + b.bup.size_bytes()
                + b.wdown.size_bytes() + b.bdown.size_bytes();
        }
        total as u64
    }
}

/// Scan a full weight container and split it into base / client shares.
pub fn scan(cfg: &ModelConfig, weights: &HashMap<String, Tensor>)
            -> Result<(BaseWeights, ClientWeights)> {
    let get = |k: &str| -> Result<Tensor> {
        weights.get(k).cloned().with_context(|| format!("missing {k}"))
    };
    let mut blocks = Vec::with_capacity(cfg.n_layers);
    let mut norm1 = Vec::new();
    let mut norm2 = Vec::new();
    for l in 0..cfg.n_layers {
        blocks.push(BlockWeights {
            wqkv: get(&format!("l{l}.wqkv"))?,
            bqkv: get(&format!("l{l}.bqkv"))?,
            wo: get(&format!("l{l}.wo"))?,
            bo: get(&format!("l{l}.bo"))?,
            wup: get(&format!("l{l}.wup"))?,
            bup: get(&format!("l{l}.bup"))?,
            wdown: get(&format!("l{l}.wdown"))?,
            bdown: get(&format!("l{l}.bdown"))?,
        });
        norm1.push(get(&format!("l{l}.norm1"))?);
        norm2.push(get(&format!("l{l}.norm2"))?);
    }
    let base = BaseWeights {
        cfg: cfg.clone(),
        embed: get("embed")?,
        pos: get("pos")?,
        lm_head_w: get("lm_head_w")?,
        lm_head_b: get("lm_head_b")?,
        blocks,
    };
    // Frozen for the deployment's lifetime: let engine workers keep the
    // device literals resident instead of re-converting per dispatch.
    base.pin_for_device_cache();
    Ok((base, ClientWeights { norm1, norm2, norm_f: get("norm_f")? }))
}

/// Load + split `artifacts/weights_<model>.bin`.
pub fn load_split(cfg: &ModelConfig, artifact_dir: &Path)
                  -> Result<(BaseWeights, ClientWeights)> {
    let path = artifact_dir.join(format!("weights_{}.bin", cfg.name));
    let weights = container::read_tensors(&path)?;
    scan(cfg, &weights)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SYM_TINY;

    fn fake_weights(cfg: &ModelConfig) -> HashMap<String, Tensor> {
        let mut m = HashMap::new();
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        m.insert("embed".into(), Tensor::zeros(&[v, d]));
        m.insert("pos".into(), Tensor::zeros(&[cfg.max_seq, d]));
        m.insert("norm_f".into(), Tensor::zeros(&[d]));
        m.insert("lm_head_w".into(), Tensor::zeros(&[d, v]));
        m.insert("lm_head_b".into(), Tensor::zeros(&[v]));
        for l in 0..cfg.n_layers {
            m.insert(format!("l{l}.norm1"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.norm2"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wqkv"), Tensor::zeros(&[d, 3 * d]));
            m.insert(format!("l{l}.bqkv"), Tensor::zeros(&[3 * d]));
            m.insert(format!("l{l}.wo"), Tensor::zeros(&[d, d]));
            m.insert(format!("l{l}.bo"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wup"), Tensor::zeros(&[d, f]));
            m.insert(format!("l{l}.bup"), Tensor::zeros(&[f]));
            m.insert(format!("l{l}.wdown"), Tensor::zeros(&[f, d]));
            m.insert(format!("l{l}.bdown"), Tensor::zeros(&[d]));
        }
        m
    }

    #[test]
    fn scan_splits_base_and_client() {
        let w = fake_weights(&SYM_TINY);
        let (base, client) = scan(&SYM_TINY, &w).unwrap();
        assert_eq!(base.blocks.len(), 4);
        assert_eq!(client.norm1.len(), 4);
        assert_eq!(base.linear_dims(LayerId::Qkv(0)), (64, 192));
        assert_eq!(base.linear_dims(LayerId::MlpDown(1)), (256, 64));
        assert_eq!(base.linear_dims(LayerId::LmHead), (64, 256));
    }

    #[test]
    fn scan_detects_missing_keys() {
        let mut w = fake_weights(&SYM_TINY);
        w.remove("l2.wo");
        assert!(scan(&SYM_TINY, &w).is_err());
    }

    #[test]
    fn base_param_bytes_counts_everything() {
        let w = fake_weights(&SYM_TINY);
        let (base, _) = scan(&SYM_TINY, &w).unwrap();
        assert!(base.param_bytes() > 0);
        // embed + pos + head dominate the tiny config
        let embed_bytes = (256 * 64 * 4) as u64;
        assert!(base.param_bytes() > embed_bytes);
    }
}
