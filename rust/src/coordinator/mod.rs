//! The Symbiosis coordinator — the paper's system contribution.
//!
//! One shared, frozen base model serves many tenants; each tenant picks
//! its own PEFT method, resources, and placement.  The public surface is
//! **session-first**: start a [`Deployment`], then spawn per-tenant jobs
//! from it with the two builders —
//!
//! ```no_run
//! # use symbiosis::config::SYM_TINY;
//! # use symbiosis::coordinator::*;
//! # fn main() -> anyhow::Result<()> {
//! # let dir = std::path::PathBuf::from("artifacts");
//! let dep = Deployment::start(&SYM_TINY, &dir,
//!                             BatchPolicy::opportunistic_default(),
//!                             Placement::Local)?;
//!
//! // an inference tenant: LoRA adapter, one request at a time
//! let adapter = Adapter::lora_from_artifacts(&SYM_TINY, &dir, 8,
//!                                            LoraTargets::QKVO, 2.0)?;
//! let mut session = dep.session().adapter(adapter).build()?;
//! let tokens = session.generate(&[1, 2, 3, 4],
//!                               &GenerationConfig::greedy(16))?;
//!
//! // a fine-tuning tenant sharing the same frozen base
//! let lora = Adapter::lora_from_artifacts(&SYM_TINY, &dir, 64,
//!                                         LoraTargets::QKVO, 0.25)?;
//! let mut trainer = dep.trainer().adapter(lora).lr(5e-3).build()?;
//! # Ok(()) }
//! ```
//!
//! Builders own every per-tenant choice (adapter, batch,
//! [`KvPlacement`], link kind, urgency policy, privacy) and do the
//! error-prone wiring — e.g. a prefix adapter's KV seed and the switch
//! to incremental prefill happen automatically.  Failures surface as
//! typed [`SymbiosisError`]s.
//!
//! Module map:
//! * [`base_executor`] — shared frozen-layer service with per-layer
//!   opportunistic batching (sections 3.2, 3.6, 3.7).
//! * [`virt_layer`] — the client-side proxy replacing frozen layers
//!   (Fig. 4).
//! * [`client`] — the layer walker, sessions/trainers, and their
//!   builders; each client drives its own execution (design goal 5).
//! * [`adapter`] — the [`AdapterHooks`] trait and the LoRA/IA3/Prefix
//!   implementations; [`optimizer`] / [`kv_cache`] — client-owned state.
//! * [`privacy`] — the additive-noise activation protocol (section 3.8).
//! * [`placement`] / [`sharding`] — Fig. 5 topologies + analytic models.

pub mod adapter;
pub mod base_executor;
pub mod batching;
pub mod client;
pub mod kv_cache;
pub mod model_state;
pub mod optimizer;
pub mod placement;
pub mod privacy;
pub mod proto;
pub mod sharding;
pub mod virt_layer;

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::coordinator::privacy::PrivacyCtx;
use crate::runtime::Engine;
use crate::transport::{Link, LinkKind};

pub use crate::error::{SymResult, SymbiosisError};
pub use adapter::{Adapter, AdapterHooks, HookCtx, Ia3Adapter,
                  LoraAdapter, LoraTargets, NoAdapter, PrefixAdapter};
pub use base_executor::{BaseExecutor, ExecutorStats};
pub use batching::BatchPolicy;
pub use client::{ClientCore, GenerationConfig, InferenceSession,
                 Sampling, SessionBuilder, Trainer, TrainerBuilder,
                 TrainOutcome, UrgencyPolicy};
pub use kv_cache::KvPlacement;
pub use placement::Placement;
pub use proto::{LayerId, OpKind, Urgency};
pub use virt_layer::VirtLayerCtx;

/// A running deployment: one base executor + the pieces needed to attach
/// clients.  This is the top-level public API — tenants are spawned from
/// it via [`Deployment::session`] and [`Deployment::trainer`].
pub struct Deployment {
    pub cfg: ModelConfig,
    pub engine: Arc<Engine>,
    pub executor: BaseExecutor,
    pub client_weights: model_state::ClientWeights,
    pub placement: Placement,
    next_client_id: std::sync::atomic::AtomicUsize,
}

impl Deployment {
    /// Load artifacts + weights and spawn the base executor.
    pub fn start(cfg: &ModelConfig, artifact_dir: &Path,
                 policy: BatchPolicy, placement: Placement)
                 -> Result<Deployment> {
        let engine = Arc::new(Engine::new(artifact_dir)?);
        Self::start_with_engine(engine, cfg, artifact_dir, policy,
                                placement)
    }

    /// Start a deployment over an existing engine — lets benches reuse
    /// one compile cache across executor restarts (a real cluster would
    /// likewise keep compiled executables across coordinator restarts).
    pub fn start_with_engine(engine: Arc<Engine>, cfg: &ModelConfig,
                             artifact_dir: &Path, policy: BatchPolicy,
                             placement: Placement) -> Result<Deployment> {
        // Drift check: manifest dims must match the compiled-in config.
        let mm = engine.manifest().model(cfg.name)?;
        anyhow::ensure!(
            mm.d_model == cfg.d_model && mm.n_layers == cfg.n_layers
                && mm.vocab == cfg.vocab && mm.n_heads == cfg.n_heads,
            "manifest/model drift for {}", cfg.name
        );
        let (base, client_weights) =
            model_state::load_split(cfg, artifact_dir)?;
        let executor = BaseExecutor::spawn(engine.clone(), base, policy);
        Ok(Deployment {
            cfg: cfg.clone(),
            engine,
            executor,
            client_weights,
            placement,
            next_client_id: std::sync::atomic::AtomicUsize::new(0),
        })
    }

    /// Begin configuring an inference session against this deployment.
    pub fn session(&self) -> SessionBuilder<'_> {
        SessionBuilder::new(self)
    }

    /// Begin configuring a fine-tuning job against this deployment.
    pub fn trainer(&self) -> TrainerBuilder<'_> {
        TrainerBuilder::new(self)
    }

    /// Allocate a client context wired to this deployment's executor
    /// over the placement's link.  Lower-level than the builders; most
    /// callers want [`Deployment::session`] / [`Deployment::trainer`].
    pub fn client_core(&self, adapter: Option<Adapter>) -> ClientCore {
        self.client_core_with_link(adapter, self.placement.link())
    }

    /// Same, with an explicit link kind (heterogeneous topologies).
    pub fn client_core_with_link(&self, adapter: Option<Adapter>,
                                 link: LinkKind) -> ClientCore {
        self.build_core(adapter, link, false, None)
    }

    /// Full control: link kind + whether simulated link delays are
    /// realized as actual sleeps (placement benches).
    pub fn client_core_opts(&self, adapter: Option<Adapter>,
                            link: LinkKind, realize_delays: bool)
                            -> ClientCore {
        self.build_core(adapter, link, realize_delays, None)
    }

    /// The one place client contexts are wired: allocates a client id,
    /// builds the layer proxy (with optional privacy), registers it with
    /// the executor.
    pub(crate) fn build_core(&self, adapter: Option<Adapter>,
                             link: LinkKind, realize_delays: bool,
                             privacy: Option<PrivacyCtx>) -> ClientCore {
        let id = self
            .next_client_id
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let mut ctx =
            VirtLayerCtx::new(id, self.executor.sender(), Link::new(link));
        ctx.realize_delays = realize_delays;
        ctx.privacy = privacy;
        let virt = Arc::new(ctx);
        virt.register();
        ClientCore {
            cfg: self.cfg.clone(),
            engine: self.engine.clone(),
            virt,
            weights: self.client_weights.clone(),
            adapter,
        }
    }

    /// Stop the executor and return its statistics.
    pub fn shutdown(self) -> ExecutorStats {
        self.executor.shutdown()
    }
}
