//! The Symbiosis coordinator — the paper's system contribution.
//!
//! One shared, frozen base model serves many tenants; each tenant picks
//! its own PEFT method, resources, and placement.  The public surface is
//! **session-first**: start a [`Deployment`], then spawn per-tenant jobs
//! from it with the two builders —
//!
//! ```no_run
//! # use symbiosis::config::SYM_TINY;
//! # use symbiosis::coordinator::*;
//! # fn main() -> anyhow::Result<()> {
//! # let dir = std::path::PathBuf::from("artifacts");
//! // shards come from the placement: `ShardedLocal { shards: 2 }`
//! // spawns a two-shard executor fleet, `Local` a fleet of one.
//! let dep = Deployment::start(&SYM_TINY, &dir,
//!                             BatchPolicy::opportunistic_default(),
//!                             Placement::Local)?;
//!
//! // an inference tenant: LoRA adapter, one request at a time
//! let adapter = Adapter::lora_from_artifacts(&SYM_TINY, &dir, 8,
//!                                            LoraTargets::QKVO, 2.0)?;
//! let mut session = dep.session().adapter(adapter).build()?;
//! let tokens = session.generate(&[1, 2, 3, 4],
//!                               &GenerationConfig::greedy(16))?;
//!
//! // a fine-tuning tenant sharing the same frozen base
//! let lora = Adapter::lora_from_artifacts(&SYM_TINY, &dir, 64,
//!                                         LoraTargets::QKVO, 0.25)?;
//! let mut trainer = dep.trainer().adapter(lora).lr(5e-3).build()?;
//! # Ok(()) }
//! ```
//!
//! Builders own every per-tenant choice (adapter, batch,
//! [`KvPlacement`], link kind, urgency policy, privacy) and do the
//! error-prone wiring — e.g. a prefix adapter's KV seed and the switch
//! to incremental prefill happen automatically.  Failures surface as
//! typed [`SymbiosisError`]s.
//!
//! Module map — the request path from client to device:
//! * [`client`] — the layer walker, sessions/trainers, and their
//!   builders; each client drives its own execution (design goal 5).
//!   Long prompts on a sharded fleet can pipeline:
//!   `SessionBuilder::prefill_chunk` splits the prompt into
//!   micro-batches driven as a wavefront so every shard stays busy.
//! * [`virt_layer`] — the client-side proxy replacing frozen layers
//!   (Fig. 4).  Holds the per-client `RoutingTable`: each `LayerId`
//!   resolves to the shard executor owning it, over a per-shard link
//!   (co-located `SharedLocal`, cross-shard `NvLink`).  The API is
//!   split-phase — `dispatch()` sends without blocking,
//!   `PendingLayer::collect()` waits — with the blocking calls as the
//!   composition of the two.
//! * [`fleet`] — the executor fleet: one shard thread per contiguous
//!   layer range, each with its own batching queues and an OOM-enforced
//!   `Device` memory ledger; `FleetStats` merges per-shard snapshots.
//! * [`base_executor`] — one shard: frozen-layer service with per-layer
//!   opportunistic batching (sections 3.2, 3.6, 3.7); failures answer
//!   typed errors over the wire.
//! * [`sharding`] / [`placement`] — the `ShardPlan` cost model **and**
//!   its executable `LayerAssignment` (section 3.3); placements map
//!   shard topology to link kinds and device classes (Fig. 5).
//! * [`adapter`] — the [`AdapterHooks`] trait and the LoRA/IA3/Prefix
//!   implementations; [`optimizer`] / [`kv_cache`] — client-owned state.
//! * [`privacy`] — the additive-noise activation protocol (section 3.8).
//!   Sharded deployments register noise via
//!   [`ExecutorFleet::sender_for`] (the layer's owning shard).
//! * [`faults`] — deterministic, seeded fault injection
//!   ([`Deployment::inject_faults`]): drop / delay / error / stall /
//!   kill rules interpose on client→shard routes so the chaos suite and
//!   benches can rehearse every failure the fleet claims to survive.
//!
//! * [`admission`] — per-tenant quotas (sessions, in-flight requests,
//!   KV bytes) enforced at build and dispatch time, so dense
//!   multi-tenancy degrades with typed denials instead of one tenant
//!   starving the rest.
//! * [`scheduler`] — the continuous-batching serving engine
//!   ([`Deployment::serving`]): an iteration-level scheduler that owns
//!   a pool of decode slots and drives many sessions as one wavefront
//!   per token step, admitting new prompts via `prefill_chunk`
//!   micro-batches without stalling in-flight decodes.  Pair with
//!   [`BatchPolicy::Continuous`].
//!
//! The failure model is first-class: per-request deadlines
//! (`SessionBuilder::request_timeout`), bounded client-side retry
//! (`RetryPolicy`), and fleet supervision (watchdog +
//! [`ExecutorFleet::respawn_shard`]) are wired through the same typed
//! error surface — see the taxonomy table in [`crate::error`].  The
//! overload path is equally typed: bounded shard ingress
//! ([`IngressMeter`] → `ShardSaturated`), per-shard circuit breakers
//! ([`CircuitBreaker`] → fast-fail `ShardUnavailable`), tenant quotas
//! (`AdmissionDenied` / `QuotaExceeded`), and urgency-based shedding of
//! `Urgency::Background` work (`WorkShed`).

pub mod adapter;
pub mod admission;
pub mod base_executor;
pub mod batching;
pub mod client;
pub mod faults;
pub mod fleet;
pub mod kv_cache;
pub mod model_state;
pub mod optimizer;
pub mod placement;
pub mod privacy;
pub mod proto;
pub mod scheduler;
pub mod sharding;
pub mod virt_layer;

use std::path::Path;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::config::ModelConfig;
use crate::coordinator::privacy::PrivacyCtx;
use crate::device::Device;
use crate::runtime::Engine;
use crate::transport::LinkKind;

pub use crate::error::{SymResult, SymbiosisError};
pub use adapter::{Adapter, AdapterHooks, HookCtx, Ia3Adapter,
                  LoraAdapter, LoraTargets, NoAdapter, PrefixAdapter};
pub use admission::{AdmissionController, TenantQuota, TenantState};
pub use base_executor::{ExecutorStats, FlushRecord, ShardExecutor};
pub use batching::BatchPolicy;
pub use client::{ClientCore, GenerationConfig, InferenceSession,
                 Sampling, SessionBuilder, Trainer, TrainerBuilder,
                 TrainOutcome, UrgencyPolicy};
pub use faults::{FaultAction, FaultPlan, FaultRule};
pub use fleet::{ExecutorFleet, FleetBarrier, FleetStats, ShardLoad,
                TrainingStats};
pub use kv_cache::{BlockPool, KvCache, KvPlacement, KvSwapStats,
                   PrefixMeta};
pub use placement::Placement;
pub use proto::{LayerId, OpKind, Urgency};
pub use scheduler::{HandleStatus, ServingBuilder, ServingEngine,
                    ServingReport, ServingRequest, SessionHandle};
pub use sharding::{LayerAssignment, ShardPlan};
pub use virt_layer::{BreakerState, CircuitBreaker, IngressMeter,
                     PendingLayer, RetryPolicy, RoutingTable,
                     ShardEndpoint, ShardRoute, VirtLayerCtx};

/// A running deployment: an executor fleet + the pieces needed to attach
/// clients.  This is the top-level public API — tenants are spawned from
/// it via [`Deployment::session`] and [`Deployment::trainer`].  The
/// number of shards is the placement's (`Placement::shards()`).
pub struct Deployment {
    pub cfg: ModelConfig,
    pub engine: Arc<Engine>,
    pub executor: ExecutorFleet,
    pub client_weights: model_state::ClientWeights,
    pub placement: Placement,
    /// Simulated device hosting the clients: every session's KV cache
    /// (when `KvPlacement::Device`) charges this shared ledger, so
    /// mixed-tenant OOM is executable — over-committing fails a
    /// session's append with a typed
    /// [`SymbiosisError::KvCacheOom`], not just the analytic model.
    pub client_device: Arc<Mutex<Device>>,
    /// Host DRAM device: `KvPlacement::Host` caches charge here, and
    /// device-resident caches swap cold background blocks here under
    /// memory pressure.
    pub host_device: Arc<Mutex<Device>>,
    /// Shared paged-KV block pool: every session's cache draws
    /// fixed-size blocks from it, which is what makes prefix sharing
    /// (one charge for N sessions' common prompt) and swap victim
    /// selection fleet-wide decisions.
    pub kv_pool: Arc<BlockPool>,
    /// Shared training counters: pipelined trainers report micro-batch
    /// in-flight / activation-stash / grad-accumulation activity here,
    /// and [`Deployment::shutdown`] stamps the totals into the final
    /// [`FleetStats`] next to shard occupancy.
    pub train_stats: Arc<TrainingStats>,
    next_client_id: std::sync::atomic::AtomicUsize,
    /// Active fault-injection plan; applied to every client core built
    /// *after* [`Deployment::inject_faults`].  Interior mutability so
    /// tests can arm faults on a shared, otherwise-immutable deployment.
    fault_plan: Mutex<Option<FaultPlan>>,
}

impl Deployment {
    /// Load artifacts + weights and spawn the executor fleet
    /// (`placement.shards()` shard threads; fails with a typed
    /// [`SymbiosisError::ShardOom`] when a shard's resident slice does
    /// not fit its device ledger).
    pub fn start(cfg: &ModelConfig, artifact_dir: &Path,
                 policy: BatchPolicy, placement: Placement)
                 -> Result<Deployment> {
        let engine = Arc::new(Engine::new(artifact_dir)?);
        Self::start_with_engine(engine, cfg, artifact_dir, policy,
                                placement)
    }

    /// Start a deployment over an existing engine — lets benches reuse
    /// one compile cache across executor restarts (a real cluster would
    /// likewise keep compiled executables across coordinator restarts).
    pub fn start_with_engine(engine: Arc<Engine>, cfg: &ModelConfig,
                             artifact_dir: &Path, policy: BatchPolicy,
                             placement: Placement) -> Result<Deployment> {
        // Drift check: manifest dims must match the compiled-in config.
        let mm = engine.manifest().model(cfg.name)?;
        anyhow::ensure!(
            mm.d_model == cfg.d_model && mm.n_layers == cfg.n_layers
                && mm.vocab == cfg.vocab && mm.n_heads == cfg.n_heads,
            "manifest/model drift for {}", cfg.name
        );
        let (base, client_weights) =
            model_state::load_split(cfg, artifact_dir)?;
        let executor =
            ExecutorFleet::start(engine.clone(), base, policy, placement)?;
        let client_device = Arc::new(Mutex::new(Device::new(
            "clients", placement.client_device())));
        let host_device = Arc::new(Mutex::new(Device::new(
            "host", placement.host_device())));
        Ok(Deployment {
            cfg: cfg.clone(),
            engine,
            executor,
            client_weights,
            placement,
            client_device,
            host_device,
            kv_pool: BlockPool::new(),
            train_stats: Arc::new(TrainingStats::default()),
            next_client_id: std::sync::atomic::AtomicUsize::new(0),
            fault_plan: Mutex::new(None),
        })
    }

    /// Arm a deterministic fault-injection plan: every client core
    /// built from now on routes through the plan's interposers (shards
    /// without matching rules keep their direct endpoints).  Pass-through
    /// for production; chaos tests and benches use it to rehearse
    /// drops, delays, stalls, error answers, and shard kills under a
    /// fixed seed.  Replaces any previously armed plan.
    pub fn inject_faults(&self, plan: FaultPlan) {
        *self
            .fault_plan
            .lock()
            .unwrap_or_else(|p| p.into_inner()) = Some(plan);
    }

    /// Disarm fault injection for subsequently built clients (already
    /// built clients keep their interposed routes).
    pub fn clear_faults(&self) {
        *self
            .fault_plan
            .lock()
            .unwrap_or_else(|p| p.into_inner()) = None;
    }

    /// Begin configuring an inference session against this deployment.
    pub fn session(&self) -> SessionBuilder<'_> {
        SessionBuilder::new(self)
    }

    /// Begin configuring a fine-tuning job against this deployment.
    pub fn trainer(&self) -> TrainerBuilder<'_> {
        TrainerBuilder::new(self)
    }

    /// Begin configuring a continuous-batching serving engine: submit
    /// prompts, get streaming handles, pump
    /// [`ServingEngine::step`](scheduler::ServingEngine::step) (or
    /// [`run`](scheduler::ServingEngine::run)) to drive every active
    /// session as one iteration-level wavefront.
    pub fn serving(&self) -> scheduler::ServingBuilder<'_> {
        scheduler::ServingBuilder::new(self)
    }

    /// Allocate a client context routed over this deployment's fleet on
    /// the placement's links.  Lower-level than the builders; most
    /// callers want [`Deployment::session`] / [`Deployment::trainer`].
    pub fn client_core(&self, adapter: Option<Adapter>) -> ClientCore {
        self.build_core(adapter, None, false, None, None, None, None)
    }

    /// Same, with an explicit link kind applied to every shard hop
    /// (heterogeneous topologies).
    pub fn client_core_with_link(&self, adapter: Option<Adapter>,
                                 link: LinkKind) -> ClientCore {
        self.build_core(adapter, Some(link), false, None, None, None,
                        None)
    }

    /// Full control: link kind + whether simulated link delays are
    /// realized as actual sleeps (placement benches).
    pub fn client_core_opts(&self, adapter: Option<Adapter>,
                            link: LinkKind, realize_delays: bool)
                            -> ClientCore {
        self.build_core(adapter, Some(link), realize_delays, None, None,
                        None, None)
    }

    /// The one place client contexts are wired: allocates a client id,
    /// builds the routed layer proxy (with optional privacy and fault
    /// interposers), registers it with every shard.  `link_override`
    /// replaces the placement-derived per-shard link kinds when set;
    /// `request_timeout` puts a deadline on every collect; `retry`
    /// bounds client-side re-dispatch of pure frozen-base ops;
    /// `tenant` charges every dispatch against that tenant's in-flight
    /// quota (`None` bypasses admission).
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn build_core(&self, adapter: Option<Adapter>,
                             link_override: Option<LinkKind>,
                             realize_delays: bool,
                             privacy: Option<PrivacyCtx>,
                             request_timeout:
                                 Option<std::time::Duration>,
                             retry: Option<RetryPolicy>,
                             tenant:
                                 Option<Arc<admission::TenantState>>)
                             -> ClientCore {
        let id = self
            .next_client_id
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let plan = self
            .fault_plan
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .clone();
        let routing = self.executor.routing_for(
            id, &self.placement, link_override, plan.as_ref());
        let mut ctx = VirtLayerCtx::new(id, routing);
        ctx.realize_delays = realize_delays;
        ctx.privacy = privacy;
        ctx.request_timeout = request_timeout;
        ctx.tenant = tenant;
        if let Some(retry) = retry {
            ctx.retry = retry;
        }
        // Clients keep the fleet-global lockstep count exact: they
        // bump it synchronously on register/deregister.
        ctx.fleet_barrier = Some(self.executor.barrier_arc());
        let virt = Arc::new(ctx);
        virt.register();
        ClientCore {
            cfg: self.cfg.clone(),
            engine: self.engine.clone(),
            virt,
            weights: self.client_weights.clone(),
            adapter,
        }
    }

    /// The fleet's admission controller: name tenants on the builders
    /// ([`SessionBuilder::tenant`](client::SessionBuilder::tenant)),
    /// configure their quotas here
    /// ([`AdmissionController::set_quota`]).
    pub fn admission(&self) -> &AdmissionController {
        self.executor.admission()
    }

    /// Stop the fleet (draining shards in layer order) and return its
    /// statistics — the merged view plus per-shard detail, stamped
    /// with the KV block pool's swap activity.
    pub fn shutdown(self) -> FleetStats {
        let swap = self.kv_pool.swap_stats();
        let mut stats = self.executor.shutdown();
        stats.kv_swap_outs = swap.swap_outs;
        stats.kv_fault_ins = swap.fault_ins;
        stats.kv_swapped_blocks = swap.swapped_blocks;
        stats.train_microbatches_in_flight_peak =
            self.train_stats.microbatches_in_flight_peak();
        stats.train_activation_stash_peak_bytes =
            self.train_stats.activation_stash_peak_bytes();
        stats.train_grad_accum_steps =
            self.train_stats.grad_accum_steps();
        stats
    }
}
