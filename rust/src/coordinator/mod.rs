//! The Symbiosis coordinator — the paper's system contribution.
//!
//! * [`base_executor`] — shared frozen-layer service with per-layer
//!   opportunistic batching (sections 3.2, 3.6, 3.7).
//! * [`virt_layer`] — the client-side proxy replacing frozen layers
//!   (Fig. 4).
//! * [`client`] — inference sessions and trainers; each client drives its
//!   own execution (design goal 5).
//! * [`adapter`] / [`optimizer`] / [`kv_cache`] — client-owned state.
//! * [`privacy`] — the additive-noise activation protocol (section 3.8).
//! * [`placement`] / [`sharding`] — Fig. 5 topologies + analytic models.

pub mod adapter;
pub mod base_executor;
pub mod batching;
pub mod client;
pub mod kv_cache;
pub mod model_state;
pub mod optimizer;
pub mod placement;
pub mod privacy;
pub mod proto;
pub mod sharding;
pub mod virt_layer;

use std::path::Path;
use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelConfig;
use crate::runtime::Engine;
use crate::transport::{Link, LinkKind};

pub use adapter::{Adapter, LoraTargets};
pub use base_executor::{BaseExecutor, ExecutorStats};
pub use batching::BatchPolicy;
pub use client::{ClientCore, InferenceSession, Trainer};
pub use kv_cache::KvPlacement;
pub use placement::Placement;
pub use proto::{LayerId, OpKind, Urgency};
pub use virt_layer::VirtLayerCtx;

/// A running deployment: one base executor + the pieces needed to attach
/// clients. This is the top-level public API the examples and benches
/// use.
pub struct Deployment {
    pub cfg: ModelConfig,
    pub engine: Arc<Engine>,
    pub executor: BaseExecutor,
    pub client_weights: model_state::ClientWeights,
    pub placement: Placement,
    next_client_id: std::sync::atomic::AtomicUsize,
}

impl Deployment {
    /// Load artifacts + weights and spawn the base executor.
    pub fn start(cfg: &ModelConfig, artifact_dir: &Path,
                 policy: BatchPolicy, placement: Placement)
                 -> Result<Deployment> {
        let engine = Arc::new(Engine::new(artifact_dir)?);
        Self::start_with_engine(engine, cfg, artifact_dir, policy,
                                placement)
    }

    /// Start a deployment over an existing engine — lets benches reuse
    /// one compile cache across executor restarts (a real cluster would
    /// likewise keep compiled executables across coordinator restarts).
    pub fn start_with_engine(engine: Arc<Engine>, cfg: &ModelConfig,
                             artifact_dir: &Path, policy: BatchPolicy,
                             placement: Placement) -> Result<Deployment> {
        // Drift check: manifest dims must match the compiled-in config.
        let mm = engine.manifest().model(cfg.name)?;
        anyhow::ensure!(
            mm.d_model == cfg.d_model && mm.n_layers == cfg.n_layers
                && mm.vocab == cfg.vocab && mm.n_heads == cfg.n_heads,
            "manifest/model drift for {}", cfg.name
        );
        let (base, client_weights) =
            model_state::load_split(cfg, artifact_dir)?;
        let executor = BaseExecutor::spawn(engine.clone(), base, policy);
        Ok(Deployment {
            cfg: cfg.clone(),
            engine,
            executor,
            client_weights,
            placement,
            next_client_id: std::sync::atomic::AtomicUsize::new(0),
        })
    }

    /// Allocate a client context wired to this deployment's executor
    /// over the placement's link.
    pub fn client_core(&self, adapter: Option<Adapter>) -> ClientCore {
        self.client_core_with_link(adapter, self.placement.link())
    }

    /// Same, with an explicit link kind (heterogeneous topologies).
    pub fn client_core_with_link(&self, adapter: Option<Adapter>,
                                 link: LinkKind) -> ClientCore {
        self.client_core_opts(adapter, link, false)
    }

    /// Full control: link kind + whether simulated link delays are
    /// realized as actual sleeps (placement benches).
    pub fn client_core_opts(&self, adapter: Option<Adapter>,
                            link: LinkKind, realize_delays: bool)
                            -> ClientCore {
        let id = self
            .next_client_id
            .fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        let mut ctx =
            VirtLayerCtx::new(id, self.executor.sender(), Link::new(link));
        ctx.realize_delays = realize_delays;
        let virt = Arc::new(ctx);
        virt.register();
        ClientCore {
            cfg: self.cfg.clone(),
            engine: self.engine.clone(),
            virt,
            weights: self.client_weights.clone(),
            adapter,
            lora_scale: 2.0,
        }
    }

    /// Stop the executor and return its statistics.
    pub fn shutdown(self) -> ExecutorStats {
        self.executor.shutdown()
    }
}
