//! Activation privacy for multi-tenancy (paper section 3.8).
//!
//! Threat model: the base-executor provider observes activations and
//! could mount a model-extraction attack to recover adapter parameters
//! (paper Fig. 8: `(C - B) / A` reveals `Wa . Wb`).  Defense: the client
//! adds a pre-registered noise tensor to activations before shipping;
//! because base layers are linear, `W(x + n) + b = (Wx + b) + Wn`, so
//! subtracting the pre-computed noise effect `n_eff = W . n` restores the
//! *exact* output.  The executor only ever sees `x + n`.
//!
//! Several noise vectors are prepared per layer and rotated per
//! invocation so the executor cannot cancel the noise by differencing
//! consecutive iterations.

#![deny(clippy::unwrap_used)]

use std::collections::HashMap;
use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::coordinator::proto::{ExecMsg, LayerId};
use crate::tensor::{ops, Tensor};

/// Deterministic noise source (no rand crate in the vendored registry):
/// splitmix64 mapped to U(-amp, amp).
pub struct NoiseGen {
    state: u64,
    amp: f32,
}

impl NoiseGen {
    pub fn new(seed: u64, amp: f32) -> Self {
        NoiseGen { state: seed, amp }
    }

    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    pub fn next_f32(&mut self) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        (2.0 * u - 1.0) * self.amp
    }

    pub fn tensor(&mut self, shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::from_f32((0..n).map(|_| self.next_f32()).collect(), shape)
    }
}

struct LayerNoise {
    /// Rotating pool of (noise, noise_effect) pairs.
    pool: Vec<(Tensor, Tensor)>,
    next: usize,
}

/// Per-client privacy state: pre-registered noise pools per layer.
pub struct PrivacyCtx {
    noise: Mutex<HashMap<LayerId, LayerNoise>>,
    /// Executor-observed activations hash log (test hook: proves the
    /// executor never saw the raw activations).
    pub sent_log: Mutex<Vec<(LayerId, f32)>>,
}

impl Default for PrivacyCtx {
    fn default() -> Self {
        Self::new()
    }
}

impl PrivacyCtx {
    pub fn new() -> Self {
        PrivacyCtx {
            noise: Mutex::new(HashMap::new()),
            sent_log: Mutex::new(Vec::new()),
        }
    }

    /// Prepare `pool_size` noise values for `layer` with activation shape
    /// `(t, din)`, fetching each `n_eff` from the executor once.  This is
    /// the setup cost; steady-state iterations add zero executor work.
    pub fn register_layer(&self, exec_tx: &Sender<ExecMsg>, layer: LayerId,
                          t: usize, din: usize, gen: &mut NoiseGen,
                          pool_size: usize) -> Result<()> {
        let mut pool = Vec::with_capacity(pool_size);
        for _ in 0..pool_size {
            let n = gen.tensor(&[t, din]);
            let (tx, rx) = channel();
            exec_tx
                .send(ExecMsg::RegisterNoise {
                    layer,
                    noise: n.clone(),
                    resp: tx,
                })
                .ok()
                .context("executor gone")?;
            let resp = rx.recv().context("noise registration dropped")?;
            let n_eff = resp.y.map_err(|m| anyhow::anyhow!(
                "noise registration failed for {layer:?}: {m}"))?;
            pool.push((n, n_eff));
        }
        self.noise
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .insert(layer, LayerNoise { pool, next: 0 });
        Ok(())
    }

    /// Noise the activations for shipping: returns `(x + n, n_eff)` using
    /// the next pool entry (rotating).  Fails if the layer was not
    /// registered or the shape mismatches the registered noise.
    pub fn apply(&self, layer: LayerId, x: &Tensor)
                 -> Result<(Tensor, Tensor)> {
        let mut map = self.noise.lock().unwrap_or_else(|p| p.into_inner());
        let ln = map
            .get_mut(&layer)
            .with_context(|| format!("no noise registered for {layer:?}"))?;
        let idx = ln.next;
        ln.next = (ln.next + 1) % ln.pool.len();
        let (n, n_eff) = &ln.pool[idx];
        if n.shape != x.shape {
            // tail iterations may have fewer tokens: slice the noise
            if n.shape.len() == 2 && x.shape.len() == 2
                && x.shape[0] <= n.shape[0] && x.shape[1] == n.shape[1]
            {
                let ns = n.slice_rows(0, x.shape[0]);
                let es = n_eff.slice_rows(0, x.shape[0]);
                let noised = ops::add(x, &ns);
                self.sent_log
                    .lock()
                    .unwrap_or_else(|p| p.into_inner())
                    .push((layer, noised.as_f32()[0]));
                return Ok((noised, es));
            }
            bail!("noise shape {:?} incompatible with x {:?}", n.shape,
                  x.shape);
        }
        let noised = ops::add(x, n);
        self.sent_log
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .push((layer, noised.as_f32()[0]));
        Ok((noised, n_eff.clone()))
    }

    /// Number of registered layers (tests).
    pub fn registered_layers(&self) -> usize {
        self.noise.lock().unwrap_or_else(|p| p.into_inner()).len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noise_gen_is_deterministic_and_bounded() {
        let mut a = NoiseGen::new(42, 0.5);
        let mut b = NoiseGen::new(42, 0.5);
        for _ in 0..1000 {
            let (x, y) = (a.next_f32(), b.next_f32());
            assert_eq!(x, y);
            assert!(x.abs() <= 0.5);
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = NoiseGen::new(1, 1.0);
        let mut b = NoiseGen::new(2, 1.0);
        let same = (0..100).filter(|_| a.next_f32() == b.next_f32()).count();
        assert!(same < 5);
    }

    #[test]
    fn apply_requires_registration() {
        let p = PrivacyCtx::new();
        let x = Tensor::zeros(&[4, 8]);
        assert!(p.apply(LayerId::Qkv(0), &x).is_err());
    }
}
