//! The executor fleet: one [`ShardExecutor`] per contiguous layer range
//! of the frozen base (paper section 3.3, executable form).
//!
//! [`ExecutorFleet::start`] derives a [`LayerAssignment`] from the
//! deployment's `Placement::shards()`, splits the loaded
//! [`BaseWeights`] into per-shard slices (`model_state::split_shards`,
//! zero-copy), charges each shard's simulated [`Device`] ledger with
//! its real resident bytes — failing with a typed
//! [`SymbiosisError::ShardOom`] before any thread starts when a slice
//! does not fit — and spawns one executor thread per shard, each with
//! its own [`BatchPolicy`] queues.
//!
//! Clients never see the fleet directly: `Deployment::build_core` hands
//! every client a [`RoutingTable`] that maps each `LayerId` to the
//! owning shard's [`ShardEndpoint`], with a per-shard
//! [`Link`](crate::transport::Link) charged per hop (co-located shard:
//! `SharedLocal`; cross-shard: `NvLink` — see `Placement::shard_links`).
//! A fleet of one shard is exactly the old single `BaseExecutor`, with
//! the same hot path.
//!
//! # Supervision and respawn
//!
//! The fleet is a *supervisor*, not just a spawner.  It retains each
//! shard's respawn seed — the weight slice (zero-copy `Arc` views), the
//! device class/capacity, the batch policy — and every client routes
//! through a fleet-shared [`ShardEndpoint`] rather than a raw channel.
//! [`ExecutorFleet::respawn_shard`] rebuilds a shard on a fresh device
//! ledger (re-charged, so a respawn cannot silently over-commit), seeds
//! the replacement's shard-local registration count from the fleet
//! barrier (clients never re-send `Register`), swaps the endpoint
//! sender under a bumped epoch — in-flight sessions migrate without
//! rebuilding their tables — and folds the dead generation's statistics
//! into a retired ledger so fleet stats stay exact across generations.
//! Privacy-noise state needs no re-arming: noise pools live client-side
//! and `n_eff = W·n` only depends on the frozen weights, which the
//! respawned shard shares.  A default-on watchdog thread polls each
//! executor's join handle (see `ExecutorStats::heartbeats` for the
//! stall-detection signal) and respawns dead shards automatically —
//! detection latency is bounded by [`WATCHDOG_INTERVAL`].
//!
//! [`FleetStats`] merges the per-shard [`ExecutorStats`] snapshots so
//! Table-5 style metrics still come out of one call; it `Deref`s to the
//! merged view, keeping existing consumers (`stats.n_flushes`,
//! `stats.mean_batch_clients()`, …) source-compatible.
//!
//! # Overload management
//!
//! The fleet also owns the overload layer: each endpoint carries the
//! shard's shared [`IngressMeter`] (bounded ingress queue — see
//! [`ExecutorFleet::set_ingress_high_water`]) and [`CircuitBreaker`]
//! ([`ExecutorFleet::set_breaker_threshold`]); the watchdog heartbeat
//! re-arms open breakers to half-open, and a respawn resets both, since
//! the replacement executor starts with an empty queue and a clean
//! record.  Tenant quotas live in the fleet's
//! [`AdmissionController`] ([`ExecutorFleet::admission`]), consulted by
//! session builders and by every dispatch of a tenant-tagged client.

// Fault-domain hot path: see `virt_layer` — locks recover from poison
// explicitly, failures are typed.
#![deny(clippy::unwrap_used)]

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::admission::AdmissionController;
use crate::coordinator::base_executor::{ExecutorStats, ShardExecutor};
use crate::coordinator::batching::BatchPolicy;
use crate::coordinator::faults::FaultPlan;
use crate::coordinator::model_state::{self, BaseWeights, ShardWeights};
use crate::coordinator::placement::Placement;
use crate::coordinator::proto::{ExecMsg, LayerId};
use crate::coordinator::sharding::LayerAssignment;
use crate::coordinator::virt_layer::{BreakerState, CircuitBreaker,
                                     IngressMeter, RoutingTable,
                                     ShardEndpoint, ShardRoute};
use crate::device::{Device, DeviceKind, MemoryLedger};
use crate::error::SymbiosisError;
use crate::runtime::Engine;
use crate::transport::LinkKind;

/// How often the fleet watchdog polls shard liveness — the upper bound
/// on crash-detection latency before a respawn begins.
pub const WATCHDOG_INTERVAL: Duration = Duration::from_millis(15);

/// Fleet-global lockstep barrier state: the one registration count all
/// shards of a fleet share (`Arc`'d into every shard thread).  Clients
/// maintain it *synchronously* in
/// `VirtLayerCtx::register`/`deregister` — before their per-shard
/// Register/Deregister messages — so no shard can observe a client's
/// requests while the global count still excludes that client;
/// `BatchPolicy::LockstepFleet` barriers read it instead of the
/// shard-local count, reproducing mLoRA's global lockstep at
/// shards > 1 (paper Tables 4/5).  It is also the respawn path's source
/// of truth for a replacement executor's initial shard-local count.
#[derive(Debug, Default)]
pub struct FleetBarrier {
    registered: AtomicUsize,
}

impl FleetBarrier {
    pub fn register(&self) {
        self.registered.fetch_add(1, Ordering::SeqCst);
    }

    pub fn deregister(&self) {
        // Saturating: a stray Deregister (client built against a dead
        // fleet) must not wrap the barrier count.
        let _ = self.registered.fetch_update(
            Ordering::SeqCst, Ordering::SeqCst,
            |n| Some(n.saturating_sub(1)));
    }

    /// Fleet-wide registered-client count.
    pub fn registered(&self) -> usize {
        self.registered.load(Ordering::SeqCst)
    }
}

/// Fleet-level aggregation of per-shard [`ExecutorStats`].  Derefs to
/// the merged snapshot (sums are exact; `flushes` keeps the most recent
/// records across the shards' bounded rings, itself capped at
/// [`crate::coordinator::base_executor::FLUSH_RECORD_CAP`] so stats
/// memory cannot grow with shard count or uptime), with the per-shard
/// detail kept alongside for placement-style breakdowns.
#[derive(Debug, Default, Clone)]
pub struct FleetStats {
    merged: ExecutorStats,
    pub per_shard: Vec<ExecutorStats>,
    /// Executor generations retired over the fleet's lifetime (crashes
    /// + rolling restarts).  Zero on snapshots built from a bare
    /// [`FleetStats::merge`].
    pub respawns: u64,
    /// Per-shard circuit-breaker state transitions (trips + probe
    /// outcomes); empty on bare merges.
    pub breaker_transitions: Vec<u64>,
    /// KV blocks swapped device → host over the deployment's lifetime
    /// (stamped by [`Deployment::shutdown`](
    /// crate::coordinator::Deployment::shutdown); zero on bare merges).
    pub kv_swap_outs: u64,
    /// KV blocks faulted host → device.
    pub kv_fault_ins: u64,
    /// KV blocks still host-resident at shutdown.
    pub kv_swapped_blocks: u64,
    /// Peak training micro-batches simultaneously in flight across the
    /// deployment's trainers (stamped by `Deployment::shutdown`; zero on
    /// bare merges and inference-only runs).
    pub train_microbatches_in_flight_peak: u64,
    /// Peak bytes of saved activations stashed across all trainers'
    /// in-flight micro-batches.
    pub train_activation_stash_peak_bytes: u64,
    /// Micro-batch gradient accumulations performed by pipelined
    /// trainers over the deployment's lifetime.
    pub train_grad_accum_steps: u64,
}

impl FleetStats {
    /// Merge per-shard snapshots (shard order preserved).
    pub fn merge(per_shard: Vec<ExecutorStats>) -> Self {
        let mut merged = ExecutorStats::default();
        for s in &per_shard {
            // `absorb` sums the exact aggregates and keeps the merged
            // flush ring bounded at FLUSH_RECORD_CAP (newest win).
            merged.absorb(s);
        }
        FleetStats {
            merged,
            per_shard,
            respawns: 0,
            breaker_transitions: Vec::new(),
            kv_swap_outs: 0,
            kv_fault_ins: 0,
            kv_swapped_blocks: 0,
            train_microbatches_in_flight_peak: 0,
            train_activation_stash_peak_bytes: 0,
            train_grad_accum_steps: 0,
        }
    }

    /// Per-shard occupancy (busy / (busy + idle)) in shard order — what
    /// the pipeline bench reports as pipeline occupancy.
    pub fn shard_occupancy(&self) -> Vec<f64> {
        self.per_shard.iter().map(|s| s.occupancy()).collect()
    }

    /// The fleet-wide merged snapshot (also reachable via `Deref`).
    pub fn merged(&self) -> &ExecutorStats {
        &self.merged
    }

    pub fn n_shards(&self) -> usize {
        self.per_shard.len()
    }
}

impl std::ops::Deref for FleetStats {
    type Target = ExecutorStats;

    fn deref(&self) -> &ExecutorStats {
        &self.merged
    }
}

/// One-screen human-readable fleet report: merged totals, then one
/// occupancy line per shard — what the benches and the serving load
/// generator print as their end-of-run summary.
impl std::fmt::Display for FleetStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "fleet: {} shard(s), {} flushes, {} served, {} shed, \
             {} generation(s) retired",
            self.n_shards(), self.merged.n_flushes,
            self.merged.requests_served, self.merged.requests_shed,
            self.respawns)?;
        writeln!(
            f,
            "  mean batch {:.2} client(s), mean wait {:.3} ms, \
             padding overhead {:.1}%",
            self.merged.mean_batch_clients(),
            self.merged.mean_wait_secs() * 1e3,
            self.merged.padding_overhead() * 100.0)?;
        if self.kv_swap_outs > 0 || self.kv_fault_ins > 0 {
            writeln!(
                f,
                "  kv swap: {} block(s) out, {} faulted back, \
                 {} still on host",
                self.kv_swap_outs, self.kv_fault_ins,
                self.kv_swapped_blocks)?;
        }
        if self.train_grad_accum_steps > 0 {
            writeln!(
                f,
                "  training: {} grad accum step(s), peak {} \
                 micro-batch(es) in flight, peak stash {} B",
                self.train_grad_accum_steps,
                self.train_microbatches_in_flight_peak,
                self.train_activation_stash_peak_bytes)?;
        }
        for (s, st) in self.per_shard.iter().enumerate() {
            let trips = self.breaker_transitions.get(s).copied()
                .unwrap_or(0);
            writeln!(
                f,
                "  shard {s}: occupancy {:5.1}%, {} flushes, \
                 {} served, {} shed, {} breaker transition(s)",
                st.occupancy() * 100.0, st.n_flushes,
                st.requests_served, st.requests_shed, trips)?;
        }
        Ok(())
    }
}

/// Shared training-side counters: every pipelined
/// [`Trainer`](crate::coordinator::client::Trainer) spawned from a
/// deployment updates these as micro-batches enter and leave the
/// wavefront, and [`Deployment::shutdown`](
/// crate::coordinator::Deployment::shutdown) stamps them into
/// [`FleetStats`].  Peaks are maintained with `fetch_max` so concurrent
/// trainers race safely.
#[derive(Debug, Default)]
pub struct TrainingStats {
    microbatches_in_flight: AtomicU64,
    microbatches_in_flight_peak: AtomicU64,
    activation_stash_bytes: AtomicU64,
    activation_stash_peak_bytes: AtomicU64,
    grad_accum_steps: AtomicU64,
}

impl TrainingStats {
    /// A micro-batch entered the wavefront (forward dispatched).
    pub fn microbatch_started(&self) {
        let now = self
            .microbatches_in_flight
            .fetch_add(1, Ordering::AcqRel) + 1;
        self.microbatches_in_flight_peak
            .fetch_max(now, Ordering::AcqRel);
    }

    /// A micro-batch's backward fully drained.
    pub fn microbatch_finished(&self) {
        // Saturating: a trainer dropped mid-step must not wrap the
        // in-flight gauge for its co-tenants.
        let _ = self.microbatches_in_flight.fetch_update(
            Ordering::AcqRel, Ordering::Acquire,
            |n| Some(n.saturating_sub(1)));
    }

    /// `bytes` of saved activations were stashed for a pending backward.
    pub fn stash_grew(&self, bytes: u64) {
        let now = self
            .activation_stash_bytes
            .fetch_add(bytes, Ordering::AcqRel) + bytes;
        self.activation_stash_peak_bytes
            .fetch_max(now, Ordering::AcqRel);
    }

    /// Backward consumed `bytes` of stashed activations.
    pub fn stash_shrunk(&self, bytes: u64) {
        let _ = self.activation_stash_bytes.fetch_update(
            Ordering::AcqRel, Ordering::Acquire,
            |n| Some(n.saturating_sub(bytes)));
    }

    /// One micro-batch's gradients were accumulated client-side.
    pub fn grad_accum_step(&self) {
        self.grad_accum_steps.fetch_add(1, Ordering::AcqRel);
    }

    pub fn microbatches_in_flight(&self) -> u64 {
        self.microbatches_in_flight.load(Ordering::Acquire)
    }

    pub fn microbatches_in_flight_peak(&self) -> u64 {
        self.microbatches_in_flight_peak.load(Ordering::Acquire)
    }

    pub fn activation_stash_bytes(&self) -> u64 {
        self.activation_stash_bytes.load(Ordering::Acquire)
    }

    pub fn activation_stash_peak_bytes(&self) -> u64 {
        self.activation_stash_peak_bytes.load(Ordering::Acquire)
    }

    pub fn grad_accum_steps(&self) -> u64 {
        self.grad_accum_steps.load(Ordering::Acquire)
    }
}

/// Instantaneous per-shard load snapshot — the occupancy feedback the
/// continuous-batching scheduler reads each iteration to decide whether
/// to admit more sessions or let `Urgency::Background` work yield
/// ([`ExecutorFleet::shard_loads`]).
#[derive(Debug, Clone)]
pub struct ShardLoad {
    pub shard: usize,
    /// Whether the shard's executor thread currently serves (a dead
    /// shard is respawned by the watchdog shortly).
    pub alive: bool,
    pub breaker: BreakerState,
    /// Requests sitting in the shard's ingress queue right now.
    pub ingress_depth: usize,
    /// `depth / high_water` clamped to [0, 1]; 0.0 when unbounded.
    pub pressure: f64,
    pub saturated: bool,
}

impl ShardLoad {
    /// Whether the scheduler should stop piling work onto this shard:
    /// dead, breaker open, or ingress at the high-water mark.
    pub fn overloaded(&self) -> bool {
        !self.alive || self.breaker == BreakerState::Open
            || self.saturated
    }
}

/// Charge a shard's resident slice to its device ledger; a slice that
/// does not fit fails with a typed [`SymbiosisError::ShardOom`] — this
/// is what makes an undeployable `ShardPlan` fail `Deployment::start`
/// instead of succeeding silently.
pub fn charge_shard(device: &mut Device, shard: usize, resident: u64)
                    -> Result<()> {
    let capacity = device.ledger.capacity();
    device.ledger.set("base-shard", resident).map_err(|_| {
        anyhow::Error::new(SymbiosisError::ShardOom {
            shard,
            need_bytes: resident,
            capacity_bytes: capacity,
        })
    })
}

/// Everything needed to rebuild one shard from scratch: the zero-copy
/// weight slice plus the device identity its replacement must be
/// charged against.
struct RespawnSeed {
    weights: ShardWeights,
    device_name: String,
    device_kind: DeviceKind,
    device_capacity: u64,
}

impl RespawnSeed {
    /// Rebuild the shard's device and re-run the OOM-enforced charge.
    fn build_device(&self, shard: usize) -> Result<Device> {
        let mut device = Device::new(&self.device_name, self.device_kind);
        device.ledger = MemoryLedger::new(self.device_capacity);
        charge_shard(&mut device, shard, self.weights.param_bytes())?;
        Ok(device)
    }
}

/// Shared fleet state: the supervisor (watchdog), the public handle,
/// and every respawn all operate on this.
struct FleetCore {
    engine: Arc<Engine>,
    policy: BatchPolicy,
    barrier: Arc<FleetBarrier>,
    seeds: Vec<RespawnSeed>,
    /// One respawn-transparent endpoint per shard — the *stable*
    /// identity clients route through across executor generations.
    endpoints: Vec<Arc<ShardEndpoint>>,
    /// The current executor generation per shard.
    shards: Mutex<Vec<ShardExecutor>>,
    /// Folded statistics of every retired (crashed / replaced)
    /// generation, per shard, so fleet stats stay exact across
    /// respawns.
    retired: Mutex<Vec<ExecutorStats>>,
    /// Tenant quota registry, consulted by session builders and by
    /// every dispatch of a tenant-tagged client.
    admission: AdmissionController,
    respawns: AtomicU64,
    stop: AtomicBool,
}

fn lock<'a, T>(m: &'a Mutex<T>) -> std::sync::MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

impl FleetCore {
    /// Replace shard `s`'s executor with a freshly spawned generation:
    /// rebuild + re-charge the device, seed the local registration
    /// count from the fleet barrier, swap the endpoint (epoch bump),
    /// retire the old generation's statistics.  Works on a live shard
    /// too (rolling restart): the old executor drains via its `Drop`.
    fn respawn_shard(&self, s: usize) -> Result<()> {
        let seed = self
            .seeds
            .get(s)
            .ok_or_else(|| anyhow::anyhow!("no shard {s} in this fleet"))?;
        let device = seed.build_device(s)?;
        let replacement = ShardExecutor::spawn_with_registered(
            self.engine.clone(),
            seed.weights.clone(),
            self.policy,
            device,
            self.barrier.clone(),
            self.barrier.registered(),
            // The replacement drains the shard's *stable* meter — queue
            // accounting survives the generation change.
            self.endpoints[s].meter().clone(),
        );
        // Swap the endpoint first: from this instant every new dispatch
        // (and every retry resolving the current sender) reaches the
        // replacement.
        self.endpoints[s].swap(replacement.sender());
        // The dead generation's queue died with it: zero the ingress
        // depth and close the breaker so the replacement starts clean
        // instead of inheriting phantom backlog or an open circuit.
        self.endpoints[s].meter().reset();
        self.endpoints[s].breaker().reset();
        let old = {
            let mut shards = lock(&self.shards);
            std::mem::replace(&mut shards[s], replacement)
        };
        lock(&self.retired)[s].absorb(&old.stats());
        self.respawns.fetch_add(1, Ordering::AcqRel);
        // Old generation: a dead thread joins instantly; a live one
        // drains its queue first (rolling restart), answering stragglers
        // that raced the endpoint swap.
        drop(old);
        Ok(())
    }

    fn is_alive(&self, s: usize) -> bool {
        lock(&self.shards)
            .get(s)
            .map(|e| e.is_alive())
            .unwrap_or(false)
    }
}

/// Watchdog: poll every shard's join handle; respawn dead ones.
fn watchdog_loop(core: Arc<FleetCore>) {
    let n = core.seeds.len();
    while !core.stop.load(Ordering::Acquire) {
        std::thread::sleep(WATCHDOG_INTERVAL);
        for s in 0..n {
            if core.stop.load(Ordering::Acquire) {
                return;
            }
            if !core.is_alive(s) {
                if let Err(e) = core.respawn_shard(s) {
                    // A seed that no longer charges (impossible unless
                    // the device model changed underneath) is fatal for
                    // this shard; keep supervising the others.
                    eprintln!("fleet-watchdog: respawn of shard {s} \
                               failed: {e:#}");
                }
            } else {
                // Heartbeat doubles as the breaker re-arm: an open
                // breaker over a live shard goes half-open (one probe
                // may pass), and a probe lost to a dropped collect is
                // returned — recovery latency is bounded by the
                // watchdog interval, like crash detection.
                core.endpoints[s].breaker().probe();
            }
        }
    }
}

/// A running, supervised pool of shard executors covering the whole
/// base model.
pub struct ExecutorFleet {
    core: Arc<FleetCore>,
    assign: LayerAssignment,
    watchdog: Option<JoinHandle<()>>,
}

impl ExecutorFleet {
    /// Split the base along `placement.shards()` and spawn the fleet on
    /// the placement's executor device class.  A placement asking for
    /// more shards than the model has blocks is an error (every shard
    /// must own at least one block), not a silent clamp — analytic
    /// models keyed off `Placement::shards()` must match the executable
    /// topology.
    pub fn start(engine: Arc<Engine>, base: BaseWeights,
                 policy: BatchPolicy, placement: Placement)
                 -> Result<ExecutorFleet> {
        let devices = (0..placement.shards().max(1))
            .map(|s| Device::new(&format!("exec-shard{s}"),
                                 placement.executor_device_for(s)))
            .collect();
        Self::start_with_devices(engine, base, policy, devices)
    }

    /// Spawn one shard per supplied device (devices are taken in layer
    /// order).  Exposed so tests and heterogeneous deployments can
    /// inject device classes/capacities; `start` is the common path.
    pub fn start_with_devices(engine: Arc<Engine>, base: BaseWeights,
                              policy: BatchPolicy,
                              mut devices: Vec<Device>)
                              -> Result<ExecutorFleet> {
        // Capacity-weighted split: each shard takes transformer blocks
        // in proportion to its device's FLOPs, so heterogeneous fleets
        // (`Placement::ShardedHetero`) don't pace every wavefront at
        // the slowest shard.  Equal weights reproduce the contiguous
        // even split exactly, so homogeneous fleets are unchanged.
        let weights: Vec<f64> = devices
            .iter()
            .map(|d| d.kind.flops(base.cfg.precision))
            .collect();
        let assign =
            LayerAssignment::capacity_weighted(base.cfg.n_layers, &weights);
        anyhow::ensure!(
            assign.shards() == devices.len(),
            "{} devices for {} assignable shards (each shard needs at \
             least one block)",
            devices.len(), assign.shards()
        );
        let slices = model_state::split_shards(base, &assign);
        // Two passes: charge every ledger first so an undeployable plan
        // fails before ANY shard thread spawns, then spawn the fleet.
        for (slice, device) in slices.iter().zip(&mut devices) {
            charge_shard(device, slice.shard, slice.param_bytes())?;
        }
        // One fleet-global lockstep barrier shared by every shard
        // (consulted only under `BatchPolicy::LockstepFleet`).
        let barrier = Arc::new(FleetBarrier::default());
        // Retain every shard's respawn seed: the weight slice is a
        // refcount bump per tensor, not a copy.
        let seeds: Vec<RespawnSeed> = slices
            .iter()
            .zip(&devices)
            .map(|(slice, device)| RespawnSeed {
                weights: slice.clone(),
                device_name: device.name.clone(),
                device_kind: device.kind,
                device_capacity: device.ledger.capacity(),
            })
            .collect();
        // One meter per shard, created *before* the executor: the
        // executor decrements it per dequeued request, the endpoint
        // gates dispatches against it, and it survives respawns (the
        // endpoint keeps the same Arc across generations).
        let meters: Vec<Arc<IngressMeter>> = (0..seeds.len())
            .map(|_| Arc::new(IngressMeter::unbounded()))
            .collect();
        let shards: Vec<ShardExecutor> = slices
            .into_iter()
            .zip(devices)
            .zip(&meters)
            .map(|((slice, device), meter)| {
                ShardExecutor::spawn(engine.clone(), slice, policy,
                                     device, barrier.clone(),
                                     meter.clone())
            })
            .collect();
        let endpoints = shards
            .iter()
            .zip(meters)
            .map(|(s, meter)| {
                Arc::new(ShardEndpoint::with_shared(
                    s.sender(),
                    meter,
                    Arc::new(CircuitBreaker::disabled()),
                ))
            })
            .collect();
        let retired = vec![ExecutorStats::default(); shards.len()];
        let core = Arc::new(FleetCore {
            engine,
            policy,
            barrier,
            seeds,
            endpoints,
            shards: Mutex::new(shards),
            retired: Mutex::new(retired),
            admission: AdmissionController::new(),
            respawns: AtomicU64::new(0),
            stop: AtomicBool::new(false),
        });
        let watchdog = std::thread::Builder::new()
            .name("fleet-watchdog".into())
            .spawn({
                let core = core.clone();
                move || watchdog_loop(core)
            })
            .ok();
        Ok(ExecutorFleet { core, assign, watchdog })
    }

    pub fn n_shards(&self) -> usize {
        self.core.seeds.len()
    }

    /// The layer partition this fleet serves.
    pub fn assignment(&self) -> &LayerAssignment {
        &self.assign
    }

    /// The fleet-global lockstep barrier state (observability/tests).
    pub fn barrier(&self) -> &FleetBarrier {
        &self.core.barrier
    }

    /// Shared handle to the fleet-global barrier, given to every
    /// client context so registration updates it synchronously.
    pub(crate) fn barrier_arc(&self) -> Arc<FleetBarrier> {
        self.core.barrier.clone()
    }

    /// Channel of the first shard — the whole fleet for single-shard
    /// deployments (every pre-fleet caller), e.g. privacy-noise
    /// registration against a local executor.  Resolves the *current*
    /// executor generation.
    pub fn sender(&self) -> Sender<ExecMsg> {
        self.core.endpoints[0].sender()
    }

    /// Channel of the shard owning `layer` (what sharded privacy
    /// registration must use).  Resolves the current generation.
    pub fn sender_for(&self, layer: LayerId) -> Sender<ExecMsg> {
        self.core.endpoints[self.assign.shard_of(layer)].sender()
    }

    /// Whether shard `s`'s executor thread is currently running.
    pub fn is_alive(&self, s: usize) -> bool {
        self.core.is_alive(s)
    }

    /// Respawn generation of shard `s`'s endpoint (0 = the original
    /// executor still serves).
    pub fn route_epoch(&self, s: usize) -> u64 {
        self.core.endpoints[s].epoch()
    }

    /// Total respawns performed over the fleet's lifetime.
    pub fn respawns(&self) -> u64 {
        self.core.respawns.load(Ordering::Acquire)
    }

    /// Tenant quota registry — configure with
    /// [`AdmissionController::set_quota`]; session builders consult it
    /// when a tenant name is attached.
    pub fn admission(&self) -> &AdmissionController {
        &self.core.admission
    }

    /// Bound every shard's ingress queue at `mark` requests (0 restores
    /// the unbounded default).  Takes effect immediately for new
    /// dispatches; already-queued work drains normally.
    pub fn set_ingress_high_water(&self, mark: usize) {
        for e in &self.core.endpoints {
            e.meter().set_high_water(mark);
        }
    }

    /// Arm every shard's circuit breaker to trip after `threshold`
    /// consecutive request failures (0 disables, the default).
    pub fn set_breaker_threshold(&self, threshold: u32) {
        for e in &self.core.endpoints {
            e.breaker().set_threshold(threshold);
        }
    }

    /// Current circuit-breaker state of shard `s` (observability,
    /// tests, the overload bench).
    pub fn breaker_state(&self, s: usize) -> BreakerState {
        self.core.endpoints[s].breaker().state()
    }

    /// Current ingress-queue depth of shard `s`.
    pub fn ingress_depth(&self, s: usize) -> usize {
        self.core.endpoints[s].meter().depth()
    }

    /// Shard `s`'s ingress meter (tests and the overload bench inject
    /// phantom load with [`IngressMeter::force_admit`] through this).
    pub fn ingress_meter(&self, s: usize) -> Arc<IngressMeter> {
        self.core.endpoints[s].meter().clone()
    }

    /// Per-shard load snapshot in shard order — liveness, breaker
    /// state, and ingress pressure in one read.  The continuous-batching
    /// scheduler consults this every iteration: any
    /// [`ShardLoad::overloaded`] shard throttles admission and benches
    /// background work for the step instead of dogpiling.
    pub fn shard_loads(&self) -> Vec<ShardLoad> {
        self.core
            .endpoints
            .iter()
            .enumerate()
            .map(|(s, e)| ShardLoad {
                shard: s,
                alive: self.core.is_alive(s),
                breaker: e.breaker().state(),
                ingress_depth: e.meter().depth(),
                pressure: e.meter().pressure(),
                saturated: e.meter().saturated(),
            })
            .collect()
    }

    /// Rebuild shard `s` on its retained seed: fresh device ledger
    /// (re-charged), registration count seeded from the fleet barrier,
    /// endpoint swapped under a bumped epoch, old generation's stats
    /// retired.  Safe on a live shard (rolling restart) — the watchdog
    /// calls this automatically for dead ones.
    pub fn respawn_shard(&self, s: usize) -> Result<()> {
        self.core.respawn_shard(s)
    }

    /// Build one client's routing table: the owning-shard endpoint per
    /// layer plus a per-shard [`Link`](crate::transport::Link).  Link
    /// kinds come from the placement (co-located shard `SharedLocal`,
    /// cross-shard hops `NvLink`) unless overridden by the session
    /// builder.  A [`FaultPlan`] interposes on the shards its rules
    /// target (fault-free shards keep the direct endpoint).
    pub(crate) fn routing_for(&self, client_id: usize,
                              placement: &Placement,
                              link_override: Option<LinkKind>,
                              faults: Option<&FaultPlan>)
                              -> RoutingTable {
        let kinds: Vec<LinkKind> = match link_override {
            Some(k) => vec![k; self.n_shards()],
            None => placement.shard_links(client_id, self.n_shards()),
        };
        let routes = self
            .core
            .endpoints
            .iter()
            .enumerate()
            .zip(kinds)
            .map(|((s, endpoint), k)| {
                let endpoint = match faults {
                    Some(plan) => plan.wrap_endpoint(s, endpoint.clone()),
                    None => endpoint.clone(),
                };
                ShardRoute::shared(s, endpoint, k)
            })
            .collect();
        RoutingTable::new(self.assign.clone(), routes)
            .expect("fleet routes match its assignment by construction")
    }

    /// Merged + per-shard statistics snapshot.  Per-shard entries
    /// include every retired generation (respawns do not lose flushes).
    pub fn stats(&self) -> FleetStats {
        let live: Vec<ExecutorStats> =
            lock(&self.core.shards).iter().map(|s| s.stats()).collect();
        let retired = lock(&self.core.retired);
        let per_shard = retired
            .iter()
            .zip(live)
            .map(|(dead, live)| {
                let mut s = dead.clone();
                s.absorb(&live);
                s
            })
            .collect();
        self.finish_stats(FleetStats::merge(per_shard))
    }

    /// Stamp fleet-level health counters (respawns, breaker trips) onto
    /// a merged snapshot — shared by [`Self::stats`] and
    /// [`Self::shutdown`].
    fn finish_stats(&self, mut fs: FleetStats) -> FleetStats {
        fs.respawns = self.respawns();
        fs.breaker_transitions = self
            .core
            .endpoints
            .iter()
            .map(|e| e.breaker().transitions())
            .collect();
        fs
    }

    /// Bytes resident on each shard's device ledger (the real weight
    /// slice — ~1/N of the base each).
    pub fn shard_resident_bytes(&self) -> Vec<u64> {
        lock(&self.core.shards)
            .iter()
            .map(|s| s.resident_bytes())
            .collect()
    }

    /// Stop the watchdog, then every shard — draining in layer order
    /// (shard 0 first) — and return the final statistics (retired
    /// generations included).
    pub fn shutdown(mut self) -> FleetStats {
        self.stop_watchdog();
        let shards = std::mem::take(&mut *lock(&self.core.shards));
        let retired = lock(&self.core.retired).clone();
        let mut per_shard = Vec::with_capacity(shards.len());
        for (dead, shard) in retired.into_iter().zip(shards) {
            let mut s = dead;
            s.absorb(&shard.shutdown());
            per_shard.push(s);
        }
        self.finish_stats(FleetStats::merge(per_shard))
    }

    fn stop_watchdog(&mut self) {
        self.core.stop.store(true, Ordering::Release);
        if let Some(h) = self.watchdog.take() {
            let _ = h.join();
        }
    }
}

impl Drop for ExecutorFleet {
    /// A fleet dropped without `shutdown` must not leave the watchdog
    /// respawning shards forever: stop it first, then the shards drain
    /// via their own `Drop`s when the core's last `Arc` goes.
    fn drop(&mut self) {
        self.stop_watchdog();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use crate::config::SYM_TINY;
    use crate::coordinator::model_state::{scan, split_shards};
    use crate::device::{DeviceKind, MemoryLedger};
    use crate::tensor::Tensor;
    use std::collections::HashMap;

    fn fake_base() -> BaseWeights {
        let cfg = &SYM_TINY;
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let mut m = HashMap::new();
        m.insert("embed".into(), Tensor::zeros(&[v, d]));
        m.insert("pos".into(), Tensor::zeros(&[cfg.max_seq, d]));
        m.insert("norm_f".into(), Tensor::zeros(&[d]));
        m.insert("lm_head_w".into(), Tensor::zeros(&[d, v]));
        m.insert("lm_head_b".into(), Tensor::zeros(&[v]));
        for l in 0..cfg.n_layers {
            m.insert(format!("l{l}.norm1"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.norm2"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wqkv"), Tensor::zeros(&[d, 3 * d]));
            m.insert(format!("l{l}.bqkv"), Tensor::zeros(&[3 * d]));
            m.insert(format!("l{l}.wo"), Tensor::zeros(&[d, d]));
            m.insert(format!("l{l}.bo"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wup"), Tensor::zeros(&[d, f]));
            m.insert(format!("l{l}.bup"), Tensor::zeros(&[f]));
            m.insert(format!("l{l}.wdown"), Tensor::zeros(&[f, d]));
            m.insert(format!("l{l}.bdown"), Tensor::zeros(&[d]));
        }
        scan(cfg, &m).unwrap().0
    }

    #[test]
    fn charge_shard_oom_is_typed() {
        let base = fake_base();
        let assign = LayerAssignment::contiguous(SYM_TINY.n_layers, 2);
        let slices = split_shards(base, &assign);
        let mut dev = Device::new("tiny", DeviceKind::GpuFast40);
        dev.ledger = MemoryLedger::new(1024); // 1 KiB: cannot fit
        let err = charge_shard(&mut dev, 1, slices[1].param_bytes())
            .unwrap_err();
        let typed: SymbiosisError = err.into();
        match typed {
            SymbiosisError::ShardOom { shard, need_bytes,
                                       capacity_bytes } => {
                assert_eq!(shard, 1);
                assert_eq!(capacity_bytes, 1024);
                assert!(need_bytes > capacity_bytes);
            }
            other => panic!("expected ShardOom, got {other}"),
        }
    }

    #[test]
    fn charge_shard_fits_and_ledgers_split_the_base() {
        let base = fake_base();
        let total = base.param_bytes();
        let assign = LayerAssignment::contiguous(SYM_TINY.n_layers, 4);
        let slices = split_shards(base, &assign);
        let mut charged = 0u64;
        for s in &slices {
            let mut dev = Device::new("g", DeviceKind::GpuA100_80);
            charge_shard(&mut dev, s.shard, s.param_bytes()).unwrap();
            assert_eq!(dev.ledger.used(), s.param_bytes());
            charged += dev.ledger.used();
        }
        assert_eq!(charged, total);
    }

    #[test]
    fn shard_weight_clones_share_storage() {
        // The respawn seed must be a refcount bump, not a weight copy.
        let base = fake_base();
        let assign = LayerAssignment::contiguous(SYM_TINY.n_layers, 2);
        let slices = split_shards(base, &assign);
        let seed = slices[0].clone();
        assert_eq!(seed.param_bytes(), slices[0].param_bytes());
        let (w_orig, _) =
            slices[0].linear(crate::coordinator::proto::LayerId::Qkv(0))
                .unwrap();
        let (w_seed, _) =
            seed.linear(crate::coordinator::proto::LayerId::Qkv(0))
                .unwrap();
        assert!(std::ptr::eq(w_orig.as_f32().as_ptr(),
                             w_seed.as_f32().as_ptr()),
                "clone must alias the same tensor storage");
    }

    #[test]
    fn hetero_flops_weights_split_tiny_three_one() {
        // The exact weights start_with_devices derives for a
        // fast + slow fleet over SYM_TINY (4 blocks): 3.5:1 flops →
        // the fast shard takes 3 blocks, the slow shard 1.
        let fast = DeviceKind::GpuFast40.flops(SYM_TINY.precision);
        let slow = DeviceKind::GpuSlow40.flops(SYM_TINY.precision);
        let assign = LayerAssignment::capacity_weighted(
            SYM_TINY.n_layers, &[fast, slow]);
        assert_eq!(assign.shards(), 2);
        assert_eq!(assign.block_range(0), 0..3);
        assert_eq!(assign.block_range(1), 3..4);
    }

    #[test]
    fn fleet_barrier_counts_and_saturates() {
        let b = FleetBarrier::default();
        assert_eq!(b.registered(), 0);
        b.register();
        b.register();
        assert_eq!(b.registered(), 2);
        b.deregister();
        b.deregister();
        b.deregister(); // stray deregister must not wrap
        assert_eq!(b.registered(), 0);
    }

    #[test]
    fn merged_stats_sum_over_shards() {
        let a = ExecutorStats {
            n_flushes: 3,
            sum_batch_clients: 6.0,
            sum_wait_secs: 0.3,
            real_tokens: 100,
            bucket_tokens: 128,
            requests_served: 9,
            busy_secs: 0.75,
            idle_secs: 0.25,
            ..Default::default()
        };
        let b = ExecutorStats {
            n_flushes: 1,
            sum_batch_clients: 2.0,
            sum_wait_secs: 0.1,
            real_tokens: 28,
            bucket_tokens: 32,
            requests_served: 2,
            ..Default::default()
        };
        let f = FleetStats::merge(vec![a, b]);
        assert_eq!(f.n_shards(), 2);
        assert_eq!(f.n_flushes, 4); // via Deref
        assert_eq!(f.requests_served, 11);
        assert!((f.busy_secs - 0.75).abs() < 1e-12);
        assert!((f.per_shard[0].occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(f.shard_occupancy().len(), 2);
        assert!((f.mean_batch_clients() - 2.0).abs() < 1e-9);
        assert!((f.padding_overhead() - (1.0 - 128.0 / 160.0)).abs()
                < 1e-9);
    }

    #[test]
    fn training_stats_track_peaks_and_print() {
        let t = TrainingStats::default();
        t.microbatch_started();
        t.microbatch_started();
        t.stash_grew(100);
        t.stash_grew(60);
        t.microbatch_finished();
        t.stash_shrunk(100);
        t.grad_accum_step();
        t.grad_accum_step();
        assert_eq!(t.microbatches_in_flight(), 1);
        assert_eq!(t.microbatches_in_flight_peak(), 2);
        assert_eq!(t.activation_stash_bytes(), 60);
        assert_eq!(t.activation_stash_peak_bytes(), 160);
        assert_eq!(t.grad_accum_steps(), 2);
        // gauges saturate instead of wrapping
        t.microbatch_finished();
        t.microbatch_finished();
        t.stash_shrunk(1000);
        assert_eq!(t.microbatches_in_flight(), 0);
        assert_eq!(t.activation_stash_bytes(), 0);
        // the Display line appears exactly when training ran
        let mut fs = FleetStats::merge(vec![ExecutorStats::default()]);
        assert!(!format!("{fs}").contains("training:"));
        fs.train_grad_accum_steps = t.grad_accum_steps();
        fs.train_microbatches_in_flight_peak =
            t.microbatches_in_flight_peak();
        fs.train_activation_stash_peak_bytes =
            t.activation_stash_peak_bytes();
        let text = format!("{fs}");
        assert!(text.contains("training: 2 grad accum step(s)"),
                "{text}");
        assert!(text.contains("peak 2 micro-batch(es)"), "{text}");
        assert!(text.contains("peak stash 160 B"), "{text}");
    }

    #[test]
    fn merged_flush_ring_is_bounded() {
        use crate::coordinator::base_executor::{FlushRecord,
                                                FLUSH_RECORD_CAP};
        use crate::coordinator::proto::{LayerId, OpKind};
        // 4 shards each at the per-shard cap: the merged ring must stay
        // at the same bound (newest records win), not 4x it.
        let rec = |l: usize| FlushRecord {
            layer: LayerId::Qkv(0),
            op: OpKind::Forward,
            n_requests: l,
            n_clients: 1,
            real_tokens: 1,
            bucket_tokens: 1,
            mean_wait_secs: 0.0,
        };
        let per_shard: Vec<ExecutorStats> = (0..4)
            .map(|s| ExecutorStats {
                flushes: (0..FLUSH_RECORD_CAP).map(|_| rec(s)).collect(),
                n_flushes: FLUSH_RECORD_CAP as u64,
                ..Default::default()
            })
            .collect();
        let f = FleetStats::merge(per_shard);
        assert_eq!(f.flushes.len(), FLUSH_RECORD_CAP,
                   "merged ring must stay bounded");
        assert_eq!(f.n_flushes, 4 * FLUSH_RECORD_CAP as u64,
                   "aggregate counters stay exact");
        // the survivors are the newest (last shards')
        assert!(f.flushes.iter().all(|r| r.n_requests == 3));
    }
}
