//! The executor fleet: one [`ShardExecutor`] per contiguous layer range
//! of the frozen base (paper section 3.3, executable form).
//!
//! [`ExecutorFleet::start`] derives a [`LayerAssignment`] from the
//! deployment's `Placement::shards()`, splits the loaded
//! [`BaseWeights`] into per-shard slices (`model_state::split_shards`,
//! zero-copy), charges each shard's simulated [`Device`] ledger with
//! its real resident bytes — failing with a typed
//! [`SymbiosisError::ShardOom`] before any thread starts when a slice
//! does not fit — and spawns one executor thread per shard, each with
//! its own [`BatchPolicy`] queues.
//!
//! Clients never see the fleet directly: `Deployment::build_core` hands
//! every client a [`RoutingTable`] that maps each `LayerId` to the
//! owning shard's channel, with a per-shard [`Link`] charged per hop
//! (co-located shard: `SharedLocal`; cross-shard: `NvLink` — see
//! `Placement::shard_links`).  A fleet of one shard is exactly the old
//! single `BaseExecutor`, with the same hot path.
//!
//! [`FleetStats`] merges the per-shard [`ExecutorStats`] snapshots so
//! Table-5 style metrics still come out of one call; it `Deref`s to the
//! merged view, keeping existing consumers (`stats.n_flushes`,
//! `stats.mean_batch_clients()`, …) source-compatible.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::Sender;
use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::base_executor::{ExecutorStats, ShardExecutor};
use crate::coordinator::batching::BatchPolicy;
use crate::coordinator::model_state::{self, BaseWeights};
use crate::coordinator::placement::Placement;
use crate::coordinator::proto::{ExecMsg, LayerId};
use crate::coordinator::sharding::LayerAssignment;
use crate::coordinator::virt_layer::{RoutingTable, ShardRoute};
use crate::device::Device;
use crate::error::SymbiosisError;
use crate::runtime::Engine;
use crate::transport::LinkKind;

/// Fleet-global lockstep barrier state: the one registration count all
/// shards of a fleet share (`Arc`'d into every shard thread).  Clients
/// maintain it *synchronously* in
/// `VirtLayerCtx::register`/`deregister` — before their per-shard
/// Register/Deregister messages — so no shard can observe a client's
/// requests while the global count still excludes that client;
/// `BatchPolicy::LockstepFleet` barriers read it instead of the
/// shard-local count, reproducing mLoRA's global lockstep at
/// shards > 1 (paper Tables 4/5).
#[derive(Debug, Default)]
pub struct FleetBarrier {
    registered: AtomicUsize,
}

impl FleetBarrier {
    pub fn register(&self) {
        self.registered.fetch_add(1, Ordering::SeqCst);
    }

    pub fn deregister(&self) {
        // Saturating: a stray Deregister (client built against a dead
        // fleet) must not wrap the barrier count.
        let _ = self.registered.fetch_update(
            Ordering::SeqCst, Ordering::SeqCst,
            |n| Some(n.saturating_sub(1)));
    }

    /// Fleet-wide registered-client count.
    pub fn registered(&self) -> usize {
        self.registered.load(Ordering::SeqCst)
    }
}

/// Fleet-level aggregation of per-shard [`ExecutorStats`].  Derefs to
/// the merged snapshot (sums are exact; `flushes` concatenates the
/// shards' bounded recent rings in shard order), with the per-shard
/// detail kept alongside for placement-style breakdowns.
#[derive(Debug, Default, Clone)]
pub struct FleetStats {
    merged: ExecutorStats,
    pub per_shard: Vec<ExecutorStats>,
}

impl FleetStats {
    /// Merge per-shard snapshots (shard order preserved).
    pub fn merge(per_shard: Vec<ExecutorStats>) -> Self {
        let mut merged = ExecutorStats::default();
        for s in &per_shard {
            merged.flushes.extend(s.flushes.iter().cloned());
            merged.n_flushes += s.n_flushes;
            merged.sum_batch_clients += s.sum_batch_clients;
            merged.sum_wait_secs += s.sum_wait_secs;
            merged.real_tokens += s.real_tokens;
            merged.bucket_tokens += s.bucket_tokens;
            merged.requests_served += s.requests_served;
            merged.noise_registrations += s.noise_registrations;
            merged.busy_secs += s.busy_secs;
            merged.idle_secs += s.idle_secs;
        }
        FleetStats { merged, per_shard }
    }

    /// Per-shard occupancy (busy / (busy + idle)) in shard order — what
    /// the pipeline bench reports as pipeline occupancy.
    pub fn shard_occupancy(&self) -> Vec<f64> {
        self.per_shard.iter().map(|s| s.occupancy()).collect()
    }

    /// The fleet-wide merged snapshot (also reachable via `Deref`).
    pub fn merged(&self) -> &ExecutorStats {
        &self.merged
    }

    pub fn n_shards(&self) -> usize {
        self.per_shard.len()
    }
}

impl std::ops::Deref for FleetStats {
    type Target = ExecutorStats;

    fn deref(&self) -> &ExecutorStats {
        &self.merged
    }
}

/// Charge a shard's resident slice to its device ledger; a slice that
/// does not fit fails with a typed [`SymbiosisError::ShardOom`] — this
/// is what makes an undeployable `ShardPlan` fail `Deployment::start`
/// instead of succeeding silently.
pub fn charge_shard(device: &mut Device, shard: usize, resident: u64)
                    -> Result<()> {
    let capacity = device.ledger.capacity();
    device.ledger.set("base-shard", resident).map_err(|_| {
        anyhow::Error::new(SymbiosisError::ShardOom {
            shard,
            need_bytes: resident,
            capacity_bytes: capacity,
        })
    })
}

/// A running pool of shard executors covering the whole base model.
pub struct ExecutorFleet {
    shards: Vec<ShardExecutor>,
    assign: LayerAssignment,
    barrier: Arc<FleetBarrier>,
}

impl ExecutorFleet {
    /// Split the base along `placement.shards()` and spawn the fleet on
    /// the placement's executor device class.  A placement asking for
    /// more shards than the model has blocks is an error (every shard
    /// must own at least one block), not a silent clamp — analytic
    /// models keyed off `Placement::shards()` must match the executable
    /// topology.
    pub fn start(engine: Arc<Engine>, base: BaseWeights,
                 policy: BatchPolicy, placement: Placement)
                 -> Result<ExecutorFleet> {
        let devices = (0..placement.shards().max(1))
            .map(|s| Device::new(&format!("exec-shard{s}"),
                                 placement.executor_device()))
            .collect();
        Self::start_with_devices(engine, base, policy, devices)
    }

    /// Spawn one shard per supplied device (devices are taken in layer
    /// order).  Exposed so tests and heterogeneous deployments can
    /// inject device classes/capacities; `start` is the common path.
    pub fn start_with_devices(engine: Arc<Engine>, base: BaseWeights,
                              policy: BatchPolicy,
                              mut devices: Vec<Device>)
                              -> Result<ExecutorFleet> {
        let assign =
            LayerAssignment::contiguous(base.cfg.n_layers, devices.len());
        anyhow::ensure!(
            assign.shards() == devices.len(),
            "{} devices for {} assignable shards (each shard needs at \
             least one block)",
            devices.len(), assign.shards()
        );
        let slices = model_state::split_shards(base, &assign);
        // Two passes: charge every ledger first so an undeployable plan
        // fails before ANY shard thread spawns, then spawn the fleet.
        for (slice, device) in slices.iter().zip(&mut devices) {
            charge_shard(device, slice.shard, slice.param_bytes())?;
        }
        // One fleet-global lockstep barrier shared by every shard
        // (consulted only under `BatchPolicy::LockstepFleet`).
        let barrier = Arc::new(FleetBarrier::default());
        let shards = slices
            .into_iter()
            .zip(devices)
            .map(|(slice, device)| {
                ShardExecutor::spawn(engine.clone(), slice, policy,
                                     device, barrier.clone())
            })
            .collect();
        Ok(ExecutorFleet { shards, assign, barrier })
    }

    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The layer partition this fleet serves.
    pub fn assignment(&self) -> &LayerAssignment {
        &self.assign
    }

    /// The fleet-global lockstep barrier state (observability/tests).
    pub fn barrier(&self) -> &FleetBarrier {
        &self.barrier
    }

    /// Shared handle to the fleet-global barrier, given to every
    /// client context so registration updates it synchronously.
    pub(crate) fn barrier_arc(&self) -> Arc<FleetBarrier> {
        self.barrier.clone()
    }

    /// Channel of the first shard — the whole fleet for single-shard
    /// deployments (every pre-fleet caller), e.g. privacy-noise
    /// registration against a local executor.
    pub fn sender(&self) -> Sender<ExecMsg> {
        self.shards[0].sender()
    }

    /// Channel of the shard owning `layer` (what sharded privacy
    /// registration must use).
    pub fn sender_for(&self, layer: LayerId) -> Sender<ExecMsg> {
        self.shards[self.assign.shard_of(layer)].sender()
    }

    /// Build one client's routing table: the owning-shard channel per
    /// layer plus a per-shard [`Link`](crate::transport::Link).  Link
    /// kinds come from the placement (co-located shard `SharedLocal`,
    /// cross-shard hops `NvLink`) unless overridden by the session
    /// builder.
    pub(crate) fn routing_for(&self, client_id: usize,
                              placement: &Placement,
                              link_override: Option<LinkKind>)
                              -> RoutingTable {
        let kinds: Vec<LinkKind> = match link_override {
            Some(k) => vec![k; self.shards.len()],
            None => placement.shard_links(client_id, self.shards.len()),
        };
        let routes = self
            .shards
            .iter()
            .zip(kinds)
            .map(|(s, k)| ShardRoute::new(s.sender(), k))
            .collect();
        RoutingTable::new(self.assign.clone(), routes)
    }

    /// Merged + per-shard statistics snapshot.
    pub fn stats(&self) -> FleetStats {
        FleetStats::merge(self.shards.iter().map(|s| s.stats()).collect())
    }

    /// Bytes resident on each shard's device ledger (the real weight
    /// slice — ~1/N of the base each).
    pub fn shard_resident_bytes(&self) -> Vec<u64> {
        self.shards.iter().map(|s| s.resident_bytes()).collect()
    }

    /// Stop every shard, draining in layer order (shard 0 first), and
    /// return the final statistics.
    pub fn shutdown(self) -> FleetStats {
        let mut per_shard = Vec::with_capacity(self.shards.len());
        for shard in self.shards {
            per_shard.push(shard.shutdown());
        }
        FleetStats::merge(per_shard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SYM_TINY;
    use crate::coordinator::model_state::{scan, split_shards};
    use crate::device::{DeviceKind, MemoryLedger};
    use crate::tensor::Tensor;
    use std::collections::HashMap;

    fn fake_base() -> BaseWeights {
        let cfg = &SYM_TINY;
        let (d, f, v) = (cfg.d_model, cfg.d_ff, cfg.vocab);
        let mut m = HashMap::new();
        m.insert("embed".into(), Tensor::zeros(&[v, d]));
        m.insert("pos".into(), Tensor::zeros(&[cfg.max_seq, d]));
        m.insert("norm_f".into(), Tensor::zeros(&[d]));
        m.insert("lm_head_w".into(), Tensor::zeros(&[d, v]));
        m.insert("lm_head_b".into(), Tensor::zeros(&[v]));
        for l in 0..cfg.n_layers {
            m.insert(format!("l{l}.norm1"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.norm2"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wqkv"), Tensor::zeros(&[d, 3 * d]));
            m.insert(format!("l{l}.bqkv"), Tensor::zeros(&[3 * d]));
            m.insert(format!("l{l}.wo"), Tensor::zeros(&[d, d]));
            m.insert(format!("l{l}.bo"), Tensor::zeros(&[d]));
            m.insert(format!("l{l}.wup"), Tensor::zeros(&[d, f]));
            m.insert(format!("l{l}.bup"), Tensor::zeros(&[f]));
            m.insert(format!("l{l}.wdown"), Tensor::zeros(&[f, d]));
            m.insert(format!("l{l}.bdown"), Tensor::zeros(&[d]));
        }
        scan(cfg, &m).unwrap().0
    }

    #[test]
    fn charge_shard_oom_is_typed() {
        let base = fake_base();
        let assign = LayerAssignment::contiguous(SYM_TINY.n_layers, 2);
        let slices = split_shards(base, &assign);
        let mut dev = Device::new("tiny", DeviceKind::GpuFast40);
        dev.ledger = MemoryLedger::new(1024); // 1 KiB: cannot fit
        let err = charge_shard(&mut dev, 1, slices[1].param_bytes())
            .unwrap_err();
        let typed: SymbiosisError = err.into();
        match typed {
            SymbiosisError::ShardOom { shard, need_bytes,
                                       capacity_bytes } => {
                assert_eq!(shard, 1);
                assert_eq!(capacity_bytes, 1024);
                assert!(need_bytes > capacity_bytes);
            }
            other => panic!("expected ShardOom, got {other}"),
        }
    }

    #[test]
    fn charge_shard_fits_and_ledgers_split_the_base() {
        let base = fake_base();
        let total = base.param_bytes();
        let assign = LayerAssignment::contiguous(SYM_TINY.n_layers, 4);
        let slices = split_shards(base, &assign);
        let mut charged = 0u64;
        for s in &slices {
            let mut dev = Device::new("g", DeviceKind::GpuA100_80);
            charge_shard(&mut dev, s.shard, s.param_bytes()).unwrap();
            assert_eq!(dev.ledger.used(), s.param_bytes());
            charged += dev.ledger.used();
        }
        assert_eq!(charged, total);
    }

    #[test]
    fn fleet_barrier_counts_and_saturates() {
        let b = FleetBarrier::default();
        assert_eq!(b.registered(), 0);
        b.register();
        b.register();
        assert_eq!(b.registered(), 2);
        b.deregister();
        b.deregister();
        b.deregister(); // stray deregister must not wrap
        assert_eq!(b.registered(), 0);
    }

    #[test]
    fn merged_stats_sum_over_shards() {
        let a = ExecutorStats {
            n_flushes: 3,
            sum_batch_clients: 6.0,
            sum_wait_secs: 0.3,
            real_tokens: 100,
            bucket_tokens: 128,
            requests_served: 9,
            busy_secs: 0.75,
            idle_secs: 0.25,
            ..Default::default()
        };
        let b = ExecutorStats {
            n_flushes: 1,
            sum_batch_clients: 2.0,
            sum_wait_secs: 0.1,
            real_tokens: 28,
            bucket_tokens: 32,
            requests_served: 2,
            ..Default::default()
        };
        let f = FleetStats::merge(vec![a, b]);
        assert_eq!(f.n_shards(), 2);
        assert_eq!(f.n_flushes, 4); // via Deref
        assert_eq!(f.requests_served, 11);
        assert!((f.busy_secs - 0.75).abs() < 1e-12);
        assert!((f.per_shard[0].occupancy() - 0.75).abs() < 1e-12);
        assert_eq!(f.shard_occupancy().len(), 2);
        assert!((f.mean_batch_clients() - 2.0).abs() < 1e-9);
        assert!((f.padding_overhead() - (1.0 - 128.0 / 160.0)).abs()
                < 1e-9);
    }
}
