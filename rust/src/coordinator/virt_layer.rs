//! `VirtLayer` — the client-side proxy for a base-model layer, routed
//! over the shard fleet.
//!
//! The paper replaces every frozen layer in the client's model definition
//! with a `torch.nn.Module` whose forward/backward ship activations to
//! the base executor (section 3.2, Fig. 4).  Here the proxy is a handle
//! that packages the request, looks the layer up in its [`RoutingTable`]
//! (section 3.3: the base may be sharded over several executors),
//! charges that shard's [`Link`], applies the privacy protocol when
//! configured, and blocks on the response — keeping the *client* the
//! driver of its own execution.
//!
//! With Arc-backed tensors the request/response payloads are shared
//! views: shipping `x` to the executor (and receiving the scattered
//! output slice back) moves no activation bytes in-process.  Each shard
//! route still charges the *modeled* transfer for the placement being
//! simulated — a co-located shard costs `SharedLocal`, a cross-shard hop
//! `NvLink` — so accounting matches the topology while real host copies
//! stay zero.
//!
//! A shard that fails a flush answers with a typed error message; the
//! proxy surfaces it as [`SymbiosisError::ExecutorFailed`] instead of a
//! bare channel disconnect.
//!
//! Contexts are built by [`Deployment::build_core`] (one per client id);
//! sessions configure the links, realized delays, and the privacy
//! protocol through the
//! [`SessionBuilder`](crate::coordinator::SessionBuilder) rather than
//! mutating this struct after the fact.
//!
//! [`Deployment::build_core`]: crate::coordinator::Deployment

use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::coordinator::privacy::PrivacyCtx;
use crate::coordinator::proto::{ExecMsg, LayerId, LayerRequest,
                                LayerResponse, OpKind, Urgency};
use crate::coordinator::sharding::LayerAssignment;
use crate::error::SymbiosisError;
use crate::tensor::Tensor;
use crate::transport::{Link, LinkKind};

/// One shard's endpoint as a client sees it: the executor channel plus
/// the simulated link the client's traffic to that shard crosses.
pub struct ShardRoute {
    pub tx: Sender<ExecMsg>,
    pub link: Mutex<Link>,
}

impl ShardRoute {
    pub fn new(tx: Sender<ExecMsg>, kind: LinkKind) -> Self {
        ShardRoute { tx, link: Mutex::new(Link::new(kind)) }
    }
}

/// Client-side routing over the executor fleet: which shard owns each
/// layer, and over which link it is reached.
pub struct RoutingTable {
    assign: LayerAssignment,
    routes: Vec<ShardRoute>,
}

impl RoutingTable {
    pub fn new(assign: LayerAssignment, routes: Vec<ShardRoute>) -> Self {
        assert_eq!(assign.shards(), routes.len(),
                   "assignment/route count mismatch");
        RoutingTable { assign, routes }
    }

    /// Single-shard table — the pre-fleet topology (tests, tools).
    pub fn single(tx: Sender<ExecMsg>, kind: LinkKind) -> Self {
        RoutingTable::new(LayerAssignment::contiguous(1, 1),
                          vec![ShardRoute::new(tx, kind)])
    }

    pub fn n_shards(&self) -> usize {
        self.routes.len()
    }

    /// The route serving `layer`.
    pub fn route(&self, layer: LayerId) -> &ShardRoute {
        &self.routes[self.assign.shard_of(layer)]
    }

    pub fn routes(&self) -> &[ShardRoute] {
        &self.routes
    }
}

/// Per-client view of the executor fleet: layer proxies share this
/// context.
pub struct VirtLayerCtx {
    pub client_id: usize,
    routing: RoutingTable,
    /// Optional activation-privacy protocol state.
    pub privacy: Option<PrivacyCtx>,
    /// When set, simulated link delays are *realized* as actual sleeps,
    /// so remote/network placements behave (not just account) slower —
    /// used by the placement benches (Figs 7/13/21).
    pub realize_delays: bool,
    /// Accumulated queue-wait observed by this client (Fig 7).
    pub wait_secs: Mutex<f64>,
    /// Accumulated simulated link time (all shard links).
    pub link_secs: Mutex<f64>,
}

impl VirtLayerCtx {
    pub fn new(client_id: usize, routing: RoutingTable) -> Self {
        VirtLayerCtx {
            client_id,
            routing,
            privacy: None,
            realize_delays: false,
            wait_secs: Mutex::new(0.0),
            link_secs: Mutex::new(0.0),
        }
    }

    /// Register with every shard (lockstep policies count clients at
    /// each shard independently).
    pub fn register(&self) {
        for r in self.routing.routes() {
            let _ = r.tx.send(ExecMsg::Register {
                client_id: self.client_id,
            });
        }
    }

    pub fn deregister(&self) {
        for r in self.routing.routes() {
            let _ = r.tx.send(ExecMsg::Deregister {
                client_id: self.client_id,
            });
        }
    }

    /// Invoke the forward pass of a base linear layer with activations
    /// `x: (T, Din)`.
    pub fn forward(&self, layer: LayerId, x: Tensor, urgency: Urgency)
                   -> Result<Tensor> {
        // Privacy: ship x + n, receive W(x+n)+b, subtract n_eff = W.n.
        if let Some(p) = &self.privacy {
            let (noised, n_eff) = p.apply(layer, &x)?;
            let y_noisy =
                self.round_trip(layer, OpKind::Forward, noised, None,
                                urgency)?;
            return Ok(crate::tensor::ops::sub(&y_noisy, &n_eff));
        }
        self.round_trip(layer, OpKind::Forward, x, None, urgency)
    }

    /// Invoke the memory-optimized backward: returns `dX = dY . W^T`.
    pub fn backward(&self, layer: LayerId, dy: Tensor, urgency: Urgency)
                    -> Result<Tensor> {
        self.round_trip(layer, OpKind::Backward, dy, None, urgency)
    }

    /// Embedding lookup: token ids + positions (both (T,) i32).
    pub fn embed(&self, tokens: Tensor, positions: Tensor,
                 urgency: Urgency) -> Result<Tensor> {
        self.round_trip(LayerId::Embed, OpKind::Forward, tokens,
                        Some(positions), urgency)
    }

    /// Charge one payload to a shard's link, realizing the delay when
    /// configured.
    fn charge(&self, route: &ShardRoute, t: &Tensor) {
        let dt = route.link.lock().unwrap().send(t);
        *self.link_secs.lock().unwrap() += dt;
        if self.realize_delays && dt > 20e-6 {
            std::thread::sleep(std::time::Duration::from_secs_f64(dt));
        }
    }

    fn round_trip(&self, layer: LayerId, op: OpKind, x: Tensor,
                  positions: Option<Tensor>, urgency: Urgency)
                  -> Result<Tensor> {
        let route = self.routing.route(layer);
        // Charge the shard's link for the request payload.
        self.charge(route, &x);
        let (tx, rx) = channel::<LayerResponse>();
        route
            .tx
            .send(ExecMsg::Request(LayerRequest {
                client_id: self.client_id,
                layer,
                op,
                x,
                positions,
                urgency,
                resp: tx,
            }))
            .ok()
            .context("shard executor is gone")?;
        let resp = rx.recv().context("shard executor dropped request")?;
        *self.wait_secs.lock().unwrap() += resp.queue_wait_secs;
        let y = resp.y.map_err(|message| {
            anyhow::Error::new(SymbiosisError::ExecutorFailed {
                layer: layer.label(),
                message,
            })
        })?;
        // Charge the link for the response payload.
        self.charge(route, &y);
        Ok(y)
    }

    /// Total simulated link time charged so far (all shards).
    pub fn link_time(&self) -> f64 {
        *self.link_secs.lock().unwrap()
    }

    /// Per-shard link traffic: `(messages, bytes_moved)` in shard
    /// order — shows where the routed topology sends this client's
    /// activations.
    pub fn link_traffic(&self) -> Vec<(u64, u64)> {
        self.routing
            .routes()
            .iter()
            .map(|r| {
                let l = r.link.lock().unwrap();
                (l.messages, l.bytes_moved)
            })
            .collect()
    }

    /// Total executor queue wait observed so far.
    pub fn queue_wait(&self) -> f64 {
        *self.wait_secs.lock().unwrap()
    }
}

impl Drop for VirtLayerCtx {
    /// Leaving clients must deregister from every shard, or lockstep
    /// barriers would wait for them forever (bounded only by the safety
    /// cap).
    fn drop(&mut self) {
        self.deregister();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn routing_sends_each_layer_to_its_owner() {
        let assign = LayerAssignment::contiguous(4, 2);
        let (tx0, rx0) = channel();
        let (tx1, rx1) = channel();
        let table = RoutingTable::new(assign, vec![
            ShardRoute::new(tx0, LinkKind::SharedLocal),
            ShardRoute::new(tx1, LinkKind::NvLink),
        ]);
        let ctx = VirtLayerCtx::new(7, table);
        ctx.register();
        // one Register at each shard
        assert!(matches!(rx0.try_recv().unwrap(),
                         ExecMsg::Register { client_id: 7 }));
        assert!(matches!(rx1.try_recv().unwrap(),
                         ExecMsg::Register { client_id: 7 }));
        // a block-0 request lands on shard 0, a block-3 one on shard 1
        for (layer, want0) in [(LayerId::Qkv(0), true),
                               (LayerId::Embed, true),
                               (LayerId::MlpUp(3), false),
                               (LayerId::LmHead, false)] {
            let route = ctx_route(&ctx, layer);
            assert_eq!(route, if want0 { 0 } else { 1 },
                       "layer {layer:?} routed to shard {route}");
        }
        drop(ctx); // deregisters everywhere
        assert!(matches!(rx0.try_recv().unwrap(),
                         ExecMsg::Deregister { client_id: 7 }));
        assert!(matches!(rx1.try_recv().unwrap(),
                         ExecMsg::Deregister { client_id: 7 }));
    }

    /// Which shard index a layer routes to (test helper: compares the
    /// route's channel against the table's endpoints by identity).
    fn ctx_route(ctx: &VirtLayerCtx, layer: LayerId) -> usize {
        let target = ctx.routing.route(layer) as *const ShardRoute;
        ctx.routing
            .routes()
            .iter()
            .position(|r| std::ptr::eq(r, target))
            .unwrap()
    }

    #[test]
    fn single_table_routes_everything_to_shard_zero() {
        let (tx, _rx) = channel();
        let t = RoutingTable::single(tx, LinkKind::SharedLocal);
        assert_eq!(t.n_shards(), 1);
        for layer in [LayerId::Embed, LayerId::Qkv(3), LayerId::LmHead] {
            // must not panic: every layer resolves to the one route
            let _ = t.route(layer);
        }
    }
}
