//! `VirtLayer` — the client-side proxy for a base-model layer, routed
//! over the shard fleet.
//!
//! The paper replaces every frozen layer in the client's model definition
//! with a `torch.nn.Module` whose forward/backward ship activations to
//! the base executor (section 3.2, Fig. 4).  Here the proxy is a handle
//! that packages the request, looks the layer up in its [`RoutingTable`]
//! (section 3.3: the base may be sharded over several executors),
//! charges that shard's [`Link`], applies the privacy protocol when
//! configured, and collects the response — keeping the *client* the
//! driver of its own execution.
//!
//! # Split-phase dispatch
//!
//! Every base-layer invocation has two halves:
//!
//! * [`VirtLayerCtx::dispatch`] sends the request and returns a
//!   [`PendingLayer`] **without blocking**.  The *request* link is
//!   charged here — the payload crosses to the shard the moment the
//!   message is sent, whether or not the client waits.
//! * [`PendingLayer::collect`] blocks on the response, accumulates the
//!   executor queue-wait, surfaces a shard failure as
//!   [`SymbiosisError::ExecutorFailed`], and charges the *response*
//!   link for the returned payload.
//!
//! The blocking convenience calls ([`VirtLayerCtx::forward`] /
//! [`VirtLayerCtx::backward`] / [`VirtLayerCtx::embed`]) are exactly
//! `dispatch(..)?.collect()`, so the sequential path is unchanged.  The
//! split-phase half is what lets the pipelined prefill walker keep one
//! in-flight request per micro-batch: micro-batch k's request occupies
//! shard s+1 while micro-batch k+1's occupies shard s.
//!
//! # Deadlines and bounded retry
//!
//! `collect` honors the context's `request_timeout`: a shard that does
//! not answer within the budget surfaces a typed
//! [`SymbiosisError::DeadlineExceeded`] instead of blocking forever
//! ([`PendingLayer::collect_deadline`] is the per-call form).  Because
//! frozen-base layer ops are *pure* — same activations in, same output
//! out, no executor-side state — a failed or timed-out request is safe
//! to re-send verbatim.  When the context's [`RetryPolicy`] allows it,
//! `collect` re-dispatches the retained request against the shard's
//! *current* endpoint (which a fleet respawn may have swapped under a
//! bumped epoch — see [`ShardEndpoint`]) under linear backoff, and
//! surfaces [`SymbiosisError::ShardUnavailable`] only when the budget
//! is exhausted.  Both the sequential walk and the pipelined wavefront
//! go through `collect`, so they inherit deadlines and retry for free.
//!
//! # Overload: bounded ingress and circuit breakers
//!
//! Every shard endpoint carries an [`IngressMeter`] — a queue-depth
//! counter incremented when a request is dispatched and decremented
//! when the shard executor dequeues it — with a configurable
//! high-water mark.  A dispatch that would exceed the mark fails fast
//! with a typed [`SymbiosisError::ShardSaturated`] instead of growing
//! the queue without bound; the default mark is 0 (unbounded), the
//! pre-overload behavior.  The endpoint also carries a
//! [`CircuitBreaker`]: after a configurable number of *consecutive*
//! failures (`ExecutorFailed`/`DeadlineExceeded`) the breaker opens
//! and dispatches fast-fail as
//! [`SymbiosisError::ShardUnavailable`]` { retries: 0 }` without
//! burning retry sleeps — so a fleet of retrying clients cannot
//! dogpile a shard that is dead or mid-respawn.  The fleet watchdog
//! re-arms an open breaker to half-open each tick; one probe dispatch
//! is admitted, and its success closes the breaker (failure reopens
//! it).  Per-tenant quotas (in-flight requests) are checked here too
//! when the context carries a tenant — see
//! [`crate::coordinator::admission`].  An executor-shed background
//! request surfaces as [`SymbiosisError::WorkShed`] and is *not*
//! retried: re-sending shed work into the same saturated queue is the
//! dogpile the shedder exists to prevent.
//!
//! Ordering guarantees: requests dispatched over one context to the
//! *same* shard arrive in dispatch order (the channel is FIFO); requests
//! to different shards are unordered relative to each other.  Dropping a
//! `PendingLayer` without collecting is safe — the shard's response to a
//! closed receiver is discarded, nothing blocks.  A *retried* request
//! may race its original (e.g. a delayed response arriving after the
//! deadline fired): the original's receiver was replaced, so the stale
//! answer is discarded the same way.
//!
//! With Arc-backed tensors the request/response payloads are shared
//! views: shipping `x` to the executor (and receiving the scattered
//! output slice back) moves no activation bytes in-process.  Each shard
//! route still charges the *modeled* transfer for the placement being
//! simulated — a co-located shard costs `SharedLocal`, a cross-shard hop
//! `NvLink` — so accounting matches the topology while real host copies
//! stay zero.  The wait/link accumulators are bit-cast `AtomicU64`s, not
//! mutexes: with pipelined prefill they are touched once per layer per
//! micro-batch, and an uncontended atomic add stays off the lock path.
//!
//! Contexts are built by [`Deployment::build_core`] (one per client id);
//! sessions configure the links, realized delays, timeouts/retry, and
//! the privacy protocol through the
//! [`SessionBuilder`](crate::coordinator::SessionBuilder) rather than
//! mutating this struct after the fact.
//!
//! [`Deployment::build_core`]: crate::coordinator::Deployment

// Fault-domain hot path: a stray unwrap here can abort a co-tenant
// process or wedge a client on a poisoned lock.  Locks recover from
// poisoning explicitly; everything else is typed.
#![deny(clippy::unwrap_used)]

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicU8,
                        AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Duration;

use anyhow::Result;

use crate::coordinator::admission::{InFlightGuard, TenantState};
use crate::coordinator::fleet::FleetBarrier;
use crate::coordinator::privacy::PrivacyCtx;
use crate::coordinator::proto::{ExecMsg, LayerId, LayerRequest,
                                LayerResponse, OpKind, Urgency,
                                SHED_MARKER};
use crate::coordinator::sharding::LayerAssignment;
use crate::error::{SymResult, SymbiosisError};
use crate::tensor::Tensor;
use crate::transport::{Link, LinkKind};

/// Deterministic 64-bit mixer (splitmix64 finalizer) — the same family
/// the fault plans use, so jitter and chaos streams stay seed-pinnable.
fn splitmix64(seed: u64) -> u64 {
    let mut z = seed.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Queue-depth accounting for one shard's ingress, shared between the
/// dispatch side (increment on every request send) and the shard
/// executor (decrement on every request dequeue).  The high-water mark
/// bounds the queue: a dispatch that would exceed it is refused with a
/// typed [`SymbiosisError::ShardSaturated`] — backpressure instead of
/// unbounded growth.  Mark 0 (the default) means unbounded, the
/// pre-overload behavior.  Control messages (register, privacy, crash)
/// never pass through the meter.
pub struct IngressMeter {
    depth: AtomicUsize,
    high_water: AtomicUsize,
}

impl Default for IngressMeter {
    fn default() -> Self {
        IngressMeter::unbounded()
    }
}

impl IngressMeter {
    /// No high-water mark: every dispatch is admitted.
    pub fn unbounded() -> Self {
        IngressMeter {
            depth: AtomicUsize::new(0),
            high_water: AtomicUsize::new(0),
        }
    }

    /// Bounded at `mark` queued requests.
    pub fn with_high_water(mark: usize) -> Self {
        let m = IngressMeter::unbounded();
        m.set_high_water(mark);
        m
    }

    /// Requests currently queued (sent, not yet dequeued).
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::SeqCst)
    }

    /// The configured high-water mark (0 = unbounded).
    pub fn high_water(&self) -> usize {
        self.high_water.load(Ordering::SeqCst)
    }

    /// Ingress pressure in `[0, 1]`: current depth over the high-water
    /// mark, clamped; 0.0 when unbounded (no mark means no pressure
    /// signal).  The continuous-batching scheduler reads this per
    /// iteration as occupancy feedback into slot selection.
    pub fn pressure(&self) -> f64 {
        let limit = self.high_water.load(Ordering::SeqCst);
        if limit == 0 {
            return 0.0;
        }
        let depth = self.depth.load(Ordering::SeqCst);
        (depth as f64 / limit as f64).min(1.0)
    }

    /// Set the high-water mark, live (0 disables the bound).
    pub fn set_high_water(&self, mark: usize) {
        self.high_water.store(mark, Ordering::SeqCst);
    }

    /// Reserve one queue slot; `Err((depth, limit))` when the queue is
    /// at its mark (the reservation is rolled back — a refused dispatch
    /// leaves no trace).
    pub fn try_admit(&self) -> Result<(), (usize, usize)> {
        let depth = self.depth.fetch_add(1, Ordering::SeqCst) + 1;
        let limit = self.high_water.load(Ordering::SeqCst);
        if limit != 0 && depth > limit {
            self.depth.fetch_sub(1, Ordering::SeqCst);
            return Err((depth, limit));
        }
        Ok(())
    }

    /// Occupy one slot unconditionally — fault injection's flood action
    /// inflates the queue past its mark on purpose.
    pub fn force_admit(&self) {
        self.depth.fetch_add(1, Ordering::SeqCst);
    }

    /// Release one slot (executor dequeued a request, or a send
    /// failed after admission).  Saturating: a respawn reset racing
    /// in-flight decrements must not underflow.
    pub fn exit(&self) {
        let _ = self.depth.fetch_update(Ordering::SeqCst,
                                        Ordering::SeqCst, |d| {
            Some(d.saturating_sub(1))
        });
    }

    /// Whether the queue currently stands at (or past) its mark — the
    /// executor's shed trigger.
    pub fn saturated(&self) -> bool {
        let limit = self.high_water.load(Ordering::SeqCst);
        limit != 0 && self.depth.load(Ordering::SeqCst) >= limit
    }

    /// Zero the depth (shard respawn: the dead executor's queue died
    /// with it).
    pub fn reset(&self) {
        self.depth.store(0, Ordering::SeqCst);
    }
}

const BREAKER_CLOSED: u8 = 0;
const BREAKER_OPEN: u8 = 1;
const BREAKER_HALF_OPEN: u8 = 2;

/// Observable state of a [`CircuitBreaker`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerState {
    /// Healthy: every dispatch admitted.
    Closed,
    /// Tripped: dispatches fast-fail without touching the shard.
    Open,
    /// Probing: exactly one dispatch admitted per watchdog re-arm;
    /// its success closes the breaker, its failure reopens it.
    HalfOpen,
}

/// Per-shard circuit breaker: opens after `threshold` *consecutive*
/// request failures (`ExecutorFailed`/`DeadlineExceeded`), fast-failing
/// subsequent dispatches as `ShardUnavailable { retries: 0 }` so a
/// retry storm cannot dogpile a dead or respawning shard.  The fleet
/// watchdog re-arms an open breaker to half-open on its heartbeat
/// ([`Self::probe`]); the first successful call closes it.  Threshold 0
/// (the default) disables the breaker entirely — the pre-overload
/// behavior.
pub struct CircuitBreaker {
    state: AtomicU8,
    consecutive_failures: AtomicU32,
    threshold: AtomicU32,
    probe_inflight: AtomicBool,
    transitions: AtomicU64,
}

impl Default for CircuitBreaker {
    fn default() -> Self {
        CircuitBreaker::disabled()
    }
}

impl CircuitBreaker {
    /// Threshold 0: never trips, always admits.
    pub fn disabled() -> Self {
        CircuitBreaker {
            state: AtomicU8::new(BREAKER_CLOSED),
            consecutive_failures: AtomicU32::new(0),
            threshold: AtomicU32::new(0),
            probe_inflight: AtomicBool::new(false),
            transitions: AtomicU64::new(0),
        }
    }

    /// Trip after `threshold` consecutive failures.
    pub fn with_threshold(threshold: u32) -> Self {
        let b = CircuitBreaker::disabled();
        b.set_threshold(threshold);
        b
    }

    /// Configure the trip threshold, live (0 disables).
    pub fn set_threshold(&self, threshold: u32) {
        self.threshold.store(threshold, Ordering::SeqCst);
    }

    pub fn state(&self) -> BreakerState {
        match self.state.load(Ordering::SeqCst) {
            BREAKER_OPEN => BreakerState::Open,
            BREAKER_HALF_OPEN => BreakerState::HalfOpen,
            _ => BreakerState::Closed,
        }
    }

    /// Lifetime state-transition count (for the overload bench).
    pub fn transitions(&self) -> u64 {
        self.transitions.load(Ordering::SeqCst)
    }

    /// Whether a dispatch may proceed.  Closed: yes.  Open: no.
    /// Half-open: exactly one caller wins the probe slot per re-arm.
    pub fn allow(&self) -> bool {
        if self.threshold.load(Ordering::SeqCst) == 0 {
            return true;
        }
        match self.state.load(Ordering::SeqCst) {
            BREAKER_OPEN => false,
            BREAKER_HALF_OPEN => self
                .probe_inflight
                .compare_exchange(false, true, Ordering::SeqCst,
                                  Ordering::SeqCst)
                .is_ok(),
            _ => true,
        }
    }

    /// A request against this shard succeeded: reset the failure run
    /// and close the breaker from any state.
    pub fn record_success(&self) {
        self.consecutive_failures.store(0, Ordering::SeqCst);
        self.probe_inflight.store(false, Ordering::SeqCst);
        let prev = self.state.swap(BREAKER_CLOSED, Ordering::SeqCst);
        if prev != BREAKER_CLOSED {
            self.transitions.fetch_add(1, Ordering::SeqCst);
        }
    }

    /// A request against this shard failed.  Half-open: the probe
    /// failed, reopen.  Closed: trip once the consecutive run reaches
    /// the threshold.
    pub fn record_failure(&self) {
        let threshold = self.threshold.load(Ordering::SeqCst);
        if threshold == 0 {
            return;
        }
        let run = self
            .consecutive_failures
            .fetch_add(1, Ordering::SeqCst)
            .saturating_add(1);
        match self.state.load(Ordering::SeqCst) {
            BREAKER_HALF_OPEN => {
                self.probe_inflight.store(false, Ordering::SeqCst);
                if self
                    .state
                    .compare_exchange(BREAKER_HALF_OPEN, BREAKER_OPEN,
                                      Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    self.transitions.fetch_add(1, Ordering::SeqCst);
                }
            }
            BREAKER_CLOSED if run >= threshold => {
                if self
                    .state
                    .compare_exchange(BREAKER_CLOSED, BREAKER_OPEN,
                                      Ordering::SeqCst, Ordering::SeqCst)
                    .is_ok()
                {
                    self.transitions.fetch_add(1, Ordering::SeqCst);
                }
            }
            _ => {}
        }
    }

    /// Watchdog re-arm: an open breaker goes half-open (one probe may
    /// pass); a half-open breaker gets its probe slot back, bounding a
    /// lost probe (dropped `PendingLayer`) to one heartbeat.
    pub fn probe(&self) {
        if self
            .state
            .compare_exchange(BREAKER_OPEN, BREAKER_HALF_OPEN,
                              Ordering::SeqCst, Ordering::SeqCst)
            .is_ok()
        {
            self.probe_inflight.store(false, Ordering::SeqCst);
            self.transitions.fetch_add(1, Ordering::SeqCst);
        } else if self.state.load(Ordering::SeqCst) == BREAKER_HALF_OPEN {
            self.probe_inflight.store(false, Ordering::SeqCst);
        }
    }

    /// Shard respawned on a fresh executor: close and forget the run.
    pub fn reset(&self) {
        self.consecutive_failures.store(0, Ordering::SeqCst);
        self.probe_inflight.store(false, Ordering::SeqCst);
        let prev = self.state.swap(BREAKER_CLOSED, Ordering::SeqCst);
        if prev != BREAKER_CLOSED {
            self.transitions.fetch_add(1, Ordering::SeqCst);
        }
    }
}

/// One shard's *current* executor channel, shared by the fleet and by
/// every client routing table.  When the fleet respawns a dead shard it
/// [`swap`](Self::swap)s in the new thread's sender and bumps the
/// epoch; clients resolve the sender *per message*, so in-flight
/// sessions migrate to the replacement executor without rebuilding
/// their tables — no one holds a dead channel.  The endpoint also
/// carries the shard's shared [`IngressMeter`] and [`CircuitBreaker`]:
/// a fault-plan interposer wrapping the endpoint shares both, so
/// overload accounting survives interposition.
pub struct ShardEndpoint {
    tx: RwLock<Sender<ExecMsg>>,
    epoch: AtomicU64,
    meter: Arc<IngressMeter>,
    breaker: Arc<CircuitBreaker>,
}

impl ShardEndpoint {
    pub fn new(tx: Sender<ExecMsg>) -> Self {
        ShardEndpoint::with_shared(tx,
                                   Arc::new(IngressMeter::unbounded()),
                                   Arc::new(CircuitBreaker::disabled()))
    }

    /// An endpoint over pre-existing overload state — how the fleet
    /// ties the endpoint to the executor's meter, and how a fault
    /// interposer's wrapped endpoint keeps the inner one's accounting.
    pub fn with_shared(tx: Sender<ExecMsg>, meter: Arc<IngressMeter>,
                       breaker: Arc<CircuitBreaker>) -> Self {
        ShardEndpoint {
            tx: RwLock::new(tx),
            epoch: AtomicU64::new(0),
            meter,
            breaker,
        }
    }

    /// The shard's ingress queue meter.
    pub fn meter(&self) -> &Arc<IngressMeter> {
        &self.meter
    }

    /// The shard's circuit breaker.
    pub fn breaker(&self) -> &Arc<CircuitBreaker> {
        &self.breaker
    }

    /// The current executor channel (clone of the live sender).  Poison
    /// on the lock is recovered — a panicking writer cannot wedge every
    /// client of the shard.
    pub fn sender(&self) -> Sender<ExecMsg> {
        self.tx
            .read()
            .unwrap_or_else(|p| p.into_inner())
            .clone()
    }

    /// Monotonic generation counter: bumped on every [`swap`](Self::swap).
    /// A client comparing epochs across a failure sees whether the fleet
    /// already replaced the executor it timed out on.
    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Acquire)
    }

    /// Install a replacement executor channel; returns the new epoch.
    pub fn swap(&self, tx: Sender<ExecMsg>) -> u64 {
        *self.tx.write().unwrap_or_else(|p| p.into_inner()) = tx;
        self.epoch.fetch_add(1, Ordering::AcqRel) + 1
    }
}

/// One shard's endpoint as a client sees it: the (respawn-transparent)
/// executor channel plus the simulated link the client's traffic to
/// that shard crosses.
pub struct ShardRoute {
    shard: usize,
    endpoint: Arc<ShardEndpoint>,
    pub link: Mutex<Link>,
}

impl ShardRoute {
    /// A private route over a fresh endpoint (tests, tools, the
    /// single-shard topology).
    pub fn new(tx: Sender<ExecMsg>, kind: LinkKind) -> Self {
        ShardRoute::shared(0, Arc::new(ShardEndpoint::new(tx)), kind)
    }

    /// A route over a fleet-shared endpoint: respawns swap the sender
    /// underneath every client holding this route.
    pub fn shared(shard: usize, endpoint: Arc<ShardEndpoint>,
                  kind: LinkKind) -> Self {
        ShardRoute { shard, endpoint, link: Mutex::new(Link::new(kind)) }
    }

    /// Index of the shard this route reaches.
    pub fn shard(&self) -> usize {
        self.shard
    }

    pub fn endpoint(&self) -> &Arc<ShardEndpoint> {
        &self.endpoint
    }

    /// Route epoch — how many times the fleet replaced this shard's
    /// executor since the route was built.
    pub fn epoch(&self) -> u64 {
        self.endpoint.epoch()
    }

    /// Send a control/request message to the shard's *current*
    /// executor.
    fn send(&self, msg: ExecMsg) -> Result<(), ExecMsg> {
        self.endpoint.sender().send(msg).map_err(|e| e.0)
    }
}

/// Client-side routing over the executor fleet: which shard owns each
/// layer, and over which link it is reached.
pub struct RoutingTable {
    assign: LayerAssignment,
    routes: Vec<ShardRoute>,
}

impl RoutingTable {
    /// Build a table; fails with a typed
    /// [`SymbiosisError::MalformedRoutingTable`] when the route count
    /// does not match the assignment's shard count (library code must
    /// not abort a co-tenant process on a malformed table).  Route
    /// shard indices are normalized to table order.
    pub fn new(assign: LayerAssignment, mut routes: Vec<ShardRoute>)
               -> SymResult<Self> {
        if assign.shards() != routes.len() {
            return Err(SymbiosisError::MalformedRoutingTable {
                shards: assign.shards(),
                routes: routes.len(),
            });
        }
        for (s, r) in routes.iter_mut().enumerate() {
            r.shard = s;
        }
        Ok(RoutingTable { assign, routes })
    }

    /// Single-shard table — the pre-fleet topology (tests, tools).
    pub fn single(tx: Sender<ExecMsg>, kind: LinkKind) -> Self {
        RoutingTable {
            assign: LayerAssignment::contiguous(1, 1),
            routes: vec![ShardRoute::new(tx, kind)],
        }
    }

    pub fn n_shards(&self) -> usize {
        self.routes.len()
    }

    /// The route serving `layer`.
    pub fn route(&self, layer: LayerId) -> &ShardRoute {
        &self.routes[self.assign.shard_of(layer)]
    }

    pub fn routes(&self) -> &[ShardRoute] {
        &self.routes
    }
}

/// Add a delta to an `f64` stored bit-cast in an `AtomicU64`.
/// Uncontended CAS loop — the counters are per client, so contention
/// only occurs if one session is driven from several threads.
fn atomic_f64_add(cell: &AtomicU64, delta: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + delta).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed,
                                         Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn atomic_f64_get(cell: &AtomicU64) -> f64 {
    f64::from_bits(cell.load(Ordering::Relaxed))
}

/// Bounded-retry budget for failed or timed-out layer requests.
/// Frozen-base ops are pure, so a retry re-sends the retained request
/// verbatim; backoff is linear (`backoff * attempt`) to give a fleet
/// watchdog time to respawn the shard between attempts.  The default
/// is *no* retry — existing callers keep fail-fast semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Re-dispatch attempts after the first failure (0 = fail fast).
    pub max_retries: u32,
    /// Base backoff before attempt k sleeps `backoff * k`.
    pub backoff: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 0, backoff: Duration::from_millis(25) }
    }
}

impl RetryPolicy {
    /// Fail-fast (the default).
    pub fn none() -> Self {
        RetryPolicy::default()
    }

    /// Retry up to `max_retries` times with the default backoff base.
    pub fn retries(max_retries: u32) -> Self {
        RetryPolicy { max_retries, ..RetryPolicy::default() }
    }

    pub fn with_backoff(mut self, backoff: Duration) -> Self {
        self.backoff = backoff;
        self
    }

    /// Backoff before retry attempt `attempt` (1-based) for a given
    /// client: linear base (`backoff * attempt`) scaled by a
    /// *deterministic* per-(client, attempt) jitter factor in
    /// [0.5, 1.5).  Jitter de-synchronizes clients retrying against the
    /// same respawning shard (no thundering herd on the watchdog's
    /// heartbeat), while splitmix64 over the salt keeps chaos runs
    /// seed-pinnable — the same client makes the same sleeps every run,
    /// and send counts never change.
    pub fn backoff_for(&self, attempt: u32, client_salt: u64) -> Duration {
        let h = splitmix64(
            client_salt
                .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                .wrapping_add(attempt as u64),
        );
        // 53 high-quality bits -> uniform in [0, 1), shifted to
        // [0.5, 1.5) so jitter never more than halves or doubles the
        // linear schedule.
        let unit = (h >> 11) as f64 / (1u64 << 53) as f64;
        self.backoff.mul_f64(attempt as f64 * (0.5 + unit))
    }
}

/// Per-client view of the executor fleet: layer proxies share this
/// context.
pub struct VirtLayerCtx {
    pub client_id: usize,
    routing: RoutingTable,
    /// Optional activation-privacy protocol state.
    pub privacy: Option<PrivacyCtx>,
    /// When set, simulated link delays are *realized* as actual sleeps,
    /// so remote/network placements behave (not just account) slower —
    /// used by the placement benches (Figs 7/13/21).
    pub realize_delays: bool,
    /// The fleet-global lockstep registration count, updated
    /// *synchronously* in [`Self::register`]/[`Self::deregister`]
    /// (before/alongside the per-shard messages) so
    /// `BatchPolicy::LockstepFleet` barriers never read a count that
    /// lags a client whose requests are already in flight.  `None` for
    /// hand-built contexts (tests, tools).
    pub fleet_barrier: Option<std::sync::Arc<FleetBarrier>>,
    /// Per-request response deadline applied by every `collect` on this
    /// context (`None` = block forever, the pre-fault-domain behavior).
    pub request_timeout: Option<Duration>,
    /// Bounded-retry budget applied by every `collect` on this context.
    pub retry: RetryPolicy,
    /// Admission-control identity: when set, every dispatch reserves an
    /// in-flight slot against this tenant's quota (released when the
    /// `PendingLayer` resolves or drops) and the session's KV ledger
    /// charges the tenant's byte quota.  `None` — an unnamed client —
    /// bypasses admission entirely, the pre-overload behavior.
    pub tenant: Option<Arc<TenantState>>,
    /// Accumulated queue-wait observed by this client (Fig 7);
    /// f64 seconds bit-cast into the atomic.
    wait_secs: AtomicU64,
    /// Accumulated simulated link time (all shard links); f64 bit-cast.
    link_secs: AtomicU64,
}

/// An in-flight base-layer invocation: the response receiver plus what
/// is needed to finish the accounting at collect time — and to
/// *re-dispatch* the request on failure (the payload is an `Arc` view,
/// so retaining it is a refcount, not a copy).  Obtained from
/// [`VirtLayerCtx::dispatch`] (or the privacy-aware
/// [`VirtLayerCtx::dispatch_forward`]); the request link was already
/// charged at dispatch.  Dropping without collecting discards the
/// response harmlessly.
pub struct PendingLayer<'a> {
    ctx: &'a VirtLayerCtx,
    route: &'a ShardRoute,
    layer: LayerId,
    rx: Receiver<LayerResponse>,
    /// Privacy: the noise effect to subtract from the response
    /// (`n_eff = W . n`), when this dispatch shipped noised activations.
    n_eff: Option<Tensor>,
    /// Retained request, as sent (noised when privacy is on), for
    /// retry re-dispatch.
    op: OpKind,
    x: Tensor,
    positions: Option<Tensor>,
    urgency: Urgency,
    /// Tenant in-flight reservation (RAII): released when the pending
    /// layer resolves or drops, so a leaked response cannot leak quota.
    _admitted: Option<InFlightGuard>,
}

impl PendingLayer<'_> {
    /// The layer this invocation targets.
    pub fn layer(&self) -> LayerId {
        self.layer
    }

    /// Block on the shard's response under the context's
    /// `request_timeout` and `retry` policy.  Accumulates the executor
    /// queue-wait, charges the *response* link for the returned payload,
    /// surfaces a failed flush as [`SymbiosisError::ExecutorFailed`] (a
    /// missed deadline as [`SymbiosisError::DeadlineExceeded`], an
    /// exhausted retry budget as
    /// [`SymbiosisError::ShardUnavailable`]), and removes the privacy
    /// noise effect when one was registered at dispatch.
    pub fn collect(self) -> Result<Tensor> {
        let deadline = self.ctx.request_timeout;
        self.collect_inner(deadline)
    }

    /// `collect` with an explicit per-call deadline, overriding the
    /// context's `request_timeout`.
    pub fn collect_deadline(self, deadline: Duration) -> Result<Tensor> {
        self.collect_inner(Some(deadline))
    }

    fn collect_inner(mut self, deadline: Option<Duration>)
                     -> Result<Tensor> {
        let retry = self.ctx.retry;
        let breaker = self.route.endpoint().breaker().clone();
        let mut attempt: u32 = 0;
        loop {
            match self.wait_once(deadline) {
                Ok(y) => {
                    breaker.record_success();
                    self.ctx.charge(self.route, &y);
                    return Ok(match &self.n_eff {
                        Some(n) => crate::tensor::ops::sub(&y, n),
                        None => y,
                    });
                }
                Err(e) => {
                    // Shed work is *deferred*, not failed: it never
                    // burns retry budget and never counts against the
                    // breaker — the shard is healthy, just saturated.
                    if matches!(e.downcast_ref::<SymbiosisError>(),
                                Some(SymbiosisError::WorkShed { .. })) {
                        return Err(e);
                    }
                    breaker.record_failure();
                    if attempt >= retry.max_retries {
                        if retry.max_retries > 0 {
                            // The budget is spent: surface the
                            // triage-level error, keeping the last
                            // fault in the chain.
                            return Err(e.context(
                                SymbiosisError::ShardUnavailable {
                                    shard: self.route.shard(),
                                    retries: retry.max_retries,
                                },
                            ));
                        }
                        return Err(e);
                    }
                    if !breaker.allow() {
                        // Breaker tripped mid-budget: fast-fail instead
                        // of sleeping through backoffs a dead shard
                        // will never answer.  `retries: attempt` says
                        // how much budget was actually burned.
                        return Err(e.context(
                            SymbiosisError::ShardUnavailable {
                                shard: self.route.shard(),
                                retries: attempt,
                            },
                        ));
                    }
                    attempt += 1;
                    // Linear backoff with deterministic per-client
                    // jitter: give the watchdog time to respawn the
                    // shard before the request goes out again, without
                    // every client's retry landing on the same tick.
                    std::thread::sleep(retry.backoff_for(
                        attempt,
                        self.ctx.client_id as u64,
                    ));
                    self.redispatch();
                    let _ = e; // superseded by the retry's outcome
                }
            }
        }
    }

    /// One wait for the current in-flight request: deadline, channel
    /// loss, and executor-reported failure each map to their typed
    /// error.
    fn wait_once(&self, deadline: Option<Duration>) -> Result<Tensor> {
        let gone = || {
            anyhow::Error::new(SymbiosisError::ExecutorFailed {
                layer: self.layer.label(),
                message: "shard dropped the request (crashed or shut \
                          down)"
                    .into(),
            })
        };
        let resp = match deadline {
            None => self.rx.recv().map_err(|_| gone())?,
            Some(d) => match self.rx.recv_timeout(d) {
                Ok(r) => r,
                Err(RecvTimeoutError::Timeout) => {
                    return Err(anyhow::Error::new(
                        SymbiosisError::DeadlineExceeded {
                            layer: self.layer.label(),
                            shard: self.route.shard(),
                            waited: d,
                        },
                    ));
                }
                Err(RecvTimeoutError::Disconnected) => {
                    return Err(gone());
                }
            },
        };
        atomic_f64_add(&self.ctx.wait_secs, resp.queue_wait_secs);
        resp.y.map_err(|message| {
            if message.starts_with(SHED_MARKER) {
                // The executor's load shedder answered instead of the
                // device: background work deferred under saturation.
                return anyhow::Error::new(SymbiosisError::WorkShed {
                    layer: self.layer.label(),
                    shard: self.route.shard(),
                });
            }
            anyhow::Error::new(SymbiosisError::ExecutorFailed {
                layer: self.layer.label(),
                message,
            })
        })
    }

    /// Re-send the retained request against the shard's *current*
    /// endpoint (a respawn may have swapped it) with a fresh response
    /// channel.  A failed send leaves a disconnected receiver behind,
    /// which the next `wait_once` surfaces as a failed attempt — so a
    /// still-dead shard burns budget instead of looping.
    fn redispatch(&mut self) {
        let meter = self.route.endpoint().meter().clone();
        if meter.try_admit().is_err() {
            // The replacement shard is already saturated: leave a
            // disconnected receiver behind so the next `wait_once`
            // burns a retry attempt instead of blocking on a request
            // that was never queued.
            let (_tx, rx) = channel::<LayerResponse>();
            self.rx = rx;
            return;
        }
        self.ctx.charge(self.route, &self.x);
        let (tx, rx) = channel::<LayerResponse>();
        if self
            .route
            .send(ExecMsg::Request(LayerRequest {
                client_id: self.ctx.client_id,
                layer: self.layer,
                op: self.op,
                x: self.x.clone(),
                positions: self.positions.clone(),
                urgency: self.urgency,
                resp: tx,
            }))
            .is_err()
        {
            // Never queued: release the reserved ingress slot.
            meter.exit();
        }
        self.rx = rx;
    }
}

impl VirtLayerCtx {
    pub fn new(client_id: usize, routing: RoutingTable) -> Self {
        VirtLayerCtx {
            client_id,
            routing,
            privacy: None,
            realize_delays: false,
            fleet_barrier: None,
            request_timeout: None,
            retry: RetryPolicy::default(),
            tenant: None,
            wait_secs: AtomicU64::new(0.0f64.to_bits()),
            link_secs: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Register with every shard (lockstep policies count clients at
    /// each shard independently).  The fleet-global barrier is bumped
    /// synchronously *first*, so no shard can observe this client's
    /// requests while the global count still excludes it.
    pub fn register(&self) {
        if let Some(b) = &self.fleet_barrier {
            b.register();
        }
        for r in self.routing.routes() {
            let _ = r.send(ExecMsg::Register {
                client_id: self.client_id,
            });
        }
    }

    pub fn deregister(&self) {
        // Drop the global count first too: a departing client must not
        // hold fleet-wide barriers for the message-drain latency.
        if let Some(b) = &self.fleet_barrier {
            b.deregister();
        }
        for r in self.routing.routes() {
            let _ = r.send(ExecMsg::Deregister {
                client_id: self.client_id,
            });
        }
    }

    /// Invoke the forward pass of a base linear layer with activations
    /// `x: (T, Din)`.  Blocking: `dispatch_forward(..)?.collect()`.
    pub fn forward(&self, layer: LayerId, x: Tensor, urgency: Urgency)
                   -> Result<Tensor> {
        self.dispatch_forward(layer, x, urgency)?.collect()
    }

    /// Invoke the memory-optimized backward: returns `dX = dY . W^T`.
    pub fn backward(&self, layer: LayerId, dy: Tensor, urgency: Urgency)
                    -> Result<Tensor> {
        self.dispatch_backward(layer, dy, urgency)?.collect()
    }

    /// Non-blocking backward dispatch — the split-phase leg the
    /// pipelined trainer drains micro-batches through.  No privacy
    /// branch: the privacy protocol covers forward activations only
    /// (trainers never configure a [`PrivacyCtx`]), and backward
    /// payloads are gradients of the client's own loss.
    pub fn dispatch_backward(&self, layer: LayerId, dy: Tensor,
                             urgency: Urgency)
                             -> Result<PendingLayer<'_>> {
        self.dispatch(layer, OpKind::Backward, dy, None, urgency)
    }

    /// Embedding lookup: token ids + positions (both (T,) i32).
    pub fn embed(&self, tokens: Tensor, positions: Tensor,
                 urgency: Urgency) -> Result<Tensor> {
        self.dispatch_embed(tokens, positions, urgency)?.collect()
    }

    /// Non-blocking forward dispatch with the privacy protocol applied:
    /// when a [`PrivacyCtx`] is configured the shard receives `x + n`
    /// and the returned [`PendingLayer`] subtracts `n_eff = W . n` at
    /// collect, so pipelined walks stay private too.  A retry re-sends
    /// the *same* noised payload — the executor still never sees raw
    /// activations, and `n_eff` stays valid because the respawned shard
    /// holds the same frozen weights.
    pub fn dispatch_forward(&self, layer: LayerId, x: Tensor,
                            urgency: Urgency)
                            -> Result<PendingLayer<'_>> {
        if let Some(p) = &self.privacy {
            let (noised, n_eff) = p.apply(layer, &x)?;
            let mut pend = self.dispatch(layer, OpKind::Forward, noised,
                                         None, urgency)?;
            pend.n_eff = Some(n_eff);
            return Ok(pend);
        }
        self.dispatch(layer, OpKind::Forward, x, None, urgency)
    }

    /// Non-blocking embedding dispatch.
    pub fn dispatch_embed(&self, tokens: Tensor, positions: Tensor,
                          urgency: Urgency) -> Result<PendingLayer<'_>> {
        self.dispatch(LayerId::Embed, OpKind::Forward, tokens,
                      Some(positions), urgency)
    }

    /// Charge one payload to a shard's link, realizing the delay when
    /// configured.  Poison on the link lock is recovered: the counters
    /// stay valid (plain additions), so a panic mid-charge elsewhere
    /// must not wedge every later layer call of this client.
    fn charge(&self, route: &ShardRoute, t: &Tensor) {
        let dt = route
            .link
            .lock()
            .unwrap_or_else(|p| p.into_inner())
            .send(t);
        atomic_f64_add(&self.link_secs, dt);
        if self.realize_delays && dt > 20e-6 {
            std::thread::sleep(std::time::Duration::from_secs_f64(dt));
        }
    }

    /// Send one base-layer invocation without waiting for the response.
    /// The *request* link is charged here (the payload crosses now);
    /// everything the response owes — queue wait, response link,
    /// failure surfacing, deadline/retry handling — happens in
    /// [`PendingLayer::collect`].
    /// Overload gates run *before* the payload is charged or sent, in
    /// fast-fail order: open breaker (`ShardUnavailable { retries: 0 }`),
    /// tenant in-flight quota (`QuotaExceeded`), then the shard's
    /// bounded ingress queue (`ShardSaturated`).  All three are typed
    /// and leave no partial state behind.
    pub fn dispatch(&self, layer: LayerId, op: OpKind, x: Tensor,
                    positions: Option<Tensor>, urgency: Urgency)
                    -> Result<PendingLayer<'_>> {
        let route = self.routing.route(layer);
        if !route.endpoint().breaker().allow() {
            return Err(anyhow::Error::new(
                SymbiosisError::ShardUnavailable {
                    shard: route.shard(),
                    retries: 0,
                },
            ));
        }
        let admitted = self
            .tenant
            .as_ref()
            .map(|t| t.begin_request())
            .transpose()?;
        let meter = route.endpoint().meter().clone();
        meter.try_admit().map_err(|(depth, limit)| {
            SymbiosisError::ShardSaturated {
                shard: route.shard(),
                depth,
                limit,
            }
        })?;
        self.charge(route, &x);
        let (tx, rx) = channel::<LayerResponse>();
        route
            .send(ExecMsg::Request(LayerRequest {
                client_id: self.client_id,
                layer,
                op,
                x: x.clone(),
                positions: positions.clone(),
                urgency,
                resp: tx,
            }))
            .map_err(|_| {
                // Never queued: the reserved ingress slot comes back.
                meter.exit();
                SymbiosisError::ExecutorFailed {
                    layer: layer.label(),
                    message: "shard executor is gone (fleet shut down \
                              or crashed before dispatch)"
                        .into(),
                }
            })?;
        Ok(PendingLayer {
            ctx: self,
            route,
            layer,
            rx,
            n_eff: None,
            op,
            x,
            positions,
            urgency,
            _admitted: admitted,
        })
    }

    /// Total simulated link time charged so far (all shards).
    pub fn link_time(&self) -> f64 {
        atomic_f64_get(&self.link_secs)
    }

    /// Per-shard link traffic: `(messages, bytes_moved)` in shard
    /// order — shows where the routed topology sends this client's
    /// activations.
    pub fn link_traffic(&self) -> Vec<(u64, u64)> {
        self.routing
            .routes()
            .iter()
            .map(|r| {
                let l = r.link.lock().unwrap_or_else(|p| p.into_inner());
                (l.messages, l.bytes_moved)
            })
            .collect()
    }

    /// Per-shard route epochs (respawn generations observed by this
    /// client's table).
    pub fn route_epochs(&self) -> Vec<u64> {
        self.routing.routes().iter().map(|r| r.epoch()).collect()
    }

    /// Total executor queue wait observed so far.
    pub fn queue_wait(&self) -> f64 {
        atomic_f64_get(&self.wait_secs)
    }
}

impl Drop for VirtLayerCtx {
    /// Leaving clients must deregister from every shard, or lockstep
    /// barriers would wait for them forever (bounded only by the safety
    /// cap).
    fn drop(&mut self) {
        self.deregister();
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn routing_sends_each_layer_to_its_owner() {
        let assign = LayerAssignment::contiguous(4, 2);
        let (tx0, rx0) = channel();
        let (tx1, rx1) = channel();
        let table = RoutingTable::new(assign, vec![
            ShardRoute::new(tx0, LinkKind::SharedLocal),
            ShardRoute::new(tx1, LinkKind::NvLink),
        ])
        .unwrap();
        let ctx = VirtLayerCtx::new(7, table);
        ctx.register();
        // one Register at each shard
        assert!(matches!(rx0.try_recv().unwrap(),
                         ExecMsg::Register { client_id: 7 }));
        assert!(matches!(rx1.try_recv().unwrap(),
                         ExecMsg::Register { client_id: 7 }));
        // a block-0 request lands on shard 0, a block-3 one on shard 1
        for (layer, want) in [(LayerId::Qkv(0), 0usize),
                              (LayerId::Embed, 0),
                              (LayerId::MlpUp(3), 1),
                              (LayerId::LmHead, 1)] {
            assert_eq!(ctx.routing.route(layer).shard(), want,
                       "layer {layer:?} misrouted");
        }
        drop(ctx); // deregisters everywhere
        assert!(matches!(rx0.try_recv().unwrap(),
                         ExecMsg::Deregister { client_id: 7 }));
        assert!(matches!(rx1.try_recv().unwrap(),
                         ExecMsg::Deregister { client_id: 7 }));
    }

    #[test]
    fn malformed_table_is_a_typed_error_not_a_panic() {
        let (tx, _rx) = channel();
        let err = RoutingTable::new(
            LayerAssignment::contiguous(4, 2),
            vec![ShardRoute::new(tx, LinkKind::SharedLocal)],
        )
        .unwrap_err();
        match err {
            SymbiosisError::MalformedRoutingTable { shards, routes } => {
                assert_eq!(shards, 2);
                assert_eq!(routes, 1);
            }
            other => panic!("expected MalformedRoutingTable, got {other}"),
        }
    }

    #[test]
    fn single_table_routes_everything_to_shard_zero() {
        let (tx, _rx) = channel();
        let t = RoutingTable::single(tx, LinkKind::SharedLocal);
        assert_eq!(t.n_shards(), 1);
        for layer in [LayerId::Embed, LayerId::Qkv(3), LayerId::LmHead] {
            // must not panic: every layer resolves to the one route
            let _ = t.route(layer);
        }
    }

    #[test]
    fn atomic_f64_counters_accumulate() {
        let cell = AtomicU64::new(0.0f64.to_bits());
        atomic_f64_add(&cell, 1.5);
        atomic_f64_add(&cell, 0.25);
        assert_eq!(atomic_f64_get(&cell), 1.75);
    }

    #[test]
    fn dispatch_charges_request_and_collect_charges_response() {
        let (tx, rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::NvLink);
        let ctx = VirtLayerCtx::new(0, table);
        let x = Tensor::zeros(&[4, 8]);
        let pend = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward, x, None,
                      Urgency::Bulk)
            .unwrap();
        // the request payload crossed the link at dispatch time
        let (msgs, bytes) = ctx.link_traffic()[0];
        assert_eq!(msgs, 1);
        assert_eq!(bytes, 4 * 8 * 4);
        assert_eq!(pend.layer(), LayerId::Qkv(0));
        // fake shard: answer with a (4, 24) tensor and some queue wait
        let req = match rx.try_recv().unwrap() {
            ExecMsg::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        };
        req.resp
            .send(LayerResponse {
                y: Ok(Tensor::zeros(&[4, 24])),
                queue_wait_secs: 0.125,
                batch_clients: 1,
            })
            .unwrap();
        let y = pend.collect().unwrap();
        assert_eq!(y.shape, vec![4, 24]);
        assert_eq!(ctx.queue_wait(), 0.125);
        let (msgs, bytes) = ctx.link_traffic()[0];
        assert_eq!(msgs, 2, "collect must charge the response hop");
        assert_eq!(bytes, (4 * 8 + 4 * 24) * 4);
    }

    #[test]
    fn collect_surfaces_executor_failure_typed() {
        let (tx, rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let ctx = VirtLayerCtx::new(0, table);
        let pend = ctx
            .dispatch(LayerId::MlpUp(1), OpKind::Forward,
                      Tensor::zeros(&[2, 4]), None, Urgency::Bulk)
            .unwrap();
        let req = match rx.try_recv().unwrap() {
            ExecMsg::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        };
        req.resp
            .send(LayerResponse {
                y: Err("injected fault".into()),
                queue_wait_secs: 0.0,
                batch_clients: 1,
            })
            .unwrap();
        let err = pend.collect().unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::ExecutorFailed { layer, message } => {
                assert_eq!(layer, "l1.mlp_up");
                assert_eq!(message, "injected fault");
            }
            other => panic!("expected ExecutorFailed, got {other}"),
        }
    }

    #[test]
    fn collect_deadline_surfaces_a_hung_shard() {
        // A shard that never answers: the receiver end is parked.
        let (tx, _rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let ctx = VirtLayerCtx::new(3, table);
        let pend = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[2, 4]), None, Urgency::Bulk)
            .unwrap();
        let err = pend
            .collect_deadline(Duration::from_millis(10))
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::DeadlineExceeded { layer, shard, waited } => {
                assert_eq!(layer, "l0.qkv");
                assert_eq!(shard, 0);
                assert_eq!(waited, Duration::from_millis(10));
            }
            other => panic!("expected DeadlineExceeded, got {other}"),
        }
    }

    #[test]
    fn context_timeout_applies_to_plain_collect() {
        let (tx, _rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let mut ctx = VirtLayerCtx::new(0, table);
        ctx.request_timeout = Some(Duration::from_millis(10));
        let err = ctx
            .forward(LayerId::Qkv(0), Tensor::zeros(&[1, 4]),
                     Urgency::Bulk)
            .unwrap_err();
        assert!(matches!(SymbiosisError::from(err),
                         SymbiosisError::DeadlineExceeded { .. }));
    }

    /// Fake shard: answers the first `fail` requests with an error,
    /// then echoes a zeros tensor of the given shape.
    fn flaky_shard(rx: std::sync::mpsc::Receiver<ExecMsg>, fail: usize,
                   shape: Vec<usize>) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut failures = 0;
            while let Ok(msg) = rx.recv() {
                if let ExecMsg::Request(req) = msg {
                    let y = if failures < fail {
                        failures += 1;
                        Err("transient fault".into())
                    } else {
                        Ok(Tensor::zeros(&shape))
                    };
                    let _ = req.resp.send(LayerResponse {
                        y,
                        queue_wait_secs: 0.0,
                        batch_clients: 1,
                    });
                }
            }
        })
    }

    #[test]
    fn retry_recovers_from_a_transient_fault() {
        let (tx, rx) = channel();
        let _shard = flaky_shard(rx, 2, vec![2, 8]);
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let mut ctx = VirtLayerCtx::new(0, table);
        ctx.retry = RetryPolicy::retries(2)
            .with_backoff(Duration::from_millis(1));
        let y = ctx
            .forward(LayerId::Qkv(0), Tensor::zeros(&[2, 4]),
                     Urgency::Bulk)
            .unwrap();
        assert_eq!(y.shape, vec![2, 8]);
        // 3 attempts crossed the request link, 1 response came back
        let (msgs, _) = ctx.link_traffic()[0];
        assert_eq!(msgs, 4);
    }

    #[test]
    fn exhausted_retry_budget_is_shard_unavailable() {
        let (tx, rx) = channel();
        let _shard = flaky_shard(rx, usize::MAX, vec![2, 8]);
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let mut ctx = VirtLayerCtx::new(0, table);
        ctx.retry = RetryPolicy::retries(2)
            .with_backoff(Duration::from_millis(1));
        let err = ctx
            .forward(LayerId::Qkv(0), Tensor::zeros(&[2, 4]),
                     Urgency::Bulk)
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::ShardUnavailable { shard, retries } => {
                assert_eq!(shard, 0);
                assert_eq!(retries, 2);
            }
            other => panic!("expected ShardUnavailable, got {other}"),
        }
    }

    #[test]
    fn endpoint_swap_reroutes_the_retry() {
        // First executor is already gone (sender dropped); the swap
        // installs a live replacement, and the retry lands there.
        let (dead_tx, _) = channel::<ExecMsg>();
        let endpoint = Arc::new(ShardEndpoint::new(dead_tx));
        let table = RoutingTable {
            assign: LayerAssignment::contiguous(1, 1),
            routes: vec![ShardRoute::shared(0, endpoint.clone(),
                                            LinkKind::SharedLocal)],
        };
        let mut ctx = VirtLayerCtx::new(0, table);
        ctx.retry = RetryPolicy::retries(1)
            .with_backoff(Duration::from_millis(1));
        assert_eq!(endpoint.epoch(), 0);
        let (live_tx, live_rx) = channel();
        let _shard = flaky_shard(live_rx, 0, vec![1, 8]);
        assert_eq!(endpoint.swap(live_tx), 1);
        // dispatch resolves the *current* sender, so this succeeds even
        // though the route was built over the dead executor
        let y = ctx
            .forward(LayerId::Qkv(0), Tensor::zeros(&[1, 4]),
                     Urgency::Bulk)
            .unwrap();
        assert_eq!(y.shape, vec![1, 8]);
        assert_eq!(ctx.route_epochs(), vec![1]);
    }

    #[test]
    fn dead_endpoint_burns_budget_without_looping() {
        // Both the original executor and every retry target are gone:
        // the budget must exhaust promptly with ShardUnavailable.
        let (tx, rx) = channel::<ExecMsg>();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let mut ctx = VirtLayerCtx::new(0, table);
        ctx.retry = RetryPolicy::retries(2)
            .with_backoff(Duration::from_millis(1));
        let pend = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .unwrap();
        drop(rx); // the shard dies with the request queued
        let err = pend.collect().unwrap_err();
        assert!(matches!(SymbiosisError::from(err),
                         SymbiosisError::ShardUnavailable { .. }));
    }

    #[test]
    fn poisoned_link_lock_recovers() {
        let (tx, _rx) = channel();
        let route = ShardRoute::new(tx, LinkKind::NvLink);
        let route = Arc::new(route);
        let r2 = route.clone();
        // Poison the link mutex from a panicking thread.
        let _ = std::thread::spawn(move || {
            let _guard = r2.link.lock().unwrap();
            panic!("poison the link");
        })
        .join();
        assert!(route.link.lock().is_err(), "lock should be poisoned");
        // charge() and link_traffic() still work on the same table.
        let table = RoutingTable {
            assign: LayerAssignment::contiguous(1, 1),
            routes: vec![Arc::try_unwrap(route).ok().unwrap()],
        };
        let ctx = VirtLayerCtx::new(0, table);
        let _ = ctx.dispatch(LayerId::Qkv(0), OpKind::Forward,
                             Tensor::zeros(&[2, 4]), None, Urgency::Bulk);
        let (msgs, bytes) = ctx.link_traffic()[0];
        assert_eq!(msgs, 1);
        assert_eq!(bytes, 2 * 4 * 4);
    }

    #[test]
    fn dropping_a_pending_layer_is_harmless() {
        let (tx, rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let ctx = VirtLayerCtx::new(0, table);
        let pend = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .unwrap();
        drop(pend);
        // the shard's answer to a dropped receiver is simply discarded
        let req = match rx.try_recv().unwrap() {
            ExecMsg::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        };
        let send_result = req.resp.send(LayerResponse {
            y: Ok(Tensor::zeros(&[1, 4])),
            queue_wait_secs: 0.0,
            batch_clients: 1,
        });
        assert!(send_result.is_err(), "receiver should be gone");
    }

    #[test]
    fn ingress_meter_bounds_at_its_mark() {
        let m = IngressMeter::with_high_water(2);
        assert!(m.try_admit().is_ok());
        assert!(m.try_admit().is_ok());
        assert!(m.saturated());
        assert_eq!(m.try_admit().unwrap_err(), (3, 2));
        assert_eq!(m.depth(), 2, "refused admit must roll back");
        m.exit();
        assert!(!m.saturated());
        assert!(m.try_admit().is_ok());
        // unbounded meter never refuses, whatever the depth
        let u = IngressMeter::unbounded();
        for _ in 0..100 {
            assert!(u.try_admit().is_ok());
        }
        assert!(!u.saturated());
        // exit never underflows past a reset
        u.reset();
        u.exit();
        assert_eq!(u.depth(), 0);
    }

    #[test]
    fn saturated_dispatch_is_typed_backpressure() {
        let (tx, _rx) = channel();
        let endpoint = Arc::new(ShardEndpoint::with_shared(
            tx,
            Arc::new(IngressMeter::with_high_water(2)),
            Arc::new(CircuitBreaker::disabled()),
        ));
        let table = RoutingTable {
            assign: LayerAssignment::contiguous(1, 1),
            routes: vec![ShardRoute::shared(0, endpoint,
                                            LinkKind::SharedLocal)],
        };
        let ctx = VirtLayerCtx::new(0, table);
        let mut pending = Vec::new();
        for _ in 0..2 {
            pending.push(ctx
                .dispatch(LayerId::Qkv(0), OpKind::Forward,
                          Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
                .unwrap());
        }
        let err = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::ShardSaturated { shard, depth, limit } => {
                assert_eq!(shard, 0);
                assert_eq!(depth, 3);
                assert_eq!(limit, 2);
            }
            other => panic!("expected ShardSaturated, got {other}"),
        }
        // a refused dispatch charged nothing to the link
        let (msgs, _) = ctx.link_traffic()[0];
        assert_eq!(msgs, 2);
    }

    #[test]
    fn breaker_trips_after_consecutive_failures() {
        let b = CircuitBreaker::with_threshold(3);
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure();
        b.record_failure();
        b.record_success(); // run broken: back to zero
        b.record_failure();
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Closed);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow());
        // watchdog heartbeat re-arms to half-open: one probe passes
        b.probe();
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert!(b.allow(), "first caller wins the probe slot");
        assert!(!b.allow(), "second caller is still fast-failed");
        b.record_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow());
    }

    #[test]
    fn half_open_probe_failure_reopens() {
        let b = CircuitBreaker::with_threshold(1);
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        b.probe();
        assert!(b.allow());
        b.record_failure();
        assert_eq!(b.state(), BreakerState::Open);
        assert!(!b.allow());
        // a lost probe (dropped PendingLayer) re-arms on the next tick
        b.probe();
        assert!(b.allow());
        b.probe(); // half-open tick: returns the stuck probe slot
        assert!(b.allow());
    }

    #[test]
    fn open_breaker_fast_fails_dispatch_without_sending() {
        let (tx, rx) = channel();
        let breaker = Arc::new(CircuitBreaker::with_threshold(1));
        let endpoint = Arc::new(ShardEndpoint::with_shared(
            tx,
            Arc::new(IngressMeter::unbounded()),
            breaker.clone(),
        ));
        let table = RoutingTable {
            assign: LayerAssignment::contiguous(1, 1),
            routes: vec![ShardRoute::shared(0, endpoint,
                                            LinkKind::SharedLocal)],
        };
        let ctx = VirtLayerCtx::new(0, table);
        breaker.record_failure();
        let before = std::time::Instant::now();
        let err = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::ShardUnavailable { shard, retries } => {
                assert_eq!(shard, 0);
                assert_eq!(retries, 0, "fast-fail burns no retries");
            }
            other => panic!("expected ShardUnavailable, got {other}"),
        }
        assert!(before.elapsed() < Duration::from_millis(20),
                "open breaker must not sleep through backoff");
        assert!(rx.try_recv().is_err(), "nothing reached the shard");
    }

    #[test]
    fn shed_response_is_deferred_not_retried() {
        let (tx, rx) = channel();
        std::thread::spawn(move || {
            while let Ok(ExecMsg::Request(req)) = rx.recv() {
                let _ = req.resp.send(LayerResponse {
                    y: Err(format!("{SHED_MARKER}saturation brown-out")),
                    queue_wait_secs: 0.0,
                    batch_clients: 1,
                });
            }
        });
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let mut ctx = VirtLayerCtx::new(0, table);
        ctx.retry = RetryPolicy::retries(3)
            .with_backoff(Duration::from_millis(1));
        let err = ctx
            .forward(LayerId::Qkv(0), Tensor::zeros(&[1, 4]),
                     Urgency::Background)
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::WorkShed { layer, shard } => {
                assert_eq!(layer, "l0.qkv");
                assert_eq!(shard, 0);
            }
            other => panic!("expected WorkShed, got {other}"),
        }
        // shed never burns the retry budget: one request only
        let (msgs, _) = ctx.link_traffic()[0];
        assert_eq!(msgs, 1);
    }

    #[test]
    fn backoff_jitter_is_deterministic_and_bounded() {
        let p = RetryPolicy::retries(4)
            .with_backoff(Duration::from_millis(20));
        for attempt in 1..=4u32 {
            for salt in [0u64, 7, 1337, u64::MAX] {
                let a = p.backoff_for(attempt, salt);
                let b = p.backoff_for(attempt, salt);
                assert_eq!(a, b, "jitter must be deterministic");
                let linear = p.backoff * attempt;
                assert!(a >= linear / 2 && a < linear * 3 / 2,
                        "attempt {attempt} salt {salt}: {a:?} outside \
                         [0.5, 1.5) x {linear:?}");
            }
        }
        // different clients de-synchronize
        assert_ne!(p.backoff_for(1, 1), p.backoff_for(1, 2));
    }

    #[test]
    fn tenant_in_flight_quota_gates_dispatch() {
        use crate::coordinator::admission::AdmissionController;
        let ac = AdmissionController::new();
        ac.set_quota("acme",
                     crate::coordinator::admission::TenantQuota::unlimited()
                         .max_in_flight(1));
        let (tx, _rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let mut ctx = VirtLayerCtx::new(0, table);
        ctx.tenant = Some(ac.tenant("acme"));
        let pend = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .unwrap();
        let err = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::QuotaExceeded { tenant, resource, .. } => {
                assert_eq!(tenant, "acme");
                assert_eq!(resource, "in-flight layer requests");
            }
            other => panic!("expected QuotaExceeded, got {other}"),
        }
        // dropping the pending layer releases the slot (RAII guard)
        drop(pend);
        assert!(ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .is_ok());
    }
}
