//! `VirtLayer` — the client-side proxy for a base-model layer, routed
//! over the shard fleet.
//!
//! The paper replaces every frozen layer in the client's model definition
//! with a `torch.nn.Module` whose forward/backward ship activations to
//! the base executor (section 3.2, Fig. 4).  Here the proxy is a handle
//! that packages the request, looks the layer up in its [`RoutingTable`]
//! (section 3.3: the base may be sharded over several executors),
//! charges that shard's [`Link`], applies the privacy protocol when
//! configured, and collects the response — keeping the *client* the
//! driver of its own execution.
//!
//! # Split-phase dispatch
//!
//! Every base-layer invocation has two halves:
//!
//! * [`VirtLayerCtx::dispatch`] sends the request and returns a
//!   [`PendingLayer`] **without blocking**.  The *request* link is
//!   charged here — the payload crosses to the shard the moment the
//!   message is sent, whether or not the client waits.
//! * [`PendingLayer::collect`] blocks on the response, accumulates the
//!   executor queue-wait, surfaces a shard failure as
//!   [`SymbiosisError::ExecutorFailed`], and charges the *response*
//!   link for the returned payload.
//!
//! The blocking convenience calls ([`VirtLayerCtx::forward`] /
//! [`VirtLayerCtx::backward`] / [`VirtLayerCtx::embed`]) are exactly
//! `dispatch(..)?.collect()`, so the sequential path is unchanged.  The
//! split-phase half is what lets the pipelined prefill walker keep one
//! in-flight request per micro-batch: micro-batch k's request occupies
//! shard s+1 while micro-batch k+1's occupies shard s.
//!
//! Ordering guarantees: requests dispatched over one context to the
//! *same* shard arrive in dispatch order (the channel is FIFO); requests
//! to different shards are unordered relative to each other.  Dropping a
//! `PendingLayer` without collecting is safe — the shard's response to a
//! closed receiver is discarded, nothing blocks.
//!
//! With Arc-backed tensors the request/response payloads are shared
//! views: shipping `x` to the executor (and receiving the scattered
//! output slice back) moves no activation bytes in-process.  Each shard
//! route still charges the *modeled* transfer for the placement being
//! simulated — a co-located shard costs `SharedLocal`, a cross-shard hop
//! `NvLink` — so accounting matches the topology while real host copies
//! stay zero.  The wait/link accumulators are bit-cast `AtomicU64`s, not
//! mutexes: with pipelined prefill they are touched once per layer per
//! micro-batch, and an uncontended atomic add stays off the lock path.
//!
//! Contexts are built by [`Deployment::build_core`] (one per client id);
//! sessions configure the links, realized delays, and the privacy
//! protocol through the
//! [`SessionBuilder`](crate::coordinator::SessionBuilder) rather than
//! mutating this struct after the fact.
//!
//! [`Deployment::build_core`]: crate::coordinator::Deployment

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::coordinator::fleet::FleetBarrier;
use crate::coordinator::privacy::PrivacyCtx;
use crate::coordinator::proto::{ExecMsg, LayerId, LayerRequest,
                                LayerResponse, OpKind, Urgency};
use crate::coordinator::sharding::LayerAssignment;
use crate::error::SymbiosisError;
use crate::tensor::Tensor;
use crate::transport::{Link, LinkKind};

/// One shard's endpoint as a client sees it: the executor channel plus
/// the simulated link the client's traffic to that shard crosses.
pub struct ShardRoute {
    pub tx: Sender<ExecMsg>,
    pub link: Mutex<Link>,
}

impl ShardRoute {
    pub fn new(tx: Sender<ExecMsg>, kind: LinkKind) -> Self {
        ShardRoute { tx, link: Mutex::new(Link::new(kind)) }
    }
}

/// Client-side routing over the executor fleet: which shard owns each
/// layer, and over which link it is reached.
pub struct RoutingTable {
    assign: LayerAssignment,
    routes: Vec<ShardRoute>,
}

impl RoutingTable {
    pub fn new(assign: LayerAssignment, routes: Vec<ShardRoute>) -> Self {
        assert_eq!(assign.shards(), routes.len(),
                   "assignment/route count mismatch");
        RoutingTable { assign, routes }
    }

    /// Single-shard table — the pre-fleet topology (tests, tools).
    pub fn single(tx: Sender<ExecMsg>, kind: LinkKind) -> Self {
        RoutingTable::new(LayerAssignment::contiguous(1, 1),
                          vec![ShardRoute::new(tx, kind)])
    }

    pub fn n_shards(&self) -> usize {
        self.routes.len()
    }

    /// The route serving `layer`.
    pub fn route(&self, layer: LayerId) -> &ShardRoute {
        &self.routes[self.assign.shard_of(layer)]
    }

    pub fn routes(&self) -> &[ShardRoute] {
        &self.routes
    }
}

/// Add a delta to an `f64` stored bit-cast in an `AtomicU64`.
/// Uncontended CAS loop — the counters are per client, so contention
/// only occurs if one session is driven from several threads.
fn atomic_f64_add(cell: &AtomicU64, delta: f64) {
    let mut cur = cell.load(Ordering::Relaxed);
    loop {
        let next = (f64::from_bits(cur) + delta).to_bits();
        match cell.compare_exchange_weak(cur, next, Ordering::Relaxed,
                                         Ordering::Relaxed) {
            Ok(_) => return,
            Err(seen) => cur = seen,
        }
    }
}

fn atomic_f64_get(cell: &AtomicU64) -> f64 {
    f64::from_bits(cell.load(Ordering::Relaxed))
}

/// Per-client view of the executor fleet: layer proxies share this
/// context.
pub struct VirtLayerCtx {
    pub client_id: usize,
    routing: RoutingTable,
    /// Optional activation-privacy protocol state.
    pub privacy: Option<PrivacyCtx>,
    /// When set, simulated link delays are *realized* as actual sleeps,
    /// so remote/network placements behave (not just account) slower —
    /// used by the placement benches (Figs 7/13/21).
    pub realize_delays: bool,
    /// The fleet-global lockstep registration count, updated
    /// *synchronously* in [`Self::register`]/[`Self::deregister`]
    /// (before/alongside the per-shard messages) so
    /// `BatchPolicy::LockstepFleet` barriers never read a count that
    /// lags a client whose requests are already in flight.  `None` for
    /// hand-built contexts (tests, tools).
    pub fleet_barrier: Option<std::sync::Arc<FleetBarrier>>,
    /// Accumulated queue-wait observed by this client (Fig 7);
    /// f64 seconds bit-cast into the atomic.
    wait_secs: AtomicU64,
    /// Accumulated simulated link time (all shard links); f64 bit-cast.
    link_secs: AtomicU64,
}

/// An in-flight base-layer invocation: the response receiver plus what
/// is needed to finish the accounting at collect time.  Obtained from
/// [`VirtLayerCtx::dispatch`] (or the privacy-aware
/// [`VirtLayerCtx::dispatch_forward`]); the request link was already
/// charged at dispatch.  Dropping without collecting discards the
/// response harmlessly.
pub struct PendingLayer<'a> {
    ctx: &'a VirtLayerCtx,
    route: &'a ShardRoute,
    layer: LayerId,
    rx: Receiver<LayerResponse>,
    /// Privacy: the noise effect to subtract from the response
    /// (`n_eff = W . n`), when this dispatch shipped noised activations.
    n_eff: Option<Tensor>,
}

impl PendingLayer<'_> {
    /// The layer this invocation targets.
    pub fn layer(&self) -> LayerId {
        self.layer
    }

    /// Block on the shard's response.  Accumulates the executor
    /// queue-wait, charges the *response* link for the returned payload,
    /// surfaces a failed flush as [`SymbiosisError::ExecutorFailed`],
    /// and removes the privacy noise effect when one was registered at
    /// dispatch.
    pub fn collect(self) -> Result<Tensor> {
        let resp =
            self.rx.recv().context("shard executor dropped request")?;
        atomic_f64_add(&self.ctx.wait_secs, resp.queue_wait_secs);
        let y = resp.y.map_err(|message| {
            anyhow::Error::new(SymbiosisError::ExecutorFailed {
                layer: self.layer.label(),
                message,
            })
        })?;
        self.ctx.charge(self.route, &y);
        match self.n_eff {
            Some(n) => Ok(crate::tensor::ops::sub(&y, &n)),
            None => Ok(y),
        }
    }
}

impl VirtLayerCtx {
    pub fn new(client_id: usize, routing: RoutingTable) -> Self {
        VirtLayerCtx {
            client_id,
            routing,
            privacy: None,
            realize_delays: false,
            fleet_barrier: None,
            wait_secs: AtomicU64::new(0.0f64.to_bits()),
            link_secs: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Register with every shard (lockstep policies count clients at
    /// each shard independently).  The fleet-global barrier is bumped
    /// synchronously *first*, so no shard can observe this client's
    /// requests while the global count still excludes it.
    pub fn register(&self) {
        if let Some(b) = &self.fleet_barrier {
            b.register();
        }
        for r in self.routing.routes() {
            let _ = r.tx.send(ExecMsg::Register {
                client_id: self.client_id,
            });
        }
    }

    pub fn deregister(&self) {
        // Drop the global count first too: a departing client must not
        // hold fleet-wide barriers for the message-drain latency.
        if let Some(b) = &self.fleet_barrier {
            b.deregister();
        }
        for r in self.routing.routes() {
            let _ = r.tx.send(ExecMsg::Deregister {
                client_id: self.client_id,
            });
        }
    }

    /// Invoke the forward pass of a base linear layer with activations
    /// `x: (T, Din)`.  Blocking: `dispatch_forward(..)?.collect()`.
    pub fn forward(&self, layer: LayerId, x: Tensor, urgency: Urgency)
                   -> Result<Tensor> {
        self.dispatch_forward(layer, x, urgency)?.collect()
    }

    /// Invoke the memory-optimized backward: returns `dX = dY . W^T`.
    pub fn backward(&self, layer: LayerId, dy: Tensor, urgency: Urgency)
                    -> Result<Tensor> {
        self.dispatch(layer, OpKind::Backward, dy, None, urgency)?
            .collect()
    }

    /// Embedding lookup: token ids + positions (both (T,) i32).
    pub fn embed(&self, tokens: Tensor, positions: Tensor,
                 urgency: Urgency) -> Result<Tensor> {
        self.dispatch_embed(tokens, positions, urgency)?.collect()
    }

    /// Non-blocking forward dispatch with the privacy protocol applied:
    /// when a [`PrivacyCtx`] is configured the shard receives `x + n`
    /// and the returned [`PendingLayer`] subtracts `n_eff = W . n` at
    /// collect, so pipelined walks stay private too.
    pub fn dispatch_forward(&self, layer: LayerId, x: Tensor,
                            urgency: Urgency)
                            -> Result<PendingLayer<'_>> {
        if let Some(p) = &self.privacy {
            let (noised, n_eff) = p.apply(layer, &x)?;
            let mut pend = self.dispatch(layer, OpKind::Forward, noised,
                                         None, urgency)?;
            pend.n_eff = Some(n_eff);
            return Ok(pend);
        }
        self.dispatch(layer, OpKind::Forward, x, None, urgency)
    }

    /// Non-blocking embedding dispatch.
    pub fn dispatch_embed(&self, tokens: Tensor, positions: Tensor,
                          urgency: Urgency) -> Result<PendingLayer<'_>> {
        self.dispatch(LayerId::Embed, OpKind::Forward, tokens,
                      Some(positions), urgency)
    }

    /// Charge one payload to a shard's link, realizing the delay when
    /// configured.
    fn charge(&self, route: &ShardRoute, t: &Tensor) {
        let dt = route.link.lock().unwrap().send(t);
        atomic_f64_add(&self.link_secs, dt);
        if self.realize_delays && dt > 20e-6 {
            std::thread::sleep(std::time::Duration::from_secs_f64(dt));
        }
    }

    /// Send one base-layer invocation without waiting for the response.
    /// The *request* link is charged here (the payload crosses now);
    /// everything the response owes — queue wait, response link,
    /// failure surfacing — happens in [`PendingLayer::collect`].
    pub fn dispatch(&self, layer: LayerId, op: OpKind, x: Tensor,
                    positions: Option<Tensor>, urgency: Urgency)
                    -> Result<PendingLayer<'_>> {
        let route = self.routing.route(layer);
        self.charge(route, &x);
        let (tx, rx) = channel::<LayerResponse>();
        route
            .tx
            .send(ExecMsg::Request(LayerRequest {
                client_id: self.client_id,
                layer,
                op,
                x,
                positions,
                urgency,
                resp: tx,
            }))
            .ok()
            .context("shard executor is gone")?;
        Ok(PendingLayer { ctx: self, route, layer, rx, n_eff: None })
    }

    /// Total simulated link time charged so far (all shards).
    pub fn link_time(&self) -> f64 {
        atomic_f64_get(&self.link_secs)
    }

    /// Per-shard link traffic: `(messages, bytes_moved)` in shard
    /// order — shows where the routed topology sends this client's
    /// activations.
    pub fn link_traffic(&self) -> Vec<(u64, u64)> {
        self.routing
            .routes()
            .iter()
            .map(|r| {
                let l = r.link.lock().unwrap();
                (l.messages, l.bytes_moved)
            })
            .collect()
    }

    /// Total executor queue wait observed so far.
    pub fn queue_wait(&self) -> f64 {
        atomic_f64_get(&self.wait_secs)
    }
}

impl Drop for VirtLayerCtx {
    /// Leaving clients must deregister from every shard, or lockstep
    /// barriers would wait for them forever (bounded only by the safety
    /// cap).
    fn drop(&mut self) {
        self.deregister();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn routing_sends_each_layer_to_its_owner() {
        let assign = LayerAssignment::contiguous(4, 2);
        let (tx0, rx0) = channel();
        let (tx1, rx1) = channel();
        let table = RoutingTable::new(assign, vec![
            ShardRoute::new(tx0, LinkKind::SharedLocal),
            ShardRoute::new(tx1, LinkKind::NvLink),
        ]);
        let ctx = VirtLayerCtx::new(7, table);
        ctx.register();
        // one Register at each shard
        assert!(matches!(rx0.try_recv().unwrap(),
                         ExecMsg::Register { client_id: 7 }));
        assert!(matches!(rx1.try_recv().unwrap(),
                         ExecMsg::Register { client_id: 7 }));
        // a block-0 request lands on shard 0, a block-3 one on shard 1
        for (layer, want0) in [(LayerId::Qkv(0), true),
                               (LayerId::Embed, true),
                               (LayerId::MlpUp(3), false),
                               (LayerId::LmHead, false)] {
            let route = ctx_route(&ctx, layer);
            assert_eq!(route, if want0 { 0 } else { 1 },
                       "layer {layer:?} routed to shard {route}");
        }
        drop(ctx); // deregisters everywhere
        assert!(matches!(rx0.try_recv().unwrap(),
                         ExecMsg::Deregister { client_id: 7 }));
        assert!(matches!(rx1.try_recv().unwrap(),
                         ExecMsg::Deregister { client_id: 7 }));
    }

    /// Which shard index a layer routes to (test helper: compares the
    /// route's channel against the table's endpoints by identity).
    fn ctx_route(ctx: &VirtLayerCtx, layer: LayerId) -> usize {
        let target = ctx.routing.route(layer) as *const ShardRoute;
        ctx.routing
            .routes()
            .iter()
            .position(|r| std::ptr::eq(r, target))
            .unwrap()
    }

    #[test]
    fn single_table_routes_everything_to_shard_zero() {
        let (tx, _rx) = channel();
        let t = RoutingTable::single(tx, LinkKind::SharedLocal);
        assert_eq!(t.n_shards(), 1);
        for layer in [LayerId::Embed, LayerId::Qkv(3), LayerId::LmHead] {
            // must not panic: every layer resolves to the one route
            let _ = t.route(layer);
        }
    }

    #[test]
    fn atomic_f64_counters_accumulate() {
        let cell = AtomicU64::new(0.0f64.to_bits());
        atomic_f64_add(&cell, 1.5);
        atomic_f64_add(&cell, 0.25);
        assert_eq!(atomic_f64_get(&cell), 1.75);
    }

    #[test]
    fn dispatch_charges_request_and_collect_charges_response() {
        let (tx, rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::NvLink);
        let ctx = VirtLayerCtx::new(0, table);
        let x = Tensor::zeros(&[4, 8]);
        let pend = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward, x, None,
                      Urgency::Bulk)
            .unwrap();
        // the request payload crossed the link at dispatch time
        let (msgs, bytes) = ctx.link_traffic()[0];
        assert_eq!(msgs, 1);
        assert_eq!(bytes, 4 * 8 * 4);
        assert_eq!(pend.layer(), LayerId::Qkv(0));
        // fake shard: answer with a (4, 24) tensor and some queue wait
        let req = match rx.try_recv().unwrap() {
            ExecMsg::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        };
        req.resp
            .send(LayerResponse {
                y: Ok(Tensor::zeros(&[4, 24])),
                queue_wait_secs: 0.125,
                batch_clients: 1,
            })
            .unwrap();
        let y = pend.collect().unwrap();
        assert_eq!(y.shape, vec![4, 24]);
        assert_eq!(ctx.queue_wait(), 0.125);
        let (msgs, bytes) = ctx.link_traffic()[0];
        assert_eq!(msgs, 2, "collect must charge the response hop");
        assert_eq!(bytes, (4 * 8 + 4 * 24) * 4);
    }

    #[test]
    fn collect_surfaces_executor_failure_typed() {
        let (tx, rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let ctx = VirtLayerCtx::new(0, table);
        let pend = ctx
            .dispatch(LayerId::MlpUp(1), OpKind::Forward,
                      Tensor::zeros(&[2, 4]), None, Urgency::Bulk)
            .unwrap();
        let req = match rx.try_recv().unwrap() {
            ExecMsg::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        };
        req.resp
            .send(LayerResponse {
                y: Err("injected fault".into()),
                queue_wait_secs: 0.0,
                batch_clients: 1,
            })
            .unwrap();
        let err = pend.collect().unwrap_err();
        match SymbiosisError::from(err) {
            SymbiosisError::ExecutorFailed { layer, message } => {
                assert_eq!(layer, "l1.mlp_up");
                assert_eq!(message, "injected fault");
            }
            other => panic!("expected ExecutorFailed, got {other}"),
        }
    }

    #[test]
    fn dropping_a_pending_layer_is_harmless() {
        let (tx, rx) = channel();
        let table = RoutingTable::single(tx, LinkKind::SharedLocal);
        let ctx = VirtLayerCtx::new(0, table);
        let pend = ctx
            .dispatch(LayerId::Qkv(0), OpKind::Forward,
                      Tensor::zeros(&[1, 4]), None, Urgency::Bulk)
            .unwrap();
        drop(pend);
        // the shard's answer to a dropped receiver is simply discarded
        let req = match rx.try_recv().unwrap() {
            ExecMsg::Request(r) => r,
            other => panic!("expected a request, got {other:?}"),
        };
        let send_result = req.resp.send(LayerResponse {
            y: Ok(Tensor::zeros(&[1, 4])),
            queue_wait_secs: 0.0,
            batch_clients: 1,
        });
        assert!(send_result.is_err(), "receiver should be gone");
    }
}
