//! `VirtLayer` — the client-side proxy for a base-model layer.
//!
//! The paper replaces every frozen layer in the client's model definition
//! with a `torch.nn.Module` whose forward/backward ship activations to
//! the base executor (section 3.2, Fig. 4).  Here the proxy is a handle
//! that packages the request, charges the client<->executor link, applies
//! the privacy protocol when configured, and blocks on the response —
//! keeping the *client* the driver of its own execution.
//!
//! With Arc-backed tensors the request/response payloads are shared
//! views: shipping `x` to the executor (and receiving the scattered
//! output slice back) moves no activation bytes in-process.  The [`Link`]
//! still charges the *modeled* transfer for the placement being
//! simulated — accounting is unchanged, only real host copies went away.
//!
//! Contexts are built by [`Deployment::build_core`] (one per client id);
//! sessions configure the link, realized delays, and the privacy
//! protocol through the
//! [`SessionBuilder`](crate::coordinator::SessionBuilder) rather than
//! mutating this struct after the fact.
//!
//! [`Deployment::build_core`]: crate::coordinator::Deployment

use std::sync::mpsc::{channel, Sender};
use std::sync::Mutex;

use anyhow::{Context, Result};

use crate::coordinator::privacy::PrivacyCtx;
use crate::coordinator::proto::{ExecMsg, LayerId, LayerRequest,
                                LayerResponse, OpKind, Urgency};
use crate::tensor::Tensor;
use crate::transport::Link;

/// Per-client view of the executor: layer proxies share this context.
pub struct VirtLayerCtx {
    pub client_id: usize,
    pub exec_tx: Sender<ExecMsg>,
    /// Simulated link to the executor (charged per message).
    pub link: Mutex<Link>,
    /// Optional activation-privacy protocol state.
    pub privacy: Option<PrivacyCtx>,
    /// When set, simulated link delays are *realized* as actual sleeps,
    /// so remote/network placements behave (not just account) slower —
    /// used by the placement benches (Figs 7/13/21).
    pub realize_delays: bool,
    /// Accumulated queue-wait observed by this client (Fig 7).
    pub wait_secs: Mutex<f64>,
    /// Accumulated simulated link time.
    pub link_secs: Mutex<f64>,
}

impl VirtLayerCtx {
    pub fn new(client_id: usize, exec_tx: Sender<ExecMsg>,
               link: Link) -> Self {
        VirtLayerCtx {
            client_id,
            exec_tx,
            link: Mutex::new(link),
            privacy: None,
            realize_delays: false,
            wait_secs: Mutex::new(0.0),
            link_secs: Mutex::new(0.0),
        }
    }

    pub fn with_privacy(mut self, p: PrivacyCtx) -> Self {
        self.privacy = Some(p);
        self
    }

    /// Register with the executor (lockstep policies count clients).
    pub fn register(&self) {
        let _ = self.exec_tx.send(ExecMsg::Register {
            client_id: self.client_id,
        });
    }

    pub fn deregister(&self) {
        let _ = self.exec_tx.send(ExecMsg::Deregister {
            client_id: self.client_id,
        });
    }

    /// Invoke the forward pass of a base linear layer with activations
    /// `x: (T, Din)`.
    pub fn forward(&self, layer: LayerId, x: Tensor, urgency: Urgency)
                   -> Result<Tensor> {
        // Privacy: ship x + n, receive W(x+n)+b, subtract n_eff = W.n.
        if let Some(p) = &self.privacy {
            let (noised, n_eff) = p.apply(layer, &x)?;
            let y_noisy =
                self.round_trip(layer, OpKind::Forward, noised, None,
                                urgency)?;
            return Ok(crate::tensor::ops::sub(&y_noisy, &n_eff));
        }
        self.round_trip(layer, OpKind::Forward, x, None, urgency)
    }

    /// Invoke the memory-optimized backward: returns `dX = dY . W^T`.
    pub fn backward(&self, layer: LayerId, dy: Tensor, urgency: Urgency)
                    -> Result<Tensor> {
        self.round_trip(layer, OpKind::Backward, dy, None, urgency)
    }

    /// Embedding lookup: token ids + positions (both (T,) i32).
    pub fn embed(&self, tokens: Tensor, positions: Tensor,
                 urgency: Urgency) -> Result<Tensor> {
        self.round_trip(LayerId::Embed, OpKind::Forward, tokens,
                        Some(positions), urgency)
    }

    fn round_trip(&self, layer: LayerId, op: OpKind, x: Tensor,
                  positions: Option<Tensor>, urgency: Urgency)
                  -> Result<Tensor> {
        // Charge the simulated link for the request payload.
        {
            let mut link = self.link.lock().unwrap();
            let dt = link.send(&x);
            *self.link_secs.lock().unwrap() += dt;
            if self.realize_delays && dt > 20e-6 {
                std::thread::sleep(std::time::Duration::from_secs_f64(dt));
            }
        }
        let (tx, rx) = channel::<LayerResponse>();
        self.exec_tx
            .send(ExecMsg::Request(LayerRequest {
                client_id: self.client_id,
                layer,
                op,
                x,
                positions,
                urgency,
                resp: tx,
            }))
            .ok()
            .context("base executor is gone")?;
        let resp = rx.recv().context("base executor dropped request")?;
        // Charge the link for the response payload.
        {
            let mut link = self.link.lock().unwrap();
            let dt = link.send(&resp.y);
            *self.link_secs.lock().unwrap() += dt;
            if self.realize_delays && dt > 20e-6 {
                std::thread::sleep(std::time::Duration::from_secs_f64(dt));
            }
        }
        *self.wait_secs.lock().unwrap() += resp.queue_wait_secs;
        Ok(resp.y)
    }

    /// Total simulated link time charged so far.
    pub fn link_time(&self) -> f64 {
        *self.link_secs.lock().unwrap()
    }

    /// Total executor queue wait observed so far.
    pub fn queue_wait(&self) -> f64 {
        *self.wait_secs.lock().unwrap()
    }
}

impl Drop for VirtLayerCtx {
    /// Leaving clients must deregister, or lockstep barriers would wait
    /// for them forever (bounded only by the safety cap).
    fn drop(&mut self) {
        self.deregister();
    }
}
