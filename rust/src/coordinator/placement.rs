//! Placement configurations (paper Fig. 5) and their analytic cost
//! models.
//!
//! Real execution always runs on the CPU PJRT substrate; placement
//! decides (a) which simulated device's ledger each component's memory is
//! charged to, and (b) which link the client<->executor traffic crosses.
//! The analytic iteration model below reproduces the *shape* of the
//! paper's placement figures (13-20) on the paper-scale models that
//! cannot execute here.

#![deny(clippy::unwrap_used)]

use crate::config::ModelConfig;
use crate::device::{Device, DeviceKind};
use crate::transport::LinkKind;

/// The four deployment shapes of Fig. 5 plus the heterogeneous variants.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// Clients co-located with the executor on one GPU.
    Local,
    /// Executor on one GPU, clients on another (NVLink).
    Remote,
    /// Base model sharded across `n` GPUs, clients on the same GPUs.
    ShardedLocal { shards: usize },
    /// Base sharded across `n` GPUs, clients on a disjoint set.
    ShardedRemote { shards: usize },
    /// Base sharded across a heterogeneous co-located fleet: `fast`
    /// GpuFast40 shards followed by `slow` GpuSlow40 shards (Fig. 18's
    /// mixed power caps, sharded).  The fleet assigns transformer
    /// blocks capacity-weighted, so fast shards take ~3.5x the blocks
    /// of slow ones instead of an even split that would pace every
    /// wavefront at the slowest device.
    ShardedHetero { fast: usize, slow: usize },
    /// Executor on the fast GPU, clients on the slow GPU (Fig. 18).
    HeteroGpu,
    /// Executor on GPU, clients (attention + KV) on the host CPU
    /// (Figs. 19/20).
    CpuClient,
}

impl Placement {
    /// Link crossed by client<->executor activations.
    pub fn link(&self) -> LinkKind {
        match self {
            Placement::Local
            | Placement::ShardedLocal { .. }
            | Placement::ShardedHetero { .. } => LinkKind::SharedLocal,
            Placement::Remote
            | Placement::ShardedRemote { .. }
            | Placement::HeteroGpu => LinkKind::NvLink,
            Placement::CpuClient => LinkKind::Pcie,
        }
    }

    /// Device kind hosting the executor.
    pub fn executor_device(&self) -> DeviceKind {
        match self {
            Placement::HeteroGpu | Placement::ShardedHetero { .. } => {
                DeviceKind::GpuFast40
            }
            _ => DeviceKind::GpuA100_80,
        }
    }

    /// Device kind hosting executor shard `shard`.  Homogeneous
    /// placements return `executor_device()` for every shard; the
    /// heterogeneous sharded fleet puts the first `fast` shards on
    /// GpuFast40 and the rest on GpuSlow40.
    pub fn executor_device_for(&self, shard: usize) -> DeviceKind {
        match self {
            Placement::ShardedHetero { fast, .. } => {
                if shard < *fast {
                    DeviceKind::GpuFast40
                } else {
                    DeviceKind::GpuSlow40
                }
            }
            _ => self.executor_device(),
        }
    }

    /// Device kind hosting clients.
    pub fn client_device(&self) -> DeviceKind {
        match self {
            Placement::HeteroGpu => DeviceKind::GpuSlow40,
            Placement::CpuClient => DeviceKind::Cpu,
            _ => DeviceKind::GpuA100_80,
        }
    }

    /// Device kind backing host DRAM — where `KvPlacement::Host`
    /// caches live and where the paged KV pool swaps cold background
    /// blocks under device pressure.  The host is the CPU under every
    /// placement shape; the accessor exists so the deployment charges
    /// it through the placement like every other device decision.
    pub fn host_device(&self) -> DeviceKind {
        DeviceKind::Cpu
    }

    pub fn shards(&self) -> usize {
        match self {
            Placement::ShardedLocal { shards }
            | Placement::ShardedRemote { shards } => *shards,
            Placement::ShardedHetero { fast, slow } => fast + slow,
            _ => 1,
        }
    }

    /// Link kind from one client to each of `shards` executor shards —
    /// what the fleet's client-side routing table charges per hop.
    /// Sharded-local clients are co-located with one shard (round-robin
    /// by client id): that hop is `SharedLocal`, cross-shard hops cross
    /// `NvLink`.  Sharded-remote clients reach every shard over
    /// `NvLink`; unsharded placements keep their single link kind.
    pub fn shard_links(&self, client_id: usize, shards: usize)
                       -> Vec<LinkKind> {
        let shards = shards.max(1);
        match self {
            Placement::ShardedLocal { .. }
            | Placement::ShardedHetero { .. } => (0..shards)
                .map(|s| {
                    if s == client_id % shards {
                        LinkKind::SharedLocal
                    } else {
                        LinkKind::NvLink
                    }
                })
                .collect(),
            Placement::ShardedRemote { .. } => {
                vec![LinkKind::NvLink; shards]
            }
            _ => vec![self.link(); shards],
        }
    }
}

/// Analytic per-iteration model of one fine-tuning client under a
/// placement: compute split between executor (linears) and client
/// (attention + adapter + norms), link transfers per layer crossing, and
/// sharded parameter fetches (FSDP all-gather per layer).
#[derive(Debug, Clone)]
pub struct IterationModel {
    pub cfg: ModelConfig,
    pub placement: Placement,
    pub batch: usize,
    pub seq: usize,
}

impl IterationModel {
    /// Executor-side FLOPs of one fwd(+bwd) pass over `t` tokens: the
    /// linear layers, 2x for backward's dX recompute.
    fn executor_flops(&self, training: bool) -> u64 {
        let t = (self.batch * self.seq) as u64;
        let d = self.cfg.d_model as u64;
        let kv_dim = (self.cfg.kv_heads * self.cfg.d_head()) as u64;
        let per_layer = d * d + 2 * d * kv_dim + d * d
            + self.cfg.mlp_mats as u64 * d * self.cfg.d_ff as u64;
        let fwd = 2 * t
            * (self.cfg.n_layers as u64 * per_layer
                + d * self.cfg.vocab as u64);
        if training { 2 * fwd } else { fwd }
    }

    /// Client-side FLOPs: attention (quadratic) + adapter path.
    fn client_flops(&self, rank: usize, n_targets: usize,
                    training: bool) -> u64 {
        let t = (self.batch * self.seq) as u64;
        let d = self.cfg.d_model as u64;
        let attn = 4 * self.cfg.n_layers as u64 * t * self.seq as u64 * d;
        let lora = 2 * t
            * self.cfg.n_layers as u64
            * (n_targets as u64 * 2 * d * rank as u64);
        let fwd = attn + lora;
        if training { 2 * fwd } else { fwd }
    }

    /// Bytes crossing the client<->executor link in one pass: one
    /// activation tensor each way per base-layer invocation (4 linears
    /// per block + embed + head), doubled for backward.
    fn link_bytes(&self, training: bool) -> u64 {
        let t = (self.batch * self.seq) as u64;
        let per_crossing = self.cfg.activation_bytes(t);
        let crossings = (self.cfg.n_layers as u64 * 4 + 2) * 2;
        let fwd = crossings * per_crossing;
        if training { 2 * fwd } else { fwd }
    }

    /// FSDP-style parameter fetch per iteration when sharded: every
    /// layer's weights are all-gathered once per pass ((shards-1)/shards
    /// of the bytes cross NVLink).
    fn shard_fetch_bytes(&self) -> u64 {
        let s = self.placement.shards() as u64;
        if s <= 1 {
            return 0;
        }
        self.cfg.param_bytes() * (s - 1) / s
    }

    /// Simulated seconds for one iteration of a single client
    /// (`training=true` for fine-tuning, false for a prefill-style
    /// inference pass), with `n_clients` sharing the executor via
    /// perfectly-batched layers (paper's best case: batching divides the
    /// per-client executor time).
    pub fn iteration_secs(&self, n_clients: usize, rank: usize,
                          n_targets: usize, training: bool) -> f64 {
        let exec_dev = Device::new("exec", self.placement.executor_device());
        let client_dev = Device::new("cli", self.placement.client_device());
        let p = self.cfg.precision;
        let t = (self.batch * self.seq) as u64;

        // executor: the batch over all clients runs as one flattened
        // matmul per layer; per-client share is ~1/n of batched time but
        // bounded below by full-utilization throughput.
        let exec_flops = self.executor_flops(training) as f64
            * n_clients as f64;
        let exec_bytes_touched = self.cfg.param_bytes()
            + n_clients as u64 * self.cfg.activation_bytes(t) * 2;
        let exec_time = exec_dev.op_time(exec_flops as u64,
                                         exec_bytes_touched, p)
            / 1.0_f64.max(self.placement.shards() as f64);

        let client_time = client_dev.op_time(
            self.client_flops(rank, n_targets, training),
            self.cfg.kv_cache_bytes(self.batch, self.seq)
                + self.cfg.activation_bytes(t) * 4,
            p,
        );

        let link = self.placement.link();
        let link_time = link.transfer_time(self.link_bytes(training));
        let shard_time = if self.placement.shards() > 1 {
            LinkKind::NvLink.transfer_time(self.shard_fetch_bytes())
        } else {
            0.0
        };

        // clients run concurrently; executor is shared (batched); link
        // serializes per client.
        exec_time + client_time + link_time + shard_time
    }

    /// Tokens/second across `n_clients` concurrent fine-tuning clients.
    pub fn throughput_tokens_per_sec(&self, n_clients: usize, rank: usize,
                                     n_targets: usize, training: bool)
                                     -> f64 {
        let iter = self.iteration_secs(n_clients, rank, n_targets,
                                       training);
        (self.batch * self.seq * n_clients) as f64 / iter
    }

    /// GPipe-style pipelined-prefill latency model: the prompt split
    /// into `chunks` micro-batches over `shards` stages fills and
    /// drains a wavefront, so the M*S chunk-stage tiles execute in
    /// M + S - 1 steps instead of M*S — latency scales by
    /// `(M + S - 1) / (M * S)` relative to the sequential walk of the
    /// same fleet (Huang et al.; mLoRA's pipelined scheduling).
    pub fn pipelined_prefill_secs(&self, chunks: usize) -> f64 {
        let s = self.placement.shards().max(1) as f64;
        let m = chunks.max(1) as f64;
        let sequential = self.iteration_secs(1, 0, 0, false);
        sequential * (m + s - 1.0) / (m * s)
    }

    /// Modeled speedup of pipelined over sequential prefill:
    /// `M*S / (M + S - 1)` — what the `pipeline` bench prints next to
    /// the measured wall-clock column.
    pub fn pipeline_speedup(&self, chunks: usize) -> f64 {
        let s = self.placement.shards().max(1) as f64;
        let m = chunks.max(1) as f64;
        m * s / (m + s - 1.0)
    }

    /// Modeled steady-state shard occupancy of the pipelined prefill:
    /// `M / (M + S - 1)` (each shard works M of the M+S-1 wavefront
    /// steps).
    pub fn pipeline_occupancy(&self, chunks: usize) -> f64 {
        let s = self.placement.shards().max(1) as f64;
        let m = chunks.max(1) as f64;
        m / (m + s - 1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::LLAMA2_13B;

    fn model(p: Placement) -> IterationModel {
        IterationModel { cfg: LLAMA2_13B, placement: p, batch: 2, seq: 512 }
    }

    #[test]
    fn local_beats_remote_beats_cpu_link() {
        let l = model(Placement::Local).iteration_secs(1, 8, 4, true);
        let r = model(Placement::Remote).iteration_secs(1, 8, 4, true);
        let c = model(Placement::CpuClient).iteration_secs(1, 8, 4, true);
        assert!(l < r, "{l} vs {r}");
        assert!(r < c, "{r} vs {c}");
    }

    #[test]
    fn batching_amortizes_executor() {
        let m = model(Placement::Remote);
        let one = m.iteration_secs(1, 8, 4, true);
        let eight = m.iteration_secs(8, 8, 4, true);
        // 8 clients take less than 8x one client's iteration
        assert!(eight < 8.0 * one);
        // throughput grows with clients
        assert!(m.throughput_tokens_per_sec(8, 8, 4, true)
                > m.throughput_tokens_per_sec(1, 8, 4, true));
    }

    #[test]
    fn hetero_close_to_homogeneous() {
        // paper Fig 18: slow client GPU barely hurts (client work is
        // light) — within 35%.
        let hom = model(Placement::Remote).iteration_secs(4, 8, 4, true);
        let het = model(Placement::HeteroGpu).iteration_secs(4, 8, 4, true);
        assert!(het < hom * 1.35, "het {het} hom {hom}");
    }

    #[test]
    fn shard_links_follow_colocation() {
        let p = Placement::ShardedLocal { shards: 4 };
        let links = p.shard_links(2, 4);
        assert_eq!(links.len(), 4);
        assert_eq!(links[2], LinkKind::SharedLocal);
        assert!(links.iter().enumerate().all(|(s, l)| {
            (s == 2) == (*l == LinkKind::SharedLocal)
        }));
        let r = Placement::ShardedRemote { shards: 2 };
        assert_eq!(r.shard_links(0, 2),
                   vec![LinkKind::NvLink, LinkKind::NvLink]);
        // unsharded placements keep their one link kind
        assert_eq!(Placement::CpuClient.shard_links(0, 1),
                   vec![LinkKind::Pcie]);
    }

    #[test]
    fn sharded_hetero_splits_devices_by_shard() {
        let p = Placement::ShardedHetero { fast: 1, slow: 1 };
        assert_eq!(p.shards(), 2);
        assert_eq!(p.link(), LinkKind::SharedLocal);
        assert_eq!(p.executor_device(), DeviceKind::GpuFast40);
        assert_eq!(p.executor_device_for(0), DeviceKind::GpuFast40);
        assert_eq!(p.executor_device_for(1), DeviceKind::GpuSlow40);
        // clients stay on the big GPU like other sharded placements
        assert_eq!(p.client_device(), DeviceKind::GpuA100_80);
        // co-located round-robin link routing like ShardedLocal
        let links = p.shard_links(1, 2);
        assert_eq!(links[1], LinkKind::SharedLocal);
        assert_eq!(links[0], LinkKind::NvLink);
        // homogeneous placements answer the same device for each shard
        let h = Placement::ShardedLocal { shards: 4 };
        assert!((0..4).all(|s| h.executor_device_for(s)
                          == h.executor_device()));
    }

    #[test]
    fn pipelining_recovers_sharded_overlap() {
        let m = IterationModel {
            cfg: LLAMA2_13B,
            placement: Placement::ShardedLocal { shards: 2 },
            batch: 1,
            seq: 2048,
        };
        // chunks=1 is the sequential walk …
        assert!((m.pipeline_speedup(1) - 1.0).abs() < 1e-12);
        assert!((m.pipelined_prefill_secs(1)
                 - m.iteration_secs(1, 0, 0, false))
                    .abs()
                < 1e-9);
        // … the acceptance point (shards=2, chunks=4) models 1.6x …
        assert!((m.pipeline_speedup(4) - 1.6).abs() < 1e-12);
        assert!(m.pipeline_speedup(4) >= 1.3);
        // … and more chunks asymptote to the shard count with rising
        // occupancy.
        assert!(m.pipeline_speedup(8) > m.pipeline_speedup(4));
        assert!(m.pipeline_speedup(64) < 2.0);
        assert!(m.pipeline_occupancy(8) > m.pipeline_occupancy(2));
        assert!(m.pipelined_prefill_secs(8)
                < m.pipelined_prefill_secs(2));
    }

    #[test]
    fn sharding_splits_compute_but_pays_fetches() {
        let flat = model(Placement::Remote).iteration_secs(1, 8, 4, true);
        let sharded = model(Placement::ShardedRemote { shards: 4 })
            .iteration_secs(1, 8, 4, true);
        // compute is split across 4 shards, so sharded is faster than
        // flat — but parameter fetches keep it well above flat/4
        // (the paper: "the primary source of overhead ... is parameter
        // fetching").
        assert!(sharded < flat);
        assert!(sharded > flat / 4.0, "sharded {sharded} flat {flat}");
    }
}
