//! FSDP-style sharding of the frozen base model (paper section 3.3).
//!
//! Symbiosis uses FSDP only for its *sharding* capability: base layers
//! are frozen, so there is no gradient synchronization — each layer is an
//! independent FSDP unit whose parameters are all-gathered right before
//! execution and released right after ("only the parameters corresponding
//! to that layer are fetched ... after the layer's execution, the fetched
//! parameters are released").
//!
//! This module provides both the analytic accounting (per-GPU memory,
//! per-layer fetch schedule) the sharded benches consume **and** the
//! executable [`LayerAssignment`] the executor fleet deploys: a
//! `ShardPlan` is no longer just a cost model — `layer_assignment()`
//! yields the contiguous block partition that `coordinator::fleet`
//! spawns one shard executor per range for.

#![deny(clippy::unwrap_used)]

use anyhow::Result;

use crate::config::ModelConfig;
use crate::coordinator::proto::LayerId;
use crate::device::Device;
use crate::transport::LinkKind;

/// A sharding plan: every base layer's parameters split evenly over
/// `shards` devices.
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub cfg: ModelConfig,
    pub shards: usize,
}

impl ShardPlan {
    pub fn new(cfg: ModelConfig, shards: usize) -> Self {
        assert!(shards >= 1);
        ShardPlan { cfg, shards }
    }

    /// Resident parameter bytes per GPU (the 1/shards slice).
    pub fn resident_bytes_per_gpu(&self) -> u64 {
        self.cfg.param_bytes() / self.shards as u64
    }

    /// Transient bytes materialized while one block executes: the full
    /// parameters of that block (all-gathered working set).
    pub fn block_working_set(&self) -> u64 {
        let d = self.cfg.d_model as u64;
        let kv_dim = (self.cfg.kv_heads * self.cfg.d_head()) as u64;
        let per_block = d * d + 2 * d * kv_dim + d * d
            + self.cfg.mlp_mats as u64 * d * self.cfg.d_ff as u64;
        per_block * self.cfg.precision.bytes() as u64
    }

    /// Bytes each GPU must receive to materialize one block:
    /// (shards-1)/shards of the block's parameters.
    pub fn fetch_bytes_per_block(&self) -> u64 {
        self.block_working_set() * (self.shards as u64 - 1)
            / self.shards as u64
    }

    /// Simulated seconds of parameter fetches for one full pass
    /// (every block all-gathered once; fetches pipeline with compute so
    /// only the non-overlapped fraction is charged).
    pub fn fetch_secs_per_pass(&self, overlap: f64) -> f64 {
        let total = self.fetch_bytes_per_block()
            * self.cfg.n_layers as u64;
        LinkKind::NvLink.transfer_time(total) * (1.0 - overlap)
    }

    /// Charge the resident shard + one block working set to a GPU
    /// ledger; errors if the device cannot hold it (the "model too large
    /// for N GPUs" lines of Fig. 17).
    pub fn charge(&self, dev: &mut Device) -> Result<()> {
        dev.ledger
            .set("base-shard", self.resident_bytes_per_gpu())?;
        dev.ledger.set("base-gathered-block",
                       self.block_working_set())?;
        Ok(())
    }

    /// Peak per-GPU memory with `clients_per_gpu` fine-tuning clients
    /// co-located (sharded-local), each with the given runtime state.
    pub fn local_peak_bytes(&self, clients_per_gpu: usize,
                            client_state: u64) -> u64 {
        self.resident_bytes_per_gpu()
            + self.block_working_set()
            + clients_per_gpu as u64 * client_state
    }
}

/// The executable layer partition a [`ShardPlan`] induces: each shard
/// owns a contiguous range of transformer blocks; the embedding rides
/// with the first shard and the LM head with the last, so a full layer
/// walk visits shards in index order (which is also the fleet's
/// shutdown-drain order).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LayerAssignment {
    n_layers: usize,
    /// First absolute block of each shard, strictly increasing.
    starts: Vec<usize>,
}

impl LayerAssignment {
    /// Split `n_layers` blocks contiguously over `shards` executors
    /// (earlier shards take the remainder).  Clamped so every shard
    /// owns at least one block.
    pub fn contiguous(n_layers: usize, shards: usize) -> Self {
        let shards = shards.max(1).min(n_layers.max(1));
        let base = n_layers / shards;
        let extra = n_layers % shards;
        let mut starts = Vec::with_capacity(shards);
        let mut at = 0;
        for s in 0..shards {
            starts.push(at);
            at += base + usize::from(s < extra);
        }
        LayerAssignment { n_layers, starts }
    }

    /// Split `n_layers` blocks contiguously over `weights.len()` shards
    /// in proportion to each shard's capability weight (e.g.
    /// `DeviceKind::flops`), so heterogeneous fleets give faster
    /// devices proportionally more transformer blocks (paper Fig. 18's
    /// fast/slow GPU split).  Largest-remainder apportionment over the
    /// blocks left after every shard is floored at one; ties break
    /// toward lower shard indices, which makes equal weights reproduce
    /// [`LayerAssignment::contiguous`] exactly — homogeneous fleets are
    /// unchanged.  Non-positive or non-finite weight sums fall back to
    /// the contiguous split.
    pub fn capacity_weighted(n_layers: usize, weights: &[f64]) -> Self {
        if n_layers == 0 {
            return Self::contiguous(0, 1);
        }
        let shards = weights.len().max(1).min(n_layers);
        let w: Vec<f64> = weights
            .iter()
            .take(shards)
            .map(|x| if x.is_finite() && *x > 0.0 { *x } else { 0.0 })
            .collect();
        let total: f64 = w.iter().sum();
        if total <= 0.0 || !total.is_finite() {
            return Self::contiguous(n_layers, shards);
        }
        // Every shard owns at least one block; apportion the rest.
        let spare = n_layers - shards;
        let quotas: Vec<f64> = w
            .iter()
            .map(|x| x / total * spare as f64)
            .collect();
        let mut counts: Vec<usize> =
            quotas.iter().map(|q| 1 + q.floor() as usize).collect();
        let assigned: usize = counts.iter().sum();
        let mut order: Vec<usize> = (0..shards).collect();
        order.sort_by(|&a, &b| {
            let (fa, fb) = (quotas[a].fract(), quotas[b].fract());
            fb.total_cmp(&fa).then(a.cmp(&b))
        });
        for &s in order.iter().take(n_layers - assigned) {
            counts[s] += 1;
        }
        let mut starts = Vec::with_capacity(shards);
        let mut at = 0;
        for c in counts {
            starts.push(at);
            at += c;
        }
        LayerAssignment { n_layers, starts }
    }

    pub fn shards(&self) -> usize {
        self.starts.len()
    }

    pub fn n_layers(&self) -> usize {
        self.n_layers
    }

    /// Absolute block range owned by `shard`.
    pub fn block_range(&self, shard: usize) -> std::ops::Range<usize> {
        let end = self
            .starts
            .get(shard + 1)
            .copied()
            .unwrap_or(self.n_layers);
        self.starts[shard]..end
    }

    /// Shard owning an absolute block index.
    pub fn shard_of_block(&self, block: usize) -> usize {
        match self.starts.binary_search(&block) {
            Ok(i) => i,
            Err(i) => i.saturating_sub(1),
        }
    }

    /// Shard owning a layer — the client-side routing function.
    pub fn shard_of(&self, layer: LayerId) -> usize {
        match layer.block() {
            Some(l) => {
                self.shard_of_block(l.min(self.n_layers.saturating_sub(1)))
            }
            None => match layer {
                LayerId::Embed => 0,
                _ => self.shards() - 1, // LmHead
            },
        }
    }
}

impl ShardPlan {
    /// The executable partition this plan induces (what
    /// `coordinator::fleet` deploys).
    pub fn layer_assignment(&self) -> LayerAssignment {
        LayerAssignment::contiguous(self.cfg.n_layers, self.shards)
    }

    /// The capacity-weighted partition for a heterogeneous fleet: one
    /// weight per shard (e.g. each device's `DeviceKind::flops`).
    pub fn layer_assignment_weighted(&self, weights: &[f64])
                                     -> LayerAssignment {
        LayerAssignment::capacity_weighted(self.cfg.n_layers, weights)
    }
}

/// Check whether a model fits a set of identical GPUs under a plan.
pub fn fits(plan: &ShardPlan, gpu_capacity: u64) -> bool {
    plan.resident_bytes_per_gpu() + plan.block_working_set()
        < gpu_capacity
}

/// Convenience: smallest shard count (power of two) that fits.
pub fn min_shards(cfg: &ModelConfig, gpu_capacity: u64,
                  max_shards: usize) -> Option<usize> {
    let mut s = 1;
    while s <= max_shards {
        if fits(&ShardPlan::new(cfg.clone(), s), gpu_capacity) {
            return Some(s);
        }
        s *= 2;
    }
    None
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{GEMMA2_27B, LLAMA2_13B, SYM_TINY};
    use crate::device::{DeviceKind, GIB};

    #[test]
    fn sharding_divides_resident_bytes() {
        let p1 = ShardPlan::new(LLAMA2_13B, 1);
        let p2 = ShardPlan::new(LLAMA2_13B, 2);
        assert!((p2.resident_bytes_per_gpu() as f64
                 - p1.resident_bytes_per_gpu() as f64 / 2.0)
                    .abs()
                < GIB as f64);
    }

    #[test]
    fn gemma27_needs_multiple_40gb_gpus() {
        // 27B bf16 ~= 59GB > 40GB: must shard on 40GB cards.
        assert_eq!(min_shards(&GEMMA2_27B, 40 * GIB, 8), Some(2));
        // fits on a single 80GB card
        assert_eq!(min_shards(&GEMMA2_27B, 80 * GIB, 8), Some(1));
    }

    #[test]
    fn tiny_fits_everywhere() {
        assert!(fits(&ShardPlan::new(SYM_TINY, 1), GIB));
    }

    #[test]
    fn charge_respects_capacity() {
        let mut dev = Device::new("g", DeviceKind::GpuFast40);
        let plan = ShardPlan::new(GEMMA2_27B, 1);
        assert!(plan.charge(&mut dev).is_err()); // 59GB > 40GB
        let plan2 = ShardPlan::new(GEMMA2_27B, 4);
        let mut dev2 = Device::new("g2", DeviceKind::GpuFast40);
        assert!(plan2.charge(&mut dev2).is_ok());
        assert!(dev2.ledger.used() > 0);
    }

    #[test]
    fn fetch_overlap_reduces_cost() {
        let plan = ShardPlan::new(LLAMA2_13B, 4);
        assert!(plan.fetch_secs_per_pass(0.8)
                < plan.fetch_secs_per_pass(0.0));
    }

    #[test]
    fn assignment_is_contiguous_and_total() {
        for (n_layers, shards) in [(4usize, 1usize), (4, 2), (4, 3),
                                   (4, 4), (7, 3), (46, 8)] {
            let a = LayerAssignment::contiguous(n_layers, shards);
            assert_eq!(a.shards(), shards.min(n_layers));
            let mut covered = 0;
            for s in 0..a.shards() {
                let r = a.block_range(s);
                assert_eq!(r.start, covered, "gap before shard {s}");
                assert!(!r.is_empty(), "empty shard {s}");
                for l in r.clone() {
                    assert_eq!(a.shard_of_block(l), s);
                    assert_eq!(a.shard_of(LayerId::Qkv(l)), s);
                    assert_eq!(a.shard_of(LayerId::MlpDown(l)), s);
                }
                covered = r.end;
            }
            assert_eq!(covered, n_layers);
            assert_eq!(a.shard_of(LayerId::Embed), 0);
            assert_eq!(a.shard_of(LayerId::LmHead), a.shards() - 1);
        }
    }

    #[test]
    fn capacity_weighted_matches_contiguous_on_equal_weights() {
        for (n_layers, shards) in [(4usize, 1usize), (4, 2), (4, 3),
                                   (4, 4), (7, 3), (46, 8)] {
            let a =
                LayerAssignment::capacity_weighted(n_layers,
                                                   &vec![1.0; shards]);
            assert_eq!(a, LayerAssignment::contiguous(n_layers, shards),
                       "equal weights must not disturb homogeneous \
                        fleets ({n_layers} layers / {shards} shards)");
        }
    }

    #[test]
    fn capacity_weighted_favors_fast_shards_and_stays_total() {
        // Fig 18's fast/slow split: 3.5x flops should take ~3.5x blocks.
        let a = LayerAssignment::capacity_weighted(4, &[3.5, 1.0]);
        assert_eq!(a.block_range(0), 0..3);
        assert_eq!(a.block_range(1), 3..4);
        // Larger fleet: contiguity + totality + min-1-block floor hold
        // for arbitrary weights, and block counts are monotone in weight.
        let weights = [8.0, 1.0, 4.0, 0.5];
        let a = LayerAssignment::capacity_weighted(46, &weights);
        assert_eq!(a.shards(), 4);
        let mut covered = 0;
        let mut counts = Vec::new();
        for s in 0..a.shards() {
            let r = a.block_range(s);
            assert_eq!(r.start, covered, "gap before shard {s}");
            assert!(!r.is_empty(), "empty shard {s}");
            counts.push(r.len());
            covered = r.end;
        }
        assert_eq!(covered, 46);
        assert!(counts[0] > counts[2], "8x weight beat by 4x: {counts:?}");
        assert!(counts[2] > counts[1], "4x weight beat by 1x: {counts:?}");
        assert!(counts[1] >= counts[3], "1x weight beat by 0.5x: \
                                         {counts:?}");
        // Degenerate weights fall back to the contiguous split.
        assert_eq!(LayerAssignment::capacity_weighted(4, &[0.0, 0.0]),
                   LayerAssignment::contiguous(4, 2));
        // More shards than layers clamps like contiguous does.
        assert_eq!(LayerAssignment::capacity_weighted(2, &[1.0; 5])
                       .shards(),
                   2);
    }

    #[test]
    fn plan_yields_its_assignment() {
        let plan = ShardPlan::new(GEMMA2_27B, 4);
        let a = plan.layer_assignment();
        assert_eq!(a.shards(), 4);
        assert_eq!(a.n_layers(), GEMMA2_27B.n_layers);
        // boundary layers ride with the boundary shards
        assert_eq!(a.shard_of(LayerId::Embed), 0);
        assert_eq!(a.shard_of(LayerId::LmHead), 3);
    }
}
