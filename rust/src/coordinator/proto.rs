//! Wire protocol between clients and the executor fleet.
//!
//! A client's `VirtLayer` proxy packages each base-layer invocation as an
//! [`ExecMsg::Request`] and routes it to the shard executor owning that
//! layer (see [`LayerId::block`], the shard-routing key); the shard
//! batches compatible requests (same layer + direction), executes the
//! AOT artifact, splits the result and answers over the per-request
//! response channel — the paper's split-execution handshake
//! (section 3.2) over the sharded base of section 3.3.
//!
//! The protocol is inherently split-phase: a request carries its own
//! response channel, so a client may hold several requests in flight
//! (one per pipelined prefill micro-batch — see
//! `VirtLayerCtx::dispatch`) and collect them in any order.  Requests
//! sent over one channel arrive in send order; responses come whenever
//! the owning shard flushes the batch that served them.

#![deny(clippy::unwrap_used)]

use std::sync::mpsc::Sender;

use crate::tensor::Tensor;

/// Identity of one base-model layer instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum LayerId {
    /// Token + position embedding lookup.
    Embed,
    /// Fused QKV projection of block `l`.
    Qkv(usize),
    /// Attention output projection of block `l`.
    AttnOut(usize),
    /// MLP up-projection of block `l`.
    MlpUp(usize),
    /// MLP down-projection of block `l`.
    MlpDown(usize),
    /// Final LM head.
    LmHead,
}

impl LayerId {
    /// Stable dense index for per-layer stats tables.
    pub fn index(&self, n_layers: usize) -> usize {
        match *self {
            LayerId::Embed => 0,
            LayerId::Qkv(l) => 1 + l * 4,
            LayerId::AttnOut(l) => 2 + l * 4,
            LayerId::MlpUp(l) => 3 + l * 4,
            LayerId::MlpDown(l) => 4 + l * 4,
            LayerId::LmHead => 1 + n_layers * 4,
        }
    }

    /// Total number of distinct base layers for a block count.
    pub fn count(n_layers: usize) -> usize {
        2 + n_layers * 4
    }

    /// The transformer block this layer belongs to — the shard-routing
    /// key.  `None` for the boundary layers: the embedding rides with
    /// the shard owning block 0, the LM head with the shard owning the
    /// last block (see `sharding::LayerAssignment`).
    pub fn block(&self) -> Option<usize> {
        match *self {
            LayerId::Qkv(l)
            | LayerId::AttnOut(l)
            | LayerId::MlpUp(l)
            | LayerId::MlpDown(l) => Some(l),
            LayerId::Embed | LayerId::LmHead => None,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            LayerId::Embed => "embed".into(),
            LayerId::Qkv(l) => format!("l{l}.qkv"),
            LayerId::AttnOut(l) => format!("l{l}.attn_out"),
            LayerId::MlpUp(l) => format!("l{l}.mlp_up"),
            LayerId::MlpDown(l) => format!("l{l}.mlp_down"),
            LayerId::LmHead => "lm_head".into(),
        }
    }
}

/// Direction of a base-layer invocation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum OpKind {
    Forward,
    /// Memory-optimized backward: `dX = dY . W^T`, recomputed from frozen
    /// parameters — no stored activations (paper section 3.6).
    Backward,
}

/// Latency class of a request — drives the opportunistic-batching wait
/// budget (paper section 3.7: "we base the wait time on the size of
/// request").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Urgency {
    /// Single-token decode for an interactive request: minimal wait.
    Interactive,
    /// Prefill or large inference batch: can afford a bounded wait.
    Bulk,
    /// Fine-tuning pass: longest wait budget.
    Training,
    /// Deferrable background work (e.g. best-effort training steps):
    /// same wait budget as `Training`, but first to be **shed** when a
    /// shard's ingress queue is at its high-water mark — the executor
    /// answers with a typed shed error instead of occupying the device
    /// ahead of interactive decode (graceful brown-out).
    Background,
}

/// Wire marker prefixing the `Err` payload of a [`LayerResponse`]
/// answered by the executor's load shedder.  `VirtLayerCtx` maps it to
/// `SymbiosisError::WorkShed` (deferred, not retried) instead of the
/// `ExecutorFailed` every other `Err` payload becomes.
pub const SHED_MARKER: &str = "__shed__: ";

/// One base-layer invocation from a client.
#[derive(Debug)]
pub struct LayerRequest {
    pub client_id: usize,
    pub layer: LayerId,
    pub op: OpKind,
    /// Token-flattened activation rows: (T_i, Din) f32 — or, for
    /// `LayerId::Embed`, token ids (T_i,) i32.
    pub x: Tensor,
    /// Positions (T_i,) i32 — only for `Embed`.
    pub positions: Option<Tensor>,
    pub urgency: Urgency,
    pub resp: Sender<LayerResponse>,
}

/// Executor's answer: the per-client slice of the batched output, or a
/// typed failure.  A failed flush answers every co-batched request with
/// `Err(message)` instead of dropping the senders, so clients surface a
/// `SymbiosisError::ExecutorFailed` rather than a bare channel
/// disconnect.
#[derive(Debug)]
pub struct LayerResponse {
    pub y: Result<Tensor, String>,
    /// How long the request waited in the batching queue (for Fig 7 /
    /// Table 5 reproductions).
    pub queue_wait_secs: f64,
    /// Number of co-batched clients in the flush that served this
    /// request.
    pub batch_clients: usize,
}

/// Messages accepted by a shard-executor thread.
#[derive(Debug)]
pub enum ExecMsg {
    /// A client joins (lockstep policies count registered clients).
    Register { client_id: usize },
    /// A client leaves.
    Deregister { client_id: usize },
    Request(LayerRequest),
    /// Privacy protocol (paper section 3.8): compute the noise effect
    /// `n_eff = W . n` (bias-free flow) for a client-chosen noise tensor.
    /// The executor sees the noise value but never the true activations.
    RegisterNoise {
        layer: LayerId,
        noise: Tensor,
        resp: Sender<LayerResponse>,
    },
    /// Drain and stop.
    Shutdown,
    /// Simulated hard crash (fault injection): the executor thread
    /// returns *immediately* without draining its queue — pending
    /// response senders drop, exactly as if the thread had panicked.
    /// The fleet watchdog observes the finished join handle and
    /// respawns the shard.
    Crash,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_indices_are_dense_and_unique() {
        let n = 4;
        let mut seen = vec![false; LayerId::count(n)];
        let mut ids = vec![LayerId::Embed, LayerId::LmHead];
        for l in 0..n {
            ids.extend([LayerId::Qkv(l), LayerId::AttnOut(l),
                        LayerId::MlpUp(l), LayerId::MlpDown(l)]);
        }
        for id in ids {
            let i = id.index(n);
            assert!(!seen[i], "collision at {i} for {id:?}");
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
