//! Continuous-batching serving engine — Orca-style iteration-level
//! scheduling over the shard fleet.
//!
//! The sequential [`InferenceSession::generate`] loop drives one
//! request at a time: its decode only overlaps another client's by
//! scheduling luck.  The [`ServingEngine`] makes the overlap
//! deliberate: it owns a pool of decode *slots* and advances every
//! occupied slot as one wavefront per [`ServingEngine::step`] — while
//! session A's walk blocks collecting a layer response from shard 1,
//! session B's request is already queued at shard 0.  Each step:
//!
//! 1. **yield** — under pressure (requests queued, no free slot, or an
//!    overloaded shard) the first `Urgency::Background` slot is
//!    evicted so foreground work can land;
//! 2. **admit** — up to `admit_per_step` queued requests fill free
//!    slots, each passing tenant admission
//!    ([`SymbiosisError::AdmissionDenied`] /
//!    [`SymbiosisError::QuotaExceeded`] surface as typed terminal
//!    states on the request's handle); admission throttles to zero
//!    while any shard is dead, breaker-open, or ingress-saturated
//!    ([`ExecutorFleet::shard_loads`]) — backing off instead of
//!    dogpiling a struggling fleet;
//! 3. **iterate** — every participating slot advances one token step:
//!    prefilling sessions run one `prefill_chunk` micro-batch,
//!    decoding sessions one token column, all interleaved in a single
//!    split-phase wavefront ([`InferenceSession::advance_walk`]);
//! 4. **retire** — finished/failed sessions free their slot, KV ledger
//!    charge, and tenant quota (RAII on session drop); their handles
//!    flip to a terminal status.
//!
//! Per-session output is **bit-identical** to sequential `generate`:
//! both paths run the same walk math and the same [`GenState`] token
//! selection, and the executor batches concurrent wavefront requests
//! output-identically (the repo-wide batching-equivalence premise).
//! `tests/serving.rs` pins this across shard counts and adapter kinds.
//!
//! Pair the engine with [`BatchPolicy::Continuous`]
//! (`crate::coordinator::BatchPolicy`): the executor then flushes per
//! iteration — exactly the wavefront's dispatches — instead of waiting
//! on a registration cohort.

#![deny(clippy::unwrap_used)]

use std::collections::VecDeque;
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

use crate::coordinator::adapter::Adapter;
use crate::coordinator::client::{GenState, GenerationConfig,
                                 InferenceSession, StepWalk,
                                 UrgencyPolicy};
use crate::coordinator::kv_cache::KvPlacement;
use crate::coordinator::proto::Urgency;
use crate::coordinator::virt_layer::RetryPolicy;
use crate::coordinator::Deployment;
use crate::error::{SymResult, SymbiosisError};
use crate::metrics::LatencyStats;
use crate::tensor::Tensor;

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

// ---------------------------------------------------------------------------
// Requests and handles
// ---------------------------------------------------------------------------

/// One serving request: a prompt plus the per-tenant session choices
/// the scheduler forwards to [`SessionBuilder`] at admission time.
///
/// [`SessionBuilder`]: crate::coordinator::SessionBuilder
#[derive(Debug, Clone)]
pub struct ServingRequest {
    pub prompt: Vec<i32>,
    pub cfg: GenerationConfig,
    pub adapter: Option<Adapter>,
    /// Tenant name for admission control (`None` bypasses quotas).
    pub tenant: Option<String>,
    pub urgency: UrgencyPolicy,
    pub batch: usize,
    pub kv: KvPlacement,
}

impl ServingRequest {
    pub fn new(prompt: Vec<i32>, cfg: GenerationConfig) -> Self {
        ServingRequest {
            prompt,
            cfg,
            adapter: None,
            tenant: None,
            urgency: UrgencyPolicy::default(),
            batch: 1,
            kv: KvPlacement::Device,
        }
    }

    pub fn adapter(mut self, a: Adapter) -> Self {
        self.adapter = Some(a);
        self
    }

    pub fn tenant(mut self, name: &str) -> Self {
        self.tenant = Some(name.to_string());
        self
    }

    pub fn urgency(mut self, policy: UrgencyPolicy) -> Self {
        self.urgency = policy;
        self
    }

    /// Mark the whole request `Urgency::Background`: first to yield its
    /// slot under pressure, sheddable at saturated shards.
    pub fn background(mut self) -> Self {
        self.urgency = UrgencyPolicy {
            prefill: Urgency::Background,
            decode: Urgency::Background,
        };
        self
    }

    pub fn batch(mut self, batch: usize) -> Self {
        self.batch = batch;
        self
    }

    pub fn kv(mut self, placement: KvPlacement) -> Self {
        self.kv = placement;
        self
    }

    fn is_background(&self) -> bool {
        self.urgency.decode == Urgency::Background
    }
}

/// Where a request currently is in its lifecycle.  `Finished`,
/// `Denied`, `Evicted`, and `Failed` are terminal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum HandleStatus {
    /// Waiting for a free slot.
    Queued,
    /// In a slot, prompt chunks still flowing.
    Prefilling,
    /// In a slot, emitting tokens.
    Decoding,
    /// Completed normally; all tokens are on the handle.
    Finished,
    /// Admission denied (tenant quota) — see [`SessionHandle::take_error`].
    Denied,
    /// A background session that yielded its slot under pressure.
    Evicted,
    /// The session's walk failed terminally (retry budget exhausted).
    Failed,
}

impl HandleStatus {
    pub fn is_terminal(self) -> bool {
        matches!(self,
                 HandleStatus::Finished | HandleStatus::Denied
                 | HandleStatus::Evicted | HandleStatus::Failed)
    }
}

struct HandleInner {
    status: HandleStatus,
    /// Tokens streamed so far, per sequence (this request only).
    tokens: Vec<Vec<i32>>,
    /// `poll` cursor per sequence.
    polled: Vec<usize>,
    error: Option<SymbiosisError>,
}

/// The caller's view of a submitted request: cheap to clone, safe to
/// poll from another thread.  Tokens stream onto it as the scheduler's
/// iterations emit them.
#[derive(Clone)]
pub struct SessionHandle {
    inner: Arc<Mutex<HandleInner>>,
}

impl SessionHandle {
    fn new(batch: usize) -> Self {
        SessionHandle {
            inner: Arc::new(Mutex::new(HandleInner {
                status: HandleStatus::Queued,
                tokens: vec![Vec::new(); batch],
                polled: vec![0; batch],
                error: None,
            })),
        }
    }

    pub fn status(&self) -> HandleStatus {
        lock(&self.inner).status
    }

    pub fn is_done(&self) -> bool {
        self.status().is_terminal()
    }

    /// Tokens emitted since the last `poll`, per sequence (the
    /// streaming interface).
    pub fn poll(&self) -> Vec<Vec<i32>> {
        let mut h = lock(&self.inner);
        let mut fresh = Vec::with_capacity(h.tokens.len());
        for b in 0..h.tokens.len() {
            let from = h.polled[b];
            fresh.push(h.tokens[b][from..].to_vec());
            h.polled[b] = h.tokens[b].len();
        }
        fresh
    }

    /// Everything emitted so far, per sequence (does not move the
    /// `poll` cursor).
    pub fn tokens(&self) -> Vec<Vec<i32>> {
        lock(&self.inner).tokens.clone()
    }

    /// The typed error behind a `Denied`/`Failed` status, if any.
    /// Consumes it (errors are not `Clone`).
    pub fn take_error(&self) -> Option<SymbiosisError> {
        lock(&self.inner).error.take()
    }
}

// ---------------------------------------------------------------------------
// Engine internals
// ---------------------------------------------------------------------------

struct Queued {
    req: ServingRequest,
    handle: SessionHandle,
    submitted: Instant,
}

enum Phase {
    /// Prompt chunks still flowing; `next_col` is the first unprocessed
    /// prompt column.
    Prefill,
    Decode,
}

/// One occupied decode slot.
struct Slot {
    sess: InferenceSession,
    gen: GenState,
    phase: Phase,
    prompt: Vec<i32>,
    /// Prompt columns per sequence.
    s_cols: usize,
    /// Resolved prefill micro-batch size (columns per iteration).
    chunk: usize,
    next_col: usize,
    /// Chunk bounds of the in-flight iteration (set while its walk
    /// runs, consumed at completion).
    cur: Option<(usize, usize)>,
    handle: SessionHandle,
    background: bool,
    /// Set when the slot was demoted under pressure (KV parked on the
    /// host, excluded from the wavefront).  Sticky while the pressure
    /// lasts: a still-yielded background slot is the eviction candidate
    /// on the next strike, which bounds demote→resume churn.  Cleared
    /// when the pressure passes.
    yielded: bool,
    submitted: Instant,
    last_token_at: Option<Instant>,
    /// Streaming cursor into `sess.generated`, per sequence.
    streamed: Vec<usize>,
}

impl Slot {
    /// Push everything newly recorded on the session out to the handle.
    fn stream_tokens(&mut self) {
        let mut h = lock(&self.handle.inner);
        for (b, g) in self.sess.generated.iter().enumerate() {
            while self.streamed[b] < g.len() {
                h.tokens[b].push(g[self.streamed[b]]);
                self.streamed[b] += 1;
            }
        }
    }
}

/// Aggregated serving metrics; snapshot via [`ServingEngine::report`].
#[derive(Debug, Default, Clone)]
pub struct ServingReport {
    pub steps: u64,
    /// Steps during which admission was throttled because some shard
    /// was overloaded (dead / breaker-open / saturated).
    pub throttled_steps: u64,
    pub submitted: u64,
    pub admitted: u64,
    pub denied: u64,
    pub evicted: u64,
    /// Background sessions demoted under pressure: their KV blocks
    /// swapped to the host and their slot sat out the wavefront, so a
    /// later admission could take the device memory without the
    /// session losing its work (it faults back in when resumed).
    pub demoted: u64,
    pub completed: u64,
    pub failed: u64,
    pub tokens_emitted: u64,
    /// Peak concurrently occupied slots.
    pub max_active: usize,
    /// Time-to-first-token: submit → prefill token on the handle.
    pub ttft: LatencyStats,
    /// Inter-token latency between successive decode emissions of one
    /// session.
    pub itl: LatencyStats,
}

impl std::fmt::Display for ServingReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "serving: {} submitted / {} admitted / {} completed \
             ({} denied, {} demoted, {} evicted, {} failed) over \
             {} step(s), peak {} active",
            self.submitted, self.admitted, self.completed, self.denied,
            self.demoted, self.evicted, self.failed, self.steps,
            self.max_active)?;
        writeln!(
            f,
            "  ttft  p50 {:.3} ms  p90 {:.3} ms  p99 {:.3} ms  (n={})",
            self.ttft.p50() * 1e3, self.ttft.percentile(90.0) * 1e3,
            self.ttft.p99() * 1e3, self.ttft.count())?;
        writeln!(
            f,
            "  itl   p50 {:.3} ms  p90 {:.3} ms  p99 {:.3} ms  (n={})",
            self.itl.p50() * 1e3, self.itl.percentile(90.0) * 1e3,
            self.itl.p99() * 1e3, self.itl.count())?;
        write!(f, "  {} token(s) emitted, {} throttled step(s)",
               self.tokens_emitted, self.throttled_steps)
    }
}

// ---------------------------------------------------------------------------
// Builder
// ---------------------------------------------------------------------------

/// Configures a [`ServingEngine`] against a deployment
/// ([`Deployment::serving`]).
pub struct ServingBuilder<'d> {
    dep: &'d Deployment,
    slots: usize,
    prefill_chunk: Option<usize>,
    admit_per_step: usize,
    max_wavefront: usize,
    request_timeout: Option<Duration>,
    retry: Option<RetryPolicy>,
}

impl<'d> ServingBuilder<'d> {
    pub(crate) fn new(dep: &'d Deployment) -> Self {
        ServingBuilder {
            dep,
            slots: 8,
            prefill_chunk: None,
            admit_per_step: 4,
            max_wavefront: usize::MAX,
            request_timeout: None,
            retry: None,
        }
    }

    /// Decode-slot pool size — the max sessions in flight (default 8).
    pub fn slots(mut self, n: usize) -> Self {
        self.slots = n.max(1);
        self
    }

    /// Engine-default prefill micro-batch size in token columns
    /// (default: the whole prompt in one chunk).  Per-request
    /// [`GenerationConfig::prefill_chunk`] overrides it.  Smaller
    /// chunks bound how long one admission's prefill can delay the
    /// in-flight decodes' next iteration.
    pub fn prefill_chunk(mut self, cols: usize) -> Self {
        self.prefill_chunk = Some(cols.max(1));
        self
    }

    /// Max sessions admitted per scheduler step (default 4) — bounds
    /// per-iteration registration work.
    pub fn admit_per_step(mut self, n: usize) -> Self {
        self.admit_per_step = n.max(1);
        self
    }

    /// Cap how many sessions join one iteration's token step (default:
    /// every occupied slot).  With a cap, participation rotates
    /// round-robin so every session keeps making progress.
    pub fn max_wavefront(mut self, n: usize) -> Self {
        self.max_wavefront = n.max(1);
        self
    }

    /// Per-collect deadline forwarded to every session
    /// ([`SessionBuilder::request_timeout`]).
    ///
    /// [`SessionBuilder::request_timeout`]:
    /// crate::coordinator::SessionBuilder::request_timeout
    pub fn request_timeout(mut self, timeout: Duration) -> Self {
        self.request_timeout = Some(timeout);
        self
    }

    /// Bounded retry forwarded to every session
    /// ([`SessionBuilder::retry`]) — with this set, a shard killed
    /// mid-iteration is retried transparently inside the walk once the
    /// watchdog respawns it.
    ///
    /// [`SessionBuilder::retry`]: crate::coordinator::SessionBuilder::retry
    pub fn retry(mut self, policy: RetryPolicy) -> Self {
        self.retry = Some(policy);
        self
    }

    pub fn build(self) -> ServingEngine<'d> {
        let mut slots = Vec::with_capacity(self.slots);
        slots.resize_with(self.slots, || None);
        ServingEngine {
            dep: self.dep,
            slots,
            queue: VecDeque::new(),
            prefill_chunk: self.prefill_chunk,
            admit_per_step: self.admit_per_step,
            max_wavefront: self.max_wavefront,
            request_timeout: self.request_timeout,
            retry: self.retry,
            rr: 0,
            metrics: ServingReport::default(),
        }
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

/// The fleet-level continuous-batching engine.  Single-threaded by
/// design: the caller (or the load generator) pumps
/// [`ServingEngine::step`]; handles are the thread-safe surface.
pub struct ServingEngine<'d> {
    dep: &'d Deployment,
    slots: Vec<Option<Slot>>,
    queue: VecDeque<Queued>,
    prefill_chunk: Option<usize>,
    admit_per_step: usize,
    max_wavefront: usize,
    request_timeout: Option<Duration>,
    retry: Option<RetryPolicy>,
    /// Round-robin cursor for capped-wavefront fairness.
    rr: usize,
    metrics: ServingReport,
}

impl<'d> ServingEngine<'d> {
    /// Enqueue a request; returns its handle immediately.  The request
    /// starts once [`Self::step`] admits it into a slot.
    pub fn submit(&mut self, req: ServingRequest) -> SessionHandle {
        let handle = SessionHandle::new(req.batch.max(1));
        self.metrics.submitted += 1;
        self.queue.push_back(Queued {
            req,
            handle: handle.clone(),
            submitted: Instant::now(),
        });
        handle
    }

    pub fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn report(&self) -> ServingReport {
        self.metrics.clone()
    }

    /// One scheduler iteration: yield → admit → iterate → retire.
    /// Returns the number of sessions that took part in the token step.
    pub fn step(&mut self) -> SymResult<usize> {
        self.metrics.steps += 1;
        let loads = self.dep.executor.shard_loads();
        let overloaded = loads.iter().any(|l| l.overloaded());

        // 1. Background yields under pressure: a queued foreground
        // request with no free slot (or an overloaded fleet) bumps a
        // background slot — in two strikes.  First strike *demotes*:
        // the session's KV blocks swap to the host and the slot sits
        // out the wavefront, so the foreground request gets the device
        // memory while the background session keeps its work (blocks
        // fault back in when it resumes).  Second strike — pressure
        // still on and every background slot already yielded — evicts.
        let fg_waiting =
            self.queue.iter().any(|q| !q.req.is_background());
        let free = self.slots.iter().filter(|s| s.is_none()).count();
        if fg_waiting && (free == 0 || overloaded) {
            let fresh = self.slots.iter().position(
                |s| s.as_ref()
                    .is_some_and(|s| s.background && !s.yielded));
            match fresh {
                Some(i) => {
                    let slot = self.slot_mut(i);
                    slot.yielded = true;
                    // A cache with nothing swappable (host-placed, or
                    // the host ledger is full) just parks; the sticky
                    // flag still makes it next in line to evict.
                    if matches!(slot.sess.demote_kv(), Ok(n) if n > 0) {
                        self.metrics.demoted += 1;
                    }
                }
                None => {
                    if let Some(i) = self.slots.iter().position(
                        |s| s.as_ref().is_some_and(|s| s.background)) {
                        self.evict(i);
                    }
                }
            }
        } else {
            // Pressure passed: resume parked background sessions (their
            // blocks fault back in on the next touch).
            for slot in self.slots.iter_mut().flatten() {
                slot.yielded = false;
            }
        }

        // 2. Admission — throttled to zero while any shard is
        // overloaded: the breaker/saturation recovers fastest when the
        // scheduler stops feeding it new sessions.
        if overloaded {
            self.metrics.throttled_steps += 1;
        } else {
            let mut admitted = 0;
            while admitted < self.admit_per_step {
                let Some(free) =
                    self.slots.iter().position(|s| s.is_none())
                else { break };
                let Some(q) = self.queue.pop_front() else { break };
                if self.admit(free, q) {
                    admitted += 1;
                }
            }
        }
        self.metrics.max_active =
            self.metrics.max_active.max(self.active());

        // 3. The iteration wavefront: pick participants, drive every
        // walk to completion round-robin.
        let ids = self.wavefront(overloaded);
        if ids.is_empty() {
            return Ok(0);
        }
        // The pending requests must borrow something that outlives the
        // per-advance `&mut` slot borrows: clone each session's virt
        // handle out first.
        let virts: Vec<_> = ids
            .iter()
            .map(|&i| {
                self.slot_ref(i).sess.core.virt.clone()
            })
            .collect();
        let mut walks: Vec<StepWalk<'_>> = Vec::with_capacity(ids.len());
        for &i in &ids {
            let chunk = {
                let slot = self.slot_mut(i);
                match slot.phase {
                    Phase::Decode => None,
                    Phase::Prefill => {
                        let c0 = slot.next_col;
                        let c1 = (c0 + slot.chunk).min(slot.s_cols);
                        slot.cur = Some((c0, c1));
                        Some((c0, c1))
                    }
                }
            };
            walks.push(match chunk {
                Some((c0, c1)) => StepWalk::chunk(c0, c1),
                None => StepWalk::decode(),
            });
        }
        let mut fails: Vec<Option<SymbiosisError>> =
            Vec::with_capacity(ids.len());
        fails.resize_with(ids.len(), || None);
        loop {
            let mut pending = false;
            for (k, &i) in ids.iter().enumerate() {
                if walks[k].is_done() || fails[k].is_some() {
                    continue;
                }
                // Split borrow: the walk advances against the slot's
                // session while the pending request borrows `virts`.
                let slot = self.slots[i]
                    .as_mut()
                    .expect("wavefront ids index occupied slots");
                match slot.sess.advance_walk(&mut walks[k], &virts[k],
                                             &slot.prompt) {
                    Ok(in_flight) => pending |= in_flight,
                    Err(e) => fails[k] = Some(SymbiosisError::from(e)),
                }
            }
            if !pending {
                break;
            }
        }
        let mut outcomes: Vec<Option<Tensor>> =
            Vec::with_capacity(ids.len());
        for (k, w) in walks.into_iter().enumerate() {
            if fails[k].is_some() {
                outcomes.push(None);
                continue;
            }
            match w.take_logits() {
                Ok(t) => outcomes.push(Some(t)),
                Err(e) => {
                    fails[k] = Some(SymbiosisError::from(e));
                    outcomes.push(None);
                }
            }
        }

        // 4. Apply outcomes and retire.
        let stepped = ids.len();
        for (k, &i) in ids.iter().enumerate() {
            if let Some(e) = fails[k].take() {
                self.retire(i, HandleStatus::Failed, Some(e));
                continue;
            }
            let logits = outcomes[k]
                .take()
                .expect("non-failed walk produced logits");
            self.complete_iteration(i, &logits);
        }
        Ok(stepped)
    }

    /// Pump [`Self::step`] until the queue is empty and every slot is
    /// free.  Errors if the engine makes no progress for a prolonged
    /// stretch (e.g. admission throttled forever by a breaker that
    /// never recovers).
    pub fn run(&mut self) -> SymResult<ServingReport> {
        let mut stalled = 0u32;
        while !self.queue.is_empty() || self.active() > 0 {
            let before = (self.queue.len(), self.active());
            let stepped = self.step()?;
            let progressed = stepped > 0
                || (self.queue.len(), self.active()) != before;
            if progressed {
                stalled = 0;
            } else {
                stalled += 1;
                if stalled > 20_000 {
                    return Err(SymbiosisError::Runtime(anyhow::anyhow!(
                        "serving engine stalled: {} queued, {} active, \
                         admission throttled and nothing advancing",
                        self.queue.len(), self.active())));
                }
                // Give the watchdog/breaker a chance to recover.
                std::thread::sleep(Duration::from_micros(200));
            }
        }
        Ok(self.report())
    }

    // -- internals ---------------------------------------------------------

    fn slot_ref(&self, i: usize) -> &Slot {
        self.slots[i].as_ref().expect("index names an occupied slot")
    }

    fn slot_mut(&mut self, i: usize) -> &mut Slot {
        self.slots[i].as_mut().expect("index names an occupied slot")
    }

    /// Participants of this iteration, in fairness order: foreground
    /// slots first (round-robin rotated), background slots last — and
    /// excluded entirely while any shard is overloaded (they are the
    /// first to yield device time, before their slots are taken).
    fn wavefront(&mut self, overloaded: bool) -> Vec<usize> {
        let occupied: Vec<usize> = (0..self.slots.len())
            .filter(|&i| self.slots[i].is_some())
            .collect();
        let n = occupied.len();
        if n == 0 {
            return Vec::new();
        }
        let rot = self.rr % n;
        self.rr = self.rr.wrapping_add(1);
        let mut fg = Vec::with_capacity(n);
        let mut bg = Vec::new();
        for off in 0..n {
            let i = occupied[(rot + off) % n];
            let s = self.slot_ref(i);
            if s.yielded {
                // Demoted under pressure: parked off the wavefront
                // (its KV is on the host) until the pressure passes.
                continue;
            }
            if s.background {
                bg.push(i);
            } else {
                fg.push(i);
            }
        }
        if !overloaded {
            fg.extend(bg);
        }
        fg.truncate(self.max_wavefront);
        fg
    }

    /// Build the session for a queued request and place it in slot
    /// `free`.  Admission failures (tenant quotas) mark the handle
    /// `Denied` with the typed error; other build failures mark it
    /// `Failed`.  Returns whether the slot was filled.
    fn admit(&mut self, free: usize, q: Queued) -> bool {
        let Queued { req, handle, submitted } = q;
        let background = req.is_background();
        let mut b = self.dep
            .session()
            .batch(req.batch.max(1))
            .kv(req.kv)
            .urgency(req.urgency);
        if let Some(a) = req.adapter {
            b = b.adapter(a);
        }
        if let Some(t) = &req.tenant {
            b = b.tenant(t);
        }
        if let Some(d) = self.request_timeout {
            b = b.request_timeout(d);
        }
        if let Some(r) = self.retry {
            b = b.retry(r);
        }
        let mut sess = match b.build() {
            Ok(s) => s,
            Err(e) => {
                let status = match &e {
                    SymbiosisError::AdmissionDenied { .. }
                    | SymbiosisError::QuotaExceeded { .. } => {
                        self.metrics.denied += 1;
                        HandleStatus::Denied
                    }
                    _ => {
                        self.metrics.failed += 1;
                        HandleStatus::Failed
                    }
                };
                let mut h = lock(&handle.inner);
                h.status = status;
                h.error = Some(e);
                return false;
            }
        };
        let gen = match sess.begin_generate(&req.cfg) {
            Ok(g) => g,
            Err(e) => {
                self.metrics.failed += 1;
                let mut h = lock(&handle.inner);
                h.status = HandleStatus::Failed;
                h.error = Some(e);
                return false;
            }
        };
        if let Err(e) = sess.check_prompt(&req.prompt) {
            self.metrics.failed += 1;
            let mut h = lock(&handle.inner);
            h.status = HandleStatus::Failed;
            h.error = Some(e);
            return false;
        }
        let batch = req.batch.max(1);
        let s_cols = req.prompt.len() / batch;
        // Chunk resolution: request > session default > engine default
        // > whole prompt in one go.
        let chunk = req.cfg.prefill_chunk
            .or_else(|| sess.session_prefill_chunk())
            .or(self.prefill_chunk)
            .unwrap_or(s_cols)
            .clamp(1, s_cols);
        lock(&handle.inner).status = HandleStatus::Prefilling;
        self.metrics.admitted += 1;
        self.slots[free] = Some(Slot {
            // Stream cursors start past anything already recorded
            // (prefix-seeded sessions), so the handle sees exactly this
            // request's tokens.
            streamed: (0..batch).map(|b| sess.generated[b].len())
                .collect(),
            sess,
            gen,
            phase: Phase::Prefill,
            prompt: req.prompt,
            s_cols,
            chunk,
            next_col: 0,
            cur: None,
            handle,
            background,
            yielded: false,
            submitted,
            last_token_at: None,
        });
        true
    }

    /// Fold one completed walk's logits into its slot: advance the
    /// prefill cursor or apply the decode selection, stream new tokens,
    /// retire the session when the request is finished.
    fn complete_iteration(&mut self, i: usize, logits: &Tensor) {
        let now = Instant::now();
        let mut finished = false;
        {
            // Index the field directly (not through `slot_mut`) so the
            // `self.slots` borrow splits from the `self.metrics` ones
            // below.
            let slot = self.slots[i]
                .as_mut()
                .expect("completed walk indexes an occupied slot");
            match slot.phase {
                Phase::Prefill => {
                    let (c0, c1) = slot.cur.take()
                        .unwrap_or((slot.next_col, slot.s_cols));
                    slot.next_col = c1;
                    if c1 < slot.s_cols {
                        // Mid-prompt chunk: its logits feed nothing
                        // (sequential prefill likewise samples only the
                        // final column's rows).
                        return;
                    }
                    let tc = c1 - c0;
                    slot.sess.pick_prefill(&mut slot.gen, logits, tc);
                    slot.phase = Phase::Decode;
                    lock(&slot.handle.inner).status =
                        HandleStatus::Decoding;
                    self.metrics
                        .ttft
                        .record(now.duration_since(slot.submitted));
                    slot.last_token_at = Some(now);
                    slot.stream_tokens();
                    self.metrics.tokens_emitted += 1;
                    finished = !slot.gen.running();
                }
                Phase::Decode => {
                    slot.sess.apply_decode_logits(&mut slot.gen, logits);
                    if let Some(prev) = slot.last_token_at {
                        self.metrics.itl.record(now.duration_since(prev));
                    }
                    slot.last_token_at = Some(now);
                    slot.stream_tokens();
                    self.metrics.tokens_emitted += 1;
                    finished = !slot.gen.running();
                }
            }
        }
        if finished {
            self.retire(i, HandleStatus::Finished, None);
        }
    }

    /// Evict a background session under pressure: stream what it has,
    /// mark the handle, free the slot (dropping the session releases
    /// its KV ledger charge and tenant ticket).
    fn evict(&mut self, i: usize) {
        self.metrics.evicted += 1;
        self.retire_inner(i, HandleStatus::Evicted, None);
    }

    fn retire(&mut self, i: usize, status: HandleStatus,
              error: Option<SymbiosisError>) {
        match status {
            HandleStatus::Finished => self.metrics.completed += 1,
            HandleStatus::Failed => self.metrics.failed += 1,
            _ => {}
        }
        self.retire_inner(i, status, error);
    }

    fn retire_inner(&mut self, i: usize, status: HandleStatus,
                    error: Option<SymbiosisError>) {
        if let Some(mut slot) = self.slots[i].take() {
            slot.stream_tokens();
            let mut h = lock(&slot.handle.inner);
            h.status = status;
            h.error = error;
            // `slot` (and its session) drops here: the executor
            // deregistration, KV ledger release, and tenant session
            // ticket all fire via RAII.
        }
    }
}
