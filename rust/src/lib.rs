//! # Symbiosis — multi-adapter inference and fine-tuning
//!
//! Reproduction of *"Symbiosis: Multi-Adapter Inference and Fine-Tuning"*
//! (Gupta et al., 2025). A shared, frozen **base model** is served by an
//! *executor fleet* — one shard thread per contiguous layer range
//! ([`coordinator::fleet`]); independent **clients** (inference or
//! fine-tuning) own their adapters, attention, KV cache, and optimizer
//! state, and invoke the owning shard per layer through a routed
//! [`coordinator::virt_layer`] proxy. See DESIGN.md for the
//! architecture and the experiment index.
//!
//! Layering:
//! * [`runtime`] — PJRT engine executing AOT-compiled JAX/Pallas HLO.
//! * [`coordinator`] — the paper's contribution: split execution,
//!   per-layer opportunistic batching, flexible placement, privacy.
//!   Its session-first API ([`coordinator::Deployment::session`] /
//!   [`coordinator::Deployment::trainer`]) is the public surface;
//!   failures are typed [`error::SymbiosisError`]s.
//! * [`device`] / [`transport`] — simulated heterogeneous fleet (memory
//!   ledger + cost model) standing in for the paper's 8xA100 testbed.
//! * [`baselines`] — dedicated-instance, lockstep (vLLM/mLoRA-like) and
//!   FSDP comparators used by the paper-figure benches.

pub mod baselines;
pub mod bench_harness;
pub mod config;
pub mod coordinator;
pub mod device;
pub mod error;
pub mod metrics;
pub mod runtime;
pub mod tensor;
pub mod transport;

pub use error::{SymResult, SymbiosisError};
