//! Paper-figure/table reproductions — one section per table AND figure
//! of the evaluation (see DESIGN.md section 6 for the index).
//!
//! Real-execution sections run the actual coordinator on `sym-tiny`
//! (CPU PJRT substrate); analytic sections use the device/link models
//! with the paper's model dims.  Absolute numbers differ from the
//! paper's A100 testbed by construction — the *shape* (who wins, by what
//! factor, where crossovers fall) is the reproduction target, and each
//! section prints the paper's claim next to the measured result.
//!
//! Run all:        cargo bench
//! Run one:        cargo bench -- fig11

use std::path::PathBuf;
use std::time::Instant;

use symbiosis::baselines::{dedicated, fsdp::FsdpTrainer,
                           lockstep::{independent_latency,
                                      vllm_lockstep_latency, MloraMode}};
use symbiosis::config::{GEMMA2_27B, GPT2_XL, GRANITE_20B, LLAMA2_13B,
                        LLAMA2_7B, LLAMA3_1B, STARCODER_15B, SYM_TINY};
use symbiosis::coordinator::adapter::{lora_table2, LoraTargets};
use symbiosis::coordinator::placement::IterationModel;
use symbiosis::coordinator::sharding::ShardPlan;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             GenerationConfig, Placement};
use symbiosis::device::{Device, DeviceKind, GIB};
use symbiosis::metrics::{gib, LatencyStats};
use symbiosis::transport::LinkKind;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

use std::sync::Arc;
use std::sync::OnceLock;

/// One engine (compile cache) shared by every section — mirrors a real
/// cluster keeping compiled executables across coordinator restarts and
/// keeps lazy-compile time out of the measurements.
static ENGINE: OnceLock<Arc<symbiosis::runtime::Engine>> = OnceLock::new();

fn engine() -> Arc<symbiosis::runtime::Engine> {
    ENGINE
        .get_or_init(|| {
            Arc::new(symbiosis::runtime::Engine::new(&artifact_dir())
                .expect("engine"))
        })
        .clone()
}

fn deploy(policy: BatchPolicy) -> Deployment {
    Deployment::start_with_engine(engine(), &SYM_TINY, &artifact_dir(),
                                  policy, Placement::Local)
        .unwrap()
}

/// Write a `symbiosis-bench-v1` artifact twice: `target/<file>` (the
/// per-run CI upload) and `bench_results/<file>` (a stable, in-repo
/// path so the perf trajectory across PRs is machine-diffable with
/// plain `git diff`).
fn write_bench_artifact(file: &str,
                        doc: &symbiosis::bench_harness::JsonValue) {
    for dir in ["target", "bench_results"] {
        let d = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(dir);
        if std::fs::create_dir_all(&d).is_err() {
            continue;
        }
        let path = d.join(file);
        match std::fs::write(&path, doc.render()) {
            Ok(()) => println!("wrote {}", path.display()),
            Err(e) => println!("could not write {}: {e}",
                               path.display()),
        }
    }
}

/// Standardized "section did not run" artifact — written so CI's
/// artifact upload stays deterministic on runners without AOT
/// artifacts.
fn skipped_record(name: &str, quick: bool, reason: &str)
                  -> symbiosis::bench_harness::JsonValue {
    use symbiosis::bench_harness::JsonValue;
    symbiosis::bench_harness::bench_record(
        name, quick, vec![], vec![], vec![],
        vec![
            ("skipped", JsonValue::Bool(true)),
            ("reason", JsonValue::Str(reason.into())),
        ])
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let filter = args
        .iter()
        .skip(1)
        .find(|a| !a.starts_with("--"))
        .cloned()
        .unwrap_or_default();
    // `--bench-quick`: CI check mode — small prompt, one timed
    // iteration; every equality assertion still executes.
    let quick = args.iter().any(|a| a == "--bench-quick");
    let run = |name: &str| filter.is_empty() || name.contains(&filter);

    if run("fig01") { fig01_runtime_state(); }
    if run("tab02") { tab02_lora_configs(); }
    if run("fig07") { fig07_wait_time(); }
    if run("fig09") { fig09_memory_single(); }
    if run("fig10") { fig10_memory_multi(); }
    if run("fig11") { fig11_12_single_gpu(); }
    if run("fig13") { fig13_14_remote(); }
    if run("fig15") { fig15_16_sharded_local(); }
    if run("fig17") { fig17_sharded_remote(); }
    if run("fig18") { fig18_hetero_gpu(); }
    if run("fig19") { fig19_longcontext(); }
    if run("fig20") { fig20_cpu_multi(); }
    if run("fig21") { fig21_privacy(); }
    if run("fig22") { fig22_23_mixed(); }
    if run("tab04") { tab04_vllm_lockstep(); }
    if run("tab05") { tab05_policies(); }
    if run("ablation") { ablation_wait_budget(); }
    if run("dispatch") { dispatch_overhead(); }
    if run("fleet") { fleet_overhead(); }
    if run("pipeline") { pipeline_prefill(quick); }
    if run("chaos") { chaos_recovery(quick); }
    if run("overload") { overload_bench(quick); }
    if run("serving") { serving_load_gen(quick); }
    if run("kv") { kv_bench(quick); }
    if run("training") { training_bench(quick); }
    println!("\nall requested bench sections complete.");
}

// =========================================================================
// Dispatch overhead — host bytes copied + wall time per layer call, seed
// (deep-copy) dispatch vs the zero-copy hot path.  Pure host-tensor
// measurement: needs no artifacts, isolates exactly the copies the
// Arc-backed refactor removed (weight clones per execute, concat + pad
// copies per flush, per-request output copies).  Results are recorded in
// EXPERIMENTS.md §Dispatch overhead.
// =========================================================================
fn dispatch_overhead() {
    use symbiosis::config::bucket_for as bfor;
    use symbiosis::config::TOKEN_BUCKETS as TB;
    use symbiosis::tensor::Tensor;

    println!("\n== Dispatch overhead: bytes copied + wall time per layer \
              call (host path, d=1024, T=16 tokens/client) ==");
    let (din, dout) = (1024usize, 1024usize);
    let t_per_client = 16usize;
    let w = Tensor::from_f32(
        (0..din * dout).map(|i| (i % 97) as f32 * 1e-3).collect(),
        &[din, dout]);
    let b = Tensor::from_f32(vec![0.1; dout], &[dout]);
    w.device_pin(); // weights are device-resident in the new path
    b.device_pin();
    let deep = |t: &Tensor| Tensor::from_f32(t.as_f32().to_vec(), &t.shape);
    let iters = 50usize;
    println!("{:>9} {:>16} {:>16} {:>9} {:>12} {:>12}", "clients",
             "seed B/call", "zerocopy B/call", "ratio", "seed us",
             "zerocopy us");
    for n_clients in [1usize, 8, 32] {
        let xs: Vec<Tensor> = (0..n_clients)
            .map(|c| Tensor::from_f32(
                (0..t_per_client * din)
                    .map(|i| ((i + c) % 31) as f32 * 0.01)
                    .collect(),
                &[t_per_client, din]))
            .collect();
        let real = n_clients * t_per_client;
        let bucket = bfor(real, TB).expect("fits the largest bucket");

        // -- seed semantics: per flush, every input is deep-cloned into
        // the execute request (x_batch, W, b), after a concat copy and a
        // pad copy; outputs are sliced back out by copy.
        let t0 = Instant::now();
        let mut sink = 0.0f32;
        for _ in 0..iters {
            let parts: Vec<&Tensor> = xs.iter().collect();
            let flat = Tensor::concat_rows(&parts);
            let mut padded = flat.as_f32().to_vec(); // pad_rows copy
            padded.resize(bucket * din, 0.0);
            let x = Tensor::from_f32(padded, &[bucket, din]);
            let (xc, wc, bc) = (deep(&x), deep(&w), deep(&b)); // req clone
            sink += xc.as_f32()[0] + wc.as_f32()[0] + bc.as_f32()[0];
            // scatter by copy (seed split_rows)
            let mut row = 0;
            for xi in &xs {
                let t = xi.shape[0];
                let out = Tensor::from_f32(
                    xc.as_f32()[row * din..(row + t) * din].to_vec(),
                    &[t, din]);
                sink += out.as_f32()[0];
                row += t;
            }
        }
        let seed_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
        let seed_bytes = 4 * (real * din          // concat
            + bucket * din                         // pad
            + bucket * din + din * dout + dout     // request deep-clones
            + real * din);                         // scatter copies

        // -- zero-copy path: one single-pass assembly into a recycled
        // scratch buffer; weights + request ride as Arc views; scatter
        // is row views.
        let t0 = Instant::now();
        let mut scratch: Vec<f32> = Vec::new();
        for _ in 0..iters {
            let parts: Vec<&Tensor> = xs.iter().collect();
            let x = Tensor::assemble_rows(std::mem::take(&mut scratch),
                                          &parts, bucket);
            let (xc, wc, bc) = (x.clone(), w.clone(), b.clone()); // views
            sink += xc.as_f32()[0] + wc.as_f32()[0] + bc.as_f32()[0];
            for (i, xi) in xs.iter().enumerate() {
                let out = x.slice_rows(i * t_per_client,
                                       i * t_per_client + xi.shape[0]);
                sink += out.as_f32()[0];
            }
            drop((xc, wc, bc));
            if let Some(v) = x.try_into_f32_vec() {
                scratch = v;
            }
        }
        let zc_us = t0.elapsed().as_secs_f64() * 1e6 / iters as f64;
        let zc_bytes = 4 * bucket * din; // the one assembly pass

        // per layer call = per co-batched flush / clients in it
        let per = |total: usize| total / n_clients;
        println!("{:>9} {:>16} {:>16} {:>8.1}x {:>12.1} {:>12.1}",
                 n_clients, per(seed_bytes), per(zc_bytes),
                 seed_bytes as f64 / zc_bytes as f64, seed_us, zc_us);
        std::hint::black_box(sink);
    }
    println!("(bytes are exact copy counts of each path; the seed column \
              includes the per-execute weight clone that dominated \
              multi-client dispatch)");
}

// =========================================================================
// Fig 1 — runtime state vs sequence length (GPT2-XL, Llama2-7B,
// Granite-20B; rank-8 adapter, batch 2). Paper: runtime state reaches
// GBs, dwarfing the adapter.
// =========================================================================
fn fig01_runtime_state() {
    println!("\n== Fig 1: fine-tuning runtime state vs sequence length \
              (GiB, batch=2, rank-8 LoRA) ==");
    println!("{:>8} {:>12} {:>12} {:>12}", "seq", "gpt2-xl",
             "llama2-7b", "granite-20b");
    for seq in [512usize, 1024, 2048, 4096] {
        let state = |cfg: &symbiosis::config::ModelConfig| {
            gib(cfg.kv_cache_bytes(2, seq)
                + cfg.optimizer_bytes(8, 4)
                + dedicated::activation_bytes(cfg, 2, seq))
        };
        println!("{:>8} {:>12.2} {:>12.2} {:>12.2}", seq,
                 state(&GPT2_XL), state(&LLAMA2_7B), state(&GRANITE_20B));
    }
    println!("paper: GBs of runtime state, growing ~linearly with \
              sequence length; adapter itself is only 10s of MBs \
              (rank-8 qkvo on 7B = {:.2} GiB params).",
             gib(LLAMA2_7B.lora_params(8, 4) * 4));
}

// =========================================================================
// Table 2 — fine-tuning iteration latency for LoRA1..4 (real run).
// Paper (Llama2-13B): more fine-tuned layers cost more than higher rank.
// =========================================================================
fn tab02_lora_configs() {
    println!("\n== Table 2: iteration latency by LoRA config \
              (real run on sym-tiny, batch=1, seq=32) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    let dir = artifact_dir();
    println!("{:<22} {:>14} {:>14}", "adapter", "dedicated (ms)",
             "symbiosis (ms)");
    for which in 1..=4 {
        let (rank, targets) = lora_table2(which);
        let mut times = Vec::new();
        for shared in [false, true] {
            let dep = deploy(if shared {
                BatchPolicy::opportunistic_default()
            } else {
                BatchPolicy::NoLockstep
            });
            let adapter = Adapter::lora_from_artifacts(
                &SYM_TINY, &dir, rank, targets, 2.0).unwrap();
            let mut tr =
                dep.trainer().adapter(adapter).build().unwrap();
            let tokens: Vec<i32> =
                (0..32).map(|k| (k * 7 % 256) as i32).collect();
            let labels: Vec<i32> =
                tokens.iter().map(|t| (t + 1) % 256).collect();
            tr.train_step(&tokens, &labels).unwrap(); // warm
            let t0 = Instant::now();
            let iters = 5;
            for _ in 0..iters {
                tr.train_step(&tokens, &labels).unwrap();
            }
            times.push(t0.elapsed().as_secs_f64() * 1e3 / iters as f64);
            drop(tr);
            dep.shutdown();
        }
        println!("{:<22} {:>14.1} {:>14.1}",
                 format!("LoRA{which} (r={rank}, {} tgts)",
                         targets.count()),
                 times[0], times[1]);
    }
    println!("paper Table 2: 0.32-0.40s baseline, 0.40-0.68s Symbiosis \
              (13B); shape: more target layers > higher rank in cost.");
}

// =========================================================================
// Fig 7 — per-layer wait time at the executor under lockstep, local vs
// remote clients.  Paper: remote clients inflate the per-layer wait.
// =========================================================================
fn fig07_wait_time() {
    println!("\n== Fig 7: per-layer executor wait under lockstep \
              (4 inference clients, real run) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    // Warm the engine so lazy HLO compiles don't pollute queue waits.
    {
        let dep = deploy(BatchPolicy::NoLockstep);
        for (c, plen) in [(0usize, 16usize), (1, 64), (2, 128), (3, 256)] {
            let mut sess = dep.session().build().unwrap();
            let prompt: Vec<i32> =
                (0..plen).map(|k| ((c + k) % 256) as i32).collect();
            sess.prefill(&prompt).unwrap();
            sess.decode_step().unwrap();
        }
        dep.shutdown();
    }
    // heterogeneous clients (different context lengths => different
    // client-side attention cost); the "remote" row places two of the
    // four clients behind a realized TCP link — the mixed-placement
    // as-a-service case the paper motivates.
    for (label, remote_clients) in [("all local", 0usize),
                                    ("2 local + 2 remote (tcp)", 2)] {
        let dep = deploy(BatchPolicy::Lockstep);
        let mut handles = Vec::new();
        for (c, plen) in [(0usize, 64usize), (1, 64), (2, 64), (3, 64)] {
            let remote = c < remote_clients;
            let sess = dep.session()
                .link(if remote {
                    LinkKind::Tcp
                } else {
                    LinkKind::SharedLocal
                })
                .realize_delays(remote)
                .build()
                .unwrap();
            handles.push(std::thread::spawn(move || {
                let mut sess = sess;
                let prompt: Vec<i32> =
                    (0..plen).map(|k| ((c + k) % 256) as i32).collect();
                sess.prefill(&prompt).unwrap();
                for _ in 0..6 {
                    sess.decode_step().unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let stats = dep.shutdown();
        let mut waits: Vec<f64> =
            stats.flushes.iter().map(|f| f.mean_wait_secs).collect();
        waits.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p50 = waits.get(waits.len() / 2).copied().unwrap_or(0.0);
        println!("{label:<28} p50 wait {:>7.2} ms (last {} flushes), \
                  mean {:>7.2} ms over all {} flushes, avg batch {:.2}",
                 p50 * 1e3, stats.flushes.len(),
                 stats.mean_wait_secs() * 1e3, stats.n_flushes,
                 stats.mean_batch_clients());
    }
    println!("paper Fig 7: per-layer lockstep waits are substantial and \
              grow when clients are remote/slow — motivates breaking \
              lockstep.");
}

// =========================================================================
// Fig 9 — memory, single fine-tuning job: baseline vs Symbiosis vs
// Symbiosis-MO.  Paper: MO makes the executor footprint ~constant.
// =========================================================================
fn fig09_memory_single() {
    println!("\n== Fig 9: GPU memory, single rank-8 FT job \
              (Llama2-13B, batch=2) ==");
    let cfg = &LLAMA2_13B;
    println!("{:>8} {:>12} {:>16} {:>14}", "seq", "baseline",
             "symbiosis-noMO", "symbiosis-MO");
    for seq in [256usize, 512, 1024, 2048] {
        let baseline = dedicated::memory_bytes(cfg, 1, 2, seq, 8, 4);
        let client = dedicated::client_state_bytes(cfg, 2, seq, 8, 4);
        // without the memory-optimized backward the executor also
        // stores every layer's input/output for the batch:
        let exec_no_mo = cfg.param_bytes()
            + dedicated::activation_bytes(cfg, 2, seq) * 2;
        let exec_mo = cfg.param_bytes(); // stateless (section 3.6)
        println!("{:>8} {:>11.1}G {:>15.1}G {:>13.1}G", seq,
                 gib(baseline), gib(exec_no_mo + client),
                 gib(exec_mo + client));
    }
    println!("paper Fig 9: non-optimized Symbiosis costs MORE than \
              baseline (double activation bookkeeping); MO flattens the \
              executor to the bare weights.");
}

// =========================================================================
// Fig 10 — memory vs number of fine-tuning clients.  Paper: executor
// flat; clients linear; Symbiosis fits 5 jobs where baseline fits 2.
// =========================================================================
fn fig10_memory_multi() {
    println!("\n== Fig 10: GPU memory vs clients \
              (Llama2-13B, batch=2, seq=512, 80GB GPU) ==");
    let cfg = &LLAMA2_13B;
    let client_state = dedicated::client_state_bytes(cfg, 2, 512, 8, 4);
    println!("{:>9} {:>12} {:>14} {:>12}", "clients", "baseline",
             "sym executor", "sym clients");
    for n in 1..=6usize {
        let baseline = dedicated::memory_bytes(cfg, n, 2, 512, 8, 4);
        let fits_b = baseline <= 80 * GIB;
        let sym = cfg.param_bytes() + n as u64 * client_state;
        let fits_s = sym <= 80 * GIB;
        println!("{:>9} {:>9.1}G {} {:>11.1}G {:>9.1}G {}", n,
                 gib(baseline), if fits_b { " " } else { "OOM" },
                 gib(cfg.param_bytes()), gib(n as u64 * client_state),
                 if fits_s { "" } else { "OOM" });
    }
    let max_b = dedicated::max_jobs(cfg, 80 * GIB, 2, 512, 8, 4);
    let mut max_s = 0;
    while cfg.param_bytes() + (max_s + 1) as u64 * client_state
        <= 80 * GIB
    {
        max_s += 1;
    }
    println!("max jobs on one 80GB GPU: baseline {max_b}, symbiosis \
              {max_s}  (paper: 2 vs 5)");
}

// =========================================================================
// Figs 11/12 — single-GPU fine-tuning latency + throughput vs #clients.
// Real run on sym-tiny; paper shape (Llama3-1B): baseline wins <= 2
// clients, Symbiosis wins beyond as batching amortizes.
// =========================================================================
fn fig11_12_single_gpu() {
    println!("\n== Figs 11/12: single-GPU fine-tuning vs #clients \
              (real run, sym-tiny, batch=1, seq=32) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    println!("{:>9} {:>18} {:>18} {:>14} {:>14}", "clients",
             "dedicated lat(ms)", "symbiosis lat(ms)", "ded tok/s",
             "sym tok/s");
    for n in [1usize, 2, 4, 6] {
        // dedicated: each client gets a private executor (own instance)
        let ded = run_ft_group(&artifact_dir(), n, false);
        // symbiosis: one shared executor, opportunistic batching
        let sym = run_ft_group(&artifact_dir(), n, true);
        println!("{:>9} {:>18.1} {:>18.1} {:>14.0} {:>14.0}", n, ded.0,
                 sym.0, ded.1, sym.1);
    }
    println!("note: the real run validates multi-client functionality; \
              on this 1-core CPU substrate batching cannot buy hardware \
              utilization (no idle SIMD/SM capacity to fill), so the \
              paper's crossover appears in the analytic model below, \
              not in CPU wall-clock.");
    println!("paper Figs 11/12: baseline faster at 1-2 clients (no \
              virt-layer hop), Symbiosis lower latency + higher \
              throughput beyond as cross-client batching amortizes; \
              throughput saturates near 6 clients.");

    // analytic counterpart at paper scale (Llama3-1B on one 80GB GPU):
    // dedicated jobs contend for the whole GPU, Symbiosis batches.
    println!("\nanalytic (Llama3-1B, batch=2, seq=512):");
    println!("{:>9} {:>16} {:>16}", "clients", "dedicated (s)",
             "symbiosis (s)");
    let m = IterationModel { cfg: LLAMA3_1B, placement: Placement::Local,
                             batch: 2, seq: 512 };
    for n in [1usize, 2, 4, 6, 8] {
        let one = m.iteration_secs(1, 8, 4, true);
        // n dedicated jobs time-share the GPU: each iteration dilates n x
        let dedicated_secs = one * n as f64;
        let sym = m.iteration_secs(n, 8, 4, true);
        println!("{:>9} {:>16.4} {:>16.4}{}", n, dedicated_secs, sym,
                 if sym < dedicated_secs { "  << sym wins" } else { "" });
    }
}

/// Run `n` fine-tuning clients; returns (mean iteration ms, tokens/s).
fn run_ft_group(dir: &std::path::Path, n: usize, shared: bool)
                -> (f64, f64) {
    let seq = 32;
    let steps = 4;
    let deployments: Vec<Deployment> = if shared {
        vec![deploy(BatchPolicy::opportunistic_default())]
    } else {
        // each dedicated job gets its own executor instance (the shared
        // compile cache only removes compile noise from the timing)
        (0..n).map(|_| deploy(BatchPolicy::NoLockstep)).collect()
    };
    let _ = dir;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..n {
        let dep = if shared { &deployments[0] } else { &deployments[c] };
        let adapter = Adapter::lora_from_artifacts(
            &SYM_TINY, dir, 8, LoraTargets::QKVO, 2.0).unwrap();
        let tr = dep.trainer().adapter(adapter).build().unwrap();
        handles.push(std::thread::spawn(move || {
            let mut tr = tr;
            let tokens: Vec<i32> =
                (0..seq).map(|k| ((c * 31 + k * 7) % 256) as i32)
                    .collect();
            let labels: Vec<i32> =
                tokens.iter().map(|t| (t + 1) % 256).collect();
            let mut lat = LatencyStats::new();
            for _ in 0..steps {
                let t = Instant::now();
                tr.train_step(&tokens, &labels).unwrap();
                lat.record(t.elapsed());
            }
            lat.mean()
        }));
    }
    let mean_iter: f64 = handles
        .into_iter()
        .map(|h| h.join().unwrap())
        .sum::<f64>()
        / n as f64;
    let wall = t0.elapsed().as_secs_f64();
    let tput = (n * steps * seq) as f64 / wall;
    for d in deployments {
        d.shutdown();
    }
    (mean_iter * 1e3, tput)
}

// =========================================================================
// Figs 13/14 — remote execution (clients on another GPU).  Analytic on
// Llama2-13B + Starcoder2-15B; real sym-tiny run over the NVLink model.
// =========================================================================
fn fig13_14_remote() {
    println!("\n== Figs 13/14: remote execution, 1 client GPU + 1 \
              executor GPU (batch=2, seq=512) ==");
    println!("{:>9} {:>18} {:>18} {:>16} {:>16}", "clients",
             "13B iter (s)", "starcoder iter(s)", "13B tok/s",
             "starcoder tok/s");
    for n in [1usize, 2, 4, 8] {
        let m13 = IterationModel { cfg: LLAMA2_13B,
                                   placement: Placement::Remote,
                                   batch: 2, seq: 512 };
        let msc = IterationModel { cfg: STARCODER_15B,
                                   placement: Placement::Remote,
                                   batch: 2, seq: 512 };
        println!("{:>9} {:>18.3} {:>18.3} {:>16.0} {:>16.0}", n,
                 m13.iteration_secs(n, 8, 4, true),
                 msc.iteration_secs(n, 8, 4, true),
                 m13.throughput_tokens_per_sec(n, 8, 4, true),
                 msc.throughput_tokens_per_sec(n, 8, 4, true));
    }
    println!("paper: Starcoder2-15B much slower than Llama2-13B (60GB \
              f32: ~10x per-op cost vs f16); its 1-GPU baseline is 3.3s \
              / 310 tok/s — our f32 starcoder column sits in the same \
              regime. Communication overhead grows with clients.");
}

// =========================================================================
// Figs 15/16 — sharded local vs mLoRA (Llama2-13B over 2 GPUs).
// =========================================================================
fn fig15_16_sharded_local() {
    println!("\n== Figs 15/16: sharded-local vs mLoRA \
              (Llama2-13B, 2 GPUs, batch=2, seq=512) ==");
    let cfg = &LLAMA2_13B;
    let m = IterationModel { cfg: cfg.clone(),
                             placement: Placement::ShardedLocal {
                                 shards: 2 },
                             batch: 2, seq: 512 };
    let mlora_fast = MloraMode { recompute: false };
    let mlora_lean = MloraMode { recompute: true };
    println!("{:>9} {:>16} {:>18} {:>18} {:>14}", "adapters",
             "symbiosis (s)", "mLoRA-perf (s)", "mLoRA-recomp (s)",
             "sym tok/s");
    for n in [1usize, 2, 4, 6, 8] {
        let sym = m.iteration_secs(n, 8, 4, true);
        let base = m.iteration_secs(n, 8, 4, true);
        let fast_fits = mlora_fast.memory_bytes(cfg, n, 2, 512, 8, 4)
            <= 2 * 80 * GIB;
        let lean_fits = mlora_lean.memory_bytes(cfg, n, 2, 512, 8, 4)
            <= 2 * 80 * GIB;
        let f = if fast_fits {
            format!("{:.3}", base * mlora_fast.time_multiplier())
        } else {
            "OOM".into()
        };
        let l = if lean_fits {
            format!("{:.3}", base * mlora_lean.time_multiplier())
        } else {
            "OOM".into()
        };
        println!("{:>9} {:>16.3} {:>18} {:>18} {:>14.0}", n, sym, f, l,
                 m.throughput_tokens_per_sec(n, 8, 4, true));
    }
    let fsdp = FsdpTrainer { cfg: cfg.clone(), shards: 2, batch: 2,
                             seq: 512 };
    println!("FSDP baseline (1 adapter over 2 GPUs): {:.3}s/iter, \
              {:.1} GiB/GPU  (paper: ~17 GiB/GPU; Symbiosis trains 8 \
              adapters in half the FSDP time = 4x)",
             fsdp.iteration_secs(8, 4),
             gib(fsdp.memory_per_gpu(8, 4)));
    println!("paper: mLoRA must pick memory OR performance; \
              Symbiosis-MO gets both (runs more adapters at lower \
              latency).");
}

// =========================================================================
// Fig 17 — sharded remote, Gemma2-27B over 4+4 GPUs vs 8-GPU FSDP.
// =========================================================================
fn fig17_sharded_remote() {
    println!("\n== Fig 17: sharded-remote throughput \
              (Gemma2-27B, executor on 4 GPUs, clients on 4, batch=2, \
              seq=64) ==");
    let cfg = &GEMMA2_27B;
    let m = IterationModel { cfg: cfg.clone(),
                             placement: Placement::ShardedRemote {
                                 shards: 4 },
                             batch: 2, seq: 64 };
    println!("{:>9} {:>14} {:>12}", "adapters", "sym tok/s",
             "per-client s");
    for n in [1usize, 2, 4, 8] {
        println!("{:>9} {:>14.1} {:>12.3}", n,
                 m.throughput_tokens_per_sec(n, 8, 4, true),
                 m.iteration_secs(n, 8, 4, true));
    }
    let fsdp = FsdpTrainer { cfg: cfg.clone(), shards: 8, batch: 2,
                             seq: 64 };
    let fsdp_tput = (2 * 64) as f64 / fsdp.iteration_secs(8, 4);
    println!("FSDP over 8 GPUs, single adapter: {fsdp_tput:.1} tok/s \
              (paper: 32 tok/s)");
    let sym8 = m.throughput_tokens_per_sec(8, 8, 4, true);
    println!("Symbiosis @8 adapters vs FSDP: {:.1}x  (paper: ~3x; \
              parameter fetching dominates both, FSDP adds gradient \
              exchange)", sym8 / fsdp_tput);
    let plan = ShardPlan::new(cfg.clone(), 4);
    println!("memory/GPU: shard {:.1} GiB + gathered block {:.2} GiB",
             gib(plan.resident_bytes_per_gpu()),
             gib(plan.block_working_set()));
}

// =========================================================================
// Fig 18 — heterogeneous GPUs (350W fast / 100W slow, 40GB).
// =========================================================================
fn fig18_hetero_gpu() {
    println!("\n== Fig 18: heterogeneous GPUs, Llama2-13B FT \
              throughput (batch=2, seq=512) ==");
    println!("{:>9} {:>16} {:>16} {:>16}", "clients",
             "C-fast B-fast", "C-slow B-fast", "C-slow B-slow");
    for n in [1usize, 2, 4] {
        // C on fast + B on fast
        let both_fast = IterationModel { cfg: LLAMA2_13B,
                                         placement: Placement::Remote,
                                         batch: 2, seq: 512 };
        // C slow, B fast — Symbiosis's recommended split
        let hetero = IterationModel { cfg: LLAMA2_13B,
                                      placement: Placement::HeteroGpu,
                                      batch: 2, seq: 512 };
        // everything on the slow GPU
        let both_slow_secs = {
            let slow = Device::new("s", DeviceKind::GpuSlow40);
            let t = (2 * 512) as u64;
            let flops = 3 * LLAMA2_13B.forward_flops(t, 512) * n as u64;
            slow.op_time(flops, LLAMA2_13B.param_bytes(),
                         LLAMA2_13B.precision)
        };
        let tput = |iter: f64| (n * 2 * 512) as f64 / iter;
        println!("{:>9} {:>16.0} {:>16.0} {:>16.0}", n,
                 tput(both_fast.iteration_secs(n, 8, 4, true)),
                 tput(hetero.iteration_secs(n, 8, 4, true)),
                 tput(both_slow_secs));
    }
    println!("paper: placing only the light client work on the 100W \
              GPU costs little — heterogeneous ~= all-fast, >> \
              all-slow.");
}

// =========================================================================
// Fig 19 — CPU-GPU long-context inference (analytic; see also the
// longcontext_hetero example for the real tiny run).
// =========================================================================
fn fig19_longcontext() {
    println!("\n== Fig 19: long-context inter-token latency \
              (Llama2-7B, calibrated model; run `cargo run --example \
              longcontext_hetero` for the real sym-tiny counterpart) ==");
    const PCIE_EFF: f64 = 25e9;
    const CPU_ATTN_EFF: f64 = 50e9;
    const CPU_CONST: f64 = 0.32;
    const GPU_KV_BUDGET: u64 = 16 * GIB;
    let cfg = &LLAMA2_7B;
    let gpu = Device::new("a100", DeviceKind::GpuA100_80);
    println!("{:>10} {:>10} {:>14} {:>14}", "context", "all-GPU",
             "GPU+offload", "Symbiosis");
    for log2 in 12..=17u32 {
        let ctx = 1u64 << log2;
        let kv = cfg.kv_cache_bytes(1, ctx as usize);
        let lin = cfg.forward_flops(1, 0);
        let attn = 4 * cfg.n_layers as u64 * ctx * cfg.d_model as u64;
        let t_gpu = gpu.op_time(lin + attn, kv.min(GPU_KV_BUDGET),
                                cfg.precision);
        let a = if kv <= GPU_KV_BUDGET {
            format!("{:.0}ms", t_gpu * 1e3)
        } else {
            "OOM".into()
        };
        let b = t_gpu + kv as f64 / PCIE_EFF;
        let c = gpu.op_time(lin, cfg.param_bytes() / 64, cfg.precision)
            + CPU_CONST
            + kv as f64 / CPU_ATTN_EFF;
        println!("{:>9}K {:>10} {:>12.0}ms {:>12.0}ms", ctx / 1024, a,
                 b * 1e3, c * 1e3);
    }
    println!("paper: crossover at ~32K; 33% faster at 64K; baseline \
              OOMs where Symbiosis keeps scaling.");
}

// =========================================================================
// Fig 20 — multiple 1K-seq requests: GPU client OOMs, CPU client scales.
// =========================================================================
fn fig20_cpu_multi() {
    println!("\n== Fig 20: multi-request inference, Llama2-7B, seq=1K \
              per request ==");
    let cfg = &LLAMA2_7B;
    const CPU_ATTN_EFF: f64 = 50e9;
    println!("{:>10} {:>14} {:>14}", "requests", "40GB-GPU client",
             "CPU client");
    for n in [8usize, 16, 24, 64, 192] {
        // requests enter at 1K tokens and generate up to the model's 4K
        // max_seq: the client must reserve cache for the full horizon
        let kv = cfg.kv_cache_bytes(n, cfg.max_seq);
        // GPU client: cache + client-side activations must fit 40GB
        let gpu_ok = kv + 2 * GIB <= 40 * GIB;
        let gpu_col = if gpu_ok {
            let d = Device::new("g", DeviceKind::GpuFast40);
            let attn = 4 * cfg.n_layers as u64 * 1024 * n as u64
                * cfg.d_model as u64;
            let t = d.op_time(attn, kv, cfg.precision) + 0.02;
            format!("{:.1} tok/s", n as f64 / t)
        } else {
            "OOM".into()
        };
        let cpu_col = {
            let t = 0.32 + kv as f64 / CPU_ATTN_EFF;
            if kv <= DeviceKind::Cpu.capacity() {
                format!("{:.1} tok/s", n as f64 / t)
            } else {
                "OOM".into()
            }
        };
        println!("{:>10} {:>14} {:>14}", n, gpu_col, cpu_col);
    }
    println!("paper: the 40GB client GPU cannot hold the cache for 24+ \
              requests; the CPU client holds 8x as many at ~7.5 tok/s.");
}

// =========================================================================
// Fig 21 — privacy overhead over the network (real run).
// =========================================================================
fn fig21_privacy() {
    println!("\n== Fig 21: privacy overhead (real run, sym-tiny, \
              8 decode tokens) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    use symbiosis::coordinator::privacy::{NoiseGen, PrivacyCtx};
    use symbiosis::coordinator::proto::LayerId;
    let _dir = artifact_dir();
    let dep = deploy(BatchPolicy::NoLockstep);
    let prompt: Vec<i32> = (0..16).map(|i| (i * 3 % 256) as i32).collect();
    let mut rows = Vec::new();
    for (label, link, private) in [
        ("local, no privacy", LinkKind::SharedLocal, false),
        ("network, no privacy", LinkKind::Tcp, false),
        ("network + privacy", LinkKind::Tcp, true),
    ] {
        let mut builder = dep.session().link(link);
        if private {
            let privacy = PrivacyCtx::new();
            let mut gen = NoiseGen::new(7, 0.05);
            let tx = dep.executor.sender();
            let (d, f) = (SYM_TINY.d_model, SYM_TINY.d_ff);
            for l in 0..SYM_TINY.n_layers {
                for (layer, din) in [(LayerId::Qkv(l), d),
                                     (LayerId::AttnOut(l), d),
                                     (LayerId::MlpUp(l), d),
                                     (LayerId::MlpDown(l), f)] {
                    privacy.register_layer(&tx, layer, 16, din, &mut gen,
                                           2).unwrap();
                }
            }
            privacy.register_layer(&tx, LayerId::LmHead, 16, d,
                                   &mut gen, 2).unwrap();
            builder = builder.privacy(privacy);
        }
        let mut sess = builder.build().unwrap();
        let t0 = Instant::now();
        sess.prefill(&prompt).unwrap();
        for _ in 0..8 {
            sess.decode_step().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let sim_link = sess.core.virt.link_time();
        rows.push((label, wall, sim_link, sess.generated[0].clone()));
    }
    println!("{:<24} {:>12} {:>16}", "config", "wall (ms)",
             "sim link (ms)");
    for (label, wall, link, _) in &rows {
        println!("{label:<24} {:>12.1} {:>16.2}", wall * 1e3,
                 link * 1e3);
    }
    assert_eq!(rows[0].3, rows[2].3, "privacy changed tokens!");
    println!("outputs identical across all three configs ✓; network \
              link time dominates, noise arithmetic ~free (paper \
              Fig 21).");
    dep.shutdown();
}

// =========================================================================
// Figs 22/23 — mixed inference + fine-tuning (real run).
// =========================================================================
fn fig22_23_mixed() {
    println!("\n== Figs 22/23: mixed inference + fine-tuning \
              throughput (real run, sym-tiny) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    let dir = artifact_dir();
    for (label, n_inf, n_ft) in [("8 inference", 8usize, 0usize),
                                 ("6 inference + 2 FT", 6, 2)] {
        let dep = deploy(BatchPolicy::opportunistic_default());
        let t0 = Instant::now();
        let mut handles: Vec<std::thread::JoinHandle<(u64, f64)>> =
            Vec::new();
        for c in 0..n_inf {
            let sess = dep.session().build().unwrap();
            handles.push(std::thread::spawn(move || {
                let mut sess = sess;
                let prompt: Vec<i32> =
                    (0..16).map(|k| ((c + k) % 256) as i32).collect();
                let mut lat = LatencyStats::new();
                sess.prefill(&prompt).unwrap();
                for _ in 0..12 {
                    let t = Instant::now();
                    sess.decode_step().unwrap();
                    lat.record(t.elapsed());
                }
                (13u64, lat.mean())
            }));
        }
        for c in 0..n_ft {
            let adapter = Adapter::lora_from_artifacts(
                &SYM_TINY, &dir, 8, LoraTargets::QKVO, 2.0).unwrap();
            let tr = dep.trainer().adapter(adapter).build().unwrap();
            handles.push(std::thread::spawn(move || {
                let mut tr = tr;
                let tokens: Vec<i32> =
                    (0..64).map(|k| ((c * 7 + k) % 256) as i32).collect();
                let labels: Vec<i32> =
                    tokens.iter().map(|t| (t + 1) % 256).collect();
                let mut toks = 0u64;
                for _ in 0..3 {
                    tr.train_step(&tokens, &labels).unwrap();
                    toks += 64;
                }
                (toks, 0.0)
            }));
        }
        let mut total = 0u64;
        let mut inf_lat = Vec::new();
        for (i, h) in handles.into_iter().enumerate() {
            let (toks, lat) = h.join().unwrap();
            total += toks;
            if i < n_inf {
                inf_lat.push(lat);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let mean_inf_lat =
            inf_lat.iter().sum::<f64>() / inf_lat.len() as f64;
        println!("{label:<22} {:>8.0} tok/s total, inference token \
                  latency {:>6.1} ms", total as f64 / wall,
                 mean_inf_lat * 1e3);
        dep.shutdown();
    }
    println!("paper: replacing 2 idle-ish inference clients with FT \
              clients raises system throughput while inference token \
              latency stays ~flat (opportunistic batching prioritizes \
              interactive requests).");
}

// =========================================================================
// Table 4 — vLLM-style lockstep penalty for co-batched small + large.
// =========================================================================
fn tab04_vllm_lockstep() {
    println!("\n== Table 4: lockstep prefill latency, small+large \
              co-batch ==");
    // calibrate per-token prefill cost so large&large ~= paper's 6.94s
    let per_token = 6.94 / 1024.0;
    let cases: [(&str, Vec<usize>); 3] = [
        ("small & small", vec![1, 1]),
        ("small & large", vec![1, 512]),
        ("large & large", vec![512, 512]),
    ];
    println!("{:<16} {:>14} {:>22}", "batch", "lockstep (s)",
             "independent small (s)");
    for (label, lens) in &cases {
        let lock = vllm_lockstep_latency(lens, per_token);
        let ind = independent_latency(lens, per_token);
        println!("{label:<16} {:>14.2} {:>22.4}", lock[0], ind[0]);
    }
    println!("paper Table 4: 0.30 / 3.74 / 6.94 s — the small request \
              inherits the large one's latency under lockstep.");
    if have_artifacts() {
        // real counterpart on sym-tiny: short vs long prompt prefill
        let _dir = artifact_dir();
        let dep = deploy(BatchPolicy::Lockstep);
        let mut handles = Vec::new();
        for (c, plen) in [(0usize, 8usize), (1, 256)] {
            let sess = dep.session().build().unwrap();
            handles.push(std::thread::spawn(move || {
                let mut sess = sess;
                let prompt: Vec<i32> =
                    (0..plen).map(|k| ((c + k) % 256) as i32).collect();
                let t = Instant::now();
                sess.prefill(&prompt).unwrap();
                (plen, t.elapsed().as_secs_f64())
            }));
        }
        println!("real sym-tiny lockstep co-batch:");
        for h in handles {
            let (plen, secs) = h.join().unwrap();
            println!("  prefill seq={plen:<4} {:.1} ms", secs * 1e3);
        }
        dep.shutdown();
    }
}

// =========================================================================
// Table 5 — batching policies: throughput / latency / avg batch size.
// =========================================================================
fn tab05_policies() {
    println!("\n== Table 5: batching policy comparison (real run, \
              8 inference clients, mixed batch sizes + adapters) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    let dir = artifact_dir();
    println!("{:<16} {:>12} {:>14} {:>16}", "policy", "tok/s",
             "latency (ms)", "avg batch size");
    for (label, policy) in [
        ("no-lockstep", BatchPolicy::NoLockstep),
        ("lockstep", BatchPolicy::Lockstep),
        ("opportunistic", BatchPolicy::opportunistic_default()),
    ] {
        let dep = deploy(policy);
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for c in 0..8usize {
            // diversity like the paper's: request batch sizes, context
            // lengths (16..384 => very different client-side attention
            // cost) and adapter types all vary across clients
            let batch = [1usize, 2, 4, 1, 2, 4, 1, 2][c];
            let plen = [16usize, 32, 16, 128, 64, 32, 384, 192][c];
            let adapter = match c % 3 {
                0 => None,
                1 => Some(Adapter::lora_from_artifacts(
                    &SYM_TINY, &dir, 8, LoraTargets::Q_ONLY, 2.0)
                    .unwrap()),
                _ => Some(Adapter::lora_from_artifacts(
                    &SYM_TINY, &dir, 64, LoraTargets::QKVO, 0.25)
                    .unwrap()),
            };
            let mut builder = dep.session().batch(batch);
            if let Some(a) = adapter {
                builder = builder.adapter(a);
            }
            let sess = builder.build().unwrap();
            handles.push(std::thread::spawn(move || {
                let mut sess = sess;
                let prompt: Vec<i32> = (0..plen * batch)
                    .map(|k| ((c + k) % 256) as i32)
                    .collect();
                let mut lat = LatencyStats::new();
                sess.prefill(&prompt).unwrap();
                for _ in 0..10 {
                    let t = Instant::now();
                    sess.decode_step().unwrap();
                    lat.record(t.elapsed());
                }
                (11u64 * batch as u64, lat.mean())
            }));
        }
        let mut toks = 0u64;
        let mut lats = Vec::new();
        for h in handles {
            let (t, l) = h.join().unwrap();
            toks += t;
            lats.push(l);
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = dep.shutdown();
        println!("{label:<16} {:>12.0} {:>14.1} {:>16.2}",
                 toks as f64 / wall,
                 lats.iter().sum::<f64>() / lats.len() as f64 * 1e3,
                 stats.mean_batch_clients());
    }
    println!("paper Table 5: opportunistic wins both throughput (103 \
              vs 94/88 tok/s) and latency (0.77 vs 1.02/1.6 s) at an \
              intermediate avg batch (3.7 vs 1/8).");
}

// =========================================================================
// Ablation — opportunistic wait budget (design choice called out in
// DESIGN.md section 6): sweep the base wait on a mixed decode+training
// workload.  0 = pure natural batching; large budgets trade decode
// latency for (on real parallel hardware) larger batches.
// =========================================================================
fn ablation_wait_budget() {
    println!("\n== Ablation: opportunistic base wait (4 decode + 2 FT \
              clients, real run) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    let dir = artifact_dir();
    println!("{:>12} {:>12} {:>16} {:>14}", "base wait", "tok/s",
             "decode lat (ms)", "avg batch");
    let mut first = true;
    for ms in [50u64, 0, 5, 50, 200] {
        // the first iteration is an untimed warm-up (lazy HLO compiles)
        let policy = BatchPolicy::Opportunistic {
            base_wait: std::time::Duration::from_millis(ms),
        };
        let dep = deploy(policy);
        let t0 = Instant::now();
        let mut handles: Vec<std::thread::JoinHandle<(u64, f64)>> =
            Vec::new();
        for c in 0..4usize {
            let sess = dep.session().build().unwrap();
            handles.push(std::thread::spawn(move || {
                let mut sess = sess;
                let prompt: Vec<i32> =
                    (0..16).map(|k| ((c + k) % 256) as i32).collect();
                sess.prefill(&prompt).unwrap();
                let mut lat = LatencyStats::new();
                for _ in 0..8 {
                    let t = Instant::now();
                    sess.decode_step().unwrap();
                    lat.record(t.elapsed());
                }
                (9, lat.mean())
            }));
        }
        for c in 0..2usize {
            let adapter = Adapter::lora_from_artifacts(
                &SYM_TINY, &dir, 8, LoraTargets::QKVO, 2.0).unwrap();
            let tr = dep.trainer().adapter(adapter).build().unwrap();
            handles.push(std::thread::spawn(move || {
                let mut tr = tr;
                let tokens: Vec<i32> =
                    (0..32).map(|k| ((c + k * 3) % 256) as i32).collect();
                let labels: Vec<i32> =
                    tokens.iter().map(|t| (t + 1) % 256).collect();
                let mut toks = 0u64;
                for _ in 0..3 {
                    tr.train_step(&tokens, &labels).unwrap();
                    toks += 32;
                }
                (toks, 0.0)
            }));
        }
        let mut toks = 0u64;
        let mut dec = Vec::new();
        for (i, h) in handles.into_iter().enumerate() {
            let (t, l) = h.join().unwrap();
            toks += t;
            if i < 4 {
                dec.push(l);
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = dep.shutdown();
        if first {
            first = false;
            continue;
        }
        println!("{:>10}ms {:>12.0} {:>16.1} {:>14.2}", ms,
                 toks as f64 / wall,
                 dec.iter().sum::<f64>() / dec.len() as f64 * 1e3,
                 stats.mean_batch_clients());
    }
    println!("takeaway: with flush-on-idle, the wait budget only caps \
              how long a *busy* executor accumulates; decode latency is \
              insensitive to it while training-batch deadlines bound \
              trainer staleness.");
}

// =========================================================================
// Fleet overhead — real run across shard counts (sym-tiny).  The
// shards=1 row is the pre-fleet hot path (routing table of one);
// shards=2/4 split the same blocks over more executor threads.  Outputs
// must be identical; the deltas show what the routed fleet costs/buys
// on a host where every "GPU" is the same CPU substrate.
// =========================================================================
fn fleet_overhead() {
    println!("\n== Fleet overhead: generation across shard counts \
              (real run, sym-tiny, greedy 16) ==");
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    let prompt: Vec<i32> = (0..16).map(|i| (i * 3 % 256) as i32).collect();
    let mut golden: Option<Vec<i32>> = None;
    println!("{:>7} {:>12} {:>14} {:>16} {:>18}", "shards", "wall (ms)",
             "flushes", "resident/shard", "cross-shard msgs");
    for shards in [1usize, 2, 4] {
        let placement = if shards == 1 {
            Placement::Local
        } else {
            Placement::ShardedLocal { shards }
        };
        let dep = Deployment::start_with_engine(
            engine(), &SYM_TINY, &artifact_dir(),
            BatchPolicy::NoLockstep, placement)
            .unwrap();
        let mut sess = dep.session().build().unwrap();
        // warm the compile cache out of the measurement
        sess.generate(&prompt, &GenerationConfig::greedy(2)).unwrap();
        sess.reset().unwrap();
        // link counters accumulate since build: snapshot after warm-up
        // so the cross-shard column matches the timed run only
        let cross_of = |s: &symbiosis::coordinator::InferenceSession| {
            s.core
                .virt
                .link_traffic()
                .iter()
                .enumerate()
                .filter(|(shard, _)| *shard != 0)
                .map(|(_, (msgs, _))| msgs)
                .sum::<u64>()
        };
        let cross_warm = cross_of(&sess);
        let flushes_warm = dep.executor.stats().n_flushes;
        let t0 = Instant::now();
        let out = sess
            .generate(&prompt, &GenerationConfig::greedy(16))
            .unwrap();
        let wall = t0.elapsed().as_secs_f64();
        // messages to shards this client is not co-located with
        let cross = cross_of(&sess) - cross_warm;
        drop(sess);
        let resident = dep.executor.shard_resident_bytes();
        let stats = dep.shutdown();
        match &golden {
            None => golden = Some(out[0].clone()),
            Some(g) => assert_eq!(&out[0], g,
                                  "shards={shards} changed the output!"),
        }
        println!("{shards:>7} {:>12.1} {:>14} {:>13} KiB {:>18}",
                 wall * 1e3, stats.n_flushes - flushes_warm,
                 resident.iter().sum::<u64>() / shards as u64 / 1024,
                 cross);
    }
    println!("outputs bit-identical across shard counts ✓; resident \
              bytes split ~1/N; the shards=1 row is the pre-fleet hot \
              path (acceptance: no regression vs the dispatch bench \
              baseline).");
}

// =========================================================================
// Pipelined prefill — long-prompt prefill latency across shards x
// chunks (real run, sym-tiny).  chunks=1 is the sequential walk; every
// cell's generated tokens are asserted equal to the shards=1/chunks=1
// golden before timing, and the first prefill token of every timed run
// is re-checked.  Emits BENCH_pipeline.json (CI uploads it) with the
// measured wall-clock, the shards' busy/idle occupancy, and the
// GPipe-style modeled speedup M*S/(M+S-1) next to each cell —
// wall-clock overlap needs real cores; the modeled column is the
// paper-scale expectation.
// =========================================================================
fn pipeline_prefill(quick: bool) {
    use symbiosis::bench_harness::JsonValue;

    println!("\n== Pipelined prefill: long-prompt latency across \
              shards x chunks (real run, sym-tiny{}) ==",
             if quick { ", quick/check mode" } else { "" });
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        return;
    }
    let plen: usize = if quick { 64 } else { 256 };
    let iters = if quick { 1 } else { 3 };
    let prompt: Vec<i32> =
        (0..plen).map(|i| (i * 5 + 1) as i32 % 256).collect();
    let mut golden: Option<Vec<i32>> = None;
    let mut rows = Vec::new();
    // (shards, chunks) -> mean secs, for the speedup columns
    let mut means: Vec<(usize, usize, f64)> = Vec::new();
    println!("{:>7} {:>7} {:>11} {:>11} {:>11} {:>10} {:>9}", "shards",
             "chunks", "mean (ms)", "min (ms)", "speedup", "modeled",
             "occup");
    for shards in [1usize, 2, 4] {
        for chunks in [1usize, 2, 4, 8] {
            let chunk_cols = (plen + chunks - 1) / chunks;
            let placement = if shards == 1 {
                Placement::Local
            } else {
                Placement::ShardedLocal { shards }
            };
            let dep = Deployment::start_with_engine(
                engine(), &SYM_TINY, &artifact_dir(),
                BatchPolicy::NoLockstep, placement)
                .unwrap();
            let mut builder = dep.session();
            if chunks > 1 {
                builder = builder.prefill_chunk(chunk_cols);
            }
            let mut sess = builder.build().unwrap();
            // warm the compile cache AND check output equality: the
            // pipelined walk must be token-identical to the golden
            // sequential one at every grid point.
            let out = sess
                .generate(&prompt, &GenerationConfig::greedy(4))
                .unwrap();
            match &golden {
                None => golden = Some(out[0].clone()),
                Some(g) => assert_eq!(
                    &out[0], g,
                    "pipeline output diverged at shards={shards} \
                     chunks={chunks}"),
            }
            // Occupancy must cover ONLY the timed prefills — snapshot
            // the lifetime busy/idle counters around the loop and diff
            // (the warm-up generate and inter-iteration gaps would
            // otherwise dilute the number).
            let occ_before = dep.executor.stats();
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                sess.reset().unwrap();
                let t0 = Instant::now();
                let first = if chunks > 1 {
                    sess.prefill_pipelined(&prompt, chunk_cols).unwrap()
                } else {
                    sess.prefill(&prompt).unwrap()
                };
                times.push(t0.elapsed().as_secs_f64());
                assert_eq!(first[0], golden.as_ref().unwrap()[0],
                           "first prefill token diverged at \
                            shards={shards} chunks={chunks}");
            }
            let occ_after = dep.executor.stats();
            let mean = times.iter().sum::<f64>() / times.len() as f64;
            let min =
                times.iter().copied().fold(f64::INFINITY, f64::min);
            let occ: Vec<f64> = occ_after
                .per_shard
                .iter()
                .zip(&occ_before.per_shard)
                .map(|(a, b)| {
                    let busy = a.busy_secs - b.busy_secs;
                    let total = busy + (a.idle_secs - b.idle_secs);
                    if total <= 0.0 { 0.0 } else { busy / total }
                })
                .collect();
            let mean_occ =
                occ.iter().sum::<f64>() / occ.len().max(1) as f64;
            drop(sess);
            dep.shutdown();
            let sequential = means
                .iter()
                .find(|(s, c, _)| *s == shards && *c == 1)
                .map(|(_, _, m)| *m)
                .unwrap_or(mean);
            let speedup = sequential / mean;
            let model = IterationModel {
                cfg: LLAMA2_13B,
                placement: Placement::ShardedLocal {
                    shards: shards.max(1),
                },
                batch: 1,
                seq: 2048,
            };
            let modeled = model.pipeline_speedup(chunks);
            means.push((shards, chunks, mean));
            println!("{shards:>7} {chunks:>7} {:>11.1} {:>11.1} \
                      {:>10.2}x {:>9.2}x {:>8.0}%",
                     mean * 1e3, min * 1e3, speedup, modeled,
                     mean_occ * 100.0);
            rows.push(JsonValue::obj(vec![
                ("shards", JsonValue::Int(shards as i64)),
                ("chunks", JsonValue::Int(chunks as i64)),
                ("chunk_cols", JsonValue::Int(chunk_cols as i64)),
                ("mean_ms", JsonValue::Num(mean * 1e3)),
                ("min_ms", JsonValue::Num(min * 1e3)),
                ("speedup_vs_sequential", JsonValue::Num(speedup)),
                ("modeled_speedup", JsonValue::Num(modeled)),
                ("occupancy", JsonValue::Num(mean_occ)),
                // asserted above — a diverging cell panics the bench
                ("outputs_equal", JsonValue::Bool(true)),
            ]));
        }
    }
    let cell = |s: usize, c: usize| {
        means
            .iter()
            .find(|(ms, mc, _)| *ms == s && *mc == c)
            .map(|(_, _, m)| *m)
            .unwrap_or(f64::NAN)
    };
    let s2_speedup = cell(2, 1) / cell(2, 4);
    let doc = symbiosis::bench_harness::bench_record(
        "pipeline", quick,
        vec![
            ("model", JsonValue::Str("sym-tiny".into())),
            ("prompt_tokens", JsonValue::Int(plen as i64)),
        ],
        vec![],
        vec![("grid_cells", JsonValue::Int(means.len() as i64))],
        vec![
            ("rows", JsonValue::Arr(rows)),
            ("acceptance", JsonValue::obj(vec![
                ("shards", JsonValue::Int(2)),
                ("chunks", JsonValue::Int(4)),
                ("speedup_vs_sequential", JsonValue::Num(s2_speedup)),
                ("modeled_speedup", JsonValue::Num(1.6)),
                ("outputs_equal_all_cells", JsonValue::Bool(true)),
            ])),
        ]);
    write_bench_artifact("BENCH_pipeline.json", &doc);
    println!("shards=2 chunks=4 speedup: measured {s2_speedup:.2}x, \
              modeled 1.60x (M*S/(M+S-1)); outputs token-identical at \
              every shards x chunks point ✓.  Wall-clock overlap needs \
              spare cores — on a single-core substrate the measured \
              column shows the pipeline's bookkeeping cost instead, \
              while the occupancy column still shows every shard \
              staying busy.");
}

// =========================================================================
// Pipelined training — fine-tuning step time across shards x
// micro-batches (real run, sym-tiny).  micro_batches=1 is the
// sequential walk; every pipelined cell's loss-bit trajectory is
// asserted equal to the sequential golden BEFORE timing (the step is
// bit-identical by construction — a diverging cell panics the bench).
// Also measures N trainers fine-tuning simultaneously (shard occupancy
// + peak training-ledger bytes) and drives the capacity edge until the
// typed QuotaExceeded / TrainerOom fires.  Emits BENCH_training.json.
// =========================================================================
fn training_bench(quick: bool) {
    use symbiosis::bench_harness::JsonValue;
    use symbiosis::coordinator::admission::TenantQuota;
    use symbiosis::error::SymbiosisError;

    println!("\n== Pipelined training: step time across shards x \
              micro-batches (real run, sym-tiny{}) ==",
             if quick { ", quick/check mode" } else { "" });
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        write_bench_artifact("BENCH_training.json", &skipped_record(
            "training", quick, "artifacts not built"));
        return;
    }
    let steps = if quick { 2 } else { 3 };
    let iters = if quick { 1 } else { 3 };
    let lora = || {
        Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(), 8,
                                     LoraTargets::QKVO, 2.0)
            .unwrap()
    };
    let data = |batch: usize| -> (Vec<i32>, Vec<i32>) {
        let t = batch * 16;
        ((0..t).map(|i| ((i * 7 + 3) % 256) as i32).collect(),
         (0..t).map(|i| ((i * 5 + 2) % 256) as i32).collect())
    };
    let placement_of = |shards: usize| if shards == 1 {
        Placement::Local
    } else {
        Placement::ShardedLocal { shards }
    };

    // ---- grid: shards x micro-batches, batch 4 (seq 16) ----
    let mut golden: Option<Vec<u32>> = None;
    let mut rows = Vec::new();
    let mut means: Vec<(usize, usize, f64)> = Vec::new();
    println!("{:>7} {:>7} {:>11} {:>11} {:>11} {:>10} {:>12}",
             "shards", "micro", "mean (ms)", "min (ms)", "speedup",
             "modeled", "peak ledger");
    for shards in [1usize, 2, 4] {
        for micro in [1usize, 2, 4] {
            let (tokens, labels) = data(4);
            let dep = Deployment::start_with_engine(
                engine(), &SYM_TINY, &artifact_dir(),
                BatchPolicy::NoLockstep, placement_of(shards))
                .unwrap();
            let mut tr = dep.trainer()
                .adapter(lora())
                .batch(4)
                .micro_batches(micro)
                .lr(5e-3)
                .build()
                .unwrap();
            // Golden check before timing: the pipelined step must be
            // bit-identical to the sequential walk, steps included.
            let bits: Vec<u32> = (0..steps)
                .map(|_| tr.train_step(&tokens, &labels)
                    .unwrap().loss.to_bits())
                .collect();
            match &golden {
                None => golden = Some(bits.clone()),
                Some(g) => assert_eq!(
                    &bits, g,
                    "loss trajectory diverged at shards={shards} \
                     micro={micro}"),
            }
            let mut times = Vec::with_capacity(iters);
            for _ in 0..iters {
                let t0 = Instant::now();
                tr.train_step(&tokens, &labels).unwrap();
                times.push(t0.elapsed().as_secs_f64());
            }
            let peak = {
                let d = dep.client_device.lock().unwrap();
                d.ledger.peak()
            };
            drop(tr);
            dep.shutdown();
            let mean = times.iter().sum::<f64>() / times.len() as f64;
            let min =
                times.iter().copied().fold(f64::INFINITY, f64::min);
            let sequential = means
                .iter()
                .find(|(s, m, _)| *s == shards && *m == 1)
                .map(|(_, _, v)| *v)
                .unwrap_or(mean);
            let speedup = sequential / mean;
            let model = IterationModel {
                cfg: LLAMA2_13B,
                placement: Placement::ShardedLocal {
                    shards: shards.max(1),
                },
                batch: 4,
                seq: 2048,
            };
            let modeled = model.pipeline_speedup(micro);
            means.push((shards, micro, mean));
            println!("{shards:>7} {micro:>7} {:>11.1} {:>11.1} \
                      {:>10.2}x {:>9.2}x {:>10} B",
                     mean * 1e3, min * 1e3, speedup, modeled, peak);
            rows.push(JsonValue::obj(vec![
                ("shards", JsonValue::Int(shards as i64)),
                ("micro_batches", JsonValue::Int(micro as i64)),
                ("mean_ms", JsonValue::Num(mean * 1e3)),
                ("min_ms", JsonValue::Num(min * 1e3)),
                ("speedup_vs_sequential", JsonValue::Num(speedup)),
                ("modeled_speedup", JsonValue::Num(modeled)),
                ("peak_ledger_bytes", JsonValue::Int(peak as i64)),
                // asserted above — a diverging cell panics the bench
                ("loss_bits_equal", JsonValue::Bool(true)),
            ]));
        }
    }

    // ---- capability unlock: batch 8 runs ONLY micro-batched (8 is
    // not an attention batch size — there is no sequential baseline to
    // diff against, so the check is cross-shard bit-identity). ----
    let mut golden8: Option<Vec<u32>> = None;
    for shards in [1usize, 2, 4] {
        let (tokens, labels) = data(8);
        let dep = Deployment::start_with_engine(
            engine(), &SYM_TINY, &artifact_dir(),
            BatchPolicy::NoLockstep, placement_of(shards))
            .unwrap();
        let mut tr = dep.trainer()
            .adapter(lora())
            .batch(8)
            .micro_batches(8)
            .lr(5e-3)
            .build()
            .unwrap();
        let bits: Vec<u32> = (0..steps)
            .map(|_| tr.train_step(&tokens, &labels)
                .unwrap().loss.to_bits())
            .collect();
        match &golden8 {
            None => golden8 = Some(bits.clone()),
            Some(g) => assert_eq!(
                &bits, g,
                "batch-8 trajectory diverged at shards={shards}"),
        }
        let t0 = Instant::now();
        tr.train_step(&tokens, &labels).unwrap();
        let wall = t0.elapsed().as_secs_f64();
        drop(tr);
        dep.shutdown();
        rows.push(JsonValue::obj(vec![
            ("shards", JsonValue::Int(shards as i64)),
            ("micro_batches", JsonValue::Int(8)),
            ("batch", JsonValue::Int(8)),
            ("mean_ms", JsonValue::Num(wall * 1e3)),
            // 8 ∉ ATTN_BATCHES: micro-batching makes this batch
            // runnable at all, so there is nothing sequential to beat.
            ("no_sequential_baseline", JsonValue::Bool(true)),
            ("loss_bits_equal_across_shards", JsonValue::Bool(true)),
        ]));
    }
    println!("batch=8 (8x1 micro-batches) runs at shards 1/2/4 with \
              bit-identical trajectories — unreachable for the \
              sequential walk (8 is not an attention batch size).");

    // ---- N adapters fine-tuning simultaneously: occupancy + peak
    // training-ledger bytes (paper fig 9's multi-trainer memory axis).
    let n_trainers = 8usize;
    let dep = Deployment::start_with_engine(
        engine(), &SYM_TINY, &artifact_dir(),
        BatchPolicy::NoLockstep, placement_of(2))
        .unwrap();
    let trainers: Vec<_> = (0..n_trainers)
        .map(|_| dep.trainer()
            .adapter(lora())
            .batch(2)
            .micro_batches(2)
            .lr(5e-3)
            .build()
            .unwrap())
        .collect();
    let occ_before = dep.executor.stats();
    let t0 = Instant::now();
    std::thread::scope(|sc| {
        for mut tr in trainers {
            sc.spawn(move || {
                let (tokens, labels) = data(2);
                for _ in 0..steps {
                    tr.train_step(&tokens, &labels).unwrap();
                }
            });
        }
    });
    let wall = t0.elapsed().as_secs_f64();
    let occ_after = dep.executor.stats();
    let occ: Vec<f64> = occ_after
        .per_shard
        .iter()
        .zip(&occ_before.per_shard)
        .map(|(a, b)| {
            let busy = a.busy_secs - b.busy_secs;
            let total = busy + (a.idle_secs - b.idle_secs);
            if total <= 0.0 { 0.0 } else { busy / total }
        })
        .collect();
    let mean_occ = occ.iter().sum::<f64>() / occ.len().max(1) as f64;
    let peak_ledger = {
        let d = dep.client_device.lock().unwrap();
        d.ledger.peak()
    };
    let stats = dep.shutdown();
    println!("{n_trainers} trainers simultaneously (shards=2): \
              {:.1} ms wall, {:.0}% mean occupancy, peak ledger \
              {peak_ledger} B, peak {} micro-batch(es) in flight, \
              peak stash {} B",
             wall * 1e3, mean_occ * 100.0,
             stats.train_microbatches_in_flight_peak,
             stats.train_activation_stash_peak_bytes);
    let occupancy = JsonValue::obj(vec![
        ("trainers", JsonValue::Int(n_trainers as i64)),
        ("shards", JsonValue::Int(2)),
        ("steps_each", JsonValue::Int(steps as i64)),
        ("wall_ms", JsonValue::Num(wall * 1e3)),
        ("mean_occupancy", JsonValue::Num(mean_occ)),
        ("peak_ledger_bytes", JsonValue::Int(peak_ledger as i64)),
        ("peak_microbatches_in_flight",
         JsonValue::Int(stats.train_microbatches_in_flight_peak as i64)),
        ("peak_stash_bytes",
         JsonValue::Int(stats.train_activation_stash_peak_bytes as i64)),
        ("grad_accum_steps",
         JsonValue::Int(stats.train_grad_accum_steps as i64)),
    ]);

    // ---- capacity edge: admit trainers until the typed error fires.
    // Tenant book first (QuotaExceeded), then the device ledger
    // (TrainerOom via a filler charge) — co-tenants stay unaffected.
    let dep = Deployment::start_with_engine(
        engine(), &SYM_TINY, &artifact_dir(),
        BatchPolicy::NoLockstep, placement_of(2))
        .unwrap();
    let probe = dep.trainer().adapter(lora()).batch(1).build().unwrap();
    let opt_bytes = probe.optimizer.state_bytes();
    drop(probe);
    dep.executor.admission().set_quota(
        "edge",
        TenantQuota::unlimited().max_train_bytes(opt_bytes * 3 / 2));
    let first = dep.trainer().adapter(lora()).batch(1)
        .tenant("edge").build();
    assert!(first.is_ok(), "first edge trainer must fit its quota");
    let second = dep.trainer().adapter(lora()).batch(1)
        .tenant("edge").build();
    let quota_err = match second {
        Err(e @ SymbiosisError::QuotaExceeded { .. }) => e.to_string(),
        other => panic!("expected QuotaExceeded at the tenant edge, \
                         got {other:?}"),
    };
    // Device edge: fill the client device so the next trainer's Adam
    // state cannot fit, then verify the co-tenant trainer still steps.
    {
        let mut d = dep.client_device.lock().unwrap();
        let cap = d.ledger.capacity();
        let used = d.ledger.used();
        d.ledger.set("bench:filler", cap - used - opt_bytes / 2)
            .unwrap();
    }
    let third = dep.trainer().adapter(lora()).batch(1).build();
    let oom_err = match third {
        Err(e @ SymbiosisError::TrainerOom { .. }) => e.to_string(),
        other => panic!("expected TrainerOom at the device edge, \
                         got {other:?}"),
    };
    {
        let mut d = dep.client_device.lock().unwrap();
        d.ledger.free("bench:filler");
    }
    let mut survivor = first.unwrap();
    let (tokens, labels) = data(1);
    survivor.train_step(&tokens, &labels).unwrap();
    drop(survivor);
    dep.shutdown();
    println!("capacity edge: tenant quota -> \"{quota_err}\"; device \
              ledger -> \"{oom_err}\"; admitted co-tenant kept \
              training through both ✓");
    let capacity_edge = JsonValue::obj(vec![
        ("opt_state_bytes", JsonValue::Int(opt_bytes as i64)),
        ("tenant_quota_error", JsonValue::Str(quota_err)),
        ("device_oom_error", JsonValue::Str(oom_err)),
        ("cotenant_unaffected", JsonValue::Bool(true)),
    ]);

    let cell = |s: usize, m: usize| {
        means
            .iter()
            .find(|(cs, cm, _)| *cs == s && *cm == m)
            .map(|(_, _, v)| *v)
            .unwrap_or(f64::NAN)
    };
    let s2_speedup = cell(2, 1) / cell(2, 4);
    let doc = symbiosis::bench_harness::bench_record(
        "training", quick,
        vec![
            ("model", JsonValue::Str("sym-tiny".into())),
            ("batch", JsonValue::Int(4)),
            ("seq", JsonValue::Int(16)),
        ],
        vec![],
        vec![("grid_cells", JsonValue::Int(means.len() as i64))],
        vec![
            ("rows", JsonValue::Arr(rows)),
            ("simultaneous", occupancy),
            ("capacity_edge", capacity_edge),
            ("acceptance", JsonValue::obj(vec![
                ("shards", JsonValue::Int(2)),
                ("micro_batches", JsonValue::Int(4)),
                ("speedup_vs_sequential", JsonValue::Num(s2_speedup)),
                ("modeled_speedup", JsonValue::Num(1.6)),
                ("loss_bits_equal_all_cells", JsonValue::Bool(true)),
            ])),
        ]);
    write_bench_artifact("BENCH_training.json", &doc);
    println!("shards=2 micro=4 step speedup: measured {s2_speedup:.2}x, \
              modeled 1.60x (M*S/(M+S-1)); loss-bit trajectories \
              identical at every cell ✓.  Wall-clock overlap needs \
              spare cores — on a single-core substrate the measured \
              column shows the wavefront's bookkeeping cost instead.");
}

// =========================================================================
// Chaos recovery — the fault-tolerance economics of the supervised
// fleet: how fast does the watchdog turn a crashed shard back into a
// routable one (kill -> epoch bump), and how long until a client
// actually gets an answer again (kill -> first successful call, riding
// the bounded-retry budget across the respawn)?  Output equality vs the
// pre-crash golden is asserted every round — a recovery that changes
// tokens is a failure, not a slow success.  Emits BENCH_chaos.json
// (CI's chaos job uploads it); when artifacts are absent a minimal
// skipped document is still written so the artifact upload is
// deterministic.
// =========================================================================
fn chaos_recovery(quick: bool) {
    use std::time::Duration;
    use symbiosis::bench_harness::JsonValue;
    use symbiosis::coordinator::fleet::WATCHDOG_INTERVAL;
    use symbiosis::coordinator::proto::ExecMsg;
    use symbiosis::coordinator::{LayerId, RetryPolicy};

    println!("\n== Chaos recovery: kill -> respawn detection and kill -> \
              first successful call (real run, sym-tiny{}) ==",
             if quick { ", quick/check mode" } else { "" });
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        write_bench_artifact("BENCH_chaos.json", &skipped_record(
            "chaos", quick, "artifacts not built"));
        return;
    }
    let iters = if quick { 1 } else { 3 };
    let prompt: Vec<i32> =
        (0..24).map(|i| (i * 5 + 1) as i32 % 256).collect();
    let mut rows = Vec::new();
    println!("{:>7} {:>6} {:>13} {:>13}", "shards", "kills",
             "respawn (ms)", "recover (ms)");
    for shards in [1usize, 2, 4] {
        let placement = if shards == 1 {
            Placement::Local
        } else {
            Placement::ShardedLocal { shards }
        };
        let dep = Deployment::start_with_engine(
            engine(), &SYM_TINY, &artifact_dir(),
            BatchPolicy::NoLockstep, placement)
            .unwrap();
        let mut sess = dep
            .session()
            .request_timeout(Duration::from_millis(250))
            .retry(RetryPolicy::retries(6)
                .with_backoff(Duration::from_millis(10)))
            .build()
            .unwrap();
        let golden = sess
            .generate(&prompt, &GenerationConfig::greedy(4))
            .unwrap();
        // Kill the LM-head owner: the last shard every walk must reach.
        let target = shards - 1;
        let wait_respawn = |since: u64| {
            let t0 = Instant::now();
            while !(dep.executor.is_alive(target)
                    && dep.executor.route_epoch(target) > since) {
                assert!(t0.elapsed() < Duration::from_secs(10),
                        "watchdog never recovered shard {target}");
                std::thread::sleep(Duration::from_millis(1));
            }
            t0.elapsed().as_secs_f64() * 1e3
        };
        let mut respawn_ms = Vec::with_capacity(iters);
        let mut recover_ms = Vec::with_capacity(iters);
        for _ in 0..iters {
            // (a) kill -> epoch bump: pure supervision latency — the
            // watchdog notices the dead join handle, rebuilds the shard
            // on its retained seed, swaps the endpoint.
            let epoch = dep.executor.route_epoch(target);
            dep.executor
                .sender_for(LayerId::LmHead)
                .send(ExecMsg::Crash)
                .unwrap();
            respawn_ms.push(wait_respawn(epoch));
            // (b) kill -> first successful call: the *client* discovers
            // the death (disconnected response channel) and rides its
            // retry budget across the respawn.
            let epoch = dep.executor.route_epoch(target);
            dep.executor
                .sender_for(LayerId::LmHead)
                .send(ExecMsg::Crash)
                .unwrap();
            let t1 = Instant::now();
            sess.reset().unwrap();
            let out = sess
                .generate(&prompt, &GenerationConfig::greedy(4))
                .unwrap();
            recover_ms.push(t1.elapsed().as_secs_f64() * 1e3);
            assert_eq!(out, golden,
                       "post-recovery output diverged at \
                        shards={shards}");
            // let the second kill's respawn land before the next round
            wait_respawn(epoch);
        }
        let kills = 2 * iters as u64;
        assert!(dep.executor.respawns() >= kills,
                "fleet lost track of respawns");
        drop(sess);
        dep.shutdown();
        let mean =
            |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        let (r_mean, c_mean) = (mean(&respawn_ms), mean(&recover_ms));
        println!("{shards:>7} {kills:>6} {r_mean:>13.1} {c_mean:>13.1}");
        rows.push(JsonValue::obj(vec![
            ("shards", JsonValue::Int(shards as i64)),
            ("kills", JsonValue::Int(kills as i64)),
            ("respawn_ms_mean", JsonValue::Num(r_mean)),
            ("recover_ms_mean", JsonValue::Num(c_mean)),
            // asserted above — a diverging recovery panics the bench
            ("outputs_equal", JsonValue::Bool(true)),
        ]));
    }
    let doc = symbiosis::bench_harness::bench_record(
        "chaos", quick,
        vec![
            ("model", JsonValue::Str("sym-tiny".into())),
            ("watchdog_interval_ms",
             JsonValue::Num(WATCHDOG_INTERVAL.as_secs_f64() * 1e3)),
        ],
        vec![],
        vec![("topologies", JsonValue::Int(3))],
        vec![
            ("rows", JsonValue::Arr(rows)),
            ("acceptance", JsonValue::obj(vec![
                ("topologies", JsonValue::Int(3)),
                ("all_recoveries_token_identical",
                 JsonValue::Bool(true)),
                ("respawn_bound_secs", JsonValue::Num(10.0)),
            ])),
        ]);
    write_bench_artifact("BENCH_chaos.json", &doc);
    println!("recovery is watchdog-bound (~{} ms poll interval), not \
              retry-bound: the client's backoff ladder only needs to \
              outlast one respawn, and every post-kill generation is \
              token-identical to the pre-kill golden ✓.",
             WATCHDOG_INTERVAL.as_millis());
}

// =========================================================================
// Overload — the economics of the admission layer under a synthetic
// flood (route-level echo shard: needs no artifacts, so CI gets a
// BENCH_overload.json on every runner).  A closed-loop interactive
// cohort shares one shard with a continuous background flood; the grid
// toggles the bounded ingress queue.  Unbounded, the flood's backlog
// sits in front of every interactive request (tail ~ backlog x service
// time); bounded, background work is rejected (`ShardSaturated`) or
// shed (`WorkShed`) at the high-water mark and the interactive tail
// stays near the service time.  A third section drives a brown shard
// through the circuit breaker and counts fast-fails and transitions.
// =========================================================================
fn overload_bench(quick: bool) {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
    use std::sync::mpsc::{channel, Sender};
    use std::time::Duration;
    use symbiosis::bench_harness::JsonValue;
    use symbiosis::coordinator::proto::{ExecMsg, LayerResponse,
                                        Urgency, SHED_MARKER};
    use symbiosis::coordinator::sharding::LayerAssignment;
    use symbiosis::coordinator::{BreakerState, CircuitBreaker,
                                 IngressMeter, LayerId, RetryPolicy,
                                 RoutingTable, ShardEndpoint,
                                 ShardRoute, SymbiosisError,
                                 VirtLayerCtx};
    use symbiosis::metrics::LatencyStats;
    use symbiosis::tensor::Tensor;

    println!("\n== Overload: interactive tail latency vs a background \
              flood, bounded vs unbounded ingress (synthetic shard, \
              200us service{}) ==",
             if quick { ", quick/check mode" } else { "" });

    const SERVICE: Duration = Duration::from_micros(200);
    const HIGH_WATER: usize = 8;
    let interactive_reqs: usize = if quick { 60 } else { 300 };

    // A shard stand-in that mirrors the real executor's overload
    // duties: release the ingress slot on dequeue, answer saturated
    // background work with the typed shed marker, fail everything
    // while "brown", serve the rest after the service delay.
    let spawn_shard = |meter: Arc<IngressMeter>,
                       healthy: Arc<AtomicBool>|
                       -> Sender<ExecMsg> {
        let (tx, rx) = channel();
        std::thread::spawn(move || {
            while let Ok(msg) = rx.recv() {
                if let ExecMsg::Request(req) = msg {
                    // Saturation is read at dequeue, counting this
                    // request — the single-request analogue of the
                    // executor's flush-time check.
                    let at_mark = meter.saturated();
                    meter.exit();
                    let y = if !healthy.load(Ordering::SeqCst) {
                        Err("brown shard".to_string())
                    } else if req.urgency == Urgency::Background
                        && at_mark
                    {
                        Err(format!("{SHED_MARKER}synthetic shard \
                                     shed background work"))
                    } else {
                        std::thread::sleep(SERVICE);
                        Ok(req.x.clone())
                    };
                    let _ = req.resp.send(LayerResponse {
                        y,
                        queue_wait_secs: 0.0,
                        batch_clients: 1,
                    });
                }
            }
        });
        tx
    };
    let mk_ctx = |client: usize, endpoint: &Arc<ShardEndpoint>| {
        let routing = RoutingTable::new(
            LayerAssignment::contiguous(SYM_TINY.n_layers, 1),
            vec![ShardRoute::shared(0, endpoint.clone(),
                                    LinkKind::SharedLocal)],
        )
        .unwrap();
        let mut ctx = VirtLayerCtx::new(client, routing);
        ctx.request_timeout = Some(Duration::from_secs(30));
        ctx
    };

    let mut rows = Vec::new();
    println!("{:>10} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9}", "ingress",
             "p50 (ms)", "p99 (ms)", "bg ok", "bg sat", "bg shed",
             "i retry");
    let mut tails: Vec<(bool, f64)> = Vec::new();
    for bounded in [false, true] {
        let meter = Arc::new(if bounded {
            IngressMeter::with_high_water(HIGH_WATER)
        } else {
            IngressMeter::unbounded()
        });
        let breaker = Arc::new(CircuitBreaker::disabled());
        let healthy = Arc::new(AtomicBool::new(true));
        let tx = spawn_shard(meter.clone(), healthy.clone());
        let endpoint =
            Arc::new(ShardEndpoint::with_shared(tx, meter, breaker));

        let stop = Arc::new(AtomicBool::new(false));
        let bg_ok = Arc::new(AtomicU64::new(0));
        let bg_sat = Arc::new(AtomicU64::new(0));
        let bg_shed = Arc::new(AtomicU64::new(0));
        let flooders: Vec<_> = (0..8)
            .map(|f| {
                let endpoint = endpoint.clone();
                let stop = stop.clone();
                let (ok, sat, shed) =
                    (bg_ok.clone(), bg_sat.clone(), bg_shed.clone());
                let ctx = mk_ctx(100 + f, &endpoint);
                let backoff = RetryPolicy::retries(1)
                    .with_backoff(Duration::from_micros(100));
                std::thread::spawn(move || {
                    // Open-loop bursts: fire a window of dispatches,
                    // then drain.  Unbounded ingress lets 8 flooders
                    // park ~64 requests ahead of every interactive
                    // arrival; bounded, the window is refused at the
                    // high-water mark instead.
                    while !stop.load(Ordering::SeqCst) {
                        let mut window = Vec::with_capacity(8);
                        let mut refused = false;
                        for _ in 0..8 {
                            match ctx.dispatch_forward(
                                LayerId::Qkv(0),
                                Tensor::zeros(&[1, 4]),
                                Urgency::Background) {
                                Ok(p) => window.push(p),
                                Err(e) => {
                                    match e
                                        .downcast_ref::<SymbiosisError>()
                                    {
                                        Some(
                                            SymbiosisError::ShardSaturated {
                                                ..
                                            },
                                        ) => {
                                            sat.fetch_add(
                                                1, Ordering::SeqCst);
                                            refused = true;
                                        }
                                        other => panic!(
                                            "untyped flood dispatch \
                                             error ({other:?}): {e:#}"),
                                    }
                                }
                            }
                        }
                        for p in window {
                            match p.collect() {
                                Ok(_) => {
                                    ok.fetch_add(1, Ordering::SeqCst);
                                }
                                Err(e) => match e
                                    .downcast_ref::<SymbiosisError>()
                                {
                                    Some(SymbiosisError::WorkShed {
                                        ..
                                    }) => {
                                        shed.fetch_add(
                                            1, Ordering::SeqCst);
                                    }
                                    other => panic!(
                                        "untyped flood collect error \
                                         ({other:?}): {e:#}"),
                                },
                            }
                        }
                        if refused {
                            // A rejected flooder backs off like a
                            // well-behaved client, riding the
                            // jittered ladder.
                            std::thread::sleep(backoff
                                .backoff_for(1, 100 + f as u64));
                        }
                    }
                })
            })
            .collect();

        // Closed-loop interactive cohort: a saturated dispatch is
        // retried on the jittered backoff ladder and the retries count
        // toward that request's latency — the bounded queue trades
        // rejections for tail latency, and the bench charges for them.
        let interactive: Vec<_> = (0..2)
            .map(|c| {
                let endpoint = endpoint.clone();
                let n = interactive_reqs;
                let ctx = mk_ctx(c, &endpoint);
                let backoff = RetryPolicy::retries(1)
                    .with_backoff(Duration::from_micros(100));
                std::thread::spawn(move || {
                    let mut secs: Vec<f64> = Vec::with_capacity(n);
                    let mut retries = 0u64;
                    for _ in 0..n {
                        let t0 = Instant::now();
                        let mut attempt: u32 = 1;
                        loop {
                            match ctx.forward(LayerId::Qkv(0),
                                              Tensor::zeros(&[1, 4]),
                                              Urgency::Interactive) {
                                Ok(_) => break,
                                Err(e) => match e
                                    .downcast_ref::<SymbiosisError>()
                                {
                                    Some(
                                        SymbiosisError::ShardSaturated {
                                            ..
                                        },
                                    ) => {
                                        retries += 1;
                                        std::thread::sleep(
                                            backoff.backoff_for(
                                                attempt, c as u64),
                                        );
                                        attempt = attempt
                                            .saturating_add(1);
                                    }
                                    other => panic!(
                                        "untyped interactive error \
                                         ({other:?}): {e:#}"),
                                },
                            }
                        }
                        secs.push(t0.elapsed().as_secs_f64());
                    }
                    (secs, retries)
                })
            })
            .collect();

        let mut lat = LatencyStats::new();
        let mut i_retries = 0u64;
        for h in interactive {
            let (secs, r) =
                h.join().expect("interactive cohort panicked");
            for s in secs {
                lat.record_secs(s);
            }
            i_retries += r;
        }
        stop.store(true, Ordering::SeqCst);
        for h in flooders {
            h.join().expect("flooder panicked");
        }

        let (p50, p99) = (lat.p50() * 1e3, lat.p99() * 1e3);
        let (ok, sat, shed) = (bg_ok.load(Ordering::SeqCst),
                               bg_sat.load(Ordering::SeqCst),
                               bg_shed.load(Ordering::SeqCst));
        let mode = if bounded { "bounded" } else { "unbounded" };
        println!("{mode:>10} {p50:>9.3} {p99:>9.3} {ok:>9} {sat:>9} \
                  {shed:>9} {i_retries:>9}");
        tails.push((bounded, p99));
        rows.push(JsonValue::obj(vec![
            ("mode", JsonValue::Str(mode.into())),
            ("high_water",
             JsonValue::Int(if bounded { HIGH_WATER as i64 } else { 0 })),
            ("interactive_p50_ms", JsonValue::Num(p50)),
            ("interactive_p99_ms", JsonValue::Num(p99)),
            ("interactive_mean_ms", JsonValue::Num(lat.mean() * 1e3)),
            ("interactive_retries", JsonValue::Int(i_retries as i64)),
            ("background_served", JsonValue::Int(ok as i64)),
            ("background_saturated", JsonValue::Int(sat as i64)),
            ("background_shed", JsonValue::Int(shed as i64)),
        ]));
    }

    // -- circuit breaker under a brown shard: how many client calls
    // burn a real round-trip vs fast-fail, and the transition count of
    // the closed -> open -> half-open -> closed arc.
    let meter = Arc::new(IngressMeter::unbounded());
    let breaker = Arc::new(CircuitBreaker::with_threshold(3));
    let healthy = Arc::new(AtomicBool::new(false));
    let tx = spawn_shard(meter.clone(), healthy.clone());
    let endpoint = Arc::new(ShardEndpoint::with_shared(
        tx, meter, breaker.clone()));
    let ctx = mk_ctx(0, &endpoint);
    let (mut reached, mut fast_failed) = (0u64, 0u64);
    for i in 0..60u32 {
        if i % 10 == 9 {
            breaker.probe(); // the watchdog heartbeat, condensed
        }
        match ctx.forward(LayerId::Qkv(0), Tensor::zeros(&[1, 4]),
                          Urgency::Interactive) {
            Ok(_) => panic!("brown shard served a request"),
            Err(e) => match e.downcast_ref::<SymbiosisError>() {
                Some(SymbiosisError::ShardUnavailable {
                    retries: 0, ..
                }) => fast_failed += 1,
                Some(SymbiosisError::ExecutorFailed { .. }) => {
                    reached += 1;
                }
                other => panic!(
                    "untyped brown-shard error ({other:?}): {e:#}"),
            },
        }
    }
    healthy.store(true, Ordering::SeqCst);
    let mut recovered = false;
    for _ in 0..4 {
        breaker.probe();
        if ctx
            .forward(LayerId::Qkv(0), Tensor::zeros(&[1, 4]),
                     Urgency::Interactive)
            .is_ok()
        {
            recovered = true;
            break;
        }
    }
    assert!(recovered, "breaker never closed after the shard healed");
    assert_eq!(breaker.state(), BreakerState::Closed);
    let transitions = breaker.transitions();
    let fast_fail_fraction = fast_failed as f64 / 60.0;
    println!("breaker: {reached} calls reached the brown shard, \
              {fast_failed} fast-failed ({:.0}%), {transitions} \
              transitions, recovered ✓",
             fast_fail_fraction * 100.0);

    let doc = symbiosis::bench_harness::bench_record(
        "overload", quick,
        vec![
            ("service_us", JsonValue::Num(SERVICE.as_secs_f64() * 1e6)),
            ("flooders", JsonValue::Int(8)),
            ("interactive_clients", JsonValue::Int(2)),
            ("interactive_requests_per_client",
             JsonValue::Int(interactive_reqs as i64)),
        ],
        vec![
            ("interactive_unbounded_p99_ms", JsonValue::Num(tails[0].1)),
            ("interactive_bounded_p99_ms", JsonValue::Num(tails[1].1)),
        ],
        vec![
            ("breaker_reached_shard", JsonValue::Int(reached as i64)),
            ("breaker_fast_failed", JsonValue::Int(fast_failed as i64)),
            ("breaker_transitions", JsonValue::Int(transitions as i64)),
        ],
        vec![
            ("rows", JsonValue::Arr(rows)),
            ("breaker", JsonValue::obj(vec![
                ("threshold", JsonValue::Int(3)),
                ("calls", JsonValue::Int(60)),
                ("fast_fail_fraction",
                 JsonValue::Num(fast_fail_fraction)),
                ("recovered", JsonValue::Bool(true)),
            ])),
            ("acceptance", JsonValue::obj(vec![
                ("all_errors_typed", JsonValue::Bool(true)),
                ("unbounded_p99_ms", JsonValue::Num(tails[0].1)),
                ("bounded_p99_ms", JsonValue::Num(tails[1].1)),
            ])),
        ]);
    write_bench_artifact("BENCH_overload.json", &doc);
    println!("every rejected request failed typed \
              (ShardSaturated/WorkShed/ShardUnavailable) ✓; the \
              bounded row's tail should sit near the service time \
              while the unbounded row's grows with the flood's \
              backlog — scheduling noise on a loaded runner moves the \
              absolute numbers, not the contrast.");
}

// =========================================================================
// Serving under load — the continuous-batching engine (PR: iteration-
// level scheduler) under a seeded session flood: an opening burst of 64
// concurrent sessions plus Poisson and bursty arrivals, mixed prompt/
// output lengths and adapter kinds (base/LoRA/IA3/prefix), ~10%
// background urgency, three tenants.  Reports p50/p90/p99 TTFT and
// inter-token latency from the engine's own clocks plus per-shard
// occupancy over exactly the serving window, and spot-checks that the
// scheduler's token streams are bit-identical to sequential
// `generate` (the full matrix lives in tests/serving.rs).  Emits
// BENCH_serving.json; a skipped record is written when artifacts are
// absent so CI's upload stays deterministic.
// =========================================================================
fn serving_load_gen(quick: bool) {
    use symbiosis::bench_harness::{bench_record, JsonValue};
    use symbiosis::coordinator::{HandleStatus, ServingRequest,
                                 TenantQuota};

    println!("\n== Serving under load: continuous batching, seeded \
              session flood (real run, sym-tiny{}) ==",
             if quick { ", quick/check mode" } else { "" });
    if !have_artifacts() {
        println!("skipped: artifacts not built");
        write_bench_artifact("BENCH_serving.json", &skipped_record(
            "serving_load_gen", quick, "artifacts not built"));
        return;
    }

    const SEED: u64 = 0x5EED_5E55_1017;
    fn splitmix64(state: &mut u64) -> u64 {
        *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = *state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
    fn unit(state: &mut u64) -> f64 {
        (splitmix64(state) >> 11) as f64 / (1u64 << 53) as f64
    }

    let n_sessions: usize = if quick { 96 } else { 384 };
    let burst = 64usize.min(n_sessions);
    let shards = 2usize;
    let slots = 96usize;
    let shard_placement = Placement::ShardedLocal { shards };
    let dep = Deployment::start_with_engine(
        engine(), &SYM_TINY, &artifact_dir(), BatchPolicy::Continuous,
        shard_placement)
        .unwrap();
    let tenants = ["ml-team", "search", "batch-jobs"];
    for t in tenants {
        dep.admission().set_quota(t, TenantQuota::unlimited());
    }
    let dir = artifact_dir();
    let adapters: [Option<Adapter>; 4] = [
        None,
        Some(Adapter::lora_from_artifacts(&SYM_TINY, &dir, 8,
                                          LoraTargets::QKVO, 2.0)
            .unwrap()),
        Some(Adapter::ia3(&SYM_TINY)),
        Some(Adapter::prefix(&SYM_TINY, 1, 4, 11)),
    ];
    let kind_names = ["base", "lora8", "ia3", "prefix4"];

    // Seeded arrival schedule (in scheduler steps): the opening burst,
    // a bursty mid-stream wave, and Poisson (exponential-gap) arrivals
    // for the rest.
    let mut rng = SEED;
    let mut arrivals: Vec<u64> = vec![0; burst];
    let wave = (n_sessions - burst).min(16);
    arrivals.extend(std::iter::repeat(12).take(wave));
    let mut t_arr = 1.0f64;
    for _ in (burst + wave)..n_sessions {
        t_arr += -(1.0 - unit(&mut rng)).ln() * 0.75;
        arrivals.push(t_arr as u64);
    }
    arrivals.sort_unstable();

    // The request mix.  Per-session golden specs are kept aside for
    // the bit-identity spot check after the run.
    let mut specs: Vec<(Vec<i32>, GenerationConfig, usize, bool)> =
        Vec::with_capacity(n_sessions);
    for i in 0..n_sessions {
        let r = splitmix64(&mut rng);
        let plen = 4 + (r % 13) as usize; // 4..=16 prompt columns
        let prompt: Vec<i32> =
            (0..plen).map(|k| ((i * 7 + k * 3 + 1) % 256) as i32)
                .collect();
        // Burst sessions decode >= 8 tokens so the opening 64 stay
        // concurrently active; the rest mix 4..=12.
        let max_tokens = if i < burst {
            8 + ((r >> 17) % 5) as usize
        } else {
            4 + ((r >> 17) % 9) as usize
        };
        let kind = i % adapters.len();
        let background = i % 10 == 9;
        specs.push((prompt, GenerationConfig::greedy(max_tokens), kind,
                    background));
    }

    let occ_before = dep.executor.stats();
    let mut srv = dep
        .serving()
        .slots(slots)
        .admit_per_step(32)
        .prefill_chunk(8)
        .build();
    let mut handles = Vec::with_capacity(n_sessions);
    let mut next_arrival = 0usize;
    let mut step_no = 0u64;
    let t0 = Instant::now();
    while next_arrival < n_sessions || srv.queued() > 0
        || srv.active() > 0
    {
        while next_arrival < n_sessions
            && arrivals[next_arrival] <= step_no
        {
            let (prompt, cfg, kind, background) =
                specs[next_arrival].clone();
            let mut req = ServingRequest::new(prompt, cfg)
                .tenant(tenants[next_arrival % tenants.len()]);
            if let Some(a) = &adapters[kind] {
                req = req.adapter(a.clone());
            }
            if background {
                req = req.background();
            }
            handles.push(srv.submit(req));
            next_arrival += 1;
        }
        srv.step().unwrap();
        step_no += 1;
        assert!(step_no < 1_000_000, "load generator never drained");
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = srv.report();
    let occ_after = dep.executor.stats();

    // Every handle must land in a terminal state: Finished for
    // foreground (quotas are unlimited here), Finished or Evicted for
    // sheddable background sessions.
    let mut evicted = 0u64;
    for (i, h) in handles.iter().enumerate() {
        match h.status() {
            HandleStatus::Finished => {}
            HandleStatus::Evicted if specs[i].3 => evicted += 1,
            other => panic!(
                "session {i} ({}, background={}) ended {other:?}",
                kind_names[specs[i].2], specs[i].3),
        }
    }
    assert!(report.max_active as u64 >= burst as u64,
            "peak concurrency {} never covered the opening burst of \
             {burst}", report.max_active);

    // Bit-identity spot check: every 16th finished foreground session
    // re-runs sequentially on a fresh session; the scheduler's stream
    // must match token-for-token.
    let mut checked = 0u64;
    for (i, h) in handles.iter().enumerate() {
        if i % 16 != 0 || h.status() != HandleStatus::Finished {
            continue;
        }
        let (prompt, cfg, kind, _) = &specs[i];
        let mut b = dep.session();
        if let Some(a) = &adapters[*kind] {
            b = b.adapter(a.clone());
        }
        let mut sess = b.build().unwrap();
        let golden = sess.generate(prompt, cfg).unwrap();
        assert_eq!(h.tokens(), golden,
                   "scheduler stream diverged from sequential generate \
                    for session {i} ({})", kind_names[*kind]);
        checked += 1;
    }
    assert!(checked > 0, "spot check never ran");

    let occ: Vec<f64> = occ_after
        .per_shard
        .iter()
        .zip(&occ_before.per_shard)
        .map(|(a, b)| {
            let busy = a.busy_secs - b.busy_secs;
            let total = busy + (a.idle_secs - b.idle_secs);
            if total <= 0.0 { 0.0 } else { busy / total }
        })
        .collect();

    println!("{n_sessions} sessions over {step_no} scheduler steps in \
              {:.2}s ({} spot-checked vs sequential ✓)",
             wall, checked);
    println!("  ttft  p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
             report.ttft.p50() * 1e3,
             report.ttft.percentile(90.0) * 1e3,
             report.ttft.p99() * 1e3);
    println!("  itl   p50 {:.2} ms  p90 {:.2} ms  p99 {:.2} ms",
             report.itl.p50() * 1e3,
             report.itl.percentile(90.0) * 1e3,
             report.itl.p99() * 1e3);
    println!("  peak {} active, {} tokens, {} evicted background, \
              occupancy {}",
             report.max_active, report.tokens_emitted, evicted,
             occ.iter()
                 .enumerate()
                 .map(|(s, o)| format!("shard{s} {:.0}%", o * 100.0))
                 .collect::<Vec<_>>()
                 .join(", "));

    let doc = bench_record(
        "serving_load_gen", quick,
        vec![
            ("model", JsonValue::Str("sym-tiny".into())),
            ("policy", JsonValue::Str("continuous".into())),
            ("shards", JsonValue::Int(shards as i64)),
            ("slots", JsonValue::Int(slots as i64)),
            ("sessions", JsonValue::Int(n_sessions as i64)),
            ("opening_burst", JsonValue::Int(burst as i64)),
            ("prefill_chunk", JsonValue::Int(8)),
            ("admit_per_step", JsonValue::Int(32)),
            ("seed", JsonValue::Str(format!("{SEED:#x}"))),
        ],
        vec![
            ("ttft_p50_ms", JsonValue::Num(report.ttft.p50() * 1e3)),
            ("ttft_p90_ms",
             JsonValue::Num(report.ttft.percentile(90.0) * 1e3)),
            ("ttft_p99_ms", JsonValue::Num(report.ttft.p99() * 1e3)),
            ("itl_p50_ms", JsonValue::Num(report.itl.p50() * 1e3)),
            ("itl_p90_ms",
             JsonValue::Num(report.itl.percentile(90.0) * 1e3)),
            ("itl_p99_ms", JsonValue::Num(report.itl.p99() * 1e3)),
        ],
        vec![
            ("submitted", JsonValue::Int(report.submitted as i64)),
            ("admitted", JsonValue::Int(report.admitted as i64)),
            ("completed", JsonValue::Int(report.completed as i64)),
            ("denied", JsonValue::Int(report.denied as i64)),
            ("evicted", JsonValue::Int(report.evicted as i64)),
            ("failed", JsonValue::Int(report.failed as i64)),
            ("tokens_emitted",
             JsonValue::Int(report.tokens_emitted as i64)),
            ("scheduler_steps", JsonValue::Int(report.steps as i64)),
            ("throttled_steps",
             JsonValue::Int(report.throttled_steps as i64)),
            ("max_active", JsonValue::Int(report.max_active as i64)),
            ("equivalence_checked", JsonValue::Int(checked as i64)),
        ],
        vec![
            ("wall_secs", JsonValue::Num(wall)),
            ("shard_occupancy", JsonValue::Arr(
                occ.iter().map(|&o| JsonValue::Num(o)).collect())),
            ("acceptance", JsonValue::obj(vec![
                ("min_concurrent_sessions", JsonValue::Int(64)),
                ("max_active_covers_burst", JsonValue::Bool(true)),
                ("spot_checks_token_identical", JsonValue::Bool(true)),
            ])),
        ]);
    write_bench_artifact("BENCH_serving.json", &doc);

    let stats = dep.shutdown();
    println!("{stats}");
    println!("iteration-level scheduling keeps every shard busy across \
              the whole session mix: prefill micro-batches of new \
              arrivals interleave with in-flight decodes instead of \
              stalling them, and each session's stream stays \
              bit-identical to its sequential run ✓.");
}

/// §Paged KV cache — bytes moved per decode step and per-step latency,
/// contiguous re-gather (the pre-paged behaviour, via the `padded`
/// compat shim) vs the paged memoized `padded_view`, across prefix
/// lengths 64/256/1024.  Pure-host `KvCache` measurement: no AOT
/// artifacts or coordinator needed, so this section always runs and
/// `BENCH_kv.json` is produced on every CI runner.
///
/// The claim under test is the tentpole's O(1) property: a paged
/// decode step moves `layers * 2 * (append + view-delta)` rows no
/// matter how long the prefix is, while the contiguous baseline
/// re-copies the whole cache every step and scales linearly.
fn kv_bench(quick: bool) {
    use symbiosis::bench_harness::{bench_record, percentile_of,
                                   JsonValue};
    use symbiosis::coordinator::kv_cache::{KvCache, KvPlacement};
    use symbiosis::tensor::Tensor;

    println!("\n=== kv: paged cache bytes/decode-step vs contiguous ===");
    let layers = 4usize;
    let bh = 4usize;
    let h = 16usize;
    let steps = if quick { 8 } else { 32 };
    let prefixes = [64usize, 256, 1024];

    // Deterministic token content so both caches see identical appends
    // and the bit-identity check at the end is meaningful.
    let tok = |t: usize, layer: usize, n: usize| -> Tensor {
        let mut d = vec![0.0f32; bh * n * h];
        for (i, x) in d.iter_mut().enumerate() {
            *x = ((t * 31 + layer * 7 + i) % 997) as f32 / 997.0;
        }
        Tensor::from_f32(d, &[bh, n, h])
    };

    let mut rows: Vec<JsonValue> = Vec::new();
    let mut contig_bps: Vec<f64> = Vec::new();
    let mut paged_bps: Vec<f64> = Vec::new();
    let mut head_p50: Vec<(String, f64)> = Vec::new();

    for &prefix in &prefixes {
        // One fixed bucket per prefix keeps the memoized gather buffer
        // stable across the measured steps (a bucket change forces a
        // full re-gather, which is a real cost but not the one this
        // section isolates).
        let bucket = (prefix + steps).next_power_of_two();
        let mut contig = KvCache::new(layers, bh, h, KvPlacement::Host);
        let mut paged = KvCache::new(layers, bh, h, KvPlacement::Host);
        for l in 0..layers {
            let (k, v) = (tok(0, l, prefix), tok(1, l, prefix));
            contig.append(l, &k, &v).expect("prefill");
            paged.append(l, &k, &v).expect("prefill");
        }
        // Warm the paged view once so the steady decode state — not the
        // first gather of the prefix — is what gets measured.
        for l in 0..layers {
            paged.padded_view(l, bucket).expect("warm view");
        }
        contig.reset_copied();
        paged.reset_copied();

        let measure = |cache: &mut KvCache, use_view: bool|
                      -> (f64, f64, f64) {
            let mut lat_us = Vec::with_capacity(steps);
            for s in 0..steps {
                let t0 = Instant::now();
                for l in 0..layers {
                    cache
                        .append(l, &tok(prefix + s, l, 1),
                                &tok(prefix + s + 1, l, 1))
                        .expect("append");
                    if use_view {
                        cache.padded_view(l, bucket).expect("view");
                    } else {
                        let _ = cache.padded(l, bucket);
                    }
                }
                lat_us.push(t0.elapsed().as_secs_f64() * 1e6);
            }
            let bps = cache.copied_bytes() as f64 / steps as f64;
            (bps, percentile_of(&lat_us, 50.0),
             percentile_of(&lat_us, 95.0))
        };
        let (cb, cp50, cp95) = measure(&mut contig, false);
        let (pb, pp50, pp95) = measure(&mut paged, true);

        // Same appends, same bucket: the paged view must be
        // bit-identical to a fresh contiguous gather, per layer.
        for l in 0..layers {
            let (ck, cv) = contig.padded(l, bucket);
            let (pk, pv) = paged.padded_view(l, bucket).expect("view");
            assert_eq!(ck.as_f32(), pk.as_f32(),
                       "K mismatch: layer {l}, prefix {prefix}");
            assert_eq!(cv.as_f32(), pv.as_f32(),
                       "V mismatch: layer {l}, prefix {prefix}");
        }

        for (mode, bps, p50, p95) in
            [("contiguous", cb, cp50, cp95), ("paged", pb, pp50, pp95)]
        {
            println!("  {mode:>10} prefix {prefix:>4}: {bps:>9.0} \
                      B/step, step p50 {p50:>7.1} us, p95 {p95:>7.1} us");
            rows.push(JsonValue::obj(vec![
                ("mode", JsonValue::Str(mode.into())),
                ("prefix_tokens", JsonValue::Int(prefix as i64)),
                ("bytes_per_step", JsonValue::Num(bps)),
                ("step_p50_us", JsonValue::Num(p50)),
                ("step_p95_us", JsonValue::Num(p95)),
            ]));
            head_p50.push((format!("{mode}_p50_us_prefix{prefix}"), p50));
        }
        contig_bps.push(cb);
        paged_bps.push(pb);
    }

    // The shapes the artifact exists to pin down: contiguous traffic
    // grows ~16x from prefix 64 to 1024; paged traffic does not grow.
    assert!(contig_bps[2] / contig_bps[0] > 8.0,
            "contiguous bytes/step should scale with prefix length \
             (64: {:.0}, 1024: {:.0})", contig_bps[0], contig_bps[2]);
    assert!(paged_bps[2] < 2.0 * paged_bps[0],
            "paged bytes/step should be flat across prefix lengths \
             (64: {:.0}, 1024: {:.0})", paged_bps[0], paged_bps[2]);

    let doc = bench_record(
        "kv", quick,
        vec![
            ("layers", JsonValue::Int(layers as i64)),
            ("bh", JsonValue::Int(bh as i64)),
            ("head_dim", JsonValue::Int(h as i64)),
            ("block_tokens", JsonValue::Int(16)),
            ("decode_steps", JsonValue::Int(steps as i64)),
            ("prefix_tokens", JsonValue::Arr(
                prefixes.iter().map(|&p| JsonValue::Int(p as i64))
                    .collect())),
        ],
        head_p50.iter()
            .map(|(k, v)| (k.as_str(), JsonValue::Num(*v)))
            .collect(),
        vec![
            ("contig_bytes_per_step_prefix1024",
             JsonValue::Int(contig_bps[2] as i64)),
            ("paged_bytes_per_step_prefix1024",
             JsonValue::Int(paged_bps[2] as i64)),
        ],
        vec![
            ("rows", JsonValue::Arr(rows)),
            ("acceptance", JsonValue::obj(vec![
                ("contiguous_bytes_per_step_linear",
                 JsonValue::Bool(true)),
                ("paged_bytes_per_step_flat", JsonValue::Bool(true)),
                ("paged_view_bit_identical_to_contiguous",
                 JsonValue::Bool(true)),
            ])),
        ]);
    write_bench_artifact("BENCH_kv.json", &doc);
    println!("paged decode traffic is flat ({:.0} B/step at prefix 64 \
              vs {:.0} at 1024) while the contiguous baseline grows \
              linearly ({:.0} vs {:.0}) ✓.",
             paged_bps[0], paged_bps[2], contig_bps[0], contig_bps[2]);
}
