//! Chaos suite: the fleet's failure story under injected faults.
//!
//! The acceptance bar for the fault-tolerant fleet (ISSUE 6): for every
//! cell of the fault matrix — kill / stall / delay, at shards 1/2/4,
//! landing mid-prefill / mid-decode / mid-training-step — a client with
//! a deadline and a bounded retry budget produces output
//! **token-identical** to the fault-free run (frozen-base ops are pure,
//! respawned shards hold the same weights), and nothing deadlocks:
//! every cell runs under a hard watchdog deadline.  Recovery paths
//! covered: executor crash → fleet watchdog respawn → endpoint swap →
//! retry against the new generation; stalled shard → client deadline →
//! retry; delayed response → deadline → retry racing the stale answer.
//!
//! Seeds: `CHAOS_SEED=<n>` pins one seed (what CI's chaos job does,
//! three times); without it each fault plan runs the default seed trio.
//!
//! Deployment-level tests skip when artifacts are absent (same
//! convention as `integration.rs`); the route/plan-level tests at the
//! bottom run everywhere.

use std::path::PathBuf;
use std::sync::mpsc::{channel, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use symbiosis::config::SYM_TINY;
use symbiosis::coordinator::adapter::LoraTargets;
use symbiosis::coordinator::proto::ExecMsg;
use symbiosis::coordinator::{Adapter, BatchPolicy, Deployment,
                             FaultAction, FaultPlan, FaultRule,
                             GenerationConfig, LayerAssignment, LayerId,
                             Placement, RetryPolicy, RoutingTable,
                             ShardRoute, SymbiosisError};
use symbiosis::runtime::Engine;
use symbiosis::transport::LinkKind;

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

/// One engine (compile cache) shared by every deployment in this file.
fn engine() -> Arc<Engine> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Arc<Engine>> = OnceLock::new();
    ENGINE
        .get_or_init(|| Arc::new(Engine::new(&artifact_dir()).unwrap()))
        .clone()
}

fn deploy(shards: usize) -> Deployment {
    let placement = if shards == 1 {
        Placement::Local
    } else {
        Placement::ShardedLocal { shards }
    };
    Deployment::start_with_engine(engine(), &SYM_TINY, &artifact_dir(),
                                  BatchPolicy::NoLockstep, placement)
        .unwrap()
}

fn prompt(len: usize) -> Vec<i32> {
    (0..len).map(|i| (i * 7 + 3) as i32 % 256).collect()
}

/// The seeds a chaos run drives its fault plans with: `CHAOS_SEED` pins
/// one (CI runs the job once per fixed seed); default is a fixed trio.
fn chaos_seeds() -> Vec<u64> {
    match std::env::var("CHAOS_SEED") {
        Ok(s) => vec![s
            .trim()
            .parse()
            .expect("CHAOS_SEED must be an unsigned integer")],
        Err(_) => vec![7, 1337, 987654321],
    }
}

/// Run `f` on its own thread under a hard deadline: a cell that
/// deadlocks fails the suite instead of hanging it.
fn with_deadline<T: Send + 'static>(
    what: &str, limit: Duration,
    f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = channel();
    let handle = std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    match rx.recv_timeout(limit) {
        Ok(v) => {
            let _ = handle.join();
            v
        }
        Err(RecvTimeoutError::Disconnected) => match handle.join() {
            Err(panic) => std::panic::resume_unwind(panic),
            Ok(()) => unreachable!("sender dropped without panicking"),
        },
        Err(RecvTimeoutError::Timeout) => {
            panic!("{what}: no result within {limit:?} — deadlocked");
        }
    }
}

/// Requests one sequential layer walk sends to `target` on an
/// N-shard fleet: 4 linear ops per owned block, plus the embedding
/// (first shard) / LM head (last shard).  Used to aim a fault at a
/// specific phase of a run.
fn requests_per_walk(shards: usize, target: usize) -> u64 {
    let mut n = (SYM_TINY.n_layers / shards * 4) as u64;
    if target == 0 {
        n += 1; // embed
    }
    if target == shards - 1 {
        n += 1; // lm_head
    }
    n
}

/// The retry/deadline client profile every chaos cell runs with.
fn chaos_retry() -> RetryPolicy {
    RetryPolicy::retries(4).with_backoff(Duration::from_millis(20))
}

const CHAOS_TIMEOUT: Duration = Duration::from_millis(250);

/// Greedy generation with the chaos client profile.
fn generate(dep: &Deployment) -> Vec<Vec<i32>> {
    let mut sess = dep
        .session()
        .request_timeout(CHAOS_TIMEOUT)
        .retry(chaos_retry())
        .build()
        .unwrap();
    let out = sess
        .generate(&prompt(12), &GenerationConfig::greedy(6))
        .unwrap();
    drop(sess);
    out
}

/// Three LoRA training steps with the chaos client profile; the loss
/// trajectory is compared bit-exactly (pure ops retried verbatim give
/// identical floats).
fn train(dep: &Deployment) -> Vec<u32> {
    let lora = Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(),
                                            8, LoraTargets::QKVO, 2.0)
        .unwrap();
    let mut tr = dep
        .trainer()
        .adapter(lora)
        .request_timeout(CHAOS_TIMEOUT)
        .retry(chaos_retry())
        .lr(5e-3)
        .build()
        .unwrap();
    let tokens = prompt(12);
    let labels: Vec<i32> = (0..12).map(|i| (i * 5 + 2) as i32 % 256)
        .collect();
    (0..3)
        .map(|_| {
            tr.train_step(&tokens, &labels).unwrap().loss.to_bits()
        })
        .collect()
}

/// Tentpole acceptance: the full fault matrix.  Every cell must
/// produce output identical to the fault-free golden of the same
/// topology, under a hard deadline.
#[test]
fn chaos_matrix_recovers_token_identical() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let faults: Vec<(&str, FaultAction)> = vec![
        ("kill", FaultAction::KillShard),
        ("stall", FaultAction::Stall),
        ("delay", FaultAction::Delay(Duration::from_millis(400))),
    ];
    for shards in [1usize, 2, 4] {
        // Fault-free goldens, one per topology.
        let golden_gen = {
            let dep = deploy(shards);
            let out = generate(&dep);
            dep.shutdown();
            out
        };
        let golden_train = {
            let dep = deploy(shards);
            let out = train(&dep);
            dep.shutdown();
            out
        };
        let target = shards - 1;
        let walk = requests_per_walk(shards, target);
        for &seed in &chaos_seeds() {
            for (fault, action) in &faults {
                // (phase name, step the fault fires at, training?)
                let phases: [(&str, u64, bool); 3] = [
                    ("mid-prefill", 2, false),
                    ("mid-decode", walk + 2, false),
                    ("mid-training-step", walk + 2, true),
                ];
                for (phase, at, training) in phases {
                    let cell = format!(
                        "seed={seed} shards={shards} fault={fault} \
                         phase={phase}");
                    let plan = FaultPlan::new(seed).rule(
                        FaultRule::on(target, action.clone())
                            .from_step(at)
                            .times(1),
                    );
                    let (g_gen, g_train) =
                        (golden_gen.clone(), golden_train.clone());
                    let label = cell.clone();
                    with_deadline(&label, Duration::from_secs(120),
                                  move || {
                        let dep = deploy(shards);
                        dep.inject_faults(plan);
                        if training {
                            assert_eq!(train(&dep), g_train,
                                       "{cell}: loss trajectory \
                                        diverged after recovery");
                        } else {
                            assert_eq!(generate(&dep), g_gen,
                                       "{cell}: tokens diverged after \
                                        recovery");
                        }
                        dep.shutdown();
                    });
                }
            }
        }
    }
}

/// Three *pipelined* LoRA training steps (2 micro-batches over batch 2)
/// with the chaos client profile — the GPipe wavefront under faults.
fn train_pipelined(dep: &Deployment) -> Vec<u32> {
    let lora = Adapter::lora_from_artifacts(&SYM_TINY, &artifact_dir(),
                                            8, LoraTargets::QKVO, 2.0)
        .unwrap();
    let mut tr = dep
        .trainer()
        .adapter(lora)
        .batch(2)
        .micro_batches(2)
        .request_timeout(CHAOS_TIMEOUT)
        .retry(chaos_retry())
        .lr(5e-3)
        .build()
        .unwrap();
    let tokens: Vec<i32> =
        (0..24).map(|i| (i * 7 + 3) as i32 % 256).collect();
    let labels: Vec<i32> =
        (0..24).map(|i| (i * 5 + 2) as i32 % 256).collect();
    (0..3)
        .map(|_| {
            tr.train_step(&tokens, &labels).unwrap().loss.to_bits()
        })
        .collect()
}

/// ISSUE 10 satellite: kill a shard mid-*backward* while the pipelined
/// trainer's wavefront is draining — the per-micro-batch retry rides
/// the respawn and the recovered loss trajectory stays bit-identical
/// to the fault-free pipelined run (which is itself bit-identical to
/// the sequential walk, pinned by `tests/training_pipeline.rs`).
#[test]
fn pipelined_training_survives_shard_kill_mid_backward() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let shards = 2usize;
    let target = shards - 1;
    let golden = {
        let dep = deploy(shards);
        let out = train_pipelined(&dep);
        dep.shutdown();
        out
    };
    // Both micro-batches complete their forward walk before backward
    // starts (the loss barrier), so the target shard has answered
    // 2 x requests_per_walk forward calls when the first step's
    // backward begins: +2 lands the kill inside the backward drain.
    let at = 2 * requests_per_walk(shards, target) + 2;
    for &seed in &chaos_seeds() {
        let plan = FaultPlan::new(seed).rule(
            FaultRule::on(target, FaultAction::KillShard)
                .from_step(at)
                .times(1),
        );
        let g = golden.clone();
        with_deadline(
            &format!("pipelined mid-backward kill seed={seed}"),
            Duration::from_secs(120),
            move || {
                let dep = deploy(shards);
                dep.inject_faults(plan);
                assert_eq!(train_pipelined(&dep), g,
                           "seed={seed}: pipelined loss trajectory \
                            diverged after mid-backward recovery");
                dep.shutdown();
            },
        );
    }
}

/// Probabilistic error storm: seeded, deterministic, and fully
/// recoverable within the retry budget (each shard fires at most 6
/// faulted answers; the budget allows 4 retries per call, and errors
/// land on different calls far more often than not — the cap keeps the
/// worst case inside the budget across calls).
#[test]
fn error_storm_is_survivable_and_seed_deterministic() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let golden = {
        let dep = deploy(2);
        let out = generate(&dep);
        dep.shutdown();
        out
    };
    for &seed in &chaos_seeds() {
        let mut plan = FaultPlan::new(seed);
        for shard in 0..2 {
            plan = plan.rule(
                FaultRule::on(shard,
                              FaultAction::ErrorResponse(
                                  "storm".into()))
                    .with_probability(0.3)
                    .times(3),
            );
        }
        let out = with_deadline(
            &format!("error storm seed={seed}"),
            Duration::from_secs(120),
            move || {
                let dep = deploy(2);
                dep.inject_faults(plan);
                let out = generate(&dep);
                dep.shutdown();
                out
            },
        );
        assert_eq!(out, golden, "seed={seed} diverged under the storm");
    }
}

/// Supervision: crash a shard executor directly; the fleet watchdog
/// must observe the dead join handle, respawn the shard on its
/// retained seed, and bump the route epoch — after which a *new*
/// session (no retry needed) generates exactly the pre-crash tokens.
#[test]
fn watchdog_respawns_a_crashed_shard() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    let golden = generate(&dep);
    assert!(dep.executor.is_alive(1));
    assert_eq!(dep.executor.route_epoch(1), 0);
    // Simulated hard crash of shard 1 (the LM-head owner).
    dep.executor
        .sender_for(LayerId::LmHead)
        .send(ExecMsg::Crash)
        .unwrap();
    let t0 = Instant::now();
    while !(dep.executor.is_alive(1) && dep.executor.respawns() >= 1) {
        assert!(t0.elapsed() < Duration::from_secs(10),
                "watchdog never respawned the crashed shard");
        std::thread::sleep(Duration::from_millis(5));
    }
    assert!(dep.executor.route_epoch(1) >= 1,
            "respawn must bump the route epoch");
    let after = generate(&dep);
    assert_eq!(after, golden,
               "respawned shard diverged from the original");
    let stats = dep.shutdown();
    assert_eq!(stats.n_shards(), 2);
    assert!(stats.requests_served > 0);
}

/// Swap-under-fault cell: a foreground generation swaps a background
/// session's KV blocks to the host ledger; the LM-head shard is then
/// crashed and respawned *while those blocks sit on the host*.  The
/// background session must fault its blocks back in against the
/// respawned fleet and finish token-identical to a fault-free,
/// unconstrained run — and the swap traffic must reach `FleetStats`.
#[test]
fn swapped_kv_survives_shard_crash_and_respawn() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    use symbiosis::coordinator::proto::Urgency;
    use symbiosis::coordinator::UrgencyPolicy;
    use symbiosis::device::MemoryLedger;

    // fault-free, unconstrained reference tokens
    let golden = {
        let dep = deploy(2);
        let out = generate(&dep);
        dep.shutdown();
        out
    };

    for &seed in &chaos_seeds() {
        let dep = deploy(2);
        // sym-tiny 16-token block: 2 (K+V) * 4 bh * 16 t * 16 h * 4 B.
        // The foreground run ends at 17 tokens = 8 blocks; 9 leave one
        // spare so its growth must displace the background's 4 blocks.
        let block: u64 = 2 * 4 * 16 * 16 * 4;
        dep.client_device.lock().unwrap().ledger =
            MemoryLedger::new(9 * block);

        let mut bg = dep
            .session()
            .request_timeout(CHAOS_TIMEOUT)
            .retry(chaos_retry())
            .urgency(UrgencyPolicy {
                prefill: Urgency::Background,
                decode: Urgency::Background,
            })
            .build()
            .unwrap();
        bg.prefill(&prompt(12)).unwrap();

        let mut fg = dep
            .session()
            .request_timeout(CHAOS_TIMEOUT)
            .retry(chaos_retry())
            .build()
            .unwrap();
        let fg_out = fg
            .generate(&prompt(12), &GenerationConfig::greedy(6))
            .unwrap();
        assert_eq!(fg_out, golden,
                   "seed={seed}: foreground diverged under KV pressure");
        assert!(dep.kv_pool.swap_stats().swap_outs > 0,
                "seed={seed}: foreground growth swapped nothing");

        // crash the LM-head owner while bg's blocks sit on the host
        dep.executor
            .sender_for(LayerId::LmHead)
            .send(ExecMsg::Crash)
            .unwrap();
        let t0 = Instant::now();
        while !(dep.executor.is_alive(1) && dep.executor.respawns() >= 1)
        {
            assert!(t0.elapsed() < Duration::from_secs(10),
                    "seed={seed}: watchdog never respawned the shard");
            std::thread::sleep(Duration::from_millis(5));
        }

        drop(fg);
        for _ in 1..6 {
            bg.decode_step().unwrap();
        }
        assert_eq!(bg.generated[0], golden[0],
                   "seed={seed}: background tokens corrupted across \
                    swap + crash + respawn");
        drop(bg);
        let stats = dep.shutdown();
        assert!(stats.kv_swap_outs > 0,
                "seed={seed}: swap-outs missing from FleetStats");
        assert!(stats.kv_fault_ins > 0,
                "seed={seed}: fault-ins missing from FleetStats");
    }
}

/// Rolling restart: respawning a *live* shard under a session built
/// before the respawn.  The endpoint swap migrates the session without
/// rebuilding its table; retired-generation statistics stay in the
/// fleet totals.
#[test]
fn respawn_is_transparent_to_live_sessions() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    let mut sess = dep
        .session()
        .retry(chaos_retry())
        .build()
        .unwrap();
    let before = sess
        .generate(&prompt(12), &GenerationConfig::greedy(6))
        .unwrap();
    let served_before = dep.executor.stats().requests_served;
    dep.executor.respawn_shard(1).unwrap();
    assert_eq!(dep.executor.route_epoch(1), 1);
    assert_eq!(dep.executor.respawns(), 1);
    assert!(dep.executor.is_alive(1));
    sess.reset().unwrap();
    let after = sess
        .generate(&prompt(12), &GenerationConfig::greedy(6))
        .unwrap();
    assert_eq!(after, before,
               "session diverged across a rolling respawn");
    drop(sess);
    let stats = dep.shutdown();
    assert!(stats.requests_served >= 2 * served_before,
            "retired-generation requests vanished from fleet stats: \
             {} < 2*{served_before}", stats.requests_served);
}

/// Satellite: `Deployment::shutdown` with sessions still registered
/// must not hang, and the orphaned session's next call fails with a
/// typed error, fast.
#[test]
fn shutdown_with_live_sessions_is_typed_not_hung() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(2);
    let mut a = dep.session().build().unwrap();
    let mut b = dep.session().build().unwrap();
    assert_eq!(dep.executor.barrier().registered(), 2);
    a.prefill(&prompt(8)).unwrap();
    drop(a);
    assert_eq!(dep.executor.barrier().registered(), 1,
               "deregistration must drain the fleet barrier");
    // Shut the fleet down under b's feet.
    with_deadline("shutdown with a live session",
                  Duration::from_secs(60), move || {
        dep.shutdown();
    });
    let err = with_deadline("post-shutdown generate",
                            Duration::from_secs(60), move || {
        let e = b
            .generate(&prompt(8), &GenerationConfig::greedy(2))
            .unwrap_err();
        drop(b); // deregister against the dead fleet must not hang
        e
    });
    match err {
        SymbiosisError::ExecutorFailed { message, .. } => {
            assert!(message.contains("gone"),
                    "unexpected message: {message}");
        }
        other => panic!("expected ExecutorFailed, got {other}"),
    }
}

/// Satellite: a stalled shard is deadline-visible.  A client with a
/// request timeout and no retry budget gets a typed
/// `DeadlineExceeded` naming the shard instead of hanging — and after
/// disarming the plan the deployment serves new clients and shuts down
/// cleanly (the stalled request never reached the executor).
#[test]
fn stalled_shard_is_deadline_visible_not_hung() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let dep = deploy(1);
    dep.inject_faults(FaultPlan::new(5).rule(
        FaultRule::on(0, FaultAction::Stall),
    ));
    let mut sess = dep
        .session()
        .request_timeout(CHAOS_TIMEOUT)
        .build()
        .unwrap();
    let err = with_deadline("prefill against a stalled shard",
                            Duration::from_secs(60), move || {
        let e = sess.prefill(&prompt(8)).unwrap_err();
        drop(sess); // releases the interposer and its parked request
        e
    });
    match err {
        SymbiosisError::DeadlineExceeded { shard, waited, .. } => {
            assert_eq!(shard, 0);
            assert!(waited >= CHAOS_TIMEOUT,
                    "deadline fired early: {waited:?}");
        }
        other => panic!("expected DeadlineExceeded, got {other}"),
    }
    dep.clear_faults();
    let mut fresh = dep.session().build().unwrap();
    fresh.prefill(&prompt(8)).unwrap();
    drop(fresh);
    with_deadline("shutdown after a stall", Duration::from_secs(60),
                  move || {
        dep.shutdown();
    });
}

// ------------------------------------------------------------------
// Route/plan-level chaos: runs without artifacts.
// ------------------------------------------------------------------

/// The default seed trio is fixed and distinct; `CHAOS_SEED` overrides.
#[test]
fn chaos_seed_selection() {
    let seeds = chaos_seeds();
    if std::env::var("CHAOS_SEED").is_ok() {
        assert_eq!(seeds.len(), 1);
    } else {
        assert_eq!(seeds, vec![7, 1337, 987654321]);
    }
}

/// Re-export + typed-error wiring: a mismatched routing table is a
/// `MalformedRoutingTable` error through the public API, not a panic.
#[test]
fn routing_table_mismatch_is_typed_via_public_api() {
    let (tx, _rx) = channel();
    let err = RoutingTable::new(
        LayerAssignment::contiguous(SYM_TINY.n_layers, 2),
        vec![ShardRoute::new(tx, LinkKind::SharedLocal)],
    )
    .unwrap_err();
    assert!(matches!(err,
                     SymbiosisError::MalformedRoutingTable {
                         shards: 2,
                         routes: 1
                     }));
}

/// A fault plan is deterministic across independent wraps of the same
/// seed — the property CI's fixed-seed chaos job relies on.
#[test]
fn fault_plan_is_deterministic_across_wraps() {
    use symbiosis::coordinator::proto::{LayerRequest, LayerResponse,
                                        OpKind, Urgency};
    use symbiosis::tensor::Tensor;
    let pattern = |seed: u64| -> Vec<bool> {
        let (exec_tx, exec_rx) = channel();
        // echo executor
        std::thread::spawn(move || {
            while let Ok(msg) = exec_rx.recv() {
                if let ExecMsg::Request(req) = msg {
                    let _ = req.resp.send(LayerResponse {
                        y: Ok(req.x.clone()),
                        queue_wait_secs: 0.0,
                        batch_clients: 1,
                    });
                }
            }
        });
        let plan = FaultPlan::new(seed).rule(
            FaultRule::on(0, FaultAction::ErrorResponse("p".into()))
                .with_probability(0.5),
        );
        let tx = plan.wrap(0, exec_tx);
        (0..24)
            .map(|_| {
                let (rtx, rrx) = channel();
                tx.send(ExecMsg::Request(LayerRequest {
                    client_id: 0,
                    layer: LayerId::Qkv(0),
                    op: OpKind::Forward,
                    x: Tensor::zeros(&[1, 4]),
                    positions: None,
                    urgency: Urgency::Bulk,
                    resp: rtx,
                }))
                .unwrap();
                rrx.recv().unwrap().y.is_err()
            })
            .collect()
    };
    for &seed in &chaos_seeds() {
        assert_eq!(pattern(seed), pattern(seed),
                   "seed {seed} not reproducible");
    }
}
