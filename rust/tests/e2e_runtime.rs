//! Engine-level integration: load AOT artifacts, execute, compare
//! against python-side goldens. Requires `make artifacts` to have run.

use std::path::PathBuf;

use symbiosis::runtime::Engine;
use symbiosis::tensor::{container, ops, Tensor};

fn artifact_dir() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn have_artifacts() -> bool {
    artifact_dir().join("manifest.txt").exists()
}

#[test]
fn linear_fwd_matches_native_matmul() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::new(&artifact_dir()).unwrap();
    // deterministic input
    let t = 8;
    let x = Tensor::from_f32(
        (0..t * 64).map(|i| ((i % 13) as f32 - 6.0) * 0.1).collect(),
        &[t, 64],
    );
    let w = Tensor::from_f32(
        (0..64 * 192).map(|i| ((i % 7) as f32 - 3.0) * 0.05).collect(),
        &[64, 192],
    );
    let b = Tensor::from_f32((0..192).map(|i| i as f32 * 0.01).collect(),
                             &[192]);
    let out = engine
        .execute("linear_fwd_t8_64x192", &[&x, &w, &b])
        .unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].shape, vec![8, 192]);
    let mut want = ops::matmul(&x, &w);
    for r in 0..t {
        for c in 0..192 {
            want.as_f32_mut()[r * 192 + c] += b.as_f32()[c];
        }
    }
    assert!(out[0].max_abs_diff(&want) < 1e-4,
            "diff {}", out[0].max_abs_diff(&want));
}

#[test]
fn linear_bwd_is_dy_w_transpose() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::new(&artifact_dir()).unwrap();
    let dy = Tensor::from_f32(
        (0..8 * 192).map(|i| ((i % 11) as f32 - 5.0) * 0.1).collect(),
        &[8, 192],
    );
    let w = Tensor::from_f32(
        (0..64 * 192).map(|i| ((i % 5) as f32 - 2.0) * 0.1).collect(),
        &[64, 192],
    );
    let out = engine.execute("linear_bwd_t8_64x192", &[&dy, &w]).unwrap();
    // want: dy @ w^T
    let mut wt = vec![0.0f32; 192 * 64];
    for i in 0..64 {
        for j in 0..192 {
            wt[j * 64 + i] = w.as_f32()[i * 192 + j];
        }
    }
    let want = ops::matmul(&dy, &Tensor::from_f32(wt, &[192, 64]));
    assert!(out[0].max_abs_diff(&want) < 1e-4);
}

#[test]
fn engine_validates_shapes() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::new(&artifact_dir()).unwrap();
    let bad = Tensor::zeros(&[4, 64]); // artifact wants t=8
    let w = Tensor::zeros(&[64, 192]);
    let b = Tensor::zeros(&[192]);
    assert!(engine.execute("linear_fwd_t8_64x192", &[&bad, &w, &b]).is_err());
    assert!(engine.execute("nonexistent", &[]).is_err());
}

#[test]
fn weights_and_golden_load() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let weights =
        container::read_tensors(&artifact_dir().join("weights_sym-tiny.bin"))
            .unwrap();
    assert_eq!(weights["embed"].shape, vec![256, 64]);
    assert_eq!(weights["l0.wqkv"].shape, vec![64, 192]);
    let golden =
        container::read_tensors(&artifact_dir().join("golden_sym-tiny.bin"))
            .unwrap();
    assert_eq!(golden["tokens16"].shape, vec![16]);
    assert_eq!(golden["base_logits16"].shape, vec![16, 256]);
}

#[test]
fn adam_artifact_steps_against_gradient() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::new(&artifact_dir()).unwrap();
    let n = 1024;
    let p = Tensor::from_f32(vec![1.0; n], &[n]);
    let g = Tensor::from_f32(
        (0..n).map(|i| if i % 2 == 0 { 0.5 } else { -0.5 }).collect(),
        &[n],
    );
    let m = Tensor::zeros(&[n]);
    let v = Tensor::zeros(&[n]);
    let t = Tensor::scalar_f32(1.0);
    let out = engine.execute("adam_n1024", &[&p, &g, &m, &v, &t]).unwrap();
    assert_eq!(out.len(), 3);
    let p2 = &out[0];
    // positive grad -> param decreases; negative grad -> increases
    assert!(p2.as_f32()[0] < 1.0);
    assert!(p2.as_f32()[1] > 1.0);
}

#[test]
fn attention_decode_ignores_padding() {
    if !have_artifacts() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let engine = Engine::new(&artifact_dir()).unwrap();
    let bh = 4;
    let s = 16;
    let h = 16;
    let q = Tensor::from_f32(
        (0..bh * h).map(|i| (i as f32 * 0.01).sin()).collect(),
        &[bh, 1, h],
    );
    let mk = |seed: f32| {
        Tensor::from_f32(
            (0..bh * s * h).map(|i| ((i as f32) * seed).cos() * 0.3)
                .collect(),
            &[bh, s, h],
        )
    };
    let (k, v) = (mk(0.013), mk(0.027));
    let kv_len = Tensor::scalar_i32(10);
    let base = engine
        .execute("attn_decode_bh4_s16_h16", &[&q, &k, &v, &kv_len])
        .unwrap();
    // poison the padded tail; output must be unchanged
    let mut k2 = k.clone();
    let mut v2 = v.clone();
    for i in bh * 10 * h..bh * s * h {
        k2.as_f32_mut()[i % (bh * s * h)] = 1e6;
        v2.as_f32_mut()[i % (bh * s * h)] = -1e6;
    }
    // poison only positions >= 10 per (bh) row
    let mut k3 = k.clone();
    let mut v3 = v.clone();
    for b in 0..bh {
        for p in 10..s {
            for c in 0..h {
                k3.as_f32_mut()[(b * s + p) * h + c] = 1e6;
                v3.as_f32_mut()[(b * s + p) * h + c] = -1e6;
            }
        }
    }
    let poisoned = engine
        .execute("attn_decode_bh4_s16_h16", &[&q, &k3, &v3, &kv_len])
        .unwrap();
    assert!(base[0].max_abs_diff(&poisoned[0]) < 1e-5);
}
